//! Quickstart: the whole Parallax pipeline on one model, end to end.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds the CLIP text encoder graph, partitions it (§3.1), extracts
//! the Branch-Layer structure (Algorithms 1–4), plans per-branch arenas
//! (§3.2), schedules under a memory budget (§3.3), and compares the
//! simulated Parallax latency against the sequential baselines.

use parallax::baselines::{Framework, Pipeline};
use parallax::branch::{self, DEFAULT_BETA};
use parallax::device::SocProfile;
use parallax::memory;
use parallax::models::ModelKind;
use parallax::partition::{partition, CostModel};
use parallax::sched::SchedCfg;
use parallax::sim::Mode;

fn main() -> anyhow::Result<()> {
    let model = ModelKind::ClipText;
    let soc = SocProfile::pixel6();

    // 1. graph analysis
    let g = model.build();
    println!("1. graph: {} — {} nodes, {} edges", g.name, g.num_nodes(), g.num_edges());

    // 2. delegate partitioning (§3.1 cost model)
    let p = partition(&g, &CostModel::default());
    println!(
        "2. partition: {} delegate regions kept, {} pruned back to CPU",
        p.regions.len(),
        p.pruned.len()
    );

    // 3. branch/layer extraction (Algorithms 1-4)
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let (layers, par, maxb) = plan.table7_metrics();
    println!(
        "3. branch-layer: {} branches in {} layers ({} parallelizable, \
         up to {} concurrent)",
        plan.branches.len(),
        layers,
        par,
        maxb
    );

    // 4. branch-aware memory (§3.2)
    let mems = memory::branch_memories(&g, &p, &plan);
    let fp = memory::parallax_footprint(&g, &p, &plan);
    let biggest = mems.iter().map(|m| m.total()).max().unwrap_or(0);
    println!(
        "4. memory: arena pool {:.1} MB + boundary {:.1} MB (largest branch {:.2} MB)",
        fp.arena_pool_bytes as f64 / 1e6,
        fp.boundary_bytes as f64 / 1e6,
        biggest as f64 / 1e6
    );

    // 5. simulate the paper's protocol on all four frameworks
    println!("5. simulated latency on {} (CPU-only, 20 runs):", soc.display_name());
    for fw in Framework::ALL {
        let pipe = Pipeline::build(fw, model, &soc, Mode::CpuOnly, SchedCfg::default())
            .expect("cpu mode always builds");
        let runs = pipe.run_protocol(20, 42);
        let lats: Vec<f64> = runs.iter().map(|r| r.latency_s * 1e3).collect();
        let min = lats.iter().cloned().fold(f64::MAX, f64::min);
        let max = lats.iter().cloned().fold(0.0, f64::max);
        println!("   {:<12} {:>6.1} / {:>6.1} ms (min/max)", format!("{fw:?}"), min, max);
    }
    Ok(())
}
