//! End-to-end serving driver — the repo's E2E validation workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_text_encoders
//! ```
//!
//! Loads the AOT PJRT artifacts, builds *real* execution engines for the
//! CLIP text encoder and DistilBERT (transformer blocks run as compiled
//! XLA executables, glue ops on host kernels), registers both behind the
//! serving front-end, and drives a batched request load.  Reports
//! latency/throughput and verifies that parallel and sequential
//! schedules produce identical outputs (§3.2 isolation invariant).
//! Results are recorded in EXPERIMENTS.md §E2E.

use std::sync::Arc;

use parallax::branch::{self, DEFAULT_BETA};
use parallax::exec::Engine;
use parallax::memory::branch_memories;
use parallax::models::ModelKind;
use parallax::partition::{partition, CostModel};
use parallax::runtime::{default_artifact_dir, RuntimePool};
use parallax::sched::{self, SchedCfg};
use parallax::serve::{FnExecutor, Server};

struct ModelCtx {
    graph: parallax::graph::Graph,
    partition: parallax::partition::Partition,
    plan: parallax::branch::BranchPlan,
    schedules: Vec<parallax::sched::LayerSchedule>,
}

fn build_ctx(model: ModelKind, threads: usize) -> ModelCtx {
    let graph = model.build();
    // CPU-only fallback view: everything is a fallback branch (the
    // serving host has no NNAPI accelerator; PJRT artifacts play the
    // role of the optimised fallback kernels).
    let p = partition(
        &graph,
        &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
    );
    let plan = branch::plan(&graph, &p, DEFAULT_BETA);
    let mems = branch_memories(&graph, &p, &plan);
    let cfg = SchedCfg { max_threads: threads, margin: 0.4 };
    let schedules = sched::schedule(&plan, &mems, 1 << 34, &cfg);
    ModelCtx { graph, partition: p, plan, schedules }
}

fn main() -> anyhow::Result<()> {
    anyhow::ensure!(
        parallax::runtime::artifacts_available(),
        "artifacts missing — run `make artifacts` first"
    );
    let t0 = std::time::Instant::now();
    let pool = Arc::new(RuntimePool::new(default_artifact_dir(), 2)?);
    println!("PJRT pool up: {} workers, {} programs", pool.size(), pool.manifest().len());

    // warm the executables used by the two encoders
    pool.warm(&[
        "attn_77x512_h8",
        "ffn_77x512x2048",
        "layernorm_77x512",
        "attn_128x768_h12",
        "ffn_128x768x3072",
        "layernorm_128x768",
    ])?;
    println!("executable cache warm in {:.2}s", t0.elapsed().as_secs_f64());

    // sanity: parallel schedule == sequential schedule, bit-for-bit
    {
        let ctx = build_ctx(ModelKind::ClipText, 6);
        let engine = Engine::new(&ctx.graph, &ctx.partition, &ctx.plan, Some(&pool));
        println!(
            "CLIP engine: {} PJRT-runnable blocks discovered",
            engine.num_blocks()
        );
        let mems = branch_memories(&ctx.graph, &ctx.partition, &ctx.plan);
        let seq = sched::schedule(&ctx.plan, &mems, 1 << 34, &SchedCfg { max_threads: 1, margin: 0.4 });
        let (v_par, st_par) = engine.run(&ctx.schedules)?;
        let (v_seq, st_seq) = engine.run(&seq)?;
        anyhow::ensure!(v_par.all_finite(), "non-finite outputs");
        anyhow::ensure!(
            v_par.checksum() == v_seq.checksum(),
            "parallel vs sequential outputs diverge!"
        );
        println!(
            "isolation check OK: checksum {:.6} (parallel {:.0} ms, sequential {:.0} ms, \
             {} PJRT calls, {} host ops)",
            v_par.checksum(),
            st_par.wall_s * 1e3,
            st_seq.wall_s * 1e3,
            st_par.pjrt_calls,
            st_par.host_ops
        );
    }

    // serving load over both encoders; contexts live for the process
    // lifetime so each lane reuses one engine (weight caches warm).
    let mut server = Server::new();
    for model in [ModelKind::ClipText, ModelKind::DistilBert] {
        let ctx: &'static ModelCtx = Box::leak(Box::new(build_ctx(model, 6)));
        let pool_ref: &'static RuntimePool =
            Box::leak(Box::new(RuntimePool::new(default_artifact_dir(), 1)?));
        let engine = Engine::new(&ctx.graph, &ctx.partition, &ctx.plan, Some(pool_ref));
        server.register(
            model.slug(),
            Box::new(FnExecutor(move |_seed| {
                let t = std::time::Instant::now();
                let (values, _stats) = engine.run(&ctx.schedules)?;
                Ok((t.elapsed().as_secs_f64(), values.checksum()))
            })),
        );
    }

    let n = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16usize);
    let report = server.run_load(&["clip-text", "distilbert"], n, 4, 3)?;
    println!(
        "\nserved {n} real inferences: {:.2} req/s (wall {:.2}s)",
        report.throughput_rps, report.wall_s
    );
    for (model, s) in &report.latency {
        println!(
            "  {model:<12} p50 {:>7.1} ms  p95 {:>7.1} ms  max {:>7.1} ms  (n={})",
            s.p50 * 1e3,
            s.p95 * 1e3,
            s.max * 1e3,
            s.n
        );
    }
    // determinism across requests of the same model
    let mut sums: std::collections::HashMap<&str, Vec<f64>> = Default::default();
    for r in &report.responses {
        sums.entry(if r.model == "clip-text" { "clip-text" } else { "distilbert" })
            .or_default()
            .push(r.checksum);
    }
    for (m, cs) in sums {
        anyhow::ensure!(
            cs.iter().all(|&c| c == cs[0]),
            "{m}: outputs varied across identical requests"
        );
    }
    println!("determinism across requests OK");
    Ok(())
}
