//! Heterogeneous placement & co-execution walkthrough: the whole
//! placement pipeline on one fallback-heavy model, annotated step by
//! step.
//!
//! ```bash
//! cargo run --release --example heterogeneous
//! ```
//!
//! 1. Partition with the device-derived cost model
//!    (`CostModel::from_profile` — Appendix B thresholds from the
//!    `SocProfile`).
//! 2. Extract branches/layers (§3.1) and assign each branch a
//!    `Placement` by modelled per-lane latency (`place::assign` —
//!    load-balanced across the SoC's accelerator lanes).
//! 3. Execute: delegated branches on persistent per-lane delegate
//!    workers overlapping the CPU fallback waves
//!    (`Engine::run_placed`), with the governor lease covering the
//!    in-flight delegate-I/O staging.
//! 4. Cross-check against the CPU-only-forced run: bit-identical
//!    outputs, strictly fewer CPU-wave branch executions.

use parallax::branch::{self, DEFAULT_BETA};
use parallax::device::SocProfile;
use parallax::exec::Engine;
use parallax::memory::branch_memories;
use parallax::models::micro;
use parallax::partition::{partition, CostModel};
use parallax::place::{self, PlacePolicy, Placement, PlacementPlan};
use parallax::sched::{self, MemoryGovernor, SchedCfg};

fn main() -> anyhow::Result<()> {
    let soc = SocProfile::pixel6();
    println!("device: {} — {} accelerator lane(s):", soc.display_name(), soc.lanes.len());
    for (i, lane) in soc.lanes.iter().enumerate() {
        println!(
            "  lane {i} ({}): {:.1} TFLOP/s @ {:.0}% util, dispatch {:.2} ms{}",
            lane.name,
            lane.flops / 1e12,
            lane.utilization * 100.0,
            lane.dispatch_s * 1e3,
            if lane.reachable { "" } else { "  [UNREACHABLE]" },
        );
    }
    println!();

    // -- 1. model + device-derived partition ---------------------------
    let g = micro::fallback_heavy(6, 24, 448, 4);
    let cm = CostModel::from_profile(&soc);
    println!(
        "cost model from profile: N>=3, F>={:.1} MFLOP, B/F<={:.4} B/FLOP",
        cm.min_flops as f64 / 1e6,
        cm.max_bytes_per_flop
    );
    let p = partition(&g, &cm);
    println!(
        "partition: {} delegate region(s), {} CPU fallback node(s), {} pruned\n",
        p.regions.len(),
        p.cpu_nodes(),
        p.pruned.len()
    );

    // -- 2. branches, layers, placement --------------------------------
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let placed = place::assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
    for b in 0..plan.branches.len() {
        let tag = match placed.assignment[b] {
            Placement::Delegate(lane) => format!("LANE {lane} ({})", soc.lanes[lane].name),
            Placement::CpuPool => "cpu".to_string(),
        };
        println!(
            "branch {b:>2}: {:>12}  modelled cpu {:>8.3} ms  delegate {:>8}  \
             staging {:>6.1} KB",
            tag,
            placed.cpu_latency_s[b] * 1e3,
            if placed.delegate_latency_s[b].is_finite() {
                format!("{:.3} ms", placed.delegate_latency_s[b] * 1e3)
            } else {
                "-".to_string()
            },
            placed.staging_bytes[b] as f64 / 1e3,
        );
    }

    // -- 3. co-execute under a governor --------------------------------
    let mems = branch_memories(&g, &p, &plan);
    let engine = Engine::new(&g, &p, &plan, None);
    let cfg = SchedCfg { max_threads: 2, margin: 0.4 };
    let schedules = sched::schedule(&plan, &mems, 1 << 31, &cfg);
    let gov = MemoryGovernor::new(u64::MAX);
    let t = std::time::Instant::now();
    let (v_coex, st_coex) = engine.run_placed(&schedules, &placed, Some(&gov))?;
    let coex_ms = t.elapsed().as_secs_f64() * 1e3;
    println!(
        "\nco-execution: {:.0} ms wall, {} CPU-wave branches + {} delegate job(s), \
         modelled acc busy {:.2} ms, peak lease {:.1} KB",
        coex_ms,
        st_coex.cpu_branch_runs,
        st_coex.delegate_jobs,
        st_coex.acc_modelled_s * 1e3,
        gov.peak_reserved() as f64 / 1e3,
    );

    // -- 4. CPU-only-forced cross-check --------------------------------
    let forced = PlacementPlan::cpu_only(plan.branches.len());
    let t = std::time::Instant::now();
    let (v_cpu, st_cpu) = engine.run_placed(&schedules, &forced, None)?;
    let cpu_ms = t.elapsed().as_secs_f64() * 1e3;
    println!(
        "cpu-only forced: {:.0} ms wall, {} CPU-wave branches",
        cpu_ms, st_cpu.cpu_branch_runs
    );
    anyhow::ensure!(
        v_cpu.checksum() == v_coex.checksum(),
        "placement must never change results"
    );
    println!(
        "outputs bit-identical; co-execution saved {:.0} ms ({:.2}x)",
        cpu_ms - coex_ms,
        cpu_ms / coex_ms.max(1e-9)
    );
    Ok(())
}
