//! Detector pipeline scenario: YOLOv8n frames through the simulated
//! device stack — the paper's motivating real-time workload.
//!
//! ```bash
//! cargo run --release --example detector_pipeline [frames]
//! ```
//!
//! Streams a synthetic camera trace (variable detection counts → the
//! dynamic NMS tail), comparing Parallax against the baselines on every
//! device for both execution modes, and prints an FPS table.

use parallax::baselines::{Framework, Pipeline};
use parallax::device::SocProfile;
use parallax::models::ModelKind;
use parallax::sched::SchedCfg;
use parallax::sim::Mode;
use parallax::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let frames: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(60);
    println!("camera trace: {frames} frames, variable scene complexity\n");

    for make in SocProfile::ALL {
        let soc = make();
        println!("== {} ==", soc.display_name());
        println!(
            "{:<12} {:>6} {:>10} {:>10} {:>12} {:>10}",
            "framework", "mode", "mean ms", "p95 ms", "fps(mean)", "energy mJ"
        );
        for fw in Framework::ALL {
            for mode in [Mode::CpuOnly, Mode::Heterogeneous] {
                let Ok(pipe) =
                    Pipeline::build(fw, ModelKind::Yolov8n, &soc, mode, SchedCfg::default())
                else {
                    println!(
                        "{:<12} {:>6} {:>10}",
                        format!("{fw:?}"),
                        if mode == Mode::CpuOnly { "cpu" } else { "het" },
                        "-"
                    );
                    continue;
                };
                let mut rng = Rng::new(99);
                let mut lats = Vec::with_capacity(frames);
                let mut energy = 0.0;
                for _ in 0..frames {
                    // scene complexity draw: how full the NMS output is
                    let fill = 0.1 + 0.9 * rng.f64() * rng.f64();
                    let r = pipe.run(&mut rng, fill);
                    lats.push(r.latency_s * 1e3);
                    energy += r.energy_j;
                }
                let s = parallax::util::stats::summarize(&lats).unwrap();
                println!(
                    "{:<12} {:>6} {:>10.1} {:>10.1} {:>12.1} {:>10.1}",
                    format!("{fw:?}"),
                    if mode == Mode::CpuOnly { "cpu" } else { "het" },
                    s.mean,
                    s.p95,
                    1000.0 / s.mean,
                    energy / frames as f64 * 1e3
                );
            }
        }
        println!();
    }
    Ok(())
}
