//! Detector pipeline scenario: YOLOv8n frames through the simulated
//! device stack — the paper's motivating real-time workload.
//!
//! ```bash
//! cargo run --release --example detector_pipeline [frames]
//! ```
//!
//! Streams a synthetic camera trace (variable detection counts → the
//! dynamic NMS tail), comparing Parallax against the baselines on every
//! device for both execution modes, and prints an FPS table.

use parallax::baselines::{Framework, Pipeline};
use parallax::branch::{self, DEFAULT_BETA};
use parallax::ctrl::SegmentedEngine;
use parallax::device::SocProfile;
use parallax::exec::Engine;
use parallax::models::ModelKind;
use parallax::partition::{partition, CostModel};
use parallax::sched::{MemoryGovernor, SchedCfg};
use parallax::sim::Mode;
use parallax::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let frames: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(60);
    println!("camera trace: {frames} frames, variable scene complexity\n");

    for make in SocProfile::ALL {
        let soc = make();
        println!("== {} ==", soc.display_name());
        println!(
            "{:<12} {:>6} {:>10} {:>10} {:>12} {:>10}",
            "framework", "mode", "mean ms", "p95 ms", "fps(mean)", "energy mJ"
        );
        for fw in Framework::ALL {
            for mode in [Mode::CpuOnly, Mode::Heterogeneous] {
                let Ok(pipe) =
                    Pipeline::build(fw, ModelKind::Yolov8n, &soc, mode, SchedCfg::default())
                else {
                    println!(
                        "{:<12} {:>6} {:>10}",
                        format!("{fw:?}"),
                        if mode == Mode::CpuOnly { "cpu" } else { "het" },
                        "-"
                    );
                    continue;
                };
                let mut rng = Rng::new(99);
                let mut lats = Vec::with_capacity(frames);
                let mut energy = 0.0;
                for _ in 0..frames {
                    // scene complexity draw: how full the NMS output is
                    let fill = 0.1 + 0.9 * rng.f64() * rng.f64();
                    let r = pipe.run(&mut rng, fill);
                    lats.push(r.latency_s * 1e3);
                    energy += r.energy_j;
                }
                let s = parallax::util::stats::summarize(&lats).unwrap();
                println!(
                    "{:<12} {:>6} {:>10.1} {:>10.1} {:>12.1} {:>10.1}",
                    format!("{fw:?}"),
                    if mode == Mode::CpuOnly { "cpu" } else { "het" },
                    s.mean,
                    s.p95,
                    1000.0 / s.mean,
                    energy / frames as f64 * 1e3
                );
            }
        }
        println!();
    }

    // §3.4 runtime subgraph control on the real engine: the NMS output
    // count is resolved from actual tensor values, so the post-NMS path
    // leases its resolved footprint instead of the 300-box worst case.
    println!("== post-NMS path with runtime subgraph control (real engine) ==");
    let g = ModelKind::Yolov8n.build();
    let p = partition(
        &g,
        &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
    );
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);
    let governor = MemoryGovernor::new(512 << 20);
    let se = SegmentedEngine::new(&engine, SchedCfg::default(), governor.budget());
    let (values, full) = se.run(&[], Some(&governor))?;
    anyhow::ensure!(values.all_finite(), "non-finite detector outputs");
    for (sym, ext) in &full.bindings {
        println!("  resolved dynamic dim: max {sym} -> {ext} boxes");
    }
    // replay just the NMS + post-NMS tail: resolved vs max-shape lease
    let bar = se.first_barrier_segment().expect("yolo has an NMS barrier");
    let tail = bar..se.num_segments();
    let res = se.run_range(tail.clone(), &values, &[], None)?;
    let max = se.run_range_static(tail, &values, None)?;
    println!(
        "  post-NMS tail lease: {:.1} KB resolved vs {:.1} KB max-shape \
         | full-run governor peak {:.2} MB",
        res.resolved_demand as f64 / 1e3,
        max.resolved_demand as f64 / 1e3,
        governor.peak_reserved() as f64 / 1e6
    );
    Ok(())
}
