//! Whisper-Tiny autoregressive decode loop on the real engine, driven
//! by runtime subgraph control (§3.4).
//!
//! ```bash
//! cargo run --release --example whisper_decode [steps]
//! ```
//!
//! The encoder prefix executes once at its static shapes; every decode
//! step then re-runs only the decoder segments with the current token
//! count bound as the dynamic-dim extent.  Per step the demo reports
//! the resolved-shape governor lease vs the max-shape plan's, and the
//! plan-cache hit rate (steps sharing a power-of-two length bucket pay
//! planning once).  At the end it re-runs one step on a single-thread
//! engine and checks bit-identical outputs — the §3.2 isolation
//! invariant extended to the dynamic path.

use parallax::branch::{self, DEFAULT_BETA};
use parallax::ctrl::SegmentedEngine;
use parallax::exec::{Engine, Values};
use parallax::models::{whisper_tiny, ModelKind};
use parallax::partition::{partition, CostModel};
use parallax::sched::{MemoryGovernor, SchedCfg};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16)
        .clamp(1, whisper_tiny::MAX_DEC_T);

    let g = ModelKind::WhisperTiny.build();
    let p = partition(
        &g,
        &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
    );
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);
    let governor = MemoryGovernor::new(512 << 20);
    let se = SegmentedEngine::new(&engine, SchedCfg::default(), governor.budget());

    let bar = se.first_barrier_segment().expect("whisper has control flow");
    let n = se.num_segments();
    println!(
        "whisper-tiny: {} nodes, {} branches, {} control segments (decode starts at segment {bar})",
        g.num_nodes(),
        plan.branches.len(),
        n
    );
    for (i, seg) in se.seg_plan().segments.iter().enumerate() {
        if let Some(b) = seg.barrier {
            println!("  segment {i}: barrier `{}` ({})", g.node(b).name, g.node(b).kind.mnemonic());
        }
    }

    // encoder prefix once, at its static shapes
    let values = Values::default();
    let t0 = std::time::Instant::now();
    let enc = se.run_range_static(0..bar, &values, Some(&governor))?;
    println!(
        "\nencoder prefix: {} segments, {} host ops, {:.0} ms\n",
        enc.segments_run,
        enc.exec.host_ops,
        t0.elapsed().as_secs_f64() * 1e3
    );

    println!(
        "{:>5} {:>14} {:>14} {:>8} {:>10}",
        "step", "lease KB", "max-plan KB", "cache", "wall ms"
    );
    let mut total_resolved = 0u64;
    let mut total_max = 0u64;
    for t in 1..=steps {
        let st = std::time::Instant::now();
        let stats = se.run_range(
            bar..n,
            &values,
            &[(whisper_tiny::MAX_DEC_T, t)],
            Some(&governor),
        )?;
        total_resolved += stats.resolved_demand;
        total_max += stats.max_plan_demand;
        println!(
            "{:>5} {:>14.1} {:>14.1} {:>8} {:>10.1}",
            t,
            stats.resolved_demand as f64 / 1e3,
            stats.max_plan_demand as f64 / 1e3,
            if stats.cache_misses == 0 { "hit" } else { "miss" },
            st.elapsed().as_secs_f64() * 1e3
        );
    }
    let (hits, misses) = se.cache_stats();
    println!(
        "\nplan cache: {hits} hits / {misses} misses over {steps} steps \
         (power-of-two length buckets)"
    );
    println!(
        "decode leases: resolved {:.2} MB vs max-shape {:.2} MB summed over the loop \
         ({:.0}% returned to the ledger)",
        total_resolved as f64 / 1e6,
        total_max as f64 / 1e6,
        (1.0 - total_resolved as f64 / total_max.max(1) as f64) * 100.0
    );
    let gstats = governor.stats();
    println!(
        "governor: peak reserved {:.2} MB of {:.0} MB budget, {} grants",
        gstats.peak_reserved as f64 / 1e6,
        governor.budget() as f64 / 1e6,
        gstats.grants
    );

    // §3.2 isolation on the dynamic path: a single-thread engine must
    // produce bit-identical decode outputs.
    let mid = (steps / 2).max(1);
    let par_values = Values::default();
    se.run_range(bar..n, &par_values, &[(whisper_tiny::MAX_DEC_T, mid)], None)?;
    let se1 = SegmentedEngine::new(
        &engine,
        SchedCfg { max_threads: 1, margin: 0.4 },
        governor.budget(),
    );
    let ser_values = Values::default();
    se1.run_range(bar..n, &ser_values, &[(whisper_tiny::MAX_DEC_T, mid)], None)?;
    anyhow::ensure!(
        par_values.checksum() == ser_values.checksum(),
        "decode step {mid} diverged across thread counts"
    );
    println!("\ndecode step {mid}: bit-identical across thread counts ✓");
    Ok(())
}
