//! ASR streaming scenario: Whisper-Tiny utterances of varying length —
//! the paper's canonical dynamic-control-flow fallback workload.
//!
//! ```bash
//! cargo run --release --example asr_stream [utterances]
//! ```
//!
//! Each utterance draws a transcript-length "fill" for the dynamic
//! decoder; the memory-budget scheduler reacts to a fluctuating
//! simulated OS free-memory signal.  Prints per-utterance latency and
//! the schedule's parallel-wave utilisation, plus an ablation of the
//! §3.3 memory margin.

use parallax::baselines::{Framework, Pipeline};
use parallax::device::SocProfile;
use parallax::models::ModelKind;
use parallax::sched::SchedCfg;
use parallax::sim::Mode;
use parallax::util::rng::Rng;
use parallax::util::stats::summarize;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(30);
    let soc = SocProfile::pixel6();

    println!("ASR stream: {n} utterances on {}\n", soc.display_name());

    // per-utterance trace with Parallax
    let pipe = Pipeline::build(
        Framework::Parallax,
        ModelKind::WhisperTiny,
        &soc,
        Mode::CpuOnly,
        SchedCfg::default(),
    )
    .expect("cpu supported");
    let mut rng = Rng::new(1234);
    let mut lats = Vec::new();
    println!("{:>4} {:>10} {:>12} {:>12}", "#", "audio fill", "latency ms", "RTFx");
    for i in 0..n {
        // LibriSpeech-like: mostly 3-12s clips of a 30s window
        let fill = 0.1 + 0.9 * rng.f64().powf(1.5);
        let r = pipe.run(&mut rng, fill);
        lats.push(r.latency_s * 1e3);
        if i < 10 || i == n - 1 {
            // real-time factor vs the clip's audio duration (30s * fill)
            let rtf = (30.0 * fill) / r.latency_s;
            println!("{:>4} {:>10.2} {:>12.1} {:>11.0}x", i, fill, r.latency_s * 1e3, rtf);
        }
    }
    let s = summarize(&lats).unwrap();
    println!(
        "\nParallax: min {:.0} / mean {:.0} / max {:.0} ms over {n} utterances",
        s.min, s.mean, s.max
    );

    // baseline comparison at mean fill
    println!("\nframework comparison (same trace):");
    for fw in [Framework::Ort, Framework::ExecuTorch, Framework::TfLite] {
        let p = Pipeline::build(fw, ModelKind::WhisperTiny, &soc, Mode::CpuOnly, SchedCfg::default())
            .unwrap();
        let runs = p.run_protocol(n, 1234);
        let l: Vec<f64> = runs.iter().map(|r| r.latency_s * 1e3).collect();
        let ss = summarize(&l).unwrap();
        println!("  {:<12} mean {:>7.1} ms", format!("{fw:?}"), ss.mean);
    }

    // §3.3 margin ablation: tighter margins = less parallelism headroom
    println!("\nmemory-margin ablation (Parallax mean ms):");
    for margin in [0.3, 0.4, 0.5, 0.9, 0.99] {
        let cfg = SchedCfg { max_threads: 6, margin };
        let p = Pipeline::build(Framework::Parallax, ModelKind::WhisperTiny, &soc, Mode::CpuOnly, cfg)
            .unwrap();
        let runs = p.run_protocol(n, 1234);
        let mean =
            runs.iter().map(|r| r.latency_s * 1e3).sum::<f64>() / runs.len() as f64;
        println!("  margin {margin:<5} mean {mean:>7.1} ms");
    }
    Ok(())
}
