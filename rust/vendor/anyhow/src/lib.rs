//! Offline drop-in subset of the `anyhow` error-handling crate.
//!
//! The build environment has no network access to crates.io, so this
//! vendored shim provides exactly the slice of `anyhow`'s API that the
//! `parallax` crate uses, with the same names and semantics:
//!
//! * [`Error`] — an opaque, `Send + Sync + 'static` error value with a
//!   human-readable message and an optional source chain.
//! * [`Result<T>`](Result) — alias for `std::result::Result<T, Error>`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — message-formatting macros.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   and `Option`.
//!
//! Like the real crate, the blanket `From` impl lets `?` convert any
//! `std::error::Error + Send + Sync + 'static` into [`Error`]; this
//! compiles because [`Error`] deliberately does *not* implement
//! `std::error::Error` itself.

use std::fmt;

/// Opaque error: a message plus an optional boxed source.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string(), source: None }
    }

    /// Wrap a concrete error value, preserving it as the source.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Self {
        Self { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Prefix the message with additional context (newest first, like
    /// `anyhow`'s context chain rendering).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The underlying source error, if one was captured.
    pub fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn std::error::Error + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error variant of a `Result` or to `None`.
pub trait Context<T, E> {
    /// Wrap the error with a fixed context message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.source().is_some());
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
        let n = 3;
        let e = anyhow!("inline {n}");
        assert_eq!(e.to_string(), "inline 3");

        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "wanted {}", true);
            if !ok {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(f(false).unwrap_err().to_string(), "wanted true");
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        let e = std::fs::read_to_string("/definitely/not/a/file")
            .with_context(|| format!("step {}", 2))
            .unwrap_err();
        assert!(e.to_string().starts_with("step 2: "));
    }
}
