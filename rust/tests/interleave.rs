//! Hand-rolled interleaving stress tests for the two concurrent
//! protocols in `sched`: the `MemoryGovernor` acquire/park/drain
//! discipline and the `LaneLedger` admit/complete bookkeeping.
//!
//! The offline build cannot depend on `loom`, so these tests explore
//! interleavings the cheap way: many OS threads hammering the shared
//! structure with deterministic per-thread workloads (seeded
//! `util::rng::Rng`), with the invariants asserted *during* the run
//! (budget never overrun, ledger never negative) and the terminal
//! state pinned exactly (everything drains to zero, counters add up).
//! That is weaker than exhaustive schedule enumeration but still
//! catches lost-wakeup, double-release, and read-modify-write races —
//! every bug class the governor's FIFO ticket queue exists to prevent.
//!
//! Feature-gated behind `interleave` (see Cargo.toml): the tests spin
//! real threads with real sleeps and belong in the dedicated CI job,
//! not in the `cargo test -q` tier-1 sweep.
//!
//! Run with: `cargo test --features interleave --test interleave`

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use parallax::sched::{LaneLedger, MemoryGovernor};
use parallax::util::rng::Rng;

/// Within-budget churn: many threads acquiring, shrinking, and
/// dropping leases concurrently.  The governor must never let the
/// reserved total exceed the budget, and after every lease is dropped
/// the ledger must read exactly zero with every grant accounted for.
#[test]
fn governor_concurrent_churn_never_overruns_budget() {
    const BUDGET: u64 = 1 << 20;
    const THREADS: u64 = 8;
    const ITERS: u64 = 200;

    let gov = Arc::new(MemoryGovernor::new(BUDGET));
    let peak_seen = Arc::new(AtomicU64::new(0));

    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let gov = Arc::clone(&gov);
            let peak_seen = Arc::clone(&peak_seen);
            thread::spawn(move || {
                let mut rng = Rng::new(0xA11CE + t);
                for i in 0..ITERS {
                    // Always within budget for one lease; up to 8
                    // threads * BUDGET/4 oversubscribes the budget 2x,
                    // so parking genuinely happens.
                    let bytes = rng.range_u64(1, BUDGET / 4);
                    let mut lease = gov.acquire(bytes);
                    let in_use = gov.in_use();
                    assert!(
                        in_use <= BUDGET,
                        "budget overrun while holding: in_use={in_use} budget={BUDGET}"
                    );
                    peak_seen.fetch_max(in_use, Ordering::Relaxed);
                    if i % 2 == 0 {
                        // Shrink-to-peak path: must return slack and
                        // wake parked waiters without double-counting.
                        lease.shrink_to(bytes / 2);
                        assert!(gov.in_use() <= BUDGET);
                    }
                    drop(lease);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let st = gov.stats();
    assert_eq!(st.in_use, 0, "all leases dropped, ledger must drain");
    assert_eq!(st.active_leases, 0);
    assert_eq!(st.grants, THREADS * ITERS, "every acquire granted exactly once");
    assert_eq!(st.over_budget_grants, 0, "no request exceeded the budget alone");
    assert!(st.peak_reserved <= BUDGET, "peak {} > budget {BUDGET}", st.peak_reserved);
    assert!(peak_seen.load(Ordering::Relaxed) <= BUDGET);
}

/// Over-budget requests (bytes > budget) are admitted only when they
/// have the governor to themselves, so concurrent over-budget callers
/// must serialize: while one holds its lease the reserved total equals
/// exactly that lease's size.
#[test]
fn governor_over_budget_grants_serialize() {
    const BUDGET: u64 = 1024;
    const BIG: u64 = 4096;
    const THREADS: u64 = 4;

    let gov = Arc::new(MemoryGovernor::new(BUDGET));
    let handles: Vec<_> = (0..THREADS)
        .map(|_| {
            let gov = Arc::clone(&gov);
            thread::spawn(move || {
                let lease = gov.acquire(BIG);
                // Exclusivity: nobody else can hold anything while an
                // over-budget lease is live.
                assert_eq!(gov.in_use(), BIG, "over-budget lease must be exclusive");
                thread::sleep(Duration::from_millis(1));
                assert_eq!(gov.in_use(), BIG);
                drop(lease);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let st = gov.stats();
    assert_eq!(st.in_use, 0);
    assert_eq!(st.active_leases, 0);
    assert_eq!(st.grants, THREADS);
    assert_eq!(st.over_budget_grants, THREADS, "each big request took the exclusive path");
    assert_eq!(st.peak_reserved, BIG);
}

/// A holder pins the whole budget while N waiters park; releasing the
/// holder must drain every waiter (no lost wakeups) and each waiter
/// parks exactly once, so `stats().waits` counts them exactly.
#[test]
fn governor_fifo_drain_serves_every_parked_waiter() {
    const BUDGET: u64 = 1000;
    const WAITERS: u64 = 6;

    let gov = Arc::new(MemoryGovernor::new(BUDGET));
    let holder = gov.acquire(BUDGET);

    let handles: Vec<_> = (0..WAITERS)
        .map(|_| {
            let gov = Arc::clone(&gov);
            thread::spawn(move || {
                // Parks: the holder owns the full budget.
                let lease = gov.acquire(BUDGET / 2);
                assert!(gov.in_use() <= BUDGET);
                drop(lease);
            })
        })
        .collect();

    // Wait (bounded) until every waiter has actually parked, so the
    // release below is a genuine wakeup storm rather than a no-op.
    for _ in 0..5000 {
        if gov.stats().waits >= WAITERS {
            break;
        }
        thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(gov.stats().waits, WAITERS, "every waiter parks exactly once");

    drop(holder);
    for h in handles {
        h.join().unwrap();
    }

    let st = gov.stats();
    assert_eq!(st.grants, 1 + WAITERS, "holder plus every drained waiter");
    assert_eq!(st.in_use, 0);
    assert_eq!(st.active_leases, 0);
    assert!(st.peak_reserved <= BUDGET);
}

/// `try_acquire` must refuse while the FIFO queue is non-empty (no
/// queue jumping) but never corrupt the ledger when it races with the
/// drain.
#[test]
fn governor_try_acquire_cannot_jump_the_queue() {
    const BUDGET: u64 = 1000;
    let gov = Arc::new(MemoryGovernor::new(BUDGET));
    let holder = gov.acquire(BUDGET);

    let waiter = {
        let gov = Arc::clone(&gov);
        thread::spawn(move || {
            let lease = gov.acquire(10);
            drop(lease);
        })
    };
    for _ in 0..5000 {
        if gov.stats().waits >= 1 {
            break;
        }
        thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(gov.stats().waits, 1);

    // Queue non-empty: even a zero-cost-looking request must refuse.
    assert!(gov.try_acquire(1).is_none(), "try_acquire must not overtake parked waiters");

    drop(holder);
    waiter.join().unwrap();

    // Queue drained: try_acquire works again and the ledger is exact.
    let lease = gov.try_acquire(123).expect("empty queue, plenty of budget");
    assert_eq!(gov.in_use(), 123);
    drop(lease);
    assert_eq!(gov.in_use(), 0);
}

/// Concurrent admit/complete pairs on the lane ledger: the integer-ns
/// representation guarantees matched pairs cancel *exactly*, so a
/// drained ledger reads back 0.0 on every lane — not merely "close to
/// zero" — no matter how the threads interleave.
#[test]
fn lane_ledger_concurrent_admit_complete_drains_exactly() {
    const LANES: usize = 4;
    const THREADS: u64 = 8;
    const BATCHES: usize = 100;
    const BATCH: usize = 16;

    let ledger = Arc::new(LaneLedger::new(LANES));
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let ledger = Arc::clone(&ledger);
            thread::spawn(move || {
                let mut rng = Rng::new(0x1ED6E5 + t);
                for _ in 0..BATCHES {
                    // Admit a batch, then complete it in reverse order
                    // so outstanding work genuinely overlaps across
                    // threads before draining.
                    let mut batch = Vec::with_capacity(BATCH);
                    for _ in 0..BATCH {
                        let lane = rng.range(0, LANES);
                        let service_s = rng.range_u64(1, 5_000_000) as f64 * 1e-9;
                        ledger.admit(lane, service_s);
                        batch.push((lane, service_s));
                    }
                    let total = ledger.outstanding_total();
                    assert!(total >= 0.0 && total.is_finite());
                    for (lane, service_s) in batch.into_iter().rev() {
                        ledger.complete(lane, service_s);
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    for lane in 0..LANES {
        assert_eq!(
            ledger.outstanding(lane),
            0.0,
            "lane {lane} outstanding must cancel exactly"
        );
    }
    assert_eq!(ledger.outstanding_total(), 0.0);
    assert_eq!(ledger.num_lanes(), LANES);
}

/// Static-load rebuilds (reset + re-add) racing with admit/complete
/// traffic must leave the two books independent: outstanding work is
/// untouched by `reset_static`, and the final static loads reflect the
/// last completed rebuild only.
#[test]
fn lane_ledger_static_rebuild_is_independent_of_outstanding() {
    const LANES: usize = 3;
    let ledger = Arc::new(LaneLedger::new(LANES));

    // Background admit/complete traffic.
    let traffic: Vec<_> = (0..4u64)
        .map(|t| {
            let ledger = Arc::clone(&ledger);
            thread::spawn(move || {
                let mut rng = Rng::new(0xBEE + t);
                for _ in 0..500 {
                    let lane = rng.range(0, LANES);
                    let service_s = rng.range_u64(1, 1_000_000) as f64 * 1e-9;
                    ledger.admit(lane, service_s);
                    ledger.complete(lane, service_s);
                }
            })
        })
        .collect();

    // Concurrent joint re-placement passes rebuilding the static book.
    for _ in 0..50 {
        ledger.reset_static();
        ledger.add_static(&[0.25, 0.5, 0.125]);
    }

    for h in traffic {
        h.join().unwrap();
    }

    assert_eq!(ledger.outstanding_total(), 0.0, "traffic drained despite rebuilds");
    assert_eq!(ledger.static_loads(), vec![0.25, 0.5, 0.125]);
}
