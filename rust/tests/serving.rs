//! Multi-model governed serving integration tests: concurrent
//! CLIP-text + DistilBERT + YOLOv8n traffic through one
//! admission-controlled dispatcher must stay under the configured
//! device budget (ISSUE 2 acceptance criterion), keep every model
//! progressing, and return bit-stable results.

use std::sync::{Arc, Condvar, Mutex};

use parallax::baselines::{Framework, Pipeline};
use parallax::device::SocProfile;
use parallax::models::ModelKind;
use parallax::sched::{MemoryGovernor, SchedCfg};
use parallax::serve::{pipeline_executor, ModelExecutor, Outcome, ServeCfg, Server, SloSpec};
use parallax::sim::Mode;

const MODELS: [ModelKind; 3] =
    [ModelKind::ClipText, ModelKind::DistilBert, ModelKind::Yolov8n];

fn pipeline(model: ModelKind, gov: &Arc<MemoryGovernor>) -> Pipeline {
    Pipeline::build(
        Framework::Parallax,
        model,
        &SocProfile::pixel6(),
        Mode::CpuOnly,
        SchedCfg::default(),
    )
    .expect("cpu always supported")
    .with_governor(gov.clone())
}

fn executor(pipe: Pipeline, seed: u64) -> Box<dyn ModelExecutor> {
    pipeline_executor(pipe, seed).1
}

#[test]
fn concurrent_three_model_traffic_stays_under_budget() {
    // Budget: enough for the hungriest single model (progress
    // guarantee) but well short of all three peaks at once, so the
    // governor must actually gate admissions.
    let probe = Arc::new(MemoryGovernor::unlimited());
    let demands: Vec<u64> =
        MODELS.iter().map(|&m| pipeline(m, &probe).peak_branch_demand()).collect();
    let max_d = *demands.iter().max().unwrap();
    let sum_d: u64 = demands.iter().sum();
    assert!(sum_d > max_d, "demands must differ for the test to bite");
    let budget = max_d.max(sum_d / 2);

    let gov = Arc::new(MemoryGovernor::new(budget));
    let mut server = Server::with_config(ServeCfg { workers: 3, max_batch: 4 }, gov.clone());
    for (i, &model) in MODELS.iter().enumerate() {
        server.register_with_demand(
            model.slug(),
            demands[i],
            executor(pipeline(model, &gov), 100 + i as u64),
        );
    }

    let names: Vec<&str> = MODELS.iter().map(|m| m.slug()).collect();
    let report = server.run_load(&names, 48, 12, 5).unwrap();

    assert_eq!(report.responses.len(), 48, "requests lost");
    for name in &names {
        let s = &report.latency[*name];
        assert!(s.n >= 16, "{name} under-served: {}", s.n);
        assert!(s.p99 >= s.p50 && s.p50 > 0.0);
    }
    // The acceptance criterion: peak reserved memory under the governor
    // never exceeds the configured device budget.
    let stats = gov.stats();
    assert!(
        stats.peak_reserved <= budget,
        "governor let peak {} exceed budget {budget}",
        stats.peak_reserved
    );
    assert_eq!(stats.over_budget_grants, 0, "no degraded-mode grants expected");
    assert_eq!(stats.in_use, 0, "leases leaked after drain");
    assert!(stats.grants >= 3, "each model admitted at least once");
}

#[test]
fn governed_results_match_isolated_results() {
    // The governor changes *when* work runs, never *what* it computes:
    // per-seed checksums under the shared governed server must equal
    // the per-model isolated baseline's.
    let gov = Arc::new(MemoryGovernor::new(256 << 20));
    let mut governed = Server::with_config(ServeCfg { workers: 3, max_batch: 4 }, gov.clone());
    for (i, &model) in MODELS.iter().enumerate() {
        let pipe = pipeline(model, &gov);
        let demand = pipe.peak_branch_demand();
        governed.register_with_demand(model.slug(), demand, executor(pipe, 7 + i as u64));
    }
    let names: Vec<&str> = MODELS.iter().map(|m| m.slug()).collect();
    let governed_report = governed.run_load(&names, 24, 6, 42).unwrap();

    let mut isolated_sums: Vec<(String, u64, f64)> = Vec::new();
    for (i, &model) in MODELS.iter().enumerate() {
        // same device budget, but a private ledger per model — the
        // per-model-isolated deployment shape
        let iso = Arc::new(MemoryGovernor::new(256 << 20));
        let mut server = Server::with_config(ServeCfg { workers: 1, max_batch: 1 }, iso.clone());
        server.register(model.slug(), executor(pipeline(model, &iso), 7 + i as u64));
        // replay the exact seeds this model saw in the mixed run
        for r in &governed_report.responses {
            if r.model == model.slug() {
                let resp = server.infer(model.slug(), 42 ^ r.id).unwrap();
                isolated_sums.push((r.model.clone(), r.id, resp.checksum));
            }
        }
    }
    for (model, id, iso_checksum) in isolated_sums {
        let governed_checksum = governed_report
            .responses
            .iter()
            .find(|r| r.id == id)
            .map(|r| r.checksum)
            .unwrap();
        assert_eq!(
            governed_checksum, iso_checksum,
            "{model} req {id}: governed and isolated outputs diverge"
        );
    }
}

#[test]
fn skewed_load_cannot_starve_minority_model() {
    // 4:1:1 skew toward clip-text; round-robin queues must still finish
    // the minority models' requests.
    let gov = Arc::new(MemoryGovernor::new(512 << 20));
    let mut server = Server::with_config(ServeCfg { workers: 2, max_batch: 4 }, gov.clone());
    for (i, &model) in MODELS.iter().enumerate() {
        let pipe = pipeline(model, &gov);
        let demand = pipe.peak_branch_demand();
        server.register_with_demand(model.slug(), demand, executor(pipe, 31 + i as u64));
    }
    let load = [
        "clip-text",
        "clip-text",
        "distilbert",
        "clip-text",
        "clip-text",
        "yolov8n",
    ];
    let report = server.run_load(&load, 36, 9, 77).unwrap();
    assert_eq!(report.responses.len(), 36);
    assert_eq!(report.latency["distilbert"].n, 6);
    assert_eq!(report.latency["yolov8n"].n, 6);
    assert_eq!(report.latency["clip-text"].n, 24);
    assert!(gov.stats().in_use == 0);
}

/// Executor whose spilled path parks on a gate: lets the test pin a
/// request *in flight on the remote lane* while its model is dropped.
struct GatedSpillExecutor {
    entered: Arc<(Mutex<bool>, Condvar)>,
    release: Arc<(Mutex<bool>, Condvar)>,
}

impl ModelExecutor for GatedSpillExecutor {
    fn execute(&mut self, seed: u64) -> anyhow::Result<(f64, f64)> {
        Ok((0.0, seed as f64))
    }

    fn execute_spilled(&mut self, seed: u64) -> anyhow::Result<Option<(f64, f64)>> {
        let (m, cv) = &*self.entered;
        *m.lock().unwrap() = true;
        cv.notify_all();
        let (m, cv) = &*self.release;
        let mut open = m.lock().unwrap();
        while !*open {
            open = cv.wait(open).unwrap();
        }
        Ok(Some((0.0, 1000.0 + seed as f64)))
    }
}

#[test]
fn drop_while_request_spilled_in_flight_still_answers_explicitly() {
    // Regression (ISSUE 9): a model dropped while one of its requests
    // is in flight on the remote lane must still answer that request
    // with an explicit Outcome (the spill result, never silence), the
    // queued request behind it gets Outcome::Dropped, and the shared
    // LaneLedger drains to exactly 0.0 — including the remote lane's
    // in-flight transfer charge.
    let entered = Arc::new((Mutex::new(false), Condvar::new()));
    let release = Arc::new((Mutex::new(false), Condvar::new()));
    let gov = Arc::new(MemoryGovernor::unlimited());
    let mut server = Server::with_config(ServeCfg { workers: 1, max_batch: 1 }, gov);
    // pinned arithmetic: the local lane can never make the deadline,
    // the remote lane always can — every request spills at admission
    let slo = SloSpec {
        lane: Some(0),
        lane_service_s: 1.0,
        cpu_service_s: 0.1,
        remote: Some((1, 1e-3)),
    };
    server.register_with_slo(
        "m",
        0,
        slo,
        Box::new(GatedSpillExecutor {
            entered: entered.clone(),
            release: release.clone(),
        }),
    );

    let rx1 = server.submit_with_deadline("m", 1, Some(0.5)).unwrap();
    // wait until the worker is inside the spilled execution — the
    // request is now in flight on the remote lane
    {
        let (m, cv) = &*entered;
        let mut seen = m.lock().unwrap();
        while !*seen {
            seen = cv.wait(seen).unwrap();
        }
    }
    let rx2 = server.submit_with_deadline("m", 2, Some(0.5)).unwrap();
    server.drop_model("m").unwrap();

    // the queued request resolves immediately and explicitly
    let r2 = rx2.recv().unwrap().unwrap();
    assert_eq!(r2.outcome, Outcome::Dropped);

    // release the in-flight spill: it must answer with its real
    // outcome, not vanish with the dropped model
    {
        let (m, cv) = &*release;
        *m.lock().unwrap() = true;
        cv.notify_all();
    }
    let r1 = rx1.recv().unwrap().unwrap();
    assert_eq!(r1.outcome, Outcome::Spilled, "in-flight spill answered explicitly");
    assert_eq!(r1.checksum, 1001.0, "served by the remote path");

    // worker completed the remote charge before replying: the ledger
    // holds exactly nothing, for the remote lane and in total
    let ledger = server.lane_ledger();
    assert_eq!(ledger.outstanding(1), 0.0, "remote lane drains to exactly 0.0");
    assert_eq!(ledger.outstanding_total(), 0.0);
}

#[test]
fn dropped_model_leaves_no_stale_rotation_slot() {
    // Regression: run_load's round-robin used to fail outright when a
    // rotation slot pointed at a dropped model.  Dropped slots must be
    // skipped (and counted), while a never-registered name stays a
    // caller error.
    let gov = Arc::new(MemoryGovernor::unlimited());
    let mut server = Server::with_config(ServeCfg { workers: 2, max_batch: 2 }, gov);
    for (i, &model) in MODELS.iter().enumerate().take(2) {
        let probe = Arc::new(MemoryGovernor::unlimited());
        server.register(model.slug(), executor(pipeline(model, &probe), 50 + i as u64));
    }
    let names = [MODELS[0].slug(), MODELS[1].slug()];
    let before = server.run_load(&names, 8, 4, 11).unwrap();
    assert_eq!(before.responses.len(), 8);
    assert_eq!(before.skipped, 0);

    server.drop_model(names[1]).unwrap();
    // same rotation, half the slots now dropped: the load must still
    // complete, serving only the survivor
    let after = server.run_load(&names, 10, 4, 12).unwrap();
    assert_eq!(after.responses.len(), 5, "survivor's share completes");
    assert_eq!(after.skipped, 5, "dropped model's slots are skipped, not errors");
    assert!(after.latency.contains_key(names[0]));
    assert!(!after.latency.contains_key(names[1]), "no phantom latencies");

    let err = server.infer(names[1], 1).unwrap_err().to_string();
    assert!(err.contains("dropped"), "got: {err}");
    // unknown names are not 'dropped': still a hard error
    assert!(server.run_load(&["never-registered"], 4, 2, 1).is_err());
}
