//! Device–edge remote spill tier: the deterministic remote-parity
//! suite.
//!
//! The remote lane executes the same host kernels as the CPU pool, so
//! whatever the link does — jitter, drops, retries, full partitions —
//! outputs must stay bit-identical to a CPU-forced run of the same
//! schedules.  Pins:
//! * spilled (fault-free link), retried-after-fault, and CPU-forced
//!   runs are checksum-bit-identical per seed, over random DAGs ×
//!   random seeded loss schedules × lane knockouts
//! * every remote transfer resolves explicitly: dispatched after at
//!   most one retry, or inline on the CPU — never a silent drop
//!   (`cpu_branch_runs + delegate_jobs` is conserved vs CPU-forced)
//! * the same seed replays the same fault schedule bitwise
//!   (`ExecStats` transfer fields compare equal to the bit)
//! * governor leases stay within budget while remote transfers are in
//!   flight, and drain to zero afterwards
//! * at the serving layer, a fixed backlog resolves to *exact*
//!   `Outcome::Spilled` counts, the `LoadReport` accounting invariant
//!   holds, and the shared lane ledger drains to exactly 0.0

use parallax::branch::{self, DEFAULT_BETA};
use parallax::device::{LinkModel, RemoteLane, SocProfile};
use parallax::exec::Engine;
use parallax::graph::Graph;
use parallax::memory::branch_memories;
use parallax::models::micro;
use parallax::partition::{partition, CostModel, Partition};
use parallax::place::{self, Placement, PlacementPlan};
use parallax::sched::{self, MemoryGovernor, SchedCfg};
use parallax::serve::{Outcome, PlacedEngineExecutor, Server, SloSpec};
use parallax::util::prop;

fn loose() -> CostModel {
    CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX }
}

/// Every delegate-safe branch forced onto the SoC's remote lane,
/// priced by the Appendix-B closed form on the link's terms — the
/// spill placement `serve` hands `execute_spilled`, built directly so
/// the suite exercises the remote path even on graphs the Auto policy
/// would keep local.
fn spill_all(
    g: &Graph,
    p: &Partition,
    plan: &branch::BranchPlan,
    soc: &SocProfile,
) -> PlacementPlan {
    let rl = soc.remote_lane().expect("profile carries a remote lane");
    let mut pl = PlacementPlan::cpu_only(plan.branches.len());
    for b in 0..plan.branches.len() {
        if place::delegate_safe(g, p, plan, b) {
            pl.assignment[b] = Placement::Delegate(rl);
            pl.staging_bytes[b] = place::transfer_bytes(g, p, plan, b);
            pl.delegate_latency_s[b] =
                place::lane_delegate_latency(g, p, plan, b, soc, &soc.lanes[rl]);
        }
    }
    pl
}

fn remote_flags(soc: &SocProfile) -> Vec<bool> {
    soc.lanes.iter().map(|l| l.remote).collect()
}

#[test]
fn prop_spilled_and_fault_retried_runs_match_cpu_forced_per_seed() {
    prop::check("remote spill parity", 25, |rng| {
        // random DAG family × lane knockouts × seeded loss schedule
        let g = match rng.range(0, 3) {
            0 => micro::fallback_heavy(rng.range(2, 5), rng.range(2, 4), 32, rng.range(2, 5)),
            1 => micro::fallback_heavy_lanes(2, rng.range(2, 4), 2, 32, 3),
            _ => micro::random_dag(rng, rng.range(2, 7), rng.range(1, 5)),
        };
        let socs = [SocProfile::pixel6, SocProfile::p30_pro, SocProfile::redmi_k50];
        let mut soc = socs[rng.range(0, 3)]();
        // knocked-out local lanes must not matter: the spill placement
        // targets only the (always reachable) remote lane
        for lane in &mut soc.lanes {
            if rng.chance(0.4) {
                lane.reachable = false;
            }
        }
        let soc = soc.with_remote(&RemoteLane::edge_server());
        let p = partition(&g, &loose());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let spill = spill_all(&g, &p, &plan, &soc);
        if spill.num_delegated() == 0 {
            return; // nothing delegate-safe in this draw
        }
        let mems = branch_memories(&g, &p, &plan);
        let cfg = SchedCfg { max_threads: rng.range(1, 4), margin: 0.4 };
        let s = sched::schedule(&plan, &mems, 1 << 34, &cfg);
        let flags = remote_flags(&soc);

        let engine = Engine::new(&g, &p, &plan, None);
        let forced = PlacementPlan::cpu_only(plan.branches.len());
        let (v_cpu, st_cpu) = engine.run_placed(&s, &forced, None).unwrap();

        // spilled over a fault-free link: all remote, bit-identical
        let mut e1 = Engine::new(&g, &p, &plan, None);
        e1.set_remote(flags.clone(), LinkModel::reliable(rng.next_u64()));
        let (v1, st1) = e1.run_placed(&s, &spill, None).unwrap();
        assert_eq!(v_cpu.checksum(), v1.checksum(), "spilled run diverged");
        assert_eq!(st1.delegate_jobs, spill.num_delegated());
        assert_eq!(st1.link_retries, 0, "reliable link never retries");
        assert!(st1.downlink_bytes > 0, "remote outputs cross the link back");

        // a random seeded loss schedule: drops retry once, persistent
        // faults fall back inline to the CPU — still bit-identical
        let link = LinkModel {
            seed: rng.next_u64(),
            jitter_frac: rng.f64() * 0.3,
            drop_p: rng.f64() * 0.5,
            partition_every: if rng.chance(0.4) { rng.range_u64(2, 6) } else { 0 },
            partition_len: 1,
        };
        let mut e2 = Engine::new(&g, &p, &plan, None);
        e2.set_remote(flags.clone(), link.clone());
        let (v2, st2) = e2.run_placed(&s, &spill, None).unwrap();
        assert_eq!(v_cpu.checksum(), v2.checksum(), "faulty-link run diverged");
        // no silent drops: every branch ran exactly once, remotely or
        // on the host
        assert_eq!(
            st2.cpu_branch_runs + st2.delegate_jobs,
            st_cpu.cpu_branch_runs,
            "a remote transfer resolved silently"
        );
        assert!(st2.delegate_jobs <= spill.num_delegated());

        // same seed → the fault schedule replays bitwise
        let mut e3 = Engine::new(&g, &p, &plan, None);
        e3.set_remote(flags.clone(), link.clone());
        let (v3, st3) = e3.run_placed(&s, &spill, None).unwrap();
        assert_eq!(v2.checksum().to_bits(), v3.checksum().to_bits());
        assert_eq!(st2.delegate_jobs, st3.delegate_jobs);
        assert_eq!(st2.link_retries, st3.link_retries);
        assert_eq!(st2.uplink_bytes, st3.uplink_bytes);
        assert_eq!(st2.downlink_bytes, st3.downlink_bytes);
        assert_eq!(st2.remote_busy_s.to_bits(), st3.remote_busy_s.to_bits());
    });
}

#[test]
fn dead_link_resolves_every_job_to_the_cpu_never_silently() {
    // partition window covers every transfer index: first attempt and
    // retry both drop, so every job must fall back inline — outputs
    // still bit-identical, stats showing the whole story
    let g = micro::fallback_heavy(4, 3, 48, 4);
    let soc = SocProfile::pixel6().with_remote(&RemoteLane::edge_server());
    let p = partition(&g, &loose());
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let spill = spill_all(&g, &p, &plan, &soc);
    assert!(spill.num_delegated() >= 1);
    let mems = branch_memories(&g, &p, &plan);
    let cfg = SchedCfg { max_threads: 2, margin: 0.4 };
    let s = sched::schedule(&plan, &mems, 1 << 34, &cfg);

    let engine = Engine::new(&g, &p, &plan, None);
    let (v_cpu, st_cpu) =
        engine.run_placed(&s, &PlacementPlan::cpu_only(plan.branches.len()), None).unwrap();
    let mut e = Engine::new(&g, &p, &plan, None);
    e.set_remote(
        remote_flags(&soc),
        LinkModel { seed: 5, jitter_frac: 0.1, drop_p: 0.0, partition_every: 2, partition_len: 2 },
    );
    let (v, st) = e.run_placed(&s, &spill, None).unwrap();
    assert_eq!(v_cpu.checksum(), v.checksum(), "dead-link fallback diverged");
    assert_eq!(st.delegate_jobs, 0, "nothing crossed a fully partitioned link");
    assert_eq!(st.link_retries, spill.num_delegated(), "each job retried exactly once");
    assert_eq!(st.cpu_branch_runs, st_cpu.cpu_branch_runs, "every job resolved on the host");
    assert_eq!(st.downlink_bytes, 0);
    assert!(st.uplink_bytes > 0, "wasted attempts are still charged");
}

#[test]
fn prop_governor_leases_hold_while_remote_transfers_in_flight() {
    // remote staging (transfer bytes) folds into the same layer leases
    // as on-die staging: whatever the budget and the loss schedule,
    // the ledger never exceeds it (short of a degraded-serial grant)
    // and always drains to zero
    let g = micro::fallback_pipeline(3, 2, 3, 48, 3);
    let soc = SocProfile::pixel6().with_remote(&RemoteLane::edge_server());
    let p = partition(&g, &loose());
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let spill = spill_all(&g, &p, &plan, &soc);
    assert!(spill.num_delegated() >= 3, "one trunk per stage must spill");
    let mems = branch_memories(&g, &p, &plan);
    let cfg = SchedCfg { max_threads: 3, margin: 0.4 };
    let s = sched::schedule(&plan, &mems, 1 << 34, &cfg);
    let flags = remote_flags(&soc);
    prop::check("remote staging within budget", 15, |rng| {
        let budget = rng.range_u64(1, 1 << 22);
        let gov = MemoryGovernor::new(budget);
        let mut e = Engine::new(&g, &p, &plan, None);
        e.set_remote(flags.clone(), LinkModel::lossy(rng.next_u64(), 0.25));
        let (v, _) = e.run_placed(&s, &spill, Some(&gov)).unwrap();
        assert!(v.all_finite());
        assert_eq!(gov.in_use(), 0, "leases leaked after the remote run");
        let st = gov.stats();
        assert!(
            st.peak_reserved <= budget || st.over_budget_grants > 0,
            "budget {budget} exceeded without a degraded-serial grant (peak {})",
            st.peak_reserved
        );
    });
}

#[test]
fn fixed_backlog_spill_counts_are_exact_and_bit_identical() {
    // SLO arithmetic chosen so the admission decision is invariant to
    // queue drain timing: the local lane always misses the deadline,
    // the remote lane always fits.  Every request must spill — an
    // exact count, not a flaky one — and every spilled response must
    // carry the CPU-forced checksum.
    let soc = SocProfile::pixel6().with_remote(&RemoteLane::edge_server());
    let rl = soc.remote_lane().unwrap();
    let g = micro::fallback_heavy(4, 3, 64, 4);
    let p = partition(&g, &loose());
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let spill = spill_all(&g, &p, &plan, &soc);
    assert!(spill.num_delegated() >= 1);
    let mems = branch_memories(&g, &p, &plan);
    let cfg = SchedCfg::default();
    let s = sched::schedule(&plan, &mems, 1 << 34, &cfg);
    let engine = Engine::new(&g, &p, &plan, None);
    let (v_cpu, _) = engine.run_cpu_forced(&s).unwrap();

    let mut server = Server::new();
    let slo = SloSpec { lane: Some(0), lane_service_s: 1.0, cpu_service_s: 0.002, remote: None }
        .with_remote(rl, 0.01);
    let exec = PlacedEngineExecutor::new(
        g.clone(),
        p.clone(),
        plan.clone(),
        s.clone(),
        PlacementPlan::cpu_only(plan.branches.len()),
    )
    .with_remote(remote_flags(&soc), LinkModel::reliable(7), spill.clone());
    server.register_with_slo("m", 0, slo, Box::new(exec));

    const N: usize = 10;
    // deadline 0.5: local eta >= 1.0 always misses; remote eta never
    // exceeds N * 0.01 = 0.1 <= 0.5, so every request spills
    let rep = server.run_load_slo(&["m"], N, N, 3, Some(0.5)).unwrap();
    assert_eq!(rep.spilled, N, "exact spill count under the fixed backlog");
    assert_eq!(
        (rep.admitted, rep.degraded, rep.shed, rep.dropped, rep.skipped),
        (0, 0, 0, 0, 0)
    );
    assert_eq!(
        rep.admitted + rep.degraded + rep.shed + rep.dropped + rep.skipped + rep.spilled,
        N,
        "LoadReport accounting invariant"
    );
    for resp in &rep.responses {
        assert_eq!(resp.outcome, Outcome::Spilled);
        assert_eq!(
            resp.checksum.to_bits(),
            v_cpu.checksum().to_bits(),
            "spilled response not bit-identical to CPU-forced"
        );
    }
    assert_eq!(server.lane_ledger().outstanding(0), 0.0, "local lane drains");
    assert_eq!(
        server.lane_ledger().outstanding(rl),
        0.0,
        "remote lane ledger drains to exactly 0.0"
    );
}
