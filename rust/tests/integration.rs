//! Integration tests: full pipeline over zoo models, PJRT round trips
//! (gated on built artifacts), and cross-module invariants.

use parallax::baselines::{Framework, Pipeline};
use parallax::branch::{self, DEFAULT_BETA};
use parallax::device::SocProfile;
use parallax::exec::Engine;
use parallax::memory::{self, branch_memories};
use parallax::models::{micro, ModelKind};
use parallax::partition::{partition, CostModel};
use parallax::runtime::{artifacts_available, default_artifact_dir, RuntimePool, Tensor};
use parallax::sched::{self, SchedCfg};
use parallax::sim::Mode;

fn cpu_only(g: &parallax::graph::Graph) -> parallax::partition::Partition {
    partition(
        g,
        &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
    )
}

// ---------------------------------------------------------------- pipeline

#[test]
fn full_pipeline_all_models_all_devices() {
    for model in ModelKind::ALL {
        for make in SocProfile::ALL {
            let soc = make();
            let pipe =
                Pipeline::build(Framework::Parallax, model, &soc, Mode::CpuOnly, SchedCfg::default())
                    .expect("cpu always builds");
            let r = pipe.run_protocol(3, 1);
            assert_eq!(r.len(), 3);
            for x in &r {
                assert!(x.latency_s > 0.0, "{} on {}", model.display_name(), soc.name);
                assert!(x.peak_mem_bytes > model.weight_bytes());
                assert!(x.energy_j > 0.0);
            }
        }
    }
}

#[test]
fn parallax_never_slower_than_tflite_by_much() {
    // Parallax is TFLite + branch parallelism; on every model its mean
    // must be at most a few percent above TFLite (sync overhead) and
    // usually below.
    let soc = SocProfile::pixel6();
    for model in ModelKind::ALL {
        let plx = Pipeline::build(Framework::Parallax, model, &soc, Mode::CpuOnly, SchedCfg::default())
            .unwrap();
        let tfl = Pipeline::build(Framework::TfLite, model, &soc, Mode::CpuOnly, SchedCfg::default())
            .unwrap();
        let mp: f64 = plx.run_protocol(8, 3).iter().map(|r| r.latency_s).sum::<f64>() / 8.0;
        let mt: f64 = tfl.run_protocol(8, 3).iter().map(|r| r.latency_s).sum::<f64>() / 8.0;
        assert!(
            mp <= mt * 1.05,
            "{}: Parallax {mp:.4}s vs TFLite {mt:.4}s",
            model.display_name()
        );
    }
}

#[test]
fn memory_overhead_is_bounded() {
    // Table 4's shape: Parallax peak memory is higher than TFLite but
    // within ~2x (the paper reports +26.5% average).
    let soc = SocProfile::pixel6();
    for model in ModelKind::ALL {
        let plx = Pipeline::build(Framework::Parallax, model, &soc, Mode::CpuOnly, SchedCfg::default())
            .unwrap();
        let tfl = Pipeline::build(Framework::TfLite, model, &soc, Mode::CpuOnly, SchedCfg::default())
            .unwrap();
        let pp = plx.run_protocol(3, 5)[0].peak_mem_bytes as f64;
        let pt = tfl.run_protocol(3, 5)[0].peak_mem_bytes as f64;
        assert!(
            pp <= pt * 2.0,
            "{}: Parallax mem {pp} vs TFLite {pt}",
            model.display_name()
        );
    }
}

#[test]
fn table7_shape_holds() {
    // Parallax's partition trimming must (a) reduce layer count vs the
    // fragmented post-delegation graph and (b) recover parallel layers,
    // for the models the paper highlights (Whisper, SwinV2).
    for model in [ModelKind::WhisperTiny, ModelKind::Swinv2Tiny] {
        let g = model.build();
        let post_p = partition(&g, &CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX });
        let post = branch::plan(&g, &post_p, DEFAULT_BETA);
        let plx_p = partition(&g, &CostModel::default());
        let plx = branch::plan(&g, &plx_p, DEFAULT_BETA);
        let (_, post_par, _) = post.table7_metrics();
        let (_, plx_par, _) = plx.table7_metrics();
        assert!(
            plx_par >= post_par,
            "{}: parallel layers {plx_par} < post {post_par}",
            model.display_name()
        );
    }
}

// ------------------------------------------------------------ failure modes

#[test]
fn oom_budget_zero_still_completes() {
    let g = ModelKind::ClipText.build();
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let mems = branch_memories(&g, &p, &plan);
    let scheds = sched::schedule(&plan, &mems, 0, &SchedCfg::default());
    let total: usize = scheds.iter().map(|s| s.all().count()).sum();
    assert_eq!(total, plan.branches.len(), "zero budget must not drop work");
    for s in &scheds {
        assert!(s.waves.iter().all(|w| w.is_empty()) || s.waves.is_empty());
    }
}

#[test]
fn missing_artifact_dir_fails_cleanly() {
    assert!(RuntimePool::new("/nonexistent/plx_artifacts", 1).is_err());
}

#[test]
fn engine_missing_program_falls_back_to_host() {
    // graphs with program hints but *no* pool must still run
    let g = ModelKind::WhisperTiny.build();
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);
    assert_eq!(engine.num_blocks(), 0);
}

// ------------------------------------------------------- PJRT (artifacts)

#[test]
fn pjrt_matmul_matches_host() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let pool = RuntimePool::new(default_artifact_dir(), 1).unwrap();
    let a = Tensor::randn(vec![64, 64], 11);
    let b = Tensor::randn(vec![64, 64], 12);
    let out = pool.execute("matmul_64x64x64", vec![a.clone(), b.clone()]).unwrap();
    let host = parallax::exec::host_kernels::matmul(&a, &b);
    assert!(out[0].max_abs_diff(&host) < 1e-3);
}

#[test]
fn pjrt_layernorm_matches_host() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let pool = RuntimePool::new(default_artifact_dir(), 1).unwrap();
    let x = Tensor::randn(vec![77, 512], 21);
    let g = Tensor::randn(vec![512], 22);
    let b = Tensor::randn(vec![512], 23);
    let out = pool
        .execute("layernorm_77x512", vec![x.clone(), g.clone(), b.clone()])
        .unwrap();
    let host = parallax::exec::host_kernels::layernorm(&x, &g, &b, 1e-5);
    assert!(
        out[0].max_abs_diff(&host) < 1e-2,
        "diff {}",
        out[0].max_abs_diff(&host)
    );
}

#[test]
fn pjrt_bad_shape_rejected() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let pool = RuntimePool::new(default_artifact_dir(), 1).unwrap();
    let a = Tensor::randn(vec![32, 32], 1);
    let b = Tensor::randn(vec![32, 32], 2);
    assert!(pool.execute("matmul_64x64x64", vec![a, b]).is_err());
    let c = Tensor::randn(vec![64, 64], 1);
    assert!(pool.execute("matmul_64x64x64", vec![c]).is_err());
    assert!(pool
        .execute("no_such_program", vec![])
        .is_err());
}

#[test]
fn real_engine_runs_clip_blocks_via_pjrt() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let pool = RuntimePool::new(default_artifact_dir(), 1).unwrap();
    let g = ModelKind::ClipText.build();
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, Some(&pool));
    assert!(engine.num_blocks() >= 24, "blocks {}", engine.num_blocks());
    let mems = branch_memories(&g, &p, &plan);
    let scheds = sched::schedule(&plan, &mems, 1 << 34, &SchedCfg::default());
    let (values, stats) = engine.run(&scheds).unwrap();
    assert!(values.all_finite());
    assert!(stats.pjrt_calls >= 24);
}

// --------------------------------------------------------- micro pipelines

#[test]
fn micro_graphs_pipeline_end_to_end() {
    for g in [micro::chain(20), micro::parallel_chains(5, 6), micro::diamond(4, 5), micro::mixed()] {
        let p = partition(&g, &CostModel::default());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let mems = branch_memories(&g, &p, &plan);
        let scheds = sched::schedule(&plan, &mems, 1 << 30, &SchedCfg::default());
        let engine = Engine::new(&g, &p, &plan, None);
        let (values, _) = engine.run(&scheds).unwrap();
        assert!(values.all_finite(), "{}", g.name);
    }
}

#[test]
fn arena_vs_estimate_consistency() {
    // the §3.3 estimator must never under-estimate what the branch
    // arena actually allocates for internal tensors
    let g = micro::parallel_chains(4, 10);
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    for b in 0..plan.branches.len() {
        let nodes = plan.branch_nodes(&g, &p, b);
        let lts = memory::analyze(&g, &nodes);
        let internal: Vec<_> = lts.iter().filter(|l| !l.escapes).cloned().collect();
        let est = memory::plan_branch(&internal).arena_bytes;
        let peak = memory::peak_bytes(&internal);
        assert!(est >= peak.min(est), "planner under peak");
    }
}
