//! Energy-ledger integration tests (EXPERIMENTS.md §Energy).
//!
//! The executor's per-run energy accounting ([`parallax::exec::ExecStats`]
//! `energy_*` fields) must agree with the simulator's Fig. 2 closed form
//! (`P_idle·T + P_core·core_seconds + P_acc·acc_busy`) term by term when
//! both price the same schedule on the same SoC, energy-aware placement
//! ([`parallax::place::PlacePolicy::EnergyAware`]) must trade latency for
//! strictly less modelled energy without changing outputs, and thermal
//! throttling must re-place mid-stream with bit-identical outputs.

use parallax::baselines;
use parallax::branch::{self, BranchPlan, DEFAULT_BETA};
use parallax::ctrl::SegmentedEngine;
use parallax::device::{SocProfile, ThermalModel, ThermalStep};
use parallax::exec::{Engine, IdleTime};
use parallax::graph::Graph;
use parallax::memory::{branch_memories, BranchMemory};
use parallax::models::micro;
use parallax::partition::{partition, CostModel, Partition};
use parallax::place::{self, PlacePolicy, PlacementPlan};
use parallax::sched::{self, LayerSchedule, MemoryGovernor, SchedCfg};
use parallax::sim::{self, Mode};
use parallax::util::prop;

fn cpu_only(g: &Graph) -> Partition {
    partition(
        g,
        &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
    )
}

fn delegable(g: &Graph) -> Partition {
    partition(g, &CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX })
}

fn setup(
    g: &Graph,
    p: &Partition,
    threads: usize,
) -> (BranchPlan, Vec<BranchMemory>, Vec<LayerSchedule>, SchedCfg) {
    let plan = branch::plan(g, p, DEFAULT_BETA);
    let mems = branch_memories(g, p, &plan);
    let cfg = SchedCfg { max_threads: threads, margin: 0.4 };
    let schedules = sched::schedule(&plan, &mems, 1 << 34, &cfg);
    (plan, mems, schedules, cfg)
}

fn assert_close(a: f64, b: f64, what: &str) {
    let denom = a.abs().max(b.abs()).max(1e-18);
    assert!(
        (a - b).abs() / denom < 1e-9,
        "{what}: exec {a} vs sim {b} (rel {})",
        (a - b).abs() / denom
    );
}

/// Tentpole check: the executor's accumulated ledger reproduces the
/// simulator's closed form term by term on random static CPU-only DAGs
/// — idle term from the modelled span, CPU term from core-seconds,
/// lane term exactly zero.
#[test]
fn prop_exec_energy_matches_sim_closed_form_on_random_dags() {
    let soc = SocProfile::pixel6();
    let fw = baselines::parallax();
    prop::check("exec energy == sim closed form", 25, |rng| {
        let layers = rng.range(2, 8);
        let width = rng.range(1, 5);
        let g = micro::random_dag(rng, layers, width);
        let p = cpu_only(&g);
        let (plan, mems, schedules, cfg) = setup(&g, &p, 4);
        let mut engine = Engine::new(&g, &p, &plan, None);
        engine.set_energy_model(sim::energy_model_for(
            &g, &p, &plan, &schedules, &fw, &soc, &cfg, 1.0,
        ));
        let (_, st) = engine.run(&schedules).unwrap();
        let r = sim::simulate(
            &g, &p, &plan, &schedules, &mems, &fw, &soc, &cfg, Mode::CpuOnly, 1.0, 0, 0,
        );
        assert!(st.energy_j > 0.0);
        assert_close(st.cpu_modelled_s, r.cpu_core_seconds, "core seconds");
        assert_close(st.energy_idle_j, soc.p_idle_w * r.latency_s, "idle term");
        assert_close(st.energy_cpu_j, soc.p_core_w * r.cpu_core_seconds, "cpu term");
        assert_eq!(st.energy_lane_j, 0.0, "no lanes on a CPU-only run");
        assert_close(st.energy_j, r.energy_j, "total energy");
        assert_close(
            st.energy_j,
            st.energy_idle_j + st.energy_cpu_j + st.energy_lane_j,
            "decomposition sums to the total",
        );
    });
}

/// Monotonicity: delegating work moves energy from the CPU term into
/// the lane term — a placed run draws lane power, a CPU-forced run
/// draws none — and outputs stay bit-identical either way.
#[test]
fn delegation_moves_energy_from_cpu_term_to_lane_term() {
    let g = micro::fallback_heavy(4, 3, 128, 6);
    let soc = SocProfile::pixel6();
    let p = delegable(&g);
    let (plan, _, schedules, cfg) = setup(&g, &p, 4);
    let mut engine = Engine::new(&g, &p, &plan, None);
    engine.set_energy_model(sim::energy_model_for(
        &g,
        &p,
        &plan,
        &schedules,
        &baselines::parallax(),
        &soc,
        &cfg,
        1.0,
    ));

    let auto = place::assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
    assert!(auto.num_delegated() >= 1, "trunk should delegate on pixel6");
    let forced = PlacementPlan::cpu_only(plan.branches.len());

    let (v_cpu, st_cpu) = engine.run_placed(&schedules, &forced, None).unwrap();
    let (v_auto, st_auto) = engine.run_placed(&schedules, &auto, None).unwrap();
    assert_eq!(
        v_cpu.checksum(),
        v_auto.checksum(),
        "placement must never change what is computed"
    );
    assert_eq!(st_cpu.energy_lane_j, 0.0);
    assert!(st_auto.energy_lane_j > 0.0, "delegated run must draw lane power");
    assert!(
        st_auto.energy_cpu_j < st_cpu.energy_cpu_j,
        "delegation must move core-seconds off the host: {} !< {}",
        st_auto.energy_cpu_j,
        st_cpu.energy_cpu_j
    );
    for st in [&st_cpu, &st_auto] {
        assert_close(
            st.energy_j,
            st.energy_idle_j + st.energy_cpu_j + st.energy_lane_j,
            "decomposition sums to the total",
        );
    }
}

/// The `IdleTime::MeasuredWall` knob charges the idle term over the
/// run's host wall clock instead of the modelled span.
#[test]
fn measured_wall_idle_time_uses_host_clock() {
    let g = micro::parallel_chains(4, 8);
    let p = cpu_only(&g);
    let (plan, _, schedules, cfg) = setup(&g, &p, 4);
    let soc = SocProfile::pixel6();
    let mut em = sim::energy_model_for(
        &g,
        &p,
        &plan,
        &schedules,
        &baselines::parallax(),
        &soc,
        &cfg,
        1.0,
    );
    em.idle = IdleTime::MeasuredWall;
    let mut engine = Engine::new(&g, &p, &plan, None);
    engine.set_energy_model(em);
    let (_, st) = engine.run(&schedules).unwrap();
    assert!(st.wall_s > 0.0);
    assert_eq!(
        st.energy_idle_j.to_bits(),
        (soc.p_idle_w * st.wall_s).to_bits(),
        "measured idle term is priced over the reported wall clock"
    );
    assert!(st.energy_j > 0.0);
}

/// `EnergyAware { alpha: 1.0 }` is a pure-latency score — it must
/// reproduce the `Auto` placement exactly.
#[test]
fn energy_aware_alpha_one_matches_auto() {
    for g in [micro::fallback_heavy(4, 3, 72, 6), micro::mixed()] {
        let p = delegable(&g);
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let soc = SocProfile::pixel6();
        let auto = place::assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
        let ea1 =
            place::assign(&g, &p, &plan, &soc, PlacePolicy::EnergyAware { alpha: 1.0 });
        assert_eq!(auto.assignment, ea1.assignment);
    }
}

/// Acceptance case: on `fallback_heavy(4, 3, 72, 6)` the Pixel 6 TPU
/// lane is *faster* than the CPU on the trunk but draws more energy, so
/// `Auto` delegates while `EnergyAware { alpha: 0.0 }` keeps the trunk
/// on the CPU — strictly less modelled energy, bit-identical outputs.
#[test]
fn energy_aware_zero_strictly_beats_auto_on_divergent_model() {
    let g = micro::fallback_heavy(4, 3, 72, 6);
    let soc = SocProfile::pixel6();
    let p = delegable(&g);
    let (plan, _, schedules, cfg) = setup(&g, &p, 4);

    let auto = place::assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
    let ea0 = place::assign(&g, &p, &plan, &soc, PlacePolicy::EnergyAware { alpha: 0.0 });
    assert!(auto.num_delegated() >= 1, "Auto must take the faster lane");
    assert_eq!(ea0.num_delegated(), 0, "alpha=0 must keep the costlier lane idle");

    let e_auto = place::plan_energy(&g, &p, &plan, &auto, &soc);
    let e_ea0 = place::plan_energy(&g, &p, &plan, &ea0, &soc);
    assert!(e_auto.is_finite() && e_ea0.is_finite());
    assert!(
        e_ea0 < e_auto,
        "EnergyAware(0) must strictly lower modelled energy: {e_ea0} !< {e_auto}"
    );

    let mut engine = Engine::new(&g, &p, &plan, None);
    engine.set_energy_model(sim::energy_model_for(
        &g,
        &p,
        &plan,
        &schedules,
        &baselines::parallax(),
        &soc,
        &cfg,
        1.0,
    ));
    let (v_auto, st_auto) = engine.run_placed(&schedules, &auto, None).unwrap();
    let (v_ea0, st_ea0) = engine.run_placed(&schedules, &ea0, None).unwrap();
    assert_eq!(v_auto.checksum(), v_ea0.checksum(), "policies must agree bit-for-bit");
    // the executor's ledger sees the same trade the placement model
    // promised: the all-CPU run draws no lane power
    assert!(st_auto.energy_lane_j > 0.0);
    assert_eq!(st_ea0.energy_lane_j, 0.0);
}

/// Thermal throttling scenario: a stream of inferences heats the lane
/// the trunk was placed on until its rate factor collapses; the
/// segmented engine must re-place mid-stream (eventually back onto the
/// CPU once every lane has throttled), keep every output bit-identical
/// to a CPU-forced run, and keep every post-throttle lease inside the
/// governor budget.
#[test]
fn thermal_throttling_replaces_mid_stream_bit_identically() {
    let g = micro::fallback_heavy(4, 3, 128, 6);
    let soc = SocProfile::pixel6();
    let p = delegable(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);
    let cfg = SchedCfg { max_threads: 4, margin: 0.4 };
    const BUDGET: u64 = 1 << 30;

    // calibrate: lane busy-seconds one inference accrues when nothing
    // throttles (also pins down the CPU-forced reference checksum)
    let probe = SegmentedEngine::with_thermal(
        &engine,
        cfg,
        BUDGET,
        &soc,
        PlacePolicy::Auto,
        ThermalModel::none(),
        0.25,
    );
    assert!(probe.placement_snapshot().unwrap().num_delegated() >= 1);
    let (v_probe, _) = probe.run(&[], None).unwrap();
    let per_run: f64 = probe.lane_busy_s().iter().sum();
    assert!(per_run > 0.0, "delegated stream must accrue lane busy time");
    assert_eq!(probe.thermal_replacements(), 0, "none() model never re-places");

    // threshold crossed mid-stream; past it the lane runs 1000x slower,
    // so no lane that has done real work can keep the trunk
    let model =
        ThermalModel::new(vec![ThermalStep { busy_s: per_run * 2.5, rate_factor: 1e-3 }]);
    let se = SegmentedEngine::with_thermal(
        &engine,
        cfg,
        BUDGET,
        &soc,
        PlacePolicy::Auto,
        model,
        0.25,
    );
    let before = se.placement_snapshot().unwrap();
    assert!(before.num_delegated() >= 1);

    let gov = MemoryGovernor::new(BUDGET);
    let mut checksums = Vec::new();
    for _ in 0..8 {
        let (v, _) = se.run(&[], Some(&gov)).unwrap();
        checksums.push(v.checksum());
        assert_eq!(gov.in_use(), 0, "every lease must be returned");
        assert!(
            gov.peak_reserved() <= gov.budget(),
            "post-throttle leases must respect the governor budget"
        );
    }
    assert!(
        se.thermal_replacements() >= 1,
        "the throttled lane must trigger a mid-stream re-placement"
    );
    let after = se.placement_snapshot().unwrap();
    assert_ne!(before.assignment, after.assignment, "placement must have moved");
    assert_eq!(
        after.num_delegated(),
        0,
        "with every worked lane throttled 1000x the trunk must fall back to CPU"
    );

    // bit-identical across the whole stream — before, during, and after
    // the re-placements — and equal to a CPU-forced reference
    let forced = PlacementPlan::cpu_only(plan.branches.len());
    let cpu_se = SegmentedEngine::with_placement(&engine, cfg, BUDGET, forced);
    let (v_cpu, _) = cpu_se.run(&[], None).unwrap();
    assert_eq!(v_probe.checksum(), v_cpu.checksum());
    for (i, c) in checksums.iter().enumerate() {
        assert_eq!(
            *c,
            v_cpu.checksum(),
            "run {i} of the throttling stream must stay bit-identical"
        );
    }
}
