//! Static analyzer tests: every shipped artifact must analyze clean,
//! and each mutation class the plan/placement passes exist to catch
//! (arena overlap, wave reorder, lease shrink, illegal delegation)
//! must be detected *statically* — no execution — with the exact
//! expected [`Finding`](parallax::analysis::Finding).

use parallax::analysis::{self, Code, Pass, Severity};
use parallax::branch::{self, DEFAULT_BETA};
use parallax::ctrl::ShapeEnv;
use parallax::device::SocProfile;
use parallax::exec::Engine;
use parallax::graph::{Graph, OpClass, OpKind};
use parallax::memory::branch_memories;
use parallax::models::{micro, ModelKind};
use parallax::partition::{partition, CostModel, Partition};
use parallax::place::{self, Placement, PlacementPlan};
use parallax::sched::{self, SchedCfg};

fn cpu_only(g: &Graph) -> Partition {
    partition(
        g,
        &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
    )
}

fn loose(g: &Graph) -> Partition {
    partition(g, &CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX })
}

fn schedules_for(
    g: &Graph,
    p: &Partition,
    plan: &branch::BranchPlan,
) -> Vec<parallax::sched::LayerSchedule> {
    let mems = branch_memories(g, p, plan);
    let cfg = SchedCfg { max_threads: 6, margin: 0.4 };
    sched::schedule(plan, &mems, 1 << 34, &cfg)
}

// -- acceptance: everything shipped analyzes clean ----------------------

#[test]
fn every_shipped_model_and_profile_analyzes_clean() {
    for kind in ModelKind::ALL {
        for mk in SocProfile::ALL {
            let soc = mk();
            let findings = analysis::analyze_model(kind, &soc);
            assert!(
                findings.is_empty(),
                "{} @ {}: {:?}",
                kind.slug(),
                soc.name,
                findings
            );
        }
    }
}

#[test]
fn clean_captures_pass_the_plan_audit() {
    let models: Vec<(&str, Graph)> = vec![
        ("chain8", micro::chain(8)),
        ("diamond4x4", micro::diamond(4, 4)),
        ("parallel4x6", micro::parallel_chains(4, 6)),
    ];
    for (name, g) in &models {
        for p in [cpu_only(g), loose(g)] {
            let plan = branch::plan(g, &p, DEFAULT_BETA);
            let engine = Engine::new(g, &p, &plan, None);
            let s = schedules_for(g, &p, &plan);
            let cp = engine.capture(&s, &ShapeEnv::unresolved(), None);
            let findings = analysis::plan::check(g, &p, &plan, &cp, None);
            assert!(findings.is_empty(), "{name}: {findings:?}");
        }
    }
}

// -- mutation class 1: arena overlap ------------------------------------

#[test]
fn arena_overlap_is_detected_statically() {
    let g = micro::chain(8);
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);
    let s = schedules_for(&g, &p, &plan);
    let mut cp = engine.capture(&s, &ShapeEnv::unresolved(), None);
    assert!(cp.corrupt_arena_overlap(), "chain must have >= 2 internal offsets");
    let findings = analysis::plan::check(&g, &p, &plan, &cp, None);
    assert!(!findings.is_empty(), "zeroed offsets must alias");
    for f in &findings {
        assert_eq!(f.code, Code::ArenaOverlap, "{f}");
        assert_eq!(f.pass, Pass::Plan, "{f}");
        assert_eq!(f.severity, Severity::Error, "{f}");
        assert!(f.message.contains("live together"), "{f}");
    }
}

// -- mutation class 2: wave reorder -------------------------------------

#[test]
fn wave_reorder_is_detected_statically() {
    let g = micro::diamond(4, 4);
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);
    let s = schedules_for(&g, &p, &plan);
    let cp_clean = engine.capture(&s, &ShapeEnv::unresolved(), None);
    assert!(analysis::plan::check(&g, &p, &plan, &cp_clean, None).is_empty());

    let mut cp = engine.capture(&s, &ShapeEnv::unresolved(), None);
    assert!(cp.corrupt_wave_order(), "diamond must schedule >= 2 layers");
    let findings = analysis::plan::check(&g, &p, &plan, &cp, None);
    assert!(!findings.is_empty(), "swapped layers must break an edge");
    for f in &findings {
        assert_eq!(f.code, Code::WaveOrderViolation, "{f}");
        assert_eq!(f.pass, Pass::Plan, "{f}");
        assert_eq!(f.severity, Severity::Error, "{f}");
    }
}

// -- mutation class 3: lease shrink -------------------------------------

#[test]
fn lease_shrink_is_detected_statically() {
    let g = micro::parallel_chains(4, 6);
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);
    let s = schedules_for(&g, &p, &plan);
    let mut cp = engine.capture(&s, &ShapeEnv::unresolved(), None);
    assert!(cp.corrupt_lease_shrink(), "demands must be > 1 byte");
    let findings = analysis::plan::check(&g, &p, &plan, &cp, None);
    assert_eq!(findings.len(), 1, "exactly the shrunk figure: {findings:?}");
    let f = &findings[0];
    assert_eq!(f.code, Code::LeaseUnderProvisioned, "{f}");
    assert_eq!(f.pass, Pass::Plan, "{f}");
    assert_eq!(f.severity, Severity::Error, "{f}");
    assert!(f.message.contains("under-lease"), "{f}");
}

#[test]
fn placed_run_lease_shrink_is_detected_statically() {
    // Force a delegate-safe branch onto pixel6's lane 0, capture under
    // that placement, then shrink the frozen run-wide lease.
    let g = micro::parallel_chains(4, 6);
    let p = loose(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let b = (0..plan.branches.len())
        .find(|&b| place::delegate_safe(&g, &p, &plan, b))
        .expect("loose partition yields a delegate-safe branch");
    let mut pl = PlacementPlan::cpu_only(plan.branches.len());
    pl.assignment[b] = Placement::Delegate(0);
    pl.staging_bytes[b] = place::staging_bytes(&g, &p, &plan, b);
    let soc = SocProfile::pixel6();
    assert!(analysis::placement::check(&g, &p, &plan, &soc, &pl).is_empty());

    let engine = Engine::new(&g, &p, &plan, None);
    let s = schedules_for(&g, &p, &plan);
    let mut cp = engine.capture(&s, &ShapeEnv::unresolved(), Some(&pl));
    assert!(
        analysis::plan::check(&g, &p, &plan, &cp, Some(&pl)).is_empty(),
        "clean placed capture must audit clean"
    );
    assert!(cp.corrupt_lease_shrink());
    let findings = analysis::plan::check(&g, &p, &plan, &cp, Some(&pl));
    assert_eq!(findings.len(), 1, "{findings:?}");
    let f = &findings[0];
    assert_eq!(f.code, Code::LeaseUnderProvisioned, "{f}");
    assert_eq!(f.location, "CapturedPlan.placed.run_demand", "{f}");
}

// -- mutation class 4: illegal delegation -------------------------------

#[test]
fn illegal_delegation_is_detected_statically() {
    // gated() holds an If node: delegating its branch violates
    // delegate_safe (dynamic-class op) — the placement pass must say
    // so without ever running the graph.
    let g = micro::gated(3);
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let soc = SocProfile::pixel6();
    let clean = PlacementPlan::cpu_only(plan.branches.len());
    assert!(analysis::placement::check(&g, &p, &plan, &soc, &clean).is_empty());

    let b = (0..plan.branches.len())
        .find(|&b| {
            plan.branch_nodes(&g, &p, b)
                .iter()
                .any(|&id| g.node(id).kind.class() == OpClass::Dynamic)
        })
        .expect("gated() has a dynamic branch");
    let mut pl = PlacementPlan::cpu_only(plan.branches.len());
    pl.assignment[b] = Placement::Delegate(0);
    let findings = analysis::placement::check(&g, &p, &plan, &soc, &pl);
    assert_eq!(findings.len(), 1, "{findings:?}");
    let f = &findings[0];
    assert_eq!(f.code, Code::IllegalDelegation, "{f}");
    assert_eq!(f.pass, Pass::Placement, "{f}");
    assert_eq!(f.severity, Severity::Error, "{f}");
    assert!(f.location.contains(&format!("branch {b}")), "{f}");
}

#[test]
fn unreachable_and_out_of_bounds_lanes_are_flagged() {
    let g = micro::parallel_chains(4, 6);
    let p = loose(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let b = (0..plan.branches.len())
        .find(|&b| place::delegate_safe(&g, &p, &plan, b))
        .expect("delegate-safe branch");

    // p30pro's lane 0 exists but is unreachable from the runtime.
    let soc = SocProfile::p30_pro();
    assert!(!soc.lanes[0].reachable, "profile precondition");
    let mut pl = PlacementPlan::cpu_only(plan.branches.len());
    pl.assignment[b] = Placement::Delegate(0);
    pl.staging_bytes[b] = place::staging_bytes(&g, &p, &plan, b);
    let findings = analysis::placement::check(&g, &p, &plan, &soc, &pl);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].code, Code::UnreachableLane, "{}", findings[0]);

    // A lane index past the profile's lane list.
    let mut pl = PlacementPlan::cpu_only(plan.branches.len());
    pl.assignment[b] = Placement::Delegate(99);
    pl.staging_bytes[b] = place::staging_bytes(&g, &p, &plan, b);
    let findings = analysis::placement::check(&g, &p, &plan, &soc, &pl);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].code, Code::LaneOutOfBounds, "{}", findings[0]);
}

#[test]
fn staging_mismatch_is_flagged() {
    let g = micro::parallel_chains(4, 6);
    let p = loose(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let b = (0..plan.branches.len())
        .find(|&b| place::delegate_safe(&g, &p, &plan, b))
        .expect("delegate-safe branch");
    let soc = SocProfile::pixel6();
    let mut pl = PlacementPlan::cpu_only(plan.branches.len());
    pl.assignment[b] = Placement::Delegate(0);
    pl.staging_bytes[b] = place::staging_bytes(&g, &p, &plan, b) + 1;
    let findings = analysis::placement::check(&g, &p, &plan, &soc, &pl);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].code, Code::StagingMismatch, "{}", findings[0]);
    assert!(findings[0].message.contains("mis-lease"), "{}", findings[0]);
}

// -- graph pass on seeded-broken graphs ---------------------------------

#[test]
fn graph_cycle_is_flagged() {
    let mut g = Graph::new("cyclic");
    let t1 = g.tensor(&[64], "t1");
    let t2 = g.tensor(&[64], "t2");
    g.add_node("a", OpKind::Relu, vec![t2], vec![t1]);
    g.add_node("b", OpKind::Relu, vec![t1], vec![t2]);
    let findings = analysis::graph::check(&g);
    assert!(
        findings.iter().any(|f| f.code == Code::Cycle),
        "{findings:?}"
    );
}

#[test]
fn graph_arity_mismatch_is_flagged() {
    let mut g = Graph::new("bad-arity");
    let a = g.tensor(&[8, 8], "a");
    let o = g.tensor(&[8, 8], "o");
    // MatMul's kernel indexes ins[1]; one input would read off the end.
    g.add_node("mm", OpKind::MatMul, vec![a], vec![o]);
    let findings = analysis::graph::check(&g);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].code, Code::ArityMismatch, "{}", findings[0]);
    assert_eq!(findings[0].pass, Pass::Graph, "{}", findings[0]);
}

#[test]
fn graph_dead_end_is_flagged_as_warning() {
    let mut g = Graph::new("dead-end");
    let input = g.tensor(&[64], "in");
    let o = g.tensor(&[64], "o");
    g.add_node("work", OpKind::Relu, vec![input], vec![o]);
    let out = g.tensor(&[64], "out");
    g.add_node("output", OpKind::Output, vec![o], vec![out]);
    // A side computation nothing consumes, in a graph that has a sink.
    let s = g.tensor(&[64], "side");
    g.add_node("side", OpKind::Silu, vec![input], vec![s]);
    let findings = analysis::graph::check(&g);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].code, Code::DeadEnd, "{}", findings[0]);
    assert_eq!(findings[0].severity, Severity::Warning, "{}", findings[0]);
    assert!(findings[0].location.contains("side"), "{}", findings[0]);
}

#[test]
fn graph_pass_accepts_micro_graphs() {
    for (name, g) in [
        ("chain", micro::chain(8)),
        ("diamond", micro::diamond(4, 4)),
        ("parallel", micro::parallel_chains(4, 6)),
        ("gated", micro::gated(3)),
        ("mixed", micro::mixed()),
    ] {
        let findings = analysis::graph::check(&g);
        assert!(findings.is_empty(), "{name}: {findings:?}");
    }
}

// -- debug-build pre-replay hook ----------------------------------------

// Only meaningful where debug_assertions are on (the hook compiles out
// of release builds: the audit is a capture-time check, not a hot-path
// cost).
#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "pre-replay static audit")]
fn corrupted_capture_panics_before_replay() {
    let g = micro::chain(8);
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);
    let s = schedules_for(&g, &p, &plan);
    let mut cp = engine.capture(&s, &ShapeEnv::unresolved(), None);
    assert!(cp.corrupt_arena_overlap());
    let _ = engine.run_replayed(&cp, None);
}

// -- finding formatting --------------------------------------------------

#[test]
fn findings_render_with_pass_code_and_location() {
    let g = micro::gated(3);
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let soc = SocProfile::pixel6();
    let b = (0..plan.branches.len())
        .find(|&b| {
            plan.branch_nodes(&g, &p, b)
                .iter()
                .any(|&id| g.node(id).kind.class() == OpClass::Dynamic)
        })
        .unwrap();
    let mut pl = PlacementPlan::cpu_only(plan.branches.len());
    pl.assignment[b] = Placement::Delegate(0);
    let findings = analysis::placement::check(&g, &p, &plan, &soc, &pl);
    let rendered = findings[0].to_string();
    assert!(rendered.starts_with("[error] placement/illegal-delegation"), "{rendered}");
    assert!(rendered.contains(&format!("branch {b}")), "{rendered}");
}
