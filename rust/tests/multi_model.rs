//! Multi-model serving: server-wide lane-aware placement + SLO
//! admission, pinned deterministically.
//!
//! Invariants:
//! * two fallback-heavy tenants never trunk onto the same lane while a
//!   second reachable lane is idle — the shared ledger's whole point
//! * dropping a tenant re-places the survivors; the freed (faster)
//!   lane is reclaimed
//! * degraded-to-CPU responses are bit-identical to normally-placed
//!   ones, across random DAGs and lane knockouts
//! * deadline admission is modelled-ledger arithmetic, so outcomes are
//!   exact counts, not timing-dependent ones

use parallax::baselines::{Framework, Pipeline};
use parallax::branch::{self, DEFAULT_BETA};
use parallax::device::SocProfile;
use parallax::memory::branch_memories;
use parallax::models::micro;
use parallax::partition::{partition, CostModel};
use parallax::place::{self, PlacePolicy};
use parallax::sched::{self, SchedCfg};
use parallax::serve::{Outcome, PlacedEngineExecutor, Server, SloSpec};
use parallax::sim::Mode;
use parallax::util::prop;

fn loose() -> CostModel {
    CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX }
}

/// One delegate-eligible matmul trunk + GELU fallback chains: the
/// profile where pixel6's two lanes both beat the CPU, so a second
/// tenant always has somewhere cheaper than colliding.
fn heavy_pipe(soc: &SocProfile) -> Pipeline {
    Pipeline::from_graph(
        Framework::Parallax,
        micro::fallback_heavy(4, 4, 128, 6),
        &loose(),
        soc,
        Mode::Heterogeneous,
        SchedCfg::default(),
    )
}

#[test]
fn tenants_spread_across_lanes_and_reclaim_on_drop() {
    let soc = SocProfile::pixel6();
    let lanes = soc.lanes.len();
    assert!(lanes >= 2, "test needs a multi-lane profile");

    // what a tenant picks with the device to itself: its home lane
    let solo = heavy_pipe(&soc);
    let alone =
        place::assign(&solo.graph, &solo.partition, &solo.plan, &solo.soc, PlacePolicy::Auto);
    assert_eq!(alone.num_delegated(), 1, "one trunk delegates");
    let home = alone.delegated().next().and_then(|b| alone.lane_of(b)).unwrap();

    let mut s = Server::new();
    let pa = s.register_placed("ma", heavy_pipe(&soc), 7);
    assert_eq!(
        pa.lane_job_counts(lanes)[home],
        1,
        "sole tenant lands on its home lane"
    );
    s.register_placed("mb", heavy_pipe(&soc), 8);

    let placements = s.placements();
    assert_eq!(placements.len(), 2);
    let ca = placements[0].1.lane_job_counts(lanes);
    let cb = placements[1].1.lane_job_counts(lanes);
    assert_eq!(ca.iter().sum::<usize>(), 1, "ma still delegates its trunk");
    assert_eq!(cb.iter().sum::<usize>(), 1, "mb still delegates its trunk");
    assert_eq!(ca[home], 1, "first tenant keeps the home lane");
    assert_eq!(
        cb[home], 0,
        "second tenant must not collide on the loaded lane while \
         another reachable lane is idle: ca={ca:?} cb={cb:?}"
    );

    // both tenants serve through the shared dispatcher
    for (m, seed) in [("ma", 1u64), ("mb", 2)] {
        let r = s.infer(m, seed).unwrap();
        assert_eq!(r.outcome, Outcome::Admitted);
        assert!(r.checksum.is_finite());
    }
    assert_eq!(s.lane_ledger().outstanding_total(), 0.0);

    // dropping ma frees the home lane; the joint re-placement must
    // move the survivor onto it
    s.drop_model("ma").unwrap();
    let after = s.placements();
    assert_eq!(after.len(), 1);
    assert_eq!(after[0].0, "mb");
    let cb_after = after[0].1.lane_job_counts(lanes);
    assert_eq!(cb_after[home], 1, "survivor reclaims the freed home lane");
    assert_eq!(cb_after, alone.lane_job_counts(lanes), "survivor now places like a sole tenant");

    // the survivor's swapped-in executor still serves
    let r = s.infer("mb", 3).unwrap();
    assert_eq!(r.outcome, Outcome::Admitted);
    assert!(s.infer("ma", 4).is_err(), "dropped tenant rejects new work");
}

#[test]
fn prop_degraded_cpu_is_bit_identical_to_placed_path() {
    // Across random DAGs and random lane knockouts, a request degraded
    // to the CPU-forced path must produce the same checksum as the
    // normally-placed path — degradation changes *where*, never *what*.
    prop::check("serve degraded bit-identity", 10, |rng| {
        let g = match rng.range(0, 3) {
            0 => micro::fallback_heavy(rng.range(2, 5), rng.range(2, 4), 32, 3),
            1 => micro::fallback_heavy_lanes(2, rng.range(2, 4), 2, 32, 3),
            _ => micro::random_dag(rng, rng.range(2, 8), rng.range(1, 5)),
        };
        let mut soc = SocProfile::pixel6();
        for lane in &mut soc.lanes {
            if rng.chance(0.4) {
                lane.reachable = false;
            }
        }
        let p = partition(&g, &loose());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let mems = branch_memories(&g, &p, &plan);
        let cfg = SchedCfg { max_threads: rng.range(1, 5), margin: 0.4 };
        let schedules = sched::schedule(&plan, &mems, 1 << 34, &cfg);
        let placement = place::assign(&g, &p, &plan, &soc, PlacePolicy::Auto);

        let mut s = Server::new();
        s.register(
            "placed",
            Box::new(PlacedEngineExecutor::new(
                g.clone(),
                p.clone(),
                plan.clone(),
                schedules.clone(),
                placement.clone(),
            )),
        );
        // pinned SLO that can never make the lane but always makes the
        // CPU: every deadline-tagged request degrades
        s.register_with_slo(
            "degraded",
            0,
            SloSpec {
                lane: Some(0),
                lane_service_s: f64::INFINITY,
                cpu_service_s: 0.0,
                remote: None,
            },
            Box::new(PlacedEngineExecutor::new(g, p, plan, schedules, placement)),
        );
        for seed in [1u64, 2] {
            let a = s.infer("placed", seed).unwrap();
            assert_eq!(a.outcome, Outcome::Admitted);
            let b = s.infer_with_deadline("degraded", seed, 1.0).unwrap();
            assert_eq!(b.outcome, Outcome::DegradedCpu);
            assert_eq!(
                a.checksum, b.checksum,
                "degraded CPU path changed results (seed {seed})"
            );
        }
    });
}

#[test]
fn deadline_admission_counts_are_exact_for_placed_tenants() {
    // Admission is arithmetic over modelled figures, so with fixed
    // seeds the LoadReport counts are exact — no sleeps, no tolerance.
    let soc = SocProfile::pixel6();
    let mut s = Server::new();
    let placement = s.register_placed("m", heavy_pipe(&soc), 3);
    assert_eq!(placement.num_delegated(), 1);

    // loose deadline: modelled lane seconds are tiny next to 1e9, so
    // every request is admitted on the placed path
    let rep = s.run_load_slo(&["m"], 12, 3, 5, Some(1e9)).unwrap();
    assert_eq!(
        (rep.admitted, rep.degraded, rep.shed, rep.dropped, rep.skipped, rep.spilled),
        (12, 0, 0, 0, 0, 0)
    );
    assert_eq!(rep.responses.len(), 12);
    // the LoadReport accounting invariant: every submission resolves to
    // exactly one outcome class, never silently
    assert_eq!(
        rep.admitted + rep.degraded + rep.shed + rep.dropped + rep.skipped + rep.spilled,
        12
    );

    // impossible deadline: even the degraded CPU path misses zero
    // seconds, so every request is shed — explicitly, never silently
    let rep = s.run_load_slo(&["m"], 12, 3, 5, Some(0.0)).unwrap();
    assert_eq!(
        (rep.admitted, rep.degraded, rep.shed, rep.dropped, rep.skipped, rep.spilled),
        (0, 0, 12, 0, 0, 0)
    );
    assert_eq!(
        rep.admitted + rep.degraded + rep.shed + rep.dropped + rep.skipped + rep.spilled,
        12
    );
    assert_eq!(rep.responses.len(), 12, "shed requests still get responses");
    assert!(rep.responses.iter().all(|r| r.outcome == Outcome::Shed && r.batched == 0));
    assert!(rep.latency.is_empty(), "nothing executed, nothing timed");
    assert_eq!(s.lane_ledger().outstanding_total(), 0.0, "ledger drains to exactly zero");
}
