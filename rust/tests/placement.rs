//! Heterogeneous placement & delegate co-execution tests.
//!
//! Pins the contracts of `place` + `exec::run_placed` +
//! `ctrl::SegmentedEngine::with_placement`:
//! * CPU-forced placement is bit-identical to the classic `Engine::run`
//! * delegated runs produce identical outputs with strictly fewer
//!   CPU-wave branch executions
//! * placement never assigns `OpClass::Dynamic` work to a delegate lane
//!   and never targets an unreachable lane
//! * 2-lane runs are bit-identical to 1-lane and CPU-forced runs
//! * cross-layer overlap merges lane outputs before their first
//!   consumer (no read-before-merge — overlap, barrier-join and
//!   CPU-forced runs all agree bit for bit)
//! * governed placed runs never exceed the budget with every in-flight
//!   lane job's host-visible staging included in its layers' leases

use parallax::branch::{self, DEFAULT_BETA};
use parallax::ctrl::SegmentedEngine;
use parallax::device::SocProfile;
use parallax::exec::Engine;
use parallax::graph::{DType, Dim, Graph, OpClass, OpKind};
use parallax::memory::branch_memories;
use parallax::models::micro;
use parallax::partition::{partition, CostModel};
use parallax::place::{self, PlacePolicy, Placement, PlacementPlan};
use parallax::sched::{
    self, placed_inflight_staging, placed_layer_demand, MemoryGovernor, SchedCfg,
};
use parallax::util::prop;

fn loose() -> CostModel {
    CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX }
}

/// A placement that force-delegates every delegate-safe branch,
/// round-robined across the device's reachable lanes, whatever the
/// latency model says — exercises the execution paths even on graphs
/// too small for the Auto policy to bother offloading.
fn delegate_all(
    g: &Graph,
    p: &parallax::partition::Partition,
    plan: &branch::BranchPlan,
    soc: &SocProfile,
) -> PlacementPlan {
    let lanes: Vec<usize> = soc.available_lanes().map(|(i, _)| i).collect();
    assert!(!lanes.is_empty(), "delegate_all needs a reachable lane");
    let mut pl = PlacementPlan::cpu_only(plan.branches.len());
    let mut k = 0usize;
    for b in 0..plan.branches.len() {
        if place::delegate_safe(g, p, plan, b) {
            let lane = lanes[k % lanes.len()];
            pl.assignment[b] = Placement::Delegate(lane);
            pl.staging_bytes[b] = place::staging_bytes(g, p, plan, b);
            // charge the lane the job actually runs on, so modelled
            // acc-busy stats line up with the assignment
            pl.delegate_latency_s[b] =
                place::lane_delegate_latency(g, p, plan, b, soc, &soc.lanes[lane]);
            k += 1;
        }
    }
    pl
}

/// fallback_heavy with a dynamic NMS tail: static trunk + CPU chains
/// merge, then NonMaxSuppression gates a dynamic post-segment — the
/// shape where delegation and §3.4 segmentation must compose.
fn fallback_heavy_dynamic(chains: usize, chain_len: usize, dim: usize, trunk_len: usize) -> Graph {
    let mut g = micro::fallback_heavy(chains, chain_len, dim, trunk_len);
    let merged = g.tensors().iter().find(|t| t.label == "merged").map(|t| t.id).unwrap();
    let dets = g.add_tensor(
        vec![Dim::Dynamic { max: 64 }, Dim::Static(6)],
        DType::F32,
        "dets",
    );
    g.add_node("nms", OpKind::NonMaxSuppression, vec![merged], vec![dets]);
    let out = g.add_tensor(
        vec![Dim::Dynamic { max: 64 }, Dim::Static(6)],
        DType::F32,
        "out",
    );
    g.add_node("output", OpKind::Output, vec![dets], vec![out]);
    g
}

#[test]
fn cpu_forced_matches_classic_run_across_thread_counts() {
    let g = micro::fallback_heavy(4, 3, 32, 3);
    let p = partition(&g, &loose());
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let mems = branch_memories(&g, &p, &plan);
    let engine = Engine::new(&g, &p, &plan, None);
    let forced = PlacementPlan::cpu_only(plan.branches.len());
    let mut baseline = None;
    for threads in [1, 2, 6] {
        let cfg = SchedCfg { max_threads: threads, margin: 0.4 };
        let s = sched::schedule(&plan, &mems, 1 << 34, &cfg);
        let (v_classic, _) = engine.run(&s).unwrap();
        let (v_placed, st) = engine.run_placed(&s, &forced, None).unwrap();
        assert_eq!(v_classic.checksum(), v_placed.checksum(), "threads={threads}");
        assert_eq!(st.delegate_jobs, 0);
        let c = v_placed.checksum();
        if let Some(prev) = baseline {
            assert_eq!(prev, c, "threads={threads} changed results");
        }
        baseline = Some(c);
    }
}

#[test]
fn delegated_outputs_identical_with_fewer_cpu_wave_runs() {
    let g = micro::fallback_heavy(6, 4, 48, 3);
    let soc = SocProfile::pixel6();
    let p = partition(&g, &loose());
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let mems = branch_memories(&g, &p, &plan);
    let engine = Engine::new(&g, &p, &plan, None);
    let cfg = SchedCfg { max_threads: 2, margin: 0.4 };
    let s = sched::schedule(&plan, &mems, 1 << 34, &cfg);
    let delegated = delegate_all(&g, &p, &plan, &soc);
    assert!(delegated.num_delegated() >= 1);
    let forced = PlacementPlan::cpu_only(plan.branches.len());
    let (v_cpu, st_cpu) = engine.run_placed(&s, &forced, None).unwrap();
    let (v_del, st_del) = engine.run_placed(&s, &delegated, None).unwrap();
    assert_eq!(v_cpu.checksum(), v_del.checksum());
    assert!(v_del.all_finite());
    assert_eq!(st_del.delegate_jobs, delegated.num_delegated());
    assert!(st_del.cpu_branch_runs < st_cpu.cpu_branch_runs);
    assert_eq!(st_del.cpu_branch_runs + st_del.delegate_jobs, st_cpu.cpu_branch_runs);
}

#[test]
fn two_lane_run_bit_identical_to_one_lane_and_cpu_forced() {
    // two independent trunks the Auto policy spreads across pixel6's
    // TPU + GPU lanes; truncating the profile to one lane must change
    // nothing but the lane schedule, and CPU-forcing must reproduce
    // the classic engine — all four stores bit-identical.
    let g = micro::fallback_heavy_lanes(2, 3, 4, 128, 6);
    let soc2 = SocProfile::pixel6();
    let mut soc1 = SocProfile::pixel6();
    soc1.lanes.truncate(1);
    let p = partition(&g, &loose());
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let mems = branch_memories(&g, &p, &plan);
    let engine = Engine::new(&g, &p, &plan, None);
    let cfg = SchedCfg { max_threads: 2, margin: 0.4 };
    let s = sched::schedule(&plan, &mems, 1 << 34, &cfg);
    let two = place::assign(&g, &p, &plan, &soc2, PlacePolicy::Auto);
    let one = place::assign(&g, &p, &plan, &soc1, PlacePolicy::Auto);
    assert_eq!(two.num_delegated(), 2, "both trunks delegate on the 2-lane profile");
    assert_eq!(two.num_lanes_used(), 2, "busy-time balancing uses both lanes");
    assert_eq!(one.num_delegated(), 2, "trunks still beat the CPU on one lane");
    assert_eq!(one.num_lanes_used(), 1);
    let forced = PlacementPlan::cpu_only(plan.branches.len());
    let (v_classic, _) = engine.run(&s).unwrap();
    let (v_forced, _) = engine.run_placed(&s, &forced, None).unwrap();
    let (v_one, st_one) = engine.run_placed(&s, &one, None).unwrap();
    let (v_two, st_two) = engine.run_placed(&s, &two, None).unwrap();
    assert_eq!(v_classic.checksum(), v_forced.checksum());
    assert_eq!(v_forced.checksum(), v_one.checksum(), "1-lane changed results");
    assert_eq!(v_one.checksum(), v_two.checksum(), "2-lane changed results");
    assert_eq!(st_one.delegate_jobs, 2);
    assert_eq!(st_two.delegate_jobs, 2);
}

#[test]
fn prop_placement_never_delegates_dynamic_work_or_unreachable_lanes() {
    prop::check("no dynamic / no unreachable lane", 40, |rng| {
        let g = match rng.range(0, 4) {
            0 => micro::mixed(),
            1 => micro::gated(rng.range(2, 6)),
            2 => fallback_heavy_dynamic(rng.range(2, 5), 3, 32, 3),
            _ => {
                let (layers, width) = (rng.range(2, 8), rng.range(1, 5));
                micro::random_dag(rng, layers, width)
            }
        };
        let socs = [SocProfile::pixel6, SocProfile::p30_pro, SocProfile::redmi_k50];
        let mut soc = socs[rng.range(0, 3)]();
        // randomly knock out lanes: unreachable hardware must never be
        // a placement target whatever the modelled rates say
        for lane in &mut soc.lanes {
            if rng.chance(0.3) {
                lane.reachable = false;
                lane.flops *= 8.0;
                lane.dispatch_s /= 8.0;
            }
        }
        let p = partition(&g, &loose());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let placed = place::assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
        for b in placed.delegated() {
            assert!(plan.branches[b].has_delegate, "branch {b} has no region");
            let lane = placed.lane_of(b).unwrap();
            assert!(
                soc.lanes[lane].reachable,
                "branch {b} delegated to unreachable lane {lane}"
            );
            for id in plan.branch_nodes(&g, &p, b) {
                assert_ne!(
                    g.node(id).kind.class(),
                    OpClass::Dynamic,
                    "dynamic op {} delegated",
                    g.node(id).name
                );
                assert!(
                    !g.node_has_dynamic_shape(id),
                    "dynamic shape {} delegated",
                    g.node(id).name
                );
            }
        }
    });
}

#[test]
fn prop_zoo_placement_keeps_dynamic_on_cpu() {
    // the real zoo under the paper's cost model: whatever the device,
    // no dynamic operator may reach a delegate lane
    for kind in [
        parallax::models::ModelKind::WhisperTiny,
        parallax::models::ModelKind::Yolov8n,
    ] {
        let g = kind.build();
        let p = partition(&g, &CostModel::default());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        for make in SocProfile::ALL {
            let placed = place::assign(&g, &p, &plan, &make(), PlacePolicy::Auto);
            for b in placed.delegated() {
                for id in plan.branch_nodes(&g, &p, b) {
                    assert_ne!(g.node(id).kind.class(), OpClass::Dynamic);
                }
            }
        }
    }
}

#[test]
fn cross_layer_overlap_merges_before_first_consumer() {
    // staged pipeline: every trunk's first consumer is the *final*
    // merge, layers away from its dispatch.  If the overlap path ever
    // let a consumer read the store before its lane job merged, the
    // consumer would read the engine's synthesized stand-in and the
    // checksum would diverge from the CPU-forced run — so three-way
    // bit-identity (overlap / barrier-join / CPU-forced) pins the
    // merge-before-first-consumer contract.
    let g = micro::fallback_pipeline(3, 3, 3, 64, 4);
    let soc = SocProfile::pixel6();
    let p = partition(&g, &loose());
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let mems = branch_memories(&g, &p, &plan);
    let engine = Engine::new(&g, &p, &plan, None);
    let cfg = SchedCfg { max_threads: 2, margin: 0.4 };
    let s = sched::schedule(&plan, &mems, 1 << 34, &cfg);
    let placement = delegate_all(&g, &p, &plan, &soc);
    assert!(placement.num_delegated() >= 3, "one trunk per stage must delegate");
    let forced = PlacementPlan::cpu_only(plan.branches.len());
    let (v_forced, _) = engine.run_placed(&s, &forced, None).unwrap();
    let (v_overlap, st_overlap) = engine.run_placed_opts(&s, &placement, None, true).unwrap();
    let (v_barrier, st_barrier) = engine.run_placed_opts(&s, &placement, None, false).unwrap();
    assert_eq!(
        v_forced.checksum(),
        v_overlap.checksum(),
        "overlap read a value before its merge"
    );
    assert_eq!(v_overlap.checksum(), v_barrier.checksum());
    assert_eq!(st_overlap.delegate_jobs, placement.num_delegated());
    assert_eq!(st_barrier.delegate_jobs, placement.num_delegated());
    assert!(
        st_overlap.lane_gaps <= st_barrier.lane_gaps,
        "overlap may only remove idle-lane gaps ({} > {})",
        st_overlap.lane_gaps,
        st_barrier.lane_gaps
    );
}

#[test]
fn prop_governed_placed_run_respects_budget_with_staging_in_flight() {
    // multi-lane, multi-stage: lane jobs from earlier layers are still
    // in flight while later layers lease — their staging must be in
    // every spanned layer's lease, and the ledger must stay within
    // budget (or record a degraded-serial grant)
    let g = micro::fallback_pipeline(3, 2, 3, 48, 3);
    let soc = SocProfile::pixel6();
    let p = partition(&g, &loose());
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let mems = branch_memories(&g, &p, &plan);
    let engine = Engine::new(&g, &p, &plan, None);
    let placement = delegate_all(&g, &p, &plan, &soc);
    assert!(placement.num_delegated() >= 3);
    let cfg = SchedCfg { max_threads: 3, margin: 0.4 };
    let s = sched::schedule(&plan, &mems, 1 << 34, &cfg);
    // every layer's lease must cover the staging of every lane job in
    // flight during it — own dispatches and carried-over ones
    let inflight = placed_inflight_staging(&plan, &placement, &s);
    for (li, ls) in s.iter().enumerate() {
        let own: u64 = ls
            .all()
            .filter(|&b| placement.is_delegated(b))
            .map(|b| placement.staging_bytes[b])
            .sum();
        assert!(
            inflight[li] >= own,
            "layer {li}: in-flight staging {} below its own dispatches {}",
            inflight[li],
            own
        );
        let d = placed_layer_demand(&mems, &placement, ls, inflight[li]);
        assert!(d >= inflight[li], "layer demand {d} below its in-flight staging");
    }
    // a trunk dispatched early is still in flight in later layers:
    // total in-flight bytes must exceed the per-layer own staging
    // somewhere (the cross-layer carry is real)
    let carried = inflight.iter().sum::<u64>()
        > s.iter()
            .map(|ls| {
                ls.all()
                    .filter(|&b| placement.is_delegated(b))
                    .map(|b| placement.staging_bytes[b])
                    .sum::<u64>()
            })
            .sum::<u64>();
    assert!(carried, "no lane job ever spanned a layer boundary");
    prop::check("placed leases within budget", 20, |rng| {
        let budget = rng.range_u64(1, 1 << 22);
        let gov = MemoryGovernor::new(budget);
        let (v, _) = engine.run_placed(&s, &placement, Some(&gov)).unwrap();
        assert!(v.all_finite());
        assert_eq!(gov.in_use(), 0, "leases leaked");
        let st = gov.stats();
        assert!(
            st.peak_reserved <= budget || st.over_budget_grants > 0,
            "budget {budget} exceeded without a degraded-serial grant \
             (peak {})",
            st.peak_reserved
        );
    });
}

#[test]
fn segmented_engine_with_placement_matches_classic_segmented() {
    // static trunk delegated, dynamic NMS tail resolved on CPU: the
    // placed segmented run must reproduce the classic one bit for bit.
    let g = fallback_heavy_dynamic(4, 3, 32, 3);
    let soc = SocProfile::pixel6();
    let p = partition(&g, &loose());
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);
    let cfg = SchedCfg::default();
    let placement = delegate_all(&g, &p, &plan, &soc);
    assert!(placement.num_delegated() >= 1, "static trunk must be delegate-safe");
    // the NMS barrier's branch stays on the CPU
    let se_classic = SegmentedEngine::new(&engine, cfg, 1 << 31);
    let (v1, s1) = se_classic.run(&[], None).unwrap();
    let se_placed = SegmentedEngine::with_placement(&engine, cfg, 1 << 31, placement.clone());
    let (v2, s2) = se_placed.run(&[], None).unwrap();
    assert_eq!(v1.checksum(), v2.checksum(), "placement changed segmented results");
    assert_eq!(s1.bindings, s2.bindings, "placement changed barrier resolution");
    assert!(s2.exec.delegate_jobs >= 1, "delegate lane unused in segmented run");
    // every branch of a barrier segment is CPU-placed
    for seg in &se_placed.seg_plan().segments {
        if seg.barrier.is_some() {
            for &b in &seg.branches {
                assert!(!placement.is_delegated(b), "barrier branch {b} delegated");
            }
        }
    }
}

#[test]
fn prop_placed_demand_never_loses_bytes() {
    // Delegating a branch may move its bytes from the CPU-peak term
    // (M_i) to the in-flight staging term, but never lose them from
    // the lease: removing the delegated branches lowers the CPU peak
    // by at most their summed M_i, so  d_all + Σ M_i(delegated) ≥
    // d_none + Σ staging(delegated)  must hold for every layer.
    prop::check("placed demand accounting", 50, |rng| {
        let g = micro::fallback_heavy(rng.range(2, 6), 3, 32, rng.range(3, 6));
        let soc = SocProfile::pixel6();
        let p = partition(&g, &loose());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let mems = branch_memories(&g, &p, &plan);
        let cfg = SchedCfg { max_threads: rng.range(1, 5), margin: 0.4 };
        let s = sched::schedule(&plan, &mems, rng.range_u64(1, 1 << 30), &cfg);
        let none = PlacementPlan::cpu_only(plan.branches.len());
        let all = delegate_all(&g, &p, &plan, &soc);
        let inflight_none = placed_inflight_staging(&plan, &none, &s);
        let inflight_all = placed_inflight_staging(&plan, &all, &s);
        for (li, ls) in s.iter().enumerate() {
            let d_none = placed_layer_demand(&mems, &none, ls, inflight_none[li]);
            let d_all = placed_layer_demand(&mems, &all, ls, inflight_all[li]);
            let staging_all: u64 =
                ls.all().filter(|&b| all.is_delegated(b)).map(|b| all.staging_bytes[b]).sum();
            let del_mi: u64 = ls
                .all()
                .filter(|&b| all.is_delegated(b))
                .map(|b| mems[b].total() as u64)
                .sum();
            assert!(d_all >= staging_all, "staging dropped from the lease");
            assert!(
                d_all + del_mi >= d_none + staging_all,
                "delegation lost bytes: d_all {d_all} + M_i {del_mi} < \
                 d_none {d_none} + staging {staging_all}"
            );
        }
    });
}
