//! Heterogeneous placement & delegate co-execution tests.
//!
//! Pins the contracts of `place` + `exec::run_placed` +
//! `ctrl::SegmentedEngine::with_placement`:
//! * CPU-forced placement is bit-identical to the classic `Engine::run`
//! * delegated runs produce identical outputs with strictly fewer
//!   CPU-wave branch executions
//! * placement never assigns `OpClass::Dynamic` work to the delegate
//! * governed placed runs never exceed the budget with the delegated
//!   branches' host-visible staging buffers included in the lease

use parallax::branch::{self, DEFAULT_BETA};
use parallax::ctrl::SegmentedEngine;
use parallax::device::SocProfile;
use parallax::exec::Engine;
use parallax::graph::{DType, Dim, Graph, OpClass, OpKind};
use parallax::memory::branch_memories;
use parallax::models::micro;
use parallax::partition::{partition, CostModel};
use parallax::place::{self, PlacePolicy, Placement, PlacementPlan};
use parallax::sched::{self, placed_layer_demand, MemoryGovernor, SchedCfg};
use parallax::util::prop;

fn loose() -> CostModel {
    CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX }
}

/// A placement that force-delegates every delegate-safe branch,
/// whatever the latency model says — exercises the execution paths
/// even on graphs too small for the Auto policy to bother offloading.
fn delegate_all(
    g: &Graph,
    p: &parallax::partition::Partition,
    plan: &branch::BranchPlan,
    soc: &SocProfile,
) -> PlacementPlan {
    let mut pl = PlacementPlan::cpu_only(plan.branches.len());
    for b in 0..plan.branches.len() {
        if place::delegate_safe(g, p, plan, b) {
            pl.assignment[b] = Placement::Delegate;
            pl.staging_bytes[b] = place::staging_bytes(g, p, plan, b);
            pl.delegate_latency_s[b] = place::delegate_latency(g, p, plan, b, soc);
        }
    }
    pl
}

/// fallback_heavy with a dynamic NMS tail: static trunk + CPU chains
/// merge, then NonMaxSuppression gates a dynamic post-segment — the
/// shape where delegation and §3.4 segmentation must compose.
fn fallback_heavy_dynamic(chains: usize, chain_len: usize, dim: usize, trunk_len: usize) -> Graph {
    let mut g = micro::fallback_heavy(chains, chain_len, dim, trunk_len);
    let merged = g.tensors().iter().find(|t| t.label == "merged").map(|t| t.id).unwrap();
    let dets = g.add_tensor(
        vec![Dim::Dynamic { max: 64 }, Dim::Static(6)],
        DType::F32,
        "dets",
    );
    g.add_node("nms", OpKind::NonMaxSuppression, vec![merged], vec![dets]);
    let out = g.add_tensor(
        vec![Dim::Dynamic { max: 64 }, Dim::Static(6)],
        DType::F32,
        "out",
    );
    g.add_node("output", OpKind::Output, vec![dets], vec![out]);
    g
}

#[test]
fn cpu_forced_matches_classic_run_across_thread_counts() {
    let g = micro::fallback_heavy(4, 3, 32, 3);
    let p = partition(&g, &loose());
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let mems = branch_memories(&g, &p, &plan);
    let engine = Engine::new(&g, &p, &plan, None);
    let forced = PlacementPlan::cpu_only(plan.branches.len());
    let mut baseline = None;
    for threads in [1, 2, 6] {
        let cfg = SchedCfg { max_threads: threads, margin: 0.4 };
        let s = sched::schedule(&plan, &mems, 1 << 34, &cfg);
        let (v_classic, _) = engine.run(&s).unwrap();
        let (v_placed, st) = engine.run_placed(&s, &forced, None).unwrap();
        assert_eq!(v_classic.checksum(), v_placed.checksum(), "threads={threads}");
        assert_eq!(st.delegate_jobs, 0);
        let c = v_placed.checksum();
        if let Some(prev) = baseline {
            assert_eq!(prev, c, "threads={threads} changed results");
        }
        baseline = Some(c);
    }
}

#[test]
fn delegated_outputs_identical_with_fewer_cpu_wave_runs() {
    let g = micro::fallback_heavy(6, 4, 48, 3);
    let soc = SocProfile::pixel6();
    let p = partition(&g, &loose());
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let mems = branch_memories(&g, &p, &plan);
    let engine = Engine::new(&g, &p, &plan, None);
    let cfg = SchedCfg { max_threads: 2, margin: 0.4 };
    let s = sched::schedule(&plan, &mems, 1 << 34, &cfg);
    let delegated = delegate_all(&g, &p, &plan, &soc);
    assert!(delegated.num_delegated() >= 1);
    let forced = PlacementPlan::cpu_only(plan.branches.len());
    let (v_cpu, st_cpu) = engine.run_placed(&s, &forced, None).unwrap();
    let (v_del, st_del) = engine.run_placed(&s, &delegated, None).unwrap();
    assert_eq!(v_cpu.checksum(), v_del.checksum());
    assert!(v_del.all_finite());
    assert_eq!(st_del.delegate_jobs, delegated.num_delegated());
    assert!(st_del.cpu_branch_runs < st_cpu.cpu_branch_runs);
    assert_eq!(st_del.cpu_branch_runs + st_del.delegate_jobs, st_cpu.cpu_branch_runs);
}

#[test]
fn prop_placement_never_delegates_dynamic_work() {
    prop::check("no dynamic on delegate", 40, |rng| {
        let g = match rng.range(0, 4) {
            0 => micro::mixed(),
            1 => micro::gated(rng.range(2, 6)),
            2 => fallback_heavy_dynamic(rng.range(2, 5), 3, 32, 3),
            _ => {
                let (layers, width) = (rng.range(2, 8), rng.range(1, 5));
                micro::random_dag(rng, layers, width)
            }
        };
        let socs = [SocProfile::pixel6, SocProfile::p30_pro, SocProfile::redmi_k50];
        let soc = socs[rng.range(0, 3)]();
        let p = partition(&g, &loose());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let placed = place::assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
        for b in placed.delegated() {
            assert!(plan.branches[b].has_delegate, "branch {b} has no region");
            for id in plan.branch_nodes(&g, &p, b) {
                assert_ne!(
                    g.node(id).kind.class(),
                    OpClass::Dynamic,
                    "dynamic op {} delegated",
                    g.node(id).name
                );
                assert!(
                    !g.node_has_dynamic_shape(id),
                    "dynamic shape {} delegated",
                    g.node(id).name
                );
            }
        }
    });
}

#[test]
fn prop_zoo_placement_keeps_dynamic_on_cpu() {
    // the real zoo under the paper's cost model: whatever the device,
    // no dynamic operator may reach the delegate
    for kind in [
        parallax::models::ModelKind::WhisperTiny,
        parallax::models::ModelKind::Yolov8n,
    ] {
        let g = kind.build();
        let p = partition(&g, &CostModel::default());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        for make in SocProfile::ALL {
            let placed = place::assign(&g, &p, &plan, &make(), PlacePolicy::Auto);
            for b in placed.delegated() {
                for id in plan.branch_nodes(&g, &p, b) {
                    assert_ne!(g.node(id).kind.class(), OpClass::Dynamic);
                }
            }
        }
    }
}

#[test]
fn prop_governed_placed_run_respects_budget_with_staging() {
    let g = micro::fallback_heavy(4, 3, 32, 3);
    let soc = SocProfile::pixel6();
    let p = partition(&g, &loose());
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let mems = branch_memories(&g, &p, &plan);
    let engine = Engine::new(&g, &p, &plan, None);
    let placement = delegate_all(&g, &p, &plan, &soc);
    assert!(placement.num_delegated() >= 1);
    let cfg = SchedCfg { max_threads: 3, margin: 0.4 };
    let s = sched::schedule(&plan, &mems, 1 << 34, &cfg);
    // staging must be part of every co-executing layer's lease
    for ls in &s {
        let d = placed_layer_demand(&mems, &placement, ls);
        let staging: u64 = ls
            .all()
            .filter(|&b| placement.is_delegated(b))
            .map(|b| placement.staging_bytes[b])
            .sum();
        assert!(d >= staging, "layer demand {d} below its staging {staging}");
    }
    prop::check("placed leases within budget", 20, |rng| {
        let budget = rng.range_u64(1, 1 << 22);
        let gov = MemoryGovernor::new(budget);
        let (v, _) = engine.run_placed(&s, &placement, Some(&gov)).unwrap();
        assert!(v.all_finite());
        assert_eq!(gov.in_use(), 0, "leases leaked");
        let st = gov.stats();
        assert!(
            st.peak_reserved <= budget || st.over_budget_grants > 0,
            "budget {budget} exceeded without a degraded-serial grant \
             (peak {})",
            st.peak_reserved
        );
    });
}

#[test]
fn segmented_engine_with_placement_matches_classic_segmented() {
    // static trunk delegated, dynamic NMS tail resolved on CPU: the
    // placed segmented run must reproduce the classic one bit for bit.
    let g = fallback_heavy_dynamic(4, 3, 32, 3);
    let soc = SocProfile::pixel6();
    let p = partition(&g, &loose());
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);
    let cfg = SchedCfg::default();
    let placement = delegate_all(&g, &p, &plan, &soc);
    assert!(placement.num_delegated() >= 1, "static trunk must be delegate-safe");
    // the NMS barrier's branch stays on the CPU
    let se_classic = SegmentedEngine::new(&engine, cfg, 1 << 31);
    let (v1, s1) = se_classic.run(&[], None).unwrap();
    let se_placed = SegmentedEngine::with_placement(&engine, cfg, 1 << 31, placement.clone());
    let (v2, s2) = se_placed.run(&[], None).unwrap();
    assert_eq!(v1.checksum(), v2.checksum(), "placement changed segmented results");
    assert_eq!(s1.bindings, s2.bindings, "placement changed barrier resolution");
    assert!(s2.exec.delegate_jobs >= 1, "delegate lane unused in segmented run");
    // every branch of a barrier segment is CPU-placed
    for seg in &se_placed.seg_plan().segments {
        if seg.barrier.is_some() {
            for &b in &seg.branches {
                assert!(!placement.is_delegated(b), "barrier branch {b} delegated");
            }
        }
    }
}

#[test]
fn prop_placed_demand_never_loses_bytes() {
    // Delegating a branch may move its bytes from the CPU-peak term
    // (M_i) to the staging term, but never lose them from the lease:
    // removing the delegated branches lowers the CPU peak by at most
    // their summed M_i, so  d_all + Σ M_i(delegated) ≥ d_none +
    // Σ staging(delegated)  must hold for every layer.
    prop::check("placed demand accounting", 50, |rng| {
        let g = micro::fallback_heavy(rng.range(2, 6), 3, 32, rng.range(3, 6));
        let soc = SocProfile::pixel6();
        let p = partition(&g, &loose());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let mems = branch_memories(&g, &p, &plan);
        let cfg = SchedCfg { max_threads: rng.range(1, 5), margin: 0.4 };
        let s = sched::schedule(&plan, &mems, rng.range_u64(1, 1 << 30), &cfg);
        let none = PlacementPlan::cpu_only(plan.branches.len());
        let all = delegate_all(&g, &p, &plan, &soc);
        for ls in &s {
            let d_none = placed_layer_demand(&mems, &none, ls);
            let d_all = placed_layer_demand(&mems, &all, ls);
            let staging_all: u64 =
                ls.all().filter(|&b| all.is_delegated(b)).map(|b| all.staging_bytes[b]).sum();
            let del_mi: u64 = ls
                .all()
                .filter(|&b| all.is_delegated(b))
                .map(|b| mems[b].total() as u64)
                .sum();
            assert!(d_all >= staging_all, "staging dropped from the lease");
            assert!(
                d_all + del_mi >= d_none + staging_all,
                "delegation lost bytes: d_all {d_all} + M_i {del_mi} < \
                 d_none {d_none} + staging {staging_all}"
            );
        }
    });
}
