//! Integration tests for plan capture & replay (the preallocated hot
//! path): a replayed [`CapturedPlan`] must be bit-identical to a
//! freshly planned run across thread counts, pow2 shape buckets, and
//! placements, and governed replays must lease exactly the captured
//! demand figures.
//!
//! The parity bar is deliberately `==` on checksums and stats, not
//! "close": replay and the interpreting engine share one kernel
//! dispatch (`exec::eval_host_node`), one source-synthesis formula,
//! and one demand computation, so any drift is a bug, not noise.

use parallax::branch::{self, DEFAULT_BETA};
use parallax::ctrl::{SegmentedEngine, ShapeEnv};
use parallax::exec::{Engine, Values, WeightBank};
use parallax::graph::{DType, Dim, Graph, OpKind};
use parallax::memory::branch_memories;
use parallax::models::micro;
use parallax::partition::{partition, CostModel, Partition};
use parallax::sched::{self, MemoryGovernor, SchedCfg};
use parallax::util::prop;

fn cpu_only(g: &Graph) -> Partition {
    partition(
        g,
        &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
    )
}

fn schedules_for(
    g: &Graph,
    p: &Partition,
    plan: &branch::BranchPlan,
    threads: usize,
) -> Vec<parallax::sched::LayerSchedule> {
    let mems = branch_memories(g, p, plan);
    let cfg = SchedCfg { max_threads: threads, margin: 0.4 };
    sched::schedule(plan, &mems, 1 << 34, &cfg)
}

#[test]
fn replay_bit_identical_across_thread_counts_and_models() {
    let models: Vec<(&str, Graph)> = vec![
        ("chain64", micro::chain(64)),
        ("parallel6x8", micro::parallel_chains(6, 8)),
        ("mixed", micro::mixed()),
        ("diamond", micro::diamond(4, 4)),
    ];
    for (name, g) in &models {
        // both partition flavours: all-CPU units and fused regions
        let parts = [
            cpu_only(g),
            partition(g, &CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX }),
        ];
        for p in &parts {
            let plan = branch::plan(g, p, DEFAULT_BETA);
            let engine = Engine::new(g, p, &plan, None);
            for threads in [1, 2, 6] {
                let s = schedules_for(g, p, &plan, threads);
                let captured = engine.capture(&s, &ShapeEnv::unresolved(), None);
                let (v_fresh, st_fresh) = engine.run(&s).unwrap();
                let (v_replay, st_replay) = engine.run_replayed(&captured, None).unwrap();
                assert_eq!(
                    v_fresh.checksum(),
                    v_replay.checksum(),
                    "{name}@{threads}t: replay must be bit-identical"
                );
                assert_eq!(st_fresh.host_ops, st_replay.host_ops, "{name}@{threads}t");
                assert_eq!(
                    st_fresh.cpu_branch_runs, st_replay.cpu_branch_runs,
                    "{name}@{threads}t"
                );
                assert_eq!(
                    st_fresh.skipped_fused, st_replay.skipped_fused,
                    "{name}@{threads}t"
                );
                assert_eq!(
                    st_fresh.peak_arena_bytes, st_replay.peak_arena_bytes,
                    "{name}@{threads}t: captured arena peak must match the \
                     interpreting path's per-run bookkeeping"
                );
            }
        }
    }
}

#[test]
fn prop_replay_matches_fresh_on_random_dags() {
    prop::check("capture/replay parity", 40, |rng| {
        let layers = rng.range(2, 10);
        let width = rng.range(1, 6);
        let g = micro::random_dag(rng, layers, width);
        let p = cpu_only(&g);
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let engine = Engine::new(&g, &p, &plan, None);
        for threads in [1, 4] {
            let s = schedules_for(&g, &p, &plan, threads);
            let captured = engine.capture(&s, &ShapeEnv::unresolved(), None);
            let (v_fresh, _) = engine.run(&s).unwrap();
            let (v_replay, _) = engine.run_replayed(&captured, None).unwrap();
            assert_eq!(v_fresh.checksum(), v_replay.checksum());
            // static CPU-only DAG: also replayable with no engine at all
            assert!(captured.is_standalone());
            let values = Values::default();
            captured.replay(&values, &WeightBank::default()).unwrap();
            assert_eq!(v_fresh.checksum(), values.checksum());
        }
    });
}

#[test]
fn governed_replay_leases_exactly_captured_demands() {
    let g = micro::parallel_chains(4, 6);
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);
    let s = schedules_for(&g, &p, &plan, 4);
    let captured = engine.capture(&s, &ShapeEnv::unresolved(), None);

    let gov_fresh = MemoryGovernor::new(1 << 30);
    let gov_replay = MemoryGovernor::new(1 << 30);
    let (v_fresh, _) = engine.run_governed(&s, Some(&gov_fresh)).unwrap();
    let (v_replay, _) = engine.run_replayed(&captured, Some(&gov_replay)).unwrap();

    assert_eq!(v_fresh.checksum(), v_replay.checksum());
    assert_eq!(
        gov_fresh.peak_reserved(),
        gov_replay.peak_reserved(),
        "governed replay must lease exactly the figures the fresh path computes"
    );
    assert_eq!(
        gov_fresh.stats().grants,
        gov_replay.stats().grants,
        "replay takes the same number of leases (one per non-empty wave/spill)"
    );
    assert_eq!(
        gov_replay.peak_reserved(),
        captured.peak_demand(),
        "the run's peak lease is the captured plan's own quoted demand"
    );
    assert_eq!(gov_fresh.in_use(), 0);
    assert_eq!(gov_replay.in_use(), 0);
}

#[test]
fn placed_replay_bit_identical_with_equal_leases() {
    // heavy enough that the Pixel 6 placement model offloads the trunk
    let g = micro::fallback_heavy(4, 3, 128, 6);
    let soc = parallax::device::SocProfile::pixel6();
    let p = partition(&g, &CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX });
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let mut engine = Engine::new(&g, &p, &plan, None);
    let s = schedules_for(&g, &p, &plan, 4);
    engine.set_energy_model(parallax::sim::energy_model_for(
        &g,
        &p,
        &plan,
        &s,
        &parallax::baselines::parallax(),
        &soc,
        &SchedCfg { max_threads: 4, margin: 0.4 },
        1.0,
    ));

    let auto = parallax::place::assign(&g, &p, &plan, &soc, parallax::place::PlacePolicy::Auto);
    assert!(auto.num_delegated() >= 1, "trunk should delegate on pixel6");
    let captured = engine.capture(&s, &ShapeEnv::unresolved(), Some(&auto));
    assert!(captured.is_placed());
    assert!(!captured.is_standalone(), "placed captures need their engine");

    let gov_fresh = MemoryGovernor::new(1 << 30);
    let gov_replay = MemoryGovernor::new(1 << 30);
    let (v_fresh, st_fresh) = engine.run_placed(&s, &auto, Some(&gov_fresh)).unwrap();
    let v_replay = Values::default();
    let st_replay = engine
        .run_captured(&captured, &v_replay, Some(&gov_replay), &ShapeEnv::unresolved(), Some(&auto))
        .unwrap();

    assert_eq!(
        v_fresh.checksum(),
        v_replay.checksum(),
        "placed replay must be bit-identical to the freshly planned placed run"
    );
    assert_eq!(st_fresh.delegate_jobs, st_replay.delegate_jobs);
    assert_eq!(st_fresh.cpu_branch_runs, st_replay.cpu_branch_runs);
    // the energy ledger is charged from modelled per-branch terms on
    // the dispatcher thread, so replay matches fresh bit for bit
    assert!(st_fresh.energy_j > 0.0);
    assert!(st_fresh.energy_lane_j > 0.0, "delegated run draws lane power");
    assert_eq!(st_fresh.energy_j.to_bits(), st_replay.energy_j.to_bits());
    assert_eq!(st_fresh.energy_idle_j.to_bits(), st_replay.energy_idle_j.to_bits());
    assert_eq!(st_fresh.energy_cpu_j.to_bits(), st_replay.energy_cpu_j.to_bits());
    assert_eq!(st_fresh.energy_lane_j.to_bits(), st_replay.energy_lane_j.to_bits());
    assert_eq!(st_fresh.cpu_modelled_s.to_bits(), st_replay.cpu_modelled_s.to_bits());
    // transfer fields follow the energy-field treatment: identical to
    // the bit (both zero here — no remote lane in this placement)
    assert_eq!(st_fresh.uplink_bytes, st_replay.uplink_bytes);
    assert_eq!(st_fresh.downlink_bytes, st_replay.downlink_bytes);
    assert_eq!(st_fresh.link_retries, st_replay.link_retries);
    assert_eq!(st_fresh.remote_busy_s.to_bits(), st_replay.remote_busy_s.to_bits());
    assert_eq!(
        gov_fresh.peak_reserved(),
        gov_replay.peak_reserved(),
        "placed replay must lease exactly the captured run-wide figure"
    );
    assert_eq!(gov_fresh.in_use(), 0);
    assert_eq!(gov_replay.in_use(), 0);

    // CPU-forced placement: captures as placed (demands stay
    // placement-aware) but with no lane topology, and still replays
    // bit-identically through the classic path
    let forced = parallax::place::PlacementPlan::cpu_only(plan.branches.len());
    let cap_forced = engine.capture(&s, &ShapeEnv::unresolved(), Some(&forced));
    assert!(cap_forced.is_placed());
    let (v_forced, _) = engine.run_placed(&s, &forced, None).unwrap();
    let v_forced_replay = Values::default();
    engine
        .run_captured(&cap_forced, &v_forced_replay, None, &ShapeEnv::unresolved(), Some(&forced))
        .unwrap();
    assert_eq!(v_forced.checksum(), v_forced_replay.checksum());
}

#[test]
fn remote_placed_replay_reproduces_transfer_stats_bitwise() {
    // A spill placement captured and replayed against the same seeded
    // link must reproduce every transfer-field stat to the bit: the
    // per-run transfer index counter follows lane dispatch order, which
    // the captured plan pins, so uplink/downlink bytes, retries, and
    // jittered remote busy seconds are part of the replay-identity
    // contract — the same treatment the PR-7 energy fields got.
    use parallax::device::{LinkModel, RemoteLane, SocProfile};
    use parallax::place::{self, Placement, PlacementPlan};

    let g = micro::fallback_heavy(4, 3, 96, 5);
    let soc = SocProfile::pixel6().with_remote(&RemoteLane::edge_server());
    let rl = soc.remote_lane().unwrap();
    let p = partition(&g, &CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX });
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let mut spill = PlacementPlan::cpu_only(plan.branches.len());
    for b in 0..plan.branches.len() {
        if place::delegate_safe(&g, &p, &plan, b) {
            spill.assignment[b] = Placement::Delegate(rl);
            spill.staging_bytes[b] = place::transfer_bytes(&g, &p, &plan, b);
            spill.delegate_latency_s[b] =
                place::lane_delegate_latency(&g, &p, &plan, b, &soc, &soc.lanes[rl]);
        }
    }
    assert!(spill.num_delegated() >= 1, "trunks must spill for the test to bite");

    let mut engine = Engine::new(&g, &p, &plan, None);
    // jitter plus a partition window over transfer index 0: the replay
    // must hit the same dropped indices, pay the same wasted-attempt
    // uplink bytes, and accumulate the same jittered busy seconds
    let link = LinkModel {
        seed: 17,
        jitter_frac: 0.2,
        drop_p: 0.0,
        partition_every: 3,
        partition_len: 1,
    };
    engine.set_remote(soc.lanes.iter().map(|l| l.remote).collect(), link);
    let s = schedules_for(&g, &p, &plan, 4);
    let captured = engine.capture(&s, &ShapeEnv::unresolved(), Some(&spill));
    assert!(captured.is_placed());

    let (v_fresh, st_fresh) = engine.run_placed(&s, &spill, None).unwrap();
    let v_replay = Values::default();
    let st_replay = engine
        .run_captured(&captured, &v_replay, None, &ShapeEnv::unresolved(), Some(&spill))
        .unwrap();

    assert_eq!(
        v_fresh.checksum().to_bits(),
        v_replay.checksum().to_bits(),
        "remote replay must be bit-identical to the fresh spilled run"
    );
    // the partition window always covers transfer index 0, so at least
    // one retry happened — the identity below covers the retry path,
    // not just the happy path
    assert!(st_fresh.link_retries >= 1, "index-0 drop must force a retry");
    assert!(st_fresh.uplink_bytes > 0, "spilled capture crosses the link");
    assert!(st_fresh.remote_busy_s > 0.0);
    assert_eq!(st_fresh.delegate_jobs, st_replay.delegate_jobs);
    assert_eq!(st_fresh.cpu_branch_runs, st_replay.cpu_branch_runs);
    assert_eq!(st_fresh.link_retries, st_replay.link_retries);
    assert_eq!(st_fresh.uplink_bytes, st_replay.uplink_bytes);
    assert_eq!(st_fresh.downlink_bytes, st_replay.downlink_bytes);
    assert_eq!(st_fresh.remote_busy_s.to_bits(), st_replay.remote_busy_s.to_bits());
}

const DYN_T: usize = 16;

/// Dynamic-seq chain: every activation's leading dim is `Dim::Dynamic`,
/// so the §3.4 segment cache plans (and captures) per pow2 bucket and
/// replays each step at its exact extent.
fn dyn_chain() -> Graph {
    let d = 32;
    let mut g = Graph::new("dyn_chain");
    let t_dyn = Dim::Dynamic { max: DYN_T };
    let mut x = g.add_tensor(vec![t_dyn, Dim::Static(d)], DType::F32, "x0");
    for i in 0..3 {
        let w = g.tensor(&[d, d], &format!("w{i}"));
        let y = g.add_tensor(vec![t_dyn, Dim::Static(d)], DType::F32, &format!("y{i}"));
        g.add_node(format!("mm{i}"), OpKind::MatMul, vec![x, w], vec![y]);
        let z = g.add_tensor(vec![t_dyn, Dim::Static(d)], DType::F32, &format!("z{i}"));
        g.add_node(format!("act{i}"), OpKind::Gelu, vec![y], vec![z]);
        x = z;
    }
    let sliced = g.tensor(&[1, d], "sliced");
    g.add_node("slice", OpKind::Slice, vec![x], vec![sliced]);
    let out = g.tensor(&[1, d], "out");
    g.add_node("output", OpKind::Output, vec![sliced], vec![out]);
    assert!(g.validate().is_empty(), "{:?}", g.validate());
    g
}

#[test]
fn bucketed_segment_replay_matches_cold_plans_across_pow2_buckets() {
    let g = dyn_chain();
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);

    // warm engine reuses bucket-cached captured plans across steps (and
    // runs wider), cold engines re-capture per step on one thread — the
    // stores must still match bit for bit at every extent
    let warm = SegmentedEngine::new(&engine, SchedCfg { max_threads: 4, margin: 0.4 }, 1 << 34);
    for t in [2usize, 3, 8, 9, 13, DYN_T] {
        let (v_warm, _) = warm.run(&[(DYN_T, t)], None).unwrap();
        let cold_engine = Engine::new(&g, &p, &plan, None);
        let cold =
            SegmentedEngine::new(&cold_engine, SchedCfg { max_threads: 1, margin: 0.4 }, 1 << 34);
        let (v_cold, _) = cold.run(&[(DYN_T, t)], None).unwrap();
        assert_eq!(
            v_warm.checksum(),
            v_cold.checksum(),
            "t={t}: bucket-cached captured plan must replay exactly like a cold plan"
        );
        assert!(v_warm.all_finite());
    }
    let (hits, misses) = warm.cache_stats();
    assert!(hits >= 1, "pow2 buckets must be re-used across extents ({hits} hits)");
    assert!(misses >= 1);
}

#[test]
fn standalone_replay_matches_engine_stats_exactly() {
    let g = micro::mixed();
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let mut engine = Engine::new(&g, &p, &plan, None);
    let s = schedules_for(&g, &p, &plan, 4);
    engine.set_energy_model(parallax::sim::energy_model_for(
        &g,
        &p,
        &plan,
        &s,
        &parallax::baselines::parallax(),
        &parallax::device::SocProfile::pixel6(),
        &SchedCfg { max_threads: 4, margin: 0.4 },
        1.0,
    ));
    let captured = engine.capture(&s, &ShapeEnv::unresolved(), None);
    assert!(captured.is_standalone());
    assert!(captured.num_programs() > 0);
    assert!(captured.peak_demand() > 0);

    let (v_fresh, st_fresh) = engine.run(&s).unwrap();
    let values = Values::default();
    let st = captured.replay(&values, &WeightBank::default()).unwrap();
    assert_eq!(v_fresh.checksum(), values.checksum());
    assert_eq!(st_fresh.host_ops, st.host_ops);
    assert_eq!(st_fresh.cpu_branch_runs, st.cpu_branch_runs);
    assert_eq!(st_fresh.skipped_fused, st.skipped_fused);
    assert_eq!(st_fresh.peak_arena_bytes, st.peak_arena_bytes);
    // the capture carries the engine's energy model, so even the
    // engine-free standalone replay reproduces the ledger bit for bit
    assert!(st_fresh.energy_j > 0.0);
    assert_eq!(st_fresh.energy_j.to_bits(), st.energy_j.to_bits());
    assert_eq!(st_fresh.energy_idle_j.to_bits(), st.energy_idle_j.to_bits());
    assert_eq!(st_fresh.energy_cpu_j.to_bits(), st.energy_cpu_j.to_bits());
    assert_eq!(st_fresh.energy_lane_j.to_bits(), st.energy_lane_j.to_bits());
    assert_eq!(st_fresh.cpu_modelled_s.to_bits(), st.cpu_modelled_s.to_bits());
}
