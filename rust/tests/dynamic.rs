//! Integration tests for runtime subgraph control (§3.4): segmented
//! execution with runtime-resolved shapes, plan caching, dead-arm
//! pruning, and resolved-shape governor leases.
//!
//! The autoregressive loop tests run on a whisper-shaped mini decoder
//! (While + EmbeddingLookup + dynamic transformer blocks) so `cargo
//! test` stays fast; Whisper-Tiny itself is exercised on its decode
//! range (the encoder prefix is synthesized, as the engine does for any
//! absent value).  The full Whisper-Tiny decode loop lives in
//! `examples/whisper_decode.rs` and `benches/dynamic_subgraph.rs`.

use parallax::branch::{self, DEFAULT_BETA};
use parallax::ctrl::SegmentedEngine;
use parallax::exec::{Engine, Values};
use parallax::graph::{DType, Dim, Graph, OpKind};
use parallax::models::blocks::{attention_block, ffn_block, TransformerCfg};
use parallax::models::{micro, whisper_tiny, ModelKind};
use parallax::partition::{partition, CostModel, Partition};
use parallax::sched::{MemoryGovernor, SchedCfg};

fn cpu_only(g: &Graph) -> Partition {
    partition(
        g,
        &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
    )
}

const MINI_T: usize = 16;

/// Whisper-shaped mini decoder: While barrier feeding an embedding
/// lookup and two dynamic transformer blocks, with a logits head.
fn mini_decoder() -> Graph {
    let d = 32;
    let mut g = Graph::new("mini_decoder");
    let t_dyn = Dim::Dynamic { max: MINI_T };
    let state = g.add_tensor(vec![t_dyn], DType::I32, "state");
    let tokens = g.add_tensor(vec![t_dyn], DType::I32, "tokens");
    g.add_node("loop", OpKind::While, vec![state], vec![tokens]);
    let table = g.tensor(&[100, d], "embed.table");
    let emb = g.add_tensor(vec![t_dyn, Dim::Static(d)], DType::F32, "embedded");
    g.add_node("embed", OpKind::EmbeddingLookup, vec![tokens, table], vec![emb]);
    let cfg = TransformerCfg {
        t: MINI_T,
        d,
        heads: 4,
        ffn_mult: 2,
        seq_dynamic: true,
        per_head: false,
    };
    let mut x = emb;
    for i in 0..2 {
        x = attention_block(&mut g, x, cfg, &format!("blk{i}"), None);
        x = ffn_block(&mut g, x, cfg, &format!("blk{i}"), None);
    }
    let last = g.tensor(&[1, d], "last");
    g.add_node("last_slice", OpKind::Slice, vec![x], vec![last]);
    let unemb = g.tensor(&[d, 100], "unembed.w");
    let logits = g.tensor(&[1, 100], "logits");
    g.add_node("unembed", OpKind::MatMul, vec![last, unemb], vec![logits]);
    let out = g.tensor(&[1, 100], "out");
    g.add_node("output", OpKind::Output, vec![logits], vec![out]);
    assert!(g.validate().is_empty(), "{:?}", g.validate());
    g
}

#[test]
fn decode_loop_bit_identical_across_thread_counts_and_schedules() {
    let g = mini_decoder();
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);

    // serial, parallel, and budget-starved (all-sequential spill)
    let configs = [
        (SchedCfg { max_threads: 1, margin: 0.4 }, 1u64 << 34),
        (SchedCfg { max_threads: 6, margin: 0.4 }, 1u64 << 34),
        (SchedCfg { max_threads: 6, margin: 0.4 }, 0u64),
    ];
    let mut all_checksums: Vec<Vec<f64>> = Vec::new();
    for (cfg, budget) in configs {
        let se = SegmentedEngine::new(&engine, cfg, budget);
        let mut checksums = Vec::new();
        for t in 1..=MINI_T {
            let (values, stats) = se.run(&[(MINI_T, t)], None).unwrap();
            assert!(stats.segments_run > 0);
            assert!(values.all_finite());
            assert_eq!(
                stats.bindings.iter().find(|&&(s, _)| s == MINI_T),
                Some(&(MINI_T, t)),
                "caller binding must drive the decode length"
            );
            checksums.push(values.checksum());
        }
        all_checksums.push(checksums);
    }
    assert_eq!(
        all_checksums[0], all_checksums[1],
        "thread count must not change decode results"
    );
    assert_eq!(
        all_checksums[0], all_checksums[2],
        "serial spill must not change decode results"
    );
}

#[test]
fn plan_cache_shares_power_of_two_buckets() {
    let g = mini_decoder();
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);
    let se = SegmentedEngine::new(&engine, SchedCfg::default(), 1 << 34);

    let (_, first) = se.run(&[(MINI_T, 9)], None).unwrap();
    assert!(first.cache_misses > 0, "cold run must plan");
    // 13 shares the 16-bucket with 9: pure cache hits, same plans
    let (_, second) = se.run(&[(MINI_T, 13)], None).unwrap();
    assert_eq!(second.cache_misses, 0, "bucketed decode step must reuse plans");
    assert!(second.cache_hits > 0);
    assert_eq!(second.resolved_demand, first.resolved_demand);
    // a different bucket re-plans
    let (_, third) = se.run(&[(MINI_T, 2)], None).unwrap();
    assert!(third.cache_misses > 0, "new bucket must plan again");
    let (hits, misses) = se.cache_stats();
    assert_eq!(hits, first.cache_hits + second.cache_hits + third.cache_hits);
    assert_eq!(misses, first.cache_misses + second.cache_misses + third.cache_misses);
}

#[test]
fn whisper_decode_range_resolved_leases_strictly_below_max() {
    let g = ModelKind::WhisperTiny.build();
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);
    let se = SegmentedEngine::new(&engine, SchedCfg::default(), 1 << 34);
    let bar = se.first_barrier_segment().expect("whisper has control flow");
    let n = se.num_segments();

    // decode range only (encoder values synthesized deterministically):
    // max-shape plan vs runtime-resolved 4-token step
    let gov_max = MemoryGovernor::new(u64::MAX);
    let gov_res = MemoryGovernor::new(u64::MAX);
    let values_max = Values::default();
    let st = se.run_range_static(bar..n, &values_max, Some(&gov_max)).unwrap();
    assert!(st.segments_run > 0);

    let values_res = Values::default();
    let r4 = se
        .run_range(bar..n, &values_res, &[(whisper_tiny::MAX_DEC_T, 4)], Some(&gov_res))
        .unwrap();
    assert_eq!(
        r4.bindings.iter().find(|&&(s, _)| s == whisper_tiny::MAX_DEC_T),
        Some(&(whisper_tiny::MAX_DEC_T, 4))
    );
    assert!(r4.resolved_demand > 0);
    assert!(
        r4.resolved_demand < r4.max_plan_demand,
        "resolved decode demand {} must be strictly below the max-shape plan {}",
        r4.resolved_demand,
        r4.max_plan_demand
    );
    assert!(
        gov_res.peak_reserved() < gov_max.peak_reserved(),
        "resolved decode leases {} must stay strictly below the max-shape peak {}",
        gov_res.peak_reserved(),
        gov_max.peak_reserved()
    );
    assert!(gov_max.peak_reserved() <= se.max_plan_peak_demand());
    assert_eq!(gov_res.in_use(), 0, "all decode leases returned");

    // the same resolved step is schedule-invariant (serial engine)
    let se1 = SegmentedEngine::new(&engine, SchedCfg { max_threads: 1, margin: 0.4 }, 1 << 34);
    let values_ser = Values::default();
    se1.run_range(bar..n, &values_ser, &[(whisper_tiny::MAX_DEC_T, 4)], None).unwrap();
    assert_eq!(
        values_res.checksum(),
        values_ser.checksum(),
        "decode step must be bit-identical across thread counts"
    );
    assert!(values_res.all_finite());
}

#[test]
fn gated_if_prunes_dead_arm_and_stays_deterministic() {
    let g = micro::gated(5);
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);

    let gov = MemoryGovernor::new(1 << 30);
    let se = SegmentedEngine::new(&engine, SchedCfg::default(), 1 << 30);
    let (values, stats) = se.run(&[], Some(&gov)).unwrap();
    assert!(values.all_finite());
    assert!(stats.pruned_branches >= 1, "untaken If arm must be pruned");
    assert!(stats.resolved_demand <= stats.max_plan_demand);
    assert_eq!(gov.in_use(), 0);

    // pruning decision is value-driven and deterministic: thread count
    // must not change the outcome or the results
    let se1 = SegmentedEngine::new(&engine, SchedCfg { max_threads: 1, margin: 0.4 }, 1 << 30);
    let (values1, stats1) = se1.run(&[], None).unwrap();
    assert_eq!(stats1.pruned_branches, stats.pruned_branches);
    assert_eq!(values.checksum(), values1.checksum());
}

#[test]
fn static_run_matches_classic_engine() {
    // run_static over all segments must equal the classic whole-graph
    // engine path: same branches, same max shapes, same values.
    let g = mini_decoder();
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);
    let se = SegmentedEngine::new(&engine, SchedCfg::default(), 1 << 34);
    let (seg_values, _) = se.run_static(None).unwrap();

    let mems = parallax::memory::branch_memories(&g, &p, &plan);
    let schedules =
        parallax::sched::schedule(&plan, &mems, 1 << 34, &SchedCfg::default());
    let (classic_values, _) = engine.run(&schedules).unwrap();
    assert_eq!(seg_values.checksum(), classic_values.checksum());
}
