//! Property-based tests over random DAGs (in-tree `prop` harness).
//!
//! Invariants from the paper:
//! * branches are a disjoint, complete cover of the unit graph (Alg. 1)
//! * layers respect dependencies and every branch appears once (Alg. 2)
//! * the arena never aliases two live tensors (Eq. 1)
//! * the scheduler never exceeds the memory budget and never drops or
//!   duplicates a branch (§3.3)
//! * the serving router never loses or duplicates a request

use parallax::branch::{self, DEFAULT_BETA};
use parallax::memory::{self, branch_memories, BumpArena};
use parallax::models::{micro, ModelKind};
use parallax::partition::{partition, CostModel};
use parallax::sched::{self, Lease, MemoryGovernor, SchedCfg};
use parallax::util::prop;
use parallax::util::rng::Rng;

fn random_graph(rng: &mut Rng) -> parallax::graph::Graph {
    let layers = rng.range(2, 12);
    let width = rng.range(1, 7);
    micro::random_dag(rng, layers, width)
}

#[test]
fn prop_branches_cover_units_exactly_once() {
    prop::check("branch cover", 200, |rng| {
        let g = random_graph(rng);
        let p = partition(&g, &CostModel::default());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let mut seen = vec![0u8; plan.unit_graph.len()];
        for b in &plan.branches {
            for &u in &b.units {
                seen[u] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "unit not covered exactly once");
    });
}

#[test]
fn prop_layers_respect_dependencies() {
    prop::check("layer order", 200, |rng| {
        let g = random_graph(rng);
        let p = partition(&g, &CostModel::default());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let mut layer_of = vec![usize::MAX; plan.branches.len()];
        for (li, layer) in plan.layers.iter().enumerate() {
            for &b in layer {
                assert_eq!(layer_of[b], usize::MAX, "branch in two layers");
                layer_of[b] = li;
            }
        }
        assert!(layer_of.iter().all(|&l| l != usize::MAX), "branch missing");
        for (u, succs) in plan.unit_graph.succs.iter().enumerate() {
            for &v in succs {
                let (bu, bv) = (plan.branch_of_unit[u], plan.branch_of_unit[v]);
                if bu != bv {
                    assert!(layer_of[bu] < layer_of[bv], "dependency violated");
                }
            }
        }
    });
}

#[test]
fn prop_arena_never_aliases_live_tensors() {
    prop::check("arena aliasing", 300, |rng| {
        let mut arena = BumpArena::new();
        let mut live: Vec<(usize, usize)> = Vec::new(); // (offset, size)
        for _ in 0..rng.range(5, 60) {
            if !live.is_empty() && rng.chance(0.4) {
                let i = rng.range(0, live.len());
                let (off, _) = live.swap_remove(i);
                arena.free(off);
            } else {
                let size = rng.range(1, 4096);
                let off = arena.alloc(size);
                // no overlap with any live allocation
                for &(o, s) in &live {
                    let sz = size.div_ceil(64) * 64;
                    assert!(
                        off + sz <= o || o + s <= off,
                        "alias: new ({off},{sz}) vs live ({o},{s})"
                    );
                }
                live.push((off, size.div_ceil(64) * 64));
            }
            assert!(arena.check(), "arena invariants broken");
        }
    });
}

#[test]
fn prop_greedy_plan_never_overlaps_lifetimes() {
    prop::check("greedy offsets", 200, |rng| {
        let n = rng.range(2, 40);
        let lts: Vec<memory::Lifetime> = (0..n)
            .map(|i| {
                let def = rng.range(0, 50);
                memory::Lifetime {
                    tensor: parallax::graph::TensorId(i as u32),
                    def_pos: def,
                    last_use: def + rng.range(0, 20),
                    escapes: false,
                    bytes: rng.range(1, 8192),
                }
            })
            .collect();
        let plan = memory::plan_greedy_global(&lts);
        for i in 0..n {
            for j in (i + 1)..n {
                let overlap_life = !(lts[i].last_use < lts[j].def_pos
                    || lts[j].last_use < lts[i].def_pos);
                if !overlap_life {
                    continue;
                }
                let (oi, si) = (plan.offsets[i], lts[i].bytes.div_ceil(64) * 64);
                let (oj, sj) = (plan.offsets[j], lts[j].bytes.div_ceil(64) * 64);
                assert!(
                    oi + si <= oj || oj + sj <= oi,
                    "live tensors {i},{j} overlap in arena"
                );
            }
        }
    });
}

#[test]
fn prop_scheduler_budget_and_exactly_once() {
    prop::check("scheduler", 150, |rng| {
        let g = random_graph(rng);
        let p = partition(&g, &CostModel::default());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let mems = branch_memories(&g, &p, &plan);
        let budget = rng.range_u64(0, 1 << 22);
        let cfg = SchedCfg { max_threads: rng.range(1, 9), margin: 0.4 };
        let scheds = sched::schedule(&plan, &mems, budget, &cfg);
        let mut seen = vec![false; plan.branches.len()];
        for (li, s) in scheds.iter().enumerate() {
            // delegate branches ride the accelerator lane of wave 0, so
            // they extend the CPU width cap rather than consuming it
            let delegates = plan.layers[li]
                .iter()
                .filter(|&&b| plan.branches[b].has_delegate)
                .count();
            for wave in &s.waves {
                assert!(wave.len() <= cfg.max_threads + delegates);
                let sum: u64 = wave
                    .iter()
                    .filter(|&&b| !plan.branches[b].has_delegate)
                    .map(|&b| mems[b].total() as u64)
                    .sum();
                assert!(sum <= budget, "layer {li}: wave over budget");
            }
            for b in s.all() {
                assert!(!seen[b], "branch {b} scheduled twice");
                seen[b] = true;
            }
        }
        assert!(seen.iter().all(|&x| x), "branch dropped");
    });
}

#[test]
fn prop_peak_estimator_matches_bruteforce() {
    prop::check("peak estimator", 200, |rng| {
        let n = rng.range(1, 30);
        let lts: Vec<memory::Lifetime> = (0..n)
            .map(|i| {
                let def = rng.range(0, 40);
                memory::Lifetime {
                    tensor: parallax::graph::TensorId(i as u32),
                    def_pos: def,
                    last_use: def + rng.range(0, 15),
                    escapes: false,
                    bytes: rng.range(1, 1000),
                }
            })
            .collect();
        // brute force: max over time steps
        let mut brute = 0usize;
        for t in 0..=60 {
            let live: usize = lts
                .iter()
                .filter(|l| l.def_pos <= t && t <= l.last_use)
                .map(|l| l.bytes)
                .sum();
            brute = brute.max(live);
        }
        assert_eq!(memory::peak_bytes(&lts), brute);
    });
}

#[test]
fn prop_spill_waves_union_sequential_is_permutation() {
    // §3.3 spill path: under a deliberately tight random budget the
    // parallel set shrinks and branches spill to the sequential tail —
    // waves ∪ sequential must still be a permutation of all branch ids,
    // and no wave may exceed max_threads (+ accelerator lane) or budget.
    prop::check("spill permutation", 200, |rng| {
        let g = random_graph(rng);
        let p = partition(&g, &CostModel::default());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let mems = branch_memories(&g, &p, &plan);
        let per_branch_max =
            mems.iter().map(memory::BranchMemory::total).max().unwrap_or(0) as u64;
        // from "nothing fits" to "everything fits", biased tight
        let budget = rng.range_u64(0, per_branch_max.saturating_mul(2) + 1);
        let cfg = SchedCfg { max_threads: rng.range(1, 5), margin: 0.4 };
        let scheds = sched::schedule(&plan, &mems, budget, &cfg);
        for (li, s) in scheds.iter().enumerate() {
            let delegates = plan.layers[li]
                .iter()
                .filter(|&&b| plan.branches[b].has_delegate)
                .count();
            for wave in &s.waves {
                assert!(wave.len() <= cfg.max_threads + delegates, "wave too wide");
                let sum: u64 = wave
                    .iter()
                    .filter(|&&b| !plan.branches[b].has_delegate)
                    .map(|&b| mems[b].total() as u64)
                    .sum();
                assert!(sum <= budget, "wave over budget");
            }
        }
        let mut ids: Vec<usize> = scheds.iter().flat_map(|s| s.all()).collect();
        ids.sort_unstable();
        let expect: Vec<usize> = (0..plan.branches.len()).collect();
        assert_eq!(ids, expect, "waves ∪ sequential is not a permutation");
    });
}

#[test]
fn prop_schedule_governed_matches_raw_budget() {
    // single- and multi-model paths share one planner: scheduling
    // against a governor must equal scheduling against its raw budget.
    prop::check("governed schedule parity", 100, |rng| {
        let g = random_graph(rng);
        let p = partition(&g, &CostModel::default());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let mems = branch_memories(&g, &p, &plan);
        let budget = rng.range_u64(0, 1 << 22);
        let cfg = SchedCfg { max_threads: rng.range(1, 9), margin: 0.4 };
        let gov = MemoryGovernor::new(budget);
        assert_eq!(
            sched::schedule_governed(&plan, &mems, &gov, &cfg),
            sched::schedule(&plan, &mems, budget, &cfg),
        );
    });
}

#[test]
fn prop_governor_ledger_never_overcommits() {
    // random acquire/release traffic: the ledger exceeds the budget
    // only in degraded serial mode (exactly one oversized lease).
    prop::check("governor ledger", 150, |rng| {
        let budget = rng.range_u64(1, 1 << 20);
        let gov = MemoryGovernor::new(budget);
        let mut held: Vec<Lease<'_>> = Vec::new();
        for _ in 0..rng.range(1, 50) {
            if !held.is_empty() && rng.chance(0.4) {
                let i = rng.range(0, held.len());
                held.swap_remove(i);
            } else {
                let want = rng.range_u64(0, budget.saturating_mul(2) + 1);
                if let Some(lease) = gov.try_acquire(want) {
                    held.push(lease);
                }
            }
            let st = gov.stats();
            // the ledger exceeds the budget only while exactly one
            // oversized lease runs in degraded serial mode (zero-byte
            // leases — delegate-only waves — may ride along)
            let nonzero = held.iter().filter(|l| l.bytes() > 0).count();
            assert!(
                st.in_use <= budget || (nonzero == 1 && st.over_budget_grants > 0),
                "overcommitted: in_use {} budget {budget} leases {}",
                st.in_use,
                st.active_leases
            );
            let held_sum: u64 = held.iter().map(Lease::bytes).sum();
            assert_eq!(st.in_use, held_sum, "ledger out of sync with live leases");
            assert_eq!(st.active_leases, held.len());
        }
        drop(held);
        assert_eq!(gov.in_use(), 0, "bytes leaked after all leases dropped");
    });
}

#[test]
fn prop_resolved_memories_never_exceed_max() {
    // §3.4 invariant: resolved-shape branch memories are bounded by the
    // max-shape plan for arbitrary fill ratios (the static offsets are
    // always a valid fallback), and short fills genuinely shrink the
    // dynamic branches.
    prop::check("resolved <= max", 25, |rng| {
        let kinds = [ModelKind::WhisperTiny, ModelKind::Yolov8n];
        let g = kinds[rng.range(0, kinds.len())].build();
        let p = partition(&g, &CostModel::default());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let max = branch_memories(&g, &p, &plan);
        let fill = 0.05 + 0.95 * rng.f64();
        let env = parallax::ctrl::ShapeEnv::from_fill(&g, fill);
        let rmems = parallax::ctrl::resolved_branch_memories(&g, &p, &plan, &env, &max);
        for (b, (r, m)) in rmems.iter().zip(&max).enumerate() {
            assert!(r.arena_bytes <= m.arena_bytes, "branch {b}: arena over max");
            assert!(
                r.boundary_out_bytes <= m.boundary_out_bytes,
                "branch {b}: boundary over max"
            );
        }
        if fill <= 0.5 {
            assert!(
                rmems.iter().zip(&max).any(|(r, m)| r.total() < m.total()),
                "no dynamic branch shrank at fill {fill}"
            );
        }
    });
}

#[test]
fn prop_router_never_loses_requests() {
    prop::check("router", 30, |rng| {
        let mut server = parallax::serve::Server::new();
        server.register(
            "m",
            Box::new(parallax::serve::FnExecutor(|seed| Ok((1e-6, seed as f64)))),
        );
        let n = rng.range(1, 40);
        let conc = rng.range(1, 10);
        let report = server.run_load(&["m"], n, conc, rng.next_u64()).unwrap();
        let mut ids: Vec<u64> = report.responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n);
    });
}
