//! Ablation benches for the design choices ARCHITECTURE.md calls out:
//! β balance threshold (§3.1), memory margin (§3.3), delegate
//! cost-model threshold (§3.1 / Appendix B).

fn main() {
    let t0 = std::time::Instant::now();
    for which in ["ablation-beta", "ablation-margin", "ablation-cost-model"] {
        println!("{}", parallax::eval::run(which).expect("known experiment"));
    }
    println!("[ablations] regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
