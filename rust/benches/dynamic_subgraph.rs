//! Bench target for §3.4 runtime subgraph control: max-shape vs
//! resolved-shape latency and peak reserved memory on dynamic models
//! (see EXPERIMENTS.md for the paper-vs-measured comparison and the
//! recorded §Perf numbers).
//!
//! `cargo bench --bench dynamic_subgraph` prints
//! 1. a planner-level table — the §3.3 peak demand of one schedule
//!    evaluated with worst-case vs resolved branch memories, and
//! 2. real-engine runs — a Whisper-Tiny autoregressive decode loop and
//!    the YOLOv8n post-NMS tail, with governor peaks and plan-cache
//!    hit rates.

use parallax::branch::{self, DEFAULT_BETA};
use parallax::ctrl::{self, SegmentedEngine, ShapeEnv};
use parallax::exec::{Engine, Values};
use parallax::memory::branch_memories;
use parallax::models::{whisper_tiny, ModelKind};
use parallax::partition::{partition, CostModel};
use parallax::sched::{self, MemoryGovernor, SchedCfg};
use parallax::sim;

const DECODE_STEPS: usize = 8;

fn cpu_only(g: &parallax::graph::Graph) -> parallax::partition::Partition {
    partition(
        g,
        &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
    )
}

fn main() {
    let t0 = std::time::Instant::now();
    println!("dynamic_subgraph: max-shape vs resolved-shape plans (§3.4)\n");

    // ---- planner level: one schedule, §3.3 peak demand at worst-case
    // vs resolved branch memories (same waves, so the comparison is
    // apples-to-apples)
    println!(
        "{:<14} {:>6} {:>14} {:>14} {:>7}",
        "model", "fill", "max peak KB", "resolved KB", "ratio"
    );
    for kind in [ModelKind::WhisperTiny, ModelKind::Yolov8n] {
        let g = kind.build();
        let p = partition(&g, &CostModel::default());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let max_mems = branch_memories(&g, &p, &plan);
        let cfg = SchedCfg::default();
        let scheds = sched::schedule(&plan, &max_mems, 1 << 31, &cfg);
        let max_peak = sim::schedule_peak_demand(&plan, &scheds, &max_mems);
        for fill in [0.125, 0.25, 0.5, 1.0] {
            let env = ShapeEnv::from_fill(&g, fill);
            let rmems = ctrl::resolved_branch_memories(&g, &p, &plan, &env, &max_mems);
            let rpeak = sim::schedule_peak_demand(&plan, &scheds, &rmems);
            println!(
                "{:<14} {:>6.3} {:>14.1} {:>14.1} {:>6.2}x",
                kind.slug(),
                fill,
                max_peak as f64 / 1e3,
                rpeak as f64 / 1e3,
                max_peak as f64 / rpeak.max(1) as f64
            );
        }
    }

    // ---- real engine: Whisper-Tiny autoregressive decode loop
    println!("\n== whisper-tiny decode loop (real engine, {DECODE_STEPS} steps) ==");
    let g = ModelKind::WhisperTiny.build();
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);
    let se = SegmentedEngine::new(&engine, SchedCfg::default(), 1 << 31);
    let bar = se.first_barrier_segment().expect("whisper has control flow");
    let n = se.num_segments();

    let values = Values::default();
    let tenc = std::time::Instant::now();
    se.run_range_static(0..bar, &values, None).expect("encoder prefix");
    println!("encoder prefix (static shapes): {:.0} ms", tenc.elapsed().as_secs_f64() * 1e3);

    let gov_res = MemoryGovernor::new(u64::MAX);
    let gov_max = MemoryGovernor::new(u64::MAX);
    let mut cold_ms = 0.0;
    let mut warm_ms = 0.0;
    let mut warm_steps = 0usize;
    let mut resolved_ms = 0.0;
    for t in 1..=DECODE_STEPS {
        let st = std::time::Instant::now();
        let stats = se
            .run_range(bar..n, &values, &[(whisper_tiny::MAX_DEC_T, t)], Some(&gov_res))
            .expect("decode step");
        let ms = st.elapsed().as_secs_f64() * 1e3;
        resolved_ms += ms;
        if stats.cache_misses > 0 {
            cold_ms += ms;
        } else {
            warm_ms += ms;
            warm_steps += 1;
        }
    }
    let mut max_ms = 0.0;
    for _ in 1..=DECODE_STEPS {
        let st = std::time::Instant::now();
        se.run_range_static(bar..n, &values, Some(&gov_max)).expect("static decode step");
        max_ms += st.elapsed().as_secs_f64() * 1e3;
    }
    let (hits, misses) = se.cache_stats();
    println!(
        "decode latency: resolved {:.0} ms vs max-shape {:.0} ms over {DECODE_STEPS} steps \
         (resolved cold {:.0} ms, warm mean {:.1} ms; plan cache {hits} hits / {misses} misses)",
        resolved_ms,
        max_ms,
        cold_ms,
        warm_ms / warm_steps.max(1) as f64
    );
    println!(
        "decode leases:  peak reserved {:.1} KB resolved vs {:.1} KB max-shape -> {}",
        gov_res.peak_reserved() as f64 / 1e3,
        gov_max.peak_reserved() as f64 / 1e3,
        if gov_res.peak_reserved() < gov_max.peak_reserved() {
            "resolved strictly below the max-shape plan"
        } else {
            "NOT below (regression!)"
        }
    );

    // ---- real engine: YOLOv8n post-NMS tail
    println!("\n== yolov8n post-NMS tail (real engine) ==");
    let g = ModelKind::Yolov8n.build();
    let p = cpu_only(&g);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let engine = Engine::new(&g, &p, &plan, None);
    let se = SegmentedEngine::new(&engine, SchedCfg::default(), 1 << 31);
    let (values, full) = se.run(&[], None).expect("full detector inference");
    for (sym, ext) in &full.bindings {
        println!("resolved NMS output: max {sym} -> {ext} boxes");
    }
    let bar = se.first_barrier_segment().expect("yolo has an NMS barrier");
    let tail = bar..se.num_segments();
    let res = se.run_range(tail.clone(), &values, &[], None).expect("resolved tail");
    let max = se.run_range_static(tail, &values, None).expect("static tail");
    println!(
        "post-NMS tail lease: {:.1} KB resolved vs {:.1} KB max-shape",
        res.resolved_demand as f64 / 1e3,
        max.resolved_demand as f64 / 1e3
    );

    println!("\n[dynamic_subgraph] completed in {:.2}s", t0.elapsed().as_secs_f64());
}
