//! Hot-path micro-benchmarks for the L3 coordinator (§Perf).
//!
//! `cargo bench --bench hotpath` times the operations on Parallax's
//! request path: graph analysis (partition + branch extraction), branch
//! memory estimation, layer scheduling, the arena allocator, and one
//! full simulated inference — the pieces the performance pass iterates
//! on (EXPERIMENTS.md §Perf records before/after).

use parallax::baselines::{Framework, Pipeline};
use parallax::branch::{self, DEFAULT_BETA};
use parallax::device::SocProfile;
use parallax::memory::{self, BumpArena};
use parallax::models::ModelKind;
use parallax::partition::{partition, CostModel};
use parallax::sched::{self, SchedCfg};
use parallax::sim::Mode;
use parallax::util::bench::{black_box, Bench};
use parallax::util::rng::Rng;

fn main() {
    let mut b = Bench::new("coordinator hot paths");

    // -- graph analysis (one-time per model load, still worth tracking)
    let g = ModelKind::WhisperTiny.build();
    b.iter("partition(whisper)", || {
        black_box(partition(&g, &CostModel::default()));
    });
    let p = partition(&g, &CostModel::default());
    b.iter("branch_plan(whisper)", || {
        black_box(branch::plan(&g, &p, DEFAULT_BETA));
    });
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    b.iter("branch_memories(whisper)", || {
        black_box(memory::branch_memories(&g, &p, &plan));
    });

    // -- per-inference path
    let mems = memory::branch_memories(&g, &p, &plan);
    let cfg = SchedCfg::default();
    b.iter("schedule(whisper)", || {
        black_box(sched::schedule(&plan, &mems, 1 << 31, &cfg));
    });

    let pipe = Pipeline::build(
        Framework::Parallax,
        ModelKind::WhisperTiny,
        &SocProfile::pixel6(),
        Mode::CpuOnly,
        cfg,
    )
    .unwrap();
    let mut rng = Rng::new(7);
    b.iter("simulate_one_inference(whisper)", || {
        black_box(pipe.run(&mut rng, 0.7));
    });

    // -- arena allocator inner loop
    b.iter("bump_arena_alloc_free_64", || {
        let mut a = BumpArena::new();
        let mut offs = Vec::with_capacity(64);
        for i in 0..64 {
            offs.push(a.alloc(256 + i * 32));
        }
        for o in offs {
            a.free(o);
        }
        black_box(a.footprint());
    });

    // -- model build (zoo generator throughput)
    b.iter("build_graph(clip)", || {
        black_box(ModelKind::ClipText.build());
    });

    b.report();

    // -- capture/replay vs the interpreting engine on real micro models:
    // classic rebuilds per-run arenas/maps and spawns per wave; replay
    // walks the captured step programs.  Same kernels, bit-identical
    // outputs — the delta is pure bookkeeping.
    let mut r = Bench::new("captured replay");
    let micro: Vec<(&str, parallax::graph::Graph)> = vec![
        ("chain64", parallax::models::micro::chain(64)),
        ("parallel6x8", parallax::models::micro::parallel_chains(6, 8)),
        ("mixed", parallax::models::micro::mixed()),
    ];
    let mut ratios = Vec::new();
    for (name, g) in &micro {
        let p = partition(
            g,
            &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
        );
        let plan = branch::plan(g, &p, DEFAULT_BETA);
        let mems = memory::branch_memories(g, &p, &plan);
        let scfg = SchedCfg { max_threads: 4, margin: 0.4 };
        let schedules = sched::schedule(&plan, &mems, 1 << 34, &scfg);
        let engine = parallax::exec::Engine::new(g, &p, &plan, None);
        let captured =
            engine.capture(&schedules, &parallax::ctrl::ShapeEnv::unresolved(), None);
        let (v_classic, _) = engine.run(&schedules).unwrap();
        let (v_replay, _) = engine.run_replayed(&captured, None).unwrap();
        assert_eq!(
            v_classic.checksum(),
            v_replay.checksum(),
            "{name}: replay must be bit-identical before it is fast"
        );
        r.iter(&format!("classic({name})"), || {
            black_box(engine.run(&schedules).unwrap());
        });
        r.iter(&format!("replay({name})"), || {
            black_box(engine.run_replayed(&captured, None).unwrap());
        });
        let cases = r.cases();
        let classic = cases[cases.len() - 2].mean_ns;
        let replay = cases[cases.len() - 1].mean_ns;
        ratios.push((*name, classic / replay));
    }
    r.report();
    println!();
    for (name, ratio) in &ratios {
        println!(
            "replay speedup {name}: {ratio:.2}x {}",
            if *ratio >= 2.0 { "(>= 2x target met)" } else { "(below 2x target)" }
        );
    }
}
