//! Bench target regenerating the paper's Table 7 (graph structure).
//!
//! `cargo bench --bench table7_graph_structure` prints the same rows the paper
//! reports (see EXPERIMENTS.md for the paper-vs-measured comparison)
//! plus the wall time of the regeneration itself.

fn main() {
    let t0 = std::time::Instant::now();
    let table = parallax::eval::run("table7").expect("known experiment");
    println!("{table}");
    println!("[table7_graph_structure] regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
