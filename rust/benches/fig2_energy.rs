//! Bench target regenerating the paper's Figure 2 (energy).
//!
//! `cargo bench --bench fig2_energy` prints the same rows the paper
//! reports (see EXPERIMENTS.md for the paper-vs-measured comparison)
//! plus the wall time of the regeneration itself.

fn main() {
    let t0 = std::time::Instant::now();
    let table = parallax::eval::run("fig2").expect("known experiment");
    println!("{table}");
    println!("[fig2_energy] regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
