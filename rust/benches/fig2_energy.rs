//! Bench target regenerating the paper's Figure 2 (energy).
//!
//! `cargo bench --bench fig2_energy` prints the same rows the paper
//! reports (see EXPERIMENTS.md for the paper-vs-measured comparison)
//! plus the wall time of the regeneration itself, and records two
//! trajectory groups (`BENCH_JSON`, tools/check_bench.py):
//!
//! * `fig2 energy modelled` — simulator closed form, mean over the
//!   30-input protocol, per model x framework;
//! * `fig2 energy measured` — the real executor's per-run energy
//!   ledger ([`parallax::eval::fig2_measured_mj`]), per model.
//!
//! The harness's nano-unit field carries nanojoules here (1 ns slot
//! ≡ 1 nJ), so the dimensionless regression ratios stay meaningful.

use parallax::baselines::{Framework, Pipeline};
use parallax::device::SocProfile;
use parallax::eval;
use parallax::models::ModelKind;
use parallax::sched::SchedCfg;
use parallax::sim::Mode;
use parallax::util::bench::Bench;

fn main() {
    let t0 = std::time::Instant::now();
    let table = eval::run("fig2").expect("known experiment");
    println!("{table}");

    let soc = SocProfile::pixel6();
    let mut modelled = Bench::new("fig2 energy modelled");
    for model in ModelKind::ALL {
        for fw in Framework::ALL {
            let Ok(p) = Pipeline::build(fw, model, &soc, Mode::CpuOnly, SchedCfg::default())
            else {
                continue;
            };
            let r = p.run_protocol(eval::RUNS, eval::SEED);
            let mj = r.iter().map(|x| x.energy_j).sum::<f64>() / r.len() as f64 * 1e3;
            modelled.record(
                &format!("{}/{}", model.display_name(), fw.profile().name),
                mj * 1e6, // mJ -> nJ
            );
        }
    }
    modelled.report();

    let mut measured = Bench::new("fig2 energy measured");
    for model in ModelKind::ALL {
        measured.record(model.display_name(), eval::fig2_measured_mj(model, &soc) * 1e6);
    }
    measured.report();

    println!("[fig2_energy] regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
