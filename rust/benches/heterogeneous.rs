//! Bench target for heterogeneous placement & multi-lane delegate
//! co-execution: CPU-only-forced vs co-executing wall-clock, 1-lane vs
//! 2-lane scaling, and the cross-layer overlap ablation on the real
//! engine (see EXPERIMENTS.md §Heterogeneous for the reproduce
//! protocol and the simulated-delegate deviation note).
//!
//! `cargo bench --bench heterogeneous` prints
//! 1. the placement-decision table (`parallax eval hetero` — pure
//!    modelling, per model × device, with the per-lane distribution),
//! 2. a real-engine run of the fallback-heavy profile: the matmul
//!    trunk offloaded to a delegate lane while the GELU fallback
//!    chains run in CPU waves, vs the same schedules with placement
//!    forced to CPU — same outputs, fewer CPU-wave branch executions,
//!    lower wall-clock,
//! 3. lane scaling: two independent trunks on pixel6's 2-lane profile
//!    (TPU + GPU) vs the same placement starved to one lane — 2-lane
//!    wall-clock must not exceed 1-lane,
//! 4. the overlap ablation on the staged pipeline: cross-layer
//!    first-consumer merges vs barrier-joins — same outputs, strictly
//!    fewer idle-lane gaps, and
//! 5. a governed line showing in-flight lane staging inside the lease.

use parallax::branch::{self, DEFAULT_BETA};
use parallax::device::SocProfile;
use parallax::exec::{Engine, ExecStats, Values};
use parallax::memory::branch_memories;
use parallax::models::micro;
use parallax::partition::{partition, CostModel};
use parallax::place::{self, PlacePolicy, PlacementPlan};
use parallax::sched::{self, MemoryGovernor, SchedCfg};

const CHAINS: usize = 8;
const CHAIN_LEN: usize = 48;
const DIM: usize = 448;
const TRUNK_LEN: usize = 4;
const REPS: usize = 3;

/// 1 warm-up + `reps` timed runs; returns (mean wall, checksum, stats).
fn time_placed(
    engine: &Engine,
    schedules: &[sched::LayerSchedule],
    placement: &PlacementPlan,
    overlap: bool,
    reps: usize,
) -> (f64, f64, ExecStats) {
    let (v, _) = engine
        .run_placed_opts(schedules, placement, None, overlap)
        .expect("warm-up");
    let checksum = v.checksum();
    let mut wall = 0.0;
    let mut last = ExecStats::default();
    for _ in 0..reps {
        let t = std::time::Instant::now();
        let (_, st) = engine
            .run_placed_opts(schedules, placement, None, overlap)
            .expect("run");
        wall += t.elapsed().as_secs_f64();
        last = st;
    }
    (wall / reps as f64, checksum, last)
}

fn main() {
    let t0 = std::time::Instant::now();
    println!("heterogeneous: placement & multi-lane delegate co-execution (real engine)\n");

    // ---- placement decisions across the zoo (modelled, no execution)
    println!("{}", parallax::eval::hetero());

    // ---- real engine: fallback-heavy profile, Pixel 6 placement
    let soc = SocProfile::pixel6();
    let g = micro::fallback_heavy(CHAINS, CHAIN_LEN, DIM, TRUNK_LEN);
    let cm = CostModel::from_profile(&soc);
    let p = partition(&g, &cm);
    assert!(!p.regions.is_empty(), "trunk must survive the device cost model");
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let mems = branch_memories(&g, &p, &plan);
    let engine = Engine::new(&g, &p, &plan, None);
    // narrow CPU budget (2 threads) so the chains span several waves —
    // the window the delegate lane hides the trunk behind
    let cfg = SchedCfg { max_threads: 2, margin: 0.4 };
    let schedules = sched::schedule(&plan, &mems, 1 << 31, &cfg);

    let auto = place::assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
    let forced = PlacementPlan::cpu_only(plan.branches.len());
    println!(
        "== fallback-heavy({CHAINS} chains x {CHAIN_LEN} GELUs, trunk {TRUNK_LEN} x \
         {DIM}^3 matmuls) on {} ==",
        soc.display_name()
    );
    println!(
        "placement: {} delegated branch(es) on {} lane(s), {:.1} KB staging, modelled \
         delegate {:.2} ms vs CPU {:.1} ms",
        auto.num_delegated(),
        auto.num_lanes_used(),
        auto.total_staging_bytes() as f64 / 1e3,
        auto.delegated().map(|b| auto.delegate_latency_s[b]).sum::<f64>() * 1e3,
        auto.delegated().map(|b| auto.cpu_latency_s[b]).sum::<f64>() * 1e3,
    );
    assert!(auto.num_delegated() >= 1, "pixel6 must offload the trunk");

    let (cpu_s, cpu_sum, cpu_st) = time_placed(&engine, &schedules, &forced, true, REPS);
    let (coex_s, coex_sum, coex_st) = time_placed(&engine, &schedules, &auto, true, REPS);
    assert_eq!(cpu_sum, coex_sum, "co-execution changed results");
    println!(
        "cpu-only forced: {:.0} ms mean over {REPS} runs ({} CPU-wave branches)",
        cpu_s * 1e3,
        cpu_st.cpu_branch_runs
    );
    println!(
        "co-execution:    {:.0} ms mean over {REPS} runs ({} CPU-wave branches \
         + {} delegate jobs)",
        coex_s * 1e3,
        coex_st.cpu_branch_runs,
        coex_st.delegate_jobs
    );
    println!(
        "verdict: {:.2}x -> {}",
        cpu_s / coex_s.max(1e-12),
        if coex_s < cpu_s {
            "co-execution beats CPU-only (outputs bit-identical)"
        } else {
            "NOT faster (regression!)"
        }
    );

    // ---- lane scaling: 2 trunks, 1-lane vs 2-lane pixel6
    let g2 = micro::fallback_heavy_lanes(2, 4, 8, DIM, TRUNK_LEN);
    let p2 = partition(&g2, &cm);
    assert!(p2.regions.len() >= 2, "both trunks must survive the cost model");
    let plan2 = branch::plan(&g2, &p2, DEFAULT_BETA);
    let mems2 = branch_memories(&g2, &p2, &plan2);
    let engine2 = Engine::new(&g2, &p2, &plan2, None);
    let schedules2 = sched::schedule(&plan2, &mems2, 1 << 31, &cfg);
    let mut soc1 = SocProfile::pixel6();
    soc1.lanes.truncate(1);
    let lane1 = place::assign(&g2, &p2, &plan2, &soc1, PlacePolicy::Auto);
    let lane2 = place::assign(&g2, &p2, &plan2, &soc, PlacePolicy::Auto);
    assert_eq!(lane1.num_lanes_used(), 1);
    assert_eq!(lane2.num_lanes_used(), 2, "busy-time balancing must use both lanes");
    println!(
        "\n== lane scaling: fallback-heavy-lanes(2 trunks x {TRUNK_LEN} x {DIM}^3) on {} ==",
        soc.display_name()
    );
    let (one_s, one_sum, _) = time_placed(&engine2, &schedules2, &lane1, true, REPS);
    let (two_s, two_sum, _) = time_placed(&engine2, &schedules2, &lane2, true, REPS);
    assert_eq!(one_sum, two_sum, "lane count changed results");
    println!("1-lane: {:.0} ms mean over {REPS} runs (both trunks on the TPU queue)", one_s * 1e3);
    println!("2-lane: {:.0} ms mean over {REPS} runs (TPU + GPU queues)", two_s * 1e3);
    // wall-clock is hardware-dependent (the lanes do real host-kernel
    // compute on extra threads), so like the co-execution verdict this
    // is reported, not asserted: on a >=4-core idle host the line must
    // read "no slower" — "SLOWER" there means lane scaling broke.
    println!(
        "lane verdict: {:.2}x -> {}",
        one_s / two_s.max(1e-12),
        if two_s <= one_s * 1.05 {
            "2-lane co-execution no slower than 1-lane (outputs bit-identical)"
        } else {
            "2-lane SLOWER than 1-lane (regression!)"
        }
    );

    // ---- overlap ablation: cross-layer merges vs barrier joins
    const STAGES: usize = 3;
    let g3 = micro::fallback_pipeline(STAGES, 4, 12, DIM, TRUNK_LEN);
    let p3 = partition(&g3, &cm);
    assert_eq!(p3.regions.len(), STAGES, "one trunk region per stage");
    let plan3 = branch::plan(&g3, &p3, DEFAULT_BETA);
    let mems3 = branch_memories(&g3, &p3, &plan3);
    let engine3 = Engine::new(&g3, &p3, &plan3, None);
    let schedules3 = sched::schedule(&plan3, &mems3, 1 << 31, &cfg);
    // one lane so every stage's trunk shares a queue: barrier joins
    // idle it at each stage boundary, overlap keeps it fed
    let stage_pl = place::assign(&g3, &p3, &plan3, &soc1, PlacePolicy::Auto);
    assert_eq!(stage_pl.num_delegated(), STAGES, "every stage trunk must delegate");
    println!(
        "\n== overlap ablation: fallback-pipeline({STAGES} stages, trunk {TRUNK_LEN} x \
         {DIM}^3 each) on one lane =="
    );
    let (ov_s, ov_sum, ov_st) = time_placed(&engine3, &schedules3, &stage_pl, true, 1);
    let (ba_s, ba_sum, ba_st) = time_placed(&engine3, &schedules3, &stage_pl, false, 1);
    assert_eq!(ov_sum, ba_sum, "overlap knob changed results");
    println!(
        "barrier-join:       {:.0} ms, {} idle-lane gaps, {} stalls",
        ba_s * 1e3,
        ba_st.lane_gaps,
        ba_st.delegate_stalls
    );
    println!(
        "cross-layer overlap: {:.0} ms, {} idle-lane gaps, {} stalls",
        ov_s * 1e3,
        ov_st.lane_gaps,
        ov_st.delegate_stalls
    );
    assert!(
        ov_st.lane_gaps < ba_st.lane_gaps,
        "overlap must show strictly fewer idle-lane gaps ({} !< {})",
        ov_st.lane_gaps,
        ba_st.lane_gaps
    );
    println!(
        "overlap verdict: {} -> {} idle-lane gaps ({:.2}x wall)",
        ba_st.lane_gaps,
        ov_st.lane_gaps,
        ba_s / ov_s.max(1e-12)
    );

    // ---- governed co-execution: in-flight lane staging is leased
    let gov = MemoryGovernor::new(u64::MAX);
    let values = Values::default();
    let st = engine3
        .run_waves_placed(
            &schedules3,
            &values,
            Some(&gov),
            &parallax::ctrl::ShapeEnv::unresolved(),
            Some(&stage_pl),
            true,
        )
        .expect("governed");
    let inflight = sched::placed_inflight_staging(&plan3, &stage_pl, &schedules3);
    println!(
        "\ngoverned: peak reserved {:.1} KB (peak in-flight lane staging {:.1} KB), \
         modelled acc busy {:.2} ms",
        gov.peak_reserved() as f64 / 1e3,
        inflight.iter().copied().max().unwrap_or(0) as f64 / 1e3,
        st.acc_modelled_s * 1e3
    );

    println!("\n[heterogeneous] completed in {:.2}s", t0.elapsed().as_secs_f64());
}
