//! Bench target for heterogeneous placement & delegate co-execution:
//! CPU-only-forced vs co-executing wall-clock on the real engine (see
//! EXPERIMENTS.md §Heterogeneous for the reproduce protocol and the
//! simulated-delegate deviation note).
//!
//! `cargo bench --bench heterogeneous` prints
//! 1. the placement-decision table (`parallax eval hetero` — pure
//!    modelling, per model × device), and
//! 2. a real-engine run of the fallback-heavy profile: the matmul
//!    trunk offloaded to the async delegate lane while the GELU
//!    fallback chains run in CPU waves, vs the same schedules with
//!    placement forced to CPU — same outputs, fewer CPU-wave branch
//!    executions, lower wall-clock.

use parallax::branch::{self, DEFAULT_BETA};
use parallax::device::SocProfile;
use parallax::exec::Engine;
use parallax::memory::branch_memories;
use parallax::models::micro;
use parallax::partition::{partition, CostModel};
use parallax::place::{self, PlacePolicy, PlacementPlan};
use parallax::sched::{self, MemoryGovernor, SchedCfg};

const CHAINS: usize = 8;
const CHAIN_LEN: usize = 48;
const DIM: usize = 448;
const TRUNK_LEN: usize = 4;
const REPS: usize = 3;

fn main() {
    let t0 = std::time::Instant::now();
    println!("heterogeneous: placement & delegate co-execution (real engine)\n");

    // ---- placement decisions across the zoo (modelled, no execution)
    println!("{}", parallax::eval::hetero());

    // ---- real engine: fallback-heavy profile, Pixel 6 placement
    let soc = SocProfile::pixel6();
    let g = micro::fallback_heavy(CHAINS, CHAIN_LEN, DIM, TRUNK_LEN);
    let cm = CostModel::from_profile(&soc);
    let p = partition(&g, &cm);
    assert!(!p.regions.is_empty(), "trunk must survive the device cost model");
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let mems = branch_memories(&g, &p, &plan);
    let engine = Engine::new(&g, &p, &plan, None);
    // narrow CPU budget (2 threads) so the chains span several waves —
    // the window the delegate lane hides the trunk behind
    let cfg = SchedCfg { max_threads: 2, margin: 0.4 };
    let schedules = sched::schedule(&plan, &mems, 1 << 31, &cfg);

    let auto = place::assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
    let forced = PlacementPlan::cpu_only(plan.branches.len());
    println!(
        "== fallback-heavy({CHAINS} chains x {CHAIN_LEN} GELUs, trunk {TRUNK_LEN} x \
         {DIM}^3 matmuls) on {} ==",
        soc.display_name()
    );
    println!(
        "placement: {} delegated branch(es), {:.1} KB staging, modelled delegate \
         {:.2} ms vs CPU {:.1} ms",
        auto.num_delegated(),
        auto.total_staging_bytes() as f64 / 1e3,
        auto.delegated().map(|b| auto.delegate_latency_s[b]).sum::<f64>() * 1e3,
        auto.delegated().map(|b| auto.cpu_latency_s[b]).sum::<f64>() * 1e3,
    );
    assert!(auto.num_delegated() >= 1, "pixel6 must offload the trunk");

    let time = |placement: &PlacementPlan| -> (f64, f64, usize) {
        // 1 warm-up + REPS timed runs, mean wall + checksum + cpu runs
        let (v, _) = engine.run_placed(&schedules, placement, None).expect("warm-up");
        let checksum = v.checksum();
        let mut wall = 0.0;
        let mut cpu_runs = 0;
        for _ in 0..REPS {
            let t = std::time::Instant::now();
            let (_, st) = engine.run_placed(&schedules, placement, None).expect("run");
            wall += t.elapsed().as_secs_f64();
            cpu_runs = st.cpu_branch_runs;
        }
        (wall / REPS as f64, checksum, cpu_runs)
    };
    let (cpu_s, cpu_sum, cpu_runs) = time(&forced);
    let (coex_s, coex_sum, coex_runs) = time(&auto);
    assert_eq!(cpu_sum, coex_sum, "co-execution changed results");
    println!(
        "cpu-only forced: {:.0} ms mean over {REPS} runs ({cpu_runs} CPU-wave branches)",
        cpu_s * 1e3
    );
    println!(
        "co-execution:    {:.0} ms mean over {REPS} runs ({coex_runs} CPU-wave branches \
         + {} delegate jobs)",
        coex_s * 1e3,
        auto.num_delegated()
    );
    println!(
        "verdict: {:.2}x -> {}",
        cpu_s / coex_s.max(1e-12),
        if coex_s < cpu_s {
            "co-execution beats CPU-only (outputs bit-identical)"
        } else {
            "NOT faster (regression!)"
        }
    );

    // ---- governed co-execution: staging is part of the lease
    let gov = MemoryGovernor::new(u64::MAX);
    let (_, st) = engine.run_placed(&schedules, &auto, Some(&gov)).expect("governed");
    println!(
        "governed: peak reserved {:.1} KB (incl. {:.1} KB delegate staging), \
         modelled acc busy {:.2} ms",
        gov.peak_reserved() as f64 / 1e3,
        auto.total_staging_bytes() as f64 / 1e3,
        st.acc_modelled_s * 1e3
    );

    println!("\n[heterogeneous] completed in {:.2}s", t0.elapsed().as_secs_f64());
}
