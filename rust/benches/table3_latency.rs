//! Bench target regenerating the paper's Table 3 (end-to-end latency).
//!
//! `cargo bench --bench table3_latency` prints the same rows the paper
//! reports (see EXPERIMENTS.md for the paper-vs-measured comparison)
//! plus the wall time of the regeneration itself.

fn main() {
    let t0 = std::time::Instant::now();
    let table = parallax::eval::run("table3").expect("known experiment");
    println!("{table}");
    println!("[table3_latency] regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
