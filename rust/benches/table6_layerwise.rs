//! Bench target regenerating the paper's Table 6 (layer-wise latency).
//!
//! `cargo bench --bench table6_layerwise` prints the same rows the paper
//! reports (see EXPERIMENTS.md for the paper-vs-measured comparison)
//! plus the wall time of the regeneration itself.

fn main() {
    let t0 = std::time::Instant::now();
    let table = parallax::eval::run("table6").expect("known experiment");
    println!("{table}");
    println!("[table6_layerwise] regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
