//! Bench target regenerating the paper's Table 4 (peak runtime memory).
//!
//! `cargo bench --bench table4_peak_memory` prints the same rows the paper
//! reports (see EXPERIMENTS.md for the paper-vs-measured comparison)
//! plus the wall time of the regeneration itself.

fn main() {
    let t0 = std::time::Instant::now();
    let table = parallax::eval::run("table4").expect("known experiment");
    println!("{table}");
    println!("[table4_peak_memory] regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
