//! Serving-throughput bench: one governed, micro-batching dispatcher
//! vs the per-model-isolated baseline (ISSUE 2 acceptance criterion).
//!
//! `cargo bench --bench serve_throughput` drives identical closed-loop
//! CLIP-text + DistilBERT + YOLOv8n traffic (same skewed mix, offered
//! load, and seeds) through two deployments:
//!
//! * **isolated** — the pre-governor layout: one single-worker server
//!   per model, private unlimited ledgers, no batching;
//! * **governed** — one shared dispatcher: pooled workers, round-robin
//!   fairness, micro-batching, and one device-wide [`MemoryGovernor`]
//!   that every admission leases branch-peak memory from.
//!
//! Reports per-model p50/p99, total throughput, mean batch size, and
//! the governor's peak-reserved high-water mark vs its budget.
//!
//! [`MemoryGovernor`]: parallax::sched::MemoryGovernor

use std::sync::{mpsc, Arc};
use std::time::Instant;

use parallax::baselines::{Framework, Pipeline};
use parallax::device::{LinkModel, RemoteLane, SocProfile};
use parallax::models::ModelKind;
use parallax::sched::{self, MemoryGovernor, SchedCfg};
use parallax::serve::{
    pipeline_executor, ModelExecutor, PlacedEngineExecutor, Response, ServeCfg, Server, SloSpec,
};
use parallax::sim::Mode;
use parallax::util::stats::summarize;

const MODELS: [ModelKind; 3] =
    [ModelKind::ClipText, ModelKind::DistilBert, ModelKind::Yolov8n];
/// 4:1:1 skew toward the text encoder — the mix where work-conserving
/// shared workers pay off over private lanes.
const LOAD: [&str; 6] =
    ["clip-text", "clip-text", "distilbert", "clip-text", "clip-text", "yolov8n"];
const N: usize = 240;
const CONCURRENCY: usize = 12;
const SEED: u64 = 2026;

fn build_pipeline(model: ModelKind, gov: Option<&Arc<MemoryGovernor>>) -> Pipeline {
    let pipe = Pipeline::build(
        Framework::Parallax,
        model,
        &SocProfile::pixel6(),
        Mode::CpuOnly,
        SchedCfg::default(),
    )
    .expect("cpu always supported");
    match gov {
        Some(g) => pipe.with_governor(g.clone()),
        None => pipe,
    }
}

fn executor(pipe: Pipeline, rng_seed: u64) -> Box<dyn ModelExecutor> {
    pipeline_executor(pipe, rng_seed).1
}

/// Closed-loop driver: `n` requests over the load mix, `conc` in
/// flight, routed to whichever server owns the model.
fn drive(
    servers: &[Server],
    pick: impl Fn(&str) -> usize,
    n: usize,
    conc: usize,
    seed: u64,
) -> (Vec<Response>, f64) {
    let t0 = Instant::now();
    let mut pending: Vec<mpsc::Receiver<anyhow::Result<Response>>> = Vec::new();
    let mut done: Vec<Response> = Vec::new();
    for i in 0..n {
        let model = LOAD[i % LOAD.len()];
        let srv = &servers[pick(model)];
        pending.push(srv.submit(model, seed ^ i as u64).expect("known model"));
        if pending.len() >= conc {
            done.push(pending.remove(0).recv().expect("reply").expect("exec ok"));
        }
    }
    for rx in pending {
        done.push(rx.recv().expect("reply").expect("exec ok"));
    }
    (done, t0.elapsed().as_secs_f64())
}

fn report(tag: &str, responses: &[Response], wall: f64) -> f64 {
    println!("\n-- {tag}: {} req in {wall:.2}s = {:.1} req/s", responses.len(),
        responses.len() as f64 / wall);
    let mut overall: Vec<f64> = Vec::new();
    for model in MODELS {
        let lats: Vec<f64> = responses
            .iter()
            .filter(|r| r.model == model.slug())
            .map(|r| r.latency_s * 1e3)
            .collect();
        overall.extend(lats.iter().map(|l| l / 1e3));
        let s = summarize(&lats).expect("model served");
        println!(
            "   {:<12} n={:<3} p50 {:>8.2} ms  p99 {:>8.2} ms  max {:>8.2} ms",
            model.slug(),
            s.n,
            s.p50,
            s.p99,
            s.max
        );
    }
    let s = summarize(&overall).unwrap();
    let batch: f64 = responses.iter().map(|r| r.batched as f64).sum::<f64>()
        / responses.len() as f64;
    println!(
        "   {:<12} n={:<3} p50 {:>8.2} ms  p99 {:>8.2} ms  mean batch {batch:.2}",
        "ALL",
        s.n,
        s.p50 * 1e3,
        s.p99 * 1e3
    );
    s.p99
}

fn main() {
    // per-model branch-peak demands drive both sizing and admission
    let demands: Vec<u64> = MODELS
        .iter()
        .map(|&m| build_pipeline(m, None).peak_branch_demand())
        .collect();
    for (model, d) in MODELS.iter().zip(&demands) {
        println!("{:<12} branch-peak demand {:>7.2} MB", model.slug(), *d as f64 / 1e6);
    }

    // -------- baseline: per-model isolated lanes (old layout) --------
    let isolated: Vec<Server> = MODELS
        .iter()
        .enumerate()
        .map(|(i, &model)| {
            let gov = Arc::new(MemoryGovernor::unlimited());
            let mut s =
                Server::with_config(ServeCfg { workers: 1, max_batch: 1 }, gov.clone());
            s.register_with_demand(
                model.slug(),
                demands[i],
                executor(build_pipeline(model, None), 7 + i as u64),
            );
            s
        })
        .collect();
    let route = |m: &str| MODELS.iter().position(|k| k.slug() == m).unwrap();
    let (iso_resp, iso_wall) = drive(&isolated, route, N, CONCURRENCY, SEED);
    let iso_p99 = report("isolated (1 worker/model, no batching)", &iso_resp, iso_wall);
    drop(isolated);

    // -------- governed: shared dispatcher + device-wide ledger --------
    let mut sorted = demands.clone();
    sorted.sort_unstable();
    // room for the two hungriest models at once; the third must wait
    let budget = sorted[sorted.len() - 1] + sorted[sorted.len() - 2];
    let gov = Arc::new(MemoryGovernor::new(budget));
    let mut governed =
        Server::with_config(ServeCfg { workers: 3, max_batch: 8 }, gov.clone());
    for (i, &model) in MODELS.iter().enumerate() {
        governed.register_with_demand(
            model.slug(),
            demands[i],
            executor(build_pipeline(model, Some(&gov)), 7 + i as u64),
        );
    }
    let (gov_resp, gov_wall) = drive(
        std::slice::from_ref(&governed),
        |_| 0,
        N,
        CONCURRENCY,
        SEED,
    );
    let gov_p99 = report("governed (3 shared workers, micro-batching)", &gov_resp, gov_wall);

    let stats = gov.stats();
    println!(
        "\ngovernor: budget {:.2} MB, peak reserved {:.2} MB ({}), \
         {} grants, {} waits, {} over-budget",
        budget as f64 / 1e6,
        stats.peak_reserved as f64 / 1e6,
        if stats.peak_reserved <= budget { "UNDER BUDGET" } else { "OVER BUDGET!" },
        stats.grants,
        stats.waits,
        stats.over_budget_grants
    );
    println!(
        "p99 governed {:.2} ms vs isolated {:.2} ms -> {}",
        gov_p99 * 1e3,
        iso_p99 * 1e3,
        if gov_p99 <= iso_p99 * 1.05 { "OK (no worse at equal offered load)" } else { "REGRESSION" }
    );

    // trajectory record: mean ns per request for both deployments
    // (what tools/check_bench.py diffs against the committed BENCH file)
    let mut b = parallax::util::bench::Bench::new("serve_throughput");
    b.record("isolated_mean_per_request", iso_wall * 1e9 / iso_resp.len() as f64);
    b.record("governed_mean_per_request", gov_wall * 1e9 / gov_resp.len() as f64);
    b.report();

    // ---- multi-model: shared-ledger vs independent placement --------
    // Two fallback-heavy tenants on pixel6.  Placed independently, both
    // trunk onto the same (fastest) lane; the shared lane ledger spreads
    // them.  Same closed-loop load either way.
    let soc = SocProfile::pixel6();
    let lanes = soc.lanes.len();
    let heavy = || {
        Pipeline::from_graph(
            Framework::Parallax,
            parallax::models::micro::fallback_heavy(4, 4, 128, 6),
            &parallax::partition::CostModel {
                min_ops: 1,
                min_flops: 0,
                max_bytes_per_flop: f64::MAX,
            },
            &soc,
            Mode::Heterogeneous,
            SchedCfg::default(),
        )
    };
    const TENANTS: [(&str, u64); 2] = [("fh-a", 21), ("fh-b", 22)];

    let mut indep = Server::new();
    for (name, seed) in TENANTS {
        let (placement, demand, exec) =
            parallax::serve::placed_pipeline_executor(heavy(), seed);
        println!(
            "independent   {name}: lane jobs {:?}",
            placement.lane_job_counts(lanes)
        );
        indep.register_with_demand(name, demand, exec);
    }
    let rep_i = indep.run_load(&["fh-a", "fh-b"], 160, 8, SEED).expect("independent load");
    drop(indep);

    let mut shared = Server::new();
    for (name, seed) in TENANTS {
        shared.register_placed(name, heavy(), seed);
    }
    for (name, placement) in shared.placements() {
        println!(
            "shared-ledger {name}: lane jobs {:?}",
            placement.lane_job_counts(lanes)
        );
    }
    let rep_s = shared.run_load(&["fh-a", "fh-b"], 160, 8, SEED).expect("shared load");
    println!(
        "multi-model mean/request: independent {:.3} ms, shared ledger {:.3} ms",
        rep_i.wall_s * 1e3 / rep_i.responses.len() as f64,
        rep_s.wall_s * 1e3 / rep_s.responses.len() as f64
    );

    let mut b = parallax::util::bench::Bench::new("serve_throughput multi");
    b.record(
        "independent_mean_per_request",
        rep_i.wall_s * 1e9 / rep_i.responses.len() as f64,
    );
    b.record(
        "shared_ledger_mean_per_request",
        rep_s.wall_s * 1e9 / rep_s.responses.len() as f64,
    );
    b.report();

    // ---- remote spill: device–edge tier vs degraded-CPU fallback ----
    // One fallback-heavy tenant whose SLO the local lane can never meet
    // (modelled lane service 1.0 s vs a 0.5 s deadline).  With an edge
    // server registered, the backlog spills over the link; without one,
    // the same backlog degrades to the CPU-forced path.  Same deadline,
    // same seeds, every request resolved explicitly either way (ISSUE
    // 9) — the record compares the two fallback tiers' mean ns/request.
    let soc_r = SocProfile::pixel6().with_remote(&RemoteLane::edge_server());
    let rl = soc_r.remote_lane().expect("profile carries a remote lane");
    let g = parallax::models::micro::fallback_heavy(4, 3, 64, 4);
    let p = parallax::partition::partition(
        &g,
        &parallax::partition::CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX },
    );
    let plan = parallax::branch::plan(&g, &p, parallax::branch::DEFAULT_BETA);
    let mems = parallax::memory::branch_memories(&g, &p, &plan);
    let s = sched::schedule(&plan, &mems, 1 << 34, &SchedCfg::default());
    let mut spill = parallax::place::PlacementPlan::cpu_only(plan.branches.len());
    for b in 0..plan.branches.len() {
        if parallax::place::delegate_safe(&g, &p, &plan, b) {
            spill.assignment[b] = parallax::place::Placement::Delegate(rl);
            spill.staging_bytes[b] = parallax::place::transfer_bytes(&g, &p, &plan, b);
            spill.delegate_latency_s[b] =
                parallax::place::lane_delegate_latency(&g, &p, &plan, b, &soc_r, &soc_r.lanes[rl]);
        }
    }
    let cpu_exec = || {
        PlacedEngineExecutor::new(
            g.clone(),
            p.clone(),
            plan.clone(),
            s.clone(),
            parallax::place::PlacementPlan::cpu_only(plan.branches.len()),
        )
    };
    let base_slo =
        SloSpec { lane: Some(0), lane_service_s: 1.0, cpu_service_s: 0.002, remote: None };
    let flags: Vec<bool> = soc_r.lanes.iter().map(|l| l.remote).collect();
    const NR: usize = 48;

    let mut remote_srv = Server::new();
    remote_srv.register_with_slo(
        "fh",
        0,
        base_slo.with_remote(rl, 0.01),
        Box::new(cpu_exec().with_remote(flags, LinkModel::reliable(SEED), spill)),
    );
    let rep_r = remote_srv.run_load_slo(&["fh"], NR, 8, SEED, Some(0.5)).expect("remote load");
    drop(remote_srv);

    let mut local_srv = Server::new();
    local_srv.register_with_slo("fh", 0, base_slo, Box::new(cpu_exec()));
    let rep_l = local_srv.run_load_slo(&["fh"], NR, 8, SEED, Some(0.5)).expect("degraded load");

    println!(
        "\nremote spill tier: {} spilled ({:.3} ms/req) vs {} degraded-cpu ({:.3} ms/req)",
        rep_r.spilled,
        rep_r.wall_s * 1e3 / rep_r.responses.len() as f64,
        rep_l.degraded,
        rep_l.wall_s * 1e3 / rep_l.responses.len() as f64
    );
    assert_eq!(rep_r.spilled, NR, "SLO ladder arithmetic: every request spills");
    assert_eq!(rep_l.degraded, NR, "without a remote lane the backlog degrades");

    let mut b = parallax::util::bench::Bench::new("serve_throughput remote");
    b.record(
        "spilled_mean_per_request",
        rep_r.wall_s * 1e9 / rep_r.responses.len() as f64,
    );
    b.record(
        "degraded_mean_per_request",
        rep_l.wall_s * 1e9 / rep_l.responses.len() as f64,
    );
    b.report();
}
