//! Bench target regenerating the paper's Table 5 (arena footprints).
//!
//! `cargo bench --bench table5_arena_footprint` prints the same rows the paper
//! reports (see EXPERIMENTS.md for the paper-vs-measured comparison)
//! plus the wall time of the regeneration itself.

fn main() {
    let t0 = std::time::Instant::now();
    let table = parallax::eval::run("table5").expect("known experiment");
    println!("{table}");
    println!("[table5_arena_footprint] regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
