//! # Parallax
//!
//! Reproduction of *"Parallax: Runtime Parallelization for Operator
//! Fallbacks in Heterogeneous Edge Systems"* (CS.DC 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: non-invasive graph
//!   analysis ([`partition`], [`branch`]), branch-aware memory
//!   management ([`memory`]), resource-constrained parallel scheduling
//!   ([`sched`]) with a process-wide memory governor
//!   ([`sched::MemoryGovernor`]), runtime subgraph control for dynamic
//!   models ([`ctrl`], §3.4), multi-lane heterogeneous device
//!   placement with cross-layer delegate co-execution ([`place`],
//!   [`device::AccLane`], [`exec::DelegateWorker`]), plus the
//!   substrates it needs: a graph
//!   IR ([`graph`]), a model zoo ([`models`]), simulated edge SoCs
//!   ([`device`]), a discrete-event executor ([`sim`]), baseline
//!   frameworks ([`baselines`]), a real PJRT execution engine
//!   ([`exec`], [`runtime`]) and a governed multi-model serving
//!   front-end ([`serve`]).
//! * **L2** — `python/compile/model.py`: JAX branch programs.
//! * **L1** — `python/compile/kernels/`: Pallas kernels, AOT-lowered to
//!   HLO text that this crate loads via PJRT (`make artifacts`).
//!
//! See `README.md` for the quickstart and the paper-table → bench-target
//! map, and `ARCHITECTURE.md` for the paper-section → module map with
//! the request lifecycle.

pub mod analysis;
pub mod baselines;
pub mod util;
pub mod branch;
pub mod config;
pub mod ctrl;
pub mod device;
pub mod eval;
pub mod exec;
pub mod flops;
pub mod graph;
pub mod memory;
pub mod models;
pub mod partition;
pub mod place;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sim;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
