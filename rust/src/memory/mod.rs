//! Branch-aware memory management (paper §3.2) and the memory models
//! behind Tables 4 and 5.
//!
//! * [`liveness`] — tensor lifetime analysis + linear-scan peak.
//! * [`arena`] — the planners: naive, greedy-global (TFLite/ORT-style)
//!   and Parallax's per-branch bump arena with cross-arena sharing.
//! * This module — branch memory estimation `M_i` (§3.3) and
//!   model-level footprint accounting.

pub mod arena;
pub mod liveness;

pub use arena::{
    aliasing_pairs, plan_branch, plan_greedy_global, plan_naive, ArenaPlan, BumpArena,
};
pub use liveness::{analyze, may_reuse, peak_bytes, Lifetime};

use std::collections::HashMap;

use crate::branch::BranchPlan;
use crate::graph::Graph;
use crate::partition::Partition;

/// Memory demand of one branch (the scheduler's M_i).
#[derive(Clone, Copy, Debug, Default)]
pub struct BranchMemory {
    /// Arena footprint for branch-internal activations.
    pub arena_bytes: usize,
    /// Bytes of this branch's outputs that outlive it (consumed by
    /// later branches / graph outputs) — allocated outside the arena.
    pub boundary_out_bytes: usize,
}

impl BranchMemory {
    /// Total demand while the branch runs.
    pub fn total(&self) -> usize {
        self.arena_bytes + self.boundary_out_bytes
    }
}

/// Estimate M_i for every branch: shape inference (sizes are already on
/// the tensors), per-branch liveness, linear-scan peak (§3.3 three-step
/// estimator), replayed through the branch arena allocator.
pub fn branch_memories(g: &Graph, p: &Partition, plan: &BranchPlan) -> Vec<BranchMemory> {
    let mut out = Vec::with_capacity(plan.branches.len());
    for b in 0..plan.branches.len() {
        let nodes = plan.branch_nodes(g, p, b);
        let lts = liveness::analyze(g, &nodes);
        let (internal, boundary): (Vec<_>, Vec<_>) =
            lts.into_iter().partition(|lt| !lt.escapes);
        let arena_plan = arena::plan_branch(&internal);
        out.push(BranchMemory {
            arena_bytes: arena_plan.arena_bytes,
            boundary_out_bytes: boundary.iter().map(|lt| lt.bytes).sum(),
        });
    }
    out
}

/// Model-level arena accounting (Table 5) for the Parallax planner.
///
/// Concurrency model: layers execute one at a time (the scheduler
/// serialises layers), so per-branch arenas of *different* layers share
/// capacity via cross-arena donation (§3.2) — the arena pool is the max
/// over layers of the sum of that layer's branch arenas.  Boundary
/// tensors crossing layers are kept in a separate region whose peak
/// comes from a layer-granular liveness scan.
#[derive(Clone, Copy, Debug, Default)]
pub struct ParallaxFootprint {
    /// max over layers of Σ branch arena bytes (shared pool).
    pub arena_pool_bytes: usize,
    /// peak of live inter-branch boundary tensors.
    pub boundary_bytes: usize,
}

impl ParallaxFootprint {
    pub fn total(&self) -> usize {
        self.arena_pool_bytes + self.boundary_bytes
    }
}

/// Compute the Parallax arena footprint of a whole model.
pub fn parallax_footprint(g: &Graph, p: &Partition, plan: &BranchPlan) -> ParallaxFootprint {
    let mems = branch_memories(g, p, plan);

    // layer index per branch
    let mut layer_of = vec![0usize; plan.branches.len()];
    for (li, layer) in plan.layers.iter().enumerate() {
        for &b in layer {
            layer_of[b] = li;
        }
    }

    // arena pool: max over layers of Σ arenas in the layer
    let mut pool = 0usize;
    for layer in &plan.layers {
        let s: usize = layer.iter().map(|&b| mems[b].arena_bytes).sum();
        pool = pool.max(s);
    }

    // boundary tensors: producer branch layer -> last consumer branch layer
    let mut node_branch: HashMap<u32, usize> = HashMap::new();
    for b in 0..plan.branches.len() {
        for nid in plan.branch_nodes(g, p, b) {
            node_branch.insert(nid.0, b);
        }
    }
    let n_layers = plan.layers.len().max(1);
    let mut deltas = vec![0isize; n_layers + 1];
    for t in g.tensors() {
        let Some(prod) = g.producer(t.id) else { continue };
        let pb = node_branch[&prod.0];
        let consumers = g.consumers(t.id);
        let crosses = consumers.iter().any(|c| node_branch[&c.0] != pb)
            || consumers.is_empty();
        if !crosses {
            continue;
        }
        let start = layer_of[pb];
        let end = consumers
            .iter()
            .map(|c| layer_of[node_branch[&c.0]])
            .max()
            .unwrap_or(n_layers - 1);
        deltas[start] += t.byte_size_max() as isize;
        deltas[end + 1] -= t.byte_size_max() as isize;
    }
    let mut cur = 0isize;
    let mut boundary = 0isize;
    for d in &deltas[..n_layers] {
        cur += d;
        boundary = boundary.max(cur);
    }

    ParallaxFootprint { arena_pool_bytes: pool, boundary_bytes: boundary as usize }
}

/// Baseline arena footprints over the *whole-graph* execution order
/// (Table 5 columns): `(naive, greedy_global)`.
pub fn baseline_footprints(g: &Graph) -> (usize, usize) {
    let order = g.topo_order().expect("DAG");
    let lts = liveness::analyze(g, &order);
    (
        arena::plan_naive(&lts).arena_bytes,
        arena::plan_greedy_global(&lts).arena_bytes,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::branch;
    use crate::models::{micro, ModelKind};
    use crate::partition::{partition, CostModel};

    fn cpu_only(g: &Graph) -> Partition {
        partition(g, &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 })
    }

    #[test]
    fn branch_memories_cover_all_branches() {
        let g = micro::parallel_chains(4, 5);
        let p = cpu_only(&g);
        let plan = branch::plan(&g, &p, branch::DEFAULT_BETA);
        let mems = branch_memories(&g, &p, &plan);
        assert_eq!(mems.len(), plan.branches.len());
        // the 4 worker chains have identical demands
        let chains: Vec<_> = mems
            .iter()
            .filter(|m| m.arena_bytes > 0)
            .map(|m| m.total())
            .collect();
        assert!(!chains.is_empty());
    }

    #[test]
    fn footprint_ordering_naive_ge_parallax_ge_greedy() {
        // The paper's Table 5 relationship: greedy-global <= Parallax
        // (branch isolation costs some reuse) <= naive (no reuse).
        for kind in [ModelKind::ClipText, ModelKind::DistilBert, ModelKind::Yolov8n] {
            let g = kind.build();
            let p = partition(&g, &CostModel::default());
            let plan = branch::plan(&g, &p, branch::DEFAULT_BETA);
            let (naive, greedy) = baseline_footprints(&g);
            let plx = parallax_footprint(&g, &p, &plan).total();
            assert!(
                plx <= naive,
                "{}: parallax {plx} > naive {naive}",
                kind.display_name()
            );
            assert!(
                greedy <= plx * 2,
                "{}: greedy {greedy} unexpectedly large vs parallax {plx}",
                kind.display_name()
            );
        }
    }

    #[test]
    fn parallax_pool_is_max_over_layers() {
        let g = micro::parallel_chains(2, 4);
        let p = cpu_only(&g);
        let plan = branch::plan(&g, &p, branch::DEFAULT_BETA);
        let fp = parallax_footprint(&g, &p, &plan);
        let mems = branch_memories(&g, &p, &plan);
        let sum_all: usize = mems.iter().map(|m| m.arena_bytes).sum();
        assert!(fp.arena_pool_bytes <= sum_all);
    }

    #[test]
    fn boundary_accounts_cross_branch_tensors() {
        let g = micro::diamond(3, 3);
        let p = cpu_only(&g);
        let plan = branch::plan(&g, &p, branch::DEFAULT_BETA);
        let fp = parallax_footprint(&g, &p, &plan);
        assert!(fp.boundary_bytes > 0, "diamond has cross-branch tensors");
    }
}
