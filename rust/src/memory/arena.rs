//! Arena allocators: offset-planning over tensor lifetimes.
//!
//! Three planners, matching the frameworks compared in Table 5:
//!
//! * [`plan_naive`] — one buffer per tensor, no reuse ("TFLite (Naive)").
//! * [`plan_greedy_global`] — single global arena, best-fit offset
//!   assignment over lifetimes (TFLite/ORT-style aggressive reuse; the
//!   data-dependency coupling this creates is exactly what blocks
//!   branch-level parallelism in the baselines).
//! * [`BumpArena`] — Parallax's per-branch allocator: bump pointer +
//!   first-fit free list with coalescing (§3.2 "In-Branch Memory
//!   Reuse").  One instance per branch; instances are independent, so
//!   concurrent branches never contend (no lock on the hot path).

use super::liveness::{may_reuse, Lifetime};

/// Result of offset planning: arena size + per-tensor offsets.
#[derive(Clone, Debug)]
pub struct ArenaPlan {
    pub arena_bytes: usize,
    /// (lifetime index, offset)
    pub offsets: Vec<usize>,
}

/// Alignment for all planners (TFLite uses 64).
pub const ALIGN: usize = 64;

fn align_up(x: usize) -> usize {
    (x + ALIGN - 1) & !(ALIGN - 1)
}

/// One buffer per tensor: arena = Σ aligned sizes.
pub fn plan_naive(lifetimes: &[Lifetime]) -> ArenaPlan {
    let mut offsets = Vec::with_capacity(lifetimes.len());
    let mut cur = 0usize;
    for lt in lifetimes {
        offsets.push(cur);
        cur += align_up(lt.bytes);
    }
    ArenaPlan { arena_bytes: cur, offsets }
}

/// Greedy best-fit offset planner over lifetimes (the TFLite
/// `SimpleMemoryArena` / ORT arena strategy): process tensors in
/// decreasing size; place each at the lowest offset where it fits
/// without overlapping any already-placed tensor with an intersecting
/// lifetime.
pub fn plan_greedy_global(lifetimes: &[Lifetime]) -> ArenaPlan {
    let mut idx: Vec<usize> = (0..lifetimes.len()).collect();
    idx.sort_by_key(|&i| std::cmp::Reverse(lifetimes[i].bytes));

    let mut placed: Vec<(usize, usize, usize)> = Vec::new(); // (lt idx, offset, end)
    let mut offsets = vec![0usize; lifetimes.len()];
    let mut arena = 0usize;

    for &i in &idx {
        let li = &lifetimes[i];
        let size = align_up(li.bytes);
        // collect blocked intervals from lifetime-overlapping tensors
        let mut blocked: Vec<(usize, usize)> = placed
            .iter()
            .filter(|(j, _, _)| {
                let lj = &lifetimes[*j];
                !(li.last_use < lj.def_pos || lj.last_use < li.def_pos)
            })
            .map(|&(_, off, end)| (off, end))
            .collect();
        blocked.sort_unstable();
        // lowest gap that fits
        let mut candidate = 0usize;
        for (off, end) in blocked {
            if candidate + size <= off {
                break;
            }
            candidate = candidate.max(end);
        }
        offsets[i] = candidate;
        placed.push((i, candidate, candidate + size));
        arena = arena.max(candidate + size);
    }
    ArenaPlan { arena_bytes: arena, offsets }
}

/// Parallax per-branch bump-pointer arena with first-fit free list and
/// coalescing (§3.2).  This is the *runtime* allocator — dynamic shapes
/// allocate at their concrete (drawn) size, not the planner's
/// worst-case bound, and resizes stay inside the owning branch's arena.
#[derive(Debug, Default)]
pub struct BumpArena {
    /// High-water mark = arena size so far.
    high: usize,
    /// Free blocks (offset, size), sorted by offset, coalesced.
    free: Vec<(usize, usize)>,
    /// Live allocations (offset -> size) for validation.
    live: std::collections::HashMap<usize, usize>,
    /// Peak of the *live* byte total (≤ high).
    live_bytes: usize,
    peak_live: usize,
}

impl BumpArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate `bytes`; returns the offset.
    pub fn alloc(&mut self, bytes: usize) -> usize {
        let size = align_up(bytes.max(1));
        // first-fit over the free list
        for k in 0..self.free.len() {
            let (off, fsize) = self.free[k];
            if fsize >= size {
                if fsize == size {
                    self.free.remove(k);
                } else {
                    self.free[k] = (off + size, fsize - size);
                }
                self.live.insert(off, size);
                self.live_bytes += size;
                self.peak_live = self.peak_live.max(self.live_bytes);
                return off;
            }
        }
        // bump
        let off = self.high;
        self.high += size;
        self.live.insert(off, size);
        self.live_bytes += size;
        self.peak_live = self.peak_live.max(self.live_bytes);
        off
    }

    /// Release an allocation back to the free list (coalescing).
    pub fn free(&mut self, offset: usize) {
        let size = self
            .live
            .remove(&offset)
            .expect("freeing an offset that is not live");
        self.live_bytes -= size;
        let pos = self.free.partition_point(|&(o, _)| o < offset);
        self.free.insert(pos, (offset, size));
        // coalesce with next then prev
        if pos + 1 < self.free.len() {
            let (o, s) = self.free[pos];
            let (on, sn) = self.free[pos + 1];
            if o + s == on {
                self.free[pos] = (o, s + sn);
                self.free.remove(pos + 1);
            }
        }
        if pos > 0 {
            let (op, sp) = self.free[pos - 1];
            let (o, s) = self.free[pos];
            if op + sp == o {
                self.free[pos - 1] = (op, sp + s);
                self.free.remove(pos);
            }
        }
    }

    /// Arena footprint (high-water mark).
    pub fn footprint(&self) -> usize {
        self.high
    }

    /// Peak concurrently-live bytes.
    pub fn peak_live(&self) -> usize {
        self.peak_live
    }

    /// Bytes currently live.
    pub fn live_bytes(&self) -> usize {
        self.live_bytes
    }

    /// Number of live allocations.
    pub fn live_count(&self) -> usize {
        self.live.len()
    }

    /// Transfer this arena's free capacity to a fresh arena for a
    /// non-concurrent branch (§3.2 "Cross-Arena Buffer Sharing"): the
    /// new arena starts with this one's full extent as free space.
    pub fn donate(self) -> BumpArena {
        BumpArena {
            high: self.high,
            free: if self.high > 0 { vec![(0, self.high)] } else { vec![] },
            live: Default::default(),
            live_bytes: 0,
            peak_live: 0,
        }
    }

    /// Validate internal consistency (tests/debug).
    pub fn check(&self) -> bool {
        // free blocks sorted, non-overlapping, within high
        let mut prev_end = 0usize;
        for &(o, s) in &self.free {
            if o < prev_end || o + s > self.high {
                return false;
            }
            prev_end = o + s;
        }
        // live allocations don't overlap free blocks
        for (&o, &s) in &self.live {
            for &(fo, fs) in &self.free {
                if o < fo + fs && fo < o + s {
                    return false;
                }
            }
        }
        true
    }
}

/// Plan a branch arena by replaying lifetimes through a [`BumpArena`]
/// in execution order — returns (footprint, offsets aligned to
/// `lifetimes` order).  This is what the §3.3 estimator uses for M_i.
pub fn plan_branch(lifetimes: &[Lifetime]) -> ArenaPlan {
    // events in execution order
    let n = lifetimes.len();
    let mut arena = BumpArena::new();
    let mut offsets = vec![0usize; n];
    // sort def events by def_pos, frees by last_use
    let mut defs: Vec<usize> = (0..n).collect();
    defs.sort_by_key(|&i| lifetimes[i].def_pos);
    let mut frees: Vec<usize> = (0..n).collect();
    frees.sort_by_key(|&i| lifetimes[i].last_use);
    let mut fi = 0;
    for &i in &defs {
        // release everything whose last_use < this def_pos
        while fi < n && lifetimes[frees[fi]].last_use < lifetimes[i].def_pos {
            if !lifetimes[frees[fi]].escapes {
                arena.free(offsets[frees[fi]]);
            }
            fi += 1;
        }
        offsets[i] = arena.alloc(lifetimes[i].bytes);
    }
    ArenaPlan { arena_bytes: arena.footprint(), offsets }
}

/// Audit an [`ArenaPlan`] against the lifetimes it was planned over:
/// return every pair `(i, j)` (`i < j`, indices into `lifetimes` /
/// `plan.offsets`) whose lifetimes overlap in time (Eq. 1's
/// [`may_reuse`] fails both ways) yet whose planned byte ranges
/// `[offset, offset + align_up(bytes))` intersect.  An empty result
/// proves the layout is aliasing-free; each returned pair is a §3.2
/// violation — two concurrently-live tensors sharing arena bytes.
/// Used by the static plan pass (`analysis::plan`) on the frozen
/// offsets inside a `CapturedPlan`.
pub fn aliasing_pairs(plan: &ArenaPlan, lifetimes: &[Lifetime]) -> Vec<(usize, usize)> {
    let n = plan.offsets.len().min(lifetimes.len());
    let mut pairs = Vec::new();
    for i in 0..n {
        let (ai, aj) = {
            let off = plan.offsets[i];
            (off, off + align_up(lifetimes[i].bytes.max(1)))
        };
        for j in (i + 1)..n {
            if may_reuse(&lifetimes[i], &lifetimes[j]) {
                continue;
            }
            let (bi, bj) = {
                let off = plan.offsets[j];
                (off, off + align_up(lifetimes[j].bytes.max(1)))
            };
            if ai < bj && bi < aj {
                pairs.push((i, j));
            }
        }
    }
    pairs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::TensorId;

    fn lt(def: usize, last: usize, bytes: usize) -> Lifetime {
        Lifetime { tensor: TensorId(0), def_pos: def, last_use: last, escapes: false, bytes }
    }

    #[test]
    fn naive_is_sum() {
        let p = plan_naive(&[lt(0, 1, 100), lt(1, 2, 100)]);
        assert_eq!(p.arena_bytes, 2 * 128);
    }

    #[test]
    fn greedy_reuses_disjoint_lifetimes() {
        // a: [0,1], b: [2,3] -> same offset
        let p = plan_greedy_global(&[lt(0, 1, 100), lt(2, 3, 100)]);
        assert_eq!(p.arena_bytes, 128);
        assert_eq!(p.offsets[0], p.offsets[1]);
    }

    #[test]
    fn greedy_never_overlaps_live_tensors() {
        let lts = vec![lt(0, 2, 64), lt(1, 3, 64), lt(2, 4, 64), lt(5, 6, 192)];
        let p = plan_greedy_global(&lts);
        for i in 0..lts.len() {
            for j in (i + 1)..lts.len() {
                let overlap_life = !(lts[i].last_use < lts[j].def_pos
                    || lts[j].last_use < lts[i].def_pos);
                let (oi, si) = (p.offsets[i], align_up(lts[i].bytes));
                let (oj, sj) = (p.offsets[j], align_up(lts[j].bytes));
                let overlap_mem = oi < oj + sj && oj < oi + si;
                assert!(!(overlap_life && overlap_mem), "{i} vs {j}");
            }
        }
    }

    #[test]
    fn bump_arena_reuses_freed_blocks() {
        let mut a = BumpArena::new();
        let o1 = a.alloc(100);
        let _o2 = a.alloc(50);
        a.free(o1);
        let o3 = a.alloc(80); // fits in o1's 128-byte block
        assert_eq!(o3, o1);
        assert!(a.check());
        assert_eq!(a.footprint(), 128 + 64);
    }

    #[test]
    fn bump_arena_coalesces() {
        let mut a = BumpArena::new();
        let o1 = a.alloc(64);
        let o2 = a.alloc(64);
        let o3 = a.alloc(64);
        a.free(o1);
        a.free(o2); // coalesce with o1
        let big = a.alloc(128);
        assert_eq!(big, o1);
        assert!(a.check());
        a.free(o3);
        a.free(big);
        assert_eq!(a.live_count(), 0);
    }

    #[test]
    #[should_panic(expected = "not live")]
    fn double_free_panics() {
        let mut a = BumpArena::new();
        let o = a.alloc(10);
        a.free(o);
        a.free(o);
    }

    #[test]
    fn donate_passes_capacity() {
        let mut a = BumpArena::new();
        let o = a.alloc(1000);
        a.free(o);
        let mut b = a.donate();
        let o2 = b.alloc(500);
        assert_eq!(o2, 0);
        assert_eq!(b.footprint(), 1024); // no growth needed
    }

    #[test]
    fn plan_branch_between_naive_and_peak() {
        let lts = vec![lt(0, 1, 100), lt(1, 2, 100), lt(2, 3, 100), lt(3, 4, 100)];
        let b = plan_branch(&lts);
        let n = plan_naive(&lts);
        // chain: at most 2 live at once -> ~2 slots
        assert!(b.arena_bytes <= n.arena_bytes);
        assert_eq!(b.arena_bytes, 2 * 128);
    }

    #[test]
    fn aliasing_pairs_accepts_planner_output() {
        let lts = vec![lt(0, 2, 64), lt(1, 3, 64), lt(2, 4, 64), lt(5, 6, 192)];
        assert!(aliasing_pairs(&plan_branch(&lts), &lts).is_empty());
        assert!(aliasing_pairs(&plan_naive(&lts), &lts).is_empty());
        assert!(aliasing_pairs(&plan_greedy_global(&lts), &lts).is_empty());
    }

    #[test]
    fn aliasing_pairs_flags_overlapping_live_tensors() {
        // Both live at position 1, both at offset 0: exactly one pair.
        let lts = vec![lt(0, 2, 64), lt(1, 3, 64)];
        let bad = ArenaPlan { arena_bytes: 64, offsets: vec![0, 0] };
        assert_eq!(aliasing_pairs(&bad, &lts), vec![(0, 1)]);
        // Disjoint lifetimes may share the offset: no pair.
        let lts2 = vec![lt(0, 1, 64), lt(2, 3, 64)];
        assert!(aliasing_pairs(&bad, &lts2).is_empty());
    }

    #[test]
    fn escaping_tensors_not_freed() {
        let mut lts = vec![lt(0, 0, 100), lt(1, 1, 100)];
        lts[0].escapes = true;
        let b = plan_branch(&lts);
        // escape keeps slot 0 alive; second tensor needs a new slot
        assert_eq!(b.arena_bytes, 2 * 128);
    }
}
