//! Tensor liveness analysis over an execution order.
//!
//! A tensor is live from the step that produces it to the last step
//! that consumes it (§3.2 Eq. 1: reuse is safe iff lifetimes are
//! disjoint).  Weights and graph inputs (tensors with no producer) are
//! *static* memory, accounted separately from the activation arena.

use std::collections::HashMap;

use crate::graph::{Graph, NodeId, TensorId};

/// Lifetime of one activation tensor, in execution-order positions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Lifetime {
    pub tensor: TensorId,
    /// Position of the producing node in the order.
    pub def_pos: usize,
    /// Position of the last consuming node (>= def_pos).  Tensors that
    /// escape the order (consumed by nodes outside it, or graph
    /// outputs) get `escapes = true` and last_use = end of order.
    pub last_use: usize,
    pub escapes: bool,
    /// Worst-case byte size.
    pub bytes: usize,
}

/// Compute lifetimes of all tensors *produced* by nodes in `order`.
///
/// `order` is any topologically consistent execution sequence (a whole
/// graph, or a single branch's nodes).  O(|order| + edges).
pub fn analyze(g: &Graph, order: &[NodeId]) -> Vec<Lifetime> {
    let pos: HashMap<NodeId, usize> =
        order.iter().enumerate().map(|(i, &n)| (n, i)).collect();
    let mut out = Vec::new();
    for (i, &nid) in order.iter().enumerate() {
        for &t in &g.node(nid).outputs {
            let mut last = i;
            let mut escapes = g.consumers(t).is_empty(); // graph output
            for &c in g.consumers(t) {
                match pos.get(&c) {
                    Some(&p) => last = last.max(p),
                    None => escapes = true, // consumed outside this order
                }
            }
            if escapes {
                last = order.len().saturating_sub(1);
            }
            out.push(Lifetime {
                tensor: t,
                def_pos: i,
                last_use: last,
                escapes,
                bytes: g.tensor_info(t).byte_size_max(),
            });
        }
    }
    out
}

/// Peak of the running live-byte total over interval endpoints — the
/// §3.3 "linear scan" branch peak-memory estimator.  O(n log n) in the
/// number of intervals (sorting endpoints; the paper fuses this with
/// branch extraction for O(n), the constant is negligible either way).
pub fn peak_bytes(lifetimes: &[Lifetime]) -> usize {
    // +bytes at def_pos, -bytes after last_use
    let mut events: Vec<(usize, isize)> = Vec::with_capacity(lifetimes.len() * 2);
    for lt in lifetimes {
        events.push((lt.def_pos, lt.bytes as isize));
        events.push((lt.last_use + 1, -(lt.bytes as isize)));
    }
    events.sort_unstable();
    let mut cur = 0isize;
    let mut peak = 0isize;
    for (_, delta) in events {
        cur += delta;
        peak = peak.max(cur);
    }
    peak as usize
}

/// Check Eq. 1 on two lifetimes: may they share a buffer?
pub fn may_reuse(a: &Lifetime, b: &Lifetime) -> bool {
    a.last_use < b.def_pos || b.last_use < a.def_pos
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    /// chain: in -> a -> b -> c, with t_in static input
    fn chain3() -> (Graph, Vec<NodeId>) {
        let mut g = Graph::new("t");
        let t0 = g.tensor(&[4], "in"); // 16 B
        let ta = g.tensor(&[8], "a"); // 32 B
        let tb = g.tensor(&[16], "b"); // 64 B
        let tc = g.tensor(&[4], "c"); // 16 B
        let n1 = g.add_node("a", OpKind::Relu, vec![t0], vec![ta]);
        let n2 = g.add_node("b", OpKind::Relu, vec![ta], vec![tb]);
        let n3 = g.add_node("c", OpKind::Relu, vec![tb], vec![tc]);
        (g, vec![n1, n2, n3])
    }

    #[test]
    fn chain_lifetimes() {
        let (g, order) = chain3();
        let lts = analyze(&g, &order);
        assert_eq!(lts.len(), 3);
        // ta: def 0, last use 1
        assert_eq!(lts[0].def_pos, 0);
        assert_eq!(lts[0].last_use, 1);
        assert!(!lts[0].escapes);
        // tc is a graph output -> escapes
        assert!(lts[2].escapes);
    }

    #[test]
    fn chain_peak() {
        let (g, order) = chain3();
        let lts = analyze(&g, &order);
        // live sets: {ta}=32 at 0, {ta,tb}=96 at 1, {tb,tc}=80 at 2
        assert_eq!(peak_bytes(&lts), 96);
    }

    #[test]
    fn reuse_rule_is_eq1() {
        let a = Lifetime { tensor: TensorId(0), def_pos: 0, last_use: 2, escapes: false, bytes: 4 };
        let b = Lifetime { tensor: TensorId(1), def_pos: 3, last_use: 5, escapes: false, bytes: 4 };
        let c = Lifetime { tensor: TensorId(2), def_pos: 2, last_use: 3, escapes: false, bytes: 4 };
        assert!(may_reuse(&a, &b));
        assert!(!may_reuse(&a, &c));
        assert!(!may_reuse(&b, &c));
    }

    #[test]
    fn partial_order_marks_escapes() {
        let (g, order) = chain3();
        // analyze only the first two nodes: tb is consumed by c outside
        let lts = analyze(&g, &order[..2]);
        assert_eq!(lts.len(), 2);
        assert!(lts[1].escapes, "tb escapes the sub-order");
    }

    #[test]
    fn empty_order() {
        let (g, _) = chain3();
        assert!(analyze(&g, &[]).is_empty());
        assert_eq!(peak_bytes(&[]), 0);
    }
}
