//! Serving front-end: one admission-controlled dispatcher for all
//! registered models.
//!
//! This is the "downstream user" face of the library: submit inference
//! requests, get latency-tracked responses.  Earlier revisions ran one
//! private worker loop per model — N independent queues whose memory
//! peaks could stack unchecked, exactly the §3.3 failure mode scaled up
//! to a multi-model host.  The server is now built on the process-wide
//! [`MemoryGovernor`]:
//!
//! * **Shared worker pool** — [`ServeCfg::workers`] threads drain *all*
//!   model queues, so idle capacity from a quiet model serves a busy
//!   one instead of sleeping.
//! * **Admission control** — before a batch executes, the dispatcher
//!   leases the model's registered branch-peak demand
//!   ([`Server::register_with_demand`]) from the governor and blocks
//!   while the device budget is exhausted.
//! * **Per-model fairness** — queues are drained round-robin, so a
//!   flood on one model cannot starve the others.
//! * **Micro-batching** — up to [`ServeCfg::max_batch`] queued requests
//!   for the same model execute as one admission + one
//!   [`ModelExecutor::execute_batch`] call, amortising dispatch.
//! * **Server-wide placement** — tenants registered via
//!   [`Server::register_placed`] are placed *jointly*: one shared
//!   per-lane busy-time [`LaneLedger`] accumulates every tenant's
//!   modelled lane seconds, each `register`/`drop` re-places all
//!   placed tenants against it
//!   ([`assign_with_loads`](crate::place::assign_with_loads)), and
//!   executor swaps are generation-stamped so a worker mid-batch on
//!   the old placement can never restore a stale executor.
//! * **SLO admission** — deadline-tagged requests
//!   ([`Server::submit_with_deadline`]) are admitted only when the
//!   target lane's outstanding modelled work fits the deadline;
//!   otherwise they spill to the device–edge remote lane when the
//!   model's [`SloSpec`] carries one and its queue fits the deadline
//!   ([`Outcome::Spilled`]), degrade to the bit-identical CPU-forced
//!   path, or are shed with an explicit [`Outcome`] — never silently
//!   dropped.  A persistent link fault mid-spill resolves to
//!   [`Outcome::DegradedCpu`] (see [`ModelExecutor::execute_spilled`]).
//!
//! (Offline build: no tokio — the dispatcher is std-thread + condvar
//! based, which for a single-host serving demo is equivalent.)
//!
//! # Examples
//!
//! ```
//! use parallax::serve::{FnExecutor, Server};
//!
//! let mut server = Server::new();
//! server.register("echo", Box::new(FnExecutor(|seed| Ok((0.0, seed as f64)))));
//! let resp = server.infer("echo", 7).unwrap();
//! assert_eq!(resp.model, "echo");
//! assert_eq!(resp.checksum, 7.0);
//! ```

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

use crate::sched::{LaneLedger, MemoryGovernor};
use crate::util::stats::{summarize, Summary};

/// An inference request (synthetic payload: seed for the input draw).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub model: String,
    pub seed: u64,
    /// Optional SLO deadline, seconds from submission; `None` = best
    /// effort (always admitted).
    pub deadline_s: Option<f64>,
    pub submitted: Instant,
}

/// How the dispatcher resolved a request — every request gets an
/// explicit outcome; nothing is silently dropped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Served on the model's normal (placed) path.
    Admitted,
    /// The deadline could not be met on the placed lane; served on the
    /// bit-identical CPU-forced path instead.
    DegradedCpu,
    /// The deadline could not be met on the placed lane but fit the
    /// device–edge remote lane's queue; served over the link
    /// (bit-identical outputs — the edge server runs the same host
    /// kernels).
    Spilled,
    /// The deadline is unmeetable even degraded: rejected without
    /// executing (`checksum` 0, `batched` 0).
    Shed,
    /// The model was dropped while the request was queued.
    Dropped,
}

/// A completed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub model: String,
    /// End-to-end latency (queueing + admission + execution).
    pub latency_s: f64,
    /// Execution-only time.
    pub exec_s: f64,
    /// Checksum of outputs (determinism probe).
    pub checksum: f64,
    /// Size of the micro-batch this request was served in (≥ 1; 0 for
    /// requests that never executed: [`Outcome::Shed`] /
    /// [`Outcome::Dropped`]).
    pub batched: usize,
    /// SLO admission outcome.
    pub outcome: Outcome,
}

/// Model executor trait — the server is generic over how a model runs
/// (real engine, simulator, or test stub).
pub trait ModelExecutor: Send + 'static {
    /// Run one request; returns (exec seconds, output checksum).
    fn execute(&mut self, seed: u64) -> anyhow::Result<(f64, f64)>;

    /// Run a micro-batch; the default loops [`ModelExecutor::execute`].
    /// Executors with a cheaper batched path (shared schedule, fused
    /// input tensors) override this.
    fn execute_batch(&mut self, seeds: &[u64]) -> anyhow::Result<Vec<(f64, f64)>> {
        seeds.iter().map(|&s| self.execute(s)).collect()
    }

    /// Run one request on the degraded (CPU-forced) path.  The default
    /// falls back to [`ModelExecutor::execute`]; placement-aware
    /// executors override it with a CPU-only run that is bit-identical
    /// in outputs (same host kernels, no delegate).
    fn execute_degraded(&mut self, seed: u64) -> anyhow::Result<(f64, f64)> {
        self.execute(seed)
    }

    /// Micro-batch of degraded requests; the default loops
    /// [`ModelExecutor::execute_degraded`].
    fn execute_batch_degraded(&mut self, seeds: &[u64]) -> anyhow::Result<Vec<(f64, f64)>> {
        seeds.iter().map(|&s| self.execute_degraded(s)).collect()
    }

    /// Run one request on the device–edge remote spill path.
    /// `Ok(None)` means a persistent link fault kept the request off
    /// the edge server entirely — the dispatcher then serves it via
    /// [`ModelExecutor::execute_degraded`] and answers
    /// [`Outcome::DegradedCpu`], so an injected link drop always
    /// resolves to an explicit outcome, never a silent loss.  The
    /// default has no link to fault and simply executes normally.
    fn execute_spilled(&mut self, seed: u64) -> anyhow::Result<Option<(f64, f64)>> {
        self.execute(seed).map(Some)
    }
}

/// Closure-based executor for tests and simple setups.
pub struct FnExecutor<F: FnMut(u64) -> anyhow::Result<(f64, f64)> + Send + 'static>(pub F);

impl<F: FnMut(u64) -> anyhow::Result<(f64, f64)> + Send + 'static> ModelExecutor for FnExecutor<F> {
    fn execute(&mut self, seed: u64) -> anyhow::Result<(f64, f64)> {
        (self.0)(seed)
    }
}

/// Standard synthetic input draw for simulated serving executors: the
/// request seed picks a dynamic fill in `[0.15, 1.0)` (text models see
/// mostly short inputs, occasionally full-length — §4.1's protocol).
pub fn sim_fill(seed: u64) -> f64 {
    0.15 + 0.85 * ((seed % 97) as f64 / 97.0)
}

/// Adapter from a simulated [`Pipeline`](crate::baselines::Pipeline) to
/// a registered executor: returns the pipeline's branch-peak demand
/// (what [`Server::register_with_demand`] should lease per batch) plus
/// the executor itself (exec time = simulated latency, checksum =
/// simulated energy).  Shared by the `parallax serve` CLI, the serving
/// integration tests, and the `serve_throughput` bench so all three
/// drive byte-identical workloads.
pub fn pipeline_executor(
    pipe: crate::baselines::Pipeline,
    rng_seed: u64,
) -> (u64, Box<dyn ModelExecutor>) {
    let demand = pipe.peak_branch_demand();
    let mut rng = crate::util::rng::Rng::new(rng_seed);
    let exec = Box::new(FnExecutor(move |seed| {
        let r = pipe.run(&mut rng, sim_fill(seed));
        Ok((r.latency_s, r.energy_j))
    }));
    (demand, exec)
}

/// [`pipeline_executor`] with the per-model device placement chosen at
/// register time: [`crate::place::assign`] decides which branches run
/// on which accelerator lane for this pipeline's SoC (load-balanced
/// across the profile's [`AccLane`](crate::device::AccLane)s;
/// unreachable lanes are never targets), and the returned demand is
/// the placement-aware branch-peak
/// ([`Pipeline::peak_placed_demand`](crate::baselines::Pipeline::peak_placed_demand))
/// — delegated branches lease their host-visible staging, held in
/// flight from dispatch to first-consumer merge, instead of a host
/// arena.  Returns the placement plan too so callers can log the
/// decision (`parallax serve` prints it per model, lanes included).
///
/// The placement also gates the *simulated* execution mode: when it
/// delegates nothing (e.g. a high-dispatch device rejects every
/// region), the pipeline is demoted to CPU-only simulation so charged
/// accelerator time matches the decision that sized the lease.  (The
/// simulator models delegation at `has_delegate` granularity, so a
/// placement that rejects only *some* regions still simulates all of
/// them accelerated — a known modelling coarseness, not a lease bug.)
pub fn placed_pipeline_executor(
    mut pipe: crate::baselines::Pipeline,
    rng_seed: u64,
) -> (crate::place::PlacementPlan, u64, Box<dyn ModelExecutor>) {
    let placement = crate::place::assign(
        &pipe.graph,
        &pipe.partition,
        &pipe.plan,
        &pipe.soc,
        crate::place::PlacePolicy::Auto,
    );
    if placement.num_delegated() == 0 {
        pipe.mode = crate::sim::Mode::CpuOnly;
    }
    let demand = pipe.peak_placed_demand(&placement);
    let mut rng = crate::util::rng::Rng::new(rng_seed);
    let exec = Box::new(FnExecutor(move |seed| {
        let r = pipe.run(&mut rng, sim_fill(seed));
        Ok((r.latency_s, r.energy_j))
    }));
    (placement, demand, exec)
}

/// Fill buckets the resolved-demand table is precomputed for.
const DEMAND_BUCKETS: [f64; 4] = [0.25, 0.5, 0.75, 1.0];

/// [`pipeline_executor`] for *dynamic* models (§3.4): instead of one
/// worst-case demand, the per-batch lease follows the request's
/// resolved shapes.  Demands are precomputed per fill bucket from
/// [`crate::ctrl::resolved_branch_memories`], so a mostly-short-input
/// stream leases far less than the max-shape plan and the governor
/// admits more concurrent batches.  Register the returned function via
/// [`Server::register_with_demand_fn`].
pub fn resolved_pipeline_executor(
    pipe: crate::baselines::Pipeline,
    rng_seed: u64,
) -> (Box<dyn Fn(u64) -> u64 + Send + Sync>, Box<dyn ModelExecutor>) {
    let table: Vec<u64> = DEMAND_BUCKETS
        .iter()
        .map(|&fill| {
            let env = crate::ctrl::ShapeEnv::from_fill(&pipe.graph, fill);
            let mems = crate::ctrl::resolved_branch_memories(
                &pipe.graph,
                &pipe.partition,
                &pipe.plan,
                &env,
                &pipe.mems,
            );
            crate::baselines::Pipeline::peak_layer_demand(&pipe.plan, &mems)
        })
        .collect();
    let demand_fn = Box::new(move |seed: u64| {
        let fill = sim_fill(seed);
        let idx = DEMAND_BUCKETS
            .iter()
            .position(|&b| fill <= b)
            .unwrap_or(DEMAND_BUCKETS.len() - 1);
        table[idx]
    });
    let mut rng = crate::util::rng::Rng::new(rng_seed);
    let exec = Box::new(FnExecutor(move |seed| {
        let r = pipe.run(&mut rng, sim_fill(seed));
        Ok((r.latency_s, r.energy_j))
    }));
    (demand_fn, exec)
}

/// Real-engine executor built on the capture/replay hot path
/// ([`crate::exec::CapturedPlan`]): partition → branch plan → schedule
/// once at registration, [`Engine::capture`](crate::exec::Engine::capture)
/// the whole thing, and serve every request by replaying the captured
/// plan — no per-request planning, no arena/map rebuilds, shared-`Arc`
/// reads.  Fails if the model cannot be captured standalone (dynamic
/// shapes or PJRT blocks need the engine at replay; register those via
/// [`pipeline_executor`] / [`resolved_pipeline_executor`] instead).
///
/// Returns the demand to lease per batch — the captured plan's own
/// [`peak_demand`](crate::exec::CapturedPlan::peak_demand), i.e.
/// exactly the largest lease a replay will request — plus the
/// executor.  Exec time is measured replay wall time; the checksum is
/// the replayed output store's, so serving results are bit-comparable
/// with a fresh engine run of the same schedules.
pub fn captured_executor(
    g: &crate::graph::Graph,
    p: &crate::partition::Partition,
    plan: &crate::branch::BranchPlan,
    cfg: &crate::sched::SchedCfg,
    budget: u64,
) -> anyhow::Result<(u64, Box<dyn ModelExecutor>)> {
    let mems = crate::memory::branch_memories(g, p, plan);
    let schedules = crate::sched::schedule(plan, &mems, budget, cfg);
    let engine = crate::exec::Engine::new(g, p, plan, None);
    let captured = engine.capture(&schedules, &crate::ctrl::ShapeEnv::unresolved(), None);
    anyhow::ensure!(
        captured.is_standalone(),
        "model '{}' cannot be captured standalone (dynamic shapes or \
         PJRT blocks) — register an engine-backed executor instead",
        g.name
    );
    let demand = captured.peak_demand();
    let weights = crate::exec::WeightBank::default();
    let exec = Box::new(FnExecutor(move |_seed| {
        let values = crate::exec::Values::default();
        let stats = captured.replay(&values, &weights)?;
        Ok((stats.wall_s, values.checksum()))
    }));
    Ok((demand, exec))
}

/// Modelled per-request service figures SLO admission compares a
/// request's deadline against.  Derived automatically for tenants
/// registered via [`Server::register_placed`]; tests and custom
/// executors can pin exact figures with [`Server::register_with_slo`].
#[derive(Clone, Copy, Debug)]
pub struct SloSpec {
    /// The busiest lane the model's placement targets (`None` = the
    /// model runs CPU-only; no lane queueing applies).
    pub lane: Option<usize>,
    /// Modelled service seconds one request occupies that lane for.
    pub lane_service_s: f64,
    /// Modelled service seconds of the degraded CPU-forced path.
    pub cpu_service_s: f64,
    /// Device–edge spill option: `(remote lane index, modelled remote
    /// service seconds)`.  A deadline the local lane misses tries this
    /// lane's queue before degrading or shedding ([`Outcome::Spilled`]);
    /// `None` disables spilling for this model.
    pub remote: Option<(usize, f64)>,
}

impl SloSpec {
    /// Figures from a placement: the lane is the plan's busiest, its
    /// service the modelled busy seconds the plan puts there, and the
    /// CPU service the serial sum of the modelled per-branch CPU
    /// latencies (worst case: no intra-request parallelism assumed).
    pub fn from_placement(placement: &crate::place::PlacementPlan, lanes: usize) -> Self {
        let busy = placement.lane_busy_s(lanes);
        let lane = busy
            .iter()
            .enumerate()
            .filter(|&(_, &b)| b > 0.0)
            .max_by(|a, b| a.1.partial_cmp(b.1).expect("busy times are finite"))
            .map(|(l, _)| l);
        Self {
            lane,
            lane_service_s: lane.map(|l| busy[l]).unwrap_or(0.0),
            cpu_service_s: placement.cpu_latency_s.iter().sum(),
            remote: None,
        }
    }

    /// This spec with a device–edge spill option: requests whose
    /// deadline the local lane misses may fall back to remote `lane`
    /// at `service_s` modelled seconds before degrading (see
    /// [`Server::submit_with_deadline`]).
    pub fn with_remote(mut self, lane: usize, service_s: f64) -> Self {
        self.remote = Some((lane, service_s));
        self
    }
}

/// Rebuild recipe for a [`Server::register_placed`] tenant: joint
/// re-placement swaps executors, so the pipeline lives behind an `Arc`
/// the fresh executor clones instead of re-building the model.
struct PlacedSpec {
    pipe: crate::baselines::Pipeline,
    rng_seed: u64,
}

/// A placed tenant's current decision + its rebuild recipe.
struct PlacedState {
    spec: Arc<PlacedSpec>,
    placement: crate::place::PlacementPlan,
}

/// Simulated executor for a placed tenant: the normal path prices the
/// placement's mode, the degraded path re-prices the same request
/// CPU-only (the simulator's analogue of the engine's bit-identical
/// CPU-forced run).
struct PlacedSimExecutor {
    spec: Arc<PlacedSpec>,
    mode: crate::sim::Mode,
    rng: crate::util::rng::Rng,
}

impl ModelExecutor for PlacedSimExecutor {
    fn execute(&mut self, seed: u64) -> anyhow::Result<(f64, f64)> {
        let r = self.spec.pipe.run_with_mode(&mut self.rng, sim_fill(seed), self.mode);
        Ok((r.latency_s, r.energy_j))
    }

    fn execute_degraded(&mut self, seed: u64) -> anyhow::Result<(f64, f64)> {
        let r = self.spec.pipe.run_with_mode(
            &mut self.rng,
            sim_fill(seed),
            crate::sim::Mode::CpuOnly,
        );
        Ok((r.latency_s, r.energy_j))
    }
}

/// Real-engine executor with an explicit degraded path, for serving
/// bit-identity tests: the normal path runs the placement via
/// [`Engine::run_placed`](crate::exec::Engine::run_placed), the
/// degraded path runs the same schedules CPU-forced via
/// [`Engine::run_cpu_forced`](crate::exec::Engine::run_cpu_forced).
/// Both synthesize identical inputs, so the checksums must agree bit
/// for bit — the unreachable-lane placement property lifted to the
/// serving layer.
pub struct PlacedEngineExecutor {
    g: crate::graph::Graph,
    p: crate::partition::Partition,
    plan: crate::branch::BranchPlan,
    schedules: Vec<crate::sched::LayerSchedule>,
    placement: crate::place::PlacementPlan,
    /// Device–edge spill path: per-lane remote flags, link-fault
    /// model, and the spill placement (delegate-safe branches on the
    /// remote lane).  `None` = no remote tier; `execute_spilled`
    /// falls back to the normal path.
    remote: Option<(Vec<bool>, crate::device::LinkModel, crate::place::PlacementPlan)>,
}

impl PlacedEngineExecutor {
    pub fn new(
        g: crate::graph::Graph,
        p: crate::partition::Partition,
        plan: crate::branch::BranchPlan,
        schedules: Vec<crate::sched::LayerSchedule>,
        placement: crate::place::PlacementPlan,
    ) -> Self {
        Self { g, p, plan, schedules, placement, remote: None }
    }

    /// This executor with a device–edge spill path:
    /// [`ModelExecutor::execute_spilled`] runs `spill` — a placement
    /// onto the remote lane — under `link`, with the link seed mixed
    /// with the request seed so per-request fault outcomes are
    /// deterministic yet independent.  A request whose every transfer
    /// faults persistently reports `Ok(None)` and is re-served on the
    /// degraded CPU path by the dispatcher.
    pub fn with_remote(
        mut self,
        remote_lanes: Vec<bool>,
        link: crate::device::LinkModel,
        spill: crate::place::PlacementPlan,
    ) -> Self {
        self.remote = Some((remote_lanes, link, spill));
        self
    }
}

impl ModelExecutor for PlacedEngineExecutor {
    fn execute(&mut self, _seed: u64) -> anyhow::Result<(f64, f64)> {
        let t0 = Instant::now();
        let engine = crate::exec::Engine::new(&self.g, &self.p, &self.plan, None);
        let (values, _) = engine.run_placed(&self.schedules, &self.placement, None)?;
        Ok((t0.elapsed().as_secs_f64(), values.checksum()))
    }

    fn execute_degraded(&mut self, _seed: u64) -> anyhow::Result<(f64, f64)> {
        let t0 = Instant::now();
        let engine = crate::exec::Engine::new(&self.g, &self.p, &self.plan, None);
        let (values, _) = engine.run_cpu_forced(&self.schedules)?;
        Ok((t0.elapsed().as_secs_f64(), values.checksum()))
    }

    fn execute_spilled(&mut self, seed: u64) -> anyhow::Result<Option<(f64, f64)>> {
        let Some((lanes, link, spill)) = &self.remote else {
            return self.execute(seed).map(Some);
        };
        let t0 = Instant::now();
        let mut engine = crate::exec::Engine::new(&self.g, &self.p, &self.plan, None);
        // mix the request seed into the link seed: each request rolls
        // an independent — still deterministic — fault schedule
        let link = crate::device::LinkModel { seed: link.seed ^ seed, ..link.clone() };
        engine.set_remote(lanes.clone(), link);
        let (values, stats) = engine.run_placed(&self.schedules, spill, None)?;
        if stats.delegate_jobs == 0 && spill.num_delegated() > 0 {
            // total link outage: every transfer faulted persistently
            // and the run already fell back branch-by-branch to the
            // bit-identical CPU path — report the request as degraded
            // service, not remote
            return Ok(None);
        }
        Ok(Some((t0.elapsed().as_secs_f64(), values.checksum())))
    }
}

/// Dispatcher tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeCfg {
    /// Shared worker threads draining all model queues.
    pub workers: usize,
    /// Max requests of one model served under a single admission.
    pub max_batch: usize,
}

impl Default for ServeCfg {
    fn default() -> Self {
        Self { workers: 4, max_batch: 8 }
    }
}

struct QueuedJob {
    req: Request,
    reply: mpsc::Sender<anyhow::Result<Response>>,
    /// Serve on the CPU-forced path (deadline-degraded admission).
    degraded: bool,
    /// Serve on the device–edge remote spill path
    /// ([`ModelExecutor::execute_spilled`]); `lane_service` then holds
    /// the remote lane's ledger charge.
    spilled: bool,
    /// `(lane, modelled service seconds)` charged to the lane ledger
    /// at admission; popped when the batch completes or the queue is
    /// drained, so a drained server's outstanding time reads zero.
    lane_service: Option<(usize, f64)>,
}

/// How a model's per-batch lease is sized.
enum Demand {
    /// One worst-case figure (static models).
    Fixed(u64),
    /// Computed per request seed (dynamic models: the lease follows the
    /// resolved shapes); a batch leases the max over its member seeds.
    PerSeed(Box<dyn Fn(u64) -> u64 + Send + Sync>),
}

struct ModelEntry {
    name: String,
    /// Branch-peak bytes leased from the governor per in-flight batch.
    /// Shared so workers can evaluate per-seed demand functions *off*
    /// the dispatcher lock (a slow or re-entrant demand fn must never
    /// stall queue routing).
    demand: Arc<Demand>,
    /// `None` while a worker is executing this model's batch — models
    /// stay internally sequential (executors are stateful `FnMut`).
    exec: Option<Box<dyn ModelExecutor>>,
    queue: VecDeque<QueuedJob>,
    /// Set when the executor panicked: the model is disabled (new
    /// submissions are rejected, queued ones get errors) but the
    /// dispatcher and every other model keep running.
    poisoned: bool,
    /// Swap stamp: bumped whenever a joint re-placement (or a drop)
    /// installs or retires this model's executor.  A worker records
    /// the stamp when it takes the executor and only restores it if
    /// the stamp is unchanged, so a stale executor can never serve
    /// post-swap traffic — the generation idiom the segmented engine's
    /// thermal re-placement uses for its plan cache.
    generation: u64,
    /// Dropped models keep their slot (worker slot indices stay
    /// stable) but reject submissions and hold no executor or queue.
    dropped: bool,
    /// Modelled figures for SLO admission (placed or pinned); `None`
    /// disables deadline handling for this model.
    slo: Option<SloSpec>,
    /// Present for [`Server::register_placed`] tenants: current
    /// placement + the recipe joint re-placement rebuilds it from.
    placed: Option<PlacedState>,
}

struct Dispatch {
    models: Vec<ModelEntry>,
    index: HashMap<String, usize>,
    /// Round-robin cursor: the next scan starts after the last model
    /// that got service.
    rr: usize,
    shutdown: bool,
}

struct Inner {
    governor: Arc<MemoryGovernor>,
    /// Shared per-lane busy-time ledger: static tenant loads for joint
    /// placement + outstanding admitted service for SLO admission.
    /// Lock order is always dispatcher state → ledger, never reversed.
    ledger: Arc<LaneLedger>,
    cfg: ServeCfg,
    state: Mutex<Dispatch>,
    work: Condvar,
}

/// The server: a governed multi-model dispatcher (see module docs).
pub struct Server {
    inner: Arc<Inner>,
    joins: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Server {
    /// Server with default knobs and an unlimited governor — the
    /// single-model-at-a-time developer path.
    pub fn new() -> Self {
        Self::with_config(ServeCfg::default(), Arc::new(MemoryGovernor::unlimited()))
    }

    /// Server whose admissions are governed by a shared device ledger.
    pub fn with_governor(governor: Arc<MemoryGovernor>) -> Self {
        Self::with_config(ServeCfg::default(), governor)
    }

    /// Fully configured server.
    pub fn with_config(cfg: ServeCfg, governor: Arc<MemoryGovernor>) -> Self {
        let inner = Arc::new(Inner {
            governor,
            ledger: Arc::new(LaneLedger::new(0)),
            cfg,
            state: Mutex::new(Dispatch {
                models: Vec::new(),
                index: HashMap::new(),
                rr: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
        });
        let joins = (0..cfg.workers.max(1))
            .map(|i| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn serve worker")
            })
            .collect();
        Self { inner, joins, next_id: AtomicU64::new(0) }
    }

    /// The shared ledger this server admits against.
    pub fn governor(&self) -> &Arc<MemoryGovernor> {
        &self.inner.governor
    }

    /// The shared per-lane busy-time ledger (placement + admission).
    pub fn lane_ledger(&self) -> &Arc<LaneLedger> {
        &self.inner.ledger
    }

    /// Register a model with zero declared memory demand (stub/test
    /// executors that hold no branch arenas).
    pub fn register(&mut self, model: &str, exec: Box<dyn ModelExecutor>) {
        self.register_with_demand(model, 0, exec);
    }

    /// Register a model, declaring the branch-peak bytes one in-flight
    /// batch reserves (see `Pipeline::peak_branch_demand`); the
    /// dispatcher leases exactly this from the governor per batch.
    pub fn register_with_demand(
        &mut self,
        model: &str,
        demand_bytes: u64,
        exec: Box<dyn ModelExecutor>,
    ) {
        self.register_entry(model, Demand::Fixed(demand_bytes), None, exec);
    }

    /// Register a *dynamic* model (§3.4): the per-batch lease is
    /// computed from the request seeds at dispatch time (a batch leases
    /// the max demand over its members), so short inputs reserve their
    /// resolved footprint rather than the worst case.  Pair with
    /// [`resolved_pipeline_executor`].
    pub fn register_with_demand_fn(
        &mut self,
        model: &str,
        demand: Box<dyn Fn(u64) -> u64 + Send + Sync>,
        exec: Box<dyn ModelExecutor>,
    ) {
        self.register_entry(model, Demand::PerSeed(demand), None, exec);
    }

    /// Register a model with pinned SLO figures — deadline-tagged
    /// submissions for this model go through admission against the
    /// shared lane ledger using exactly these modelled service times.
    /// The deterministic deadline tests use this to pin arithmetic.
    pub fn register_with_slo(
        &mut self,
        model: &str,
        demand_bytes: u64,
        slo: SloSpec,
        exec: Box<dyn ModelExecutor>,
    ) {
        self.register_entry(model, Demand::Fixed(demand_bytes), Some(slo), exec);
    }

    fn register_entry(
        &mut self,
        model: &str,
        demand: Demand,
        slo: Option<SloSpec>,
        exec: Box<dyn ModelExecutor>,
    ) {
        let mut st = self.inner.state.lock().unwrap();
        let slot = st.models.len();
        st.models.push(ModelEntry {
            name: model.to_string(),
            demand: Arc::new(demand),
            exec: Some(exec),
            queue: VecDeque::new(),
            poisoned: false,
            generation: 0,
            dropped: false,
            slo,
            placed: None,
        });
        st.index.insert(model.to_string(), slot);
        drop(st);
        self.inner.work.notify_all();
    }

    /// Register a simulated pipeline as a *server-placed* tenant: the
    /// server, not the caller, decides its lane placement — jointly
    /// with every other placed tenant, against the shared
    /// [`LaneLedger`]'s accumulated loads — and re-decides on every
    /// later placed `register`/[`Server::drop_model`].  Executor swaps
    /// are generation-stamped, so in-flight batches on the old
    /// placement finish and their stale executor is retired, never
    /// restored.  Returns this tenant's placement as decided right now
    /// (later registrations may move it; see [`Server::placements`]).
    pub fn register_placed(
        &mut self,
        model: &str,
        pipe: crate::baselines::Pipeline,
        rng_seed: u64,
    ) -> crate::place::PlacementPlan {
        let branches = pipe.plan.branches.len();
        let spec = Arc::new(PlacedSpec { pipe, rng_seed });
        let mut st = self.inner.state.lock().unwrap();
        let slot = st.models.len();
        st.models.push(ModelEntry {
            name: model.to_string(),
            demand: Arc::new(Demand::Fixed(0)),
            exec: None,
            queue: VecDeque::new(),
            poisoned: false,
            generation: 0,
            dropped: false,
            slo: None,
            placed: Some(PlacedState {
                spec,
                placement: crate::place::PlacementPlan::cpu_only(branches),
            }),
        });
        st.index.insert(model.to_string(), slot);
        replace_all(&mut st, &self.inner.ledger);
        let placement = st.models[slot]
            .placed
            .as_ref()
            .expect("just registered placed")
            .placement
            .clone();
        drop(st);
        self.inner.work.notify_all();
        placement
    }

    /// Drop a model: its queued requests are answered with
    /// [`Outcome::Dropped`] (never silently lost), its slot stays (so
    /// worker indices and submit errors stay stable), and every placed
    /// tenant is jointly re-placed over the lane time the drop freed.
    pub fn drop_model(&self, model: &str) -> anyhow::Result<()> {
        let mut st = self.inner.state.lock().unwrap();
        let &slot = st
            .index
            .get(model)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
        if st.models[slot].dropped {
            anyhow::bail!("model {model} was dropped");
        }
        st.models[slot].dropped = true;
        st.models[slot].generation += 1;
        // a worker mid-batch holds the old executor; the bumped stamp
        // makes it retire that executor instead of restoring it
        let exec = st.models[slot].exec.take();
        let drained: Vec<QueuedJob> = st.models[slot].queue.drain(..).collect();
        for job in &drained {
            if let Some((lane, svc)) = job.lane_service {
                self.inner.ledger.complete(lane, svc);
            }
        }
        replace_all(&mut st, &self.inner.ledger);
        drop(st);
        drop(exec);
        for job in drained {
            let _ = job.reply.send(Ok(Response {
                id: job.req.id,
                model: model.to_string(),
                latency_s: job.req.submitted.elapsed().as_secs_f64(),
                exec_s: 0.0,
                checksum: 0.0,
                batched: 0,
                outcome: Outcome::Dropped,
            }));
        }
        self.inner.work.notify_all();
        Ok(())
    }

    /// Current placements of the live server-placed tenants, in
    /// registration order.
    pub fn placements(&self) -> Vec<(String, crate::place::PlacementPlan)> {
        let st = self.inner.state.lock().unwrap();
        st.models
            .iter()
            .filter(|m| !m.dropped)
            .filter_map(|m| {
                m.placed.as_ref().map(|p| (m.name.clone(), p.placement.clone()))
            })
            .collect()
    }

    /// Registered, not-dropped model names in registration (fairness-
    /// ring) order.
    pub fn models(&self) -> Vec<String> {
        let st = self.inner.state.lock().unwrap();
        st.models
            .iter()
            .filter(|m| !m.dropped)
            .map(|m| m.name.clone())
            .collect()
    }

    /// Whether `model` was registered and then dropped.
    fn is_dropped(&self, model: &str) -> bool {
        let st = self.inner.state.lock().unwrap();
        st.index
            .get(model)
            .map_or(false, |&slot| st.models[slot].dropped)
    }

    /// Submit a best-effort request (no deadline; always admitted).
    pub fn submit(
        &self,
        model: &str,
        seed: u64,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<Response>>> {
        self.submit_with_deadline(model, seed, None)
    }

    /// Submit a request, optionally deadline-tagged.  Admission runs
    /// under the dispatcher lock, in submission order, against the
    /// shared lane ledger:
    ///
    /// * the lane's outstanding modelled work plus this request's lane
    ///   service fits the deadline → **admitted** on the placed path;
    /// * it doesn't, but the [`SloSpec::remote`] lane's outstanding
    ///   work plus the remote service does → **spilled** to the
    ///   device–edge lane ([`Outcome::Spilled`], bit-identical output;
    ///   the remote charge goes on the same shared ledger);
    /// * that misses too (or no remote lane), but the degraded
    ///   CPU-forced service fits → **degraded**
    ///   ([`Outcome::DegradedCpu`], bit-identical output);
    /// * even that misses → **shed** immediately: the receiver gets a
    ///   [`Outcome::Shed`] response without executing.
    ///
    /// Models without an [`SloSpec`] ignore deadlines (always admit).
    pub fn submit_with_deadline(
        &self,
        model: &str,
        seed: u64,
        deadline_s: Option<f64>,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<Response>>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        let mut st = self.inner.state.lock().unwrap();
        let &slot = st
            .index
            .get(model)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
        if st.models[slot].dropped {
            anyhow::bail!("model {model} was dropped");
        }
        if st.models[slot].poisoned {
            anyhow::bail!("model {model} disabled: its executor panicked");
        }
        let mut degraded = false;
        let mut spilled = false;
        let mut lane_service = None;
        if let Some(slo) = st.models[slot].slo {
            // the device–edge escape hatch both deadline arms share: a
            // deadline the local path misses tries the remote lane's
            // queue before degrading or shedding
            let try_remote = |d: f64| {
                slo.remote.filter(|&(rl, rs)| {
                    self.inner.ledger.outstanding(rl) + rs <= d
                })
            };
            match (deadline_s, slo.lane) {
                (Some(d), Some(lane)) => {
                    let eta = self.inner.ledger.outstanding(lane) + slo.lane_service_s;
                    if eta <= d {
                        lane_service = Some((lane, slo.lane_service_s));
                    } else if let Some((rl, rs)) = try_remote(d) {
                        lane_service = Some((rl, rs));
                        spilled = true;
                    } else if slo.cpu_service_s <= d {
                        degraded = true;
                    } else {
                        drop(st);
                        let _ = reply.send(Ok(shed_response(id, model)));
                        return Ok(rx);
                    }
                }
                (Some(d), None) => {
                    // CPU-only tenant: no lane queue, but an unmeetable
                    // deadline tries the remote lane, then is shed
                    // rather than broken silently
                    if slo.cpu_service_s > d {
                        if let Some((rl, rs)) = try_remote(d) {
                            lane_service = Some((rl, rs));
                            spilled = true;
                        } else {
                            drop(st);
                            let _ = reply.send(Ok(shed_response(id, model)));
                            return Ok(rx);
                        }
                    }
                }
                (None, Some(lane)) => {
                    // best-effort requests still occupy the lane, so
                    // later deadline-tagged ones see honest queueing
                    lane_service = Some((lane, slo.lane_service_s));
                }
                (None, None) => {}
            }
        }
        if let Some((lane, svc)) = lane_service {
            self.inner.ledger.admit(lane, svc);
        }
        st.models[slot].queue.push_back(QueuedJob {
            req: Request {
                id,
                model: model.to_string(),
                seed,
                deadline_s,
                submitted: Instant::now(),
            },
            reply,
            degraded,
            spilled,
            lane_service,
        });
        drop(st);
        self.inner.work.notify_one();
        Ok(rx)
    }

    /// Submit and wait.
    pub fn infer(&self, model: &str, seed: u64) -> anyhow::Result<Response> {
        let rx = self.submit(model, seed)?;
        rx.recv().map_err(|_| anyhow::anyhow!("dispatcher dropped reply"))?
    }

    /// Deadline-tagged submit-and-wait.
    pub fn infer_with_deadline(
        &self,
        model: &str,
        seed: u64,
        deadline_s: f64,
    ) -> anyhow::Result<Response> {
        let rx = self.submit_with_deadline(model, seed, Some(deadline_s))?;
        rx.recv().map_err(|_| anyhow::anyhow!("dispatcher dropped reply"))?
    }

    /// Run a closed-loop load: `n` requests round-robin over `models`,
    /// `concurrency` in flight.  Returns per-model latency summaries +
    /// total throughput (req/s).
    pub fn run_load(
        &self,
        models: &[&str],
        n: usize,
        concurrency: usize,
        seed: u64,
    ) -> anyhow::Result<LoadReport> {
        self.run_load_slo(models, n, concurrency, seed, None)
    }

    /// [`Server::run_load`] with every request deadline-tagged.  The
    /// rotation skips models dropped mid-run (their slots are counted
    /// in [`LoadReport::skipped`], not retried elsewhere) — a name that
    /// was *never* registered is still a caller error.
    pub fn run_load_slo(
        &self,
        models: &[&str],
        n: usize,
        concurrency: usize,
        seed: u64,
        deadline_s: Option<f64>,
    ) -> anyhow::Result<LoadReport> {
        let t0 = Instant::now();
        let mut pending: Vec<(String, mpsc::Receiver<anyhow::Result<Response>>)> = Vec::new();
        let mut done: Vec<Response> = Vec::new();
        let mut skipped = 0usize;
        for i in 0..n {
            let model = models[i % models.len()];
            match self.submit_with_deadline(model, seed ^ i as u64, deadline_s) {
                Ok(rx) => pending.push((model.to_string(), rx)),
                // dropped tenants leave stale rotation slots behind;
                // skip them instead of failing the whole load
                Err(_) if self.is_dropped(model) => {
                    skipped += 1;
                    continue;
                }
                Err(e) => return Err(e),
            }
            if pending.len() >= concurrency {
                let (_, rx) = pending.remove(0);
                done.push(rx.recv().map_err(|_| anyhow::anyhow!("dispatcher died"))??);
            }
        }
        for (_, rx) in pending {
            done.push(rx.recv().map_err(|_| anyhow::anyhow!("dispatcher died"))??);
        }
        let wall = t0.elapsed().as_secs_f64();
        let mut by_model: HashMap<String, Vec<f64>> = HashMap::new();
        let (mut admitted, mut degraded, mut shed, mut dropped, mut spilled) =
            (0, 0, 0, 0, 0);
        for r in &done {
            match r.outcome {
                Outcome::Admitted => admitted += 1,
                Outcome::DegradedCpu => degraded += 1,
                Outcome::Spilled => spilled += 1,
                Outcome::Shed => shed += 1,
                Outcome::Dropped => dropped += 1,
            }
            if matches!(
                r.outcome,
                Outcome::Admitted | Outcome::DegradedCpu | Outcome::Spilled
            ) {
                by_model.entry(r.model.clone()).or_default().push(r.latency_s);
            }
        }
        Ok(LoadReport {
            wall_s: wall,
            throughput_rps: done.len() as f64 / wall,
            latency: by_model
                .into_iter()
                .map(|(m, xs)| (m, summarize(&xs).unwrap()))
                .collect(),
            peak_reserved_bytes: self.inner.governor.peak_reserved(),
            admitted,
            degraded,
            shed,
            dropped,
            skipped,
            spilled,
            responses: done,
        })
    }
}

/// The response a shed request's receiver gets: explicit, immediate,
/// never executed.
fn shed_response(id: u64, model: &str) -> Response {
    Response {
        id,
        model: model.to_string(),
        latency_s: 0.0,
        exec_s: 0.0,
        checksum: 0.0,
        batched: 0,
        outcome: Outcome::Shed,
    }
}

/// Joint re-placement over every live server-placed tenant, in
/// registration order: rebuild the shared ledger's static lane loads
/// from scratch, feeding each tenant's `assign_with_loads` call the
/// loads the previous tenants accumulated.  Swaps in a fresh executor
/// (generation-stamped) and refreshes the tenant's lease demand + SLO
/// figures to match the new placement.  Caller holds the state lock.
fn replace_all(st: &mut Dispatch, ledger: &LaneLedger) {
    ledger.reset_static();
    for slot in 0..st.models.len() {
        if st.models[slot].dropped || st.models[slot].placed.is_none() {
            continue;
        }
        let spec = st.models[slot].placed.as_ref().expect("checked above").spec.clone();
        let pipe = &spec.pipe;
        let placement = crate::place::assign_with_loads(
            &pipe.graph,
            &pipe.partition,
            &pipe.plan,
            &pipe.soc,
            crate::place::PlacePolicy::Auto,
            &ledger.static_loads(),
        );
        ledger.add_static(&placement.lane_busy_s(pipe.soc.lanes.len()));
        let demand = pipe.peak_placed_demand(&placement);
        let mut slo = SloSpec::from_placement(&placement, pipe.soc.lanes.len());
        // tenants on a remote-capable SoC get the device–edge spill
        // option: remote service = modelled serial latency of every
        // delegate-safe branch over the link (Appendix-B closed form
        // on the remote lane's terms)
        if let Some(rl) = pipe.soc.remote_lane() {
            if slo.lane != Some(rl) {
                let svc: f64 = (0..pipe.plan.branches.len())
                    .map(|b| {
                        crate::place::lane_delegate_latency(
                            &pipe.graph,
                            &pipe.partition,
                            &pipe.plan,
                            b,
                            &pipe.soc,
                            &pipe.soc.lanes[rl],
                        )
                    })
                    .filter(|l| l.is_finite())
                    .sum();
                if svc > 0.0 {
                    slo = slo.with_remote(rl, svc);
                }
            }
        }
        let mode = if placement.num_delegated() == 0 {
            crate::sim::Mode::CpuOnly
        } else {
            pipe.mode
        };
        let exec: Box<dyn ModelExecutor> = Box::new(PlacedSimExecutor {
            spec: spec.clone(),
            mode,
            rng: crate::util::rng::Rng::new(spec.rng_seed),
        });
        let entry = &mut st.models[slot];
        entry.demand = Arc::new(Demand::Fixed(demand));
        entry.slo = Some(slo);
        entry.exec = Some(exec);
        entry.generation += 1;
        entry.placed.as_mut().expect("checked above").placement = placement;
    }
}

impl Default for Server {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
        }
        self.inner.work.notify_all();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// One shared dispatcher worker: scan queues round-robin, claim the
/// model's executor, lease memory, run the batch, reply.
///
/// Shutdown is graceful: workers keep draining queued requests and only
/// exit once every queue is empty, so work accepted before
/// [`Server::drop`] still completes.  A panicking executor poisons its
/// model (queued + future requests error out) without taking the
/// worker, the other models, or the process down.
fn worker_loop(inner: &Inner) {
    let mut st = inner.state.lock().unwrap();
    loop {
        if st.shutdown && st.models.iter().all(|m| m.queue.is_empty()) {
            // chain-wake siblings parked before shutdown was flagged
            inner.work.notify_all();
            return;
        }
        // round-robin scan for a model with queued work AND an
        // available executor (models stay internally sequential)
        let n = st.models.len();
        let mut pick = None;
        for k in 0..n {
            let i = (st.rr + k) % n;
            if !st.models[i].queue.is_empty() && st.models[i].exec.is_some() {
                pick = Some(i);
                break;
            }
        }
        let Some(slot) = pick else {
            st = inner.work.wait(st).unwrap();
            continue;
        };
        st.rr = (slot + 1) % n.max(1);
        let mut exec = st.models[slot].exec.take().expect("picked available executor");
        // stamp recorded at take: a joint re-placement or drop bumps it,
        // and this worker then retires the stale executor on return
        let gen = st.models[slot].generation;
        let mut jobs: Vec<QueuedJob> = Vec::new();
        while jobs.len() < inner.cfg.max_batch.max(1) {
            // degraded (CPU-forced), spilled (remote) and normal
            // requests never share a batch: one execute call serves
            // one path
            if let Some(first) = jobs.first() {
                match st.models[slot].queue.front() {
                    Some(next)
                        if (next.degraded, next.spilled)
                            == (first.degraded, first.spilled) => {}
                    _ => break,
                }
            }
            match st.models[slot].queue.pop_front() {
                Some(j) => jobs.push(j),
                None => break,
            }
        }
        let degraded = jobs.first().map(|j| j.degraded).unwrap_or(false);
        let spilled = jobs.first().map(|j| j.spilled).unwrap_or(false);
        let demand_src = st.models[slot].demand.clone();
        let name = st.models[slot].name.clone();
        drop(st);

        // size the lease off the dispatcher lock: a user-supplied demand
        // fn may be arbitrarily slow without stalling queue routing
        let demand = match &*demand_src {
            Demand::Fixed(b) => *b,
            Demand::PerSeed(f) => jobs.iter().map(|j| f(j.req.seed)).max().unwrap_or(0),
        };

        // admission: one lease covers the whole micro-batch
        let lease = inner.governor.acquire(demand);
        let seeds: Vec<u64> = jobs.iter().map(|j| j.req.seed).collect();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if spilled {
                // remote spills execute per request: every transfer
                // rolls its own link faults, so outcomes can differ
                // within one batch.  A persistent fault (`Ok(None)`)
                // re-serves that request on the bit-identical degraded
                // CPU path — an injected drop always resolves to an
                // explicit outcome.
                seeds
                    .iter()
                    .map(|&s| match exec.execute_spilled(s)? {
                        Some((t, c)) => Ok((t, c, Outcome::Spilled)),
                        None => exec
                            .execute_degraded(s)
                            .map(|(t, c)| (t, c, Outcome::DegradedCpu)),
                    })
                    .collect::<anyhow::Result<Vec<_>>>()
            } else if degraded {
                exec.execute_batch_degraded(&seeds).map(|rs| {
                    rs.into_iter().map(|(t, c)| (t, c, Outcome::DegradedCpu)).collect()
                })
            } else {
                exec.execute_batch(&seeds).map(|rs| {
                    rs.into_iter().map(|(t, c)| (t, c, Outcome::Admitted)).collect()
                })
            }
        }));
        // memory is free before anyone can observe the response
        drop(lease);

        // pop the batch's admitted lane charges: whatever the executor
        // did, these requests no longer occupy the lane
        for job in &jobs {
            if let Some((lane, svc)) = job.lane_service {
                inner.ledger.complete(lane, svc);
            }
        }

        let batch = jobs.len();
        let mut poisoned = false;
        match outcome {
            Ok(Ok(results)) if results.len() == jobs.len() => {
                for (job, (exec_s, checksum, served)) in jobs.into_iter().zip(results) {
                    let resp = Response {
                        id: job.req.id,
                        model: name.clone(),
                        latency_s: job.req.submitted.elapsed().as_secs_f64(),
                        exec_s,
                        checksum,
                        batched: batch,
                        outcome: served,
                    };
                    let _ = job.reply.send(Ok(resp));
                }
            }
            Ok(Ok(results)) => {
                let msg = format!(
                    "{name}: executor returned {} results for a batch of {batch}",
                    results.len()
                );
                for job in jobs {
                    let _ = job.reply.send(Err(anyhow::anyhow!("{msg}")));
                }
            }
            Ok(Err(e)) => {
                let msg = e.to_string();
                for job in jobs {
                    let _ = job.reply.send(Err(anyhow::anyhow!("{name}: {msg}")));
                }
            }
            Err(panic) => {
                poisoned = true;
                let msg = panic
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                for job in jobs {
                    let _ = job
                        .reply
                        .send(Err(anyhow::anyhow!("{name}: executor panicked: {msg}")));
                }
            }
        }

        if poisoned {
            // the executor's state is unknown: retire it (off-lock, in
            // case its Drop misbehaves too), disable the model, and
            // fail whatever was already queued for it — unless a swap
            // already installed a fresh executor (stamp moved on), in
            // which case the panic died with the old generation
            drop(exec);
            st = inner.state.lock().unwrap();
            if st.models[slot].generation == gen {
                st.models[slot].poisoned = true;
                let err_name = st.models[slot].name.clone();
                let stale: Vec<QueuedJob> = st.models[slot].queue.drain(..).collect();
                for job in &stale {
                    if let Some((lane, svc)) = job.lane_service {
                        inner.ledger.complete(lane, svc);
                    }
                }
                for job in stale {
                    let _ = job.reply.send(Err(anyhow::anyhow!(
                        "model {err_name} disabled: its executor panicked"
                    )));
                }
            }
        } else {
            st = inner.state.lock().unwrap();
            if st.models[slot].generation == gen {
                st.models[slot].exec = Some(exec);
            } else {
                // re-placement swapped executors mid-batch: retire the
                // stale one off-lock, never restore it
                drop(st);
                drop(exec);
                st = inner.state.lock().unwrap();
            }
            if !st.models[slot].queue.is_empty() {
                // more backlog for this model: wake a sibling worker
                inner.work.notify_one();
            }
        }
    }
}

/// Result of a load run.
#[derive(Debug)]
pub struct LoadReport {
    pub wall_s: f64,
    pub throughput_rps: f64,
    /// Latency summaries over *executed* responses only
    /// ([`Outcome::Admitted`] / [`Outcome::DegradedCpu`] /
    /// [`Outcome::Spilled`]).
    pub latency: HashMap<String, Summary>,
    /// Governor high-water mark observed by the end of the run.
    pub peak_reserved_bytes: u64,
    /// Requests served on the normal placed path.
    pub admitted: usize,
    /// Requests degraded to the CPU-forced path to make their deadline.
    pub degraded: usize,
    /// Requests shed at admission (deadline unmeetable, not executed).
    pub shed: usize,
    /// Queued requests answered with [`Outcome::Dropped`] because their
    /// model was dropped mid-run.
    pub dropped: usize,
    /// Submissions skipped because the rotation hit a dropped model.
    pub skipped: usize,
    /// Requests spilled to the device–edge remote lane
    /// ([`Outcome::Spilled`]).  The accounting invariant every
    /// outcome-counting test pins:
    /// `admitted + degraded + shed + dropped + skipped + spilled`
    /// equals the number of submissions attempted.
    pub spilled: usize,
    pub responses: Vec<Response>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stub(delay_us: u64) -> Box<dyn ModelExecutor> {
        Box::new(FnExecutor(move |seed| {
            std::thread::sleep(std::time::Duration::from_micros(delay_us));
            Ok((delay_us as f64 * 1e-6, seed as f64))
        }))
    }

    #[test]
    fn routes_to_correct_lane() {
        let mut s = Server::new();
        s.register("a", stub(10));
        s.register("b", stub(10));
        let r = s.infer("a", 7).unwrap();
        assert_eq!(r.model, "a");
        assert_eq!(r.checksum, 7.0);
        assert!(s.infer("c", 0).is_err());
    }

    #[test]
    fn load_run_completes_all() {
        let mut s = Server::new();
        s.register("a", stub(50));
        s.register("b", stub(50));
        let rep = s.run_load(&["a", "b"], 20, 4, 1).unwrap();
        assert_eq!(rep.responses.len(), 20);
        assert!(rep.throughput_rps > 0.0);
        assert!(rep.latency.contains_key("a") && rep.latency.contains_key("b"));
    }

    #[test]
    fn no_request_lost_or_duplicated() {
        let mut s = Server::new();
        s.register("m", stub(1));
        let rep = s.run_load(&["m"], 50, 8, 3).unwrap();
        let mut ids: Vec<u64> = rep.responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 50, "duplicate or lost responses");
    }

    #[test]
    fn captured_executor_serves_engine_identical_results() {
        // a CPU-only micro model captures standalone; serving it must
        // reproduce the fresh engine run bit-for-bit, on every request
        let g = crate::models::micro::parallel_chains(4, 6);
        let p = crate::partition::partition(
            &g,
            &crate::partition::CostModel {
                min_ops: usize::MAX,
                min_flops: u64::MAX,
                max_bytes_per_flop: 0.0,
            },
        );
        let plan = crate::branch::plan(&g, &p, crate::branch::DEFAULT_BETA);
        let cfg = crate::sched::SchedCfg { max_threads: 4, margin: 0.4 };
        let (demand, exec) = captured_executor(&g, &p, &plan, &cfg, 1 << 34).unwrap();
        assert!(demand > 0, "captured demand must be a real lease figure");

        // reference: fresh engine run over the same schedules
        let mems = crate::memory::branch_memories(&g, &p, &plan);
        let schedules = crate::sched::schedule(&plan, &mems, 1 << 34, &cfg);
        let engine = crate::exec::Engine::new(&g, &p, &plan, None);
        let (vals, _) = engine.run(&schedules).unwrap();
        let want = vals.checksum();

        let mut s = Server::new();
        s.register_with_demand("captured", demand, exec);
        let r1 = s.infer("captured", 1).unwrap();
        let r2 = s.infer("captured", 2).unwrap();
        assert_eq!(r1.checksum, want, "replay must match the fresh engine run");
        assert_eq!(r2.checksum, want, "every replay is deterministic");
    }

    #[test]
    fn failing_executor_propagates_error() {
        let mut s = Server::new();
        s.register("bad", Box::new(FnExecutor(|_| anyhow::bail!("boom"))));
        assert!(s.infer("bad", 0).is_err());
    }

    /// Gate that executors park on until the test opens it — makes the
    /// "backlog fully formed before service starts" setup deterministic
    /// (at most one batch can be claimed before the gate opens, and it
    /// blocks inside `execute`, off the dispatcher lock).
    struct Gate(Mutex<bool>, Condvar);

    impl Gate {
        fn new() -> Arc<Self> {
            Arc::new(Gate(Mutex::new(false), Condvar::new()))
        }
        fn open(&self) {
            *self.0.lock().unwrap() = true;
            self.1.notify_all();
        }
        fn wait(&self) {
            let mut open = self.0.lock().unwrap();
            while !*open {
                open = self.1.wait(open).unwrap();
            }
        }
    }

    #[test]
    fn backlog_is_micro_batched() {
        // one worker, gated executor: everything queued behind the gate
        // must coalesce into micro-batches once service starts.
        let gate = Gate::new();
        let mut s = Server::with_config(
            ServeCfg { workers: 1, max_batch: 4 },
            Arc::new(MemoryGovernor::unlimited()),
        );
        let g = gate.clone();
        s.register(
            "m",
            Box::new(FnExecutor(move |seed| {
                g.wait();
                Ok((0.0, seed as f64))
            })),
        );
        let rxs: Vec<_> = (0..5).map(|i| s.submit("m", i).unwrap()).collect();
        gate.open();
        let resps: Vec<Response> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
        assert_eq!(resps.len(), 5);
        assert!(resps.iter().all(|r| r.batched >= 1 && r.batched <= 4));
        // at most one single-request batch can start before the gate
        // opens, so 5 requests over ≤4-batches always form one ≥ 2
        assert!(
            resps.iter().any(|r| r.batched >= 2),
            "no micro-batch formed under backlog"
        );
    }

    #[test]
    fn round_robin_interleaves_models() {
        // single worker, unit batches, backlog on both models: the
        // fairness ring must alternate services, never drain one model.
        let order = Arc::new(Mutex::new(Vec::<&'static str>::new()));
        let gate = Gate::new();
        let mut s = Server::with_config(
            ServeCfg { workers: 1, max_batch: 1 },
            Arc::new(MemoryGovernor::unlimited()),
        );
        for name in ["a", "b"] {
            let order = order.clone();
            let g = gate.clone();
            s.register(
                name,
                Box::new(FnExecutor(move |seed| {
                    g.wait();
                    order.lock().unwrap().push(name);
                    Ok((0.0, seed as f64))
                })),
            );
        }
        let mut rxs = Vec::new();
        for i in 0..4 {
            rxs.push(s.submit("a", i).unwrap());
        }
        for i in 0..4 {
            rxs.push(s.submit("b", i).unwrap());
        }
        gate.open();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let log = order.lock().unwrap();
        assert_eq!(*log, ["a", "b", "a", "b", "a", "b", "a", "b"]);
    }

    #[test]
    fn panicking_executor_poisons_only_its_model() {
        let mut s = Server::with_config(
            ServeCfg { workers: 2, max_batch: 2 },
            Arc::new(MemoryGovernor::unlimited()),
        );
        s.register(
            "boom",
            Box::new(FnExecutor(|_| -> anyhow::Result<(f64, f64)> {
                panic!("kaboom")
            })),
        );
        s.register("ok", stub(1));
        let err = s.infer("boom", 1).unwrap_err().to_string();
        assert!(err.contains("panicked"), "got: {err}");
        // subsequent submissions to the poisoned model fail fast...
        assert!(s.submit("boom", 2).is_err());
        // ...while the healthy model keeps serving on the same pool
        for i in 0..8 {
            assert_eq!(s.infer("ok", i).unwrap().checksum, i as f64);
        }
    }

    #[test]
    fn drop_drains_accepted_requests() {
        // work accepted before drop must complete, not be abandoned
        let gate = Gate::new();
        let mut s = Server::with_config(
            ServeCfg { workers: 1, max_batch: 2 },
            Arc::new(MemoryGovernor::unlimited()),
        );
        let g = gate.clone();
        s.register(
            "m",
            Box::new(FnExecutor(move |seed| {
                g.wait();
                Ok((0.0, seed as f64))
            })),
        );
        let rxs: Vec<_> = (0..6).map(|i| s.submit("m", i).unwrap()).collect();
        gate.open();
        drop(s);
        let mut got: Vec<f64> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap().checksum)
            .collect();
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(got, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn per_seed_demand_leases_resolved_bytes() {
        // demand fn: even seeds are "short inputs" (10 B), odd are
        // full-length (60 B).  With unit batches the ledger must see
        // exactly the per-request figure, never the worst case.
        let gov = Arc::new(MemoryGovernor::new(1_000));
        let mut s = Server::with_config(ServeCfg { workers: 1, max_batch: 1 }, gov.clone());
        let g = gov.clone();
        s.register_with_demand_fn(
            "dyn",
            Box::new(|seed| if seed % 2 == 0 { 10 } else { 60 }),
            Box::new(FnExecutor(move |seed| {
                let expect = if seed % 2 == 0 { 10 } else { 60 };
                assert_eq!(g.in_use(), expect, "lease must match the resolved demand");
                Ok((0.0, seed as f64))
            })),
        );
        for seed in 0..6 {
            s.infer("dyn", seed).unwrap();
        }
        assert_eq!(gov.in_use(), 0);
        assert_eq!(gov.peak_reserved(), 60, "worst case only when a long input arrives");
    }

    #[test]
    fn resolved_demands_monotone_in_fill() {
        // the §3.4 adapter: a dynamic model's resolved demand at short
        // fills must stay below the worst-case figure register_with_demand
        // would lease.
        let soc = crate::device::SocProfile::pixel6();
        let pipe = crate::baselines::Pipeline::build(
            crate::baselines::Framework::Parallax,
            crate::models::ModelKind::WhisperTiny,
            &soc,
            crate::sim::Mode::CpuOnly,
            crate::sched::SchedCfg::default(),
        )
        .unwrap();
        let worst = pipe.peak_branch_demand();
        let (demand_fn, _exec) = resolved_pipeline_executor(pipe, 7);
        // sim_fill(0) ≈ 0.15 (shortest bucket), sim_fill covers [0.15, 1)
        let short = demand_fn(0);
        assert!(short <= worst, "short {short} > worst {worst}");
        for seed in 0..97 {
            assert!(demand_fn(seed) <= worst);
        }
    }

    #[test]
    fn placed_executor_demand_covers_staging() {
        // register-time placement: the adapter must lease exactly the
        // placement-aware peak, which covers the host-visible staging
        // of every delegated branch's layer.
        let soc = crate::device::SocProfile::pixel6();
        let pipe = crate::baselines::Pipeline::build(
            crate::baselines::Framework::Parallax,
            crate::models::ModelKind::Yolov8n,
            &soc,
            crate::sim::Mode::Heterogeneous,
            crate::sched::SchedCfg::default(),
        )
        .unwrap();
        let expect = crate::place::assign(
            &pipe.graph,
            &pipe.partition,
            &pipe.plan,
            &pipe.soc,
            crate::place::PlacePolicy::Auto,
        );
        let expect_demand = pipe.peak_placed_demand(&expect);
        let (placement, demand, _exec) = placed_pipeline_executor(pipe, 7);
        assert_eq!(demand, expect_demand, "adapter must lease the placed peak");
        assert_eq!(placement.num_delegated(), expect.num_delegated());
        assert!(demand > 0);
        for b in placement.delegated() {
            assert!(
                demand >= placement.staging_bytes[b],
                "demand must cover branch {b} staging"
            );
        }
    }

    /// Executor that reports which path served it: positive checksums
    /// for the normal path, negative for the degraded (CPU-forced) one.
    struct PathProbe(Arc<Gate>);

    impl ModelExecutor for PathProbe {
        fn execute(&mut self, seed: u64) -> anyhow::Result<(f64, f64)> {
            self.0.wait();
            Ok((0.0, 1.0 + seed as f64))
        }
        fn execute_degraded(&mut self, seed: u64) -> anyhow::Result<(f64, f64)> {
            self.0.wait();
            Ok((0.0, -(1.0 + seed as f64)))
        }
        fn execute_spilled(&mut self, seed: u64) -> anyhow::Result<Option<(f64, f64)>> {
            self.0.wait();
            Ok(Some((0.0, 1000.0 + seed as f64)))
        }
    }

    #[test]
    fn slo_admission_is_deterministic_under_backlog() {
        // pinned figures: lane service 1.0 s, degraded CPU 0.25 s.  The
        // gate holds every admitted request outstanding, so the ledger
        // arithmetic below is exact, not timing-dependent.
        let gate = Gate::new();
        let mut s = Server::with_config(
            ServeCfg { workers: 1, max_batch: 1 },
            Arc::new(MemoryGovernor::unlimited()),
        );
        s.register_with_slo(
            "m",
            0,
            SloSpec { lane: Some(0), lane_service_s: 1.0, cpu_service_s: 0.25, remote: None },
            Box::new(PathProbe(gate.clone())),
        );
        // eta 1.0 ≤ 10.0 → admitted (outstanding 1.0)
        let r1 = s.submit_with_deadline("m", 0, Some(10.0)).unwrap();
        // eta 2.0 > 1.5, cpu 0.25 ≤ 1.5 → degraded (no lane charge)
        let r2 = s.submit_with_deadline("m", 1, Some(1.5)).unwrap();
        // eta 2.0 ≤ 2.5 → admitted (outstanding 2.0)
        let r3 = s.submit_with_deadline("m", 2, Some(2.5)).unwrap();
        // eta 3.0 > 0.1 and cpu 0.25 > 0.1 → shed, replied immediately
        let r4 = s.submit_with_deadline("m", 3, Some(0.1)).unwrap();
        let shed = r4.recv().unwrap().unwrap();
        assert_eq!(shed.outcome, Outcome::Shed);
        assert_eq!(shed.batched, 0);
        assert_eq!(shed.checksum, 0.0, "shed requests never execute");
        gate.open();
        let a1 = r1.recv().unwrap().unwrap();
        let d2 = r2.recv().unwrap().unwrap();
        let a3 = r3.recv().unwrap().unwrap();
        assert_eq!(a1.outcome, Outcome::Admitted);
        assert!(a1.checksum > 0.0, "normal path served it");
        assert_eq!(d2.outcome, Outcome::DegradedCpu);
        assert!(d2.checksum < 0.0, "degraded path served it");
        assert_eq!(a3.outcome, Outcome::Admitted);
        assert_eq!(
            s.lane_ledger().outstanding(0),
            0.0,
            "drained server's lane ledger must read exactly zero"
        );
    }

    #[test]
    fn load_report_counts_outcomes_exactly() {
        let gov = Arc::new(MemoryGovernor::unlimited());
        let mut s = Server::with_config(ServeCfg { workers: 2, max_batch: 2 }, gov);
        s.register_with_slo(
            "t",
            0,
            SloSpec { lane: Some(0), lane_service_s: 5.0, cpu_service_s: 5.0, remote: None },
            stub(1),
        );
        // deadline 0.5 < both services: every request shed
        let rep = s.run_load_slo(&["t"], 8, 4, 1, Some(0.5)).unwrap();
        assert_eq!((rep.admitted, rep.degraded, rep.shed, rep.dropped), (0, 0, 8, 0));
        assert_eq!(rep.responses.len(), 8);
        assert!(rep.latency.is_empty(), "shed requests carry no latency");
        // lane path (5.0) misses a 1.0 deadline but the cheap CPU
        // fallback (0.25) makes it: every request degrades
        s.register_with_slo(
            "u",
            0,
            SloSpec { lane: Some(1), lane_service_s: 5.0, cpu_service_s: 0.25, remote: None },
            stub(1),
        );
        let rep = s.run_load_slo(&["u"], 8, 4, 1, Some(1.0)).unwrap();
        assert_eq!((rep.admitted, rep.degraded, rep.shed, rep.dropped), (0, 8, 0, 0));
        // loose deadline, tiny lane service: everything admitted
        s.register_with_slo(
            "v",
            0,
            SloSpec { lane: Some(2), lane_service_s: 1e-3, cpu_service_s: 1e-3, remote: None },
            stub(1),
        );
        let rep = s.run_load_slo(&["v"], 8, 4, 1, Some(10.0)).unwrap();
        assert_eq!((rep.admitted, rep.degraded, rep.shed, rep.dropped), (8, 0, 0, 0));
        assert_eq!(s.lane_ledger().outstanding_total(), 0.0);
        // local lane (5.0) misses the 1.0 deadline; the remote lane
        // (1 ms) makes it: every request spills, none shed/degraded
        s.register_with_slo(
            "w",
            0,
            SloSpec {
                lane: Some(3),
                lane_service_s: 5.0,
                cpu_service_s: 5.0,
                remote: Some((4, 1e-3)),
            },
            stub(1),
        );
        let rep = s.run_load_slo(&["w"], 8, 4, 1, Some(1.0)).unwrap();
        assert_eq!(
            (rep.admitted, rep.degraded, rep.shed, rep.dropped, rep.spilled),
            (0, 0, 0, 0, 8)
        );
        assert_eq!(
            rep.admitted + rep.degraded + rep.shed + rep.dropped + rep.skipped
                + rep.spilled,
            8,
            "outcome accounting must partition the submissions"
        );
        assert!(rep.latency.contains_key("w"), "spilled requests carry latency");
        assert_eq!(s.lane_ledger().outstanding_total(), 0.0);
    }

    #[test]
    fn spill_admission_is_deterministic_under_backlog() {
        // pinned figures: local lane 1.0 s, remote 0.5 s, CPU 0.25 s.
        // The gate holds admitted work outstanding so the ledger
        // arithmetic is exact: admit → spill → degrade → shed, in
        // submission order.
        let gate = Gate::new();
        let mut s = Server::with_config(
            ServeCfg { workers: 1, max_batch: 1 },
            Arc::new(MemoryGovernor::unlimited()),
        );
        s.register_with_slo(
            "m",
            0,
            SloSpec {
                lane: Some(0),
                lane_service_s: 1.0,
                cpu_service_s: 0.25,
                remote: Some((1, 0.5)),
            },
            Box::new(PathProbe(gate.clone())),
        );
        // lane eta 1.0 ≤ 10.0 → admitted (lane 0 outstanding 1.0)
        let r1 = s.submit_with_deadline("m", 0, Some(10.0)).unwrap();
        // lane eta 2.0 > 1.5; remote eta 0.5 ≤ 1.5 → spilled (lane 1
        // outstanding 0.5)
        let r2 = s.submit_with_deadline("m", 1, Some(1.5)).unwrap();
        // lane eta 2.0 > 0.6; remote eta 1.0 > 0.6; cpu 0.25 ≤ 0.6 →
        // degraded (no ledger charge)
        let r3 = s.submit_with_deadline("m", 2, Some(0.6)).unwrap();
        // every path misses 0.1 → shed immediately
        let r4 = s.submit_with_deadline("m", 3, Some(0.1)).unwrap();
        let shed = r4.recv().unwrap().unwrap();
        assert_eq!(shed.outcome, Outcome::Shed);
        gate.open();
        let a1 = r1.recv().unwrap().unwrap();
        let sp2 = r2.recv().unwrap().unwrap();
        let d3 = r3.recv().unwrap().unwrap();
        assert_eq!(a1.outcome, Outcome::Admitted);
        assert_eq!(a1.checksum, 1.0, "normal path served it");
        assert_eq!(sp2.outcome, Outcome::Spilled);
        assert_eq!(sp2.checksum, 1002.0, "spilled path served it");
        assert_eq!(d3.outcome, Outcome::DegradedCpu);
        assert_eq!(d3.checksum, -3.0, "degraded path served it");
        assert_eq!(s.lane_ledger().outstanding(0), 0.0);
        assert_eq!(
            s.lane_ledger().outstanding(1),
            0.0,
            "remote lane charges must drain to exactly zero"
        );
    }

    #[test]
    fn spill_link_fault_resolves_to_degraded_never_silent() {
        // executor whose remote path persistently faults on odd seeds
        // (`Ok(None)`): those requests must come back DegradedCpu —
        // explicit outcomes for every injected drop, nothing lost.
        struct FaultyLink;
        impl ModelExecutor for FaultyLink {
            fn execute(&mut self, seed: u64) -> anyhow::Result<(f64, f64)> {
                Ok((0.0, 1.0 + seed as f64))
            }
            fn execute_degraded(&mut self, seed: u64) -> anyhow::Result<(f64, f64)> {
                Ok((0.0, -(1.0 + seed as f64)))
            }
            fn execute_spilled(&mut self, seed: u64) -> anyhow::Result<Option<(f64, f64)>> {
                if seed % 2 == 1 {
                    return Ok(None);
                }
                Ok(Some((0.0, 1000.0 + seed as f64)))
            }
        }
        let mut s = Server::with_config(
            ServeCfg { workers: 1, max_batch: 4 },
            Arc::new(MemoryGovernor::unlimited()),
        );
        s.register_with_slo(
            "m",
            0,
            SloSpec {
                lane: Some(0),
                lane_service_s: 10.0,
                cpu_service_s: 0.1,
                remote: Some((1, 1e-3)),
            },
            Box::new(FaultyLink),
        );
        let rxs: Vec<_> =
            (0..4).map(|i| s.submit_with_deadline("m", i, Some(1.0)).unwrap()).collect();
        let resps: Vec<Response> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
        for (seed, r) in resps.iter().enumerate() {
            if seed % 2 == 1 {
                assert_eq!(r.outcome, Outcome::DegradedCpu, "faulted spill degrades");
                assert_eq!(r.checksum, -(1.0 + seed as f64), "degraded path served it");
            } else {
                assert_eq!(r.outcome, Outcome::Spilled);
                assert_eq!(r.checksum, 1000.0 + seed as f64, "remote path served it");
            }
        }
        assert_eq!(s.lane_ledger().outstanding_total(), 0.0);
    }

    #[test]
    fn drop_model_answers_queue_and_rejects_new() {
        // single worker parked on a gated model: the victim's queue
        // builds, then the drop must answer every queued request with
        // an explicit Dropped outcome and reject new submissions.
        let gate = Gate::new();
        let mut s = Server::with_config(
            ServeCfg { workers: 1, max_batch: 2 },
            Arc::new(MemoryGovernor::unlimited()),
        );
        let g = gate.clone();
        s.register(
            "hold",
            Box::new(FnExecutor(move |seed| {
                g.wait();
                Ok((0.0, seed as f64))
            })),
        );
        s.register("victim", stub(1));
        let busy = s.submit("hold", 0).unwrap();
        let queued: Vec<_> = (0..3).map(|i| s.submit("victim", i).unwrap()).collect();
        s.drop_model("victim").unwrap();
        for rx in queued {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.outcome, Outcome::Dropped);
            assert_eq!(resp.batched, 0);
        }
        let err = s.submit("victim", 9).unwrap_err().to_string();
        assert!(err.contains("dropped"), "got: {err}");
        assert!(s.drop_model("victim").is_err(), "double drop is an error");
        assert!(s.drop_model("ghost").is_err(), "unknown model is an error");
        assert_eq!(s.models(), vec!["hold".to_string()]);
        gate.open();
        busy.recv().unwrap().unwrap();
    }

    #[test]
    fn admission_respects_governor_budget() {
        // two models, each demanding 60 of a 100-byte budget: batches
        // must serialise and the ledger may never exceed the budget.
        let gov = Arc::new(MemoryGovernor::new(100));
        let mut s = Server::with_config(ServeCfg { workers: 2, max_batch: 2 }, gov.clone());
        for name in ["a", "b"] {
            let g = gov.clone();
            s.register_with_demand(
                name,
                60,
                Box::new(FnExecutor(move |seed| {
                    assert!(g.in_use() <= 100, "ledger over budget during execution");
                    std::thread::sleep(std::time::Duration::from_millis(2));
                    Ok((0.0, seed as f64))
                })),
            );
        }
        let rep = s.run_load(&["a", "b"], 16, 8, 9).unwrap();
        assert_eq!(rep.responses.len(), 16);
        assert!(rep.peak_reserved_bytes <= 100);
        assert_eq!(gov.in_use(), 0, "leases leaked");
    }
}
