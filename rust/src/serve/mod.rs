//! Serving front-end: a multi-model request router + batcher over the
//! real execution engine.
//!
//! This is the "downstream user" face of the library: submit inference
//! requests, get latency-tracked responses.  Internally one worker
//! thread per registered model owns that model's Parallax pipeline
//! (plan + arenas + PJRT pool handle) and drains its queue; text-encoder
//! requests with equal shapes are micro-batched.
//!
//! (Offline build: no tokio — the loop is std-thread + channel based,
//! which for a single-host serving demo is equivalent.)

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::util::stats::{summarize, Summary};

/// An inference request (synthetic payload: seed for the input draw).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub model: String,
    pub seed: u64,
    pub submitted: Instant,
}

/// A completed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub model: String,
    /// End-to-end latency (queueing + execution).
    pub latency_s: f64,
    /// Execution-only time.
    pub exec_s: f64,
    /// Checksum of outputs (determinism probe).
    pub checksum: f64,
}

/// Model executor trait — the server is generic over how a model runs
/// (real engine, simulator, or test stub).
pub trait ModelExecutor: Send + 'static {
    /// Run one request; returns (exec seconds, output checksum).
    fn execute(&mut self, seed: u64) -> anyhow::Result<(f64, f64)>;
}

/// Closure-based executor for tests and simple setups.
pub struct FnExecutor<F: FnMut(u64) -> anyhow::Result<(f64, f64)> + Send + 'static>(pub F);

impl<F: FnMut(u64) -> anyhow::Result<(f64, f64)> + Send + 'static> ModelExecutor for FnExecutor<F> {
    fn execute(&mut self, seed: u64) -> anyhow::Result<(f64, f64)> {
        (self.0)(seed)
    }
}

enum Job {
    Run(Request, mpsc::Sender<anyhow::Result<Response>>),
    Stop,
}

struct ModelLane {
    tx: mpsc::Sender<Job>,
    join: Option<std::thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

/// The server: routes requests to per-model lanes.
pub struct Server {
    lanes: HashMap<String, ModelLane>,
    next_id: AtomicU64,
    completed: Arc<Mutex<Vec<Response>>>,
}

impl Server {
    pub fn new() -> Self {
        Self {
            lanes: HashMap::new(),
            next_id: AtomicU64::new(0),
            completed: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// Register a model lane with its executor.
    pub fn register(&mut self, model: &str, mut exec: Box<dyn ModelExecutor>) {
        let (tx, rx) = mpsc::channel::<Job>();
        let queued = Arc::new(AtomicUsize::new(0));
        let q2 = queued.clone();
        let model_name = model.to_string();
        let join = std::thread::Builder::new()
            .name(format!("lane-{model}"))
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Stop => break,
                        Job::Run(req, reply) => {
                            q2.fetch_sub(1, Ordering::Relaxed);
                            let result = exec.execute(req.seed).map(|(exec_s, checksum)| {
                                Response {
                                    id: req.id,
                                    model: model_name.clone(),
                                    latency_s: req.submitted.elapsed().as_secs_f64(),
                                    exec_s,
                                    checksum,
                                }
                            });
                            let _ = reply.send(result);
                        }
                    }
                }
            })
            .expect("spawn lane");
        self.lanes.insert(
            model.to_string(),
            ModelLane { tx, join: Some(join), queued },
        );
    }

    pub fn models(&self) -> Vec<&str> {
        self.lanes.keys().map(String::as_str).collect()
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(
        &self,
        model: &str,
        seed: u64,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<Response>>> {
        let lane = self
            .lanes
            .get(model)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        lane.queued.fetch_add(1, Ordering::Relaxed);
        lane.tx
            .send(Job::Run(
                Request { id, model: model.to_string(), seed, submitted: Instant::now() },
                reply,
            ))
            .map_err(|_| anyhow::anyhow!("lane closed"))?;
        Ok(rx)
    }

    /// Submit and wait.
    pub fn infer(&self, model: &str, seed: u64) -> anyhow::Result<Response> {
        let rx = self.submit(model, seed)?;
        let resp = rx.recv().map_err(|_| anyhow::anyhow!("lane dropped reply"))??;
        self.completed.lock().unwrap().push(resp.clone());
        Ok(resp)
    }

    /// Run a closed-loop load: `n` requests round-robin over models,
    /// `concurrency` in flight.  Returns per-model latency summaries +
    /// total throughput (req/s).
    pub fn run_load(
        &self,
        models: &[&str],
        n: usize,
        concurrency: usize,
        seed: u64,
    ) -> anyhow::Result<LoadReport> {
        let t0 = Instant::now();
        let mut pending: Vec<(String, mpsc::Receiver<anyhow::Result<Response>>)> = Vec::new();
        let mut done: Vec<Response> = Vec::new();
        for i in 0..n {
            let model = models[i % models.len()];
            pending.push((model.to_string(), self.submit(model, seed ^ i as u64)?));
            if pending.len() >= concurrency {
                let (_, rx) = pending.remove(0);
                done.push(rx.recv().map_err(|_| anyhow::anyhow!("lane died"))??);
            }
        }
        for (_, rx) in pending {
            done.push(rx.recv().map_err(|_| anyhow::anyhow!("lane died"))??);
        }
        let wall = t0.elapsed().as_secs_f64();
        let mut by_model: HashMap<String, Vec<f64>> = HashMap::new();
        for r in &done {
            by_model.entry(r.model.clone()).or_default().push(r.latency_s);
        }
        Ok(LoadReport {
            wall_s: wall,
            throughput_rps: n as f64 / wall,
            latency: by_model
                .into_iter()
                .map(|(m, xs)| (m, summarize(&xs).unwrap()))
                .collect(),
            responses: done,
        })
    }
}

impl Default for Server {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        for lane in self.lanes.values() {
            let _ = lane.tx.send(Job::Stop);
        }
        for lane in self.lanes.values_mut() {
            if let Some(j) = lane.join.take() {
                let _ = j.join();
            }
        }
    }
}

/// Result of a load run.
#[derive(Debug)]
pub struct LoadReport {
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub latency: HashMap<String, Summary>,
    pub responses: Vec<Response>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stub(delay_us: u64) -> Box<dyn ModelExecutor> {
        Box::new(FnExecutor(move |seed| {
            std::thread::sleep(std::time::Duration::from_micros(delay_us));
            Ok((delay_us as f64 * 1e-6, seed as f64))
        }))
    }

    #[test]
    fn routes_to_correct_lane() {
        let mut s = Server::new();
        s.register("a", stub(10));
        s.register("b", stub(10));
        let r = s.infer("a", 7).unwrap();
        assert_eq!(r.model, "a");
        assert_eq!(r.checksum, 7.0);
        assert!(s.infer("c", 0).is_err());
    }

    #[test]
    fn load_run_completes_all() {
        let mut s = Server::new();
        s.register("a", stub(50));
        s.register("b", stub(50));
        let rep = s.run_load(&["a", "b"], 20, 4, 1).unwrap();
        assert_eq!(rep.responses.len(), 20);
        assert!(rep.throughput_rps > 0.0);
        assert!(rep.latency.contains_key("a") && rep.latency.contains_key("b"));
    }

    #[test]
    fn no_request_lost_or_duplicated() {
        let mut s = Server::new();
        s.register("m", stub(1));
        let rep = s.run_load(&["m"], 50, 8, 3).unwrap();
        let mut ids: Vec<u64> = rep.responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 50, "duplicate or lost responses");
    }

    #[test]
    fn failing_executor_propagates_error() {
        let mut s = Server::new();
        s.register("bad", Box::new(FnExecutor(|_| anyhow::bail!("boom"))));
        assert!(s.infer("bad", 0).is_err());
    }
}
