//! Runtime subgraph control (paper §3.4): heterogeneous inference of
//! *dynamic* models with runtime-resolved shapes and control flow.
//!
//! The static pipeline plans every `Dim::Dynamic { max }` at its upper
//! bound and treats control-flow operators purely as Split-Merge
//! barriers — correct, but it reserves worst-case memory and re-plans
//! nothing between decode steps.  This module closes that gap:
//!
//! 1. **Segmentation** — [`ctrl_segments`] cuts the DAG at dynamic
//!    operators (`If`/`While`/`BeamSearchStep`/`NonMaxSuppression`/
//!    `EmbeddingLookup`) into statically-schedulable segments that
//!    execute in order; every barrier owns a singleton segment.
//! 2. **Resolution** — [`resolve_barrier`] turns actual tensor values
//!    into concrete extents for the dynamic dims a barrier controls
//!    (iteration counts, NMS output counts, taken `If` arms), recorded
//!    in a [`ShapeEnv`] and propagated into every downstream segment.
//! 3. **Resolved planning** — [`resolved_branch_memories`] re-runs the
//!    §3.3 branch-peak estimator at the resolved sizes (clamped by the
//!    max-shape plan, which is always a valid fallback), so governor
//!    leases shrink from worst-case to actual.
//! 4. **Plan caching** — per-segment schedules are cached keyed by
//!    (segment, resolved-shape bucket), so an autoregressive decode
//!    loop pays partitioned planning once per power-of-two length
//!    bucket instead of once per step.
//! 5. **Dead-branch pruning** — a resolved `If` predicate marks the
//!    untaken arm dead ([`dead_nodes`]); its branches are skipped and
//!    their arena reservations never leased.
//!
//! [`SegmentedEngine`] drives all five against the real
//! [`Engine`](crate::exec::Engine), leasing each segment's resolved
//! demand from the process-wide
//! [`MemoryGovernor`](crate::sched::MemoryGovernor).
//!
//! # Examples
//!
//! ```
//! use parallax::branch::{self, DEFAULT_BETA};
//! use parallax::ctrl::{self, ShapeEnv};
//! use parallax::graph::Dim;
//! use parallax::models::ModelKind;
//! use parallax::partition::{partition, CostModel};
//!
//! // Resolve the Whisper decoder's dynamic length to 9 of max 64 tokens.
//! let mut env = ShapeEnv::unresolved();
//! env.bind(64, 9);
//! assert_eq!(env.dim(Dim::Dynamic { max: 64 }), 9);
//! assert_eq!(env.dim(Dim::Static(384)), 384);
//!
//! // Control-flow barriers split the DAG into ordered segments, and
//! // resolved shapes shrink the §3.3 branch demands.
//! let g = ModelKind::WhisperTiny.build();
//! let p = partition(&g, &CostModel::default());
//! let plan = branch::plan(&g, &p, DEFAULT_BETA);
//! let seg = ctrl::segment_plan(&g, &p, &plan);
//! assert!(seg.segments.iter().any(|s| s.barrier.is_some()));
//! let max = parallax::memory::branch_memories(&g, &p, &plan);
//! let resolved = ctrl::resolved_branch_memories(&g, &p, &plan, &env, &max);
//! assert!(resolved.iter().zip(&max).all(|(r, m)| r.total() <= m.total()));
//! ```

use std::collections::{BTreeMap, HashMap, HashSet};
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::branch::BranchPlan;
use crate::device::{SocProfile, ThermalModel};
use crate::exec::{Engine, ExecStats, Values};
use crate::graph::{Dim, Graph, NodeId, OpClass, OpKind, TensorId, TensorInfo};
use crate::memory::{self, BranchMemory};
use crate::partition::Partition;
use crate::place::{self, PlacePolicy, PlacementPlan};
use crate::runtime::Tensor;
use crate::sched::{self, MemoryGovernor, SchedCfg};

// ---------------------------------------------------------------- ShapeEnv

/// Runtime bindings for dynamic dimensions.
///
/// The zoo encodes a symbolic dynamic dim by its bound: every tensor
/// sharing `Dim::Dynamic { max: 64 }` shares the same runtime extent
/// (the decode length), so the bound doubles as the symbol.  A
/// `ShapeEnv` maps symbols to resolved extents; unbound symbols stay at
/// their max, which reproduces the static worst-case plan exactly.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShapeEnv {
    bindings: BTreeMap<usize, usize>,
}

impl ShapeEnv {
    /// No bindings: every dynamic dim at its max (the static plan).
    pub fn unresolved() -> Self {
        Self::default()
    }

    /// Bind every dynamic symbol in the graph from a fill factor in
    /// (0, 1] — the simulator's input-draw protocol expressed as an
    /// environment.
    pub fn from_fill(g: &Graph, fill: f64) -> Self {
        let mut env = Self::default();
        for t in g.tensors() {
            for &d in &t.shape {
                if let Dim::Dynamic { max } = d {
                    env.bind_if_absent(max, d.resolve(fill));
                }
            }
        }
        env
    }

    /// Bind `symbol` (a dynamic dim's max) to a concrete extent,
    /// clamped into `1..=symbol`.
    pub fn bind(&mut self, symbol: usize, extent: usize) {
        self.bindings.insert(symbol, extent.clamp(1, symbol.max(1)));
    }

    /// [`ShapeEnv::bind`] unless the symbol is already bound — callers
    /// (a decode loop driving the length) win over barrier resolvers.
    pub fn bind_if_absent(&mut self, symbol: usize, extent: usize) {
        if !self.bindings.contains_key(&symbol) {
            self.bind(symbol, extent);
        }
    }

    /// The resolved extent of a symbol, if bound.
    pub fn binding(&self, symbol: usize) -> Option<usize> {
        self.bindings.get(&symbol).copied()
    }

    /// All bindings as (symbol, extent) pairs, ascending by symbol.
    pub fn bindings(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.bindings.iter().map(|(&s, &e)| (s, e))
    }

    /// True when no symbol is bound (pure max-shape planning).
    pub fn is_unresolved(&self) -> bool {
        self.bindings.is_empty()
    }

    /// Concrete extent of one dimension under this environment.
    pub fn dim(&self, d: Dim) -> usize {
        match d {
            Dim::Static(n) => n,
            Dim::Dynamic { max } => self.binding(max).unwrap_or(max).min(max),
        }
    }

    /// Concrete shape of a tensor under this environment.
    pub fn shape(&self, info: &TensorInfo) -> Vec<usize> {
        info.shape.iter().map(|&d| self.dim(d)).collect()
    }

    /// Concrete byte size of a tensor under this environment.
    pub fn byte_size(&self, info: &TensorInfo) -> usize {
        self.shape(info).iter().product::<usize>() * info.dtype.byte_width()
    }

    /// Round every extent up to the next power of two (capped at the
    /// symbol) — the plan-cache bucket.  Memory sized at the bucket's
    /// upper bound stays valid for every exact extent in the bucket, so
    /// decode steps 33..=64 share one cached plan.
    pub fn bucketed(&self) -> ShapeEnv {
        let mut env = ShapeEnv::default();
        for (&sym, &ext) in &self.bindings {
            env.bind(sym, ext.next_power_of_two().min(sym));
        }
        env
    }
}

// ------------------------------------------------------------ segmentation

/// Is this op a subgraph-control barrier?  Every `OpClass::Dynamic`
/// operator qualifies: control flow (`If`/`While`/`BeamSearchStep`)
/// plus dynamic-output producers (`NonMaxSuppression`,
/// `EmbeddingLookup`) whose results gate downstream shapes.
pub fn is_ctrl_barrier(kind: &OpKind) -> bool {
    matches!(kind.class(), OpClass::Dynamic)
}

/// One node-level segment: a statically-schedulable body, or a barrier
/// by itself.
#[derive(Clone, Debug)]
pub struct CtrlSegment {
    /// Member nodes in topological order.
    pub nodes: Vec<NodeId>,
    /// Set when this segment is a singleton barrier.
    pub barrier: Option<NodeId>,
}

/// Cut the DAG at control barriers into ordered segments.
///
/// A node's level counts the barriers on its deepest incoming path
/// (the same construction the partitioner uses for delegate regions);
/// non-barrier nodes of one level share a segment, and every barrier
/// gets its own, ordered after its level's body.  Returns the segments
/// in execution order plus each node's segment index.  For every edge
/// `u -> v`: `seg(u) <= seg(v)`, strictly when `u` is a barrier.
pub fn ctrl_segments(g: &Graph) -> (Vec<CtrlSegment>, Vec<usize>) {
    let order = g.topo_order().expect("ctrl segmentation requires a DAG");
    let n = g.num_nodes();
    let mut lvl = vec![0u32; n];
    for &v in &order {
        let mut l = 0;
        for p in g.preds(v) {
            let step = u32::from(is_ctrl_barrier(&g.node(p).kind));
            l = l.max(lvl[p.0 as usize] + step);
        }
        lvl[v.0 as usize] = l;
    }
    // sort key: (2*lvl + barrier-bit) in the high half; barriers
    // tie-break by topo position so each owns a distinct segment.
    let mut keyed: Vec<(u64, NodeId)> = Vec::with_capacity(n);
    for (pos, &v) in order.iter().enumerate() {
        let b = is_ctrl_barrier(&g.node(v).kind);
        let base = 2 * lvl[v.0 as usize] as u64 + u64::from(b);
        let key = (base << 32) | if b { pos as u64 + 1 } else { 0 };
        keyed.push((key, v));
    }
    keyed.sort_by_key(|&(k, _)| k); // stable: bodies keep topo order
    let mut segments: Vec<CtrlSegment> = Vec::new();
    let mut seg_of_node = vec![0usize; n];
    let mut last_key = u64::MAX;
    for (key, v) in keyed {
        if key != last_key {
            let barrier = is_ctrl_barrier(&g.node(v).kind).then_some(v);
            segments.push(CtrlSegment { nodes: Vec::new(), barrier });
            last_key = key;
        }
        seg_of_node[v.0 as usize] = segments.len() - 1;
        segments.last_mut().unwrap().nodes.push(v);
    }
    (segments, seg_of_node)
}

/// One segment of the branch-level execution plan.
#[derive(Clone, Debug)]
pub struct SegmentExec {
    /// The barrier resolved before this segment runs, if any.
    pub barrier: Option<NodeId>,
    /// `(original layer index, branch ids)` — the Branch-Layer plan's
    /// layers restricted to this segment, in layer order.
    pub layers: Vec<(usize, Vec<usize>)>,
    /// All branch ids of this segment (layer order).
    pub branches: Vec<usize>,
}

/// A [`BranchPlan`] projected onto control segments.
#[derive(Clone, Debug)]
pub struct SegmentedPlan {
    /// Segments in execution order.
    pub segments: Vec<SegmentExec>,
    /// Segment index of every branch.
    pub seg_of_branch: Vec<usize>,
}

impl SegmentedPlan {
    /// Index of the first barrier segment (where the dynamic suffix of
    /// the model starts), if the graph has one.
    pub fn first_barrier(&self) -> Option<usize> {
        self.segments.iter().position(|s| s.barrier.is_some())
    }
}

/// Assign every branch of a Branch-Layer plan to a control segment.
///
/// A branch lands in the latest segment any of its nodes belongs to; a
/// dependency fix-up pass (over the plan's topological layers) then
/// raises consumers past their producers, so executing segments in
/// order can never run a branch before its inputs exist — whatever the
/// node-level labels say about delegate regions.
pub fn segment_plan(g: &Graph, p: &Partition, plan: &BranchPlan) -> SegmentedPlan {
    let (segs, seg_of_node) = ctrl_segments(g);
    let nb = plan.branches.len();
    let mut seg_of_branch = vec![0usize; nb];
    for (b, seg) in seg_of_branch.iter_mut().enumerate() {
        *seg = plan
            .branch_nodes(g, p, b)
            .iter()
            .map(|id| seg_of_node[id.0 as usize])
            .max()
            .unwrap_or(0);
    }
    // branch-level predecessor sets from the unit graph
    let ug = &plan.unit_graph;
    let mut preds: Vec<Vec<usize>> = vec![Vec::new(); nb];
    for (u, succs) in ug.succs.iter().enumerate() {
        let bu = plan.branch_of_unit[u];
        for &v in succs {
            let bv = plan.branch_of_unit[v];
            if bu != bv && !preds[bv].contains(&bu) {
                preds[bv].push(bu);
            }
        }
    }
    // layers are topological over branches: one pass suffices
    for layer in &plan.layers {
        for &b in layer {
            for &a in &preds[b] {
                if seg_of_branch[a] > seg_of_branch[b] {
                    seg_of_branch[b] = seg_of_branch[a];
                }
            }
        }
    }
    let mut segments: Vec<SegmentExec> = segs
        .iter()
        .map(|s| SegmentExec { barrier: s.barrier, layers: Vec::new(), branches: Vec::new() })
        .collect();
    for (li, layer) in plan.layers.iter().enumerate() {
        for (s, seg) in segments.iter_mut().enumerate() {
            let members: Vec<usize> =
                layer.iter().copied().filter(|&b| seg_of_branch[b] == s).collect();
            if !members.is_empty() {
                seg.branches.extend(members.iter().copied());
                seg.layers.push((li, members));
            }
        }
    }
    SegmentedPlan { segments, seg_of_branch }
}

// ------------------------------------------------------ resolved memories

/// §3.3 branch-peak estimate of one branch at resolved shapes.
///
/// The result is clamped by the max-shape estimate: the static plan's
/// offsets are always a valid fallback, so a resolved plan never needs
/// more memory than the worst case — the invariant the property tests
/// pin down.
pub fn resolved_branch_memory(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    b: usize,
    env: &ShapeEnv,
    max: &BranchMemory,
) -> BranchMemory {
    if env.is_unresolved() {
        return *max;
    }
    let nodes = plan.branch_nodes(g, p, b);
    let mut lts = memory::analyze(g, &nodes);
    for lt in &mut lts {
        lt.bytes = env.byte_size(g.tensor_info(lt.tensor));
    }
    let (internal, boundary): (Vec<_>, Vec<_>) = lts.into_iter().partition(|lt| !lt.escapes);
    let arena = memory::plan_branch(&internal).arena_bytes;
    let boundary_sum: usize = boundary.iter().map(|lt| lt.bytes).sum();
    BranchMemory {
        arena_bytes: arena.min(max.arena_bytes),
        boundary_out_bytes: boundary_sum.min(max.boundary_out_bytes),
    }
}

/// [`resolved_branch_memory`] for every branch of a plan.
pub fn resolved_branch_memories(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    env: &ShapeEnv,
    max: &[BranchMemory],
) -> Vec<BranchMemory> {
    (0..plan.branches.len())
        .map(|b| resolved_branch_memory(g, p, plan, b, env, &max[b]))
        .collect()
}

// ------------------------------------------------------------- resolution

/// What resolving one barrier against actual values yields.
#[derive(Clone, Debug, Default)]
pub struct BarrierOutcome {
    /// `(symbol, extent)` bindings for the dynamic dims this barrier
    /// controls (its outputs' `Dim::Dynamic` bounds).
    pub bindings: Vec<(usize, usize)>,
    /// Output tensors of an `If` whose arm was not taken — seeds for
    /// [`dead_nodes`].
    pub dead: Vec<TensorId>,
}

fn value_hash(t: &Tensor) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &x in t.data() {
        h ^= x.to_bits() as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Resolve a barrier node from its actual input values.
///
/// * `While`/`BeamSearchStep`/`NonMaxSuppression`/`EmbeddingLookup`:
///   every dynamic dim of the outputs is bound to a value-derived
///   extent in `1..=max` (deterministic in the input bits, so results
///   stay bit-identical across thread counts and schedules).
/// * `If`: the first input's leading element picks the arm; with two
///   or more outputs the untaken arm's token is reported dead.
pub fn resolve_barrier(
    g: &Graph,
    id: NodeId,
    read: impl Fn(TensorId) -> Arc<Tensor>,
) -> BarrierOutcome {
    let node = g.node(id);
    let mut out = BarrierOutcome::default();
    let h = node.inputs.first().map(|&t| value_hash(&read(t))).unwrap_or(0x5EED);
    for &o in &node.outputs {
        for &d in &g.tensor_info(o).shape {
            if let Dim::Dynamic { max } = d {
                if !out.bindings.iter().any(|&(s, _)| s == max) {
                    let extent = 1 + (h % max.max(1) as u64) as usize;
                    out.bindings.push((max, extent));
                }
            }
        }
    }
    if matches!(node.kind, OpKind::If) && node.outputs.len() >= 2 {
        let taken = node
            .inputs
            .first()
            .map(|&t| read(t).data().first().copied().unwrap_or(0.0) >= 0.0)
            .unwrap_or(true);
        // taken -> arm 0 live, output[1] dead (and vice versa)
        out.dead.push(node.outputs[usize::from(taken)]);
    }
    out
}

/// Nodes reachable *exclusively* from `seeds` (an untaken `If` arm):
/// a node is dead iff at least one input is dead and every produced
/// input is dead too (weights and other sources don't keep an arm
/// alive; a merge fed by the live arm does).
///
/// `If` semantics make the untaken arm's values *don't-care*: a merge
/// that still lists the dead arm as an input reads the engine's
/// deterministic synthesized stand-in (the same fallback used for any
/// dropped value), so pruned runs are bit-reproducible — but they are
/// intentionally *not* value-identical to a static run that executes
/// both arms, exactly as a real `If` never materialises the branch it
/// didn't take.
pub fn dead_nodes(g: &Graph, seeds: &[TensorId]) -> HashSet<NodeId> {
    let mut dead_t: HashSet<TensorId> = seeds.iter().copied().collect();
    let mut dead_n: HashSet<NodeId> = HashSet::new();
    for v in g.topo_order().expect("DAG") {
        let node = g.node(v);
        if node.inputs.is_empty() {
            continue;
        }
        let touches = node.inputs.iter().any(|t| dead_t.contains(t));
        if !touches {
            continue;
        }
        let exclusive = node
            .inputs
            .iter()
            .all(|&t| dead_t.contains(&t) || g.producer(t).is_none());
        if exclusive {
            dead_n.insert(v);
            dead_t.extend(node.outputs.iter().copied());
        }
    }
    dead_n
}

// -------------------------------------------------------- segmented engine

/// A cached per-segment plan: schedules, the lease they hold, and the
/// captured executable form the engine replays — the §3.4 plan cache
/// is a consumer of the same plan-capture layer the static hot path
/// uses ([`crate::exec::CapturedPlan`]): a cache hit costs zero
/// planning *and* zero per-run structure walking.
struct Entry {
    schedules: Vec<sched::LayerSchedule>,
    demand: u64,
    captured: crate::exec::CapturedPlan,
}

#[allow(clippy::too_many_arguments)]
fn build_entry(
    engine: &Engine<'_>,
    branch_succs: &[Vec<usize>],
    mems: &[BranchMemory],
    seg: &SegmentExec,
    dead: &[usize],
    budget: u64,
    cfg: &SchedCfg,
    placement: Option<&PlacementPlan>,
    env: &ShapeEnv,
) -> Entry {
    let plan = engine.plan;
    // Which branches skip host arena/boundary accounting: with a
    // placement, exactly the delegate-placed ones (their staging is
    // priced below; a `has_delegate` branch forced onto the CPU holds
    // a real host arena) — without one, the classic `has_delegate`
    // convention.
    let off_host = |b: usize| match placement {
        Some(pl) => pl.is_delegated(b),
        None => plan.branches[b].has_delegate,
    };
    let mut schedules = Vec::with_capacity(seg.layers.len());
    for (li, members) in &seg.layers {
        let live: Vec<usize> =
            members.iter().copied().filter(|b| !dead.contains(b)).collect();
        if live.is_empty() {
            continue;
        }
        schedules.push(sched::schedule_layer(
            &plan.branches,
            mems,
            &live,
            budget,
            cfg,
            plan.layer_parallel[*li],
        ));
    }
    // Segment residency demand: every CPU branch's escaping outputs
    // stay resident for downstream segments, plus the peak *transient*
    // footprint of any one layer — §3.3 applied at segment
    // granularity.  Resolved shapes shrink both terms, so decode-step
    // leases track the actual sequence length instead of the worst
    // case.  Under a placement, a layer's transient adds the
    // host-visible delegate-I/O staging of every lane job *in flight*
    // during that layer — with cross-layer overlap a job dispatched in
    // an earlier layer holds its staging until its first consumer, so
    // the per-layer staging term is the in-flight accounting of
    // `sched::placed_inflight_staging`, not just the layer's own
    // dispatches — on top of its widest wave's arena peak.
    let inflight: Vec<u64> = match placement {
        Some(pl) => sched::placed_inflight_staging_from(branch_succs, pl, &schedules),
        None => vec![0; schedules.len()],
    };
    let mut boundary = 0u64;
    let mut peak_transient = 0u64;
    for (li, ls) in schedules.iter().enumerate() {
        let mut layer_arena = 0u64;
        for wave in &ls.waves {
            let mut arena = 0u64;
            for &b in wave {
                if off_host(b) {
                    continue;
                }
                arena += mems[b].arena_bytes as u64;
                boundary += mems[b].boundary_out_bytes as u64;
            }
            layer_arena = layer_arena.max(arena);
        }
        for &b in &ls.sequential {
            if off_host(b) {
                continue;
            }
            layer_arena = layer_arena.max(mems[b].arena_bytes as u64);
            boundary += mems[b].boundary_out_bytes as u64;
        }
        peak_transient = peak_transient.max(inflight[li] + layer_arena);
    }
    let captured = engine.capture(&schedules, env, placement);
    Entry { schedules, demand: boundary + peak_transient, captured }
}

fn merge_stats(acc: &mut ExecStats, s: ExecStats) {
    acc.pjrt_calls += s.pjrt_calls;
    acc.host_ops += s.host_ops;
    acc.skipped_fused += s.skipped_fused;
    acc.peak_arena_bytes = acc.peak_arena_bytes.max(s.peak_arena_bytes);
    acc.cpu_branch_runs += s.cpu_branch_runs;
    acc.delegate_jobs += s.delegate_jobs;
    acc.acc_modelled_s += s.acc_modelled_s;
    acc.delegate_stalls += s.delegate_stalls;
    acc.lane_gaps += s.lane_gaps;
    acc.wall_s += s.wall_s;
    acc.cpu_modelled_s += s.cpu_modelled_s;
    acc.energy_j += s.energy_j;
    acc.energy_idle_j += s.energy_idle_j;
    acc.energy_cpu_j += s.energy_cpu_j;
    acc.energy_lane_j += s.energy_lane_j;
}

/// Plan-cache key: (placement generation, segment id, bucketed
/// bindings, dead branch ids).  Structural — two distinct (generation,
/// bucket, dead-set) states can never collide into reusing the wrong
/// cached plan; the generation term is what invalidates every cached
/// [`CapturedPlan`](crate::exec::CapturedPlan) when a thermal
/// re-placement swaps the lane topology mid-stream.
type PlanKey = (usize, usize, Vec<(usize, usize)>, Vec<usize>);

/// Thermal-throttling configuration of a [`SegmentedEngine`] (see
/// [`SegmentedEngine::with_thermal`]).
struct ThermalCfg {
    /// The unthrottled device profile placements are derived from.
    soc: SocProfile,
    model: ThermalModel,
    policy: PlacePolicy,
    /// Re-place when any lane's effective rate factor drifts from the
    /// factor the current placement was derived at by more than this.
    tolerance: f64,
}

/// The mutable placement state of a [`SegmentedEngine`]: swapped
/// atomically (under one lock) by a thermal re-placement, snapshotted
/// per segment by the execution path.
struct PlacedState {
    placement: Option<Arc<PlacementPlan>>,
    /// Per-segment plans at worst-case shapes under `placement`.
    max_entries: Vec<Arc<Entry>>,
    /// Bumped on every re-placement — the plan-cache epoch.
    generation: usize,
    /// The per-lane rate factors `placement` was derived at.
    lane_factors: Vec<f64>,
}

/// Statistics of one segmented run.
#[derive(Clone, Debug, Default)]
pub struct CtrlStats {
    /// Segments that executed at least one branch.
    pub segments_run: usize,
    /// Plan-cache hits / misses during this run.
    pub cache_hits: usize,
    pub cache_misses: usize,
    /// Branches skipped because their `If` arm was not taken.
    pub pruned_branches: usize,
    /// Peak per-segment lease the max-shape plan would have held.
    pub max_plan_demand: u64,
    /// Peak per-segment lease this run actually held.
    pub resolved_demand: u64,
    /// Final symbol bindings, `(symbol, extent)` ascending.
    pub bindings: Vec<(usize, usize)>,
    /// Aggregated engine statistics over all segments.
    pub exec: ExecStats,
}

/// Segment-by-segment executor over a real [`Engine`]: resolves
/// barriers from live values, re-plans (cached) at resolved shapes,
/// prunes dead arms, and leases each segment's resolved demand from
/// the governor.  See the [module docs](self).
pub struct SegmentedEngine<'a> {
    engine: &'a Engine<'a>,
    seg_plan: SegmentedPlan,
    max_mems: Vec<BranchMemory>,
    /// Branch successor map, derived once from the immutable plan
    /// (feeds the in-flight staging spans of every re-plan).
    branch_succs: Vec<Vec<usize>>,
    budget: u64,
    cfg: SchedCfg,
    /// Heterogeneous placement + its per-segment max-shape plans:
    /// behind one lock because a thermal re-placement swaps both
    /// together mid-stream (plain placed/static engines take the lock
    /// once per segment and never contend).
    state: Mutex<PlacedState>,
    /// Thermal throttling: set by [`SegmentedEngine::with_thermal`].
    thermal: Option<ThermalCfg>,
    /// Accumulated modelled busy seconds per lane across every run of
    /// this engine — the stream-level odometer the thermal model reads.
    lane_busy: Mutex<Vec<f64>>,
    /// Mid-stream re-placements performed so far.
    replacements: AtomicUsize,
    cache: Mutex<HashMap<PlanKey, Arc<Entry>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl<'a> SegmentedEngine<'a> {
    /// Build the segmented view of an engine's plan.  `budget` is the
    /// per-wave scheduling budget (typically the governor's).
    pub fn new(engine: &'a Engine<'a>, cfg: SchedCfg, budget: u64) -> Self {
        Self::build(engine, cfg, budget, None, None)
    }

    /// [`SegmentedEngine::new`] with a heterogeneous placement
    /// (`crate::place`): delegate-placed branches execute on their
    /// lane's persistent [`DelegateWorker`](crate::exec::DelegateWorker)
    /// thread, and every segment's residency lease covers their
    /// host-visible staging buffers for as long as the jobs are in
    /// flight.  Because placement never delegates a branch carrying
    /// `OpClass::Dynamic` work, resolved dynamic segments stay on the
    /// CPU while their static neighbours may be offloaded — the §3.4
    /// and heterogeneous paths compose instead of conflicting.
    pub fn with_placement(
        engine: &'a Engine<'a>,
        cfg: SchedCfg,
        budget: u64,
        placement: PlacementPlan,
    ) -> Self {
        Self::build(engine, cfg, budget, Some(placement), None)
    }

    /// [`SegmentedEngine::with_placement`] under a
    /// [`ThermalModel`](crate::device::ThermalModel): the initial
    /// placement is derived from the cold `soc` under `policy`, and
    /// every run accumulates each lane's modelled busy seconds.  When a
    /// lane's thermal rate factor drifts from the factor the current
    /// placement was derived at by more than `tolerance`, the engine
    /// re-places against the throttled profile *mid-stream*: the
    /// placement and per-segment max-shape plans are swapped atomically
    /// and every cached [`CapturedPlan`](crate::exec::CapturedPlan) is
    /// invalidated (the plan-cache key carries a placement generation).
    /// Outputs stay bit-identical across re-placements by construction
    /// — placement only moves branches between devices, never changes
    /// what they compute — and every post-throttle lease is still sized
    /// by the §3.3 rules under the new placement.
    pub fn with_thermal(
        engine: &'a Engine<'a>,
        cfg: SchedCfg,
        budget: u64,
        soc: &SocProfile,
        policy: PlacePolicy,
        model: ThermalModel,
        tolerance: f64,
    ) -> Self {
        let placement =
            place::assign(engine.graph, engine.partition, engine.plan, soc, policy);
        let thermal =
            ThermalCfg { soc: soc.clone(), model, policy, tolerance };
        Self::build(engine, cfg, budget, Some(placement), Some(thermal))
    }

    fn build(
        engine: &'a Engine<'a>,
        cfg: SchedCfg,
        budget: u64,
        placement: Option<PlacementPlan>,
        thermal: Option<ThermalCfg>,
    ) -> Self {
        let (g, p, plan) = (engine.graph, engine.partition, engine.plan);
        let seg_plan = segment_plan(g, p, plan);
        let max_mems = memory::branch_memories(g, p, plan);
        // the plan is immutable: derive the branch successor map once
        // and reuse it for every (re-)planned segment's in-flight
        // staging spans instead of rebuilding it per cache miss
        let branch_succs = plan.branch_succs();
        let max_entries = seg_plan
            .segments
            .iter()
            .map(|seg| {
                Arc::new(build_entry(
                    engine,
                    &branch_succs,
                    &max_mems,
                    seg,
                    &[],
                    budget,
                    &cfg,
                    placement.as_ref(),
                    &ShapeEnv::unresolved(),
                ))
            })
            .collect();
        let num_lanes = thermal.as_ref().map_or(0, |tc| tc.soc.lanes.len());
        Self {
            engine,
            seg_plan,
            max_mems,
            branch_succs,
            budget,
            cfg,
            state: Mutex::new(PlacedState {
                placement: placement.map(Arc::new),
                max_entries,
                generation: 0,
                lane_factors: vec![1.0; num_lanes],
            }),
            thermal,
            lane_busy: Mutex::new(vec![0.0; num_lanes]),
            replacements: AtomicUsize::new(0),
            cache: Mutex::new(HashMap::new()),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    /// The segmented plan (segments in execution order).
    pub fn seg_plan(&self) -> &SegmentedPlan {
        &self.seg_plan
    }

    /// Number of segments.
    pub fn num_segments(&self) -> usize {
        self.seg_plan.segments.len()
    }

    /// First barrier segment — where the model's dynamic suffix starts.
    pub fn first_barrier_segment(&self) -> Option<usize> {
        self.seg_plan.first_barrier()
    }

    /// Lifetime plan-cache counters: `(hits, misses)`.
    pub fn cache_stats(&self) -> (usize, usize) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Peak per-segment lease of the worst-case (max-shape) plan.
    pub fn max_plan_peak_demand(&self) -> u64 {
        let st = self.state.lock().unwrap();
        st.max_entries.iter().map(|e| e.demand).max().unwrap_or(0)
    }

    /// Mid-stream thermal re-placements performed so far (0 without
    /// [`SegmentedEngine::with_thermal`]).
    pub fn thermal_replacements(&self) -> usize {
        self.replacements.load(Ordering::Relaxed)
    }

    /// The placement currently in force, if any — a snapshot: a
    /// concurrent thermal re-placement swaps the engine to a new plan
    /// without invalidating handed-out `Arc`s.
    pub fn placement_snapshot(&self) -> Option<Arc<PlacementPlan>> {
        self.state.lock().unwrap().placement.clone()
    }

    /// Accumulated modelled busy seconds per lane across every run of
    /// this engine (empty without [`SegmentedEngine::with_thermal`]).
    pub fn lane_busy_s(&self) -> Vec<f64> {
        self.lane_busy.lock().unwrap().clone()
    }

    /// Run the whole model with runtime resolution.  `bindings` are
    /// caller-supplied `(symbol, extent)` pairs (e.g. the decode loop's
    /// current length) that take precedence over barrier resolvers.
    pub fn run(
        &self,
        bindings: &[(usize, usize)],
        governor: Option<&MemoryGovernor>,
    ) -> anyhow::Result<(Values, CtrlStats)> {
        let values = Values::default();
        let stats =
            self.run_range(0..self.num_segments(), &values, bindings, governor)?;
        Ok((values, stats))
    }

    /// Run the whole model at max shapes, no resolution — the static
    /// baseline the benches compare against.
    pub fn run_static(
        &self,
        governor: Option<&MemoryGovernor>,
    ) -> anyhow::Result<(Values, CtrlStats)> {
        let values = Values::default();
        let stats = self.run_range_static(0..self.num_segments(), &values, governor)?;
        Ok((values, stats))
    }

    /// Run a segment range with resolution against a shared value
    /// store — the autoregressive pattern: run the prefix once, then
    /// re-run the decoder range per step with a fresh length binding.
    pub fn run_range(
        &self,
        range: Range<usize>,
        values: &Values,
        bindings: &[(usize, usize)],
        governor: Option<&MemoryGovernor>,
    ) -> anyhow::Result<CtrlStats> {
        let mut env = ShapeEnv::unresolved();
        for &(sym, ext) in bindings {
            env.bind(sym, ext);
        }
        let mut stats = CtrlStats::default();
        self.exec_range(range, values, &mut env, true, governor, &mut stats)?;
        stats.bindings = env.bindings().collect();
        Ok(stats)
    }

    /// [`SegmentedEngine::run_range`] at max shapes, no resolution.
    pub fn run_range_static(
        &self,
        range: Range<usize>,
        values: &Values,
        governor: Option<&MemoryGovernor>,
    ) -> anyhow::Result<CtrlStats> {
        let mut env = ShapeEnv::unresolved();
        let mut stats = CtrlStats::default();
        self.exec_range(range, values, &mut env, false, governor, &mut stats)?;
        Ok(stats)
    }

    fn exec_range(
        &self,
        range: Range<usize>,
        values: &Values,
        env: &mut ShapeEnv,
        resolve: bool,
        governor: Option<&MemoryGovernor>,
        stats: &mut CtrlStats,
    ) -> anyhow::Result<()> {
        let (g, p, plan) = (self.engine.graph, self.engine.partition, self.engine.plan);
        let mut dead_branches: Vec<usize> = Vec::new();
        for sid in range {
            let seg = &self.seg_plan.segments[sid];
            if resolve {
                if let Some(bar) = seg.barrier {
                    let node = g.node(bar);
                    // Resolve only when this barrier can still contribute
                    // — an If arm decision, or an output dynamic symbol
                    // not already bound.  A decode loop that drives the
                    // length keeps its warm steps value-hash-free.
                    let needs = matches!(node.kind, OpKind::If)
                        || node.outputs.iter().any(|&o| {
                            g.tensor_info(o).shape.iter().any(|&d| match d {
                                Dim::Dynamic { max } => env.binding(max).is_none(),
                                Dim::Static(_) => false,
                            })
                        });
                    // ...and only from values that were actually computed:
                    // a producer-fed input absent from the store means its
                    // branch was deferred past this barrier — plan at max
                    // instead of resolving from a synthesized stand-in.
                    let ready = node
                        .inputs
                        .iter()
                        .all(|&t| g.producer(t).is_none() || values.contains(t));
                    if needs && ready {
                        let outcome =
                            resolve_barrier(g, bar, |t| self.engine.read_value(values, t));
                        for (sym, ext) in outcome.bindings {
                            env.bind_if_absent(sym, ext);
                        }
                        if !outcome.dead.is_empty() {
                            let dn = dead_nodes(g, &outcome.dead);
                            for b in 0..plan.branches.len() {
                                if dead_branches.contains(&b) {
                                    continue;
                                }
                                let nodes = plan.branch_nodes(g, p, b);
                                if !nodes.is_empty()
                                    && nodes.iter().all(|id| dn.contains(id))
                                {
                                    dead_branches.push(b);
                                }
                            }
                            dead_branches.sort_unstable();
                        }
                    }
                }
            }
            // Snapshot placement + max-shape plan + generation in one
            // lock acquisition, so the entry replayed below can never
            // mismatch the placement it was captured under — even if a
            // thermal re-placement lands between two segments.
            let (placement, max_entry, generation) = {
                let st = self.state.lock().unwrap();
                (st.placement.clone(), st.max_entries[sid].clone(), st.generation)
            };
            stats.max_plan_demand = stats.max_plan_demand.max(max_entry.demand);
            let seg_dead: Vec<usize> = seg
                .branches
                .iter()
                .copied()
                .filter(|b| dead_branches.contains(b))
                .collect();
            stats.pruned_branches += seg_dead.len();
            let entry = if resolve && !(env.is_unresolved() && seg_dead.is_empty()) {
                self.entry_for(sid, env, &seg_dead, stats, placement.as_deref(), generation)
            } else {
                max_entry
            };
            if entry.schedules.is_empty() {
                continue;
            }
            stats.resolved_demand = stats.resolved_demand.max(entry.demand);
            // Admission sized from resolved shapes: the max-vs-actual
            // slack is never taken from the process-wide ledger, so
            // co-resident models admit more concurrent waves.
            let _lease = governor.map(|gov| gov.acquire(entry.demand));
            // Replay the cached capture: a plan-cache hit costs zero
            // planning and zero structure walking (dynamic output
            // shapes still resolve through this step's exact env).
            let s = self.engine.run_captured(
                &entry.captured,
                values,
                None,
                env,
                placement.as_deref(),
            )?;
            merge_stats(&mut stats.exec, s);
            stats.segments_run += 1;
            if self.thermal.is_some() {
                self.note_thermal(&entry, placement.as_deref());
            }
        }
        Ok(())
    }

    /// Thermal bookkeeping after one segment: advance each lane's busy
    /// odometer by the modelled delegate time this segment's schedules
    /// put on it (the same per-branch figure the engine's lane ledger
    /// charges), then re-place if any lane's rate factor drifted past
    /// the tolerance since the current placement was derived.
    fn note_thermal(&self, entry: &Entry, placement: Option<&PlacementPlan>) {
        let Some(tc) = &self.thermal else { return };
        let Some(pl) = placement else { return };
        let mut busy = self.lane_busy.lock().unwrap();
        for ls in &entry.schedules {
            for b in ls.all() {
                if let Some(lane) = pl.lane_of(b) {
                    if lane < busy.len() {
                        busy[lane] += pl.delegate_latency_s[b];
                    }
                }
            }
        }
        let mut st = self.state.lock().unwrap();
        let drifted = st
            .lane_factors
            .iter()
            .enumerate()
            .any(|(l, &f)| (f - tc.model.rate_factor(busy[l])).abs() > tc.tolerance);
        if !drifted {
            return;
        }
        let factors: Vec<f64> =
            (0..busy.len()).map(|l| tc.model.rate_factor(busy[l])).collect();
        let throttled = tc.model.throttled(&tc.soc, &busy);
        let (g, p, plan) = (self.engine.graph, self.engine.partition, self.engine.plan);
        let next = place::assign(g, p, plan, &throttled, tc.policy);
        // Always adopt the new factors (no re-check until the next
        // drift); swap plans only when the assignment actually moved.
        st.lane_factors = factors;
        let changed = st
            .placement
            .as_ref()
            .map_or(true, |cur| cur.assignment != next.assignment);
        if !changed {
            return;
        }
        let next = Arc::new(next);
        st.max_entries = self
            .seg_plan
            .segments
            .iter()
            .map(|seg| {
                Arc::new(build_entry(
                    self.engine,
                    &self.branch_succs,
                    &self.max_mems,
                    seg,
                    &[],
                    self.budget,
                    &self.cfg,
                    Some(next.as_ref()),
                    &ShapeEnv::unresolved(),
                ))
            })
            .collect();
        st.placement = Some(next);
        st.generation += 1;
        self.replacements.fetch_add(1, Ordering::Relaxed);
        // stale-generation entries can never be looked up again — drop
        // them rather than letting a long stream accumulate dead plans
        self.cache.lock().unwrap().clear();
    }

    fn entry_for(
        &self,
        sid: usize,
        env: &ShapeEnv,
        dead: &[usize],
        stats: &mut CtrlStats,
        placement: Option<&PlacementPlan>,
        generation: usize,
    ) -> Arc<Entry> {
        // memory is sized at the bucket's upper bound, so every exact
        // env in the bucket stays within the cached reservation
        let bucketed = env.bucketed();
        let key: PlanKey =
            (generation, sid, bucketed.bindings().collect(), dead.to_vec());
        // one lock across lookup + plan: concurrent first-steps on the
        // same bucket must not double-plan, or the documented
        // ≤ ⌈log₂ t_max⌉+1 misses-per-segment bound breaks.  Planning
        // under the lock is fine — it only happens on misses, which the
        // bound keeps rare.
        let mut cache = self.cache.lock().unwrap();
        if let Some(e) = cache.get(&key) {
            stats.cache_hits += 1;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return e.clone();
        }
        stats.cache_misses += 1;
        self.misses.fetch_add(1, Ordering::Relaxed);
        let (g, p, plan) = (self.engine.graph, self.engine.partition, self.engine.plan);
        let seg = &self.seg_plan.segments[sid];
        let mut mems = self.max_mems.clone();
        for &b in &seg.branches {
            mems[b] = resolved_branch_memory(g, p, plan, b, &bucketed, &self.max_mems[b]);
        }
        let entry = Arc::new(build_entry(
            self.engine,
            &self.branch_succs,
            &mems,
            seg,
            dead,
            self.budget,
            &self.cfg,
            placement,
            &bucketed,
        ));
        cache.insert(key, entry.clone());
        entry
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::branch::{self, DEFAULT_BETA};
    use crate::models::{micro, whisper_tiny, ModelKind};
    use crate::partition::{partition, CostModel};

    fn cpu_only(g: &Graph) -> Partition {
        partition(
            g,
            &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
        )
    }

    #[test]
    fn shape_env_binds_and_clamps() {
        let mut env = ShapeEnv::unresolved();
        assert!(env.is_unresolved());
        env.bind(64, 200);
        assert_eq!(env.binding(64), Some(64), "extent clamps to the symbol");
        env.bind(64, 0);
        assert_eq!(env.binding(64), Some(1), "extent clamps up to 1");
        env.bind(64, 9);
        env.bind_if_absent(64, 50);
        assert_eq!(env.dim(Dim::Dynamic { max: 64 }), 9, "first binding wins");
        assert_eq!(env.dim(Dim::Dynamic { max: 32 }), 32, "unbound stays at max");
        assert_eq!(env.dim(Dim::Static(7)), 7);
    }

    #[test]
    fn shape_env_buckets() {
        let mut a = ShapeEnv::unresolved();
        a.bind(64, 9);
        let mut b = ShapeEnv::unresolved();
        b.bind(64, 13);
        // 9 and 13 share the 16-bucket
        assert_eq!(a.bucketed(), b.bucketed());
        assert_eq!(a.bucketed().binding(64), Some(16));
        let mut c = ShapeEnv::unresolved();
        c.bind(64, 60);
        assert_eq!(c.bucketed().binding(64), Some(64), "bucket caps at the symbol");
    }

    #[test]
    fn from_fill_binds_every_symbol() {
        let g = ModelKind::WhisperTiny.build();
        let env = ShapeEnv::from_fill(&g, 0.5);
        assert_eq!(env.binding(whisper_tiny::MAX_DEC_T), Some(32));
        assert_eq!(env.binding(5), Some(3), "beam width symbol bound too");
    }

    #[test]
    fn segments_respect_edge_order() {
        for g in [ModelKind::WhisperTiny.build(), ModelKind::Yolov8n.build(), micro::gated(4)] {
            let (segs, seg_of) = ctrl_segments(&g);
            assert!(!segs.is_empty());
            for node in g.nodes() {
                let su = seg_of[node.id.0 as usize];
                for v in g.succs(node.id) {
                    let sv = seg_of[v.0 as usize];
                    assert!(su <= sv, "{}: segment order violated", g.name);
                    if is_ctrl_barrier(&node.kind) {
                        assert!(su < sv, "{}: barrier not a cut", g.name);
                    }
                }
            }
            // every barrier is alone in its segment
            for s in &segs {
                if s.barrier.is_some() {
                    assert_eq!(s.nodes.len(), 1);
                }
            }
        }
    }

    #[test]
    fn whisper_has_control_segments() {
        let g = ModelKind::WhisperTiny.build();
        let (segs, _) = ctrl_segments(&g);
        let barriers = segs.iter().filter(|s| s.barrier.is_some()).count();
        // While + EmbeddingLookup + BeamSearchStep
        assert_eq!(barriers, 3, "{:?}", segs.iter().map(|s| s.barrier).collect::<Vec<_>>());
    }

    #[test]
    fn segment_plan_respects_branch_dependencies() {
        for g in [ModelKind::WhisperTiny.build(), ModelKind::Yolov8n.build(), micro::gated(3)] {
            let p = partition(&g, &CostModel::default());
            let plan = branch::plan(&g, &p, DEFAULT_BETA);
            let sp = segment_plan(&g, &p, &plan);
            // every branch in exactly one segment
            let mut count = vec![0usize; plan.branches.len()];
            for seg in &sp.segments {
                for &b in &seg.branches {
                    count[b] += 1;
                }
            }
            assert!(count.iter().all(|&c| c == 1), "{}: {:?}", g.name, count);
            // cross-branch unit edges never point backwards in segments
            for (u, succs) in plan.unit_graph.succs.iter().enumerate() {
                let bu = plan.branch_of_unit[u];
                for &v in succs {
                    let bv = plan.branch_of_unit[v];
                    if bu != bv {
                        assert!(
                            sp.seg_of_branch[bu] <= sp.seg_of_branch[bv],
                            "{}: branch dependency crosses segments backwards",
                            g.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn resolved_memories_clamped_by_max() {
        let g = ModelKind::WhisperTiny.build();
        let p = cpu_only(&g);
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let max = memory::branch_memories(&g, &p, &plan);
        let env = ShapeEnv::from_fill(&g, 0.25);
        let rmems = resolved_branch_memories(&g, &p, &plan, &env, &max);
        for (r, m) in rmems.iter().zip(&max) {
            assert!(r.arena_bytes <= m.arena_bytes);
            assert!(r.boundary_out_bytes <= m.boundary_out_bytes);
        }
        assert!(
            rmems.iter().zip(&max).any(|(r, m)| r.total() < m.total()),
            "decoder branches must shrink at fill 0.25"
        );
        // full fill binds every symbol to its max: the resolved
        // estimator must reproduce the worst-case plan exactly
        // (EXPERIMENTS.md §Dynamic's "at fill 1.0 the ratio is 1.0×")
        let full = ShapeEnv::from_fill(&g, 1.0);
        let rfull = resolved_branch_memories(&g, &p, &plan, &full, &max);
        for (b, (r, m)) in rfull.iter().zip(&max).enumerate() {
            assert_eq!(r.arena_bytes, m.arena_bytes, "branch {b} arena at fill 1.0");
            assert_eq!(
                r.boundary_out_bytes, m.boundary_out_bytes,
                "branch {b} boundary at fill 1.0"
            );
        }
    }

    #[test]
    fn dead_nodes_cover_untaken_arm_only() {
        let g = micro::gated(3);
        let gate = g.nodes().iter().find(|n| matches!(n.kind, OpKind::If)).unwrap();
        let dead = dead_nodes(&g, &[gate.outputs[1]]);
        assert_eq!(dead.len(), 3, "exactly the untaken arm chain");
        for id in &dead {
            assert!(g.node(*id).name.starts_with("arm_b"), "{}", g.node(*id).name);
        }
        // the merge consumes the live arm too -> alive
        let select = g.nodes().iter().find(|n| n.name == "select").unwrap();
        assert!(!dead.contains(&select.id));
    }

    #[test]
    fn resolve_barrier_binds_dynamic_outputs() {
        let g = ModelKind::WhisperTiny.build();
        let beam = g
            .nodes()
            .iter()
            .find(|n| matches!(n.kind, OpKind::While))
            .unwrap();
        let out = resolve_barrier(&g, beam.id, |t| {
            Arc::new(Tensor::randn(
                g.tensor_info(t).shape.iter().map(|d| d.max()).collect(),
                7,
            ))
        });
        assert_eq!(out.bindings.len(), 1);
        let (sym, ext) = out.bindings[0];
        assert_eq!(sym, whisper_tiny::MAX_DEC_T);
        assert!((1..=sym).contains(&ext));
        assert!(out.dead.is_empty());
    }
}
