//! Device placement for the real runtime (paper §3.1 applied to
//! execution, not just simulation).
//!
//! Until this module existed, heterogeneity lived only in the `sim`
//! device-time model: the real [`Engine`](crate::exec::Engine) ran
//! every wave on host CPU threads, delegate regions included.  This
//! module closes that sim-vs-exec gap.  Given a Branch-Layer plan and a
//! [`SocProfile`], [`assign`] gives every branch a [`Placement`] — CPU
//! thread pool or one of the SoC's accelerator *lanes*
//! ([`AccLane`](crate::device::AccLane): TPU, GPU, DSP — concurrent
//! delegate queues) — by minimising the modelled latency from the
//! profile's parameters:
//!
//! ```text
//!   t_cpu(b)         = Σ_units max(F / R_cpu, B / (share · B_bw))
//!   t_delegate(b, l) = Σ_regions (L_l + F / (R_l · util_l) + B_boundary / B_l)
//!                    + Σ_glue    F / R_cpu
//! ```
//!
//! the same Appendix-B terms the `sim` timing model and the
//! [`CostModel`](crate::partition::CostModel) thresholds are built
//! from, evaluated per lane.  A branch is delegated only when some
//! *reachable* lane's `t_delegate < t_cpu` *and* the branch is
//! delegate-safe: it contains a delegate region and carries no
//! `OpClass::Dynamic` operator or dynamically-shaped tensor — dynamic
//! work always falls back to the CPU pool, which is what keeps the
//! §3.4 segmented path's barrier segments host-side by construction.
//! Among the lanes that beat the CPU, [`assign`] load-balances by
//! accumulated modelled busy time, so a two-lane SoC splits delegated
//! branches across its queues instead of serialising them onto one.
//!
//! Reachability is a hard gate, not a cost: a lane the runtime cannot
//! drive (`AccLane::reachable == false`, folding the old
//! `SocProfile::nnapi` flag — the P30 Pro's accelerator) yields
//! `INFINITY` from [`lane_delegate_latency`] whatever its modelled
//! rates, so placement can never target hardware the runtime cannot
//! reach.
//!
//! A [`RemoteLane`](crate::device::RemoteLane) attached via
//! [`SocProfile::with_remote`](crate::device::SocProfile::with_remote)
//! is priced by the *same* closed form — its Appendix-B terms are the
//! uplink latency (`dispatch_s`), the link bandwidth (`mem_bw`) and
//! the server-side rate (`flops · utilization`) — so a device–edge
//! spill tier needs no new pricing code.  What changes is bookkeeping:
//! for remote-assigned branches, [`transfer_bytes`] replace
//! [`staging_bytes`] (same boundary tensors, crossing the link), and
//! dynamic work still never delegates — [`delegate_safe`] gates the
//! remote lane exactly as it gates on-die ones.
//!
//! The plan also prices what delegation *costs the host*: each
//! delegated branch needs host-visible staging buffers for delegate
//! I/O (the region boundary tensors that cross the host↔accelerator
//! interface), held from dispatch until the branch's outputs merge at
//! its first consumer.
//! [`sched::placed_layer_demand`](crate::sched::placed_layer_demand)
//! (fed by [`sched::placed_inflight_staging`](crate::sched::placed_inflight_staging))
//! folds those in-flight staging bytes into the governor lease of
//! every layer a lane job spans, so offloading never becomes a way to
//! smuggle memory past the §3.3 budget.
//!
//! Downstream consumers:
//! * [`exec::Engine::run_placed`](crate::exec::Engine::run_placed) —
//!   executes delegated branches on persistent per-lane
//!   [`DelegateWorker`](crate::exec::DelegateWorker) threads that
//!   overlap wall-clock with the CPU fallback waves *across* layer
//!   barriers;
//! * [`ctrl::SegmentedEngine::with_placement`](crate::ctrl::SegmentedEngine::with_placement)
//!   — dynamic models: resolved dynamic segments stay on CPU, static
//!   neighbours may be delegated;
//! * [`serve::placed_pipeline_executor`](crate::serve::placed_pipeline_executor)
//!   — per-model placement chosen at register time.
//!
//! # Examples
//!
//! ```
//! use parallax::branch::{self, DEFAULT_BETA};
//! use parallax::device::SocProfile;
//! use parallax::models::micro;
//! use parallax::partition::{partition, CostModel};
//! use parallax::place::{self, PlacePolicy, Placement};
//!
//! let g = micro::fallback_heavy(4, 4, 512, 4);
//! let soc = SocProfile::pixel6();
//! let p = partition(&g, &CostModel::from_profile(&soc));
//! let plan = branch::plan(&g, &p, DEFAULT_BETA);
//! let placed = place::assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
//! // the heavy matmul trunk goes to a delegate lane, fallback chains stay CPU
//! assert!(placed.num_delegated() >= 1);
//! let forced = place::assign(&g, &p, &plan, &soc, PlacePolicy::ForceCpu);
//! assert!(forced.assignment.iter().all(|&pl| pl == Placement::CpuPool));
//! ```

use crate::branch::{BranchPlan, Unit};
use crate::device::{AccLane, SocProfile};
use crate::flops;
use crate::graph::{Graph, OpClass};
use crate::partition::Partition;

/// Where one branch executes (branch-level, unlike
/// [`partition::Placement`](crate::partition::Placement) which labels
/// individual nodes during region discovery).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Host CPU thread pool (the classic wave path).
    CpuPool,
    /// Accelerator delegate, executed on the given lane's async worker
    /// (an index into [`SocProfile::lanes`]).
    Delegate(usize),
}

/// How [`assign`] decides placements.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PlacePolicy {
    /// Minimise modelled latency: delegate exactly the delegate-safe
    /// branches for which some reachable lane beats their CPU time,
    /// load-balanced across lanes by accumulated modelled busy time.
    Auto,
    /// Force everything onto the CPU pool — the baseline configuration
    /// whose execution is bit-identical to the classic
    /// [`Engine::run`](crate::exec::Engine::run).
    ForceCpu,
    /// Pareto knob between latency and energy (Fig. 2): every branch ×
    /// device option is scored `alpha·latency + (1−alpha)·energy`
    /// (seconds blended with joules from the [`SocProfile`] power
    /// draws), delegation requires a lane score strictly below the CPU
    /// score, and lanes load-balance on accumulated blended score.
    /// `alpha: 1.0` reproduces [`PlacePolicy::Auto`] exactly;
    /// `alpha: 0.0` minimises modelled energy alone.
    EnergyAware {
        /// Latency weight in `[0, 1]` (energy weight is `1 − alpha`).
        alpha: f64,
    },
}

/// A complete branch → device assignment plus the modelled figures it
/// was decided from.  Built once per (model, device) by [`assign`];
/// immutable afterwards.
#[derive(Clone, Debug)]
pub struct PlacementPlan {
    /// Per-branch placement, indexed by branch id.
    pub assignment: Vec<Placement>,
    /// Modelled single-core CPU latency per branch, seconds.
    pub cpu_latency_s: Vec<f64>,
    /// Modelled delegate latency per branch, seconds: on its assigned
    /// lane for delegated branches, the best reachable lane otherwise
    /// (`f64::INFINITY` for branches that cannot delegate at all).
    pub delegate_latency_s: Vec<f64>,
    /// Host-visible staging bytes for delegate I/O per branch (region
    /// boundary tensors); 0 for CPU-placed branches.
    pub staging_bytes: Vec<u64>,
}

impl PlacementPlan {
    /// The one constructor every plan starts from — all-CPU, no
    /// modelled figures.  [`PlacementPlan::cpu_only`] and [`assign`]
    /// both build on this, so the per-branch vectors can never drift
    /// between the two paths.
    fn blank(num_branches: usize) -> Self {
        Self {
            assignment: vec![Placement::CpuPool; num_branches],
            cpu_latency_s: vec![0.0; num_branches],
            delegate_latency_s: vec![f64::INFINITY; num_branches],
            staging_bytes: vec![0; num_branches],
        }
    }

    /// Placement with every branch on the CPU pool (no modelling).
    pub fn cpu_only(num_branches: usize) -> Self {
        Self::blank(num_branches)
    }

    /// Is branch `b` assigned to an accelerator lane?
    pub fn is_delegated(&self, b: usize) -> bool {
        matches!(self.assignment[b], Placement::Delegate(_))
    }

    /// The lane branch `b` is assigned to, if delegated.
    pub fn lane_of(&self, b: usize) -> Option<usize> {
        match self.assignment[b] {
            Placement::Delegate(l) => Some(l),
            Placement::CpuPool => None,
        }
    }

    /// Number of delegated branches.
    pub fn num_delegated(&self) -> usize {
        (0..self.assignment.len()).filter(|&b| self.is_delegated(b)).count()
    }

    /// Branch ids assigned to a delegate lane, ascending.
    pub fn delegated(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.assignment.len()).filter(move |&b| self.is_delegated(b))
    }

    /// Number of distinct lanes this plan actually uses.
    pub fn num_lanes_used(&self) -> usize {
        let mut seen: Vec<usize> = self.delegated().filter_map(|b| self.lane_of(b)).collect();
        seen.sort_unstable();
        seen.dedup();
        seen.len()
    }

    /// Delegated-branch count per lane, padded to at least `lanes`
    /// entries (a device's full lane roster) — the eval table's lane
    /// distribution column.
    pub fn lane_job_counts(&self, lanes: usize) -> Vec<usize> {
        let width = self
            .delegated()
            .filter_map(|b| self.lane_of(b))
            .max()
            .map(|m| m + 1)
            .unwrap_or(0)
            .max(lanes);
        let mut counts = vec![0usize; width];
        for b in self.delegated() {
            counts[self.lane_of(b).expect("delegated branch has a lane")] += 1;
        }
        counts
    }

    /// Total host-visible staging bytes of the delegated branches.
    pub fn total_staging_bytes(&self) -> u64 {
        self.delegated().map(|b| self.staging_bytes[b]).sum()
    }

    /// Modelled busy seconds this plan adds to each lane (sum of the
    /// delegate latencies of the branches assigned there), padded to at
    /// least `lanes` entries.  This is the per-tenant contribution the
    /// serving ledger accumulates so that later placements see the
    /// lanes other models already occupy (see
    /// [`assign_with_loads`]).
    pub fn lane_busy_s(&self, lanes: usize) -> Vec<f64> {
        let width = self
            .delegated()
            .filter_map(|b| self.lane_of(b))
            .max()
            .map(|m| m + 1)
            .unwrap_or(0)
            .max(lanes);
        let mut busy = vec![0.0f64; width];
        for b in self.delegated() {
            busy[self.lane_of(b).expect("delegated branch has a lane")] +=
                self.delegate_latency_s[b];
        }
        busy
    }
}

/// Single-thread share of the SoC memory bandwidth a streaming CPU
/// kernel reaches (mirrors the simulator's single-core share).
const CPU_BW_SHARE: f64 = 0.35;

/// Bytes a node streams at worst-case shapes (inputs + outputs).
fn node_stream_bytes(g: &Graph, id: crate::graph::NodeId) -> u64 {
    let n = g.node(id);
    n.inputs
        .iter()
        .chain(n.outputs.iter())
        .map(|&t| g.tensor_info(t).byte_size_max() as u64)
        .sum()
}

/// Modelled single-core CPU latency of a branch: per unit, the greater
/// of its compute time and its memory-streaming time (§3.1 cost-model
/// terms, evaluated at worst-case shapes).
pub fn cpu_latency(g: &Graph, p: &Partition, plan: &BranchPlan, b: usize, soc: &SocProfile) -> f64 {
    let bw = soc.mem_bw * CPU_BW_SHARE;
    plan.branches[b]
        .units
        .iter()
        .map(|&u| {
            let f = plan.unit_graph.flops[u] as f64;
            let bytes: u64 = match &plan.unit_graph.units[u] {
                Unit::Cpu(id) => node_stream_bytes(g, *id),
                Unit::Region(ri) => {
                    p.regions[*ri].iter().map(|&id| node_stream_bytes(g, id)).sum()
                }
            };
            (f / soc.cpu_flops_per_core).max(bytes as f64 / bw)
        })
        .sum()
}

/// Modelled delegate latency of a branch on one specific lane: per
/// region `L_l + F/(R_l·util_l) + B_boundary/B_l` (Appendix B per
/// lane); CPU glue units inside the branch are charged exactly as
/// [`cpu_latency`] charges them — `max(F/R_cpu, B/(share·B_bw))` — so
/// the two alternatives price identical host work identically and the
/// comparison is never biased by the glue.  `INFINITY` when the branch
/// holds no delegate region **or the lane is unreachable** — the
/// runtime must never be told to delegate to hardware it cannot drive,
/// however fast the lane's modelled rates are.  Remote lanes price
/// through the same form with their link terms substituted: uplink
/// latency as `L_l`, link bandwidth as `B_l`, server rate as
/// `R_l·util_l` (boundary bytes cross the link instead of the on-die
/// interconnect).
pub fn lane_delegate_latency(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    b: usize,
    soc: &SocProfile,
    lane: &AccLane,
) -> f64 {
    if !plan.branches[b].has_delegate || !lane.reachable {
        return f64::INFINITY;
    }
    let bw = soc.mem_bw * CPU_BW_SHARE;
    plan.branches[b]
        .units
        .iter()
        .map(|&u| match &plan.unit_graph.units[u] {
            Unit::Region(ri) => {
                let f = plan.unit_graph.flops[u] as f64;
                let bnd = flops::boundary_bytes(g, &p.regions[*ri]) as f64;
                lane.dispatch_s + f / lane.effective_flops() + bnd / lane.mem_bw
            }
            Unit::Cpu(id) => {
                let f = plan.unit_graph.flops[u] as f64;
                (f / soc.cpu_flops_per_core).max(node_stream_bytes(g, *id) as f64 / bw)
            }
        })
        .sum()
}

/// Best modelled delegate latency of a branch over the device's
/// *reachable* lanes (the one-lane view of [`lane_delegate_latency`]).
/// `INFINITY` when the branch holds no delegate region or no lane is
/// reachable — an nnapi-false device can never look delegatable.
pub fn delegate_latency(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    b: usize,
    soc: &SocProfile,
) -> f64 {
    soc.available_lanes()
        .map(|(_, lane)| lane_delegate_latency(g, p, plan, b, soc, lane))
        .fold(f64::INFINITY, f64::min)
}

/// Modelled CPU energy of a branch, joules: the marginal core power
/// over its modelled single-core latency — the `P_core · core_seconds`
/// term of the Fig. 2 decomposition, priced per branch.
pub fn cpu_energy(g: &Graph, p: &Partition, plan: &BranchPlan, b: usize, soc: &SocProfile) -> f64 {
    soc.p_core_w * cpu_latency(g, p, plan, b, soc)
}

/// Modelled delegate energy of a branch on one specific lane, joules:
/// the lane's power draw over its busy terms (the same
/// `L_l + F/(R_l·util_l) + B_boundary/B_l` time [`lane_delegate_latency`]
/// charges), plus core power over the CPU glue units — so the CPU and
/// delegate alternatives price identical host work identically, in
/// energy exactly as in latency.  `INFINITY` when the branch holds no
/// delegate region or the lane is unreachable.
pub fn lane_delegate_energy(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    b: usize,
    soc: &SocProfile,
    lane: &AccLane,
) -> f64 {
    if !plan.branches[b].has_delegate || !lane.reachable {
        return f64::INFINITY;
    }
    let bw = soc.mem_bw * CPU_BW_SHARE;
    plan.branches[b]
        .units
        .iter()
        .map(|&u| match &plan.unit_graph.units[u] {
            Unit::Region(ri) => {
                let f = plan.unit_graph.flops[u] as f64;
                let bnd = flops::boundary_bytes(g, &p.regions[*ri]) as f64;
                lane.power_w * (lane.dispatch_s + f / lane.effective_flops() + bnd / lane.mem_bw)
            }
            Unit::Cpu(id) => {
                let f = plan.unit_graph.flops[u] as f64;
                soc.p_core_w
                    * (f / soc.cpu_flops_per_core).max(node_stream_bytes(g, *id) as f64 / bw)
            }
        })
        .sum()
}

/// Total modelled energy of a placement plan, joules: every branch
/// priced on its assigned device ([`cpu_energy`] or
/// [`lane_delegate_energy`]) — the figure
/// [`PlacePolicy::EnergyAware`] minimises at `alpha: 0.0`, and what
/// the energy tests compare across policies.
pub fn plan_energy(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    placed: &PlacementPlan,
    soc: &SocProfile,
) -> f64 {
    (0..plan.branches.len())
        .map(|b| match placed.assignment[b] {
            Placement::CpuPool => cpu_energy(g, p, plan, b, soc),
            Placement::Delegate(l) => lane_delegate_energy(g, p, plan, b, soc, &soc.lanes[l]),
        })
        .sum()
}

/// Host-visible staging bytes a delegated branch needs: the boundary
/// tensors of its regions, which cross the host↔accelerator interface
/// and must stay resident on the host while the delegate runs.
pub fn staging_bytes(g: &Graph, p: &Partition, plan: &BranchPlan, b: usize) -> u64 {
    plan.branches[b]
        .units
        .iter()
        .map(|&u| match &plan.unit_graph.units[u] {
            Unit::Region(ri) => flops::boundary_bytes(g, &p.regions[*ri]),
            Unit::Cpu(_) => 0,
        })
        .sum()
}

/// Transfer bytes of a branch spilled to a remote lane
/// ([`RemoteLane`](crate::device::RemoteLane)): the same region
/// boundary tensors [`staging_bytes`] prices, crossing the device–edge
/// link instead of the on-die interconnect — for a remote-assigned
/// branch, transfer bytes *replace* staging bytes (the host holds the
/// transfer buffers from dispatch until the downlinked outputs merge,
/// so the governor lease accounting is byte-for-byte unchanged).
pub fn transfer_bytes(g: &Graph, p: &Partition, plan: &BranchPlan, b: usize) -> u64 {
    staging_bytes(g, p, plan, b)
}

/// Can this branch execute on a delegate lane at all?  Requires a
/// delegate region and forbids `OpClass::Dynamic` operators and dynamic
/// shapes anywhere in the branch (NNAPI-style static requirement —
/// dynamic work is exactly what the paper's fallback story keeps on the
/// CPU).
pub fn delegate_safe(g: &Graph, p: &Partition, plan: &BranchPlan, b: usize) -> bool {
    plan.branches[b].has_delegate
        && plan.branch_nodes(g, p, b).iter().all(|&id| {
            g.node(id).kind.class() != OpClass::Dynamic && !g.node_has_dynamic_shape(id)
        })
}

/// Assign every branch of a plan a [`Placement`] for one device.
///
/// Under [`PlacePolicy::Auto`] a branch is delegated iff it is
/// [`delegate_safe`] and some *reachable* lane's modelled delegate
/// latency beats its modelled CPU latency; among the lanes that beat
/// the CPU, the branch goes to the one with the least accumulated
/// modelled busy time (ties: faster lane, then lower index), so a
/// multi-queue SoC spreads delegated branches instead of piling them
/// onto the fastest lane.  [`PlacePolicy::ForceCpu`] pins everything to
/// the CPU pool (the bit-identical baseline).
/// [`PlacePolicy::EnergyAware`] runs the same algorithm on the blended
/// score `alpha·latency + (1−alpha)·energy` — at `alpha: 1.0` the
/// scores *are* the latencies, so it reproduces `Auto` exactly.  The
/// modelled latencies and staging bytes are recorded on the returned
/// plan so executors and benches can report the decision basis.
pub fn assign(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    soc: &SocProfile,
    policy: PlacePolicy,
) -> PlacementPlan {
    assign_with_loads(g, p, plan, soc, policy, &[])
}

/// [`assign`] against pre-existing per-lane loads: the busy-time
/// accumulator starts from `loads[l]` instead of zero, so a model
/// placed on a device other tenants already occupy is steered toward
/// the lanes they left idle.  `loads` is indexed by lane (missing
/// entries are zero) and expressed in the policy's score units —
/// seconds under [`PlacePolicy::Auto`], blended score under
/// [`PlacePolicy::EnergyAware`].  The serving tier feeds it from the
/// other tenants' [`PlacementPlan::lane_busy_s`] sums; `assign` is the
/// empty-device special case.
pub fn assign_with_loads(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    soc: &SocProfile,
    policy: PlacePolicy,
    loads: &[f64],
) -> PlacementPlan {
    let (w_lat, w_en) = match policy {
        PlacePolicy::EnergyAware { alpha } => (alpha, 1.0 - alpha),
        PlacePolicy::Auto | PlacePolicy::ForceCpu => (1.0, 0.0),
    };
    let nb = plan.branches.len();
    let mut out = PlacementPlan::blank(nb);
    let mut busy = vec![0.0f64; soc.lanes.len()];
    for (l, b) in busy.iter_mut().enumerate() {
        *b = loads.get(l).copied().unwrap_or(0.0);
    }
    for b in 0..nb {
        out.cpu_latency_s[b] = cpu_latency(g, p, plan, b, soc);
        if !delegate_safe(g, p, plan, b) {
            continue;
        }
        let cpu_score = w_lat * out.cpu_latency_s[b] + w_en * cpu_energy(g, p, plan, b, soc);
        // least-busy lane whose blended score beats the CPU's
        let mut best: Option<(usize, f64, f64)> = None; // (lane, score, latency)
        let mut best_lat = f64::INFINITY; // best lane latency overall (reporting)
        for (l, lane) in soc.lanes.iter().enumerate() {
            let lat = lane_delegate_latency(g, p, plan, b, soc, lane);
            best_lat = best_lat.min(lat);
            if !lat.is_finite() {
                // unreachable lane (or no region): never a target, and
                // 0·∞ would poison the blended score with a NaN
                continue;
            }
            let score = w_lat * lat + w_en * lane_delegate_energy(g, p, plan, b, soc, lane);
            if score >= cpu_score {
                continue;
            }
            let better = match best {
                None => true,
                Some((bl, bscore, _)) => {
                    busy[l] < busy[bl] || (busy[l] == busy[bl] && score < bscore)
                }
            };
            if better {
                best = Some((l, score, lat));
            }
        }
        out.delegate_latency_s[b] = best.map(|(_, _, lat)| lat).unwrap_or(best_lat);
        if policy != PlacePolicy::ForceCpu {
            if let Some((l, score, _)) = best {
                out.assignment[b] = Placement::Delegate(l);
                // remote lanes hold *transfer* bytes over the link
                // instead of on-die staging — same boundary tensors,
                // same host-resident lease, so the governor accounting
                // is identical either way
                out.staging_bytes[b] = if soc.lanes[l].remote {
                    transfer_bytes(g, p, plan, b)
                } else {
                    staging_bytes(g, p, plan, b)
                };
                busy[l] += score;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::branch::{self, DEFAULT_BETA};
    use crate::models::micro;
    use crate::partition::{partition, CostModel};

    fn loose() -> CostModel {
        CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX }
    }

    #[test]
    fn heavy_trunk_delegates_on_pixel6() {
        let g = micro::fallback_heavy(4, 4, 128, 6);
        let soc = SocProfile::pixel6();
        let p = partition(&g, &loose());
        assert!(!p.regions.is_empty(), "trunk must form a region");
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let placed = assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
        assert!(placed.num_delegated() >= 1, "heavy static trunk should delegate");
        for b in placed.delegated() {
            assert!(plan.branches[b].has_delegate);
            assert!(placed.staging_bytes[b] > 0, "delegate I/O needs staging");
            assert!(placed.delegate_latency_s[b] < placed.cpu_latency_s[b]);
            let lane = placed.lane_of(b).expect("delegated branch carries a lane");
            assert!(soc.lanes[lane].reachable, "assigned lane must be reachable");
        }
        assert!(placed.total_staging_bytes() > 0);
    }

    #[test]
    fn force_cpu_places_nothing_on_delegate() {
        let g = micro::fallback_heavy(4, 4, 128, 6);
        let soc = SocProfile::pixel6();
        let p = partition(&g, &loose());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let placed = assign(&g, &p, &plan, &soc, PlacePolicy::ForceCpu);
        assert_eq!(placed.num_delegated(), 0);
        assert!(placed.assignment.iter().all(|&pl| pl == Placement::CpuPool));
        assert_eq!(placed.total_staging_bytes(), 0);
        assert_eq!(placed.num_lanes_used(), 0);
    }

    #[test]
    fn dynamic_branches_never_delegate() {
        let g = micro::mixed();
        let soc = SocProfile::pixel6();
        let p = partition(&g, &loose());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let placed = assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
        for b in placed.delegated() {
            for id in plan.branch_nodes(&g, &p, b) {
                assert_ne!(g.node(id).kind.class(), OpClass::Dynamic);
                assert!(!g.node_has_dynamic_shape(id));
            }
        }
    }

    #[test]
    fn unreachable_device_never_delegates() {
        // Regression for the nnapi-reachability bug: the P30 Pro's
        // accelerator is runtime-unreachable, yet the heavy fallback
        // trunk's modelled delegate time *beats* its CPU time — before
        // the reachability gate this graph delegated on p30.  Placement
        // must keep everything on the CPU and report the lane as
        // un-delegatable.
        let g = micro::fallback_heavy(6, 24, 448, 4);
        let soc = SocProfile::p30_pro();
        let p = partition(&g, &loose());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        // the modelled rates alone would say "delegate": compute the
        // raw lane figure with reachability ignored
        let b = (0..plan.branches.len())
            .find(|&b| plan.branches[b].has_delegate)
            .expect("trunk branch");
        let mut ghost = soc.lanes[0].clone();
        ghost.reachable = true;
        let raw = lane_delegate_latency(&g, &p, &plan, b, &soc, &ghost);
        let cpu = cpu_latency(&g, &p, &plan, b, &soc);
        assert!(raw < cpu, "premise: modelled rates alone favour the delegate");
        // ...but the reachability gate wins
        assert!(lane_delegate_latency(&g, &p, &plan, b, &soc, &soc.lanes[0]).is_infinite());
        assert!(delegate_latency(&g, &p, &plan, b, &soc).is_infinite());
        let placed = assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
        assert_eq!(placed.num_delegated(), 0, "unreachable hardware must never be a target");
    }

    #[test]
    fn fast_but_unreachable_profile_never_delegates() {
        // An nnapi-false profile with *fast* modelled rates (the exact
        // hypothetical from the bug report): every lane unreachable,
        // rates better than pixel6's TPU.
        let mut soc = SocProfile::pixel6();
        soc.nnapi = false;
        for lane in &mut soc.lanes {
            lane.flops *= 4.0;
            lane.dispatch_s /= 4.0;
            lane.reachable = false;
        }
        let g = micro::fallback_heavy(4, 4, 128, 6);
        let p = partition(&g, &loose());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let placed = assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
        assert_eq!(placed.num_delegated(), 0);
        for b in 0..plan.branches.len() {
            assert!(placed.delegate_latency_s[b].is_infinite());
        }
    }

    #[test]
    fn two_lane_device_balances_delegated_branches() {
        // two independent heavy trunks: the least-busy balancing rule
        // must split them across pixel6's TPU + GPU lanes rather than
        // serialise both onto the fastest queue
        let g = micro::fallback_heavy_lanes(2, 2, 4, 128, 6);
        let soc = SocProfile::pixel6();
        let p = partition(&g, &loose());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        assert!(p.regions.len() >= 2, "two trunks, two regions");
        let placed = assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
        assert_eq!(placed.num_delegated(), 2, "both trunks delegate");
        assert_eq!(placed.num_lanes_used(), 2, "busy-time balancing spreads lanes");
        let counts = placed.lane_job_counts(soc.lanes.len());
        assert_eq!(counts, vec![1, 1]);
    }

    #[test]
    fn preloaded_lane_steers_single_trunk_away() {
        // one heavy trunk, empty device: the fastest lane wins.  Same
        // trunk with that lane pre-loaded (another tenant's busy time):
        // placement must move to the idle lane — the serving ledger's
        // whole premise.
        let g = micro::fallback_heavy(4, 4, 128, 6);
        let soc = SocProfile::pixel6();
        let p = partition(&g, &loose());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let empty = assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
        assert_eq!(empty.num_delegated(), 1, "single trunk delegates");
        let home = empty.delegated().next().and_then(|b| empty.lane_of(b)).unwrap();
        let mut loads = vec![0.0; soc.lanes.len()];
        loads[home] = 1.0; // a whole second of tenant busy time
        let steered = assign_with_loads(&g, &p, &plan, &soc, PlacePolicy::Auto, &loads);
        assert_eq!(steered.num_delegated(), 1);
        let away = steered.delegated().next().and_then(|b| steered.lane_of(b)).unwrap();
        assert_ne!(away, home, "pre-loaded lane must lose the trunk");
        // per-tenant busy contribution feeds back into the ledger
        let busy = steered.lane_busy_s(soc.lanes.len());
        assert_eq!(busy.len(), soc.lanes.len());
        assert!(busy[away] > 0.0 && busy[home] == 0.0);
        assert!((busy[away] - steered.delegate_latency_s[steered.delegated().next().unwrap()])
            .abs()
            < 1e-12);
    }

    #[test]
    fn assign_is_assign_with_empty_loads() {
        for g in [
            micro::fallback_heavy(4, 4, 128, 6),
            micro::fallback_heavy_lanes(2, 2, 4, 128, 6),
        ] {
            let soc = SocProfile::pixel6();
            let p = partition(&g, &loose());
            let plan = branch::plan(&g, &p, DEFAULT_BETA);
            let a = assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
            let b = assign_with_loads(&g, &p, &plan, &soc, PlacePolicy::Auto, &[]);
            assert_eq!(a.assignment, b.assignment, "{}", g.name);
            let c = assign_with_loads(&g, &p, &plan, &soc, PlacePolicy::Auto, &[0.0, 0.0]);
            assert_eq!(a.assignment, c.assignment, "zero loads are no loads");
        }
    }

    #[test]
    fn high_dispatch_device_keeps_small_regions_on_cpu() {
        // a modest trunk: worth offloading on the TPU-class pixel6,
        // never on the P30 Pro whose only lane is runtime-unreachable
        let g = micro::fallback_heavy(2, 3, 48, 2);
        let p = partition(&g, &loose());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let fast = assign(&g, &p, &plan, &SocProfile::pixel6(), PlacePolicy::Auto);
        let slow = assign(&g, &p, &plan, &SocProfile::p30_pro(), PlacePolicy::Auto);
        assert!(
            slow.num_delegated() <= fast.num_delegated(),
            "higher dispatch cost must never delegate more"
        );
        assert_eq!(slow.num_delegated(), 0, "p30's lanes are unreachable");
    }

    #[test]
    fn energy_aware_alpha_one_reproduces_auto() {
        // at alpha 1.0 the blended scores ARE the latencies, so the
        // whole decision trace (eligibility, balancing, tie-breaks)
        // must match Auto bit for bit
        for g in [
            micro::fallback_heavy(4, 4, 128, 6),
            micro::fallback_heavy_lanes(2, 2, 4, 128, 6),
            micro::fallback_heavy(2, 3, 48, 2),
        ] {
            let soc = SocProfile::pixel6();
            let p = partition(&g, &loose());
            let plan = branch::plan(&g, &p, DEFAULT_BETA);
            let auto_pl = assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
            let ea = assign(&g, &p, &plan, &soc, PlacePolicy::EnergyAware { alpha: 1.0 });
            assert_eq!(auto_pl.assignment, ea.assignment, "{}", g.name);
            assert_eq!(auto_pl.delegate_latency_s, ea.delegate_latency_s, "{}", g.name);
            assert_eq!(auto_pl.staging_bytes, ea.staging_bytes, "{}", g.name);
        }
    }

    #[test]
    fn energy_aware_zero_never_uses_more_energy() {
        // pure-energy placement minimises per-branch energy greedily,
        // so its plan energy can never exceed the latency-first plan's
        for g in [
            micro::fallback_heavy(4, 4, 128, 6),
            micro::fallback_heavy(4, 3, 72, 6),
            micro::fallback_heavy_lanes(2, 2, 4, 128, 6),
        ] {
            let soc = SocProfile::pixel6();
            let p = partition(&g, &loose());
            let plan = branch::plan(&g, &p, DEFAULT_BETA);
            let auto_pl = assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
            let ea0 = assign(&g, &p, &plan, &soc, PlacePolicy::EnergyAware { alpha: 0.0 });
            let e_auto = plan_energy(&g, &p, &plan, &auto_pl, &soc);
            let e_ea0 = plan_energy(&g, &p, &plan, &ea0, &soc);
            assert!(e_ea0.is_finite() && e_auto.is_finite(), "{}", g.name);
            assert!(e_ea0 <= e_auto, "{}: {e_ea0} > {e_auto}", g.name);
        }
    }

    #[test]
    fn remote_lane_prices_through_the_same_closed_form() {
        // a remote lane is AccLane-shaped by construction: the generic
        // pricing must equal the hand-computed Appendix-B form with
        // uplink/link/server terms substituted, and transfer bytes must
        // equal the staging bytes the lease accounting already prices
        let g = micro::fallback_heavy(4, 4, 128, 6);
        let base = SocProfile::pixel6();
        let remote = crate::device::RemoteLane::edge_server();
        let soc = base.with_remote(&remote);
        let rl = soc.remote_lane().expect("remote lane attached");
        let p = partition(&g, &loose());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let b = (0..plan.branches.len())
            .find(|&b| plan.branches[b].has_delegate)
            .expect("trunk branch");
        let lat = lane_delegate_latency(&g, &p, &plan, b, &soc, &soc.lanes[rl]);
        assert!(lat.is_finite() && lat > remote.uplink_latency_s);
        // hand-computed: one region unit + CPU glue
        let glue: f64 = plan.branches[b]
            .units
            .iter()
            .filter_map(|&u| match &plan.unit_graph.units[u] {
                Unit::Cpu(id) => {
                    let f = plan.unit_graph.flops[u] as f64;
                    Some((f / soc.cpu_flops_per_core).max(
                        node_stream_bytes(&g, *id) as f64 / (soc.mem_bw * CPU_BW_SHARE),
                    ))
                }
                Unit::Region(_) => None,
            })
            .sum();
        let region_f: f64 = plan.branches[b]
            .units
            .iter()
            .filter(|&&u| matches!(plan.unit_graph.units[u], Unit::Region(_)))
            .map(|&u| plan.unit_graph.flops[u] as f64)
            .sum();
        let bytes = staging_bytes(&g, &p, &plan, b) as f64;
        let expect = remote.uplink_latency_s
            + region_f / (remote.server_flops * remote.server_utilization)
            + bytes / remote.link_bw
            + glue;
        assert!((lat - expect).abs() < 1e-12, "priced {lat}, expected {expect}");
        assert_eq!(
            transfer_bytes(&g, &p, &plan, b),
            staging_bytes(&g, &p, &plan, b),
            "transfer bytes replace staging bytes byte-for-byte"
        );
    }

    #[test]
    fn knocked_out_local_lanes_spill_to_remote_but_dynamic_stays_cpu() {
        // all on-die lanes unreachable: the remote lane is the only
        // target left, and Auto takes it for the heavy static trunk —
        // while dynamic branches stay CPU exactly as on-die rules say
        let mut base = SocProfile::pixel6();
        for lane in &mut base.lanes {
            lane.reachable = false;
        }
        let soc = base.with_remote(&crate::device::RemoteLane::edge_server());
        let rl = soc.remote_lane().unwrap();
        let g = micro::fallback_heavy(6, 24, 448, 4);
        let p = partition(&g, &loose());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let placed = assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
        assert!(placed.num_delegated() >= 1, "trunk must spill to the edge");
        for b in placed.delegated() {
            assert_eq!(placed.lane_of(b), Some(rl), "only the remote lane is reachable");
            assert_eq!(
                placed.staging_bytes[b],
                transfer_bytes(&g, &p, &plan, b),
                "remote assignment records transfer bytes"
            );
            for id in plan.branch_nodes(&g, &p, b) {
                assert_ne!(g.node(id).kind.class(), OpClass::Dynamic);
            }
        }
        // dynamic work never delegates, remote lane or not
        let gd = micro::mixed();
        let pd = partition(&gd, &loose());
        let pland = branch::plan(&gd, &pd, DEFAULT_BETA);
        let placedd = assign(&gd, &pd, &pland, &soc, PlacePolicy::Auto);
        for b in placedd.delegated() {
            for id in pland.branch_nodes(&gd, &pd, b) {
                assert_ne!(gd.node(id).kind.class(), OpClass::Dynamic);
                assert!(!gd.node_has_dynamic_shape(id));
            }
        }
    }

    #[test]
    fn modelled_latencies_are_finite_and_positive_for_cpu() {
        let g = micro::parallel_chains(3, 4);
        let soc = SocProfile::redmi_k50();
        let p = partition(
            &g,
            &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
        );
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let placed = assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
        for b in 0..plan.branches.len() {
            assert!(placed.cpu_latency_s[b].is_finite());
            assert!(placed.cpu_latency_s[b] > 0.0);
            assert!(placed.delegate_latency_s[b].is_infinite(), "no regions -> no delegate");
        }
    }
}
