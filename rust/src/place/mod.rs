//! Device placement for the real runtime (paper §3.1 applied to
//! execution, not just simulation).
//!
//! Until this module existed, heterogeneity lived only in the `sim`
//! device-time model: the real [`Engine`](crate::exec::Engine) ran
//! every wave on host CPU threads, delegate regions included.  This
//! module closes that sim-vs-exec gap.  Given a Branch-Layer plan and a
//! [`SocProfile`], [`assign`] gives every branch a [`Placement`] — CPU
//! thread pool or accelerator delegate — by minimising the modelled
//! latency from the profile's parameters:
//!
//! ```text
//!   t_cpu(b)      = Σ_units max(F / R_cpu, B / (share · B_bw))
//!   t_delegate(b) = Σ_regions (L_dispatch + F / (R_acc · util) + B_boundary / B_bw)
//!                 + Σ_glue    F / R_cpu
//! ```
//!
//! the same Appendix-B terms the `sim` timing model and the
//! [`CostModel`](crate::partition::CostModel) thresholds are built
//! from.  A branch is delegated only when `t_delegate < t_cpu` *and*
//! it is delegate-safe: it contains a delegate region and carries no
//! `OpClass::Dynamic` operator or dynamically-shaped tensor — dynamic
//! work always falls back to the CPU pool, which is what keeps the
//! §3.4 segmented path's barrier segments host-side by construction.
//!
//! The plan also prices what delegation *costs the host*: each
//! delegated branch needs host-visible staging buffers for delegate
//! I/O (the region boundary tensors that cross the host↔accelerator
//! interface).  [`sched::placed_layer_demand`](crate::sched::placed_layer_demand)
//! folds those staging bytes into the governor lease of every layer
//! that co-executes, so offloading never becomes a way to smuggle
//! memory past the §3.3 budget.
//!
//! Downstream consumers:
//! * [`exec::Engine::run_placed`](crate::exec::Engine::run_placed) —
//!   executes delegated branches on an async
//!   [`DelegateWorker`](crate::exec::DelegateWorker) lane that
//!   overlaps wall-clock with the CPU fallback waves;
//! * [`ctrl::SegmentedEngine::with_placement`](crate::ctrl::SegmentedEngine::with_placement)
//!   — dynamic models: resolved dynamic segments stay on CPU, static
//!   neighbours may be delegated;
//! * [`serve::placed_pipeline_executor`](crate::serve::placed_pipeline_executor)
//!   — per-model placement chosen at register time.
//!
//! # Examples
//!
//! ```
//! use parallax::branch::{self, DEFAULT_BETA};
//! use parallax::device::SocProfile;
//! use parallax::models::micro;
//! use parallax::partition::{partition, CostModel};
//! use parallax::place::{self, PlacePolicy, Placement};
//!
//! let g = micro::fallback_heavy(4, 4, 512, 4);
//! let soc = SocProfile::pixel6();
//! let p = partition(&g, &CostModel::from_profile(&soc));
//! let plan = branch::plan(&g, &p, DEFAULT_BETA);
//! let placed = place::assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
//! // the heavy matmul trunk goes to the delegate, fallback chains stay CPU
//! assert!(placed.num_delegated() >= 1);
//! let forced = place::assign(&g, &p, &plan, &soc, PlacePolicy::ForceCpu);
//! assert!(forced.assignment.iter().all(|&pl| pl == Placement::CpuPool));
//! ```

use crate::branch::{BranchPlan, Unit};
use crate::device::SocProfile;
use crate::flops;
use crate::graph::{Graph, OpClass};
use crate::partition::Partition;

/// Where one branch executes (branch-level, unlike
/// [`partition::Placement`](crate::partition::Placement) which labels
/// individual nodes during region discovery).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Host CPU thread pool (the classic wave path).
    CpuPool,
    /// Accelerator delegate, executed on the async delegate lane.
    Delegate,
}

/// How [`assign`] decides placements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacePolicy {
    /// Minimise modelled latency: delegate exactly the delegate-safe
    /// branches whose modelled accelerator time beats their CPU time.
    Auto,
    /// Force everything onto the CPU pool — the baseline configuration
    /// whose execution is bit-identical to the classic
    /// [`Engine::run`](crate::exec::Engine::run).
    ForceCpu,
}

/// A complete branch → device assignment plus the modelled figures it
/// was decided from.  Built once per (model, device) by [`assign`];
/// immutable afterwards.
#[derive(Clone, Debug)]
pub struct PlacementPlan {
    /// Per-branch placement, indexed by branch id.
    pub assignment: Vec<Placement>,
    /// Modelled single-core CPU latency per branch, seconds.
    pub cpu_latency_s: Vec<f64>,
    /// Modelled delegate latency per branch, seconds
    /// (`f64::INFINITY` for branches that cannot delegate).
    pub delegate_latency_s: Vec<f64>,
    /// Host-visible staging bytes for delegate I/O per branch (region
    /// boundary tensors); 0 for CPU-placed branches.
    pub staging_bytes: Vec<u64>,
}

impl PlacementPlan {
    /// Placement with every branch on the CPU pool (no modelling).
    pub fn cpu_only(num_branches: usize) -> Self {
        Self {
            assignment: vec![Placement::CpuPool; num_branches],
            cpu_latency_s: vec![0.0; num_branches],
            delegate_latency_s: vec![f64::INFINITY; num_branches],
            staging_bytes: vec![0; num_branches],
        }
    }

    /// Is branch `b` assigned to the accelerator delegate?
    pub fn is_delegated(&self, b: usize) -> bool {
        self.assignment[b] == Placement::Delegate
    }

    /// Number of delegated branches.
    pub fn num_delegated(&self) -> usize {
        self.assignment.iter().filter(|&&p| p == Placement::Delegate).count()
    }

    /// Branch ids assigned to the delegate, ascending.
    pub fn delegated(&self) -> impl Iterator<Item = usize> + '_ {
        self.assignment
            .iter()
            .enumerate()
            .filter(|(_, &p)| p == Placement::Delegate)
            .map(|(b, _)| b)
    }

    /// Total host-visible staging bytes of the delegated branches.
    pub fn total_staging_bytes(&self) -> u64 {
        self.delegated().map(|b| self.staging_bytes[b]).sum()
    }
}

/// Single-thread share of the SoC memory bandwidth a streaming CPU
/// kernel reaches (mirrors the simulator's single-core share).
const CPU_BW_SHARE: f64 = 0.35;

/// Bytes a node streams at worst-case shapes (inputs + outputs).
fn node_stream_bytes(g: &Graph, id: crate::graph::NodeId) -> u64 {
    let n = g.node(id);
    n.inputs
        .iter()
        .chain(n.outputs.iter())
        .map(|&t| g.tensor_info(t).byte_size_max() as u64)
        .sum()
}

/// Modelled single-core CPU latency of a branch: per unit, the greater
/// of its compute time and its memory-streaming time (§3.1 cost-model
/// terms, evaluated at worst-case shapes).
pub fn cpu_latency(g: &Graph, p: &Partition, plan: &BranchPlan, b: usize, soc: &SocProfile) -> f64 {
    let bw = soc.mem_bw * CPU_BW_SHARE;
    plan.branches[b]
        .units
        .iter()
        .map(|&u| {
            let f = plan.unit_graph.flops[u] as f64;
            let bytes: u64 = match &plan.unit_graph.units[u] {
                Unit::Cpu(id) => node_stream_bytes(g, *id),
                Unit::Region(ri) => {
                    p.regions[*ri].iter().map(|&id| node_stream_bytes(g, id)).sum()
                }
            };
            (f / soc.cpu_flops_per_core).max(bytes as f64 / bw)
        })
        .sum()
}

/// Modelled delegate latency of a branch: per region
/// `L + F/(R_acc·util) + B_boundary/B_bw` (Appendix B); CPU glue units
/// inside the branch are charged exactly as [`cpu_latency`] charges
/// them — `max(F/R_cpu, B/(share·B_bw))` — so the two alternatives
/// price identical host work identically and the comparison is never
/// biased by the glue.  `INFINITY` when the branch holds no delegate
/// region.
pub fn delegate_latency(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    b: usize,
    soc: &SocProfile,
) -> f64 {
    if !plan.branches[b].has_delegate {
        return f64::INFINITY;
    }
    let bw = soc.mem_bw * CPU_BW_SHARE;
    plan.branches[b]
        .units
        .iter()
        .map(|&u| match &plan.unit_graph.units[u] {
            Unit::Region(ri) => {
                let f = plan.unit_graph.flops[u] as f64;
                let bnd = flops::boundary_bytes(g, &p.regions[*ri]) as f64;
                soc.acc_dispatch_s
                    + f / (soc.acc_flops * soc.acc_utilization)
                    + bnd / soc.mem_bw
            }
            Unit::Cpu(id) => {
                let f = plan.unit_graph.flops[u] as f64;
                (f / soc.cpu_flops_per_core).max(node_stream_bytes(g, *id) as f64 / bw)
            }
        })
        .sum()
}

/// Host-visible staging bytes a delegated branch needs: the boundary
/// tensors of its regions, which cross the host↔accelerator interface
/// and must stay resident on the host while the delegate runs.
pub fn staging_bytes(g: &Graph, p: &Partition, plan: &BranchPlan, b: usize) -> u64 {
    plan.branches[b]
        .units
        .iter()
        .map(|&u| match &plan.unit_graph.units[u] {
            Unit::Region(ri) => flops::boundary_bytes(g, &p.regions[*ri]),
            Unit::Cpu(_) => 0,
        })
        .sum()
}

/// Can this branch execute on the delegate at all?  Requires a delegate
/// region and forbids `OpClass::Dynamic` operators and dynamic shapes
/// anywhere in the branch (NNAPI-style static requirement — dynamic
/// work is exactly what the paper's fallback story keeps on the CPU).
pub fn delegate_safe(g: &Graph, p: &Partition, plan: &BranchPlan, b: usize) -> bool {
    plan.branches[b].has_delegate
        && plan.branch_nodes(g, p, b).iter().all(|&id| {
            g.node(id).kind.class() != OpClass::Dynamic && !g.node_has_dynamic_shape(id)
        })
}

/// Assign every branch of a plan a [`Placement`] for one device.
///
/// Under [`PlacePolicy::Auto`] a branch is delegated iff it is
/// [`delegate_safe`] and its modelled delegate latency beats its
/// modelled CPU latency; [`PlacePolicy::ForceCpu`] pins everything to
/// the CPU pool (the bit-identical baseline).  The modelled latencies
/// and staging bytes are recorded on the returned plan so executors
/// and benches can report the decision basis.
pub fn assign(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    soc: &SocProfile,
    policy: PlacePolicy,
) -> PlacementPlan {
    let nb = plan.branches.len();
    let mut out = PlacementPlan {
        assignment: vec![Placement::CpuPool; nb],
        cpu_latency_s: vec![0.0; nb],
        delegate_latency_s: vec![f64::INFINITY; nb],
        staging_bytes: vec![0; nb],
    };
    for b in 0..nb {
        out.cpu_latency_s[b] = cpu_latency(g, p, plan, b, soc);
        if !delegate_safe(g, p, plan, b) {
            continue;
        }
        out.delegate_latency_s[b] = delegate_latency(g, p, plan, b, soc);
        if policy == PlacePolicy::Auto && out.delegate_latency_s[b] < out.cpu_latency_s[b] {
            out.assignment[b] = Placement::Delegate;
            out.staging_bytes[b] = staging_bytes(g, p, plan, b);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::branch::{self, DEFAULT_BETA};
    use crate::models::micro;
    use crate::partition::{partition, CostModel};

    fn loose() -> CostModel {
        CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX }
    }

    #[test]
    fn heavy_trunk_delegates_on_pixel6() {
        let g = micro::fallback_heavy(4, 4, 128, 6);
        let soc = SocProfile::pixel6();
        let p = partition(&g, &loose());
        assert!(!p.regions.is_empty(), "trunk must form a region");
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let placed = assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
        assert!(placed.num_delegated() >= 1, "heavy static trunk should delegate");
        for b in placed.delegated() {
            assert!(plan.branches[b].has_delegate);
            assert!(placed.staging_bytes[b] > 0, "delegate I/O needs staging");
            assert!(placed.delegate_latency_s[b] < placed.cpu_latency_s[b]);
        }
        assert!(placed.total_staging_bytes() > 0);
    }

    #[test]
    fn force_cpu_places_nothing_on_delegate() {
        let g = micro::fallback_heavy(4, 4, 128, 6);
        let soc = SocProfile::pixel6();
        let p = partition(&g, &loose());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let placed = assign(&g, &p, &plan, &soc, PlacePolicy::ForceCpu);
        assert_eq!(placed.num_delegated(), 0);
        assert!(placed.assignment.iter().all(|&pl| pl == Placement::CpuPool));
        assert_eq!(placed.total_staging_bytes(), 0);
    }

    #[test]
    fn dynamic_branches_never_delegate() {
        let g = micro::mixed();
        let soc = SocProfile::pixel6();
        let p = partition(&g, &loose());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let placed = assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
        for b in placed.delegated() {
            for id in plan.branch_nodes(&g, &p, b) {
                assert_ne!(g.node(id).kind.class(), OpClass::Dynamic);
                assert!(!g.node_has_dynamic_shape(id));
            }
        }
    }

    #[test]
    fn high_dispatch_device_keeps_small_regions_on_cpu() {
        // a modest trunk: worth offloading on the TPU-class pixel6,
        // not through the P30 Pro's 1.1 ms OpenCL dispatch path
        let g = micro::fallback_heavy(2, 3, 48, 2);
        let p = partition(&g, &loose());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let fast = assign(&g, &p, &plan, &SocProfile::pixel6(), PlacePolicy::Auto);
        let slow = assign(&g, &p, &plan, &SocProfile::p30_pro(), PlacePolicy::Auto);
        assert!(
            slow.num_delegated() <= fast.num_delegated(),
            "higher dispatch cost must never delegate more"
        );
        assert_eq!(slow.num_delegated(), 0, "48³ matmuls lose to 1.1 ms dispatch");
    }

    #[test]
    fn modelled_latencies_are_finite_and_positive_for_cpu() {
        let g = micro::parallel_chains(3, 4);
        let soc = SocProfile::redmi_k50();
        let p = partition(
            &g,
            &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
        );
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let placed = assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
        for b in 0..plan.branches.len() {
            assert!(placed.cpu_latency_s[b].is_finite());
            assert!(placed.cpu_latency_s[b] > 0.0);
            assert!(placed.delegate_latency_s[b].is_infinite(), "no regions -> no delegate");
        }
    }
}
