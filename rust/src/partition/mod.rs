//! Optimized delegate partitioning (paper §3.1 + Appendix B).
//!
//! Given a graph and a device's accelerator parameters, decide which
//! regions run on the accelerator ("delegate regions") and which fall
//! back to the CPU.  The naive framework behaviour (offload every
//! supported op) fragments the graph into many small delegate islands
//! whose dispatch + transfer overhead exceeds their compute; Parallax
//! prunes those with an analytical cost model:
//!
//! A candidate region S is offloaded only if
//!
//! ```text
//!   N = |V(S)|        >= 3
//!   F = Σ FLOPs(v)    >= 1e9            (compute-bound condition)
//!   B/F               <= 0.1 bytes/FLOP (memory-bound condition)
//! ```
//!
//! derived from `T_offload = L + F/R_acc + B/B_bw < F/R_cpu` (App. B).

use std::collections::HashSet;

use crate::flops;
use crate::graph::{Graph, NodeId};

/// Thresholds of the §3.1 cost model.  Defaults are the paper's relaxed
/// values; [`CostModel::from_device`] derives the strict ones from SoC
/// parameters.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Minimum ops per region (N ≥ 3).
    pub min_ops: usize,
    /// Minimum region FLOPs (F ≥ 1e9).
    pub min_flops: u64,
    /// Maximum boundary-bytes per FLOP (B/F ≤ 0.1).
    pub max_bytes_per_flop: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        // paper's relaxed thresholds (§3.1); min_flops further relaxed
        // from 1e9 to 3e8 because NNAPI-unsupported ops (LayerNorm,
        // GELU) bound our transformer delegate regions at ~0.3 GFLOP —
        // see EXPERIMENTS.md §Deviations.
        Self { min_ops: 3, min_flops: 300_000_000, max_bytes_per_flop: 0.1 }
    }
}

impl CostModel {
    /// Derive strict thresholds from SoC parameters (Appendix B):
    /// `F > L·R_cpu` and `B/F < B_bw/R_acc`.
    pub fn from_device(
        dispatch_latency_s: f64,
        r_cpu_macs: f64,
        r_acc_macs: f64,
        bw_bytes: f64,
    ) -> Self {
        Self {
            min_ops: 3,
            min_flops: (dispatch_latency_s * r_cpu_macs * 2.0) as u64,
            max_bytes_per_flop: bw_bytes / (2.0 * r_acc_macs),
        }
    }

    /// [`CostModel::from_device`] with every parameter read off a
    /// [`SocProfile`](crate::device::SocProfile): dispatch latency,
    /// single-big-core CPU rate, sustained accelerator rate and
    /// host↔accelerator bandwidth.  This is the placement-aware wiring
    /// — the same device model that decides branch placement
    /// (`crate::place`) also prices the partitioner's keep-or-prune
    /// cut, so what gets offloaded and what it costs to offload come
    /// from one set of numbers.
    pub fn from_profile(soc: &crate::device::SocProfile) -> Self {
        Self::from_device(
            soc.acc_dispatch_s,
            soc.cpu_flops_per_core / 2.0,
            soc.acc_flops * soc.acc_utilization / 2.0,
            soc.mem_bw,
        )
    }

    /// Paper's check: keep a region on the accelerator?
    pub fn keep_delegate(&self, n: usize, f: u64, b: u64) -> bool {
        n >= self.min_ops
            && f >= self.min_flops
            && (b as f64) <= self.max_bytes_per_flop * f as f64
    }
}

/// How one node is placed after partitioning.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Runs inside delegate region `idx`.
    Delegate { region: usize },
    /// CPU fallback.
    Cpu,
}

/// Result of delegate partitioning.
#[derive(Clone, Debug)]
pub struct Partition {
    /// Node placements, indexed by NodeId.
    pub placement: Vec<Placement>,
    /// Delegate regions (maximal connected sets of supported ops that
    /// survived pruning), in discovery order.
    pub regions: Vec<Vec<NodeId>>,
    /// Candidate regions rejected by the cost model (returned to CPU).
    pub pruned: Vec<Vec<NodeId>>,
}

impl Partition {
    pub fn is_cpu(&self, id: NodeId) -> bool {
        matches!(self.placement[id.0 as usize], Placement::Cpu)
    }

    pub fn region_of(&self, id: NodeId) -> Option<usize> {
        match self.placement[id.0 as usize] {
            Placement::Delegate { region } => Some(region),
            Placement::Cpu => None,
        }
    }

    /// Number of nodes on the CPU fallback path.
    pub fn cpu_nodes(&self) -> usize {
        self.placement.iter().filter(|p| matches!(p, Placement::Cpu)).count()
    }

    /// "Post-delegation" node count: CPU nodes + one unit per region
    /// (Table 7 "Post" treats each delegate region as a single node).
    pub fn post_node_count(&self) -> usize {
        self.cpu_nodes() + self.regions.len()
    }
}

/// A node is delegate-*eligible* if its op kind is supported AND none of
/// its tensors are dynamically shaped (NNAPI-style static requirement).
pub fn delegate_eligible(g: &Graph, id: NodeId) -> bool {
    let node = g.node(id);
    node.kind.delegate_supported() && !g.node_has_dynamic_shape(id)
}

/// Grow candidate delegate regions, then prune each with the cost model.
///
/// Region growth must keep the region/CPU unit graph **acyclic** (a
/// region that both feeds and consumes the same fallback node would
/// deadlock).  We use barrier-level clustering — the strategy real
/// delegates use (`PartitionGraphIntoIndependentNodeSubsets` in TFLite):
/// each node's *level* counts the ineligible nodes on its deepest
/// incoming path; eligible nodes group into connected components within
/// one level.  Any path leaving a level-L region passes an ineligible
/// node and re-enters at level > L, so no cycle can form.
pub fn partition(g: &Graph, cm: &CostModel) -> Partition {
    let n = g.num_nodes();
    let mut placement = vec![Placement::Cpu; n];
    let mut regions = Vec::new();
    let mut pruned = Vec::new();

    let order = g.topo_order().expect("partition requires a DAG");
    // barrier level per node
    let mut level = vec![0u32; n];
    for &v in &order {
        let mut lv = 0;
        for p in g.preds(v) {
            let step = if delegate_eligible(g, p) { 0 } else { 1 };
            lv = lv.max(level[p.0 as usize] + step);
        }
        level[v.0 as usize] = lv;
    }

    // connected components of eligible nodes within one level
    let mut visited: HashSet<NodeId> = HashSet::new();
    for &start in &order {
        if visited.contains(&start) || !delegate_eligible(g, start) {
            continue;
        }
        let lv = level[start.0 as usize];
        let mut region = Vec::new();
        let mut queue = std::collections::VecDeque::from([start]);
        visited.insert(start);
        while let Some(u) = queue.pop_front() {
            region.push(u);
            for v in g.preds(u).into_iter().chain(g.succs(u)) {
                if !visited.contains(&v)
                    && delegate_eligible(g, v)
                    && level[v.0 as usize] == lv
                {
                    visited.insert(v);
                    queue.push_back(v);
                }
            }
        }
        region.sort_unstable();
        let f = flops::region_flops(g, &region);
        let b = flops::boundary_bytes(g, &region);
        if cm.keep_delegate(region.len(), f, b) {
            let idx = regions.len();
            for &id in &region {
                placement[id.0 as usize] = Placement::Delegate { region: idx };
            }
            regions.push(region);
        } else {
            pruned.push(region);
        }
    }

    Partition { placement, regions, pruned }
}

/// Per-region workload metadata (feeds §3.1 "per-branch workload
/// metadata for later stages").
#[derive(Clone, Copy, Debug)]
pub struct RegionStats {
    pub ops: usize,
    pub flops: u64,
    pub boundary_bytes: u64,
}

pub fn region_stats(g: &Graph, region: &[NodeId]) -> RegionStats {
    RegionStats {
        ops: region.len(),
        flops: flops::region_flops(g, region),
        boundary_bytes: flops::boundary_bytes(g, region),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;
    use crate::models::micro;

    #[test]
    fn cost_model_thresholds() {
        let cm = CostModel::default();
        assert!(cm.keep_delegate(3, 1_000_000_000, 0));
        assert!(!cm.keep_delegate(2, 1_000_000_000, 0)); // too few ops
        assert!(!cm.keep_delegate(3, 299_999_999, 0)); // too little compute
        assert!(!cm.keep_delegate(3, 1_000_000_000, 200_000_000)); // B/F > 0.1
    }

    #[test]
    fn cost_model_from_device_matches_appendix_b() {
        // L=0.2ms, R_cpu=1e9 MAC/s, R_acc=2.6e13 MAC/s, bw=51.2e9 B/s
        let cm = CostModel::from_device(0.2e-3, 1e9, 2.6e13, 51.2e9);
        // F > L*R_cpu = 2e5 MACs = 4e5 FLOPs
        assert_eq!(cm.min_flops, 400_000);
        // B/F < bw/R_acc = 0.00197 bytes/MAC ≈ 0.000985 bytes/FLOP
        assert!((cm.max_bytes_per_flop - 51.2e9 / 5.2e13).abs() < 1e-9);
    }

    #[test]
    fn dynamic_nodes_stay_on_cpu() {
        let g = micro::mixed();
        let p = partition(&g, &CostModel { min_flops: 0, min_ops: 1, max_bytes_per_flop: 1e9 });
        for node in g.nodes() {
            if matches!(node.kind, OpKind::NonMaxSuppression) {
                assert!(p.is_cpu(node.id), "NMS must fall back");
            }
        }
    }

    #[test]
    fn conv_trunk_is_delegated_under_loose_model() {
        let g = micro::mixed();
        let p = partition(&g, &CostModel { min_flops: 0, min_ops: 1, max_bytes_per_flop: 1e9 });
        let conv0 = g.nodes().iter().find(|n| n.name == "conv0").unwrap();
        assert!(!p.is_cpu(conv0.id));
    }

    #[test]
    fn small_regions_pruned_by_default_model() {
        // chain of relus: eligible but tiny compute -> pruned to CPU
        let g = micro::chain(10);
        let p = partition(&g, &CostModel::default());
        assert!(p.regions.is_empty());
        assert_eq!(p.pruned.len(), 1);
        assert_eq!(p.cpu_nodes(), 10);
    }

    #[test]
    fn regions_are_disjoint_and_complete() {
        let g = crate::models::ModelKind::Yolov8n.build();
        let p = partition(&g, &CostModel::default());
        let mut seen = HashSet::new();
        for r in &p.regions {
            for &id in r {
                assert!(seen.insert(id), "node in two regions");
                assert_eq!(p.region_of(id), Some(p.region_of(id).unwrap()));
            }
        }
        // every delegated placement belongs to a listed region
        for (i, pl) in p.placement.iter().enumerate() {
            if let Placement::Delegate { region } = pl {
                assert!(p.regions[*region].contains(&NodeId(i as u32)));
            }
        }
    }

    #[test]
    fn post_count_collapses_regions() {
        let g = micro::mixed();
        let p = partition(&g, &CostModel { min_flops: 0, min_ops: 1, max_bytes_per_flop: 1e9 });
        assert_eq!(p.post_node_count(), p.cpu_nodes() + p.regions.len());
        assert!(p.post_node_count() < g.num_nodes());
    }
}
