//! Pure-Rust reference kernels for glue ops.
//!
//! The real execution engine runs program-hinted blocks through AOT
//! PJRT artifacts; everything in between (elementwise glue, softmax,
//! shape plumbing, the dynamic ops no artifact can cover) runs here.
//! These are correctness-first implementations — the heavy FLOPs all
//! live in the artifacts, so these loops stay off the critical path.

use crate::runtime::Tensor;

/// Column tile of the blocked GEMM (output elements per row chunk).
const MM_JB: usize = 64;
/// Inner-dim tile of the blocked GEMM.
const MM_KB: usize = 64;

/// Blocked/tiled row-major GEMM.
///
/// Column (`MM_JB`) and inner-dim (`MM_KB`) tiles keep one `b` panel
/// and one `out` row chunk cache-resident across the `k` sweep.  Per
/// output element the `k`-accumulation order is ascending regardless
/// of the tiling, so results are bit-identical to the naive ascending
/// triple loop.  Every `a[i][k]` contributes — zeros included — so
/// kernel latency is data-independent (zero-heavy post-ReLU
/// activations time the same as dense inputs; no sparsity skew in the
/// benches).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (sa, sb) = (a.shape(), b.shape());
    assert_eq!(sa.len(), 2, "matmul lhs must be rank-2");
    assert_eq!(sb.len(), 2, "matmul rhs must be rank-2");
    let (m, k) = (sa[0], sa[1]);
    let (k2, n) = (sb[0], sb[1]);
    assert_eq!(k, k2, "matmul inner dim mismatch");
    let mut out = vec![0f32; m * n];
    let (da, db) = (a.data(), b.data());
    for j0 in (0..n).step_by(MM_JB) {
        let jl = MM_JB.min(n - j0);
        for k0 in (0..k).step_by(MM_KB) {
            let kl = MM_KB.min(k - k0);
            for i in 0..m {
                let arow = &da[i * k + k0..i * k + k0 + kl];
                let orow = &mut out[i * n + j0..i * n + j0 + jl];
                for (kk, &av) in arow.iter().enumerate() {
                    let brow = &db[(k0 + kk) * n + j0..(k0 + kk) * n + j0 + jl];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

/// Broadcasting binary op: supports equal shapes and trailing-axis
/// broadcast (bias-style `(..., N) ⊕ (N,)`).
pub fn binary(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
    if a.shape() == b.shape() {
        let data = a.data().iter().zip(b.data()).map(|(&x, &y)| f(x, y)).collect();
        return Tensor::new(a.shape().to_vec(), data);
    }
    let n = *b.shape().last().unwrap_or(&1);
    assert_eq!(
        b.len(),
        n,
        "binary broadcast supports (..,N) op (N,) only: {:?} vs {:?}",
        a.shape(),
        b.shape()
    );
    binary_bias(a, b.data(), f)
}

/// Fused elementwise ⊕ trailing-axis bias: one pass over `a` in
/// row-sized chunks, zipping the bias slice directly — no broadcast
/// tensor materialised and no per-element index modulo.  Bit-identical
/// to `f(a[i], bias[i % len])` by construction; this *is* the
/// trailing-axis path of [`binary`], exposed so the engine can feed a
/// bias tensor without first cloning it into an `a`-shaped view.
pub fn binary_bias(a: &Tensor, bias: &[f32], f: impl Fn(f32, f32) -> f32) -> Tensor {
    assert!(!bias.is_empty(), "empty bias");
    assert_eq!(
        a.len() % bias.len(),
        0,
        "bias length must divide the input: {:?} ⊕ {}",
        a.shape(),
        bias.len()
    );
    let mut out = Vec::with_capacity(a.len());
    for row in a.data().chunks_exact(bias.len()) {
        for (&x, &y) in row.iter().zip(bias) {
            out.push(f(x, y));
        }
    }
    Tensor::new(a.shape().to_vec(), out)
}

pub fn unary(a: &Tensor, f: impl Fn(f32) -> f32) -> Tensor {
    Tensor::new(a.shape().to_vec(), a.data().iter().map(|&x| f(x)).collect())
}

pub fn relu(x: f32) -> f32 {
    x.max(0.0)
}

pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

pub fn gelu(x: f32) -> f32 {
    // tanh approximation (matches jax.nn.gelu default)
    0.5 * x * (1.0 + ((0.7978845608 * (x + 0.044715 * x * x * x)).tanh()))
}

pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Softmax over the last axis.
pub fn softmax(a: &Tensor) -> Tensor {
    let d = *a.shape().last().expect("softmax needs rank>=1");
    let mut out = a.data().to_vec();
    for row in out.chunks_mut(d) {
        let m = row.iter().fold(f32::MIN, |acc, &x| acc.max(x));
        let mut sum = 0.0;
        for x in row.iter_mut() {
            *x = (*x - m).exp();
            sum += *x;
        }
        for x in row.iter_mut() {
            *x /= sum;
        }
    }
    Tensor::new(a.shape().to_vec(), out)
}

/// LayerNorm over the last axis with gamma/beta.
pub fn layernorm(a: &Tensor, gamma: &Tensor, beta: &Tensor, eps: f32) -> Tensor {
    let d = *a.shape().last().expect("layernorm needs rank>=1");
    assert_eq!(gamma.len(), d);
    assert_eq!(beta.len(), d);
    let mut out = a.data().to_vec();
    for row in out.chunks_mut(d) {
        let mean: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for (j, x) in row.iter_mut().enumerate() {
            *x = (*x - mean) * inv * gamma.data()[j] + beta.data()[j];
        }
    }
    Tensor::new(a.shape().to_vec(), out)
}

/// Single-head scaled-dot-product attention on rank-2 q/k/v.
pub fn attention(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    let d = q.shape()[1] as f32;
    let kt = transpose2(k);
    let mut scores = matmul(q, &kt);
    for x in scores.data_mut() {
        *x /= d.sqrt();
    }
    let probs = softmax(&scores);
    matmul(&probs, v)
}

pub fn transpose2(a: &Tensor) -> Tensor {
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = a.data()[i * n + j];
        }
    }
    Tensor::new(vec![n, m], out)
}

/// Mean over all but the last axis -> (1, D).
pub fn mean_rows(a: &Tensor) -> Tensor {
    let d = *a.shape().last().unwrap();
    let rows = a.len() / d;
    let mut out = vec![0f32; d];
    for r in 0..rows {
        for j in 0..d {
            out[j] += a.data()[r * d + j];
        }
    }
    for x in &mut out {
        *x /= rows as f32;
    }
    Tensor::new(vec![1, d], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let i = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(matmul(&a, &i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let a = Tensor::randn(vec![4, 8], 3);
        let s = softmax(&a);
        for row in s.data().chunks(8) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let a = Tensor::randn(vec![3, 16], 5);
        let g = Tensor::new(vec![16], vec![1.0; 16]);
        let b = Tensor::new(vec![16], vec![0.0; 16]);
        let o = layernorm(&a, &g, &b, 1e-5);
        for row in o.data().chunks(16) {
            let mean: f32 = row.iter().sum::<f32>() / 16.0;
            assert!(mean.abs() < 1e-5);
        }
    }

    #[test]
    fn bias_broadcast() {
        let a = Tensor::new(vec![2, 3], vec![0.; 6]);
        let b = Tensor::new(vec![3], vec![1., 2., 3.]);
        let o = binary(&a, &b, |x, y| x + y);
        assert_eq!(o.data(), &[1., 2., 3., 1., 2., 3.]);
    }

    /// The naive ascending-k triple loop *without* the old `av == 0.0`
    /// skip — the reference the blocked kernel must match bit for bit.
    fn matmul_naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                for kk in 0..k {
                    out[i * n + j] += a.data()[i * k + kk] * b.data()[kk * n + j];
                }
            }
        }
        Tensor::new(vec![m, n], out)
    }

    #[test]
    fn matmul_blocked_matches_naive_bitwise() {
        // randomized shapes straddling the tile sizes, plus zero-heavy
        // inputs (post-ReLU style) that the old kernel special-cased
        for (seed, (m, k, n)) in
            [(1u64, (3, 5, 7)), (2, (17, 64, 65)), (3, (2, 130, 70)), (4, (65, 65, 64))]
        {
            let a = unary(&Tensor::randn(vec![m, k], seed), |x| {
                if x > 0.5 {
                    0.0
                } else {
                    x
                }
            });
            let b = Tensor::randn(vec![k, n], seed ^ 0xFF);
            let (got, want) = (matmul(&a, &b), matmul_naive(&a, &b));
            assert_eq!(got.shape(), want.shape());
            for (g, w) in got.data().iter().zip(want.data()) {
                assert_eq!(g.to_bits(), w.to_bits(), "blocked GEMM must be bit-identical");
            }
        }
    }

    #[test]
    fn binary_bias_matches_modulo_reference_bitwise() {
        // the pre-rewrite trailing-axis path: per-element `i % n` index
        for (seed, (rows, n)) in [(9u64, (1, 1)), (10, (4, 8)), (11, (7, 33))] {
            let a = Tensor::randn(vec![rows, n], seed);
            let b = Tensor::randn(vec![n], seed ^ 0xAB);
            let f = |x: f32, y: f32| x * 0.75 + y;
            let reference: Vec<f32> = a
                .data()
                .iter()
                .enumerate()
                .map(|(i, &x)| f(x, b.data()[i % n]))
                .collect();
            let got = binary(&a, &b, f);
            for (g, w) in got.data().iter().zip(&reference) {
                assert_eq!(g.to_bits(), w.to_bits(), "row-chunked bias must be bit-identical");
            }
        }
    }

    #[test]
    fn transpose_round_trip() {
        let a = Tensor::randn(vec![3, 5], 1);
        assert_eq!(transpose2(&transpose2(&a)), a);
    }

    #[test]
    fn attention_uniform_is_mean() {
        // q == 0 -> uniform probs -> output = mean of v rows
        let q = Tensor::zeros(vec![1, 4]);
        let k = Tensor::randn(vec![3, 4], 2);
        let v = Tensor::new(vec![3, 4], (0..12).map(|i| i as f32).collect());
        let o = attention(&q, &k, &v);
        assert!((o.data()[0] - 4.0).abs() < 1e-5); // mean of 0,4,8
    }
}
