//! Pure-Rust reference kernels for glue ops.
//!
//! The real execution engine runs program-hinted blocks through AOT
//! PJRT artifacts; everything in between (elementwise glue, softmax,
//! shape plumbing, the dynamic ops no artifact can cover) runs here.
//! These are correctness-first implementations — the heavy FLOPs all
//! live in the artifacts, so these loops stay off the critical path.

use crate::runtime::Tensor;

pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (sa, sb) = (a.shape(), b.shape());
    assert_eq!(sa.len(), 2, "matmul lhs must be rank-2");
    assert_eq!(sb.len(), 2, "matmul rhs must be rank-2");
    let (m, k) = (sa[0], sa[1]);
    let (k2, n) = (sb[0], sb[1]);
    assert_eq!(k, k2, "matmul inner dim mismatch");
    let mut out = vec![0f32; m * n];
    let (da, db) = (a.data(), b.data());
    for i in 0..m {
        for kk in 0..k {
            let av = da[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let row = &db[kk * n..(kk + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(row) {
                *o += av * bv;
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

/// Broadcasting binary op: supports equal shapes and trailing-axis
/// broadcast (bias-style `(..., N) ⊕ (N,)`).
pub fn binary(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
    if a.shape() == b.shape() {
        let data = a.data().iter().zip(b.data()).map(|(&x, &y)| f(x, y)).collect();
        return Tensor::new(a.shape().to_vec(), data);
    }
    let n = *b.shape().last().unwrap_or(&1);
    assert_eq!(
        b.len(),
        n,
        "binary broadcast supports (..,N) op (N,) only: {:?} vs {:?}",
        a.shape(),
        b.shape()
    );
    assert_eq!(a.len() % n, 0);
    let mut out = Vec::with_capacity(a.len());
    for (i, &x) in a.data().iter().enumerate() {
        out.push(f(x, b.data()[i % n]));
    }
    Tensor::new(a.shape().to_vec(), out)
}

pub fn unary(a: &Tensor, f: impl Fn(f32) -> f32) -> Tensor {
    Tensor::new(a.shape().to_vec(), a.data().iter().map(|&x| f(x)).collect())
}

pub fn relu(x: f32) -> f32 {
    x.max(0.0)
}

pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

pub fn gelu(x: f32) -> f32 {
    // tanh approximation (matches jax.nn.gelu default)
    0.5 * x * (1.0 + ((0.7978845608 * (x + 0.044715 * x * x * x)).tanh()))
}

pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Softmax over the last axis.
pub fn softmax(a: &Tensor) -> Tensor {
    let d = *a.shape().last().expect("softmax needs rank>=1");
    let mut out = a.data().to_vec();
    for row in out.chunks_mut(d) {
        let m = row.iter().fold(f32::MIN, |acc, &x| acc.max(x));
        let mut sum = 0.0;
        for x in row.iter_mut() {
            *x = (*x - m).exp();
            sum += *x;
        }
        for x in row.iter_mut() {
            *x /= sum;
        }
    }
    Tensor::new(a.shape().to_vec(), out)
}

/// LayerNorm over the last axis with gamma/beta.
pub fn layernorm(a: &Tensor, gamma: &Tensor, beta: &Tensor, eps: f32) -> Tensor {
    let d = *a.shape().last().expect("layernorm needs rank>=1");
    assert_eq!(gamma.len(), d);
    assert_eq!(beta.len(), d);
    let mut out = a.data().to_vec();
    for row in out.chunks_mut(d) {
        let mean: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for (j, x) in row.iter_mut().enumerate() {
            *x = (*x - mean) * inv * gamma.data()[j] + beta.data()[j];
        }
    }
    Tensor::new(a.shape().to_vec(), out)
}

/// Single-head scaled-dot-product attention on rank-2 q/k/v.
pub fn attention(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    let d = q.shape()[1] as f32;
    let kt = transpose2(k);
    let mut scores = matmul(q, &kt);
    for x in scores.data_mut() {
        *x /= d.sqrt();
    }
    let probs = softmax(&scores);
    matmul(&probs, v)
}

pub fn transpose2(a: &Tensor) -> Tensor {
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = a.data()[i * n + j];
        }
    }
    Tensor::new(vec![n, m], out)
}

/// Mean over all but the last axis -> (1, D).
pub fn mean_rows(a: &Tensor) -> Tensor {
    let d = *a.shape().last().unwrap();
    let rows = a.len() / d;
    let mut out = vec![0f32; d];
    for r in 0..rows {
        for j in 0..d {
            out[j] += a.data()[r * d + j];
        }
    }
    for x in &mut out {
        *x /= rows as f32;
    }
    Tensor::new(vec![1, d], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let i = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(matmul(&a, &i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let a = Tensor::randn(vec![4, 8], 3);
        let s = softmax(&a);
        for row in s.data().chunks(8) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let a = Tensor::randn(vec![3, 16], 5);
        let g = Tensor::new(vec![16], vec![1.0; 16]);
        let b = Tensor::new(vec![16], vec![0.0; 16]);
        let o = layernorm(&a, &g, &b, 1e-5);
        for row in o.data().chunks(16) {
            let mean: f32 = row.iter().sum::<f32>() / 16.0;
            assert!(mean.abs() < 1e-5);
        }
    }

    #[test]
    fn bias_broadcast() {
        let a = Tensor::new(vec![2, 3], vec![0.; 6]);
        let b = Tensor::new(vec![3], vec![1., 2., 3.]);
        let o = binary(&a, &b, |x, y| x + y);
        assert_eq!(o.data(), &[1., 2., 3., 1., 2., 3.]);
    }

    #[test]
    fn transpose_round_trip() {
        let a = Tensor::randn(vec![3, 5], 1);
        assert_eq!(transpose2(&transpose2(&a)), a);
    }

    #[test]
    fn attention_uniform_is_mean() {
        // q == 0 -> uniform probs -> output = mean of v rows
        let q = Tensor::zeros(vec![1, 4]);
        let k = Tensor::randn(vec![3, 4], 2);
        let v = Tensor::new(vec![3, 4], (0..12).map(|i| i as f32).collect());
        let o = attention(&q, &k, &v);
        assert!((o.data()[0] - 4.0).abs() < 1e-5); // mean of 0,4,8
    }
}
