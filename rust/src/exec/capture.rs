//! Plan capture & replay: the engine's preallocated hot path.
//!
//! The paper's latency story (§4) rests on a request path that does no
//! per-run planning work.  The interpreting engine still walks the
//! branch/unit structure, rebuilds a [`BumpArena`] and a scratch map
//! per branch run, and recomputes every wave's lease demand per layer.
//! This module hoists all of that to a one-time *capture* (the
//! capture-then-launch idiom of Opara, PAPERS.md): the first run of a
//! (model, shape-bucket, placement) triple records a [`CapturedPlan`] —
//! ordered wave lists, per-wave/per-layer lease demands, per-branch
//! step programs with pre-resolved read sources and arena layouts
//! ([`crate::memory::plan_branch`] offsets), and the placed lane
//! topology — and every later run replays it.
//!
//! Replay is bit-identical to the fresh path by construction: both
//! funnel every host node through the same
//! [`eval_host_node`](super::eval_host_node) kernel dispatch, read the
//! same shared [`Values`] store with the same local-first/-then-store/
//! -then-source resolution, and lease the same demand figures (the
//! capture records exactly the numbers the fresh path would compute).
//! What replay *removes* is bookkeeping: no structure walk, no
//! per-run arena or hash map, no thread spawn for one-branch waves, no
//! deep copies out of the value store.

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use super::{eval_host_node, Counters, EnergyModel, Engine, ExecStats, IdleTime, Values};
use crate::branch::Unit;
use crate::ctrl::ShapeEnv;
use crate::graph::{NodeId, OpKind, TensorId};
use crate::memory::{analyze, plan_branch, ArenaPlan, BumpArena};
use crate::place::PlacementPlan;
use crate::runtime::Tensor;
use crate::sched::LayerSchedule;

/// Deterministic synthesized-weight bank, keyed by source tensor id.
///
/// Parallax never inspects weight values (see ARCHITECTURE.md
/// §Substitutions), so sources are synthesized with a fixed per-tensor
/// seed.  The bank materialises each tensor once and hands out shared
/// `Arc`s: repeated reads of the same weight never deep-copy, whether
/// from the engine, a captured replay, or a standalone
/// [`CapturedPlan::replay`].
#[derive(Default)]
pub struct WeightBank {
    map: Mutex<HashMap<TensorId, Arc<Tensor>>>,
}

impl WeightBank {
    /// The synthesized value for source tensor `t`, materialised on
    /// first touch at the shape the closure supplies (dynamic dims at
    /// max — artifact shapes must line up).  The formula is the one
    /// the engine has always used: seeded `randn`, scaled down so deep
    /// chains stay numerically tame.
    pub fn source(&self, t: TensorId, shape: impl FnOnce() -> Vec<usize>) -> Arc<Tensor> {
        let mut m = self.map.lock().unwrap();
        Arc::clone(m.entry(t).or_insert_with(|| {
            let mut w = Tensor::randn(shape(), 0xBEEF ^ t.0 as u64);
            for x in w.data_mut() {
                *x *= 0.05;
            }
            Arc::new(w)
        }))
    }
}

/// Where a replayed step finds one input — resolved at capture, so
/// replay does no producer lookups.
#[derive(Clone, Debug)]
pub(crate) enum ReadSrc {
    /// Index into the branch-local produced list (the tensor was
    /// produced earlier in this same branch).
    Local(usize),
    /// The shared store, falling back to the synthesized source bank
    /// (shape recorded for engine-free replay).
    Extern { t: TensorId, shape: Vec<usize> },
}

/// One precompiled host step of a branch program.
#[derive(Clone, Debug)]
pub(crate) struct Step {
    kind: OpKind,
    ins: Vec<TensorId>,
    outs: Vec<TensorId>,
    /// Read source per input, parallel to `ins`.
    reads: Vec<ReadSrc>,
    /// Output shapes resolved at capture time, parallel to `outs`.
    shapes: Vec<Vec<usize>>,
    /// All outputs statically shaped: `shapes` replays under any env.
    /// Otherwise the replay re-resolves through its own [`ShapeEnv`]
    /// (the §3.4 exact-extent path).
    static_shapes: bool,
}

/// The captured executable form of one branch: its host steps plus the
/// arena layout the §3.2 planner assigns its internal activations.
#[derive(Clone, Debug)]
pub(crate) struct BranchProgram {
    steps: Vec<Step>,
    /// Fused-block members skipped inside this branch (stat parity
    /// with the interpreting path).
    n_skipped: usize,
    /// Peak live arena bytes of the captured execution (the figure the
    /// fresh path's per-run [`BumpArena`] replay reports).
    peak_arena: usize,
    /// §3.2 arena layout for branch-internal activations: planned
    /// once at capture ([`crate::memory::plan_branch`] offsets), where
    /// the interpreting path replays alloc/free bookkeeping per run.
    arena: ArenaPlan,
    /// Every step's outputs are statically shaped.
    static_shapes: bool,
}

impl BranchProgram {
    /// The frozen §3.2 arena layout, for the static plan pass
    /// (`analysis::plan`) to audit against recomputed lifetimes.
    pub(crate) fn arena(&self) -> &ArenaPlan {
        &self.arena
    }
}

/// Captured per-layer lease figures, parallel to the layer's schedule:
/// `waves[i]` is wave `i`'s combined §3.3 peak (0 for empty waves,
/// which the executor skips before leasing), `sequential[j]` the
/// j-th spill branch's.
#[derive(Clone, Debug)]
pub(crate) struct CapturedLayer {
    pub(crate) waves: Vec<u64>,
    pub(crate) sequential: Vec<u64>,
}

/// Captured lane topology of a placed run (overlap mode): what
/// `run_overlapped` would otherwise derive from the placement per run.
#[derive(Clone, Debug)]
pub(crate) struct CapturedPlaced {
    /// The ONE run-wide lease figure (max over layers of in-flight
    /// staging + CPU-wave peak).
    pub(crate) run_demand: u64,
    /// Lanes that receive jobs from these schedules.
    pub(crate) used: Vec<bool>,
    /// Delegated predecessors per branch — the merge points a consumer
    /// waits for.
    pub(crate) preds_del: Vec<Vec<usize>>,
    pub(crate) num_lanes: usize,
}

/// An executable capture of one (schedules, shape-env, placement)
/// triple — see the [module docs](self) for what is recorded and why.
///
/// Build one with [`Engine::capture`]; replay it with
/// [`Engine::run_captured`] (engine-assisted: PJRT blocks, dynamic
/// shapes, placements) or, when [`CapturedPlan::is_standalone`] holds,
/// with [`CapturedPlan::replay`] — no engine, graph, or plan borrow
/// required, which is what lets a registered serving model own its
/// captured plan outright.
pub struct CapturedPlan {
    schedules: Vec<LayerSchedule>,
    progs: Vec<Option<BranchProgram>>,
    layers: Vec<CapturedLayer>,
    placed: Option<CapturedPlaced>,
    /// Captured under a placement (demands are placement-aware).
    with_placement: bool,
    /// Fully self-contained: no placement, no PJRT-block branches, all
    /// shapes static — replayable without the engine.
    standalone: bool,
    /// The engine's [`EnergyModel`] at capture time, so standalone
    /// replays charge the same Fig. 2 decomposition the fresh path
    /// would (engine-assisted replays use the engine's own model).
    energy: Option<EnergyModel>,
}

impl CapturedPlan {
    /// The schedules this plan was captured over (replay runs exactly
    /// these waves in exactly this order).
    pub fn schedules(&self) -> &[LayerSchedule] {
        &self.schedules
    }

    /// Was this capture taken under a placement?  Replay must pass the
    /// same placement back.
    pub fn is_placed(&self) -> bool {
        self.with_placement
    }

    /// Can this plan replay without its engine ([`CapturedPlan::replay`])?
    /// True when nothing in it needs graph or pool context: no
    /// placement, no PJRT-block branches, every step statically shaped.
    pub fn is_standalone(&self) -> bool {
        self.standalone
    }

    /// Number of branches captured as step programs (branches with
    /// PJRT blocks fall back to the interpreting path at replay).
    pub fn num_programs(&self) -> usize {
        self.progs.iter().filter(|p| p.is_some()).count()
    }

    /// Peak single lease a replay will request: the max captured
    /// wave/spill demand (and the run-wide placed figure, if any) —
    /// what a serving registration quotes as the model's demand.
    pub fn peak_demand(&self) -> u64 {
        let classic = self
            .layers
            .iter()
            .flat_map(|cl| cl.waves.iter().chain(&cl.sequential))
            .copied()
            .max()
            .unwrap_or(0);
        classic.max(self.placed.as_ref().map_or(0, |pp| pp.run_demand))
    }

    pub(crate) fn prog(&self, b: usize) -> Option<&BranchProgram> {
        self.progs.get(b).and_then(|p| p.as_ref())
    }

    pub(crate) fn layer(&self, li: usize) -> &CapturedLayer {
        &self.layers[li]
    }

    pub(crate) fn placed(&self) -> Option<&CapturedPlaced> {
        self.placed.as_ref()
    }

    pub(crate) fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Test hook: zero every arena offset of the first captured branch
    /// program with at least two internal activations, so
    /// lifetime-overlapping tensors share bytes. Returns whether a
    /// program was corrupted. Exists so `rust/tests/analysis.rs` can
    /// pin the exact [`ArenaOverlap`](crate::analysis::Code) finding
    /// the plan pass must produce — never called by the runtime.
    pub fn corrupt_arena_overlap(&mut self) -> bool {
        for prog in self.progs.iter_mut().flatten() {
            if prog.arena.offsets.len() >= 2 {
                for off in &mut prog.arena.offsets {
                    *off = 0;
                }
                return true;
            }
        }
        false
    }

    /// Test hook: swap the first two layer schedules (and their frozen
    /// demand rows, so only the ordering is wrong), making consumers
    /// run before their producers. Returns whether a swap happened.
    /// Pins the [`WaveOrderViolation`](crate::analysis::Code) finding.
    pub fn corrupt_wave_order(&mut self) -> bool {
        if self.schedules.len() >= 2 {
            self.schedules.swap(0, 1);
            self.layers.swap(0, 1);
            return true;
        }
        false
    }

    /// Test hook: halve the largest frozen lease figure — the placed
    /// run-wide lease if this capture has one, else the largest
    /// per-wave/sequential demand. Returns whether anything shrank.
    /// Pins the [`LeaseUnderProvisioned`](crate::analysis::Code)
    /// finding.
    pub fn corrupt_lease_shrink(&mut self) -> bool {
        if let Some(pp) = &mut self.placed {
            if pp.run_demand > 1 {
                pp.run_demand /= 2;
                return true;
            }
        }
        let best = self
            .layers
            .iter_mut()
            .flat_map(|cl| cl.waves.iter_mut().chain(&mut cl.sequential))
            .max_by_key(|d| **d);
        if let Some(d) = best {
            if *d > 1 {
                *d /= 2;
                return true;
            }
        }
        false
    }

    /// Engine-free replay for standalone plans (see
    /// [`CapturedPlan::is_standalone`]): run the captured waves against
    /// `values`, synthesizing source tensors from `weights`.  Outputs
    /// are bit-identical to the engine running the same schedules —
    /// both paths share one kernel dispatch and one source formula.
    /// Multi-branch waves still execute on scoped threads (branch
    /// isolation is load-bearing, §3.2); singleton waves run inline.
    pub fn replay(&self, values: &Values, weights: &WeightBank) -> anyhow::Result<ExecStats> {
        anyhow::ensure!(
            self.standalone,
            "captured plan needs its engine (placement, PJRT blocks, or dynamic shapes)"
        );
        let t0 = std::time::Instant::now();
        let mut stats = ExecStats::default();
        // Energy ledger mirrors the engine's: per-wave span is the max
        // branch slot time (+ sync for multi-branch waves), core-seconds
        // add up.  Single-threaded here, so plain accumulators.
        let (mut span_s, mut core_s) = (0.0f64, 0.0f64);
        let charge = |wave: &[usize], span_s: &mut f64, core_s: &mut f64| {
            let Some(em) = &self.energy else { return };
            let span = wave
                .iter()
                .map(|&b| em.branch_span_s.get(b).copied().unwrap_or(0.0))
                .fold(0.0, f64::max);
            let sync = if wave.len() > 1 { em.sync_s } else { 0.0 };
            *span_s += span + sync;
            *core_s += wave
                .iter()
                .map(|&b| em.branch_core_s.get(b).copied().unwrap_or(0.0))
                .sum::<f64>();
        };
        let mut merge = |out: Vec<(TensorId, Arc<Tensor>)>| {
            for (t, v) in out {
                values.insert_arc(t, v);
            }
        };
        let mut run_one = |b: usize, stats: &mut ExecStats| {
            let prog = self.prog(b).expect("standalone plan has every program");
            stats.host_ops += prog.steps.len();
            stats.skipped_fused += prog.n_skipped;
            stats.peak_arena_bytes = stats.peak_arena_bytes.max(prog.peak_arena);
            stats.cpu_branch_runs += 1;
            replay_branch(prog, values, weights)
        };
        for ls in &self.schedules {
            for wave in &ls.waves {
                match wave.len() {
                    0 => continue,
                    1 => {
                        let out = run_one(wave[0], &mut stats);
                        charge(wave, &mut span_s, &mut core_s);
                        merge(out);
                    }
                    _ => {
                        let outs: Vec<Vec<(TensorId, Arc<Tensor>)>> =
                            std::thread::scope(|scope| {
                                let handles: Vec<_> = wave
                                    .iter()
                                    .map(|&b| {
                                        let prog = self
                                            .prog(b)
                                            .expect("standalone plan has every program");
                                        scope.spawn(move || replay_branch(prog, values, weights))
                                    })
                                    .collect();
                                handles.into_iter().map(|h| h.join().unwrap()).collect()
                            });
                        for &b in wave {
                            let prog = self.prog(b).unwrap();
                            stats.host_ops += prog.steps.len();
                            stats.skipped_fused += prog.n_skipped;
                            stats.peak_arena_bytes =
                                stats.peak_arena_bytes.max(prog.peak_arena);
                            stats.cpu_branch_runs += 1;
                        }
                        charge(wave, &mut span_s, &mut core_s);
                        for out in outs {
                            merge(out);
                        }
                    }
                }
            }
            for &b in &ls.sequential {
                let out = run_one(b, &mut stats);
                charge(&[b], &mut span_s, &mut core_s);
                merge(out);
            }
        }
        stats.wall_s = t0.elapsed().as_secs_f64();
        if let Some(em) = &self.energy {
            let t_total = match em.idle {
                IdleTime::Modelled => em.base_s + span_s,
                IdleTime::MeasuredWall => stats.wall_s,
            };
            stats.cpu_modelled_s = core_s;
            stats.energy_idle_j = em.p_idle_w * t_total;
            stats.energy_cpu_j = em.p_core_w * core_s;
            stats.energy_lane_j = 0.0;
            stats.energy_j = stats.energy_idle_j + stats.energy_cpu_j;
        }
        Ok(stats)
    }
}

/// Execute one captured branch program with no engine in sight: steps
/// in order, reads through the pre-resolved [`ReadSrc`]s, shapes from
/// the capture.
fn replay_branch(
    prog: &BranchProgram,
    values: &Values,
    weights: &WeightBank,
) -> Vec<(TensorId, Arc<Tensor>)> {
    let mut local: Vec<(TensorId, Arc<Tensor>)> = Vec::new();
    for step in &prog.steps {
        let out = eval_host_node(
            &step.kind,
            &step.ins,
            &step.outs,
            |t| {
                resolve(step, t, &local, values, |t, shape| {
                    weights.source(t, || shape.to_vec())
                })
            },
            |i| step.shapes[i].clone(),
        );
        local.extend(out);
    }
    local
}

/// Resolve one replay read: local list by captured index, else store,
/// else synthesized source at the captured shape.  (≤3 inputs per op —
/// the position scan is a handful of compares.)
fn resolve(
    step: &Step,
    t: TensorId,
    local: &[(TensorId, Arc<Tensor>)],
    values: &Values,
    source: impl Fn(TensorId, &[usize]) -> Arc<Tensor>,
) -> Arc<Tensor> {
    let i = step
        .ins
        .iter()
        .position(|&x| x == t)
        .expect("replay read of a tensor the step does not input");
    match &step.reads[i] {
        ReadSrc::Local(ix) => Arc::clone(&local[*ix].1),
        ReadSrc::Extern { t, shape } => {
            values.get(*t).unwrap_or_else(|| source(*t, shape))
        }
    }
}

impl<'a> Engine<'a> {
    /// Capture an executable plan for these schedules under `env` and
    /// `placement` — the one-time structure walk whose result
    /// [`Engine::run_captured`] replays.  Capture is static: nothing
    /// executes.  Per branch it records the step program (read sources
    /// pre-resolved, output shapes resolved through `env`), replays
    /// the arena alloc/free bookkeeping once for the peak figure, and
    /// plans the §3.2 arena layout; per layer it records the §3.3
    /// lease demands the executor would compute; for a delegating
    /// placement it records the lane topology and the run-wide lease.
    ///
    /// Branches containing PJRT program blocks are left uncaptured —
    /// replay routes them through the interpreting path (the pool call
    /// is the cost there, not the bookkeeping).
    pub fn capture(
        &self,
        schedules: &[LayerSchedule],
        env: &ShapeEnv,
        placement: Option<&PlacementPlan>,
    ) -> CapturedPlan {
        let nb = self.plan.branches.len();
        let mut appears = vec![false; nb];
        for ls in schedules {
            for b in ls.all() {
                appears[b] = true;
            }
        }
        let mut progs: Vec<Option<BranchProgram>> = (0..nb).map(|_| None).collect();
        for (b, prog) in progs.iter_mut().enumerate() {
            if appears[b] {
                *prog = self.capture_branch(b, env);
            }
        }
        let demand = |wave: &[usize]| match placement {
            Some(pl) => self.wave_demand_placed(wave, pl),
            None => self.wave_demand(wave),
        };
        let layers = schedules
            .iter()
            .map(|ls| CapturedLayer {
                waves: ls.waves.iter().map(|w| demand(w)).collect(),
                sequential: ls.sequential.iter().map(|&b| demand(&[b])).collect(),
            })
            .collect();
        let placed = placement.and_then(|pl| {
            let delegated_here =
                schedules.iter().any(|ls| ls.all().any(|b| pl.is_delegated(b)));
            if !delegated_here {
                return None;
            }
            let (num_lanes, used, preds_del) = self.lane_topology(schedules, pl);
            Some(CapturedPlaced {
                run_demand: self.overlapped_run_demand(schedules, pl, true),
                used,
                preds_del,
                num_lanes,
            })
        });
        let standalone = placement.is_none()
            && (0..nb).all(|b| {
                !appears[b]
                    || progs[b].as_ref().map_or(false, |p| p.static_shapes)
            });
        CapturedPlan {
            schedules: schedules.to_vec(),
            progs,
            layers,
            placed,
            with_placement: placement.is_some(),
            standalone,
            energy: self.energy.clone(),
        }
    }

    /// Capture one branch as a step program, or `None` if it contains
    /// a PJRT program block.  This walks exactly the node sequence
    /// [`Engine::run_branch`] would execute and replays its arena
    /// bookkeeping (alloc per produced tensor, free after the last
    /// consumer) so the captured peak matches the interpreting path's
    /// per-run figure.
    fn capture_branch(&self, b: usize, env: &ShapeEnv) -> Option<BranchProgram> {
        let mut steps = Vec::new();
        let mut n_skipped = 0usize;
        let mut n_local = 0usize;
        let mut local_ix: HashMap<TensorId, usize> = HashMap::new();
        let mut arena = BumpArena::new();
        let mut slots: HashMap<TensorId, usize> = HashMap::new();
        let mut static_all = true;
        for &u in &self.plan.branches[b].units {
            let node_ids: Vec<NodeId> = match &self.plan.unit_graph.units[u] {
                Unit::Cpu(id) => vec![*id],
                Unit::Region(ri) => self.partition.regions[*ri].clone(),
            };
            for id in node_ids {
                if self.covered.contains(&id) {
                    n_skipped += 1;
                    continue;
                }
                if self.blocks.contains_key(&id) {
                    return None;
                }
                let node = self.graph.node(id);
                let reads = node
                    .inputs
                    .iter()
                    .map(|&t| match local_ix.get(&t) {
                        Some(&ix) => ReadSrc::Local(ix),
                        None => ReadSrc::Extern {
                            t,
                            shape: self
                                .graph
                                .tensor_info(t)
                                .shape
                                .iter()
                                .map(|d| d.max())
                                .collect(),
                        },
                    })
                    .collect();
                // which tensors the step produces (multi-output nodes
                // produce all outputs; single-output just the first —
                // mirroring the kernel dispatch)
                let produced: Vec<TensorId> = if node.outputs.len() > 1 {
                    node.outputs.clone()
                } else {
                    vec![node.outputs[0]]
                };
                let shapes: Vec<Vec<usize>> = node
                    .outputs
                    .iter()
                    .map(|&t| self.shape_of(t, env))
                    .collect();
                static_all &= node
                    .outputs
                    .iter()
                    .all(|&t| !self.graph.tensor_info(t).has_dynamic_dim());
                for (t, shape) in produced.iter().zip(&shapes) {
                    let bytes = shape.iter().product::<usize>() * 4;
                    slots.insert(*t, arena.alloc(bytes));
                    local_ix.insert(*t, n_local);
                    n_local += 1;
                }
                for &t in &node.inputs {
                    if let Some(&off) = slots.get(&t) {
                        let last = self
                            .graph
                            .consumers(t)
                            .iter()
                            .all(|&c| c.0 <= id.0 || self.covered.contains(&c));
                        if last {
                            arena.free(off);
                            slots.remove(&t);
                        }
                    }
                }
                steps.push(Step {
                    kind: node.kind.clone(),
                    ins: node.inputs.clone(),
                    outs: node.outputs.clone(),
                    reads,
                    shapes,
                    static_shapes: node
                        .outputs
                        .iter()
                        .all(|&t| !self.graph.tensor_info(t).has_dynamic_dim()),
                });
            }
        }
        // §3.2 layout, planned once: internal (non-escaping) lifetimes
        // through the branch planner — the offsets a zero-copy runtime
        // would hand every replay.
        let nodes = self.plan.branch_nodes(self.graph, self.partition, b);
        let lts = analyze(self.graph, &nodes);
        let internal: Vec<_> = lts.into_iter().filter(|lt| !lt.escapes).collect();
        Some(BranchProgram {
            steps,
            n_skipped,
            peak_arena: arena.peak_live(),
            arena: plan_branch(&internal),
            static_shapes: static_all,
        })
    }

    /// Replay one captured branch program inside the engine: same step
    /// loop as the standalone path, but dynamic output shapes resolve
    /// through `env` and source synthesis goes through the engine's
    /// weight bank.  Counter updates mirror [`Engine::run_branch`]
    /// (one host op per step, skips, the captured arena peak).
    pub(crate) fn run_branch_captured(
        &self,
        prog: &BranchProgram,
        values: &Values,
        c: &Counters,
        env: &ShapeEnv,
    ) -> anyhow::Result<Vec<(TensorId, Arc<Tensor>)>> {
        let mut local: Vec<(TensorId, Arc<Tensor>)> = Vec::new();
        for step in &prog.steps {
            let read = |t| {
                resolve(step, t, &local, values, |t, shape| {
                    self.weights.source(t, || shape.to_vec())
                })
            };
            let out = if step.static_shapes {
                eval_host_node(&step.kind, &step.ins, &step.outs, read, |i| {
                    step.shapes[i].clone()
                })
            } else {
                eval_host_node(&step.kind, &step.ins, &step.outs, read, |i| {
                    self.shape_of(step.outs[i], env)
                })
            };
            local.extend(out);
        }
        c.host_ops.fetch_add(prog.steps.len(), Ordering::Relaxed);
        c.skipped.fetch_add(prog.n_skipped, Ordering::Relaxed);
        c.peak_arena.fetch_max(prog.peak_arena, Ordering::Relaxed);
        Ok(local)
    }
}
