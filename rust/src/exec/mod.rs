//! Real execution engine: runs a scheduled Branch-Layer plan with
//! actual data movement — AOT PJRT artifacts for program-hinted blocks,
//! pure-Rust host kernels for the glue.
//!
//! This is the request-path counterpart of the simulator: the simulator
//! produces *device-time* results (the paper's tables); the engine
//! proves the whole stack composes — Parallax schedule → per-branch
//! arenas → concurrent branch threads → PJRT executables — and powers
//! the serving examples.  Its key invariant (tested here and in
//! `rust/tests/`): outputs are bit-identical whatever the thread count
//! or memory budget, i.e. branch isolation is sound (§3.2).
//!
//! Weights are synthesised deterministically per tensor id (Parallax
//! never inspects weights; see ARCHITECTURE.md §Substitutions).  Dynamic
//! dims run at their maximum by default so artifact shapes line up; the
//! subgraph-control path ([`crate::ctrl`], §3.4) threads a
//! [`ShapeEnv`] through [`Engine::run_waves`] to execute at
//! runtime-resolved extents instead.
//!
//! Multi-model hosts call [`Engine::run_governed`]: every wave leases
//! its combined branch-peak demand from the process-wide
//! [`MemoryGovernor`](crate::sched::MemoryGovernor) before spawning
//! branch threads, so concurrently serving pipelines can never stack
//! their individually-safe peaks into a device-level memory spike.
//!
//! Heterogeneous hosts call [`Engine::run_placed`] with a
//! [`PlacementPlan`](crate::place::PlacementPlan): branches the §3.1
//! placement model assigns to an accelerator lane execute on that
//! lane's persistent [`DelegateWorker`] — one dedicated thread per
//! [`AccLane`](crate::device::AccLane) that outlives layer barriers,
//! overlaps wall-clock with the CPU fallback waves, charges the
//! modelled delegate time from the device profile, and drives the
//! PJRT pool for program-hinted blocks when the `pjrt` feature is on.
//! A lane job's outputs merge into the value store right before its
//! *first consumer's* wave (not at its own layer barrier), so jobs
//! keep the accelerator busy across the next layers' CPU waves —
//! dependency-safe because every consumer waits for exactly the
//! delegated predecessors it reads.  Forcing the placement to CPU-only
//! reproduces the classic [`Engine::run`] path bit for bit, and
//! overlap can be disabled per run ([`Engine::run_placed_opts`]) for
//! the barrier-join ablation.

pub mod capture;
pub mod host_kernels;

pub use capture::{CapturedPlan, WeightBank};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::branch::{BranchPlan, Unit};
use crate::ctrl::ShapeEnv;
use crate::graph::{Graph, Node, NodeId, OpKind, TensorId};
use crate::memory::{BranchMemory, BumpArena};
use crate::partition::Partition;
use crate::place::PlacementPlan;
use crate::runtime::{RuntimePool, Tensor, WorkerClient};
use crate::sched::{LayerSchedule, MemoryGovernor};

/// A program-hinted fused block discovered from the graph.
#[derive(Clone, Debug)]
struct ProgramBlock {
    program: String,
    /// Activation input: the anchor node's first input tensor.
    act_in: TensorId,
    /// The block's escaping output tensor (written by the artifact).
    out: TensorId,
    /// All members (anchor + fused), for accounting.
    members: Vec<NodeId>,
}

/// Execution statistics for one inference.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    pub pjrt_calls: usize,
    pub host_ops: usize,
    pub skipped_fused: usize,
    /// Peak of the summed per-branch arena live bytes.
    pub peak_arena_bytes: usize,
    /// Branch executions on the CPU wave/spill path (a delegated run
    /// has strictly fewer than its CPU-only twin).
    pub cpu_branch_runs: usize,
    /// Branch executions on the async [`DelegateWorker`] lane.
    pub delegate_jobs: usize,
    /// Modelled accelerator-busy seconds summed over all delegate
    /// lanes (the `SocProfile` timing recorded by the placement plan)
    /// — the simulated-delegate substitute for NNAPI wall time, see
    /// EXPERIMENTS.md §Heterogeneous.
    pub acc_modelled_s: f64,
    /// Times the executor had to *block* on a lane result (a consumer
    /// wave or barrier arrived before the job finished).
    pub delegate_stalls: usize,
    /// Observed idle-lane gaps: dispatches to a lane whose previous
    /// jobs had all completed *and merged* — the lane provably sat
    /// idle in between.  Barrier-join runs pay one per re-used lane
    /// per co-executing layer (deterministic: every layer ends
    /// drained); overlap runs absorb results lazily, so on a
    /// single-lane run the count is deterministic too, while
    /// multi-lane counts can vary with cross-lane arrival order.
    /// Cross-layer overlap's whole point is to drive this to zero
    /// (the bench's ablation metric, measured on one lane).
    pub lane_gaps: usize,
    pub wall_s: f64,
    /// Modelled CPU core-seconds accumulated over the run's waves and
    /// sequential spills — the `core_seconds` input of the Fig. 2
    /// energy decomposition.  Zero unless the engine carries an
    /// [`EnergyModel`].
    pub cpu_modelled_s: f64,
    /// Total modelled energy of this run, joules:
    /// `energy_idle_j + energy_cpu_j + energy_lane_j` — the same
    /// `P_idle·T + P_core·core_seconds + P_acc·acc_busy` decomposition
    /// the analytic `sim` path uses (see EXPERIMENTS.md §Energy).
    /// Zero unless the engine carries an [`EnergyModel`].
    pub energy_j: f64,
    /// Idle/base-power term: `p_idle_w · T`, where `T` is either the
    /// modelled span or the measured wall time, per
    /// [`EnergyModel::idle`].
    pub energy_idle_j: f64,
    /// CPU term: `p_core_w · cpu_modelled_s`.
    pub energy_cpu_j: f64,
    /// Accelerator term: Σ over lanes of `lane_power_w[l] ·` that
    /// lane's accumulated modelled busy seconds.
    pub energy_lane_j: f64,
    /// Device→edge bytes sent over remote links this run.  Every
    /// uplink attempt is charged — including the wasted first attempt
    /// of a retried transfer — so a lossy link shows more uplink
    /// traffic for the same work.  Zero unless [`Engine::set_remote`]
    /// marked a lane remote.
    pub uplink_bytes: u64,
    /// Edge→device bytes: the byte sizes of every tensor a remote
    /// lane job merged back into the value store (charged once per
    /// *completed* job; faulted jobs that fell back to the CPU never
    /// produce downlink traffic).
    pub downlink_bytes: u64,
    /// Modelled remote-lane busy seconds after per-transfer
    /// [`LinkModel`](crate::device::LinkModel) jitter.  The
    /// un-jittered figure is the placement plan's modelled delegate
    /// latency; `eval remote` reports the gap between the two as the
    /// modelled-link error column.
    pub remote_busy_s: f64,
    /// Remote transfers that rolled a link drop and were retried once
    /// at the next transfer index.  A second drop is a persistent
    /// fault: the job runs inline on the bit-identical CPU path
    /// instead (counted in [`ExecStats::cpu_branch_runs`]) — never a
    /// silent drop.
    pub link_retries: usize,
}

/// Per-run energy accounting model (Fig. 2): power draws plus the
/// per-branch modelled times the executor charges as branches actually
/// run.  Built from a [`SocProfile`](crate::device::SocProfile) and a
/// schedule — [`crate::sim::energy_model_for`] precomputes each
/// branch's span/core-seconds under exactly the wave composition the
/// analytic simulator uses, so the executor's independently-accumulated
/// decomposition can be tested term-by-term against `sim`'s closed
/// form.  Attach with [`Engine::set_energy_model`]; engines without a
/// model report all-zero energy fields.
#[derive(Clone, Debug, Default)]
pub struct EnergyModel {
    /// Device idle/base power draw, watts.
    pub p_idle_w: f64,
    /// Marginal power of one busy CPU core, watts.
    pub p_core_w: f64,
    /// Marginal power of each accelerator lane, watts (indexed like
    /// `SocProfile::lanes`; missing entries draw 0).
    pub lane_power_w: Vec<f64>,
    /// Modelled elapsed seconds of each branch *in its scheduled
    /// slot* (wave-position dependent).  A wave's span is the max over
    /// its branches.
    pub branch_span_s: Vec<f64>,
    /// Modelled CPU core-seconds of each branch in its scheduled slot.
    pub branch_core_s: Vec<f64>,
    /// Fixed per-run overhead seconds (framework graph overhead) added
    /// to the modelled span.
    pub base_s: f64,
    /// Synchronisation seconds charged per multi-branch wave.
    pub sync_s: f64,
    /// What the idle term's `T` is charged from.
    pub idle: IdleTime,
}

/// The time base of the [`EnergyModel`] idle term.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum IdleTime {
    /// `T` = modelled span (`base_s` + accumulated wave/spill spans) —
    /// comparable term-by-term with the analytic `sim` closed form.
    #[default]
    Modelled,
    /// `T` = measured host wall time ([`ExecStats::wall_s`]).  Host
    /// wall clock is not SoC time (see EXPERIMENTS.md §Deviations), so
    /// this deviates from `sim` by construction.
    MeasuredWall,
}

/// Shared per-run counters threaded through branch executions.
#[derive(Default)]
struct Counters {
    pjrt_calls: AtomicUsize,
    host_ops: AtomicUsize,
    skipped: AtomicUsize,
    peak_arena: AtomicUsize,
    cpu_branch_runs: AtomicUsize,
    /// Modelled span seconds, f64 bits (energy ledger; dispatcher
    /// thread only, so accumulation order is deterministic and replay
    /// charges are bit-identical to fresh runs).
    span_bits: AtomicU64,
    /// Modelled CPU core-seconds, f64 bits (energy ledger).
    core_bits: AtomicU64,
}

/// Add `v` into an f64 stored as `AtomicU64` bits.
fn add_f64(bits: &AtomicU64, v: f64) {
    let mut cur = bits.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match bits.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

/// The engine: graph + plan + (optional) PJRT pool.
pub struct Engine<'a> {
    pub graph: &'a Graph,
    pub partition: &'a Partition,
    pub plan: &'a BranchPlan,
    pool: Option<&'a RuntimePool>,
    blocks: HashMap<NodeId, ProgramBlock>,
    /// Nodes subsumed by an *active* program block (skipped at run time).
    covered: std::collections::HashSet<NodeId>,
    /// Per-branch peak demand M_i (§3.3) — what governed runs lease
    /// from the process-wide ledger before executing a wave.
    mems: Vec<BranchMemory>,
    /// Branch-level successor sets (computed once — the plan is
    /// immutable): the merge points of the cross-layer delegate
    /// overlap and the spans of the in-flight staging accounting.
    branch_succs: Vec<Vec<usize>>,
    /// Deterministic synthesized weights, keyed by source tensor id —
    /// shared `Arc`s so repeated reads never deep-copy.
    weights: WeightBank,
    /// Synthesized program weight args, keyed by (program, arg index).
    prog_weights: Mutex<HashMap<(String, usize), Tensor>>,
    /// Optional energy ledger (Fig. 2): when set, every run charges
    /// the modelled idle/cpu/lane energy terms into its [`ExecStats`].
    energy: Option<EnergyModel>,
    /// Optional device–edge tier: which lanes are remote and the
    /// seeded link-fault model their transfers roll against.
    remote: Option<RemoteCfg>,
}

/// Remote-lane runtime configuration: per-lane remote flags (indexed
/// like `SocProfile::lanes`) plus the deterministic
/// [`LinkModel`](crate::device::LinkModel) every remote transfer rolls
/// against.
struct RemoteCfg {
    lanes: Vec<bool>,
    link: crate::device::LinkModel,
}

impl<'a> Engine<'a> {
    pub fn new(
        graph: &'a Graph,
        partition: &'a Partition,
        plan: &'a BranchPlan,
        pool: Option<&'a RuntimePool>,
    ) -> Self {
        // discover program blocks
        let mut members: HashMap<NodeId, Vec<NodeId>> = HashMap::new();
        for n in graph.nodes() {
            if let Some(anchor) = n.fused_into {
                members.entry(anchor).or_default().push(n.id);
            }
        }
        let mut blocks = HashMap::new();
        for n in graph.nodes() {
            let Some(program) = n.program.clone() else { continue };
            // artifacts usable only when a pool with that program exists
            if let Some(pool) = pool {
                if !pool.manifest().contains(&program) {
                    continue;
                }
            } else {
                continue;
            }
            let mut mem = members.remove(&n.id).unwrap_or_default();
            mem.push(n.id);
            let set: std::collections::HashSet<NodeId> = mem.iter().copied().collect();
            // block output: tensor produced inside, consumed outside (or
            // graph output); take the largest by bytes if several.
            let mut out: Option<(usize, TensorId)> = None;
            for &m in &mem {
                for &t in &graph.node(m).outputs {
                    let escapes = graph.consumers(t).iter().any(|c| !set.contains(c))
                        || graph.consumers(t).is_empty();
                    if escapes {
                        let sz = graph.tensor_info(t).byte_size_max();
                        if out.map(|(s, _)| sz > s).unwrap_or(true) {
                            out = Some((sz, t));
                        }
                    }
                }
            }
            let Some((_, out)) = out else { continue };
            blocks.insert(
                n.id,
                ProgramBlock {
                    program,
                    act_in: n.inputs[0],
                    out,
                    members: mem,
                },
            );
        }
        let mut covered = std::collections::HashSet::new();
        for b in blocks.values() {
            for &m in &b.members {
                if graph.node(m).program.is_none() {
                    covered.insert(m);
                }
            }
        }
        let mems = crate::memory::branch_memories(graph, partition, plan);
        let branch_succs = plan.branch_succs();
        Self {
            graph,
            partition,
            plan,
            pool,
            blocks,
            covered,
            mems,
            branch_succs,
            weights: WeightBank::default(),
            prog_weights: Mutex::new(HashMap::new()),
            energy: None,
            remote: None,
        }
    }

    /// Attach an [`EnergyModel`]: subsequent runs on any path (classic,
    /// governed, placed, captured-replay, segmented) charge the Fig. 2
    /// energy decomposition into their [`ExecStats`].  Call before the
    /// engine is shared (`&Engine` runs cannot mutate it); captures
    /// taken afterwards carry the model for standalone replay.
    pub fn set_energy_model(&mut self, em: EnergyModel) {
        self.energy = Some(em);
    }

    /// The attached [`EnergyModel`], if any.
    pub fn energy_model(&self) -> Option<&EnergyModel> {
        self.energy.as_ref()
    }

    /// Mark which lanes are device–edge remote lanes (indexed like
    /// `SocProfile::lanes`, e.g. `soc.lanes.iter().map(|l|
    /// l.remote)`) and attach the seeded
    /// [`LinkModel`](crate::device::LinkModel) their transfers roll
    /// against.  Remote lane jobs charge uplink/downlink bytes and
    /// jittered remote busy seconds into [`ExecStats`], and a dropped
    /// transfer retries once, then falls back to the bit-identical
    /// inline CPU path.  Without this call, a remote-placed run
    /// treats the remote lane like one more on-die lane (fault-free,
    /// no transfer accounting).  Call before the engine is shared,
    /// like [`Engine::set_energy_model`].
    pub fn set_remote(&mut self, remote_lanes: Vec<bool>, link: crate::device::LinkModel) {
        self.remote = Some(RemoteCfg { lanes: remote_lanes, link });
    }

    /// Combined §3.3 peak demand of a wave's CPU branches (delegate
    /// branches occupy the accelerator, not host arenas).
    fn wave_demand(&self, wave: &[usize]) -> u64 {
        wave.iter()
            .filter(|&&b| !self.plan.branches[b].has_delegate)
            .map(|&b| self.mems[b].total() as u64)
            .sum()
    }

    /// [`Engine::wave_demand`] under a placement: every branch the
    /// placement keeps on the CPU counts at its full M_i — including
    /// `has_delegate` branches whose offload was rejected, whose host
    /// arena is real (the classic convention zero-counts those because
    /// the classic path has no way to reject an offload).
    fn wave_demand_placed(&self, wave: &[usize], pl: &PlacementPlan) -> u64 {
        wave.iter()
            .filter(|&&b| !pl.is_delegated(b))
            .map(|&b| self.mems[b].total() as u64)
            .sum()
    }

    /// Number of discovered PJRT-runnable blocks.
    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Lane topology of a placed run over these schedules: lane count,
    /// which lanes actually receive jobs, and each branch's delegated
    /// predecessors (the merge points a consumer must wait for before
    /// it may read the store).  Computed per run on the fresh path,
    /// once at capture on the replay path.
    fn lane_topology(
        &self,
        schedules: &[LayerSchedule],
        pl: &PlacementPlan,
    ) -> (usize, Vec<bool>, Vec<Vec<usize>>) {
        let nb = self.plan.branches.len();
        let num_lanes = pl
            .delegated()
            .filter_map(|b| pl.lane_of(b))
            .max()
            .map(|m| m + 1)
            .expect("lane topology requires delegated branches");
        let mut used = vec![false; num_lanes];
        for ls in schedules {
            for b in ls.all() {
                if let Some(l) = pl.lane_of(b) {
                    used[l] = true;
                }
            }
        }
        let mut preds_del: Vec<Vec<usize>> = vec![Vec::new(); nb];
        for d in pl.delegated() {
            for &cns in &self.branch_succs[d] {
                preds_del[cns].push(d);
            }
        }
        (num_lanes, used, preds_del)
    }

    /// The ONE lease figure of a placed co-executing run: the max over
    /// layers of (in-flight lane staging + CPU-wave peak) — see the
    /// lease comment in [`Engine::run_overlapped`].
    fn overlapped_run_demand(
        &self,
        schedules: &[LayerSchedule],
        pl: &PlacementPlan,
        overlap: bool,
    ) -> u64 {
        let inflight: Vec<u64> = if overlap {
            crate::sched::placed_inflight_staging_from(&self.branch_succs, pl, schedules)
        } else {
            schedules
                .iter()
                .map(|ls| {
                    ls.all()
                        .filter(|&b| pl.is_delegated(b))
                        .map(|b| pl.staging_bytes[b])
                        .sum()
                })
                .collect()
        };
        schedules
            .iter()
            .zip(&inflight)
            .map(|(ls, &infl)| crate::sched::placed_layer_demand(&self.mems, pl, ls, infl))
            .max()
            .unwrap_or(0)
    }

    /// Resolve a tensor's concrete shape under a [`ShapeEnv`]
    /// (unresolved env = every dynamic dim at max).
    fn shape_of(&self, t: TensorId, env: &ShapeEnv) -> Vec<usize> {
        env.shape(self.graph.tensor_info(t))
    }

    /// A tensor's current value: the store if present, else the
    /// deterministic synthesised source — what barrier resolvers
    /// ([`crate::ctrl::resolve_barrier`]) read.  Returns a shared
    /// handle; reading never copies tensor data.
    pub fn read_value(&self, values: &Values, t: TensorId) -> Arc<Tensor> {
        values.get(t).unwrap_or_else(|| self.source_value(t))
    }

    /// Deterministic weight/input for a source tensor (no producer).
    fn source_value(&self, t: TensorId) -> Arc<Tensor> {
        self.weights.source(t, || {
            self.graph.tensor_info(t).shape.iter().map(|d| d.max()).collect()
        })
    }

    /// Deterministic weight for a program argument slot.
    fn program_arg(&self, program: &str, idx: usize, shape: Vec<usize>) -> Tensor {
        let mut cache = self.prog_weights.lock().unwrap();
        cache
            .entry((program.to_string(), idx))
            .or_insert_with(|| {
                let mut w = Tensor::randn(shape, 0xA11CE ^ (idx as u64) << 32 ^ hash(program));
                for x in w.data_mut() {
                    *x *= 0.05;
                }
                w
            })
            .clone()
    }

    /// Run one inference over the given per-layer schedules.
    ///
    /// Ungoverned convenience wrapper around
    /// [`Engine::run_governed`] — single-pipeline tools where the
    /// schedule's own budget is the only constraint.
    pub fn run(&self, schedules: &[LayerSchedule]) -> anyhow::Result<(Values, ExecStats)> {
        self.run_governed(schedules, None)
    }

    /// Run one inference, leasing every wave's branch-peak demand from
    /// the process-wide [`MemoryGovernor`] first.
    ///
    /// With a governor, concurrently running engines (multi-model
    /// serving) block each other exactly when their combined §3.3 peaks
    /// would exceed the device budget — the cross-model generalisation
    /// of the per-layer budget rule.  Passing `None` skips admission
    /// control and behaves like the classic single-model path.
    pub fn run_governed(
        &self,
        schedules: &[LayerSchedule],
        governor: Option<&MemoryGovernor>,
    ) -> anyhow::Result<(Values, ExecStats)> {
        let values = Values::default();
        let stats = self.run_waves(schedules, &values, governor, &ShapeEnv::unresolved())?;
        Ok((values, stats))
    }

    /// Run one inference with a heterogeneous [`PlacementPlan`]
    /// (`crate::place`): delegated branches execute on persistent
    /// per-lane [`DelegateWorker`] threads, overlapping wall-clock
    /// with the CPU fallback waves across layer barriers; CPU-placed
    /// branches take the classic wave path.  The run holds ONE
    /// governor lease — the max over layers of the CPU-wave peak
    /// *plus* the in-flight lane jobs' host-visible staging
    /// ([`placed_layer_demand`](crate::sched::placed_layer_demand)) —
    /// from before the first dispatch until the final drain, so
    /// staging is never resident outside a lease.
    ///
    /// A placement with no delegated branches (e.g.
    /// [`PlacePolicy::ForceCpu`](crate::place::PlacePolicy)) executes
    /// exactly like [`Engine::run_governed`], so CPU-forced placed
    /// runs are bit-identical to the classic engine.  (Lease *sizes*
    /// stay placement-aware even then: a rejected-offload branch
    /// executing on the CPU leases its real arena, which the classic
    /// `has_delegate` convention zero-counts.)
    pub fn run_placed(
        &self,
        schedules: &[LayerSchedule],
        placement: &PlacementPlan,
        governor: Option<&MemoryGovernor>,
    ) -> anyhow::Result<(Values, ExecStats)> {
        self.run_placed_opts(schedules, placement, governor, true)
    }

    /// CPU-forced run of the same schedules — the serving tier's
    /// degrade path.  Identical to [`Engine::run_placed`] under an
    /// all-CPU [`PlacementPlan::cpu_only`] placement, so its outputs
    /// are bit-identical to both the classic [`Engine::run`] and any
    /// delegated placement of the same schedules (the delegate workers
    /// run the same host kernels).  A deadline-squeezed request served
    /// this way returns exactly the bytes the placed path would have.
    pub fn run_cpu_forced(
        &self,
        schedules: &[LayerSchedule],
    ) -> anyhow::Result<(Values, ExecStats)> {
        let forced = PlacementPlan::cpu_only(self.plan.branches.len());
        self.run_placed(schedules, &forced, None)
    }

    /// [`Engine::run_placed`] with the cross-layer overlap knob
    /// exposed.  `overlap: false` reproduces the barrier-join
    /// behaviour — every lane job merges at its own layer's end — the
    /// ablation baseline `benches/heterogeneous.rs` compares against
    /// (same outputs; more [`ExecStats::lane_gaps`]).
    pub fn run_placed_opts(
        &self,
        schedules: &[LayerSchedule],
        placement: &PlacementPlan,
        governor: Option<&MemoryGovernor>,
        overlap: bool,
    ) -> anyhow::Result<(Values, ExecStats)> {
        let values = Values::default();
        let stats = self.run_waves_placed(
            schedules,
            &values,
            governor,
            &ShapeEnv::unresolved(),
            Some(placement),
            overlap,
        )?;
        Ok((values, stats))
    }

    /// Lowest-level entry: run schedules against a shared value store.
    ///
    /// * `values` may already hold earlier segments' results (the §3.4
    ///   segment-by-segment path); this run's outputs merge into it.
    /// * `env` resolves dynamic dims; [`ShapeEnv::unresolved`] executes
    ///   every dynamic dim at its max (the classic static path).  The
    ///   subgraph-control path leases each segment's *resolved* demand
    ///   itself and passes `governor: None` here.
    pub fn run_waves(
        &self,
        schedules: &[LayerSchedule],
        values: &Values,
        governor: Option<&MemoryGovernor>,
        env: &ShapeEnv,
    ) -> anyhow::Result<ExecStats> {
        self.run_waves_placed(schedules, values, governor, env, None, true)
    }

    /// [`Engine::run_waves`] with an optional heterogeneous placement
    /// — the shared executor core behind the classic, governed, placed
    /// and segmented (§3.4) paths.  `placement: None` (or a placement
    /// that delegates nothing in these schedules) runs every branch on
    /// CPU waves exactly like the classic engine; otherwise delegated
    /// branches run on persistent per-lane [`DelegateWorker`]s, with
    /// `overlap` choosing first-consumer merges (`true`) or
    /// barrier-joins at each layer end (`false`, the ablation
    /// baseline).  All in-flight lane jobs are drained before this
    /// returns, so callers never observe a partially-merged store.
    pub fn run_waves_placed(
        &self,
        schedules: &[LayerSchedule],
        values: &Values,
        governor: Option<&MemoryGovernor>,
        env: &ShapeEnv,
        placement: Option<&PlacementPlan>,
        overlap: bool,
    ) -> anyhow::Result<ExecStats> {
        self.run_waves_inner(schedules, values, governor, env, placement, overlap, None)
    }

    /// Replay a [`CapturedPlan`] against a shared value store — the
    /// hot-path twin of [`Engine::run_waves_placed`]: same executor
    /// core, but wave lists, per-wave lease demands, branch step
    /// programs, arena layouts and lane dispatch order come from the
    /// capture instead of being recomputed, and singleton waves run
    /// inline without a thread spawn.  Outputs are bit-identical to
    /// the freshly planned run.  `placement` must be the plan the
    /// capture was taken under (pass `None` for CPU-only captures);
    /// `env` resolves any dynamic output shapes at their exact
    /// extents, exactly like the un-captured path.
    pub fn run_captured(
        &self,
        cp: &CapturedPlan,
        values: &Values,
        governor: Option<&MemoryGovernor>,
        env: &ShapeEnv,
        placement: Option<&PlacementPlan>,
    ) -> anyhow::Result<ExecStats> {
        #[cfg(debug_assertions)]
        self.audit_captured(cp, placement);
        self.run_waves_inner(
            cp.schedules(),
            values,
            governor,
            env,
            placement,
            true,
            Some(cp),
        )
    }

    /// Debug-build pre-replay hook: run the static plan pass
    /// ([`crate::analysis::plan`]) over the capture before trusting
    /// its frozen offsets, wave lists, and lease figures. A corrupted
    /// capture becomes a structured panic naming the exact findings
    /// instead of silent memory aliasing or an under-sized lease.
    /// Release builds skip it — the audit is the capture-time
    /// invariant check, not a hot-path cost.
    #[cfg(debug_assertions)]
    fn audit_captured(&self, cp: &CapturedPlan, placement: Option<&PlacementPlan>) {
        let findings =
            crate::analysis::plan::check(self.graph, self.partition, self.plan, cp, placement);
        if !findings.is_empty() {
            let mut msg = String::from("pre-replay static audit failed:");
            for f in &findings {
                msg.push_str("\n  ");
                msg.push_str(&f.to_string());
            }
            panic!("{msg}");
        }
    }

    /// One-call captured replay at max shapes: fresh store in, `(store,
    /// stats)` out — the replay twin of [`Engine::run_governed`].
    pub fn run_replayed(
        &self,
        cp: &CapturedPlan,
        governor: Option<&MemoryGovernor>,
    ) -> anyhow::Result<(Values, ExecStats)> {
        let values = Values::default();
        let stats =
            self.run_captured(cp, &values, governor, &ShapeEnv::unresolved(), None)?;
        Ok((values, stats))
    }

    #[allow(clippy::too_many_arguments)]
    fn run_waves_inner(
        &self,
        schedules: &[LayerSchedule],
        values: &Values,
        governor: Option<&MemoryGovernor>,
        env: &ShapeEnv,
        placement: Option<&PlacementPlan>,
        overlap: bool,
        cp: Option<&CapturedPlan>,
    ) -> anyhow::Result<ExecStats> {
        let t0 = std::time::Instant::now();
        let c = Counters::default();
        let delegated_here = placement
            .map(|pl| schedules.iter().any(|ls| ls.all().any(|b| pl.is_delegated(b))))
            .unwrap_or(false);
        let lanes = if delegated_here {
            self.run_overlapped(
                schedules,
                values,
                governor,
                env,
                placement.unwrap(),
                overlap,
                &c,
                cp,
            )?
        } else {
            // Classic path (also the CPU-forced placed path): per-wave
            // admission, holding each wave's combined peak for exactly
            // as long as its branches are in flight.  With a placement,
            // demand is placement-aware: a `has_delegate` branch whose
            // offload was rejected executes with a real host arena and
            // must lease it.
            for (li, ls) in schedules.iter().enumerate() {
                self.run_layer_classic(ls, values, governor, env, placement, &c, cp, li)?;
            }
            LaneTotals::default()
        };
        let wall_s = t0.elapsed().as_secs_f64();
        let mut stats = ExecStats {
            pjrt_calls: c.pjrt_calls.into_inner(),
            host_ops: c.host_ops.into_inner(),
            skipped_fused: c.skipped.into_inner(),
            peak_arena_bytes: c.peak_arena.into_inner(),
            cpu_branch_runs: c.cpu_branch_runs.into_inner(),
            delegate_jobs: lanes.jobs,
            acc_modelled_s: lanes.modelled_s,
            delegate_stalls: lanes.stalls,
            lane_gaps: lanes.gaps,
            uplink_bytes: lanes.uplink_bytes,
            downlink_bytes: lanes.downlink_bytes,
            remote_busy_s: lanes.remote_busy_s,
            link_retries: lanes.link_retries,
            wall_s,
            ..ExecStats::default()
        };
        if let Some(em) = &self.energy {
            let span = f64::from_bits(c.span_bits.into_inner());
            let core = f64::from_bits(c.core_bits.into_inner());
            let t_total = match em.idle {
                IdleTime::Modelled => em.base_s + span,
                IdleTime::MeasuredWall => wall_s,
            };
            stats.cpu_modelled_s = core;
            stats.energy_idle_j = em.p_idle_w * t_total;
            stats.energy_cpu_j = em.p_core_w * core;
            stats.energy_lane_j = lanes
                .busy_s
                .iter()
                .enumerate()
                .map(|(l, &busy)| em.lane_power_w.get(l).copied().unwrap_or(0.0) * busy)
                .sum();
            stats.energy_j = stats.energy_idle_j + stats.energy_cpu_j + stats.energy_lane_j;
        }
        Ok(stats)
    }

    /// Execute one layer with no delegate lanes in play.  On replay
    /// (`cp` set) the per-wave lease figures come from the capture
    /// instead of being recomputed — by construction they are the very
    /// numbers this function would compute, so governed replays lease
    /// bit-identical demands.
    #[allow(clippy::too_many_arguments)]
    fn run_layer_classic(
        &self,
        ls: &LayerSchedule,
        values: &Values,
        governor: Option<&MemoryGovernor>,
        env: &ShapeEnv,
        placement: Option<&PlacementPlan>,
        c: &Counters,
        cp: Option<&CapturedPlan>,
        li: usize,
    ) -> anyhow::Result<()> {
        let cl = cp.map(|cp| cp.layer(li));
        let demand = |wave: &[usize]| match placement {
            Some(pl) => self.wave_demand_placed(wave, pl),
            None => self.wave_demand(wave),
        };
        for (wi, wave) in ls.waves.iter().enumerate() {
            if wave.is_empty() {
                continue;
            }
            let _lease = governor
                .map(|g| g.acquire(cl.map_or_else(|| demand(wave), |cl| cl.waves[wi])));
            self.run_wave(wave, values, env, c, cp)?;
        }
        for (si, &b) in ls.sequential.iter().enumerate() {
            let _lease = governor
                .map(|g| g.acquire(cl.map_or_else(|| demand(&[b]), |cl| cl.sequential[si])));
            self.run_sequential(b, values, env, c, cp)?;
        }
        Ok(())
    }

    /// Execute the whole schedule with persistent per-lane delegate
    /// workers (see [`DelegateWorker`]).  Dependency-safe handoff goes
    /// through the shared value store: a lane job's outputs merge
    /// right before the first wave that consumes them (`overlap`) or
    /// at its own layer's end (barrier-join ablation), and every lane
    /// drains before this returns.
    #[allow(clippy::too_many_arguments)]
    fn run_overlapped(
        &self,
        schedules: &[LayerSchedule],
        values: &Values,
        governor: Option<&MemoryGovernor>,
        env: &ShapeEnv,
        pl: &PlacementPlan,
        overlap: bool,
        c: &Counters,
        cp: Option<&CapturedPlan>,
    ) -> anyhow::Result<LaneTotals> {
        let nb = self.plan.branches.len();
        // On replay the lane topology — used lanes, delegated
        // predecessor sets, the run-wide lease figure — comes from the
        // capture; it is placement-derived, so recomputing it per run
        // is pure overhead.
        let captured_placed = cp.and_then(|cp| cp.placed());
        let computed;
        let (num_lanes, used, preds_del): (usize, &[bool], &[Vec<usize>]) =
            if let Some(pp) = captured_placed {
                (pp.num_lanes, &pp.used, &pp.preds_del)
            } else {
                computed = self.lane_topology(schedules, pl);
                (computed.0, &computed.1, &computed.2)
            };
        // ONE lease covers the whole co-executing run: the max over
        // layers of (in-flight staging + CPU-wave peak), held from
        // before the first dispatch until after the final drain.
        // Staging is leased per lane job from dispatch to merge
        // (§3.3): jobs keep their host-visible staging across layer
        // boundaries, so a per-layer lease would leave that staging
        // unleased in the windows between layers (and during the final
        // drain) — the §3.3 "never smuggle memory past the budget"
        // invariant demands the lease outlive every job.  One lease
        // per thread also keeps the governor deadlock-free.  This
        // mirrors `Pipeline::peak_placed_demand`, the figure serving
        // leases per in-flight batch.  Ungoverned runs (the §3.4
        // segment path holds its own lease and passes governor: None
        // once per segment per decode step) skip the accounting
        // entirely.
        let _lease = governor.map(|g| {
            let run_demand = match captured_placed {
                Some(pp) => pp.run_demand,
                None => self.overlapped_run_demand(schedules, pl, overlap),
            };
            g.acquire(run_demand)
        });
        std::thread::scope(|scope| -> anyhow::Result<LaneTotals> {
            let (res_tx, res_rx) = std::sync::mpsc::channel::<LaneMsg>();
            let mut job_tx: Vec<Option<std::sync::mpsc::Sender<usize>>> = Vec::new();
            for (lane, &u) in used.iter().enumerate() {
                if !u {
                    job_tx.push(None);
                    continue;
                }
                let (tx, rx) = std::sync::mpsc::channel::<usize>();
                let client = self.pool.map(|p| p.client());
                let results = res_tx.clone();
                DelegateWorker::spawn(
                    scope, self, lane, rx, results, values, env, client, c, cp,
                );
                job_tx.push(Some(tx));
            }
            drop(res_tx);
            let mut st = LaneSt::new(nb, num_lanes);
            if let Some(rc) = &self.remote {
                st.remote = rc.lanes.clone();
            }
            for ls in schedules {
                // Dispatch this layer's *ready* lane jobs first so they
                // overlap the CPU waves below (and, with `overlap`, the
                // next layers' waves too).  A lane job that consumes an
                // earlier job's still-pending output is deferred past
                // the waves instead of blocking the whole layer on the
                // accelerator (head-of-line) — its merge-then-dispatch
                // happens after the CPU work, the earliest point that
                // doesn't stall independent waves.
                let mut deferred: Vec<(usize, usize)> = Vec::new();
                for b in ls.all() {
                    let Some(lane) = pl.lane_of(b) else { continue };
                    if preds_del[b].iter().any(|&d| st.pending[d]) {
                        deferred.push((b, lane));
                        continue;
                    }
                    self.dispatch_lane_job(&mut st, &job_tx, b, lane, pl, values, env, c, cp)?;
                }
                for wave in &ls.waves {
                    let cpu: Vec<usize> =
                        wave.iter().copied().filter(|&b| !pl.is_delegated(b)).collect();
                    if cpu.is_empty() {
                        continue;
                    }
                    // first-consumer merge point: block only on the
                    // delegated predecessors this wave actually reads
                    for &b in &cpu {
                        st.settle_deps(&preds_del[b], &res_rx, values, pl)?;
                    }
                    self.run_wave(&cpu, values, env, c, cp)?;
                }
                for &b in &ls.sequential {
                    if pl.is_delegated(b) {
                        continue;
                    }
                    st.settle_deps(&preds_del[b], &res_rx, values, pl)?;
                    self.run_sequential(b, values, env, c, cp)?;
                }
                for (b, lane) in deferred {
                    // merge the pending inputs, then hand off (the mpsc
                    // send orders the store reads after the merges)
                    st.settle_deps(&preds_del[b], &res_rx, values, pl)?;
                    self.dispatch_lane_job(&mut st, &job_tx, b, lane, pl, values, env, c, cp)?;
                }
                if !overlap {
                    // barrier-join ablation: every lane job merges at
                    // its own layer's end, idling the lanes in between
                    st.drain(&res_rx, values, pl)?;
                }
            }
            st.drain(&res_rx, values, pl)?;
            Ok(st.totals)
        })
    }

    /// Hand one lane job to its worker, routing remote lanes through
    /// the link-fault model first.  A remote transfer draws the next
    /// transfer index (dispatcher-thread counter, so indices follow
    /// dispatch order — schedule order — and fault outcomes replay
    /// bit-identically); a dropped transfer retries once at the next
    /// index, and a second drop is a persistent fault: the job runs
    /// *inline* on the bit-identical CPU path (dependency-safe — both
    /// dispatch sites settle the job's delegated predecessors first,
    /// and its CPU predecessors live in earlier, completed layers).
    /// Transfer stats are charged here, on the dispatcher thread, so
    /// f64 accumulation order is deterministic.
    #[allow(clippy::too_many_arguments)]
    fn dispatch_lane_job(
        &self,
        st: &mut LaneSt,
        job_tx: &[Option<std::sync::mpsc::Sender<usize>>],
        b: usize,
        lane: usize,
        pl: &PlacementPlan,
        values: &Values,
        env: &ShapeEnv,
        c: &Counters,
        cp: Option<&CapturedPlan>,
    ) -> anyhow::Result<()> {
        if st.remote.get(lane).copied().unwrap_or(false) {
            let link = &self
                .remote
                .as_ref()
                .expect("remote lane flags without a link model")
                .link;
            let first = st.next_transfer();
            st.totals.uplink_bytes += pl.staging_bytes[b];
            let idx = if link.dropped(first) {
                // retry once: the wasted first uplink stays charged,
                // then the transfer goes out again at the next index
                let retry = st.next_transfer();
                st.totals.link_retries += 1;
                st.totals.uplink_bytes += pl.staging_bytes[b];
                if link.dropped(retry) {
                    // persistent link fault: the job never reaches
                    // the edge server — run the branch inline on the
                    // bit-identical CPU path, never drop it silently
                    return self.run_sequential(b, values, env, c, cp);
                }
                retry
            } else {
                first
            };
            st.totals.remote_busy_s += pl.delegate_latency_s[b] * link.jitter(idx);
        }
        dispatch_job(st, job_tx, b, lane)
    }

    /// Run one parallel wave of CPU branches on scoped threads and
    /// merge their outputs.  Replay runs singleton waves inline — no
    /// spawn, no join; the capture's whole point is a bookkeeping-free
    /// hot path, and a one-branch wave has no parallelism to buy.
    fn run_wave(
        &self,
        wave: &[usize],
        values: &Values,
        env: &ShapeEnv,
        c: &Counters,
        cp: Option<&CapturedPlan>,
    ) -> anyhow::Result<()> {
        if cp.is_some() && wave.len() == 1 {
            return self.run_sequential(wave[0], values, env, c, cp);
        }
        let results: Vec<anyhow::Result<Vec<(TensorId, Arc<Tensor>)>>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = wave
                    .iter()
                    .map(|&b| {
                        let client = self.pool.map(|p| p.client());
                        scope.spawn(move || self.exec_branch(b, values, client, c, env, cp))
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
        c.cpu_branch_runs.fetch_add(wave.len(), Ordering::Relaxed);
        if let Some(em) = &self.energy {
            // A wave's span is the max over its branches' slot times;
            // core-seconds add up.  Multi-branch waves pay one sync.
            let span = wave
                .iter()
                .map(|&b| em.branch_span_s.get(b).copied().unwrap_or(0.0))
                .fold(0.0, f64::max);
            let sync = if wave.len() > 1 { em.sync_s } else { 0.0 };
            add_f64(&c.span_bits, span + sync);
            let core: f64 = wave
                .iter()
                .map(|&b| em.branch_core_s.get(b).copied().unwrap_or(0.0))
                .sum();
            add_f64(&c.core_bits, core);
        }
        for r in results {
            for (t, v) in r? {
                values.insert_arc(t, v);
            }
        }
        Ok(())
    }

    /// Run one sequential-spill CPU branch and merge its outputs.
    fn run_sequential(
        &self,
        b: usize,
        values: &Values,
        env: &ShapeEnv,
        c: &Counters,
        cp: Option<&CapturedPlan>,
    ) -> anyhow::Result<()> {
        let client = self.pool.map(|p| p.client());
        let out = self.exec_branch(b, values, client, c, env, cp)?;
        c.cpu_branch_runs.fetch_add(1, Ordering::Relaxed);
        if let Some(em) = &self.energy {
            add_f64(&c.span_bits, em.branch_span_s.get(b).copied().unwrap_or(0.0));
            add_f64(&c.core_bits, em.branch_core_s.get(b).copied().unwrap_or(0.0));
        }
        for (t, v) in out {
            values.insert_arc(t, v);
        }
        Ok(())
    }

    /// Branch execution dispatch: a captured program replays over its
    /// precompiled steps; anything else (fresh runs, branches with
    /// PJRT blocks) takes the interpreting [`Engine::run_branch`].
    /// Both paths evaluate host nodes through the same
    /// [`eval_host_node`] dispatch, so outputs are bit-identical by
    /// construction.
    fn exec_branch(
        &self,
        b: usize,
        values: &Values,
        client: Option<WorkerClient>,
        c: &Counters,
        env: &ShapeEnv,
        cp: Option<&CapturedPlan>,
    ) -> anyhow::Result<Vec<(TensorId, Arc<Tensor>)>> {
        if let Some(prog) = cp.and_then(|cp| cp.prog(b)) {
            return self.run_branch_captured(prog, values, c, env);
        }
        self.run_branch(b, values, client, c, env)
    }

    /// Execute one branch; returns produced (tensor, value) pairs.
    fn run_branch(
        &self,
        b: usize,
        values: &Values,
        client: Option<WorkerClient>,
        c: &Counters,
        env: &ShapeEnv,
    ) -> anyhow::Result<Vec<(TensorId, Arc<Tensor>)>> {
        let mut local: Vec<(TensorId, Arc<Tensor>)> = Vec::new();
        let mut arena = BumpArena::new();
        let mut arena_slots: HashMap<TensorId, usize> = HashMap::new();

        // Shared handles all the way down: a hit in the local list or
        // the store clones an `Arc`, never the tensor data.  A miss
        // with no producer — or a producer whose value was dropped
        // (fused) — reads the deterministic synthesized source.
        let read = |t: TensorId, local: &[(TensorId, Arc<Tensor>)]| -> Arc<Tensor> {
            if let Some((_, v)) = local.iter().rev().find(|(id, _)| *id == t) {
                return Arc::clone(v);
            }
            if let Some(v) = values.get(t) {
                return v;
            }
            self.source_value(t)
        };

        for &u in &self.plan.branches[b].units {
            let node_ids: Vec<NodeId> = match &self.plan.unit_graph.units[u] {
                Unit::Cpu(id) => vec![*id],
                Unit::Region(ri) => self.partition.regions[*ri].clone(),
            };
            for id in node_ids {
                let node = self.graph.node(id);
                if self.covered.contains(&id) {
                    c.skipped.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                let produced: Vec<(TensorId, Arc<Tensor>)> = if let Some(block) =
                    self.blocks.get(&id)
                {
                    // PJRT artifact call
                    let client = client
                        .as_ref()
                        .ok_or_else(|| anyhow::anyhow!("program block without pool"))?;
                    let spec = self
                        .pool
                        .unwrap()
                        .manifest()
                        .get(&block.program)
                        .unwrap()
                        .clone();
                    let act = fit(&read(block.act_in, &local), &spec.inputs[0]);
                    let mut args = vec![act];
                    for (i, shp) in spec.inputs.iter().enumerate().skip(1) {
                        args.push(self.program_arg(&block.program, i, shp.clone()));
                    }
                    let outs = client.execute(&block.program, args)?;
                    c.pjrt_calls.fetch_add(1, Ordering::Relaxed);
                    let out_shape = self.shape_of(block.out, env);
                    vec![(block.out, Arc::new(fit(&outs[0], &out_shape)))]
                } else {
                    c.host_ops.fetch_add(1, Ordering::Relaxed);
                    self.run_host_node(node, |t| read(t, &local), env)
                };
                for (t, v) in produced {
                    // arena accounting (the values themselves are Vec-backed;
                    // the arena tracks what a zero-copy runtime would hold)
                    let off = arena.alloc(v.byte_size());
                    arena_slots.insert(t, off);
                    local.push((t, v));
                }
                // free tensors whose last consumer is this node
                for &t in &node.inputs {
                    if let Some(&off) = arena_slots.get(&t) {
                        let last = self
                            .graph
                            .consumers(t)
                            .iter()
                            .all(|&c| c.0 <= id.0 || self.covered.contains(&c));
                        if last {
                            arena.free(off);
                            arena_slots.remove(&t);
                        }
                    }
                }
            }
        }
        c.peak_arena.fetch_max(arena.peak_live(), Ordering::Relaxed);
        Ok(local)
    }

    /// Host-kernel execution of one node (output shapes resolved
    /// through `env`) — a graph-aware wrapper over [`eval_host_node`],
    /// the one kernel dispatch both fresh runs and captured replays
    /// share.
    fn run_host_node(
        &self,
        node: &Node,
        read: impl Fn(TensorId) -> Arc<Tensor>,
        env: &ShapeEnv,
    ) -> Vec<(TensorId, Arc<Tensor>)> {
        eval_host_node(&node.kind, &node.inputs, &node.outputs, read, |i| {
            self.shape_of(node.outputs[i], env)
        })
    }
}

/// Host-kernel dispatch for one node, independent of graph and engine:
/// `(kind, inputs, outputs)` plus a read closure and an output-shape
/// resolver.  The runtime path ([`Engine::run_branch`]) and the
/// captured-replay path both funnel through here, so replayed outputs
/// are bit-identical to fresh runs by construction — there is no
/// second kernel dispatch to drift.
pub(crate) fn eval_host_node(
    kind: &OpKind,
    ins: &[TensorId],
    outs: &[TensorId],
    read: impl Fn(TensorId) -> Arc<Tensor>,
    out_shape: impl Fn(usize) -> Vec<usize>,
) -> Vec<(TensorId, Arc<Tensor>)> {
    use host_kernels as hk;
    let val = match kind {
        OpKind::MatMul | OpKind::FullyConnected => {
            let a = as2d(&read(ins[0]));
            let b = as2d(&read(ins[1]));
            if a.shape()[1] == b.shape()[0] {
                fit(&hk::matmul(&a, &b), &out_shape(0))
            } else {
                // shape-mismatched synthetic site: cast-copy
                fit(&a, &out_shape(0))
            }
        }
        OpKind::Add => fit(&bin(&read(ins[0]), &read(ins[1]), |x, y| x + y), &out_shape(0)),
        OpKind::Sub => fit(&bin(&read(ins[0]), &read(ins[1]), |x, y| x - y), &out_shape(0)),
        OpKind::Mul => fit(&bin(&read(ins[0]), &read(ins[1]), |x, y| x * y), &out_shape(0)),
        OpKind::Maximum => fit(&bin(&read(ins[0]), &read(ins[1]), f32::max), &out_shape(0)),
        OpKind::Relu => hk::unary(&read(ins[0]), hk::relu),
        OpKind::Silu => hk::unary(&read(ins[0]), hk::silu),
        OpKind::Gelu => hk::unary(&read(ins[0]), hk::gelu),
        OpKind::Logistic => hk::unary(&read(ins[0]), hk::sigmoid),
        OpKind::Tanh => hk::unary(&read(ins[0]), f32::tanh),
        OpKind::Softmax => hk::softmax(&read(ins[0])),
        OpKind::LayerNorm => {
            let x = read(ins[0]);
            let d = *x.shape().last().unwrap();
            let g = fit(&read(ins[1]), &[d]);
            let bta = fit(&read(ins[2]), &[d]);
            hk::layernorm(&x, &g, &bta, 1e-5)
        }
        OpKind::Attention { .. } => {
            let q = as2d(&read(ins[0]));
            let k = as2d(&read(ins[1]));
            let v = as2d(&read(ins[2]));
            if q.shape()[1] == k.shape()[1] && k.shape() == v.shape() {
                fit(&hk::attention(&q, &k, &v), &out_shape(0))
            } else {
                fit(&q, &out_shape(0))
            }
        }
        OpKind::Mean => hk::mean_rows(&read(ins[0])),
        OpKind::Transpose => {
            let x = read(ins[0]);
            if x.shape().len() == 2 {
                fit(&hk::transpose2(&x), &out_shape(0))
            } else {
                fit(&x, &out_shape(0))
            }
        }
        // shape plumbing, pools, dynamic ops: shape-cast semantics
        // (synthetic values; structure is what matters — see module
        // docs)
        _ => {
            if ins.is_empty() {
                Tensor::zeros(out_shape(0))
            } else {
                fit(&read(ins[0]), &out_shape(0))
            }
        }
    };
    let mut out = vec![(outs[0], Arc::new(fit(&val, &out_shape(0))))];
    // multi-output nodes (Split): slice the input round-robin
    if outs.len() > 1 {
        let src = read(ins[0]);
        out = (0..outs.len())
            .map(|i| (outs[i], Arc::new(fit(&src, &out_shape(i)))))
            .collect();
    }
    out
}

/// Elementwise binary with the engine's broadcast convention: equal
/// shapes zip directly; a trailing-axis bias takes the fused
/// [`host_kernels::binary_bias`] kernel (no broadcast tensor, no
/// per-element modulo); anything else shape-casts `b` to `a`'s shape
/// first.  Bit-identical to materialising the broadcast and calling
/// [`host_kernels::binary`] — case for case, the same kernel path runs
/// on the same values.
fn bin(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
    if a.shape() == b.shape() {
        return host_kernels::binary(a, b, f);
    }
    let last = *a.shape().last().unwrap_or(&1);
    if b.len() == last {
        return host_kernels::binary_bias(a, b.data(), f);
    }
    host_kernels::binary(a, &fit(b, a.shape()), f)
}

/// Record a lane-job dispatch and hand it to the lane's worker (the
/// one place the dispatch bookkeeping and the channel handoff live —
/// the ready and deferred paths of `run_overlapped` share it).
fn dispatch_job(
    st: &mut LaneSt,
    job_tx: &[Option<std::sync::mpsc::Sender<usize>>],
    b: usize,
    lane: usize,
) -> anyhow::Result<()> {
    st.dispatch(b, lane);
    job_tx[lane]
        .as_ref()
        .expect("job for an unused lane")
        .send(b)
        .map_err(|_| anyhow::anyhow!("delegate lane {lane} died"))
}

/// One finished lane job, reported back to the dispatching thread.
struct LaneMsg {
    branch: usize,
    lane: usize,
    out: anyhow::Result<Vec<(TensorId, Arc<Tensor>)>>,
}

/// Aggregate delegate-lane statistics of one run.
#[derive(Default)]
struct LaneTotals {
    jobs: usize,
    modelled_s: f64,
    stalls: usize,
    gaps: usize,
    /// Per-lane modelled busy seconds (energy ledger's `acc_busy`
    /// term, split by lane; empty on CPU-only runs).
    busy_s: Vec<f64>,
    /// Device→edge bytes, every uplink attempt charged (see
    /// [`ExecStats::uplink_bytes`]).
    uplink_bytes: u64,
    /// Edge→device bytes of merged remote job outputs.
    downlink_bytes: u64,
    /// Jittered modelled remote busy seconds (dispatcher-side
    /// accumulation — deterministic order).
    remote_busy_s: f64,
    /// Remote transfers retried after a first-attempt link drop.
    link_retries: usize,
}

/// Dispatcher-side lane bookkeeping: which jobs are still in flight,
/// per-lane occupancy (for the idle-gap metric) and the running
/// totals.  Results are absorbed lazily — only when a consumer, a
/// barrier, or the final drain actually needs them — so the idle-gap
/// count reflects lanes *provably* observed empty (deterministic on a
/// single lane; multi-lane counts can vary with cross-lane arrival
/// order, since a blocking settle absorbs whatever message lands
/// first — see [`ExecStats::lane_gaps`]).
struct LaneSt {
    pending: Vec<bool>,
    pending_n: usize,
    /// Jobs dispatched to each lane and not yet absorbed.
    inflight: Vec<usize>,
    /// Lanes that have received at least one job.
    ran: Vec<bool>,
    /// Which lanes are device–edge remote lanes (empty when the
    /// engine carries no remote config — every lane then on-die).
    remote: Vec<bool>,
    /// Next remote transfer index — increments in dispatch order, the
    /// deterministic coordinate the [`crate::device::LinkModel`]
    /// fault schedule is evaluated at.
    transfer_idx: u64,
    totals: LaneTotals,
}

impl LaneSt {
    fn new(num_branches: usize, num_lanes: usize) -> Self {
        Self {
            pending: vec![false; num_branches],
            pending_n: 0,
            inflight: vec![0; num_lanes],
            ran: vec![false; num_lanes],
            remote: Vec::new(),
            transfer_idx: 0,
            totals: LaneTotals {
                busy_s: vec![0.0; num_lanes],
                ..LaneTotals::default()
            },
        }
    }

    /// Draw the next remote transfer index.
    fn next_transfer(&mut self) -> u64 {
        let i = self.transfer_idx;
        self.transfer_idx += 1;
        i
    }

    /// Record a dispatch (the caller sends the job right after).
    fn dispatch(&mut self, b: usize, lane: usize) {
        if self.inflight[lane] == 0 && self.ran[lane] {
            // every earlier job on this lane completed *and merged*
            // before new work arrived: the lane provably idled
            self.totals.gaps += 1;
        }
        self.ran[lane] = true;
        self.inflight[lane] += 1;
        self.pending[b] = true;
        self.pending_n += 1;
    }

    /// Merge one finished job into the store.
    fn absorb(
        &mut self,
        msg: LaneMsg,
        values: &Values,
        pl: &PlacementPlan,
    ) -> anyhow::Result<()> {
        let out = msg.out?;
        if self.remote.get(msg.lane).copied().unwrap_or(false) {
            // downlink: the job's outputs come back over the link
            // (u64 adds commute, so absorb order cannot perturb it)
            self.totals.downlink_bytes +=
                out.iter().map(|(_, v)| v.byte_size() as u64).sum::<u64>();
        }
        for (t, v) in out {
            values.insert_arc(t, v);
        }
        self.pending[msg.branch] = false;
        self.pending_n -= 1;
        self.inflight[msg.lane] -= 1;
        self.totals.jobs += 1;
        self.totals.modelled_s += pl.delegate_latency_s[msg.branch];
        self.totals.busy_s[msg.lane] += pl.delegate_latency_s[msg.branch];
        Ok(())
    }

    /// Absorb results until `done` holds, counting a stall whenever we
    /// actually have to block on a lane.
    fn settle<F: Fn(&LaneSt) -> bool>(
        &mut self,
        rx: &std::sync::mpsc::Receiver<LaneMsg>,
        values: &Values,
        pl: &PlacementPlan,
        done: F,
    ) -> anyhow::Result<()> {
        use std::sync::mpsc::TryRecvError;
        while !done(self) {
            match rx.try_recv() {
                Ok(m) => self.absorb(m, values, pl)?,
                Err(TryRecvError::Empty) => {
                    self.totals.stalls += 1;
                    let m = rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("delegate lanes disconnected"))?;
                    self.absorb(m, values, pl)?;
                }
                Err(TryRecvError::Disconnected) => {
                    anyhow::bail!("delegate lanes disconnected with jobs pending")
                }
            }
        }
        Ok(())
    }

    /// Merge every still-pending job among `deps` (a consumer's
    /// delegated predecessors) before the consumer reads the store.
    fn settle_deps(
        &mut self,
        deps: &[usize],
        rx: &std::sync::mpsc::Receiver<LaneMsg>,
        values: &Values,
        pl: &PlacementPlan,
    ) -> anyhow::Result<()> {
        if deps.iter().any(|&d| self.pending[d]) {
            self.settle(rx, values, pl, |st| !deps.iter().any(|&d| st.pending[d]))?;
        }
        Ok(())
    }

    /// Merge everything in flight (layer barrier / end of run).
    fn drain(
        &mut self,
        rx: &std::sync::mpsc::Receiver<LaneMsg>,
        values: &Values,
        pl: &PlacementPlan,
    ) -> anyhow::Result<()> {
        self.settle(rx, values, pl, |st| st.pending_n == 0)
    }
}

/// One persistent accelerator lane: a dedicated thread bound to one
/// [`AccLane`](crate::device::AccLane) that executes its queued jobs
/// *serially* (one accelerator queue, as a real NNAPI delegate
/// presents) while the CPU fallback waves — and, with cross-layer
/// overlap, the *next layers'* waves — run concurrently on the main
/// path.  The worker outlives layer barriers: it is spawned once per
/// [`Engine::run_placed`] call, fed over an mpsc job queue, and
/// reports each finished branch back to the dispatcher, which merges
/// the outputs into the shared value store right before their first
/// consumer.
///
/// The lane computes branch outputs with the same deterministic host
/// kernels (or the PJRT pool for program-hinted blocks when the `pjrt`
/// feature is on), so delegated results are bit-identical to CPU
/// execution; what the *delegate* contributes is modelled timing
/// ([`SocProfile`](crate::device::SocProfile) per-lane dispatch +
/// compute + transfer, recorded on the
/// [`PlacementPlan`](crate::place::PlacementPlan)) plus real
/// wall-clock overlap.
pub struct DelegateWorker;

impl DelegateWorker {
    /// Spawn one lane worker inside `scope`.  It drains `jobs` until
    /// the dispatcher drops the sending half, reporting every finished
    /// branch on `results` (outputs are merged by the dispatcher, not
    /// here, so the dispatcher controls every merge point).  A
    /// panicking job is caught and reported as an `Err` message — the
    /// dispatcher is blocked in `recv()` waiting for this very job, so
    /// letting the panic kill the thread (while sibling lanes keep
    /// their sender clones alive) would deadlock the run instead of
    /// failing it.
    #[allow(clippy::too_many_arguments)]
    fn spawn<'scope, 'env>(
        scope: &'scope std::thread::Scope<'scope, 'env>,
        engine: &'env Engine<'env>,
        lane: usize,
        jobs: std::sync::mpsc::Receiver<usize>,
        results: std::sync::mpsc::Sender<LaneMsg>,
        values: &'env Values,
        env: &'env ShapeEnv,
        client: Option<WorkerClient>,
        counters: &'env Counters,
        cp: Option<&'env CapturedPlan>,
    ) {
        scope.spawn(move || {
            while let Ok(b) = jobs.recv() {
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    engine.exec_branch(b, values, client.clone(), counters, env, cp)
                }))
                .unwrap_or_else(|panic| {
                    let msg = panic
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "<non-string panic>".into());
                    Err(anyhow::anyhow!("lane {lane} job {b} panicked: {msg}"))
                });
                if results.send(LaneMsg { branch: b, lane, out }).is_err() {
                    // dispatcher bailed: stop draining
                    break;
                }
            }
        });
    }
}

/// Concurrent value store: branches in one wave write disjoint tensors,
/// so a mutex-per-map is enough (writes merge at wave boundaries; the
/// mutex serves the sequential-spill path).  Values are held behind
/// shared `Arc`s: a read hands back a handle, never a deep copy of the
/// tensor data — the store is copy-free on the hot path.
#[derive(Default)]
pub struct Values {
    map: Mutex<HashMap<TensorId, Arc<Tensor>>>,
}

impl Values {
    pub fn insert(&self, t: TensorId, v: Tensor) {
        self.insert_arc(t, Arc::new(v));
    }

    /// Insert an already-shared value (the executor's merge paths —
    /// branch outputs are born shared and never re-boxed).
    pub fn insert_arc(&self, t: TensorId, v: Arc<Tensor>) {
        self.map.lock().unwrap().insert(t, v);
    }

    /// A shared handle on the stored value — cloning the `Arc`, not
    /// the tensor.
    pub fn get(&self, t: TensorId) -> Option<Arc<Tensor>> {
        self.map.lock().unwrap().get(&t).cloned()
    }

    /// Is a value stored for this tensor?  (No clone — the §3.4
    /// resolver uses this to tell computed values from absent ones.)
    pub fn contains(&self, t: TensorId) -> bool {
        self.map.lock().unwrap().contains_key(&t)
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Checksum over all stored values (determinism tests).
    pub fn checksum(&self) -> f64 {
        let m = self.map.lock().unwrap();
        let mut keys: Vec<_> = m.keys().copied().collect();
        keys.sort();
        let mut acc = 0f64;
        for k in keys {
            for (i, &x) in m[&k].data().iter().enumerate() {
                if x.is_finite() {
                    acc += (x as f64) * ((i % 97) as f64 + 1.0) * 1e-6;
                }
            }
        }
        acc
    }

    /// Do all stored tensors contain only finite values?
    pub fn all_finite(&self) -> bool {
        self.map
            .lock()
            .unwrap()
            .values()
            .all(|t| t.data().iter().all(|x| x.is_finite()))
    }
}

/// Reshape-or-resize a tensor to a target shape (copy min prefix,
/// zero-pad) — the shape-cast semantics for synthetic glue sites.
fn fit(t: &Tensor, shape: &[usize]) -> Tensor {
    let n: usize = shape.iter().product();
    if t.len() == n {
        return Tensor::new(shape.to_vec(), t.data().to_vec());
    }
    let mut data = vec![0f32; n];
    let m = n.min(t.len());
    data[..m].copy_from_slice(&t.data()[..m]);
    Tensor::new(shape.to_vec(), data)
}

/// View as rank-2 (collapse leading axes).
fn as2d(t: &Tensor) -> Tensor {
    let shape = t.shape();
    if shape.len() == 2 {
        return t.clone();
    }
    let last = *shape.last().unwrap_or(&1);
    let rows = t.len() / last.max(1);
    Tensor::new(vec![rows, last.max(1)], t.data().to_vec())
}

fn hash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::branch::{self, DEFAULT_BETA};
    use crate::memory::branch_memories;
    use crate::partition::{partition, CostModel};
    use crate::sched::{self, MemoryGovernor, SchedCfg};

    fn full_setup(g: Graph) -> (Graph, Partition, BranchPlan) {
        let p = partition(
            &g,
            &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
        );
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        (g, p, plan)
    }

    fn schedules(
        g: &Graph,
        p: &Partition,
        plan: &BranchPlan,
        threads: usize,
    ) -> Vec<crate::sched::LayerSchedule> {
        let mems = branch_memories(g, p, plan);
        let cfg = SchedCfg { max_threads: threads, margin: 0.4 };
        sched::schedule(plan, &mems, 1 << 34, &cfg)
    }

    #[test]
    fn host_only_run_is_finite_and_deterministic() {
        let (g, p, plan) = full_setup(crate::models::micro::mixed());
        let engine = Engine::new(&g, &p, &plan, None);
        let s1 = schedules(&g, &p, &plan, 1);
        let (v1, st1) = engine.run(&s1).unwrap();
        assert!(v1.all_finite());
        assert!(st1.host_ops > 5);
        let (v2, _) = engine.run(&s1).unwrap();
        assert_eq!(v1.checksum(), v2.checksum());
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let (g, p, plan) = full_setup(crate::models::micro::parallel_chains(6, 8));
        let engine = Engine::new(&g, &p, &plan, None);
        let seq = schedules(&g, &p, &plan, 1);
        let par = schedules(&g, &p, &plan, 6);
        assert!(par.iter().any(|s| !s.waves.is_empty()), "expected waves");
        let (v1, _) = engine.run(&seq).unwrap();
        let (v2, _) = engine.run(&par).unwrap();
        assert_eq!(
            v1.checksum(),
            v2.checksum(),
            "branch isolation must make results schedule-invariant"
        );
    }

    #[test]
    fn arena_accounting_positive() {
        let (g, p, plan) = full_setup(crate::models::micro::diamond(4, 4));
        let engine = Engine::new(&g, &p, &plan, None);
        let s = schedules(&g, &p, &plan, 4);
        let (_, stats) = engine.run(&s).unwrap();
        assert!(stats.peak_arena_bytes > 0);
    }

    #[test]
    fn governed_run_matches_ungoverned() {
        let (g, p, plan) = full_setup(crate::models::micro::parallel_chains(4, 6));
        let engine = Engine::new(&g, &p, &plan, None);
        let s = schedules(&g, &p, &plan, 4);
        let gov = MemoryGovernor::new(1 << 30);
        let (v1, _) = engine.run(&s).unwrap();
        let (v2, _) = engine.run_governed(&s, Some(&gov)).unwrap();
        assert_eq!(
            v1.checksum(),
            v2.checksum(),
            "admission control must not change results"
        );
        assert_eq!(gov.in_use(), 0, "all leases returned");
        assert!(gov.stats().grants > 0, "waves actually leased memory");
        assert!(gov.peak_reserved() <= gov.budget());
    }

    #[test]
    fn tight_governor_still_completes() {
        // a budget smaller than any single branch forces degraded
        // serial admission; the run must still complete and release.
        let (g, p, plan) = full_setup(crate::models::micro::parallel_chains(4, 6));
        let engine = Engine::new(&g, &p, &plan, None);
        let s = schedules(&g, &p, &plan, 4);
        let gov = MemoryGovernor::new(1);
        let (v, _) = engine.run_governed(&s, Some(&gov)).unwrap();
        assert!(v.all_finite());
        assert_eq!(gov.in_use(), 0);
        assert!(gov.stats().over_budget_grants > 0);
    }

    #[test]
    fn delegate_regions_execute_on_host_without_pool() {
        // a partition with regions still runs correctly host-side
        let g = crate::models::micro::mixed();
        let p = partition(&g, &CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX });
        assert!(!p.regions.is_empty());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let engine = Engine::new(&g, &p, &plan, None);
        let s = schedules(&g, &p, &plan, 2);
        let (v, _) = engine.run(&s).unwrap();
        assert!(v.all_finite());
    }

    #[test]
    fn cpu_forced_placed_run_is_bit_identical_to_classic() {
        let g = crate::models::micro::fallback_heavy(4, 3, 32, 3);
        let p = partition(&g, &CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX });
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let engine = Engine::new(&g, &p, &plan, None);
        let s = schedules(&g, &p, &plan, 2);
        let placement = crate::place::PlacementPlan::cpu_only(plan.branches.len());
        let (v1, st1) = engine.run(&s).unwrap();
        let (v2, st2) = engine.run_placed(&s, &placement, None).unwrap();
        assert_eq!(
            v1.checksum(),
            v2.checksum(),
            "CPU-forced placement must be bit-identical to Engine::run"
        );
        assert_eq!(st2.delegate_jobs, 0);
        assert_eq!(st2.acc_modelled_s, 0.0);
        assert_eq!(st1.host_ops, st2.host_ops);
        assert_eq!(st1.cpu_branch_runs, st2.cpu_branch_runs);
    }

    #[test]
    fn delegated_run_matches_outputs_with_fewer_cpu_branches() {
        // heavy enough that the Pixel 6 placement model offloads the
        // trunk; outputs must stay bit-identical while strictly fewer
        // branches execute on the CPU wave path.
        let g = crate::models::micro::fallback_heavy(4, 3, 128, 6);
        let soc = crate::device::SocProfile::pixel6();
        let p = partition(&g, &CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX });
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let engine = Engine::new(&g, &p, &plan, None);
        let s = schedules(&g, &p, &plan, 4);
        let auto = crate::place::assign(&g, &p, &plan, &soc, crate::place::PlacePolicy::Auto);
        assert!(auto.num_delegated() >= 1, "trunk should delegate on pixel6");
        let forced = crate::place::PlacementPlan::cpu_only(plan.branches.len());
        let (v_cpu, st_cpu) = engine.run_placed(&s, &forced, None).unwrap();
        let (v_del, st_del) = engine.run_placed(&s, &auto, None).unwrap();
        assert_eq!(
            v_cpu.checksum(),
            v_del.checksum(),
            "delegate lane must not change results"
        );
        assert_eq!(st_del.delegate_jobs, auto.num_delegated());
        assert!(st_del.acc_modelled_s > 0.0);
        assert!(
            st_del.cpu_branch_runs < st_cpu.cpu_branch_runs,
            "delegated run must execute strictly fewer CPU-wave branches \
             ({} !< {})",
            st_del.cpu_branch_runs,
            st_cpu.cpu_branch_runs
        );
        assert_eq!(
            st_del.cpu_branch_runs + st_del.delegate_jobs,
            st_cpu.cpu_branch_runs,
            "every branch still executes exactly once"
        );
    }

    #[test]
    fn barrier_join_matches_overlap_bit_for_bit() {
        // the overlap knob moves merge points, never values: first-
        // consumer merges and layer-barrier joins must produce the
        // same store and the same job counts
        let g = crate::models::micro::fallback_pipeline(3, 3, 3, 128, 6);
        let soc = crate::device::SocProfile::pixel6();
        let cm = CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX };
        let p = partition(&g, &cm);
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let engine = Engine::new(&g, &p, &plan, None);
        let s = schedules(&g, &p, &plan, 2);
        let auto = crate::place::assign(&g, &p, &plan, &soc, crate::place::PlacePolicy::Auto);
        assert!(auto.num_delegated() >= 2, "every stage trunk should delegate");
        let (v_overlap, st_overlap) = engine.run_placed_opts(&s, &auto, None, true).unwrap();
        let (v_barrier, st_barrier) = engine.run_placed_opts(&s, &auto, None, false).unwrap();
        assert_eq!(v_overlap.checksum(), v_barrier.checksum());
        assert_eq!(st_overlap.delegate_jobs, st_barrier.delegate_jobs);
        assert_eq!(st_overlap.cpu_branch_runs, st_barrier.cpu_branch_runs);
        assert!(
            st_overlap.lane_gaps <= st_barrier.lane_gaps,
            "overlap may only remove idle-lane gaps ({} > {})",
            st_overlap.lane_gaps,
            st_barrier.lane_gaps
        );
    }

    /// Force every delegate-safe branch onto the soc's remote lane —
    /// the spill-everything placement the remote fault tests run.
    fn remote_all(
        g: &Graph,
        p: &Partition,
        plan: &BranchPlan,
        soc: &crate::device::SocProfile,
    ) -> crate::place::PlacementPlan {
        let rl = soc.remote_lane().expect("soc must carry a remote lane");
        let mut pl = crate::place::PlacementPlan::cpu_only(plan.branches.len());
        for b in 0..plan.branches.len() {
            let lat =
                crate::place::lane_delegate_latency(g, p, plan, b, soc, &soc.lanes[rl]);
            if !lat.is_finite() {
                continue;
            }
            pl.assignment[b] = crate::place::Placement::Delegate(rl);
            pl.staging_bytes[b] = crate::place::transfer_bytes(g, p, plan, b);
            pl.delegate_latency_s[b] = lat;
        }
        assert!(pl.num_delegated() >= 1, "expected delegate-safe branches");
        pl
    }

    #[test]
    fn retried_remote_transfers_stay_bit_identical_and_charge_the_link() {
        let g = crate::models::micro::fallback_heavy(4, 3, 128, 6);
        let soc = crate::device::SocProfile::pixel6()
            .with_remote(&crate::device::RemoteLane::edge_server());
        let p = partition(
            &g,
            &CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX },
        );
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let s = schedules(&g, &p, &plan, 4);
        let pl = remote_all(&g, &p, &plan, &soc);
        let engine_cpu = Engine::new(&g, &p, &plan, None);
        let (v_cpu, _) = engine_cpu.run_cpu_forced(&s).unwrap();
        // every first attempt lands in a partition window, every
        // retry clears it: all jobs reach the server, all retried
        let mut engine = Engine::new(&g, &p, &plan, None);
        engine.set_remote(
            soc.lanes.iter().map(|l| l.remote).collect(),
            crate::device::LinkModel {
                seed: 9,
                jitter_frac: 0.25,
                drop_p: 0.0,
                partition_every: 2,
                partition_len: 1,
            },
        );
        let (v, st) = engine.run_placed(&s, &pl, None).unwrap();
        assert_eq!(
            v_cpu.checksum(),
            v.checksum(),
            "remote lane must not change results"
        );
        assert_eq!(st.delegate_jobs, pl.num_delegated());
        assert_eq!(st.link_retries, pl.num_delegated(), "every transfer retried once");
        let staged: u64 = (0..plan.branches.len())
            .filter(|&b| pl.is_delegated(b))
            .map(|b| pl.staging_bytes[b])
            .sum();
        assert_eq!(st.uplink_bytes, 2 * staged, "wasted first attempts charged");
        assert!(st.downlink_bytes > 0);
        assert!(st.remote_busy_s > 0.0);
    }

    #[test]
    fn dead_link_falls_back_to_cpu_bit_identically_never_silently() {
        let g = crate::models::micro::fallback_heavy(4, 3, 128, 6);
        let soc = crate::device::SocProfile::pixel6()
            .with_remote(&crate::device::RemoteLane::edge_server());
        let p = partition(
            &g,
            &CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX },
        );
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let s = schedules(&g, &p, &plan, 4);
        let pl = remote_all(&g, &p, &plan, &soc);
        let engine_cpu = Engine::new(&g, &p, &plan, None);
        let (v_cpu, st_cpu) = engine_cpu.run_cpu_forced(&s).unwrap();
        // a permanent partition: every transfer (and every retry) drops
        let mut engine = Engine::new(&g, &p, &plan, None);
        engine.set_remote(
            soc.lanes.iter().map(|l| l.remote).collect(),
            crate::device::LinkModel {
                seed: 1,
                jitter_frac: 0.0,
                drop_p: 0.0,
                partition_every: 2,
                partition_len: 2,
            },
        );
        let (v, st) = engine.run_placed(&s, &pl, None).unwrap();
        assert_eq!(
            v_cpu.checksum(),
            v.checksum(),
            "persistent-fault fallback must be bit-identical to CPU-forced"
        );
        assert_eq!(st.delegate_jobs, 0, "nothing ever reached the edge server");
        assert_eq!(st.link_retries, pl.num_delegated(), "each job retried once first");
        assert_eq!(st.cpu_branch_runs, st_cpu.cpu_branch_runs, "every branch still ran");
        assert_eq!(st.downlink_bytes, 0);
        assert_eq!(st.remote_busy_s, 0.0);
        assert!(st.uplink_bytes > 0, "the failed attempts still burned uplink");
    }

    #[test]
    fn lossy_remote_runs_repeat_transfer_stats_bitwise() {
        let g = crate::models::micro::fallback_heavy(4, 3, 128, 6);
        let soc = crate::device::SocProfile::pixel6()
            .with_remote(&crate::device::RemoteLane::edge_server());
        let p = partition(
            &g,
            &CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX },
        );
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let s = schedules(&g, &p, &plan, 4);
        let pl = remote_all(&g, &p, &plan, &soc);
        let mut engine = Engine::new(&g, &p, &plan, None);
        engine.set_remote(
            soc.lanes.iter().map(|l| l.remote).collect(),
            crate::device::LinkModel::lossy(2026, 0.2),
        );
        let (v1, st1) = engine.run_placed(&s, &pl, None).unwrap();
        let (v2, st2) = engine.run_placed(&s, &pl, None).unwrap();
        assert_eq!(v1.checksum(), v2.checksum());
        assert_eq!(st1.uplink_bytes, st2.uplink_bytes);
        assert_eq!(st1.downlink_bytes, st2.downlink_bytes);
        assert_eq!(st1.link_retries, st2.link_retries);
        assert_eq!(
            st1.remote_busy_s.to_bits(),
            st2.remote_busy_s.to_bits(),
            "jittered remote busy time must accumulate deterministically"
        );
    }
}
