//! Operator-level FLOP estimation (paper Appendix A).
//!
//! | Op class           | FLOPs per node                                   |
//! |--------------------|--------------------------------------------------|
//! | Conv2D / Depthwise | 2·Cin·Hout·Wout·Kh·Kw·Cout (÷Cin groups for DW)  |
//! | MatMul / Dense     | 2·M·N·K                                          |
//! | Elementwise        | output_size                                      |
//! | Pooling / Reduce   | Hout·Wout·Kh·Kw (per channel·batch)              |
//! | Misc. / Other      | 0 (shape plumbing)                               |
//!
//! Dynamic dims are counted at their upper bound — the delegate cost
//! model (§3.1) wants the worst case, and the simulator rescales by the
//! drawn fill factor at run time.

use crate::graph::{Graph, NodeId, OpClass, OpKind};

/// Estimated FLOPs for one node at worst-case (max) shapes.
pub fn node_flops(g: &Graph, id: NodeId) -> u64 {
    let n = g.node(id);
    let out_numel = |i: usize| -> u64 {
        n.outputs
            .get(i)
            .map(|&t| g.tensor_info(t).numel_max() as u64)
            .unwrap_or(0)
    };
    let in_numel = |i: usize| -> u64 {
        n.inputs
            .get(i)
            .map(|&t| g.tensor_info(t).numel_max() as u64)
            .unwrap_or(0)
    };
    match &n.kind {
        OpKind::Conv2D { kh, kw, .. } => {
            // out: (N, Ho, Wo, Cout); weights: in[1] = (kh, kw, Cin, Cout)
            let cin = conv_cin(g, id);
            2 * out_numel(0) * (*kh as u64) * (*kw as u64) * cin
        }
        OpKind::DepthwiseConv2D { kh, kw, .. } => {
            2 * out_numel(0) * (*kh as u64) * (*kw as u64)
        }
        OpKind::FullyConnected | OpKind::MatMul => {
            // out (…, M, N); the contraction length K comes from input 0's
            // last dim.
            let k = n
                .inputs
                .first()
                .and_then(|&t| g.tensor_info(t).shape.last().map(|d| d.max() as u64))
                .unwrap_or(1);
            2 * out_numel(0) * k
        }
        OpKind::Attention { .. } => {
            // QK^T + PV over (T, D): 4·T·T·D — the quadratic part only;
            // projections appear as separate MatMul nodes.
            let t_d = out_numel(0); // (T, D)
            let t = n
                .outputs
                .first()
                .map(|&o| g.tensor_info(o).shape.first().map(|d| d.max()).unwrap_or(1))
                .unwrap_or(1) as u64;
            4 * t_d * t
        }
        k if k.class() == OpClass::Elementwise => out_numel(0),
        OpKind::Softmax => 5 * out_numel(0),
        OpKind::LayerNorm => 8 * out_numel(0),
        OpKind::AvgPool { k, .. } | OpKind::MaxPool { k, .. } => {
            out_numel(0) * (*k as u64) * (*k as u64)
        }
        OpKind::Mean | OpKind::Sum => in_numel(0),
        k if k.class() == OpClass::Shape => 0,
        // dynamic ops: small constant workload (paper: "assigned a small
        // constant workload")
        OpKind::NonMaxSuppression => 512 * 1024,
        OpKind::BeamSearchStep => 256 * 1024,
        OpKind::EmbeddingLookup => out_numel(0),
        OpKind::If | OpKind::While => 1024,
        _ => 0,
    }
}

fn conv_cin(g: &Graph, id: NodeId) -> u64 {
    let n = g.node(id);
    // Input activation is (N, H, W, Cin) — last dim.
    n.inputs
        .first()
        .and_then(|&t| g.tensor_info(t).shape.last().map(|d| d.max() as u64))
        .unwrap_or(1)
}

/// Sum of node FLOPs over a set of nodes.
pub fn region_flops(g: &Graph, nodes: &[NodeId]) -> u64 {
    nodes.iter().map(|&id| node_flops(g, id)).sum()
}

/// Total graph FLOPs.
pub fn graph_flops(g: &Graph) -> u64 {
    g.nodes().iter().map(|n| node_flops(g, n.id)).sum()
}

/// Boundary transfer bytes of a node set S: tensors crossing ∂S
/// (inputs produced outside S + outputs consumed outside S), per §3.1.
pub fn boundary_bytes(g: &Graph, nodes: &[NodeId]) -> u64 {
    let in_set = |id: NodeId| nodes.contains(&id);
    let mut seen = std::collections::HashSet::new();
    let mut total = 0u64;
    for &id in nodes {
        let n = g.node(id);
        for &t in &n.inputs {
            let from_outside = g.producer(t).map(|p| !in_set(p)).unwrap_or(true);
            if from_outside && seen.insert(t) {
                total += g.tensor_info(t).byte_size_max() as u64;
            }
        }
        for &t in &n.outputs {
            let read_outside = g.consumers(t).iter().any(|&c| !in_set(c));
            if read_outside && seen.insert(t) {
                total += g.tensor_info(t).byte_size_max() as u64;
            }
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, Dim, OpKind};

    #[test]
    fn conv_flops_formula() {
        let mut g = Graph::new("t");
        let x = g.tensor(&[1, 8, 8, 16], "x");
        let w = g.tensor(&[3, 3, 16, 32], "w");
        let y = g.tensor(&[1, 8, 8, 32], "y");
        let id = g.add_node("c", OpKind::Conv2D { kh: 3, kw: 3, stride: 1 }, vec![x, w], vec![y]);
        // 2 * (1*8*8*32) * 3*3*16
        assert_eq!(node_flops(&g, id), 2 * 2048 * 9 * 16);
    }

    #[test]
    fn matmul_flops_formula() {
        let mut g = Graph::new("t");
        let x = g.tensor(&[4, 8], "x");
        let w = g.tensor(&[8, 6], "w");
        let y = g.tensor(&[4, 6], "y");
        let id = g.add_node("m", OpKind::MatMul, vec![x, w], vec![y]);
        assert_eq!(node_flops(&g, id), 2 * 4 * 6 * 8);
    }

    #[test]
    fn elementwise_is_output_size() {
        let mut g = Graph::new("t");
        let x = g.tensor(&[10, 10], "x");
        let y = g.tensor(&[10, 10], "y");
        let z = g.tensor(&[10, 10], "z");
        let id = g.add_node("a", OpKind::Add, vec![x, y], vec![z]);
        assert_eq!(node_flops(&g, id), 100);
    }

    #[test]
    fn shape_ops_are_free() {
        let mut g = Graph::new("t");
        let x = g.tensor(&[10, 10], "x");
        let y = g.tensor(&[100], "y");
        let id = g.add_node("r", OpKind::Reshape, vec![x], vec![y]);
        assert_eq!(node_flops(&g, id), 0);
    }

    #[test]
    fn dynamic_dims_use_upper_bound() {
        let mut g = Graph::new("t");
        let x = g.add_tensor(
            vec![Dim::Dynamic { max: 16 }, Dim::Static(8)],
            DType::F32,
            "x",
        );
        let w = g.tensor(&[8, 6], "w");
        let y = g.add_tensor(
            vec![Dim::Dynamic { max: 16 }, Dim::Static(6)],
            DType::F32,
            "y",
        );
        let id = g.add_node("m", OpKind::MatMul, vec![x, w], vec![y]);
        assert_eq!(node_flops(&g, id), 2 * 16 * 6 * 8);
    }

    #[test]
    fn boundary_bytes_diamond() {
        let mut g = Graph::new("t");
        let t0 = g.tensor(&[4], "in"); // 16 B
        let ta = g.tensor(&[8], "a"); // 32 B
        let tb = g.tensor(&[2], "b"); // 8 B
        g.add_node("a", OpKind::Relu, vec![t0], vec![ta]);
        let nb = g.add_node("b", OpKind::Relu, vec![ta], vec![tb]);
        let tc = g.tensor(&[2], "c");
        let nc = g.add_node("c", OpKind::Relu, vec![tb], vec![tc]);
        // region {b}: boundary = ta (in) + tb (out to c)
        assert_eq!(boundary_bytes(&g, &[nb]), 32 + 8);
        // region {b, c}: boundary = ta in + tc (graph output, no consumer)
        assert_eq!(boundary_bytes(&g, &[nb, nc]), 32);
    }

    #[test]
    fn region_is_sum() {
        let mut g = Graph::new("t");
        let x = g.tensor(&[10], "x");
        let y = g.tensor(&[10], "y");
        let z = g.tensor(&[10], "z");
        let n1 = g.add_node("r1", OpKind::Relu, vec![x], vec![y]);
        let n2 = g.add_node("r2", OpKind::Relu, vec![y], vec![z]);
        assert_eq!(region_flops(&g, &[n1, n2]), 20);
        assert_eq!(graph_flops(&g), 20);
    }
}
