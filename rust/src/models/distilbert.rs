//! DistilBERT for sentiment classification (Table 2: FP32, 66.96M).
//!
//! 6 transformer blocks, D=768, 12 heads, dynamic sequence length
//! (max 128 tokens) + a classification head.

use super::blocks::{attention_block, ffn_block, TransformerCfg};
use crate::graph::{DType, Dim, Graph, OpKind};

pub const BLOCKS: usize = 6;
pub const D: usize = 768;
pub const HEADS: usize = 12;
pub const MAX_T: usize = 128;

pub fn build() -> Graph {
    let mut g = Graph::new("distilbert");
    let cfg = TransformerCfg {
        t: MAX_T,
        d: D,
        heads: HEADS,
        ffn_mult: 4,
        seq_dynamic: true,
        per_head: false,
    };
    let seq = Dim::Dynamic { max: MAX_T };

    let raw = g.add_tensor(vec![seq], DType::I32, "ids_in");
    let ids = g.add_tensor(vec![seq], DType::I32, "token_ids");
    g.add_node("input", OpKind::Input, vec![raw], vec![ids]);
    let emb_table = g.tensor(&[30522, D], "tok_embedding");
    let emb = g.add_tensor(vec![seq, Dim::Static(D)], DType::F32, "embedded");
    g.add_node("embed", OpKind::EmbeddingLookup, vec![ids, emb_table], vec![emb]);
    let pos_table = g.tensor(&[MAX_T, D], "pos_embedding");
    let pos_slice = g.add_tensor(vec![seq, Dim::Static(D)], DType::F32, "pos_slice");
    g.add_node("pos.slice", OpKind::Slice, vec![pos_table], vec![pos_slice]);
    let summed = g.add_tensor(vec![seq, Dim::Static(D)], DType::F32, "emb_sum");
    g.add_node("pos.add", OpKind::Add, vec![emb, pos_slice], vec![summed]);
    let ln_g0 = g.tensor(&[D], "emb_ln.g");
    let ln_b0 = g.tensor(&[D], "emb_ln.b");
    let mut x = g.add_tensor(vec![seq, Dim::Static(D)], DType::F32, "h0");
    g.add_node("emb_ln", OpKind::LayerNorm, vec![summed, ln_g0, ln_b0], vec![x]);

    for i in 0..BLOCKS {
        x = attention_block(&mut g, x, cfg, &format!("blk{i}"), Some("attn_128x768_h12"));
        x = ffn_block(&mut g, x, cfg, &format!("blk{i}"), Some("ffn_128x768x3072"));
    }

    // classification head: CLS gather -> pre-classifier -> relu -> classifier
    let cls = g.tensor(&[1, D], "cls");
    g.add_node("cls_gather", OpKind::Gather, vec![x], vec![cls]);
    let w1 = g.tensor(&[D, D], "pre_classifier.w");
    let h1 = g.tensor(&[1, D], "pre_classifier");
    g.add_node("pre_classifier", OpKind::MatMul, vec![cls, w1], vec![h1]);
    let act = g.tensor(&[1, D], "pre_relu");
    g.add_node("pre_relu", OpKind::Relu, vec![h1], vec![act]);
    let w2 = g.tensor(&[D, 2], "classifier.w");
    let logits = g.tensor(&[1, 2], "logits");
    g.add_node("classifier", OpKind::MatMul, vec![act, w2], vec![logits]);
    let probs = g.tensor(&[1, 2], "probs");
    g.add_node("softmax", OpKind::Softmax, vec![logits], vec![probs]);
    let out = g.tensor(&[1, 2], "out");
    g.add_node("output", OpKind::Output, vec![probs], vec![out]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_count_near_table7() {
        // Table 7 "Pre": 353 nodes.
        let g = build();
        let n = g.num_nodes();
        assert!(
            (250..=400).contains(&n),
            "DistilBERT node count {n} too far from Table 7's 353"
        );
    }

    #[test]
    fn validates() {
        let g = build();
        assert!(g.validate().is_empty(), "{:?}", g.validate());
    }

    #[test]
    fn classification_head_present() {
        let g = build();
        assert!(g.nodes().iter().any(|n| n.name == "classifier"));
        assert!(g.nodes().iter().any(|n| n.name == "softmax"));
    }
}
