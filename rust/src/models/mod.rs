//! The model zoo: structurally faithful synthetic reconstructions of
//! the paper's five benchmark DNNs (Table 2), plus micro graphs for
//! tests.
//!
//! Parallax is weight-agnostic — every analysis consumes only DAG
//! topology, op metadata, shapes and FLOPs — so a topology-faithful
//! synthetic graph exercises the full pipeline exactly as the real
//! model would (see ARCHITECTURE.md §Substitutions).  Node counts are
//! calibrated against Table 7's "Pre" column.

pub mod blocks;
pub mod clip_text;
pub mod distilbert;
pub mod micro;
pub mod swinv2_tiny;
pub mod whisper_tiny;
pub mod yolov8n;

use crate::graph::Graph;

/// The five paper models.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelKind {
    Yolov8n,
    WhisperTiny,
    Swinv2Tiny,
    ClipText,
    DistilBert,
}

impl ModelKind {
    pub const ALL: [ModelKind; 5] = [
        ModelKind::Yolov8n,
        ModelKind::WhisperTiny,
        ModelKind::Swinv2Tiny,
        ModelKind::ClipText,
        ModelKind::DistilBert,
    ];

    /// Paper's display name (Tables 3–7 row label).
    pub fn display_name(&self) -> &'static str {
        match self {
            ModelKind::Yolov8n => "YOLOv8n",
            ModelKind::WhisperTiny => "Whisper-Tiny",
            ModelKind::Swinv2Tiny => "SwinV2-Tiny",
            ModelKind::ClipText => "CLIP Text Encoder",
            ModelKind::DistilBert => "DistilBERT",
        }
    }

    /// CLI identifier.
    pub fn slug(&self) -> &'static str {
        match self {
            ModelKind::Yolov8n => "yolov8n",
            ModelKind::WhisperTiny => "whisper-tiny",
            ModelKind::Swinv2Tiny => "swinv2-tiny",
            ModelKind::ClipText => "clip-text",
            ModelKind::DistilBert => "distilbert",
        }
    }

    pub fn from_slug(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|m| m.slug() == s)
    }

    /// Static weight bytes (Table 2 params × dtype width) — part of the
    /// peak-memory accounting in Table 4.
    pub fn weight_bytes(&self) -> u64 {
        match self {
            ModelKind::Yolov8n => 3_190_000 * 4,
            ModelKind::WhisperTiny => 46_510_000, // INT8-quantised weights
            ModelKind::Swinv2Tiny => 28_600_000 * 2, // FP16
            ModelKind::ClipText => 63_170_000 * 4,
            ModelKind::DistilBert => 66_960_000 * 4,
        }
    }

    /// Build the computation graph.
    pub fn build(&self) -> Graph {
        match self {
            ModelKind::Yolov8n => yolov8n::build(),
            ModelKind::WhisperTiny => whisper_tiny::build(),
            ModelKind::Swinv2Tiny => swinv2_tiny::build(),
            ModelKind::ClipText => clip_text::build(),
            ModelKind::DistilBert => distilbert::build(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_build_and_validate() {
        for kind in ModelKind::ALL {
            let g = kind.build();
            assert!(
                g.validate().is_empty(),
                "{}: {:?}",
                kind.display_name(),
                g.validate()
            );
            assert!(g.topo_order().is_some(), "{}", kind.display_name());
        }
    }

    #[test]
    fn slug_round_trip() {
        for kind in ModelKind::ALL {
            assert_eq!(ModelKind::from_slug(kind.slug()), Some(kind));
        }
        assert_eq!(ModelKind::from_slug("nope"), None);
    }
}
