//! YOLOv8n object detector (Table 2: [1,3,640,640], FP32, 3.19M).
//!
//! Converter-grained graph: SiLU expanded to Logistic+Mul, explicit
//! Pads on stride-2 convs, C2f split/concat blocks, SPPF, FPN/PAN neck,
//! decoupled detect head with DFL, and a final NonMaxSuppression whose
//! output box count is dynamic — the op that forces every baseline
//! framework onto the CPU for the tail of this graph.

use super::blocks::{conv1x1, conv_silu};
use crate::graph::{DType, Dim, Graph, OpKind, TensorId};

/// One C2f block: cv1 → split → n bottlenecks (+pass-through) → concat → cv2.
#[allow(clippy::too_many_arguments)]
fn c2f(
    g: &mut Graph,
    x: TensorId,
    h: usize,
    w: usize,
    c: usize,
    n: usize,
    tag: &str,
) -> TensorId {
    let half = c / 2;
    let cv1 = conv1x1(g, x, h, w, c, c, true, &format!("{tag}.cv1"));
    // split into two halves
    let s1 = g.tensor(&[1, h, w, half], &format!("{tag}.split1"));
    let s2 = g.tensor(&[1, h, w, half], &format!("{tag}.split2"));
    g.add_node(format!("{tag}.split"), OpKind::Split { ways: 2 }, vec![cv1], vec![s1, s2]);
    let mut parts = vec![s1, s2];
    let mut cur = s2;
    for i in 0..n {
        let b1 = conv_silu(g, cur, h, w, half, half, 1, &format!("{tag}.m{i}.cv1"), None);
        let b2 = conv_silu(g, b1, h, w, half, half, 1, &format!("{tag}.m{i}.cv2"), None);
        let added = g.tensor(&[1, h, w, half], &format!("{tag}.m{i}.add"));
        g.add_node(format!("{tag}.m{i}.add"), OpKind::Add, vec![cur, b2], vec![added]);
        parts.push(added);
        cur = added;
    }
    let cat = g.tensor(&[1, h, w, half * parts.len()], &format!("{tag}.cat"));
    g.add_node(format!("{tag}.concat"), OpKind::Concat, parts, vec![cat]);
    conv1x1(g, cat, h, w, half * (n + 2), c, true, &format!("{tag}.cv2"))
}

/// SPPF: cv1 → 3 chained maxpools → concat → cv2.
fn sppf(g: &mut Graph, x: TensorId, h: usize, w: usize, c: usize, tag: &str) -> TensorId {
    let half = c / 2;
    let cv1 = conv1x1(g, x, h, w, c, half, true, &format!("{tag}.cv1"));
    let mut pools = vec![cv1];
    let mut cur = cv1;
    for i in 0..3 {
        let p = g.tensor(&[1, h, w, half], &format!("{tag}.pool{i}"));
        g.add_node(
            format!("{tag}.pool{i}"),
            OpKind::MaxPool { k: 5, stride: 1 },
            vec![cur],
            vec![p],
        );
        pools.push(p);
        cur = p;
    }
    let cat = g.tensor(&[1, h, w, half * 4], &format!("{tag}.cat"));
    g.add_node(format!("{tag}.concat"), OpKind::Concat, pools, vec![cat]);
    conv1x1(g, cat, h, w, half * 4, c, true, &format!("{tag}.cv2"))
}

/// Detect head for one scale: separate box and cls conv towers (the
/// paper's 6-branch layer: 3 scales × 2 towers), DFL decode on the box
/// side.
fn detect_head(
    g: &mut Graph,
    x: TensorId,
    h: usize,
    w: usize,
    c: usize,
    tag: &str,
) -> (TensorId, TensorId) {
    // box tower
    let b1 = conv_silu(g, x, h, w, c, 64, 1, &format!("{tag}.box1"), None);
    let b2 = conv_silu(g, b1, h, w, 64, 64, 1, &format!("{tag}.box2"), None);
    let box_raw = conv1x1(g, b2, h, w, 64, 64, false, &format!("{tag}.box3"));
    // DFL: shape glue + reshape -> softmax over 16 bins -> expectation
    // matmul -> reshape, then grid/anchor decode (slice, add, mul, concat).
    let shp = g.tensor(&[4], &format!("{tag}.dfl.shape"));
    g.add_node(format!("{tag}.dfl.shape"), OpKind::Cast, vec![box_raw], vec![shp]);
    let r1 = g.tensor(&[1, h * w * 4, 16], &format!("{tag}.dfl.r1"));
    g.add_node(format!("{tag}.dfl.reshape1"), OpKind::Reshape, vec![box_raw, shp], vec![r1]);
    let tr = g.tensor(&[1, 16, h * w * 4], &format!("{tag}.dfl.t"));
    g.add_node(format!("{tag}.dfl.transpose"), OpKind::Transpose, vec![r1], vec![tr]);
    let sm = g.tensor(&[1, 16, h * w * 4], &format!("{tag}.dfl.sm"));
    g.add_node(format!("{tag}.dfl.softmax"), OpKind::Softmax, vec![tr], vec![sm]);
    let dflw = g.tensor(&[16, 1], &format!("{tag}.dfl.w"));
    let expd = g.tensor(&[1, h * w * 4, 1], &format!("{tag}.dfl.mm"));
    g.add_node(format!("{tag}.dfl.expect"), OpKind::MatMul, vec![sm, dflw], vec![expd]);
    let dist = g.tensor(&[1, h * w, 4], &format!("{tag}.dist"));
    g.add_node(format!("{tag}.dfl.reshape2"), OpKind::Reshape, vec![expd], vec![dist]);
    // grid decode: anchors + strides (lt/rb slices, sub/add, concat, mul)
    let anchors = g.tensor(&[1, h * w, 2], &format!("{tag}.anchors"));
    let lt = g.tensor(&[1, h * w, 2], &format!("{tag}.lt"));
    g.add_node(format!("{tag}.lt_slice"), OpKind::Slice, vec![dist], vec![lt]);
    let rb = g.tensor(&[1, h * w, 2], &format!("{tag}.rb"));
    g.add_node(format!("{tag}.rb_slice"), OpKind::Slice, vec![dist], vec![rb]);
    let x1y1 = g.tensor(&[1, h * w, 2], &format!("{tag}.x1y1"));
    g.add_node(format!("{tag}.x1y1"), OpKind::Sub, vec![anchors, lt], vec![x1y1]);
    let x2y2 = g.tensor(&[1, h * w, 2], &format!("{tag}.x2y2"));
    g.add_node(format!("{tag}.x2y2"), OpKind::Add, vec![anchors, rb], vec![x2y2]);
    let xyxy = g.tensor(&[1, h * w, 4], &format!("{tag}.xyxy"));
    g.add_node(format!("{tag}.xyxy"), OpKind::Concat, vec![x1y1, x2y2], vec![xyxy]);
    let stride_t = g.tensor(&[1], &format!("{tag}.stride"));
    let boxes = g.tensor(&[1, h * w, 4], &format!("{tag}.boxes"));
    g.add_node(format!("{tag}.stride_mul"), OpKind::Mul, vec![xyxy, stride_t], vec![boxes]);

    // cls tower
    let c1 = conv_silu(g, x, h, w, c, 80, 1, &format!("{tag}.cls1"), None);
    let c2 = conv_silu(g, c1, h, w, 80, 80, 1, &format!("{tag}.cls2"), None);
    let cls_raw = conv1x1(g, c2, h, w, 80, 80, false, &format!("{tag}.cls3"));
    let cls_r = g.tensor(&[1, h * w, 80], &format!("{tag}.cls_r"));
    g.add_node(format!("{tag}.cls.reshape"), OpKind::Reshape, vec![cls_raw], vec![cls_r]);
    let cls = g.tensor(&[1, h * w, 80], &format!("{tag}.cls_sig"));
    g.add_node(format!("{tag}.cls.sigmoid"), OpKind::Logistic, vec![cls_r], vec![cls]);
    (boxes, cls)
}

pub fn build() -> Graph {
    let mut g = Graph::new("yolov8n");

    let raw = g.tensor(&[1, 640, 640, 3], "image_in");
    let img = g.tensor(&[1, 640, 640, 3], "image");
    g.add_node("input", OpKind::Input, vec![raw], vec![img]);

    // backbone (channels scaled for the nano model, converter-grained)
    let x = conv_silu(&mut g, img, 640, 640, 3, 16, 2, "stem0", None); // 320
    let x = conv_silu(&mut g, x, 320, 320, 16, 32, 2, "stem1", None); // 160
    let x = c2f(&mut g, x, 160, 160, 32, 3, "s1.c2f");
    let x = conv_silu(&mut g, x, 160, 160, 32, 64, 2, "s2.down", None); // 80
    let p3 = c2f(&mut g, x, 80, 80, 64, 6, "s2.c2f");
    let x = conv_silu(
        &mut g, p3, 80, 80, 64, 128, 2,
        "s3.down", Some("conv3x3_silu_40x40x64x128_s2"),
    ); // 40
    let p4 = c2f(&mut g, x, 40, 40, 128, 6, "s3.c2f");
    let x = conv_silu(&mut g, p4, 40, 40, 128, 256, 2, "s4.down", None); // 20
    let x = c2f(&mut g, x, 20, 20, 256, 3, "s4.c2f");
    let p5 = sppf(&mut g, x, 20, 20, 256, "sppf");

    // neck: top-down (FPN)
    let up4 = g.tensor(&[1, 40, 40, 256], "up4");
    g.add_node("up4.resize", OpKind::Cast, vec![p5], vec![up4]); // nearest-resize
    let cat4 = g.tensor(&[1, 40, 40, 384], "cat4");
    g.add_node("cat4", OpKind::Concat, vec![up4, p4], vec![cat4]);
    let n4 = c2f(&mut g, cat4, 40, 40, 128, 2, "neck.p4");

    let up3 = g.tensor(&[1, 80, 80, 128], "up3");
    g.add_node("up3.resize", OpKind::Cast, vec![n4], vec![up3]);
    let cat3 = g.tensor(&[1, 80, 80, 192], "cat3");
    g.add_node("cat3", OpKind::Concat, vec![up3, p3], vec![cat3]);
    let n3 = c2f(&mut g, cat3, 80, 80, 64, 2, "neck.p3");

    // bottom-up (PAN)
    let d3 = conv_silu(&mut g, n3, 80, 80, 64, 64, 2, "pan.d3", None); // 40
    let cat4b = g.tensor(&[1, 40, 40, 192], "cat4b");
    g.add_node("cat4b", OpKind::Concat, vec![d3, n4], vec![cat4b]);
    let n4b = c2f(&mut g, cat4b, 40, 40, 128, 2, "pan.p4");

    let d4 = conv_silu(&mut g, n4b, 40, 40, 128, 128, 2, "pan.d4", None); // 20
    let cat5 = g.tensor(&[1, 20, 20, 384], "cat5");
    g.add_node("cat5", OpKind::Concat, vec![d4, p5], vec![cat5]);
    let n5 = c2f(&mut g, cat5, 20, 20, 256, 2, "pan.p5");

    // decoupled heads at 3 scales (box + cls towers = 6 parallel branches)
    let (b3, c3) = detect_head(&mut g, n3, 80, 80, 64, "head.p3");
    let (b4, c4) = detect_head(&mut g, n4b, 40, 40, 128, "head.p4");
    let (b5, c5) = detect_head(&mut g, n5, 20, 20, 256, "head.p5");

    // gather detections and NMS (dynamic output)
    let all_boxes = g.tensor(&[1, 8400, 4], "all_boxes");
    g.add_node("cat_boxes", OpKind::Concat, vec![b3, b4, b5], vec![all_boxes]);
    let all_cls = g.tensor(&[1, 8400, 80], "all_cls");
    g.add_node("cat_cls", OpKind::Concat, vec![c3, c4, c5], vec![all_cls]);
    let dets = g.add_tensor(
        vec![Dim::Static(1), Dim::Dynamic { max: 300 }, Dim::Static(6)],
        DType::F32,
        "detections",
    );
    g.add_node("nms", OpKind::NonMaxSuppression, vec![all_boxes, all_cls], vec![dets]);
    let out = g.add_tensor(
        vec![Dim::Static(1), Dim::Dynamic { max: 300 }, Dim::Static(6)],
        DType::F32,
        "out",
    );
    g.add_node("output", OpKind::Output, vec![dets], vec![out]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_count_near_table7() {
        // Table 7 "Pre": 480 nodes.
        let g = build();
        let n = g.num_nodes();
        assert!(
            (220..=600).contains(&n),
            "YOLOv8n node count {n} too far from Table 7's 480"
        );
    }

    #[test]
    fn validates() {
        let g = build();
        assert!(g.validate().is_empty(), "{:?}", g.validate());
        assert!(g.topo_order().is_some());
    }

    #[test]
    fn nms_is_dynamic() {
        let g = build();
        let nms = g.nodes().iter().find(|n| n.name == "nms").unwrap();
        assert!(g.node_has_dynamic_shape(nms.id));
    }

    #[test]
    fn flops_in_nano_range() {
        // YOLOv8n is ~8.7 GFLOPs at 640x640; converter-grained graph with
        // scaled channels should land within 2-20 G.
        let g = build();
        let f = crate::flops::graph_flops(&g);
        assert!(
            (2e9..2e10).contains(&(f as f64)),
            "YOLOv8n flops {f} out of range"
        );
    }
}
