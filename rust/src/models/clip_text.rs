//! CLIP Text Encoder (Table 2: [batch, sequence_len], FP32, 63.17M).
//!
//! 12 transformer blocks, D=512, 8 heads, dynamic sequence length
//! (max 77 tokens).  The dynamic seq dim is what defeats TFLite's NNAPI
//! delegation in the paper (Table 3 "Het" column is `-` for TFLite).

use super::blocks::{attention_block, ffn_block, TransformerCfg};
use crate::graph::{DType, Dim, Graph, OpKind};

pub const BLOCKS: usize = 12;
pub const D: usize = 512;
pub const HEADS: usize = 8;
pub const MAX_T: usize = 77;

pub fn build() -> Graph {
    let mut g = Graph::new("clip_text");
    let cfg = TransformerCfg {
        t: MAX_T,
        d: D,
        heads: HEADS,
        ffn_mult: 4,
        seq_dynamic: true,
        per_head: false,
    };
    let seq = Dim::Dynamic { max: MAX_T };

    // token ids -> embedding lookup + positional add
    let ids = g.add_tensor(vec![seq], DType::I32, "token_ids");
    let in_node = {
        let t = g.add_tensor(vec![seq], DType::I32, "ids_in");
        g.add_node("input", OpKind::Input, vec![t], vec![ids])
    };
    let _ = in_node;
    let emb_table = g.tensor(&[49408, D], "tok_embedding");
    let emb = g.add_tensor(vec![seq, Dim::Static(D)], DType::F32, "embedded");
    g.add_node("embed", OpKind::EmbeddingLookup, vec![ids, emb_table], vec![emb]);
    let pos_table = g.tensor(&[MAX_T, D], "pos_embedding");
    let pos_slice = g.add_tensor(vec![seq, Dim::Static(D)], DType::F32, "pos_slice");
    g.add_node("pos.slice", OpKind::Slice, vec![pos_table], vec![pos_slice]);
    let mut x = g.add_tensor(vec![seq, Dim::Static(D)], DType::F32, "h0");
    g.add_node("pos.add", OpKind::Add, vec![emb, pos_slice], vec![x]);

    for i in 0..BLOCKS {
        x = attention_block(&mut g, x, cfg, &format!("blk{i}"), Some("attn_77x512_h8"));
        x = ffn_block(&mut g, x, cfg, &format!("blk{i}"), Some("ffn_77x512x2048"));
    }

    // final LN + EOS-token pooling + projection
    let ln_g = g.tensor(&[D], "final_ln.g");
    let ln_b = g.tensor(&[D], "final_ln.b");
    let lnf = g.add_tensor(vec![seq, Dim::Static(D)], DType::F32, "final_ln");
    let anchor = g.add_node("final_ln", OpKind::LayerNorm, vec![x, ln_g, ln_b], vec![lnf]);
    g.set_program(anchor, "layernorm_77x512");
    let pooled = g.tensor(&[1, D], "pooled");
    g.add_node("eos_gather", OpKind::Gather, vec![lnf], vec![pooled]);
    let wp = g.tensor(&[D, D], "text_proj.w");
    let projected = g.tensor(&[1, D], "text_embedding");
    g.add_node("text_proj", OpKind::MatMul, vec![pooled, wp], vec![projected]);
    let out = g.tensor(&[1, D], "out");
    g.add_node("output", OpKind::Output, vec![projected], vec![out]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_count_near_table7() {
        // Table 7 "Pre": 635 nodes for the CLIP text encoder.
        let g = build();
        let n = g.num_nodes();
        assert!(
            (460..=700).contains(&n),
            "CLIP node count {n} too far from Table 7's 635"
        );
    }

    #[test]
    fn validates_and_topo_sorts() {
        let g = build();
        assert!(g.validate().is_empty(), "{:?}", g.validate());
        assert!(g.topo_order().is_some());
    }

    #[test]
    fn has_dynamic_inputs() {
        let g = build();
        assert!(g
            .nodes()
            .iter()
            .any(|n| g.node_has_dynamic_shape(n.id)));
    }

    #[test]
    fn program_hints_present() {
        let g = build();
        let hints: std::collections::HashSet<_> = g
            .nodes()
            .iter()
            .filter_map(|n| n.program.as_deref())
            .collect();
        assert!(hints.contains("attn_77x512_h8"));
        assert!(hints.contains("ffn_77x512x2048"));
    }
}
