//! Shared subgraph builders for the model zoo.
//!
//! Each builder appends a fine-grained op subgraph (the granularity a
//! TFLite converter would emit — separate bias adds, reshapes,
//! transposes) and returns the output tensor.  Builders optionally tag
//! the subgraph with an L2 `program` hint: the anchor node carries the
//! program name and every other node is `fused_into` it, so the real
//! execution engine can run the whole block as one AOT artifact while
//! the analyses still see the fine-grained structure.

use crate::graph::{DType, Dim, Graph, NodeId, OpKind, TensorId};

/// Config for one transformer block.
#[derive(Clone, Copy, Debug)]
pub struct TransformerCfg {
    /// Sequence length (tokens). `seq_dynamic` makes it a dynamic dim.
    pub t: usize,
    pub d: usize,
    pub heads: usize,
    pub ffn_mult: usize,
    pub seq_dynamic: bool,
    /// Expand attention into per-head parallel branches (how some
    /// converters export MHA; gives the Table 6 BR=6 Whisper layers).
    pub per_head: bool,
}

impl TransformerCfg {
    fn seq_dim(&self) -> Dim {
        if self.seq_dynamic {
            Dim::Dynamic { max: self.t }
        } else {
            Dim::Static(self.t)
        }
    }

    fn td(&self, g: &mut Graph, label: &str) -> TensorId {
        let dims = vec![self.seq_dim(), Dim::Static(self.d)];
        g.add_tensor(dims, DType::F32, label)
    }
}

/// Mark `nodes` as fused into `anchor`, which carries `program`.
fn tag_program(g: &mut Graph, anchor: NodeId, nodes: &[NodeId], program: Option<&str>) {
    if let Some(p) = program {
        g.set_program(anchor, p);
        for &n in nodes {
            if n != anchor {
                g.set_fused_into(n, anchor);
            }
        }
    }
}

/// Shape-computation glue a converter emits around dynamic reshapes:
/// Cast → Gather → Concat producing the i32 shape vector the Reshape
/// consumes.  Returns (shape_tensor, nodes).
fn shape_glue(g: &mut Graph, src: TensorId, tag: &str) -> (TensorId, Vec<NodeId>) {
    let s0 = g.add_tensor(vec![Dim::Static(4)], DType::I32, &format!("{tag}.shape"));
    let n1 = g.add_node(format!("{tag}.shape"), OpKind::Cast, vec![src], vec![s0]);
    let s1 = g.add_tensor(vec![Dim::Static(1)], DType::I32, &format!("{tag}.dim"));
    let n2 = g.add_node(format!("{tag}.dim"), OpKind::Gather, vec![s0], vec![s1]);
    let s2 = g.add_tensor(vec![Dim::Static(3)], DType::I32, &format!("{tag}.newshape"));
    let n3 = g.add_node(format!("{tag}.pack"), OpKind::Concat, vec![s1], vec![s2]);
    (s2, vec![n1, n2, n3])
}

/// Multi-head self-attention block (pre-LN, residual), fine-grained:
///
///   x ──ln──┬─ q = x@Wq + bq ─ reshape ─┐
///           ├─ k = x@Wk + bk ─ reshape ─┼─ attn ─ reshape ─ o = @Wo ─ add(bias)
///           └─ v = x@Wv + bv ─ reshape ─┘                     │
///   x ────────────────────────── residual add ◄───────────────┘
///
/// The q/k/v chains are the paper's intra-block parallel branches
/// (Table 7: CLIP/DistilBERT show Max-Branches = 4 — q, k, v and the
/// residual skip).
pub fn attention_block(
    g: &mut Graph,
    x: TensorId,
    cfg: TransformerCfg,
    tag: &str,
    program: Option<&str>,
) -> TensorId {
    let mut nodes = Vec::new();
    let d = cfg.d;

    let ln_out = cfg.td(g, &format!("{tag}.ln1"));
    let ln_g = g.tensor(&[d], &format!("{tag}.ln1.g"));
    let ln_b = g.tensor(&[d], &format!("{tag}.ln1.b"));
    let anchor = g.add_node(
        format!("{tag}.ln1"),
        OpKind::LayerNorm,
        vec![x, ln_g, ln_b],
        vec![ln_out],
    );
    nodes.push(anchor);

    // q, k, v projection chains (parallel branches), converter-grained:
    // matmul, bias-reshape, bias-add, shape glue, reshape, transpose.
    let mut heads_in = Vec::new();
    for name in ["q", "k", "v"] {
        let w = g.tensor(&[d, d], &format!("{tag}.{name}.w"));
        let b = g.tensor(&[d], &format!("{tag}.{name}.b"));
        let mm = cfg.td(g, &format!("{tag}.{name}.mm"));
        let n1 = g.add_node(format!("{tag}.{name}.matmul"), OpKind::MatMul, vec![ln_out, w], vec![mm]);
        let biased = cfg.td(g, &format!("{tag}.{name}.bias"));
        let n2 = g.add_node(format!("{tag}.{name}.bias"), OpKind::Add, vec![mm, b], vec![biased]);
        nodes.extend([n1, n2]);
        let mut rs_in = vec![biased];
        if cfg.seq_dynamic {
            let (st, glue) = shape_glue(g, biased, &format!("{tag}.{name}"));
            nodes.extend(glue);
            rs_in.push(st);
        }
        let shaped = g.add_tensor(
            vec![cfg.seq_dim(), Dim::Static(cfg.heads), Dim::Static(d / cfg.heads)],
            DType::F32,
            &format!("{tag}.{name}.heads"),
        );
        let n3 = g.add_node(format!("{tag}.{name}.reshape"), OpKind::Reshape, rs_in, vec![shaped]);
        let tp = g.add_tensor(
            vec![Dim::Static(cfg.heads), cfg.seq_dim(), Dim::Static(d / cfg.heads)],
            DType::F32,
            &format!("{tag}.{name}.t"),
        );
        let n4 = g.add_node(format!("{tag}.{name}.transpose"), OpKind::Transpose, vec![shaped], vec![tp]);
        nodes.extend([n3, n4]);
        heads_in.push(tp);
    }

    // scaled-dot-product attention, either heads-fused (one chain) or
    // per-head (H parallel chains — the converter layout that yields
    // the Table 6 Whisper layers with BR=6):
    //   scores = q@k^T * scale (+ mask); p = softmax(scores); ctx = p@v
    let dh = d / cfg.heads;
    let hs = |g: &mut Graph, label: &str| {
        g.add_tensor(
            vec![Dim::Static(cfg.heads), cfg.seq_dim(), cfg.seq_dim()],
            DType::F32,
            label,
        )
    };
    let ctx = if cfg.per_head {
        // split each of q/k/v into H per-head tensors
        let mut per_head: Vec<Vec<TensorId>> = Vec::new();
        for (i, name) in ["q", "k", "v"].iter().enumerate() {
            let outs: Vec<TensorId> = (0..cfg.heads)
                .map(|h| {
                    let dims = vec![cfg.seq_dim(), Dim::Static(dh)];
                    g.add_tensor(dims, DType::F32, &format!("{tag}.{name}.h{h}"))
                })
                .collect();
            let ns = g.add_node(
                format!("{tag}.{name}.head_split"),
                OpKind::Split { ways: cfg.heads },
                vec![heads_in[i]],
                outs.clone(),
            );
            nodes.push(ns);
            per_head.push(outs);
        }
        let mut head_ctx = Vec::new();
        for h in 0..cfg.heads {
            let kt = g.add_tensor(
                vec![Dim::Static(dh), cfg.seq_dim()],
                DType::F32,
                &format!("{tag}.h{h}.kT"),
            );
            let n1 = g.add_node(
                format!("{tag}.h{h}.kT"),
                OpKind::Transpose,
                vec![per_head[1][h]],
                vec![kt],
            );
            let sc = {
                let dims = vec![cfg.seq_dim(), cfg.seq_dim()];
                g.add_tensor(dims, DType::F32, &format!("{tag}.h{h}.scores"))
            };
            let n2 = g.add_node(
                format!("{tag}.h{h}.qk"),
                OpKind::MatMul,
                vec![per_head[0][h], kt],
                vec![sc],
            );
            let scale = g.tensor(&[1], &format!("{tag}.h{h}.scale"));
            let scd = {
                let dims = vec![cfg.seq_dim(), cfg.seq_dim()];
                g.add_tensor(dims, DType::F32, &format!("{tag}.h{h}.scaled"))
            };
            let n3 = g.add_node(
                format!("{tag}.h{h}.scale"),
                OpKind::Mul,
                vec![sc, scale],
                vec![scd],
            );
            let pr = {
                let dims = vec![cfg.seq_dim(), cfg.seq_dim()];
                g.add_tensor(dims, DType::F32, &format!("{tag}.h{h}.probs"))
            };
            let n4 = g.add_node(format!("{tag}.h{h}.softmax"), OpKind::Softmax, vec![scd], vec![pr]);
            let cx = {
                let dims = vec![cfg.seq_dim(), Dim::Static(dh)];
                g.add_tensor(dims, DType::F32, &format!("{tag}.h{h}.ctx"))
            };
            let n5 = g.add_node(
                format!("{tag}.h{h}.pv"),
                OpKind::MatMul,
                vec![pr, per_head[2][h]],
                vec![cx],
            );
            nodes.extend([n1, n2, n3, n4, n5]);
            head_ctx.push(cx);
        }
        let ctx = g.add_tensor(
            vec![Dim::Static(cfg.heads), cfg.seq_dim(), Dim::Static(dh)],
            DType::F32,
            &format!("{tag}.ctx"),
        );
        let nc = g.add_node(format!("{tag}.head_concat"), OpKind::Concat, head_ctx, vec![ctx]);
        nodes.push(nc);
        ctx
    } else {
        let kt = g.add_tensor(
            vec![Dim::Static(cfg.heads), Dim::Static(dh), cfg.seq_dim()],
            DType::F32,
            &format!("{tag}.kT"),
        );
        let nkt = g.add_node(format!("{tag}.kT"), OpKind::Transpose, vec![heads_in[1]], vec![kt]);
        let scores = hs(g, &format!("{tag}.scores"));
        let nqk = g.add_node(format!("{tag}.qk"), OpKind::MatMul, vec![heads_in[0], kt], vec![scores]);
        let scale = g.tensor(&[1], &format!("{tag}.scale"));
        let scaled = hs(g, &format!("{tag}.scaled"));
        let nsc = g.add_node(format!("{tag}.scale"), OpKind::Mul, vec![scores, scale], vec![scaled]);
        let mask = g.add_tensor(
            vec![cfg.seq_dim(), cfg.seq_dim()],
            DType::F32,
            &format!("{tag}.mask"),
        );
        let masked = hs(g, &format!("{tag}.masked"));
        let nma = g.add_node(format!("{tag}.mask"), OpKind::Add, vec![scaled, mask], vec![masked]);
        let probs = hs(g, &format!("{tag}.probs"));
        let nsm = g.add_node(format!("{tag}.softmax"), OpKind::Softmax, vec![masked], vec![probs]);
        let ctx = g.add_tensor(
            vec![Dim::Static(cfg.heads), cfg.seq_dim(), Dim::Static(dh)],
            DType::F32,
            &format!("{tag}.ctx"),
        );
        let npv = g.add_node(format!("{tag}.pv"), OpKind::MatMul, vec![probs, heads_in[2]], vec![ctx]);
        nodes.extend([nkt, nqk, nsc, nma, nsm, npv]);
        ctx
    };

    let ctx_t = g.add_tensor(
        vec![cfg.seq_dim(), Dim::Static(cfg.heads), Dim::Static(d / cfg.heads)],
        DType::F32,
        &format!("{tag}.ctx_t"),
    );
    let nct = g.add_node(format!("{tag}.ctx_transpose"), OpKind::Transpose, vec![ctx], vec![ctx_t]);
    let mut mg_in = vec![ctx_t];
    if cfg.seq_dynamic {
        let (st, glue) = shape_glue(g, ctx_t, &format!("{tag}.merge"));
        nodes.extend(glue);
        mg_in.push(st);
    }
    let merged = cfg.td(g, &format!("{tag}.merge"));
    let nm = g.add_node(format!("{tag}.merge"), OpKind::Reshape, mg_in, vec![merged]);
    let wo = g.tensor(&[d, d], &format!("{tag}.o.w"));
    let proj = cfg.td(g, &format!("{tag}.o.mm"));
    let np = g.add_node(format!("{tag}.o.matmul"), OpKind::MatMul, vec![merged, wo], vec![proj]);
    let bo = g.tensor(&[d], &format!("{tag}.o.b"));
    let projb = cfg.td(g, &format!("{tag}.o.bias"));
    let nb = g.add_node(format!("{tag}.o.bias"), OpKind::Add, vec![proj, bo], vec![projb]);
    let out = cfg.td(g, &format!("{tag}.res"));
    let nr = g.add_node(format!("{tag}.residual"), OpKind::Add, vec![x, projb], vec![out]);
    nodes.extend([nct, nm, np, nb, nr]);

    tag_program(g, anchor, &nodes, program);
    out
}

/// Cross-attention block: queries from `x`, keys/values from `ctx`.
pub fn cross_attention_block(
    g: &mut Graph,
    x: TensorId,
    ctx: TensorId,
    cfg: TransformerCfg,
    ctx_t: usize,
    tag: &str,
) -> TensorId {
    let d = cfg.d;
    let ln_out = cfg.td(g, &format!("{tag}.ln"));
    let ln_g = g.tensor(&[d], &format!("{tag}.ln.g"));
    let ln_b = g.tensor(&[d], &format!("{tag}.ln.b"));
    g.add_node(format!("{tag}.ln"), OpKind::LayerNorm, vec![x, ln_g, ln_b], vec![ln_out]);

    // q from x; k, v from ctx — parallel chains with different sources
    let wq = g.tensor(&[d, d], &format!("{tag}.q.w"));
    let qm = cfg.td(g, &format!("{tag}.q.mm"));
    g.add_node(format!("{tag}.q.matmul"), OpKind::MatMul, vec![ln_out, wq], vec![qm]);
    let bq = g.tensor(&[d], &format!("{tag}.q.b"));
    let q = cfg.td(g, &format!("{tag}.q"));
    g.add_node(format!("{tag}.q.bias"), OpKind::Add, vec![qm, bq], vec![q]);

    let mut kv = Vec::new();
    for name in ["k", "v"] {
        let w = g.tensor(&[d, d], &format!("{tag}.{name}.w"));
        let mm = g.tensor(&[ctx_t, d], &format!("{tag}.{name}.mm"));
        g.add_node(format!("{tag}.{name}.matmul"), OpKind::MatMul, vec![ctx, w], vec![mm]);
        let b = g.tensor(&[d], &format!("{tag}.{name}.b"));
        let t = g.tensor(&[ctx_t, d], &format!("{tag}.{name}"));
        g.add_node(format!("{tag}.{name}.bias"), OpKind::Add, vec![mm, b], vec![t]);
        kv.push(t);
    }

    // expanded cross attention: q @ k^T * scale -> softmax -> @ v
    let kt = g.tensor(&[d, ctx_t], &format!("{tag}.kT"));
    g.add_node(format!("{tag}.kT"), OpKind::Transpose, vec![kv[0]], vec![kt]);
    let scores = {
        let dims = vec![cfg.seq_dim(), Dim::Static(ctx_t)];
        g.add_tensor(dims, DType::F32, &format!("{tag}.scores"))
    };
    g.add_node(format!("{tag}.qk"), OpKind::MatMul, vec![q, kt], vec![scores]);
    let scale = g.tensor(&[1], &format!("{tag}.scale"));
    let scaled = {
        let dims = vec![cfg.seq_dim(), Dim::Static(ctx_t)];
        g.add_tensor(dims, DType::F32, &format!("{tag}.scaled"))
    };
    g.add_node(format!("{tag}.scale"), OpKind::Mul, vec![scores, scale], vec![scaled]);
    let probs = {
        let dims = vec![cfg.seq_dim(), Dim::Static(ctx_t)];
        g.add_tensor(dims, DType::F32, &format!("{tag}.probs"))
    };
    g.add_node(format!("{tag}.softmax"), OpKind::Softmax, vec![scaled], vec![probs]);
    let attn_out = cfg.td(g, &format!("{tag}.attn"));
    g.add_node(format!("{tag}.pv"), OpKind::MatMul, vec![probs, kv[1]], vec![attn_out]);

    let wo = g.tensor(&[d, d], &format!("{tag}.o.w"));
    let proj = cfg.td(g, &format!("{tag}.o.mm"));
    g.add_node(format!("{tag}.o.matmul"), OpKind::MatMul, vec![attn_out, wo], vec![proj]);
    let bo = g.tensor(&[d], &format!("{tag}.o.b"));
    let projb = cfg.td(g, &format!("{tag}.o"));
    g.add_node(format!("{tag}.o.bias"), OpKind::Add, vec![proj, bo], vec![projb]);
    let out = cfg.td(g, &format!("{tag}.res"));
    g.add_node(format!("{tag}.residual"), OpKind::Add, vec![x, projb], vec![out]);
    out
}

/// FFN block (pre-LN, residual): LN → W1+gelu → W2 → add.
pub fn ffn_block(
    g: &mut Graph,
    x: TensorId,
    cfg: TransformerCfg,
    tag: &str,
    program: Option<&str>,
) -> TensorId {
    let d = cfg.d;
    let h = d * cfg.ffn_mult;
    let mut nodes = Vec::new();

    let ln_out = cfg.td(g, &format!("{tag}.ln2"));
    let ln_g = g.tensor(&[d], &format!("{tag}.ln2.g"));
    let ln_b = g.tensor(&[d], &format!("{tag}.ln2.b"));
    let anchor = g.add_node(
        format!("{tag}.ln2"),
        OpKind::LayerNorm,
        vec![x, ln_g, ln_b],
        vec![ln_out],
    );
    nodes.push(anchor);

    let w1 = g.tensor(&[d, h], &format!("{tag}.w1"));
    let h1 = {
        let dims = vec![cfg.seq_dim(), Dim::Static(h)];
        g.add_tensor(dims, DType::F32, &format!("{tag}.h1"))
    };
    let n1 = g.add_node(format!("{tag}.fc1"), OpKind::MatMul, vec![ln_out, w1], vec![h1]);
    let b1 = g.tensor(&[h], &format!("{tag}.b1"));
    let h1b = {
        let dims = vec![cfg.seq_dim(), Dim::Static(h)];
        g.add_tensor(dims, DType::F32, &format!("{tag}.h1b"))
    };
    let n2 = g.add_node(format!("{tag}.bias1"), OpKind::Add, vec![h1, b1], vec![h1b]);
    let act = {
        let dims = vec![cfg.seq_dim(), Dim::Static(h)];
        g.add_tensor(dims, DType::F32, &format!("{tag}.gelu"))
    };
    let n3 = g.add_node(format!("{tag}.gelu"), OpKind::Gelu, vec![h1b], vec![act]);
    let w2 = g.tensor(&[h, d], &format!("{tag}.w2"));
    let h2 = cfg.td(g, &format!("{tag}.h2"));
    let n4 = g.add_node(format!("{tag}.fc2"), OpKind::MatMul, vec![act, w2], vec![h2]);
    let b2 = g.tensor(&[d], &format!("{tag}.b2"));
    let h2b = cfg.td(g, &format!("{tag}.h2b"));
    let n5 = g.add_node(format!("{tag}.bias2"), OpKind::Add, vec![h2, b2], vec![h2b]);
    let out = cfg.td(g, &format!("{tag}.res2"));
    let n6 = g.add_node(format!("{tag}.residual2"), OpKind::Add, vec![x, h2b], vec![out]);
    nodes.extend([n1, n2, n3, n4, n5, n6]);

    tag_program(g, anchor, &nodes, program);
    out
}

/// Conv + SiLU unit (BN folded, activation fused per the runtime's
/// effective view), NHWC; stride-2 convs carry an explicit Pad.
pub fn conv_silu(
    g: &mut Graph,
    x: TensorId,
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    stride: usize,
    tag: &str,
    program: Option<&str>,
) -> TensorId {
    let ho = h.div_ceil(stride);
    let wo = w.div_ceil(stride);
    let mut nodes = Vec::new();
    let conv_in = if stride > 1 {
        let padded = g.tensor(&[1, h + 1, w + 1, cin], &format!("{tag}.pad"));
        nodes.push(g.add_node(format!("{tag}.pad"), OpKind::Pad, vec![x], vec![padded]));
        padded
    } else {
        x
    };
    let wt = g.tensor(&[3, 3, cin, cout], &format!("{tag}.w"));
    let conv_out = g.tensor(&[1, ho, wo, cout], &format!("{tag}.conv"));
    let anchor = g.add_node(
        format!("{tag}.conv"),
        OpKind::Conv2D { kh: 3, kw: 3, stride },
        vec![conv_in, wt],
        vec![conv_out],
    );
    nodes.push(anchor);
    let act = g.tensor(&[1, ho, wo, cout], &format!("{tag}.silu"));
    nodes.push(g.add_node(format!("{tag}.silu"), OpKind::Silu, vec![conv_out], vec![act]));
    tag_program(g, anchor, &nodes, program);
    act
}

/// 1x1 conv (pointwise) + optional activation.
pub fn conv1x1(
    g: &mut Graph,
    x: TensorId,
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    act: bool,
    tag: &str,
) -> TensorId {
    let wt = g.tensor(&[1, 1, cin, cout], &format!("{tag}.w"));
    let conv_out = g.tensor(&[1, h, w, cout], &format!("{tag}.conv1x1"));
    g.add_node(
        format!("{tag}.conv1x1"),
        OpKind::Conv2D { kh: 1, kw: 1, stride: 1 },
        vec![x, wt],
        vec![conv_out],
    );
    if !act {
        return conv_out;
    }
    let a = g.tensor(&[1, h, w, cout], &format!("{tag}.silu"));
    g.add_node(format!("{tag}.silu"), OpKind::Silu, vec![conv_out], vec![a]);
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TransformerCfg {
        TransformerCfg { t: 16, d: 32, heads: 4, ffn_mult: 4, seq_dynamic: false, per_head: false }
    }

    #[test]
    fn attention_block_structure() {
        let mut g = Graph::new("t");
        let x = g.tensor(&[16, 32], "x");
        let out = attention_block(&mut g, x, cfg(), "b0", None);
        assert!(g.validate().is_empty(), "{:?}", g.validate());
        // static cfg: ln + 3*(mm,bias,reshape,transpose)
        //           + 6 attn ops + ctx_t, merge, proj, bias, res
        assert_eq!(g.num_nodes(), 1 + 12 + 6 + 5);
        assert_eq!(g.tensor_info(out).numel_max(), 16 * 32);
    }

    #[test]
    fn attention_block_dynamic_has_glue() {
        let mut g = Graph::new("t");
        let c = TransformerCfg { seq_dynamic: true, ..cfg() };
        let x = g.add_tensor(
            vec![Dim::Dynamic { max: 16 }, Dim::Static(32)],
            DType::F32,
            "x",
        );
        attention_block(&mut g, x, c, "b0", None);
        // 4 glue sites x 3 nodes on top of the static count
        assert_eq!(g.num_nodes(), 24 + 12);
    }

    #[test]
    fn ffn_block_structure() {
        let mut g = Graph::new("t");
        let x = g.tensor(&[16, 32], "x");
        let out = ffn_block(&mut g, x, cfg(), "b0", None);
        assert!(g.validate().is_empty(), "{:?}", g.validate());
        assert_eq!(g.num_nodes(), 7);
        assert_eq!(g.tensor_info(out).numel_max(), 16 * 32);
    }

    #[test]
    fn program_tagging() {
        let mut g = Graph::new("t");
        let x = g.tensor(&[16, 32], "x");
        attention_block(&mut g, x, cfg(), "b0", Some("attn_test"));
        let with_program: Vec<_> =
            g.nodes().iter().filter(|n| n.program.is_some()).collect();
        assert_eq!(with_program.len(), 1);
        let fused = g.nodes().iter().filter(|n| n.fused_into.is_some()).count();
        assert_eq!(fused, g.num_nodes() - 1);
    }

    #[test]
    fn conv_silu_shapes() {
        let mut g = Graph::new("t");
        let x = g.tensor(&[1, 8, 8, 3], "x");
        let out = conv_silu(&mut g, x, 8, 8, 3, 16, 2, "c0", None);
        assert_eq!(g.tensor_info(out).numel_max(), 4 * 4 * 16);
        assert!(g.validate().is_empty());
    }

    #[test]
    fn dynamic_seq_propagates() {
        let mut g = Graph::new("t");
        let c = TransformerCfg { seq_dynamic: true, ..cfg() };
        let x = g.add_tensor(
            vec![Dim::Dynamic { max: 16 }, Dim::Static(32)],
            DType::F32,
            "x",
        );
        let out = attention_block(&mut g, x, c, "b0", None);
        assert!(g.tensor_info(out).has_dynamic_dim());
    }
}
