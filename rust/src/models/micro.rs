//! Micro graphs for unit tests, property tests and documentation.

use crate::graph::{DType, Dim, Graph, OpKind, TensorId};
use crate::util::rng::Rng;

/// Linear chain of `n` relu nodes.
pub fn chain(n: usize) -> Graph {
    let mut g = Graph::new("chain");
    let mut t = g.tensor(&[64], "in");
    for i in 0..n {
        let o = g.tensor(&[64], &format!("t{i}"));
        g.add_node(format!("relu{i}"), OpKind::Relu, vec![t], vec![o]);
        t = o;
    }
    g
}

/// `k` parallel chains of length `len` between a splitter and a merger.
pub fn parallel_chains(k: usize, len: usize) -> Graph {
    let mut g = Graph::new("parallel");
    let input = g.tensor(&[64 * k], "in");
    let outs: Vec<TensorId> = (0..k).map(|i| g.tensor(&[64], &format!("s{i}"))).collect();
    g.add_node("split", OpKind::Split { ways: k }, vec![input], outs.clone());
    let mut tails = Vec::new();
    for (i, &s) in outs.iter().enumerate() {
        let mut t = s;
        for j in 0..len {
            let o = g.tensor(&[64], &format!("c{i}_{j}"));
            g.add_node(format!("work{i}_{j}"), OpKind::Silu, vec![t], vec![o]);
            t = o;
        }
        tails.push(t);
    }
    let merged = g.tensor(&[64 * k], "merged");
    g.add_node("merge", OpKind::Concat, tails, vec![merged]);
    g
}

/// Diamond: one splitter, two unequal-length branches, one merger.
pub fn diamond(short: usize, long: usize) -> Graph {
    let mut g = Graph::new("diamond");
    let input = g.tensor(&[128], "in");
    let a = g.tensor(&[64], "a");
    let b = g.tensor(&[64], "b");
    g.add_node("split", OpKind::Split { ways: 2 }, vec![input], vec![a, b]);
    let mut ta = a;
    for j in 0..short {
        let o = g.tensor(&[64], &format!("s{j}"));
        g.add_node(format!("short{j}"), OpKind::Relu, vec![ta], vec![o]);
        ta = o;
    }
    let mut tb = b;
    for j in 0..long {
        let o = g.tensor(&[64], &format!("l{j}"));
        g.add_node(format!("long{j}"), OpKind::Relu, vec![tb], vec![o]);
        tb = o;
    }
    let m = g.tensor(&[128], "out");
    g.add_node("merge", OpKind::Concat, vec![ta, tb], vec![m]);
    g
}

/// Mixed graph with a delegate-worthy conv trunk, a dynamic NMS tail
/// and two parallel FC branches — exercises every partitioning rule.
pub fn mixed() -> Graph {
    let mut g = Graph::new("mixed");
    let raw = g.tensor(&[1, 64, 64, 3], "in");
    let img = g.tensor(&[1, 64, 64, 3], "img");
    g.add_node("input", OpKind::Input, vec![raw], vec![img]);
    // conv trunk (static, heavy)
    let mut t = img;
    let mut c = 3;
    for i in 0..4 {
        let co = 64 << (i / 2);
        let w = g.tensor(&[3, 3, c, co], &format!("w{i}"));
        let o = g.tensor(&[1, 64, 64, co], &format!("conv{i}"));
        g.add_node(
            format!("conv{i}"),
            OpKind::Conv2D { kh: 3, kw: 3, stride: 1 },
            vec![t, w],
            vec![o],
        );
        t = o;
        c = co;
    }
    // two parallel FC branches
    let flat = g.tensor(&[4096, c], "flat");
    g.add_node("flatten", OpKind::Reshape, vec![t], vec![flat]);
    let w_box = g.tensor(&[c, 4], "w_box");
    let boxes = g.tensor(&[4096, 4], "boxes");
    g.add_node("fc_box", OpKind::FullyConnected, vec![flat, w_box], vec![boxes]);
    let w_cls = g.tensor(&[c, 10], "w_cls");
    let cls = g.tensor(&[4096, 10], "cls");
    g.add_node("fc_cls", OpKind::FullyConnected, vec![flat, w_cls], vec![cls]);
    // dynamic tail
    let dets = g.add_tensor(
        vec![Dim::Dynamic { max: 100 }, Dim::Static(6)],
        DType::F32,
        "dets",
    );
    g.add_node("nms", OpKind::NonMaxSuppression, vec![boxes, cls], vec![dets]);
    let out = g.add_tensor(
        vec![Dim::Dynamic { max: 100 }, Dim::Static(6)],
        DType::F32,
        "out",
    );
    g.add_node("output", OpKind::Output, vec![dets], vec![out]);
    g
}

/// Fallback-heavy co-execution profile (the paper's §3.1 story in one
/// graph): a static delegate-eligible matmul trunk of `trunk_len`
/// `[dim×dim]` matmuls runs in parallel with `chains` GELU fallback
/// chains of `chain_len` ops each (GELU is NNAPI-unsupported, so the
/// chains can never delegate), merged by a final concat.  The trunk
/// and the chains start from independent source tensors, so they land
/// in one Branch-Layer with no mutual dependencies — exactly the shape
/// where accelerator/CPU co-execution pays: the delegate lane hides
/// the trunk behind the CPU fallback waves.
pub fn fallback_heavy(chains: usize, chain_len: usize, dim: usize, trunk_len: usize) -> Graph {
    let mut g = Graph::new("fallback_heavy");
    // heavy static trunk: a matmul chain (delegate-eligible region)
    let mut t = g.tensor(&[dim, dim], "trunk_in");
    for i in 0..trunk_len {
        let w = g.tensor(&[dim, dim], &format!("trunk_w{i}"));
        let o = g.tensor(&[dim, dim], &format!("trunk_t{i}"));
        g.add_node(format!("trunk_mm{i}"), OpKind::MatMul, vec![t, w], vec![o]);
        t = o;
    }
    let mut tails = vec![t];
    // CPU fallback chains: GELU is outside the NNAPI-style support set
    for c in 0..chains {
        let mut x = g.tensor(&[dim * dim], &format!("chain{c}_in"));
        for j in 0..chain_len {
            let o = g.tensor(&[dim * dim], &format!("chain{c}_t{j}"));
            g.add_node(format!("fallback{c}_{j}"), OpKind::Gelu, vec![x], vec![o]);
            x = o;
        }
        tails.push(x);
    }
    let merged = g.tensor(&[dim * dim * (chains + 1)], "merged");
    g.add_node("merge", OpKind::Concat, tails, vec![merged]);
    g
}

/// [`fallback_heavy`] with several independent trunks — the multi-lane
/// co-execution profile: `trunks` delegate-eligible matmul chains (each
/// its own region, so each becomes its own delegated branch) run in one
/// Branch-Layer next to the GELU fallback chains.  On a multi-lane
/// `SocProfile` the placement spreads the trunks across accelerator
/// queues, so a 2-lane device really does run two trunks concurrently
/// while the CPU chains execute in waves.
pub fn fallback_heavy_lanes(
    trunks: usize,
    chains: usize,
    chain_len: usize,
    dim: usize,
    trunk_len: usize,
) -> Graph {
    let mut g = Graph::new("fallback_heavy_lanes");
    let mut tails = Vec::new();
    for k in 0..trunks {
        let mut t = g.tensor(&[dim, dim], &format!("trunk{k}_in"));
        for i in 0..trunk_len {
            let w = g.tensor(&[dim, dim], &format!("trunk{k}_w{i}"));
            let o = g.tensor(&[dim, dim], &format!("trunk{k}_t{i}"));
            g.add_node(format!("trunk{k}_mm{i}"), OpKind::MatMul, vec![t, w], vec![o]);
            t = o;
        }
        tails.push(t);
    }
    for c in 0..chains {
        let mut x = g.tensor(&[dim * dim], &format!("chain{c}_in"));
        for j in 0..chain_len {
            let o = g.tensor(&[dim * dim], &format!("chain{c}_t{j}"));
            g.add_node(format!("fallback{c}_{j}"), OpKind::Gelu, vec![x], vec![o]);
            x = o;
        }
        tails.push(x);
    }
    let merged = g.tensor(&[dim * dim * (chains + trunks)], "merged");
    g.add_node("merge", OpKind::Concat, tails, vec![merged]);
    g
}

/// Staged co-execution pipeline — the cross-layer overlap profile.
/// `stages` stages each hold a delegate-eligible matmul trunk plus
/// `chains` GELU fallback chains; the chains feed the next stage
/// through a concat→split mixer (kept on the CPU), while every trunk's
/// output is consumed only by the *final* merge.  So a trunk dispatched
/// in stage `s` has its first consumer many layers later: a barrier-
/// join executor idles the accelerator at every stage boundary, while
/// cross-layer overlap keeps the lane busy straight through the next
/// stages' CPU waves — exactly the gap `benches/heterogeneous.rs`'s
/// overlap ablation measures.
pub fn fallback_pipeline(
    stages: usize,
    chains: usize,
    chain_len: usize,
    dim: usize,
    trunk_len: usize,
) -> Graph {
    let mut g = Graph::new("fallback_pipeline");
    let mut trunk_tails: Vec<TensorId> = Vec::new();
    let mut chain_heads: Vec<TensorId> =
        (0..chains).map(|c| g.tensor(&[dim * dim], &format!("s0_chain{c}_in"))).collect();
    // stage-0 trunk feeds from its own source; later trunks feed from
    // the previous stage's mixer through a CPU Gelu gate, so their
    // dispatch depends on CPU work, never on an in-flight lane job
    let mut trunk_feed: Option<TensorId> = None;
    for s in 0..stages {
        let mut t = match trunk_feed {
            None => g.tensor(&[dim, dim], "trunk0_in"),
            Some(feed) => {
                let gated = g.tensor(&[dim * dim], &format!("s{s}_trunk_gate"));
                g.add_node(format!("s{s}_gate"), OpKind::Gelu, vec![feed], vec![gated]);
                let shaped = g.tensor(&[dim, dim], &format!("s{s}_trunk_in"));
                g.add_node(format!("s{s}_reshape"), OpKind::Reshape, vec![gated], vec![shaped]);
                shaped
            }
        };
        for i in 0..trunk_len {
            let w = g.tensor(&[dim, dim], &format!("s{s}_trunk_w{i}"));
            let o = g.tensor(&[dim, dim], &format!("s{s}_trunk_t{i}"));
            g.add_node(format!("s{s}_trunk_mm{i}"), OpKind::MatMul, vec![t, w], vec![o]);
            t = o;
        }
        trunk_tails.push(t);
        let mut chain_tails = Vec::new();
        for (c, &head) in chain_heads.iter().enumerate() {
            let mut x = head;
            for j in 0..chain_len {
                let o = g.tensor(&[dim * dim], &format!("s{s}_chain{c}_t{j}"));
                g.add_node(format!("s{s}_fallback{c}_{j}"), OpKind::Gelu, vec![x], vec![o]);
                x = o;
            }
            chain_tails.push(x);
        }
        if s + 1 < stages {
            // mixer: concat the chain tails, split into the next
            // stage's chain heads plus the next trunk's feed
            let mixed = g.tensor(&[dim * dim * chains], &format!("s{s}_mixed"));
            g.add_node(format!("s{s}_mix"), OpKind::Concat, chain_tails, vec![mixed]);
            let outs: Vec<TensorId> = (0..=chains)
                .map(|c| g.tensor(&[dim * dim], &format!("s{s}_split{c}")))
                .collect();
            g.add_node(
                format!("s{s}_split"),
                OpKind::Split { ways: chains + 1 },
                vec![mixed],
                outs.clone(),
            );
            trunk_feed = Some(outs[chains]);
            chain_heads = outs[..chains].to_vec();
        } else {
            // last stage: chains merge straight into the final concat
            chain_heads = chain_tails;
        }
    }
    let mut final_in = trunk_tails;
    final_in.extend(chain_heads);
    let n_in = final_in.len();
    let merged = g.tensor(&[dim * dim * n_in], "merged");
    g.add_node("merge", OpKind::Concat, final_in, vec![merged]);
    g
}

/// If-gated arms: a predicate-driven `If` barrier emits two arm tokens,
/// each feeding a chain of `arm_len` ops, merged by a `Maximum` select.
/// At runtime only one arm is live — the §3.4 subgraph-control path
/// resolves the predicate, prunes the untaken arm's branches and never
/// leases their arena reservations.  The untaken arm's input to the
/// select is don't-care (`If` semantics): a pruned run reads the
/// engine's deterministic stand-in there, so results are reproducible
/// but not equal to a run that executes both arms.
pub fn gated(arm_len: usize) -> Graph {
    let mut g = Graph::new("gated");
    let pred = g.tensor(&[4], "pred");
    let a0 = g.tensor(&[64], "arm_a.t0");
    let b0 = g.tensor(&[64], "arm_b.t0");
    g.add_node("gate", OpKind::If, vec![pred], vec![a0, b0]);
    let mut ta = a0;
    for j in 0..arm_len {
        let o = g.tensor(&[64], &format!("arm_a.t{}", j + 1));
        g.add_node(format!("arm_a.{j}"), OpKind::Relu, vec![ta], vec![o]);
        ta = o;
    }
    let mut tb = b0;
    for j in 0..arm_len {
        let o = g.tensor(&[64], &format!("arm_b.t{}", j + 1));
        g.add_node(format!("arm_b.{j}"), OpKind::Silu, vec![tb], vec![o]);
        tb = o;
    }
    let m = g.tensor(&[64], "selected");
    g.add_node("select", OpKind::Maximum, vec![ta, tb], vec![m]);
    let out = g.tensor(&[64], "out");
    g.add_node("output", OpKind::Output, vec![m], vec![out]);
    g
}

/// Random layered DAG for property tests: `layers` layers of up to
/// `width` elementwise nodes, each consuming 1-2 tensors from earlier
/// layers.  Always acyclic by construction.
pub fn random_dag(rng: &mut Rng, layers: usize, width: usize) -> Graph {
    let mut g = Graph::new("random");
    let mut frontier: Vec<TensorId> = vec![g.tensor(&[64], "in")];
    let mut idx = 0;
    for _ in 0..layers {
        let k = rng.range(1, width + 1);
        let mut next = Vec::new();
        for _ in 0..k {
            let n_in = if frontier.len() > 1 && rng.chance(0.3) { 2 } else { 1 };
            let mut ins = Vec::new();
            for _ in 0..n_in {
                ins.push(*rng.pick(&frontier));
            }
            ins.dedup();
            let o = g.tensor(&[64], &format!("t{idx}"));
            let kind = match rng.range(0, 4) {
                0 => OpKind::Relu,
                1 => OpKind::Silu,
                2 if ins.len() == 2 => OpKind::Add,
                _ => OpKind::Gelu,
            };
            let kind = if ins.len() == 1 && matches!(kind, OpKind::Add) {
                OpKind::Relu
            } else {
                kind
            };
            g.add_node(format!("n{idx}"), kind, ins, vec![o]);
            next.push(o);
            idx += 1;
        }
        // keep some old frontier alive so the DAG has skip connections
        if rng.chance(0.5) && !frontier.is_empty() {
            next.push(*rng.pick(&frontier));
        }
        frontier = next;
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_is_sequential() {
        let g = chain(10);
        assert_eq!(g.num_nodes(), 10);
        assert!(g.validate().is_empty());
    }

    #[test]
    fn parallel_has_k_branches() {
        let g = parallel_chains(4, 3);
        assert_eq!(g.num_nodes(), 1 + 4 * 3 + 1);
        assert!(g.validate().is_empty());
    }

    #[test]
    fn random_dag_always_valid() {
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let g = random_dag(&mut rng, 8, 5);
            assert!(g.validate().is_empty(), "seed {seed}: {:?}", g.validate());
            assert!(g.topo_order().is_some(), "seed {seed}");
        }
    }

    #[test]
    fn fallback_heavy_shape() {
        let g = fallback_heavy(4, 3, 32, 3);
        assert!(g.validate().is_empty(), "{:?}", g.validate());
        assert_eq!(g.num_nodes(), 3 + 4 * 3 + 1);
        // trunk is delegate-eligible, chains are not
        let p = crate::partition::partition(
            &g,
            &crate::partition::CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX },
        );
        assert!(!p.regions.is_empty(), "trunk must form a region");
        for n in g.nodes() {
            if n.name.starts_with("fallback") {
                assert!(p.is_cpu(n.id), "{} must fall back", n.name);
            }
        }
    }

    #[test]
    fn fallback_heavy_lanes_has_one_region_per_trunk() {
        let g = fallback_heavy_lanes(3, 2, 4, 32, 3);
        assert!(g.validate().is_empty(), "{:?}", g.validate());
        assert_eq!(g.num_nodes(), 3 * 3 + 2 * 4 + 1);
        let p = crate::partition::partition(
            &g,
            &crate::partition::CostModel { min_ops: 3, min_flops: 0, max_bytes_per_flop: f64::MAX },
        );
        assert_eq!(p.regions.len(), 3, "each trunk is its own delegate region");
    }

    #[test]
    fn fallback_pipeline_trunks_merge_only_at_the_end() {
        let stages = 3;
        let g = fallback_pipeline(stages, 2, 3, 32, 3);
        assert!(g.validate().is_empty(), "{:?}", g.validate());
        let p = crate::partition::partition(
            &g,
            &crate::partition::CostModel { min_ops: 3, min_flops: 0, max_bytes_per_flop: f64::MAX },
        );
        assert_eq!(p.regions.len(), stages, "one trunk region per stage");
        // every trunk tail is consumed by the final merge only
        let merge = g.nodes().iter().find(|n| n.name == "merge").unwrap();
        for s in 0..stages {
            let tail = g
                .tensors()
                .iter()
                .find(|t| t.label == format!("s{s}_trunk_t2"))
                .map(|t| t.id)
                .unwrap();
            assert_eq!(g.consumers(tail), vec![merge.id], "stage {s} trunk tail");
        }
    }

    #[test]
    fn gated_validates_with_control_flow() {
        let g = gated(3);
        assert!(g.validate().is_empty(), "{:?}", g.validate());
        assert_eq!(g.num_nodes(), 1 + 3 + 3 + 2);
        assert!(g.nodes().iter().any(|n| n.kind.is_control_flow()));
    }

    #[test]
    fn mixed_has_dynamic_and_static() {
        let g = mixed();
        assert!(g.validate().is_empty());
        let dynamic = g.nodes().iter().filter(|n| g.node_has_dynamic_shape(n.id)).count();
        assert!(dynamic >= 1);
    }
}
