//! Whisper-Tiny ASR (Table 2: [1, 3000] audio, INT8/FP32, 46.51M).
//!
//! Encoder: mel-spectrogram front-end + 2 conv stems + 4 transformer
//! blocks at T=192 (pooled frame slice; the full 1500-frame encoder is
//! downscaled so the zoo's shape universe matches the AOT artifact set —
//! see ARCHITECTURE.md §Substitutions).  Decoder: 4 blocks of self-attention
//! (dynamic length, KV-cached) + cross-attention + FFN, driven by a
//! beam-search While loop — the paper's canonical dynamic-control-flow
//! fallback.

use super::blocks::{attention_block, cross_attention_block, ffn_block, TransformerCfg};
use crate::graph::{DType, Dim, Graph, OpKind, TensorId};

pub const ENC_T: usize = 192;
pub const D: usize = 384;
pub const HEADS: usize = 6;
pub const ENC_BLOCKS: usize = 4;
pub const DEC_BLOCKS: usize = 4;
pub const MAX_DEC_T: usize = 64;

/// Mel front-end: pad → conv1d(as 2D) ×2 with GELU → log-scale.
fn mel_frontend(g: &mut Graph) -> TensorId {
    let raw = g.tensor(&[1, 3000], "audio_in");
    let audio = g.tensor(&[1, 3000], "audio");
    g.add_node("input", OpKind::Input, vec![raw], vec![audio]);
    // mel projection: frame, window-mul, matmul against mel filters, log
    let frames = g.tensor(&[1, ENC_T * 2, 400], "frames");
    g.add_node("mel.frame", OpKind::Reshape, vec![audio], vec![frames]);
    let window = g.tensor(&[400], "mel.window");
    let windowed = g.tensor(&[1, ENC_T * 2, 400], "mel.windowed");
    g.add_node("mel.window_mul", OpKind::Mul, vec![frames, window], vec![windowed]);
    let filt = g.tensor(&[400, 80], "mel.filters");
    let mel = g.tensor(&[1, ENC_T * 2, 80], "mel.spec");
    g.add_node("mel.project", OpKind::MatMul, vec![windowed, filt], vec![mel]);
    let logmel = g.tensor(&[1, ENC_T * 2, 80], "mel.log");
    g.add_node("mel.log", OpKind::Tanh, vec![mel], vec![logmel]); // log≈tanh-class cost

    // conv stem 1 (stride 1) + gelu
    let w1 = g.tensor(&[3, 1, 80, D], "stem1.w");
    let c1 = g.tensor(&[1, ENC_T * 2, 1, D], "stem1.conv");
    let r1 = g.tensor(&[1, ENC_T * 2, 1, 80], "stem1.r");
    g.add_node("stem1.reshape", OpKind::Reshape, vec![logmel], vec![r1]);
    g.add_node("stem1.conv", OpKind::Conv2D { kh: 3, kw: 1, stride: 1 }, vec![r1, w1], vec![c1]);
    let g1 = g.tensor(&[1, ENC_T * 2, 1, D], "stem1.gelu");
    g.add_node("stem1.gelu", OpKind::Gelu, vec![c1], vec![g1]);

    // conv stem 2 (stride 2: halves T) + gelu
    let w2 = g.tensor(&[3, 1, D, D], "stem2.w");
    let c2 = g.tensor(&[1, ENC_T, 1, D], "stem2.conv");
    g.add_node("stem2.conv", OpKind::Conv2D { kh: 3, kw: 1, stride: 2 }, vec![g1, w2], vec![c2]);
    let g2 = g.tensor(&[1, ENC_T, 1, D], "stem2.gelu");
    g.add_node("stem2.gelu", OpKind::Gelu, vec![c2], vec![g2]);
    let flat = g.tensor(&[ENC_T, D], "enc_in");
    g.add_node("stem2.squeeze", OpKind::Reshape, vec![g2], vec![flat]);
    let pos = g.tensor(&[ENC_T, D], "enc.pos");
    let enc0 = g.tensor(&[ENC_T, D], "enc.h0");
    g.add_node("enc.pos_add", OpKind::Add, vec![flat, pos], vec![enc0]);
    enc0
}

/// Decoder self-attention with KV cache plumbing: separate past-K and
/// past-V concat + slice chains — the converter-level ops a cached
/// decode step carries.
fn kv_cache_glue(g: &mut Graph, x: TensorId, t_dim: Dim, tag: &str) -> TensorId {
    let mut cur = x;
    for name in ["k", "v"] {
        let past = g.add_tensor(
            vec![t_dim, Dim::Static(D)],
            DType::F32,
            &format!("{tag}.past_{name}"),
        );
        let cat = g.add_tensor(
            vec![t_dim, Dim::Static(D)],
            DType::F32,
            &format!("{tag}.{name}_cat"),
        );
        g.add_node(format!("{tag}.{name}_concat"), OpKind::Concat, vec![past, cur], vec![cat]);
        let sliced = g.add_tensor(
            vec![t_dim, Dim::Static(D)],
            DType::F32,
            &format!("{tag}.{name}_cur"),
        );
        g.add_node(format!("{tag}.{name}_slice"), OpKind::Slice, vec![cat], vec![sliced]);
        cur = sliced;
    }
    cur
}

pub fn build() -> Graph {
    let mut g = Graph::new("whisper_tiny");

    // ---- encoder ----
    let enc_cfg = TransformerCfg {
        t: ENC_T,
        d: D,
        heads: HEADS,
        ffn_mult: 4,
        seq_dynamic: false,
        per_head: true,
    };
    let mut x = mel_frontend(&mut g);
    for i in 0..ENC_BLOCKS {
        x = attention_block(&mut g, x, enc_cfg, &format!("enc{i}"), Some("attn_192x384_h6"));
        x = ffn_block(&mut g, x, enc_cfg, &format!("enc{i}"), Some("ffn_192x384x1536"));
    }
    let lng = g.tensor(&[D], "enc.ln.g");
    let lnb = g.tensor(&[D], "enc.ln.b");
    let enc_out = g.tensor(&[ENC_T, D], "enc_out");
    let enc_ln = g.add_node("enc.ln", OpKind::LayerNorm, vec![x, lng, lnb], vec![enc_out]);
    g.set_program(enc_ln, "layernorm_192x384");

    // ---- decoder (one unrolled step inside the beam-search loop) ----
    let dec_cfg = TransformerCfg {
        t: MAX_DEC_T,
        d: D,
        heads: HEADS,
        ffn_mult: 4,
        seq_dynamic: true,
        per_head: false,
    };
    let t_dyn = Dim::Dynamic { max: MAX_DEC_T };

    // beam-search control: While barrier feeding token ids
    let state = g.add_tensor(vec![t_dyn], DType::I32, "beam.state");
    let tokens = g.add_tensor(vec![t_dyn], DType::I32, "dec.tokens");
    g.add_node("beam.while", OpKind::While, vec![state], vec![tokens]);
    let emb_table = g.tensor(&[51865, D], "dec.tok_embedding");
    let emb = g.add_tensor(vec![t_dyn, Dim::Static(D)], DType::F32, "dec.embedded");
    g.add_node("dec.embed", OpKind::EmbeddingLookup, vec![tokens, emb_table], vec![emb]);
    let pos_table = g.tensor(&[MAX_DEC_T, D], "dec.pos_embedding");
    let pos = g.add_tensor(vec![t_dyn, Dim::Static(D)], DType::F32, "dec.pos");
    g.add_node("dec.pos_slice", OpKind::Slice, vec![pos_table], vec![pos]);
    let mut d = g.add_tensor(vec![t_dyn, Dim::Static(D)], DType::F32, "dec.h0");
    g.add_node("dec.pos_add", OpKind::Add, vec![emb, pos], vec![d]);

    for i in 0..DEC_BLOCKS {
        let cached = kv_cache_glue(&mut g, d, t_dyn, &format!("dec{i}"));
        d = attention_block(&mut g, cached, dec_cfg, &format!("dec{i}.self"), None);
        d = cross_attention_block(&mut g, d, enc_out, dec_cfg, ENC_T, &format!("dec{i}.cross"));
        d = ffn_block(&mut g, d, dec_cfg, &format!("dec{i}"), None);
    }

    // logits + beam step (dynamic)
    let lng2 = g.tensor(&[D], "dec.ln.g");
    let lnb2 = g.tensor(&[D], "dec.ln.b");
    let dln = g.add_tensor(vec![t_dyn, Dim::Static(D)], DType::F32, "dec.ln");
    g.add_node("dec.ln", OpKind::LayerNorm, vec![d, lng2, lnb2], vec![dln]);
    // only the last position feeds the next-token logits (the export
    // slices before the unembedding matmul)
    let last = g.tensor(&[1, D], "dec.last");
    g.add_node("dec.last_slice", OpKind::Slice, vec![dln], vec![last]);
    let unemb = g.tensor(&[D, 51865], "dec.unembed.w");
    let logits = g.tensor(&[1, 51865], "dec.logits");
    g.add_node("dec.unembed", OpKind::MatMul, vec![last, unemb], vec![logits]);
    let beam_out = g.add_tensor(vec![Dim::Dynamic { max: 5 }, t_dyn], DType::I32, "beam.hyps");
    g.add_node("beam.step", OpKind::BeamSearchStep, vec![logits], vec![beam_out]);
    let out = g.add_tensor(vec![Dim::Dynamic { max: 5 }, t_dyn], DType::I32, "out");
    g.add_node("output", OpKind::Output, vec![beam_out], vec![out]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_count_near_table7() {
        // Table 7 "Pre": 627 nodes (we model the pooled-T encoder).
        let g = build();
        let n = g.num_nodes();
        assert!(
            (430..=760).contains(&n),
            "Whisper node count {n} too far from Table 7's 627"
        );
    }

    #[test]
    fn validates() {
        let g = build();
        assert!(g.validate().is_empty(), "{:?}", g.validate());
    }

    #[test]
    fn has_control_flow_and_dynamic() {
        let g = build();
        assert!(g.nodes().iter().any(|n| n.kind.is_control_flow()));
        assert!(g
            .nodes()
            .iter()
            .any(|n| matches!(n.kind, OpKind::BeamSearchStep)));
    }

    #[test]
    fn encoder_blocks_have_programs() {
        let g = build();
        let hints: std::collections::HashSet<_> =
            g.nodes().iter().filter_map(|n| n.program.as_deref()).collect();
        assert!(hints.contains("attn_192x384_h6"));
        assert!(hints.contains("ffn_192x384x1536"));
    }
}
