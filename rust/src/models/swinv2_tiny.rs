//! SwinV2-Tiny image classifier (Table 2: [1,3,224,224], FP16, 28.6M).
//!
//! 4 stages of window-attention blocks ([2,2,6,2]); each block
//! partitions the feature map into window *groups* whose attentions are
//! independent — the paper's prime source of CPU-fallback parallelism
//! (Table 6 shows SwinV2 layers with up to 6 concurrent branches, and
//! Table 7 max-branches = 8).  Shifted blocks carry the roll/unroll
//! slice-concat plumbing; stages end with patch-merging.

use crate::graph::{Graph, OpKind, TensorId};

pub const STAGES: [usize; 4] = [2, 2, 6, 2];
pub const DIMS: [usize; 4] = [96, 192, 384, 768];
pub const HEADS: [usize; 4] = [3, 6, 12, 24];
/// Window groups exposed as parallel branches per stage (structure knob:
/// how many independent window-attention chains the converter leaves
/// un-batched).
pub const GROUPS: [usize; 4] = [8, 8, 4, 2];

/// Per-window-group attention chain: qkv matmul + bias + attn(+cpb bias)
/// + proj — converter-grained but without per-head splits.
fn window_attention(
    g: &mut Graph,
    x: TensorId,
    tokens: usize,
    d: usize,
    heads: usize,
    tag: &str,
    program: Option<&str>,
) -> TensorId {
    let mut nodes = Vec::new();
    let wqkv = g.tensor(&[d, 3 * d], &format!("{tag}.qkv.w"));
    let qkv = g.tensor(&[tokens, 3 * d], &format!("{tag}.qkv"));
    let anchor = g.add_node(format!("{tag}.qkv"), OpKind::MatMul, vec![x, wqkv], vec![qkv]);
    nodes.push(anchor);
    let bqkv = g.tensor(&[3 * d], &format!("{tag}.qkv.b"));
    let qkv_b = g.tensor(&[tokens, 3 * d], &format!("{tag}.qkv_b"));
    nodes.push(g.add_node(format!("{tag}.qkv.bias"), OpKind::Add, vec![qkv, bqkv], vec![qkv_b]));
    let q = g.tensor(&[tokens, d], &format!("{tag}.q"));
    let k = g.tensor(&[tokens, d], &format!("{tag}.k"));
    let v = g.tensor(&[tokens, d], &format!("{tag}.v"));
    nodes.push(g.add_node(
        format!("{tag}.qkv.split"),
        OpKind::Split { ways: 3 },
        vec![qkv_b],
        vec![q, k, v],
    ));
    // cosine attention (SwinV2): L2-normalise q and k, scaled by a
    // learned (clamped) logit scale, plus the log-CPB position bias.
    let qn = g.tensor(&[tokens, d], &format!("{tag}.qn"));
    nodes.push(g.add_node(format!("{tag}.q.norm"), OpKind::Mul, vec![q, q], vec![qn]));
    let kn = g.tensor(&[tokens, d], &format!("{tag}.kn"));
    nodes.push(g.add_node(format!("{tag}.k.norm"), OpKind::Mul, vec![k, k], vec![kn]));
    let kt = g.tensor(&[d, tokens], &format!("{tag}.kT"));
    nodes.push(g.add_node(format!("{tag}.kT"), OpKind::Transpose, vec![kn], vec![kt]));
    let scores = g.tensor(&[tokens, tokens], &format!("{tag}.scores"));
    nodes.push(g.add_node(format!("{tag}.qk"), OpKind::MatMul, vec![qn, kt], vec![scores]));
    let logit_scale = g.tensor(&[1], &format!("{tag}.logit_scale"));
    let clamped = g.tensor(&[1], &format!("{tag}.scale_clamp"));
    nodes.push(g.add_node(format!("{tag}.scale_clamp"), OpKind::Maximum, vec![logit_scale], vec![clamped]));
    let scaled = g.tensor(&[tokens, tokens], &format!("{tag}.scaled"));
    nodes.push(g.add_node(format!("{tag}.scale"), OpKind::Mul, vec![scores, clamped], vec![scaled]));
    let cpb = g.tensor(&[tokens, tokens], &format!("{tag}.cpb"));
    let biased_s = g.tensor(&[tokens, tokens], &format!("{tag}.scores_b"));
    nodes.push(g.add_node(format!("{tag}.cpb_add"), OpKind::Add, vec![scaled, cpb], vec![biased_s]));
    let probs = g.tensor(&[tokens, tokens], &format!("{tag}.probs"));
    nodes.push(g.add_node(format!("{tag}.softmax"), OpKind::Softmax, vec![biased_s], vec![probs]));
    let attn = g.tensor(&[tokens, d], &format!("{tag}.attn"));
    nodes.push(g.add_node(format!("{tag}.pv"), OpKind::MatMul, vec![probs, v], vec![attn]));
    let _ = heads; // head count folded into the fused score matmuls
    let wo = g.tensor(&[d, d], &format!("{tag}.o.w"));
    let proj = g.tensor(&[tokens, d], &format!("{tag}.o.mm"));
    nodes.push(g.add_node(format!("{tag}.o"), OpKind::MatMul, vec![attn, wo], vec![proj]));
    let bo = g.tensor(&[d], &format!("{tag}.o.b"));
    let out = g.tensor(&[tokens, d], &format!("{tag}.o_b"));
    nodes.push(g.add_node(format!("{tag}.o.bias"), OpKind::Add, vec![proj, bo], vec![out]));
    if let Some(p) = program {
        g.set_program(anchor, p);
        for &n in &nodes[1..] {
            g.set_fused_into(n, anchor);
        }
    }
    out
}

/// One Swin block: (shift) → window partition → G parallel window-group
/// attentions → concat → unshift → LN/residual → MLP.
#[allow(clippy::too_many_arguments)]
fn swin_block(
    g: &mut Graph,
    x: TensorId,
    hw: usize,
    d: usize,
    heads: usize,
    groups: usize,
    shifted: bool,
    tag: &str,
    program: Option<&str>,
) -> TensorId {
    let tokens = hw * hw;
    let group_tokens = tokens / groups;

    let mut cur = x;
    if shifted {
        // roll = slice + concat (x2 axes collapsed into one pair here)
        let s = g.tensor(&[tokens, d], &format!("{tag}.roll_slice"));
        g.add_node(format!("{tag}.roll_slice"), OpKind::Slice, vec![cur], vec![s]);
        let r = g.tensor(&[tokens, d], &format!("{tag}.roll"));
        g.add_node(format!("{tag}.roll_concat"), OpKind::Concat, vec![s], vec![r]);
        cur = r;
    }

    // window partition: reshape + transpose + split into groups
    let part = g.tensor(&[groups, group_tokens, d], &format!("{tag}.partition"));
    g.add_node(format!("{tag}.partition"), OpKind::Reshape, vec![cur], vec![part]);
    let tr = g.tensor(&[groups, group_tokens, d], &format!("{tag}.perm"));
    g.add_node(format!("{tag}.perm"), OpKind::Transpose, vec![part], vec![tr]);
    let group_outs: Vec<TensorId> = {
        let outs: Vec<TensorId> = (0..groups)
            .map(|w| g.tensor(&[group_tokens, d], &format!("{tag}.win{w}")))
            .collect();
        g.add_node(
            format!("{tag}.win_split"),
            OpKind::Split { ways: groups },
            vec![tr],
            outs.clone(),
        );
        outs
            .into_iter()
            .enumerate()
            .map(|(w, t)| {
                window_attention(
                    g,
                    t,
                    group_tokens,
                    d,
                    heads,
                    &format!("{tag}.win{w}"),
                    program,
                )
            })
            .collect()
    };
    let merged = g.tensor(&[tokens, d], &format!("{tag}.win_merge"));
    g.add_node(format!("{tag}.win_merge"), OpKind::Concat, group_outs, vec![merged]);

    if shifted {
        let s = g.tensor(&[tokens, d], &format!("{tag}.unroll_slice"));
        g.add_node(format!("{tag}.unroll_slice"), OpKind::Slice, vec![merged], vec![s]);
        let r = g.tensor(&[tokens, d], &format!("{tag}.unroll"));
        g.add_node(format!("{tag}.unroll_concat"), OpKind::Concat, vec![s], vec![r]);
        cur = r;
    } else {
        cur = merged;
    }

    // post-LN (SwinV2) + residual
    let lng = g.tensor(&[d], &format!("{tag}.ln1.g"));
    let lnb = g.tensor(&[d], &format!("{tag}.ln1.b"));
    let ln = g.tensor(&[tokens, d], &format!("{tag}.ln1"));
    g.add_node(format!("{tag}.ln1"), OpKind::LayerNorm, vec![cur, lng, lnb], vec![ln]);
    let res = g.tensor(&[tokens, d], &format!("{tag}.res1"));
    g.add_node(format!("{tag}.res1"), OpKind::Add, vec![x, ln], vec![res]);

    // MLP: fc1 + gelu + fc2 + post-LN + residual
    let w1 = g.tensor(&[d, 4 * d], &format!("{tag}.mlp.w1"));
    let h1 = g.tensor(&[tokens, 4 * d], &format!("{tag}.mlp.h1"));
    g.add_node(format!("{tag}.mlp.fc1"), OpKind::MatMul, vec![res, w1], vec![h1]);
    let act = g.tensor(&[tokens, 4 * d], &format!("{tag}.mlp.gelu"));
    g.add_node(format!("{tag}.mlp.gelu"), OpKind::Gelu, vec![h1], vec![act]);
    let w2 = g.tensor(&[4 * d, d], &format!("{tag}.mlp.w2"));
    let h2 = g.tensor(&[tokens, d], &format!("{tag}.mlp.h2"));
    g.add_node(format!("{tag}.mlp.fc2"), OpKind::MatMul, vec![act, w2], vec![h2]);
    let lng2 = g.tensor(&[d], &format!("{tag}.ln2.g"));
    let lnb2 = g.tensor(&[d], &format!("{tag}.ln2.b"));
    let ln2 = g.tensor(&[tokens, d], &format!("{tag}.ln2"));
    g.add_node(format!("{tag}.ln2"), OpKind::LayerNorm, vec![h2, lng2, lnb2], vec![ln2]);
    let out = g.tensor(&[tokens, d], &format!("{tag}.res2"));
    g.add_node(format!("{tag}.res2"), OpKind::Add, vec![res, ln2], vec![out]);
    out
}

/// Patch merging: reshape + 4-way slice + concat + LN + reduction matmul.
fn patch_merge(g: &mut Graph, x: TensorId, hw: usize, d: usize, tag: &str) -> TensorId {
    let t_out = (hw / 2) * (hw / 2);
    let slices: Vec<TensorId> = (0..4)
        .map(|i| {
            let s = g.tensor(&[t_out, d], &format!("{tag}.s{i}"));
            g.add_node(format!("{tag}.slice{i}"), OpKind::Slice, vec![x], vec![s]);
            s
        })
        .collect();
    let cat = g.tensor(&[t_out, 4 * d], &format!("{tag}.cat"));
    g.add_node(format!("{tag}.concat"), OpKind::Concat, slices, vec![cat]);
    let lng = g.tensor(&[4 * d], &format!("{tag}.ln.g"));
    let lnb = g.tensor(&[4 * d], &format!("{tag}.ln.b"));
    let ln = g.tensor(&[t_out, 4 * d], &format!("{tag}.ln"));
    g.add_node(format!("{tag}.ln"), OpKind::LayerNorm, vec![cat, lng, lnb], vec![ln]);
    let w = g.tensor(&[4 * d, 2 * d], &format!("{tag}.w"));
    let out = g.tensor(&[t_out, 2 * d], &format!("{tag}.reduce"));
    g.add_node(format!("{tag}.reduce"), OpKind::MatMul, vec![ln, w], vec![out]);
    out
}

pub fn build() -> Graph {
    let mut g = Graph::new("swinv2_tiny");

    let raw = g.tensor(&[1, 224, 224, 3], "image_in");
    let img = g.tensor(&[1, 224, 224, 3], "image");
    g.add_node("input", OpKind::Input, vec![raw], vec![img]);

    // patch embed: conv 4x4 stride 4 → 56x56x96 + LN
    let wp = g.tensor(&[4, 4, 3, DIMS[0]], "patch_embed.w");
    let pe = g.tensor(&[1, 56, 56, DIMS[0]], "patch_embed");
    g.add_node(
        "patch_embed",
        OpKind::Conv2D { kh: 4, kw: 4, stride: 4 },
        vec![img, wp],
        vec![pe],
    );
    let mut x = g.tensor(&[56 * 56, DIMS[0]], "tokens0");
    g.add_node("patch_flatten", OpKind::Reshape, vec![pe], vec![x]);
    let lng = g.tensor(&[DIMS[0]], "pe.ln.g");
    let lnb = g.tensor(&[DIMS[0]], "pe.ln.b");
    let ln = g.tensor(&[56 * 56, DIMS[0]], "pe.ln");
    g.add_node("pe.ln", OpKind::LayerNorm, vec![x, lng, lnb], vec![ln]);
    x = ln;

    let mut hw = 56;
    for (s, &blocks) in STAGES.iter().enumerate() {
        let d = DIMS[s];
        let heads = HEADS[s];
        let groups = GROUPS[s];
        // program hints where the group token count matches an artifact
        let group_tokens = hw * hw / groups;
        let program = match (group_tokens, d) {
            (64, 96) => Some("attn_64x96_h3"),
            (64, 192) => Some("attn_64x192_h6"),
            _ => None,
        };
        for b in 0..blocks {
            x = swin_block(
                &mut g,
                x,
                hw,
                d,
                heads,
                groups,
                b % 2 == 1,
                &format!("st{s}.blk{b}"),
                program,
            );
        }
        if s < 3 {
            x = patch_merge(&mut g, x, hw, d, &format!("st{s}.merge"));
            hw /= 2;
        }
    }

    // head: LN + global mean + FC
    let d = DIMS[3];
    let lng = g.tensor(&[d], "head.ln.g");
    let lnb = g.tensor(&[d], "head.ln.b");
    let ln = g.tensor(&[hw * hw, d], "head.ln");
    g.add_node("head.ln", OpKind::LayerNorm, vec![x, lng, lnb], vec![ln]);
    let pooled = g.tensor(&[1, d], "head.pool");
    g.add_node("head.pool", OpKind::Mean, vec![ln], vec![pooled]);
    let wfc = g.tensor(&[d, 1000], "head.fc.w");
    let logits = g.tensor(&[1, 1000], "logits");
    g.add_node("head.fc", OpKind::FullyConnected, vec![pooled, wfc], vec![logits]);
    let out = g.tensor(&[1, 1000], "out");
    g.add_node("output", OpKind::Output, vec![logits], vec![out]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_count_near_table7() {
        // Table 7 "Pre": 1108 nodes.
        let g = build();
        let n = g.num_nodes();
        assert!(
            (800..=1350).contains(&n),
            "SwinV2 node count {n} too far from Table 7's 1108"
        );
    }

    #[test]
    fn validates() {
        let g = build();
        assert!(g.validate().is_empty(), "{:?}", g.validate());
        assert!(g.topo_order().is_some());
    }

    #[test]
    fn window_groups_exist() {
        let g = build();
        // stage 0 block 0 should have 8 window-attention chains
        let wins = g
            .nodes()
            .iter()
            .filter(|n| n.name.starts_with("st0.blk0.win") && n.name.ends_with(".pv"))
            .count();
        assert_eq!(wins, GROUPS[0]);
    }

    #[test]
    fn program_hints_on_stage1() {
        // stage 1: hw=28, groups=8 → 98 tokens — no artifact; stage 0:
        // 56x56/8 = 392 — no artifact either.  Check the hint logic only
        // fires on exact matches (none for the default config).
        let g = build();
        let hinted = g.nodes().iter().filter(|n| n.program.is_some()).count();
        // No stage matches 64-token windows with the default GROUPS, so
        // hints may be zero — the graph must still validate.
        let _ = hinted;
        assert!(g.validate().is_empty());
    }
}
