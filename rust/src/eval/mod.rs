//! Experiment regenerators — one function per paper table/figure.
//!
//! Each returns the formatted table as a `String` (the `parallax eval`
//! CLI prints it; the bench targets time the underlying pipelines and
//! print the same rows).  Protocol mirrors §4.1: 5 warm-ups + 20 timed
//! runs over 30 random inputs, min/max reported.

use crate::baselines::{Framework, Pipeline, Unsupported};
use crate::branch::{self, DEFAULT_BETA};
use crate::device::SocProfile;
use crate::memory;
use crate::models::ModelKind;
use crate::partition::{partition, CostModel};
use crate::sched::SchedCfg;
use crate::sim::Mode;

pub const RUNS: usize = 20;
pub const SEED: u64 = 2026;

/// Table 3 cell: min/max latency in ms, or None for "-".
pub fn latency_cell(
    fw: Framework,
    model: ModelKind,
    soc: &SocProfile,
    mode: Mode,
    threads: usize,
) -> Option<(f64, f64)> {
    let cfg = SchedCfg { max_threads: threads, ..SchedCfg::default() };
    let pipe = match Pipeline::build(fw, model, soc, mode, cfg) {
        Ok(p) => p,
        Err(Unsupported::NoAcceleratorPath)
        | Err(Unsupported::DynamicOps)
        | Err(Unsupported::OperatorMismatch)
        | Err(Unsupported::NothingDelegated) => return None,
    };
    let runs = pipe.run_protocol(RUNS, SEED);
    let lats: Vec<f64> = runs.iter().map(|r| r.latency_s * 1e3).collect();
    let min = lats.iter().cloned().fold(f64::MAX, f64::min);
    let max = lats.iter().cloned().fold(0.0, f64::max);
    Some((min, max))
}

fn fmt_cell(c: Option<(f64, f64)>) -> String {
    match c {
        Some((lo, hi)) => format!("{:.0} / {:.0}", lo, hi),
        None => "-".to_string(),
    }
}

/// Table 3: end-to-end latency, 5 models × 3 devices × 4 frameworks ×
/// {CPU, Het}.
pub fn table3() -> String {
    let mut out = String::from(
        "Table 3: End-to-end inference latency (ms), min / max over the \
         30-input protocol\n",
    );
    for make in SocProfile::ALL {
        let soc = make();
        out += &format!("\n== {} ==\n", soc.display_name());
        out += &format!(
            "{:<18} {:>13} {:>13} {:>13} {:>13} {:>13} {:>13} {:>13} {:>13}\n",
            "Model", "ORT cpu", "ORT het", "ET cpu", "ET het", "TFL cpu",
            "TFL het", "PLX cpu", "PLX het"
        );
        for model in ModelKind::ALL {
            let mut row = format!("{:<18}", model.display_name());
            for fw in Framework::ALL {
                for mode in [Mode::CpuOnly, Mode::Heterogeneous] {
                    row += &format!(
                        " {:>13}",
                        fmt_cell(latency_cell(fw, model, &soc, mode, 6))
                    );
                }
            }
            out += &row;
            out.push('\n');
        }
    }
    out
}

/// Table 4: peak runtime memory (MB) per model × device × framework.
pub fn table4() -> String {
    let mut out = String::from("Table 4: Peak runtime memory usage (MB)\n");
    for make in SocProfile::ALL {
        let soc = make();
        out += &format!("\n== {} ==\n", soc.display_name());
        out += &format!(
            "{:<18} {:>9} {:>9} {:>9} {:>9}\n",
            "Model", "ORT", "ET", "TFLite", "Parallax"
        );
        for model in ModelKind::ALL {
            let mut row = format!("{:<18}", model.display_name());
            for fw in Framework::ALL {
                let cell = Pipeline::build(fw, model, &soc, Mode::CpuOnly, SchedCfg::default())
                    .ok()
                    .map(|p| {
                        let r = p.run_protocol(5, SEED);
                        r.iter().map(|x| x.peak_mem_bytes).max().unwrap() as f64 / 1e6
                    });
                row += &match cell {
                    Some(mb) => format!(" {:>9.1}", mb),
                    None => format!(" {:>9}", "-"),
                };
            }
            out += &row;
            out.push('\n');
        }
    }
    out
}

/// Table 5: tensor-arena footprint (MB) per planner.
pub fn table5() -> String {
    let mut out = String::from(
        "Table 5: Peak memory footprint (MB) of tensor arena allocations\n",
    );
    out += &format!(
        "{:<18} {:>9} {:>11} {:>9} {:>15} {:>9}\n",
        "Model", "ORT", "ExecuTorch", "TFLite", "TFLite (Naive)", "Parallax"
    );
    for model in ModelKind::ALL {
        let g = model.build();
        let (naive, greedy) = memory::baseline_footprints(&g);
        // ORT/ET/TFLite all use greedy-reuse arenas with slightly
        // different alignment/slack — model as small constant factors.
        let ort = greedy as f64 * 0.97;
        let et = greedy as f64 * 1.04;
        let tfl = greedy as f64;
        let p = partition(&g, &CostModel::default());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let plx = memory::parallax_footprint(&g, &p, &plan).total() as f64;
        out += &format!(
            "{:<18} {:>9.2} {:>11.2} {:>9.2} {:>15.2} {:>9.2}\n",
            model.display_name(),
            ort / 1e6,
            et / 1e6,
            tfl / 1e6,
            naive as f64 / 1e6,
            plx / 1e6,
        );
    }
    out
}

/// Figure 2 measured column: one real-engine inference with the energy
/// ledger attached (mJ).  The schedule is the same fixed-budget one the
/// simulator prices, the [`crate::exec::EnergyModel`] comes from
/// [`crate::sim::energy_model_for`] at full fill (the engine executes
/// max-shape tensors), so on static models the executor's accumulated
/// `ExecStats::energy_j` reproduces the simulator's closed form; on
/// dynamic models it reports max-fill energy, above the random-fill
/// modelled mean (EXPERIMENTS.md §Energy, §Deviations).
pub fn fig2_measured_mj(model: ModelKind, soc: &SocProfile) -> f64 {
    let cfg = SchedCfg::default();
    let pipe = Pipeline::build(Framework::Parallax, model, soc, Mode::CpuOnly, cfg)
        .expect("cpu always supported");
    // fixed (effectively unbounded) budget: no free-memory jitter, the
    // measured schedule is exactly the one the modelled column prices
    let schedules = crate::sched::schedule(&pipe.plan, &pipe.mems, 1 << 34, &cfg);
    let mut engine =
        crate::exec::Engine::new(&pipe.graph, &pipe.partition, &pipe.plan, None);
    engine.set_energy_model(crate::sim::energy_model_for(
        &pipe.graph,
        &pipe.partition,
        &pipe.plan,
        &schedules,
        &pipe.profile,
        soc,
        &cfg,
        1.0,
    ));
    let (_, st) = engine.run(&schedules).expect("host execution");
    st.energy_j * 1e3
}

/// Figure 2: energy on Pixel 6, CPU-only (mJ per inference).  The four
/// framework columns are modelled (simulator closed form over the
/// 30-input protocol); `PLX meas` is the real executor's per-run energy
/// ledger ([`fig2_measured_mj`]).
pub fn fig2() -> String {
    let soc = SocProfile::pixel6();
    let mut out = String::from("Figure 2: Energy per inference, Pixel 6 CPU-only (mJ)\n");
    out += &format!(
        "{:<18} {:>9} {:>11} {:>9} {:>9} {:>10}\n",
        "Model", "ORT", "ExecuTorch", "TFLite", "Parallax", "PLX meas"
    );
    for model in ModelKind::ALL {
        let mut row = format!("{:<18}", model.display_name());
        for fw in Framework::ALL {
            let e = Pipeline::build(fw, model, &soc, Mode::CpuOnly, SchedCfg::default())
                .ok()
                .map(|p| {
                    let r = p.run_protocol(RUNS, SEED);
                    r.iter().map(|x| x.energy_j).sum::<f64>() / r.len() as f64 * 1e3
                });
            row += &match e {
                Some(mj) => format!(" {:>9.1}", mj),
                None => format!(" {:>9}", "-"),
            };
        }
        row += &format!(" {:>10.1}", fig2_measured_mj(model, &soc));
        out += &row;
        out.push('\n');
    }
    out
}

/// Figure 3: latency vs max parallel threads (Pixel 6, CPU-only).
pub fn fig3() -> String {
    let soc = SocProfile::pixel6();
    let mut out = String::from(
        "Figure 3: Parallax latency (ms, mean) vs max parallel threads, \
         Pixel 6 CPU-only\n",
    );
    out += &format!("{:<18}", "Model");
    for t in 1..=8 {
        out += &format!(" {:>7}", format!("T={t}"));
    }
    out.push('\n');
    for model in ModelKind::ALL {
        let mut row = format!("{:<18}", model.display_name());
        for threads in 1..=8 {
            let cfg = SchedCfg { max_threads: threads, ..SchedCfg::default() };
            let p = Pipeline::build(Framework::Parallax, model, &soc, Mode::CpuOnly, cfg)
                .expect("cpu always supported");
            let r = p.run_protocol(10, SEED);
            let mean = r.iter().map(|x| x.latency_s * 1e3).sum::<f64>() / r.len() as f64;
            row += &format!(" {:>7.1}", mean);
        }
        out += &row;
        out.push('\n');
    }
    out
}

/// Table 6: layer-wise latency, TFLite vs Parallax, with branch counts.
/// (Whisper on CPU; SwinV2 heterogeneous — mirrors the paper's setup.)
pub fn table6() -> String {
    let soc = SocProfile::pixel6();
    let mut out = String::from(
        "Table 6: Layer-wise latency (ms) and branch counts, Pixel 6\n",
    );
    for (model, mode, label) in [
        (ModelKind::WhisperTiny, Mode::CpuOnly, "Whisper (CPU)"),
        (ModelKind::Swinv2Tiny, Mode::Heterogeneous, "SwinV2-Tiny (CPU+TPU)"),
    ] {
        out += &format!("\n== {label} ==\n");
        let cfg = SchedCfg::default();
        let tfl = match Pipeline::build(Framework::TfLite, model, &soc, Mode::CpuOnly, cfg) {
            Ok(p) => p,
            Err(_) => continue,
        };
        let plx = match Pipeline::build(Framework::Parallax, model, &soc, mode, cfg) {
            Ok(p) => p,
            Err(_) => Pipeline::build(Framework::Parallax, model, &soc, Mode::CpuOnly, cfg).unwrap(),
        };
        let mut rng_t = crate::util::rng::Rng::new(SEED);
        let mut rng_p = crate::util::rng::Rng::new(SEED);
        let rt = tfl.run(&mut rng_t, 0.8);
        let rp = plx.run(&mut rng_p, 0.8);
        out += &format!(
            "{:>8} {:>12} {:>14} {:>6}\n",
            "Layer", "TFLite (ms)", "Parallax (ms)", "BR"
        );
        // report the layers with the largest TFLite time plus a couple
        // of single-branch ones (the paper's selection style)
        let mut order: Vec<usize> = (0..rp.per_layer.len().min(rt.per_layer.len())).collect();
        order.sort_by(|&a, &b| {
            rt.per_layer[b]
                .latency_s
                .partial_cmp(&rt.per_layer[a].latency_s)
                .unwrap()
        });
        // the paper profiles mostly multi-branch layers plus a couple of
        // single-branch (incl. delegated) ones
        let mut shown: Vec<usize> = order
            .iter()
            .copied()
            .filter(|&l| rp.per_layer[l].branches > 1)
            .take(3)
            .collect();
        let singles: Vec<usize> = order
            .iter()
            .copied()
            .filter(|&l| rp.per_layer[l].branches == 1 && !shown.contains(&l))
            .take(2)
            .collect();
        shown.extend(singles);
        shown.sort_unstable();
        for l in shown {
            let d = if rp.per_layer[l].has_delegate { " (D)" } else { "" };
            out += &format!(
                "{:>8} {:>12.2} {:>14.2} {:>6}\n",
                l,
                rt.per_layer[l].latency_s * 1e3,
                rp.per_layer[l].latency_s * 1e3,
                format!("{}{}", rp.per_layer[l].branches, d),
            );
        }
    }
    out
}

/// Table 7: graph structure pre/post/Parallax.
pub fn table7() -> String {
    let mut out = String::from(
        "Table 7: Graph structure and parallelism (nodes / layers / \
         par-layers / max-branches)\n",
    );
    out += &format!(
        "{:<18} {:>22} {:>22} {:>22}\n",
        "Model", "Pre", "Post", "Parallax"
    );
    for model in ModelKind::ALL {
        let g = model.build();
        // Pre: everything on CPU, fine-grained
        let pre_p = partition(
            &g,
            &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
        );
        let pre = branch::plan(&g, &pre_p, DEFAULT_BETA);
        // Post: naive full delegation (every eligible region, any size)
        let post_p = partition(
            &g,
            &CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX },
        );
        let post = branch::plan(&g, &post_p, DEFAULT_BETA);
        // Parallax: cost-model pruned
        let plx_p = partition(&g, &CostModel::default());
        let plx = branch::plan(&g, &plx_p, DEFAULT_BETA);

        let fmt = |nodes: usize, plan: &branch::BranchPlan| {
            let (layers, par, maxb) = plan.table7_metrics();
            format!("{nodes:>5} /{layers:>4} /{par:>4} /{maxb:>3}")
        };
        out += &format!(
            "{:<18} {:>22} {:>22} {:>22}\n",
            model.display_name(),
            fmt(g.num_nodes(), &pre),
            fmt(post_p.post_node_count(), &post),
            fmt(plx_p.post_node_count(), &plx),
        );
    }
    out
}

/// Heterogeneous placement decisions (repo-specific, `crate::place`):
/// per model × device, how the placement model distributes delegated
/// branches across the device's accelerator lanes (the `a+b` column —
/// one count per [`AccLane`](crate::device::AccLane), so a 2-lane
/// device shows how the busy-time balancing split the work), the
/// host-visible staging they lease, and the modelled delegate-vs-CPU
/// latency of the delegated set.  Devices whose lanes are
/// runtime-unreachable (the P30 Pro) never delegate, whatever their
/// modelled rates.  Pure modelling — no execution — so the table is
/// cheap and exact; `benches/heterogeneous.rs` measures the
/// real-engine wall-clock effect (EXPERIMENTS.md §Heterogeneous).
///
/// Regions come from the paper's relaxed [`CostModel::default`] (one
/// partition per model, shared by every device column); what varies
/// per device is the *placement* of those regions.  The heterogeneous
/// bench's own run section instead derives the cut from the device
/// (`CostModel::from_profile`), which is stricter — its region set can
/// be smaller than this table's.
pub fn hetero() -> String {
    use crate::place::{self, PlacePolicy};
    let mut out = String::from(
        "Heterogeneous placement: delegated branches per lane / staging KB / \
         modelled delegate vs CPU ms (delegated set)\n",
    );
    out += &format!("{:<18}", "Model");
    for make in SocProfile::ALL {
        let soc = make();
        out += &format!(
            " {:>24}",
            format!("{} ({}L)", soc.display_name(), soc.lanes.len())
        );
    }
    out.push('\n');
    let micro_fb = crate::models::micro::fallback_heavy(6, 24, 448, 4);
    let mut rows: Vec<(String, crate::graph::Graph)> = vec![("fallback-heavy".into(), micro_fb)];
    for model in ModelKind::ALL {
        rows.push((model.display_name().to_string(), model.build()));
    }
    for (name, g) in rows {
        let mut row = format!("{:<18}", name);
        let p = partition(&g, &CostModel::default());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        for make in SocProfile::ALL {
            let soc = make();
            let placed = place::assign(&g, &p, &plan, &soc, PlacePolicy::Auto);
            if placed.num_delegated() == 0 {
                row += &format!(" {:>24}", "0 (all CPU)");
                continue;
            }
            let (mut acc_ms, mut cpu_ms) = (0.0, 0.0);
            for b in placed.delegated() {
                acc_ms += placed.delegate_latency_s[b] * 1e3;
                cpu_ms += placed.cpu_latency_s[b] * 1e3;
            }
            let dist = placed
                .lane_job_counts(soc.lanes.len())
                .iter()
                .map(usize::to_string)
                .collect::<Vec<_>>()
                .join("+");
            row += &format!(
                " {:>24}",
                format!(
                    "{}/{:.0}KB/{:.2}v{:.1}",
                    dist,
                    placed.total_staging_bytes() as f64 / 1e3,
                    acc_ms,
                    cpu_ms
                )
            );
        }
        out += &row;
        out.push('\n');
    }
    out
}

/// Cross-model serving placement (repo-specific, `crate::serve`): two
/// fallback-heavy tenants on Pixel 6, placed *independently* (each
/// tenant assigns as if it had the device alone — both trunk onto the
/// same fastest lane) vs *jointly* through a server's shared
/// [`LaneLedger`](crate::sched::LaneLedger) (the second tenant sees the
/// first's lane load and takes the idle lane); then one tenant drops
/// and the joint re-placement moves the survivor onto the freed lane.
/// Pure modelling over the same placement engine the dispatcher swaps
/// executors from, so every cell is deterministic
/// (EXPERIMENTS.md §Serving).
pub fn serving() -> String {
    use crate::place::{self, PlacePolicy};

    let soc = SocProfile::pixel6();
    let lanes = soc.lanes.len();
    let loose = CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX };
    let heavy = || {
        Pipeline::from_graph(
            Framework::Parallax,
            crate::models::micro::fallback_heavy(4, 4, 128, 6),
            &loose,
            &soc,
            Mode::Heterogeneous,
            SchedCfg::default(),
        )
    };
    let fmt_counts = |counts: &[usize]| {
        counts.iter().map(usize::to_string).collect::<Vec<_>>().join("+")
    };
    let collide =
        |a: &[usize], b: &[usize]| a.iter().zip(b).any(|(&x, &y)| x > 0 && y > 0);

    let mut out = String::from(
        "Cross-model serving placement (Pixel 6, two fallback-heavy tenants): \
         delegated jobs per lane\n",
    );
    out += &format!("{:<22} {:>10} {:>10}\n", "deployment", "tenant-a", "tenant-b");

    let mut indep = Vec::new();
    for name in ["tenant-a", "tenant-b"] {
        let pipe = heavy();
        let placed = place::assign(
            &pipe.graph,
            &pipe.partition,
            &pipe.plan,
            &pipe.soc,
            PlacePolicy::Auto,
        );
        indep.push((name, placed.lane_job_counts(lanes)));
    }
    out += &format!(
        "{:<22} {:>10} {:>10}  {}\n",
        "independent assign",
        fmt_counts(&indep[0].1),
        fmt_counts(&indep[1].1),
        if collide(&indep[0].1, &indep[1].1) { "COLLIDE" } else { "disjoint" },
    );

    let mut server = crate::serve::Server::new();
    server.register_placed("tenant-a", heavy(), 7);
    server.register_placed("tenant-b", heavy(), 8);
    let shared: Vec<(String, Vec<usize>)> = server
        .placements()
        .into_iter()
        .map(|(n, p)| (n, p.lane_job_counts(lanes)))
        .collect();
    out += &format!(
        "{:<22} {:>10} {:>10}  {}\n",
        "shared lane ledger",
        fmt_counts(&shared[0].1),
        fmt_counts(&shared[1].1),
        if collide(&shared[0].1, &shared[1].1) { "COLLIDE" } else { "disjoint" },
    );

    server.drop_model("tenant-a").expect("registered above");
    let after = server.placements();
    out += &format!(
        "{:<22} {:>10} {:>10}  survivor re-placed onto the freed lane\n",
        "after drop(tenant-a)",
        "-",
        fmt_counts(&after[0].1.lane_job_counts(lanes)),
    );
    out
}

/// Spill placement for the remote experiment: every delegate-safe
/// branch forced onto the SoC's remote lane, priced by the Appendix-B
/// closed form on the link's terms (uplink dispatch, link bandwidth,
/// server rate) with [`transfer_bytes`](crate::place::transfer_bytes)
/// as the staged I/O.
fn spill_placement(
    g: &crate::graph::Graph,
    p: &crate::partition::Partition,
    plan: &branch::BranchPlan,
    soc: &SocProfile,
) -> crate::place::PlacementPlan {
    let rl = soc.remote_lane().expect("remote-capable soc");
    let lane = &soc.lanes[rl];
    let mut pl = crate::place::PlacementPlan::cpu_only(plan.branches.len());
    for b in 0..plan.branches.len() {
        let lat = crate::place::lane_delegate_latency(g, p, plan, b, soc, lane);
        if lat.is_finite() {
            pl.assignment[b] = crate::place::Placement::Delegate(rl);
            pl.staging_bytes[b] = crate::place::transfer_bytes(g, p, plan, b);
            pl.delegate_latency_s[b] = lat;
        }
    }
    pl
}

/// Device–edge remote spill (repo-specific, `crate::device::remote` +
/// `crate::serve`): two deterministic sections.
///
/// *Link sweep* — a fallback-heavy pipeline spilled onto the Pixel 6's
/// edge-server lane under progressively worse seeded
/// [`LinkModel`](crate::device::LinkModel)s.  Every row re-runs the
/// same transfer schedule, so the jobs/retries/byte columns are exact;
/// the `err%` column is the modelled-link error — measured jittered
/// remote busy seconds (`ExecStats::remote_busy_s`) against the
/// un-jittered Appendix-B sum — 0.0% on the reliable link by
/// construction, and negative once drops push branches back onto the
/// host.  The checksum column compares every run against the same
/// engine CPU-forced: remote execution uses the host kernels, so it is
/// bit-identical whatever the link does.
///
/// *Spill ladder* — a fixed backlog of deadline-tagged requests
/// through [`Server::register_with_slo`](crate::serve::Server) with a
/// real-engine spill executor, one deadline tier per admission
/// outcome.  Tier arithmetic is chosen so the decision is invariant to
/// queue drain timing, making the `Outcome::Spilled` counts exact.
pub fn remote() -> String {
    use crate::device::{LinkModel, RemoteLane};
    use crate::serve::{Outcome, PlacedEngineExecutor, Server, SloSpec};

    let soc = SocProfile::pixel6().with_remote(&RemoteLane::edge_server());
    let loose = CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX };
    let cfg = SchedCfg::default();
    let pipe = Pipeline::from_graph(
        Framework::Parallax,
        crate::models::micro::fallback_heavy(4, 3, 128, 6),
        &loose,
        &soc,
        Mode::Heterogeneous,
        cfg,
    );
    let schedules = crate::sched::schedule(&pipe.plan, &pipe.mems, 1 << 34, &cfg);
    let spill = spill_placement(&pipe.graph, &pipe.partition, &pipe.plan, &pipe.soc);
    let flags: Vec<bool> = soc.lanes.iter().map(|l| l.remote).collect();
    let modelled_s: f64 = spill.delegated().map(|b| spill.delegate_latency_s[b]).sum();

    let mut out = String::from(
        "Remote spill: Pixel 6 + edge-server lane, fallback-heavy tenant\n\n\
         Link sweep (spill placement under seeded links; checksum vs \
         CPU-forced)\n",
    );
    out += &format!(
        "{:<16} {:>5} {:>7} {:>8} {:>8} {:>9} {:>9} {:>7}  {}\n",
        "link", "jobs", "retries", "up KB", "down KB", "busy ms", "model ms", "err%",
        "bit-identical",
    );
    let engine = crate::exec::Engine::new(&pipe.graph, &pipe.partition, &pipe.plan, None);
    let (cpu_values, _) = engine.run_cpu_forced(&schedules).expect("host execution");
    let cpu_checksum = cpu_values.checksum();
    let links = [
        ("reliable", LinkModel::reliable(SEED)),
        (
            "jitter 5%",
            LinkModel { seed: SEED, jitter_frac: 0.05, ..LinkModel::reliable(SEED) },
        ),
        (
            "jitter 25%",
            LinkModel { seed: SEED, jitter_frac: 0.25, ..LinkModel::reliable(SEED) },
        ),
        ("lossy 20%", LinkModel::lossy(SEED, 0.20)),
        (
            "partitioned",
            LinkModel {
                seed: SEED,
                jitter_frac: 0.10,
                partition_every: 3,
                partition_len: 1,
                ..LinkModel::reliable(SEED)
            },
        ),
    ];
    for (name, link) in links {
        let mut engine =
            crate::exec::Engine::new(&pipe.graph, &pipe.partition, &pipe.plan, None);
        engine.set_remote(flags.clone(), link);
        let (values, st) = engine.run_placed(&schedules, &spill, None).expect("spill run");
        // busy seconds accumulate in dispatch order, the modelled sum
        // in branch order — same terms on a reliable link, so snap the
        // ulp-level reassociation noise to an exact zero
        let err = (st.remote_busy_s - modelled_s) / modelled_s * 100.0;
        let err = if err.abs() < 1e-9 { 0.0 } else { err };
        out += &format!(
            "{:<16} {:>5} {:>7} {:>8.1} {:>8.1} {:>9.3} {:>9.3} {:>7.1}  {}\n",
            name,
            st.delegate_jobs,
            st.link_retries,
            st.uplink_bytes as f64 / 1e3,
            st.downlink_bytes as f64 / 1e3,
            st.remote_busy_s * 1e3,
            modelled_s * 1e3,
            err,
            if values.checksum() == cpu_checksum { "yes" } else { "NO" },
        );
    }

    const BACKLOG: usize = 12;
    out += &format!(
        "\nSpill ladder ({BACKLOG}-request backlog, pinned SLO: lane 1.0s / \
         cpu 0.002s / remote 0.01s)\n",
    );
    out += &format!(
        "{:<22} {:>9} {:>9} {:>9} {:>5}\n",
        "deadline", "admitted", "spilled", "degraded", "shed",
    );
    let mut server = Server::new();
    let slo = SloSpec {
        lane: Some(0),
        lane_service_s: 1.0,
        cpu_service_s: 0.002,
        remote: None,
    }
    .with_remote(soc.remote_lane().expect("remote lane appended"), 0.01);
    let exec = PlacedEngineExecutor::new(
        pipe.graph.clone(),
        pipe.partition.clone(),
        pipe.plan.clone(),
        schedules.clone(),
        crate::place::PlacementPlan::cpu_only(pipe.plan.branches.len()),
    )
    .with_remote(flags.clone(), LinkModel::reliable(SEED), spill.clone());
    server.register_with_slo("edge-tenant", 0, slo, Box::new(exec));
    // each tier's arithmetic is invariant to drain timing: the local
    // lane eta is always >= 1.0s, the remote eta never exceeds
    // BACKLOG * 0.01s, and the CPU path is a plain threshold check
    for (label, d) in [
        ("100.0 (admit)", 100.0),
        ("0.5 (spill)", 0.5),
        ("0.005 (degrade)", 0.005),
        ("0.001 (shed)", 0.001),
    ] {
        let r = server
            .run_load_slo(&["edge-tenant"], BACKLOG, BACKLOG, SEED, Some(d))
            .expect("load run");
        // Promoted from a debug_assert: the outcome partition must be
        // exhaustive in release builds too (CI runs eval in release).
        assert_eq!(
            r.admitted + r.degraded + r.shed + r.dropped + r.skipped + r.spilled,
            BACKLOG,
        );
        let spilled_ok = r
            .responses
            .iter()
            .filter(|x| x.outcome == Outcome::Spilled)
            .all(|x| x.checksum == cpu_checksum);
        out += &format!(
            "{:<22} {:>9} {:>9} {:>9} {:>5}{}\n",
            label,
            r.admitted,
            r.spilled,
            r.degraded,
            r.shed,
            if spilled_ok { "" } else { "  CHECKSUM MISMATCH" },
        );
    }
    out
}

/// Dispatch by name (CLI + tests).
pub fn run(which: &str) -> Option<String> {
    Some(match which {
        "table3" => table3(),
        "table4" => table4(),
        "table5" => table5(),
        "table6" => table6(),
        "table7" => table7(),
        "fig2" => fig2(),
        "fig3" => fig3(),
        "hetero" => hetero(),
        "serving" => serving(),
        "remote" => remote(),
        "ablation-beta" => ablation_beta(),
        "ablation-margin" => ablation_margin(),
        "ablation-cost-model" => ablation_cost_model(),
        _ => return None,
    })
}

pub const ALL_EXPERIMENTS: [&str; 13] = [
    "table3", "table4", "table5", "table6", "table7", "fig2", "fig3", "hetero",
    "serving", "remote", "ablation-beta", "ablation-margin", "ablation-cost-model",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_runs_and_orders() {
        let t = table5();
        assert!(t.contains("YOLOv8n"));
        assert!(t.contains("DistilBERT"));
    }

    #[test]
    fn table7_runs() {
        let t = table7();
        assert!(t.contains("Parallax"));
        // 5 model rows + 2 header lines
        assert_eq!(t.lines().count(), 7);
    }

    #[test]
    fn dispatch_rejects_unknown() {
        assert!(run("table9").is_none());
    }

    #[test]
    fn hetero_runs_and_delegates_somewhere() {
        let t = hetero();
        assert!(t.contains("fallback-heavy"));
        assert!(t.contains("Whisper"));
        // at least one (model, device) cell must delegate (the cell
        // format prints "<n>/<staging>KB/<acc>v<cpu>" when it does)
        assert!(t.contains("KB/"), "{t}");
    }

    #[test]
    fn remote_experiment_pins_link_parity_and_spill_ladder() {
        let t = remote();
        // every link row — including the lossy and partitioned ones —
        // must report bit-identical outputs vs the CPU-forced run
        assert!(!t.contains("NO"), "{t}");
        assert!(!t.contains("CHECKSUM MISMATCH"), "{t}");
        // the reliable link's modelled-link error is exactly zero
        let reliable = t.lines().find(|l| l.starts_with("reliable")).expect("reliable row");
        assert!(reliable.trim_end().ends_with("0.0  yes"), "{t}");
        // ladder tiers resolve to exactly one outcome class each
        for (label, col) in [
            ("100.0 (admit)", 1),
            ("0.5 (spill)", 2),
            ("0.005 (degrade)", 3),
            ("0.001 (shed)", 4),
        ] {
            let row = t.lines().find(|l| l.starts_with(label)).expect("ladder row");
            let cells: Vec<&str> = row.split_whitespace().collect();
            // cells: [deadline, "(tier)", admitted, spilled, degraded, shed]
            for (i, c) in cells[2..].iter().enumerate() {
                let want = if i + 1 == col { "12" } else { "0" };
                assert_eq!(*c, want, "tier {label}: {t}");
            }
        }
    }

    #[test]
    fn serving_experiment_tenants_disjoint_under_shared_ledger() {
        let t = serving();
        assert!(t.contains("independent assign"));
        let shared = t
            .lines()
            .find(|l| l.starts_with("shared lane ledger"))
            .expect("shared row present");
        assert!(shared.contains("disjoint"), "{t}");
        assert!(t.contains("after drop(tenant-a)"));
    }
}

/// Ablation A: β (workload-balance threshold, §3.1 refinement) sweep —
/// how many layers qualify as parallel, and the latency effect.
pub fn ablation_beta() -> String {
    let soc = SocProfile::pixel6();
    let mut out = String::from(
        "Ablation A: balance threshold beta (par-layers / mean latency ms, \
         Pixel 6 CPU)\n",
    );
    out += &format!("{:<18}", "Model");
    for beta in [1.0, 1.25, 1.5, 2.0, 3.0, 10.0] {
        out += &format!(" {:>12}", format!("beta={beta}"));
    }
    out.push('\n');
    for model in [ModelKind::WhisperTiny, ModelKind::ClipText, ModelKind::Yolov8n] {
        let mut row = format!("{:<18}", model.display_name());
        for beta in [1.0, 1.25, 1.5, 2.0, 3.0, 10.0] {
            let g = model.build();
            let p = partition(
                &g,
                &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
            );
            let plan = branch::plan(&g, &p, beta);
            let (_, par, _) = plan.table7_metrics();
            // latency through a Parallax pipeline with this plan
            let mems = crate::memory::branch_memories(&g, &p, &plan);
            let fw = crate::baselines::parallax();
            let cfg = SchedCfg::default();
            let act = crate::sim::activation_footprint(&g, &p, &plan, &fw);
            let gov = crate::sched::MemoryGovernor::new(1 << 31);
            let scheds = crate::sched::schedule_governed(&plan, &mems, &gov, &cfg);
            let r = crate::sim::simulate(
                &g, &p, &plan, &scheds, &mems, &fw, &soc, &cfg,
                Mode::CpuOnly, 0.8, model.weight_bytes(), act,
            );
            row += &format!(" {:>12}", format!("{par}/{:.0}", r.latency_s * 1e3));
        }
        out += &row;
        out.push('\n');
    }
    out
}

/// Ablation B: §3.3 memory safety margin sweep — latency vs margin
/// (tight margins force sequential spill).
pub fn ablation_margin() -> String {
    let soc = SocProfile::pixel6();
    let mut out = String::from(
        "Ablation B: memory margin (Parallax mean latency ms, Pixel 6 CPU)\n",
    );
    out += &format!("{:<18}", "Model");
    for m in [0.3, 0.4, 0.5, 0.8, 0.95, 0.999] {
        out += &format!(" {:>8}", format!("m={m}"));
    }
    out.push('\n');
    for model in ModelKind::ALL {
        let mut row = format!("{:<18}", model.display_name());
        for margin in [0.3, 0.4, 0.5, 0.8, 0.95, 0.999] {
            let cfg = SchedCfg { max_threads: 6, margin };
            let p = Pipeline::build(Framework::Parallax, model, &soc, Mode::CpuOnly, cfg)
                .unwrap();
            let r = p.run_protocol(8, SEED);
            let mean = r.iter().map(|x| x.latency_s * 1e3).sum::<f64>() / r.len() as f64;
            row += &format!(" {:>8.1}", mean);
        }
        out += &row;
        out.push('\n');
    }
    out
}

/// Ablation C: §3.1 delegate cost-model min-FLOPs threshold sweep —
/// regions kept and heterogeneous latency.
pub fn ablation_cost_model() -> String {
    let soc = SocProfile::pixel6();
    let mut out = String::from(
        "Ablation C: delegate min-FLOPs threshold (regions kept / het \
         latency ms, Pixel 6)\n",
    );
    out += &format!("{:<18}", "Model");
    let thresholds: [u64; 5] = [0, 100_000_000, 300_000_000, 1_000_000_000, 5_000_000_000];
    for t in thresholds {
        out += &format!(" {:>12}", format!("F>={:.1}G", t as f64 / 1e9));
    }
    out.push('\n');
    for model in [ModelKind::Yolov8n, ModelKind::Swinv2Tiny, ModelKind::WhisperTiny] {
        let mut row = format!("{:<18}", model.display_name());
        for t in thresholds {
            let g = model.build();
            let cm = CostModel { min_ops: 3, min_flops: t, max_bytes_per_flop: 0.1 };
            let p = partition(&g, &cm);
            if p.regions.is_empty() {
                row += &format!(" {:>12}", "0/-");
                continue;
            }
            let plan = branch::plan(&g, &p, DEFAULT_BETA);
            let mems = crate::memory::branch_memories(&g, &p, &plan);
            let fw = crate::baselines::parallax();
            let cfg = SchedCfg::default();
            let act = crate::sim::activation_footprint(&g, &p, &plan, &fw);
            let gov = crate::sched::MemoryGovernor::new(1 << 31);
            let scheds = crate::sched::schedule_governed(&plan, &mems, &gov, &cfg);
            let r = crate::sim::simulate(
                &g, &p, &plan, &scheds, &mems, &fw, &soc, &cfg,
                Mode::Heterogeneous, 0.8, model.weight_bytes(), act,
            );
            row += &format!(" {:>12}", format!("{}/{:.0}", p.regions.len(), r.latency_s * 1e3));
        }
        out += &row;
        out.push('\n');
    }
    out
}
