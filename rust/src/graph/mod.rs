//! Computation-graph IR: tensors, operators, and the DAG.
//!
//! Everything Parallax does — delegate partitioning (§3.1), branch and
//! layer extraction (Algorithms 1–4), arena planning (§3.2) and
//! resource-constrained scheduling (§3.3) — is a pure function of this
//! IR.  Model weights never appear here: the paper's framework is
//! non-invasive and operates on structure + metadata only.

mod dag;
mod op;
mod tensor;

pub use dag::{Graph, Node, NodeId};
pub use op::{OpClass, OpKind};
pub use tensor::{DType, Dim, TensorId, TensorInfo};
