//! The computation DAG: nodes (operations) + edges (tensor deps).
//!
//! This is the substrate every Parallax stage operates on: delegate
//! partitioning walks it, branch extraction re-labels it, the memory
//! planner reads tensor liveness off its topological order, and the
//! simulator executes it.  All traversals are O(|V|+|E|), matching the
//! complexity the paper claims for its analyses.

use std::collections::HashMap;

use super::op::OpKind;
use super::tensor::{DType, Dim, TensorId, TensorInfo};

/// Unique node identifier within a graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

/// One operation in the DAG.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub kind: OpKind,
    pub inputs: Vec<TensorId>,
    pub outputs: Vec<TensorId>,
    /// L2 program this node anchors, if any.  When the real-execution
    /// engine reaches a node with a program hint it invokes the AOT
    /// artifact for the whole fused block this node represents; nodes
    /// covered by someone else's hint carry `fused_into`.
    pub program: Option<String>,
    /// Set when this node's computation is subsumed by another node's
    /// program artifact (real execution skips it; analysis still sees it).
    pub fused_into: Option<NodeId>,
}

/// A computation graph (DAG).
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub name: String,
    nodes: Vec<Node>,
    tensors: Vec<TensorInfo>,
    /// producer[tensor] = node that writes it (None for graph inputs).
    producer: Vec<Option<NodeId>>,
    /// consumers[tensor] = nodes that read it.
    consumers: Vec<Vec<NodeId>>,
}

impl Graph {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), ..Default::default() }
    }

    // -- construction ---------------------------------------------------

    /// Add a tensor; returns its id.
    pub fn add_tensor(&mut self, shape: Vec<Dim>, dtype: DType, label: impl Into<String>) -> TensorId {
        let id = TensorId(self.tensors.len() as u32);
        self.tensors.push(TensorInfo { id, shape, dtype, label: label.into() });
        self.producer.push(None);
        self.consumers.push(Vec::new());
        id
    }

    /// Convenience: all-static f32 tensor.
    pub fn tensor(&mut self, dims: &[usize], label: &str) -> TensorId {
        self.add_tensor(dims.iter().map(|&d| Dim::Static(d)).collect(), DType::F32, label)
    }

    /// Add a node; returns its id.  Panics on dangling tensor ids or
    /// double-produced tensors (DAG property).
    pub fn add_node(
        &mut self,
        name: impl Into<String>,
        kind: OpKind,
        inputs: Vec<TensorId>,
        outputs: Vec<TensorId>,
    ) -> NodeId {
        let id = NodeId(self.nodes.len() as u32);
        for &t in inputs.iter().chain(outputs.iter()) {
            assert!((t.0 as usize) < self.tensors.len(), "dangling tensor {t:?}");
        }
        for &t in &outputs {
            assert!(
                self.producer[t.0 as usize].is_none(),
                "tensor {t:?} already produced"
            );
            self.producer[t.0 as usize] = Some(id);
        }
        for &t in &inputs {
            self.consumers[t.0 as usize].push(id);
        }
        self.nodes.push(Node {
            id,
            name: name.into(),
            kind,
            inputs,
            outputs,
            program: None,
            fused_into: None,
        });
        id
    }

    /// Attach an L2 program hint to a node.
    pub fn set_program(&mut self, node: NodeId, program: impl Into<String>) {
        self.nodes[node.0 as usize].program = Some(program.into());
    }

    /// Mark a node as fused into another's program artifact.
    pub fn set_fused_into(&mut self, node: NodeId, anchor: NodeId) {
        self.nodes[node.0 as usize].fused_into = Some(anchor);
    }

    // -- accessors --------------------------------------------------------

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0 as usize]
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn tensor_info(&self, id: TensorId) -> &TensorInfo {
        &self.tensors[id.0 as usize]
    }

    pub fn tensors(&self) -> &[TensorInfo] {
        &self.tensors
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn num_edges(&self) -> usize {
        self.consumers.iter().map(Vec::len).sum()
    }

    /// Node that produces a tensor (None for graph inputs/consts fed in).
    pub fn producer(&self, t: TensorId) -> Option<NodeId> {
        self.producer[t.0 as usize]
    }

    /// Nodes that consume a tensor.
    pub fn consumers(&self, t: TensorId) -> &[NodeId] {
        &self.consumers[t.0 as usize]
    }

    /// Predecessor node ids (dedup'd, order-preserving).
    pub fn preds(&self, id: NodeId) -> Vec<NodeId> {
        let mut seen = Vec::new();
        for &t in &self.node(id).inputs {
            if let Some(p) = self.producer(t) {
                if !seen.contains(&p) {
                    seen.push(p);
                }
            }
        }
        seen
    }

    /// Successor node ids (dedup'd, order-preserving).
    pub fn succs(&self, id: NodeId) -> Vec<NodeId> {
        let mut seen = Vec::new();
        for &t in &self.node(id).outputs {
            for &c in self.consumers(t) {
                if !seen.contains(&c) {
                    seen.push(c);
                }
            }
        }
        seen
    }

    /// In-degree in node space.
    pub fn in_degree(&self, id: NodeId) -> usize {
        self.preds(id).len()
    }

    /// Out-degree in node space.
    pub fn out_degree(&self, id: NodeId) -> usize {
        self.succs(id).len()
    }

    /// Whether any input or output tensor has a dynamic dim.
    pub fn node_has_dynamic_shape(&self, id: NodeId) -> bool {
        let n = self.node(id);
        n.inputs
            .iter()
            .chain(n.outputs.iter())
            .any(|&t| self.tensor_info(t).has_dynamic_dim())
    }

    // -- traversal ---------------------------------------------------------

    /// Kahn topological order.  Returns None if the graph has a cycle.
    pub fn topo_order(&self) -> Option<Vec<NodeId>> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        for node in &self.nodes {
            indeg[node.id.0 as usize] = self.in_degree(node.id);
        }
        let mut queue: std::collections::VecDeque<NodeId> = (0..n)
            .filter(|&i| indeg[i] == 0)
            .map(|i| NodeId(i as u32))
            .collect();
        let mut order = Vec::with_capacity(n);
        while let Some(u) = queue.pop_front() {
            order.push(u);
            for v in self.succs(u) {
                indeg[v.0 as usize] -= 1;
                if indeg[v.0 as usize] == 0 {
                    queue.push_back(v);
                }
            }
        }
        (order.len() == n).then_some(order)
    }

    /// Validate DAG invariants; returns a list of problems (empty = ok).
    pub fn validate(&self) -> Vec<String> {
        let mut problems = Vec::new();
        if self.topo_order().is_none() {
            problems.push("graph has a cycle".to_string());
        }
        for t in &self.tensors {
            let produced = self.producer[t.id.0 as usize].is_some();
            let consumed = !self.consumers[t.id.0 as usize].is_empty();
            if !produced && !consumed {
                problems.push(format!("orphan tensor {} ({:?})", t.label, t.id));
            }
        }
        for node in &self.nodes {
            if node.outputs.is_empty() && !matches!(node.kind, OpKind::Output) {
                problems.push(format!("node {} has no outputs", node.name));
            }
        }
        problems
    }

    // -- export -------------------------------------------------------------

    /// Graphviz DOT text (for debugging / the paper's Fig. 1-style views).
    pub fn to_dot(&self) -> String {
        let mut s = format!("digraph \"{}\" {{\n  rankdir=TB;\n", self.name);
        for n in &self.nodes {
            s.push_str(&format!(
                "  n{} [label=\"{}\\n{}\"];\n",
                n.id.0,
                n.name,
                n.kind.mnemonic()
            ));
        }
        for n in &self.nodes {
            for v in self.succs(n.id) {
                s.push_str(&format!("  n{} -> n{};\n", n.id.0, v.0));
            }
        }
        s.push_str("}\n");
        s
    }

    /// Summary counts by op class (debugging; Table 7 uses partition data).
    pub fn class_histogram(&self) -> HashMap<&'static str, usize> {
        let mut h = HashMap::new();
        for n in &self.nodes {
            *h.entry(n.kind.mnemonic()).or_insert(0) += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// a -> b -> d, a -> c -> d (diamond)
    fn diamond() -> Graph {
        let mut g = Graph::new("diamond");
        let t0 = g.tensor(&[4], "in");
        let ta = g.tensor(&[4], "a_out");
        let tb = g.tensor(&[4], "b_out");
        let tc = g.tensor(&[4], "c_out");
        let td = g.tensor(&[4], "d_out");
        g.add_node("a", OpKind::Relu, vec![t0], vec![ta]);
        g.add_node("b", OpKind::Relu, vec![ta], vec![tb]);
        g.add_node("c", OpKind::Silu, vec![ta], vec![tc]);
        g.add_node("d", OpKind::Add, vec![tb, tc], vec![td]);
        g
    }

    #[test]
    fn degrees_and_topo() {
        let g = diamond();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.in_degree(NodeId(0)), 0);
        assert_eq!(g.out_degree(NodeId(0)), 2);
        assert_eq!(g.in_degree(NodeId(3)), 2);
        let order = g.topo_order().unwrap();
        assert_eq!(order.len(), 4);
        let pos: Vec<usize> = (0..4)
            .map(|i| order.iter().position(|&n| n == NodeId(i)).unwrap())
            .collect();
        assert!(pos[0] < pos[1] && pos[0] < pos[2]);
        assert!(pos[1] < pos[3] && pos[2] < pos[3]);
    }

    #[test]
    fn validate_clean_graph() {
        assert!(diamond().validate().is_empty());
    }

    #[test]
    fn orphan_tensor_detected() {
        let mut g = diamond();
        g.tensor(&[1], "orphan");
        assert!(!g.validate().is_empty());
    }

    #[test]
    #[should_panic(expected = "already produced")]
    fn double_producer_panics() {
        let mut g = Graph::new("bad");
        let t0 = g.tensor(&[1], "in");
        let t1 = g.tensor(&[1], "x");
        g.add_node("a", OpKind::Relu, vec![t0], vec![t1]);
        g.add_node("b", OpKind::Relu, vec![t0], vec![t1]);
    }

    #[test]
    fn dynamic_shape_detection() {
        let mut g = Graph::new("dyn");
        let t0 = g.add_tensor(
            vec![Dim::Static(1), Dim::Dynamic { max: 100 }],
            DType::F32,
            "boxes",
        );
        let t1 = g.tensor(&[1], "out");
        let n = g.add_node("nms", OpKind::NonMaxSuppression, vec![t0], vec![t1]);
        assert!(g.node_has_dynamic_shape(n));
    }

    #[test]
    fn dot_export_mentions_nodes() {
        let dot = diamond().to_dot();
        assert!(dot.contains("digraph"));
        assert!(dot.contains("n0 -> n1"));
    }

    #[test]
    fn edge_count() {
        // a->b, a->c (tensor ta consumed twice = 2 edges), b->d, c->d + input edge t0->a
        let g = diamond();
        assert_eq!(g.num_edges(), 5);
    }
}
