//! Tensor metadata: shapes with (optionally) dynamic dimensions.
//!
//! Parallax never touches tensor *values* during analysis — only
//! shapes, dtypes and liveness.  Dynamic dimensions (the paper's §3.2
//! "Handling Dynamic Tensor Shapes") carry an upper bound so static
//! peak-memory estimation stays safe, and the simulator draws a
//! concrete value per inference to model runtime variability.

/// One dimension of a tensor shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dim {
    /// Statically known.
    Static(usize),
    /// Resolved only at runtime; `max` bounds memory planning.
    Dynamic { max: usize },
}

impl Dim {
    /// Upper bound (the value used for arena sizing).
    pub fn max(&self) -> usize {
        match *self {
            Dim::Static(n) => n,
            Dim::Dynamic { max } => max,
        }
    }

    /// Concrete value given a dynamic-fill factor in (0, 1].
    pub fn resolve(&self, fill: f64) -> usize {
        match *self {
            Dim::Static(n) => n,
            Dim::Dynamic { max } => ((max as f64 * fill).ceil() as usize).max(1),
        }
    }

    pub fn is_dynamic(&self) -> bool {
        matches!(self, Dim::Dynamic { .. })
    }
}

/// Element type.  The zoo models use F32/F16/INT8 per Table 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F16,
    I8,
    I32,
}

impl DType {
    pub fn byte_width(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F16 => 2,
            DType::I8 => 1,
        }
    }
}

/// Unique tensor identifier within a graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TensorId(pub u32);

/// Static tensor metadata.
#[derive(Clone, Debug)]
pub struct TensorInfo {
    pub id: TensorId,
    pub shape: Vec<Dim>,
    pub dtype: DType,
    /// Human-readable label (op output name), for DOT export/debugging.
    pub label: String,
}

impl TensorInfo {
    /// Worst-case element count.
    pub fn numel_max(&self) -> usize {
        self.shape.iter().map(Dim::max).product()
    }

    /// Worst-case byte size — what the memory planner reserves.
    pub fn byte_size_max(&self) -> usize {
        self.numel_max() * self.dtype.byte_width()
    }

    /// Concrete byte size for a dynamic-fill draw.
    pub fn byte_size_at(&self, fill: f64) -> usize {
        self.shape.iter().map(|d| d.resolve(fill)).product::<usize>()
            * self.dtype.byte_width()
    }

    pub fn has_dynamic_dim(&self) -> bool {
        self.shape.iter().any(Dim::is_dynamic)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dim_resolution() {
        assert_eq!(Dim::Static(8).resolve(0.1), 8);
        assert_eq!(Dim::Dynamic { max: 100 }.resolve(0.25), 25);
        assert_eq!(Dim::Dynamic { max: 100 }.resolve(0.001), 1);
        assert_eq!(Dim::Dynamic { max: 100 }.max(), 100);
    }

    #[test]
    fn byte_sizes() {
        let t = TensorInfo {
            id: TensorId(0),
            shape: vec![Dim::Static(2), Dim::Dynamic { max: 10 }],
            dtype: DType::F16,
            label: "t".into(),
        };
        assert_eq!(t.numel_max(), 20);
        assert_eq!(t.byte_size_max(), 40);
        assert_eq!(t.byte_size_at(0.5), 2 * 5 * 2);
        assert!(t.has_dynamic_dim());
    }
}
