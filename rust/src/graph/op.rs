//! Operator vocabulary, mirroring the TFLite op classes the paper's
//! Appendix A groups for FLOP estimation.

/// Operator kind + the attributes the analyses need.
#[derive(Clone, Debug, PartialEq)]
pub enum OpKind {
    // -- compute-heavy (delegateable when shapes are static) ----------
    /// kh, kw, stride; channels come from the tensor shapes.
    Conv2D { kh: usize, kw: usize, stride: usize },
    DepthwiseConv2D { kh: usize, kw: usize, stride: usize },
    /// Dense / FullyConnected; transpose flags omitted (row-major).
    FullyConnected,
    MatMul,
    /// Fused scaled-dot-product attention (appears post-fusion in
    /// transformer graphs).
    Attention { heads: usize },

    // -- elementwise ---------------------------------------------------
    Add,
    Sub,
    Mul,
    Maximum,
    Relu,
    Silu,
    Gelu,
    Tanh,
    Logistic,

    // -- normalisation / reduction -------------------------------------
    Softmax,
    LayerNorm,
    AvgPool { k: usize, stride: usize },
    MaxPool { k: usize, stride: usize },
    Mean,
    Sum,

    // -- shape plumbing (0-FLOP) ----------------------------------------
    Reshape,
    Transpose,
    Slice,
    Concat,
    Split { ways: usize },
    Pad,
    Gather,
    Cast,

    // -- dynamic / control flow (never delegateable) ---------------------
    /// Conditional subgraph execution.
    If,
    /// Loop (e.g. beam-search decode steps).
    While,
    /// Produces a dynamically-shaped output (e.g. NonMaxSuppression).
    NonMaxSuppression,
    /// Dynamic-length decode step (beam search).
    BeamSearchStep,
    /// Embedding lookup with dynamic sequence length.
    EmbeddingLookup,

    // -- sources/sinks -----------------------------------------------------
    Input,
    Output,
    Const,
}

/// Coarse delegation class — drives both NNAPI-style support checks and
/// the Appendix A FLOP grouping.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpClass {
    ConvLike,
    MatMulLike,
    Elementwise,
    PoolReduce,
    Shape,
    Dynamic,
    SourceSink,
}

impl OpKind {
    pub fn class(&self) -> OpClass {
        use OpKind::*;
        match self {
            Conv2D { .. } | DepthwiseConv2D { .. } => OpClass::ConvLike,
            FullyConnected | MatMul | Attention { .. } => OpClass::MatMulLike,
            Add | Sub | Mul | Maximum | Relu | Silu | Gelu | Tanh | Logistic => {
                OpClass::Elementwise
            }
            Softmax | LayerNorm | AvgPool { .. } | MaxPool { .. } | Mean | Sum => {
                OpClass::PoolReduce
            }
            Reshape | Transpose | Slice | Concat | Split { .. } | Pad | Gather | Cast => {
                OpClass::Shape
            }
            If | While | NonMaxSuppression | BeamSearchStep | EmbeddingLookup => {
                OpClass::Dynamic
            }
            Input | Output | Const => OpClass::SourceSink,
        }
    }

    /// Whether an accelerator delegate supports this op *kind* at all
    /// (shape dynamism is checked separately — a supported kind with a
    /// dynamic input still falls back).  Mirrors the NNAPI 1.3 operator
    /// set: no LayerNorm, no GELU, no fused attention — the boundaries
    /// that fragment transformer graphs into many small delegates (the
    /// paper's core fallback story).
    pub fn delegate_supported(&self) -> bool {
        if matches!(
            self,
            OpKind::LayerNorm | OpKind::Gelu | OpKind::Attention { .. }
        ) {
            return false;
        }
        !matches!(
            self.class(),
            OpClass::Dynamic | OpClass::SourceSink
        )
    }

    /// Control-flow ops are Split-Merge barriers for branch extraction
    /// (§3.1: "control-flow operators are marked Split-Merge to ensure
    /// sequential correctness").
    pub fn is_control_flow(&self) -> bool {
        matches!(self, OpKind::If | OpKind::While | OpKind::BeamSearchStep)
    }

    /// Short mnemonic for DOT export / tables.
    pub fn mnemonic(&self) -> &'static str {
        use OpKind::*;
        match self {
            Conv2D { .. } => "conv",
            DepthwiseConv2D { .. } => "dwconv",
            FullyConnected => "fc",
            MatMul => "matmul",
            Attention { .. } => "attn",
            Add => "add",
            Sub => "sub",
            Mul => "mul",
            Maximum => "max",
            Relu => "relu",
            Silu => "silu",
            Gelu => "gelu",
            Tanh => "tanh",
            Logistic => "sigmoid",
            Softmax => "softmax",
            LayerNorm => "lnorm",
            AvgPool { .. } => "avgpool",
            MaxPool { .. } => "maxpool",
            Mean => "mean",
            Sum => "sum",
            Reshape => "reshape",
            Transpose => "transpose",
            Slice => "slice",
            Concat => "concat",
            Split { .. } => "split",
            Pad => "pad",
            Gather => "gather",
            Cast => "cast",
            If => "if",
            While => "while",
            NonMaxSuppression => "nms",
            BeamSearchStep => "beam",
            EmbeddingLookup => "embed",
            Input => "input",
            Output => "output",
            Const => "const",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_are_consistent() {
        assert_eq!(OpKind::Conv2D { kh: 3, kw: 3, stride: 1 }.class(), OpClass::ConvLike);
        assert_eq!(OpKind::MatMul.class(), OpClass::MatMulLike);
        assert_eq!(OpKind::Relu.class(), OpClass::Elementwise);
        assert_eq!(OpKind::Reshape.class(), OpClass::Shape);
        assert_eq!(OpKind::While.class(), OpClass::Dynamic);
    }

    #[test]
    fn dynamic_ops_never_delegate() {
        assert!(!OpKind::NonMaxSuppression.delegate_supported());
        assert!(!OpKind::While.delegate_supported());
        assert!(!OpKind::Input.delegate_supported());
        assert!(OpKind::MatMul.delegate_supported());
        assert!(OpKind::Softmax.delegate_supported());
    }

    #[test]
    fn control_flow_flags() {
        assert!(OpKind::If.is_control_flow());
        assert!(OpKind::While.is_control_flow());
        assert!(!OpKind::NonMaxSuppression.is_control_flow());
    }
}
