//! Simulated edge SoC device models.
//!
//! The paper's testbed (Google Pixel 6 / Huawei P30 Pro / Redmi K50) is
//! replaced by parameterised SoC profiles (ARCHITECTURE.md §Substitutions):
//! per-core CPU throughput, accelerator throughput + dispatch latency,
//! memory bandwidth, RAM, and a power-state energy model.  Values are
//! anchored to the paper's §3.1 representative numbers and public SoC
//! specs; absolute ms/mJ are calibration targets, the *relative*
//! behaviour (who wins, where crossovers fall) is what the simulator
//! must reproduce.

use crate::util::rng::Rng;

pub mod remote;
pub use remote::{LinkModel, RemoteLane};

/// One accelerator queue ("lane") of a SoC: the TPU/NPU, the GPU, a
/// DSP.  Mobile SoCs expose several such queues simultaneously; each
/// lane has its own sustained rate, dispatch latency, transfer
/// bandwidth and power draw, and — crucially — its own *reachability*:
/// a lane the runtime cannot drive (the P30 Pro's NPU has no NNAPI
/// path) must never be a placement target, however fast its modelled
/// rates look.  The legacy scalar fields on [`SocProfile`]
/// (`acc_flops`/`acc_utilization`/`acc_dispatch_s`/`p_acc_w`/`nnapi`)
/// remain as a one-lane compatibility view mirroring `lanes[0]`, with
/// the old `nnapi` flag folded into [`AccLane::reachable`].
#[derive(Clone, Debug)]
pub struct AccLane {
    /// Short lane name for tables ("tpu", "gpu", "mdla", ...).
    pub name: &'static str,
    /// Peak compute rate, FLOP/s.
    pub flops: f64,
    /// Sustained fraction of peak a delegate reaches on the zoo's
    /// region sizes (small tensors never fill the MAC array).
    pub utilization: f64,
    /// Dispatch latency per delegate invocation, seconds.
    pub dispatch_s: f64,
    /// Host<->lane transfer bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Lane active power, watts.
    pub power_w: f64,
    /// Whether the runtime can actually drive this lane (NNAPI/OpenCL
    /// visibility).  Unreachable lanes are modelling-only: placement
    /// (`crate::place`) must never delegate to them.
    pub reachable: bool,
    /// Whether this lane is a device–edge spill tier ([`RemoteLane`])
    /// rather than an on-die queue: its `dispatch_s`/`mem_bw` are
    /// uplink latency and link bandwidth, its transfers cross a lossy
    /// link (`LinkModel`), and its staging bytes are *transfer* bytes.
    /// Stock profiles never set this; attach one via
    /// [`SocProfile::with_remote`].
    pub remote: bool,
}

impl AccLane {
    /// Sustained effective compute rate, FLOP/s.
    pub fn effective_flops(&self) -> f64 {
        self.flops * self.utilization
    }
}

/// One SoC profile.
#[derive(Clone, Debug)]
pub struct SocProfile {
    pub name: &'static str,
    /// Total CPU cores (big + little).
    pub cpu_cores: usize,
    /// Sustained per-big-core compute rate, FLOP/s (2 FLOPs per MAC).
    pub cpu_flops_per_core: f64,
    /// Relative throughput of additional cores (big.LITTLE scaling):
    /// core i contributes `cpu_flops_per_core * core_scale[i]`.
    pub core_scale: [f64; 8],
    /// Accelerator peak compute rate, FLOP/s.
    pub acc_flops: f64,
    /// Sustained fraction of peak an NNAPI delegate reaches on the
    /// zoo's region sizes (small tensors never fill the MAC array).
    pub acc_utilization: f64,
    /// Accelerator dispatch latency per delegate invocation, seconds.
    pub acc_dispatch_s: f64,
    /// Host<->accelerator transfer bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Physical RAM, bytes.
    pub ram_bytes: u64,
    /// OS + resident apps baseline, bytes (free mem = ram - this - jitter).
    pub os_reserved: u64,
    /// Whether the accelerator is reachable (P30 Pro's NPU is not
    /// NNAPI-accessible; its GPU path has higher dispatch cost).
    pub nnapi: bool,
    /// Per-active-CPU-core power, watts.
    pub p_core_w: f64,
    /// Accelerator active power, watts.
    pub p_acc_w: f64,
    /// Idle/baseline platform power, watts.
    pub p_idle_w: f64,
    /// Accelerator lanes (concurrent delegate queues).  `lanes[0]`
    /// mirrors the scalar `acc_*`/`nnapi` fields (the one-lane
    /// compatibility view); further entries are additional queues the
    /// multi-lane placement (`crate::place`) can load-balance across.
    pub lanes: Vec<AccLane>,
}

impl SocProfile {
    /// Google Pixel 6 — Google Tensor: 2×X1@2.80GHz + 2×A76 + 4×A55, TPU.
    pub fn pixel6() -> Self {
        Self {
            name: "pixel6",
            cpu_cores: 8,
            // ~2.8GHz X1, 2×128-bit NEON FMA ≈ 8 f32 FLOPs/cycle sustained
            cpu_flops_per_core: 21.0e9,
            core_scale: [1.0, 1.0, 0.85, 0.85, 0.55, 0.50, 0.45, 0.40],
            acc_flops: 30.0e12, // EdgeTPU-class
            acc_utilization: 0.22,
            acc_dispatch_s: 0.20e-3,
            mem_bw: 51.2e9, // LPDDR5
            ram_bytes: 8 * (1 << 30),
            os_reserved: 4 * (1 << 30),
            nnapi: true,
            p_core_w: 1.9,
            p_acc_w: 2.4,
            p_idle_w: 0.65,
            lanes: vec![
                AccLane {
                    name: "tpu",
                    flops: 30.0e12,
                    utilization: 0.22,
                    dispatch_s: 0.20e-3,
                    mem_bw: 51.2e9,
                    power_w: 2.4,
                    reachable: true,
                    remote: false,
                },
                AccLane {
                    // Mali-G78 via the GPU delegate: slower sustained
                    // rate, higher queue latency, but a second
                    // concurrent lane next to the TPU.
                    name: "gpu",
                    flops: 4.0e12,
                    utilization: 0.30,
                    dispatch_s: 0.45e-3,
                    mem_bw: 51.2e9,
                    power_w: 1.6,
                    reachable: true,
                    remote: false,
                },
            ],
        }
    }

    /// Huawei P30 Pro — Kirin 980: 2×A76@2.60GHz + 2×A76 + 4×A55.
    /// NPU not NNAPI-accessible; OpenCL GPU path with high dispatch.
    pub fn p30_pro() -> Self {
        Self {
            name: "p30pro",
            cpu_cores: 8,
            cpu_flops_per_core: 14.5e9,
            core_scale: [1.0, 1.0, 0.75, 0.75, 0.45, 0.40, 0.35, 0.30],
            acc_flops: 6.0e12, // Mali-G76 via OpenCL
            acc_utilization: 0.15,
            acc_dispatch_s: 1.1e-3, // GL/CL queue latency
            mem_bw: 34.1e9, // LPDDR4X
            ram_bytes: 8 * (1 << 30),
            os_reserved: 4 * (1 << 30) + (1 << 29),
            nnapi: false,
            p_core_w: 1.7,
            p_acc_w: 3.1,
            p_idle_w: 0.70,
            lanes: vec![AccLane {
                // The Kirin 980's NPU is not NNAPI-accessible and the
                // OpenCL GL/CL queue is not runtime-drivable either in
                // our delegate model: the lane exists for modelling but
                // placement must never target it (reachable = false
                // folds the `nnapi` flag).
                name: "gpu-cl",
                flops: 6.0e12,
                utilization: 0.15,
                dispatch_s: 1.1e-3,
                mem_bw: 34.1e9,
                power_w: 3.1,
                reachable: false,
                remote: false,
            }],
        }
    }

    /// Redmi K50 — Dimensity 8100: 4×A78@2.85GHz + 4×A55, MDLA/DSP/GPU.
    pub fn redmi_k50() -> Self {
        Self {
            name: "redmik50",
            cpu_cores: 8,
            cpu_flops_per_core: 18.5e9,
            core_scale: [1.0, 1.0, 1.0, 1.0, 0.50, 0.45, 0.40, 0.35],
            acc_flops: 12.0e12, // MDLA 3.0
            acc_utilization: 0.20,
            acc_dispatch_s: 0.35e-3,
            mem_bw: 51.2e9, // LPDDR5
            ram_bytes: 8 * (1 << 30),
            os_reserved: 3 * (1 << 30) + (1 << 29),
            nnapi: true,
            p_core_w: 1.5,
            p_acc_w: 2.0,
            p_idle_w: 0.60,
            lanes: vec![
                AccLane {
                    name: "mdla",
                    flops: 12.0e12,
                    utilization: 0.20,
                    dispatch_s: 0.35e-3,
                    mem_bw: 51.2e9,
                    power_w: 2.0,
                    reachable: true,
                    remote: false,
                },
                AccLane {
                    // Mali-G610 GPU delegate as the second queue.
                    name: "gpu",
                    flops: 2.6e12,
                    utilization: 0.22,
                    dispatch_s: 0.60e-3,
                    mem_bw: 51.2e9,
                    power_w: 1.4,
                    reachable: true,
                    remote: false,
                },
            ],
        }
    }

    pub const ALL: [fn() -> SocProfile; 3] =
        [Self::pixel6, Self::p30_pro, Self::redmi_k50];

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "pixel6" => Some(Self::pixel6()),
            "p30pro" => Some(Self::p30_pro()),
            "redmik50" => Some(Self::redmi_k50()),
            _ => None,
        }
    }

    /// Paper's display name.
    pub fn display_name(&self) -> &'static str {
        match self.name {
            "pixel6" => "Google Pixel 6",
            "p30pro" => "Huawei P30 Pro",
            "redmik50" => "Redmi K50",
            _ => self.name,
        }
    }

    /// Aggregate CPU rate with `k` threads busy (big cores first):
    /// Σ_{i<k} cpu_flops_per_core * core_scale[i].
    pub fn cpu_rate(&self, k: usize) -> f64 {
        let k = k.clamp(1, self.cpu_cores);
        self.core_scale[..k]
            .iter()
            .map(|s| self.cpu_flops_per_core * s)
            .sum()
    }

    /// Effective intra-op parallel speedup for one operator spread over
    /// `threads` cores: heavy ops scale sub-linearly (sync + memory
    /// bound), tiny ops not at all.
    pub fn intra_op_speedup(&self, flops: u64, threads: usize) -> f64 {
        if threads <= 1 {
            return 1.0;
        }
        let ideal = self.cpu_rate(threads) / self.cpu_rate(1);
        // efficiency falls off for small ops: below ~2 MFLOP a kernel
        // can't amortise the fork/join.
        let eff = (flops as f64 / 2.0e6).min(1.0).max(0.0);
        1.0 + (ideal - 1.0) * eff
    }

    /// OS free-memory query (§3.3: "continuously queries the operating
    /// system for available free memory") with load jitter.
    pub fn query_free_memory(&self, rng: &mut Rng) -> u64 {
        let base = self.ram_bytes - self.os_reserved;
        let jitter = (base as f64 * 0.08 * (rng.f64() - 0.5)) as i64;
        (base as i64 + jitter).max(1 << 28) as u64
    }

    /// The lanes the runtime can actually drive, with their indices —
    /// what the multi-lane placement (`crate::place`) iterates.
    pub fn available_lanes(&self) -> impl Iterator<Item = (usize, &AccLane)> {
        self.lanes.iter().enumerate().filter(|(_, l)| l.reachable)
    }
}

/// One throttling step of a [`ThermalModel`]: once a lane's accumulated
/// busy time crosses `busy_s` seconds, its sustained compute rate is
/// multiplied by `rate_factor` (< 1.0 for throttling).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ThermalStep {
    /// Accumulated lane busy-time threshold, seconds.
    pub busy_s: f64,
    /// Multiplicative rate degradation applied once the threshold is
    /// crossed (0 < factor ≤ 1).
    pub rate_factor: f64,
}

/// Deterministic thermal-throttling model: lane rates degrade as
/// accumulated per-lane busy time crosses the step thresholds.  The
/// model is a stand-in for a SoC's thermal governor — sustained
/// accelerator load heats the die and the firmware caps the clocks.
/// Every lane shares the same step table but is throttled by *its own*
/// accumulated busy time, so an idle lane stays at full rate while a
/// saturated one degrades.
///
/// The segmented engine
/// ([`SegmentedEngine::with_thermal`](crate::ctrl::SegmentedEngine::with_thermal))
/// tracks per-lane busy time across a decode/serve stream, derives the
/// throttled profile via [`ThermalModel::throttled`], and re-places
/// mid-stream when any lane's effective rate drifts past a tolerance.
///
/// ```
/// use parallax::device::{ThermalModel, ThermalStep};
/// let tm = ThermalModel::new(vec![ThermalStep { busy_s: 1.0, rate_factor: 0.5 }]);
/// assert_eq!(tm.rate_factor(0.5), 1.0);
/// assert_eq!(tm.rate_factor(2.0), 0.5);
/// ```
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ThermalModel {
    /// Throttling thresholds; evaluation takes the minimum factor over
    /// all crossed steps, so step order does not matter.
    pub steps: Vec<ThermalStep>,
}

impl ThermalModel {
    pub fn new(steps: Vec<ThermalStep>) -> Self {
        Self { steps }
    }

    /// A model that never throttles.
    pub fn none() -> Self {
        Self { steps: Vec::new() }
    }

    /// The multiplicative rate factor for a lane that has accumulated
    /// `busy_s` seconds of busy time: the minimum factor over every
    /// crossed step, 1.0 while no threshold is crossed.
    pub fn rate_factor(&self, busy_s: f64) -> f64 {
        self.steps
            .iter()
            .filter(|s| busy_s >= s.busy_s)
            .map(|s| s.rate_factor)
            .fold(1.0, f64::min)
    }

    /// The SoC profile with every lane's compute rate degraded by its
    /// own accumulated busy time (`lane_busy_s[l]`; missing entries
    /// count as idle).  The scalar `acc_flops` compatibility mirror is
    /// kept in lock-step with `lanes[0]`.
    pub fn throttled(&self, base: &SocProfile, lane_busy_s: &[f64]) -> SocProfile {
        let mut soc = base.clone();
        for (l, lane) in soc.lanes.iter_mut().enumerate() {
            let busy = lane_busy_s.get(l).copied().unwrap_or(0.0);
            lane.flops = base.lanes[l].flops * self.rate_factor(busy);
        }
        if let Some(l0) = soc.lanes.first() {
            soc.acc_flops = l0.flops;
        }
        soc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_resolve_by_name() {
        for f in SocProfile::ALL {
            let p = f();
            assert_eq!(SocProfile::by_name(p.name).unwrap().name, p.name);
        }
        assert!(SocProfile::by_name("iphone").is_none());
    }

    #[test]
    fn cpu_rate_monotone_in_threads() {
        let p = SocProfile::pixel6();
        let mut prev = 0.0;
        for k in 1..=8 {
            let r = p.cpu_rate(k);
            assert!(r > prev);
            prev = r;
        }
    }

    #[test]
    fn intra_op_speedup_bounds() {
        let p = SocProfile::pixel6();
        // tiny op: no speedup
        assert!((p.intra_op_speedup(1_000, 6) - 1.0).abs() < 0.05);
        // huge op: meaningful but sub-linear speedup
        let s = p.intra_op_speedup(1_000_000_000, 6);
        assert!(s > 1.8 && s < 6.0, "speedup {s}");
        // single thread: exactly 1
        assert_eq!(p.intra_op_speedup(1_000_000_000, 1), 1.0);
    }

    #[test]
    fn free_memory_within_physical() {
        let p = SocProfile::pixel6();
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let f = p.query_free_memory(&mut rng);
            assert!(f < p.ram_bytes);
            assert!(f > (1 << 28));
        }
    }

    #[test]
    fn p30_has_no_nnapi() {
        assert!(!SocProfile::p30_pro().nnapi);
        assert!(SocProfile::pixel6().nnapi);
    }

    #[test]
    fn lane_zero_mirrors_scalar_view() {
        // the scalar acc_* fields are the one-lane compatibility view:
        // they must stay in lock-step with lanes[0], nnapi included
        for f in SocProfile::ALL {
            let p = f();
            assert!(!p.lanes.is_empty(), "{}: no lanes", p.name);
            let l0 = &p.lanes[0];
            assert_eq!(l0.flops, p.acc_flops, "{}", p.name);
            assert_eq!(l0.utilization, p.acc_utilization, "{}", p.name);
            assert_eq!(l0.dispatch_s, p.acc_dispatch_s, "{}", p.name);
            assert_eq!(l0.mem_bw, p.mem_bw, "{}", p.name);
            assert_eq!(l0.power_w, p.p_acc_w, "{}", p.name);
            assert_eq!(l0.reachable, p.nnapi, "{}: nnapi folds into lane 0", p.name);
        }
    }

    #[test]
    fn thermal_model_degrades_only_crossed_lanes() {
        let tm = ThermalModel::new(vec![
            ThermalStep { busy_s: 1.0, rate_factor: 0.6 },
            ThermalStep { busy_s: 2.0, rate_factor: 0.3 },
        ]);
        assert_eq!(tm.rate_factor(0.0), 1.0);
        assert_eq!(tm.rate_factor(1.5), 0.6);
        assert_eq!(tm.rate_factor(5.0), 0.3, "deepest crossed step wins");
        let base = SocProfile::pixel6();
        // lane 0 hot, lane 1 idle
        let hot = tm.throttled(&base, &[1.5, 0.0]);
        assert_eq!(hot.lanes[0].flops, base.lanes[0].flops * 0.6);
        assert_eq!(hot.lanes[1].flops, base.lanes[1].flops);
        assert_eq!(hot.acc_flops, hot.lanes[0].flops, "scalar mirror follows lane 0");
        // busy vector shorter than the lane list: missing lanes idle
        let short = tm.throttled(&base, &[3.0]);
        assert_eq!(short.lanes[0].flops, base.lanes[0].flops * 0.3);
        assert_eq!(short.lanes[1].flops, base.lanes[1].flops);
        // a no-step model never throttles
        assert_eq!(ThermalModel::none().rate_factor(1e9), 1.0);
    }

    #[test]
    fn lane_availability_follows_reachability() {
        let pixel = SocProfile::pixel6();
        assert_eq!(pixel.available_lanes().count(), 2, "pixel6 is a 2-lane device");
        let p30 = SocProfile::p30_pro();
        assert_eq!(
            p30.available_lanes().count(),
            0,
            "p30's accelerator is runtime-unreachable"
        );
        let redmi = SocProfile::redmi_k50();
        assert_eq!(redmi.available_lanes().count(), 2);
        for (i, lane) in pixel.available_lanes() {
            assert!(lane.effective_flops() > 0.0, "lane {i}");
        }
    }
}
