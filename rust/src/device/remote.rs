//! Device–edge spill tier: the remote lane and its link-fault model.
//!
//! Intra-DP-style device–edge splitting (PAPERS.md) modelled as one
//! more [`AccLane`]: a [`RemoteLane`] is an edge server reached over a
//! wireless link, whose Appendix-B terms are the *uplink latency*
//! (lane dispatch), the *link bandwidth* (lane transfer bandwidth) and
//! the *server-side rate* (lane compute rate).  Because the shape is
//! identical, `place::lane_delegate_latency` prices it with the same
//! closed form as an on-die lane, and the executor runs its jobs
//! through the same persistent lane-worker threads — the edge server
//! executes the same host kernels, so remote outputs are bit-identical
//! to CPU-forced runs by construction (ARCHITECTURE.md §Device–edge
//! tier lifecycle).
//!
//! What an on-die lane does not have is an unreliable interconnect:
//! [`LinkModel`] is a deterministic, seeded fault model evaluated per
//! transfer index — multiplicative jitter on the modelled transfer
//! time, i.i.d. drop probability, and periodic partition (outage)
//! windows.  It is *stateless per index*, so fault outcomes depend
//! only on `(seed, transfer index)` and never on thread timing:
//! injected faults replay bit-identically (`rust/tests/remote.rs`).

use super::{AccLane, SocProfile};

/// An edge server reached over a wireless/LAN link, expressed in the
/// same Appendix-B terms as an on-die accelerator lane.
#[derive(Clone, Debug)]
pub struct RemoteLane {
    /// Lane name for tables ("edge", "wifi-server", ...).
    pub name: &'static str,
    /// One-way uplink latency per delegate invocation, seconds — the
    /// remote analogue of [`AccLane::dispatch_s`].
    pub uplink_latency_s: f64,
    /// Link bandwidth, bytes/s — the remote analogue of
    /// [`AccLane::mem_bw`]; boundary tensors cross this instead of the
    /// on-die interconnect.
    pub link_bw: f64,
    /// Server-side peak compute rate, FLOP/s.
    pub server_flops: f64,
    /// Sustained fraction of server peak the offloaded regions reach.
    pub server_utilization: f64,
    /// Device-side radio/NIC active power while transfers and remote
    /// compute are in flight, watts (the *device* pays this, not the
    /// server).
    pub power_w: f64,
}

impl RemoteLane {
    /// A Wi-Fi-class edge server: ~4 ms uplink, ~40 MB/s link, an
    /// order of magnitude more sustained compute than the device TPU.
    pub fn edge_server() -> Self {
        Self {
            name: "edge",
            uplink_latency_s: 4.0e-3,
            link_bw: 40.0e6,
            server_flops: 60.0e12,
            server_utilization: 0.35,
            power_w: 0.9,
        }
    }

    /// The lane view placement prices: uplink latency as dispatch,
    /// link bandwidth as transfer bandwidth, server rate as compute.
    pub fn to_acc_lane(&self) -> AccLane {
        AccLane {
            name: self.name,
            flops: self.server_flops,
            utilization: self.server_utilization,
            dispatch_s: self.uplink_latency_s,
            mem_bw: self.link_bw,
            power_w: self.power_w,
            reachable: true,
            remote: true,
        }
    }
}

/// Deterministic, seeded link-fault model for a [`RemoteLane`].
///
/// Evaluated per *transfer index* (the dispatcher numbers remote
/// transfers in dispatch order, which is schedule order and therefore
/// deterministic): each index hashes with the seed into a jitter
/// factor and a drop verdict, and periodic partition windows of
/// `partition_len` indices every `partition_every` model link outages.
/// Statelessness per index is the whole point — outcomes never depend
/// on wall-clock timing or thread interleaving, so a faulty run
/// replays bit-identically from the same seed.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkModel {
    /// Fault-schedule seed.
    pub seed: u64,
    /// Multiplicative jitter amplitude on the modelled transfer time:
    /// each transfer's time scales by a factor in
    /// `[1 - jitter_frac, 1 + jitter_frac]`.
    pub jitter_frac: f64,
    /// I.i.d. per-transfer drop probability in `[0, 1]`.
    pub drop_p: f64,
    /// Partition-schedule period in transfer indices; 0 disables
    /// partition windows.
    pub partition_every: u64,
    /// Transfers dropped at the start of each period (the outage
    /// window length); must be < `partition_every` when enabled.
    pub partition_len: u64,
}

impl LinkModel {
    /// A fault-free link (jitter and drops all zero) — remote runs
    /// behave like one more on-die lane.
    pub fn reliable(seed: u64) -> Self {
        Self { seed, jitter_frac: 0.0, drop_p: 0.0, partition_every: 0, partition_len: 0 }
    }

    /// A link with i.i.d. drops at probability `drop_p` and mild
    /// (±10%) transfer jitter.
    pub fn lossy(seed: u64, drop_p: f64) -> Self {
        Self { seed, jitter_frac: 0.10, drop_p, partition_every: 0, partition_len: 0 }
    }

    /// SplitMix64-style hash of `(seed, idx)` — one u64 per transfer.
    fn mix(&self, idx: u64) -> u64 {
        let mut z = self
            .seed
            .wrapping_add(idx.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform draw in [0, 1) for transfer `idx`.
    fn unit(&self, idx: u64) -> f64 {
        (self.mix(idx) >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Multiplicative jitter factor for transfer `idx`, in
    /// `[1 - jitter_frac, 1 + jitter_frac]`.
    pub fn jitter(&self, idx: u64) -> f64 {
        1.0 + self.jitter_frac * (2.0 * self.unit(idx) - 1.0)
    }

    /// Whether transfer `idx` is dropped — inside a partition window,
    /// or by the i.i.d. drop draw.  A dropped transfer is retried once
    /// at the *next* index; a second drop is a persistent fault and
    /// the job falls back to the bit-identical CPU path (never a
    /// silent drop).
    pub fn dropped(&self, idx: u64) -> bool {
        if self.partition_every > 0 && idx % self.partition_every < self.partition_len {
            return true;
        }
        // decorrelate the drop draw from the jitter draw
        self.drop_p > 0.0 && self.unit(idx ^ 0x5DEE_CE66) < self.drop_p
    }
}

impl SocProfile {
    /// This profile with `remote` appended as one more lane — the
    /// device–edge spill tier.  Stock profiles never carry a remote
    /// lane (their lane counts are test-pinned); opting in is always
    /// explicit.  The returned lane's index is `lanes.len() - 1`, also
    /// exposed as [`SocProfile::remote_lane`].
    pub fn with_remote(&self, remote: &RemoteLane) -> SocProfile {
        let mut soc = self.clone();
        soc.lanes.push(remote.to_acc_lane());
        soc
    }

    /// Index of this profile's remote lane, if one was attached via
    /// [`SocProfile::with_remote`].
    pub fn remote_lane(&self) -> Option<usize> {
        self.lanes.iter().position(|l| l.remote)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn remote_lane_maps_link_terms_onto_acc_lane() {
        let r = RemoteLane::edge_server();
        let lane = r.to_acc_lane();
        assert!(lane.remote && lane.reachable);
        assert_eq!(lane.dispatch_s, r.uplink_latency_s);
        assert_eq!(lane.mem_bw, r.link_bw);
        assert_eq!(lane.effective_flops(), r.server_flops * r.server_utilization);
    }

    #[test]
    fn with_remote_appends_without_touching_stock_lanes() {
        let base = SocProfile::pixel6();
        let soc = base.with_remote(&RemoteLane::edge_server());
        assert_eq!(soc.lanes.len(), base.lanes.len() + 1);
        assert_eq!(soc.remote_lane(), Some(base.lanes.len()));
        assert_eq!(base.remote_lane(), None, "stock profiles carry no remote lane");
        // the scalar compatibility mirror still tracks lanes[0]
        assert_eq!(soc.acc_flops, soc.lanes[0].flops);
        assert_eq!(soc.available_lanes().count(), base.available_lanes().count() + 1);
    }

    #[test]
    fn link_model_is_deterministic_per_index() {
        let a = LinkModel::lossy(42, 0.3);
        let b = LinkModel::lossy(42, 0.3);
        for idx in 0..256 {
            assert_eq!(a.dropped(idx), b.dropped(idx));
            assert_eq!(a.jitter(idx).to_bits(), b.jitter(idx).to_bits());
        }
        let c = LinkModel::lossy(43, 0.3);
        assert!((0..256).any(|i| a.dropped(i) != c.dropped(i)), "seed must matter");
    }

    #[test]
    fn reliable_link_never_drops_or_jitters() {
        let l = LinkModel::reliable(7);
        for idx in 0..512 {
            assert!(!l.dropped(idx));
            assert_eq!(l.jitter(idx), 1.0);
        }
    }

    #[test]
    fn partition_windows_drop_exactly_the_scheduled_indices() {
        let l = LinkModel {
            seed: 1,
            jitter_frac: 0.0,
            drop_p: 0.0,
            partition_every: 8,
            partition_len: 3,
        };
        for idx in 0..64u64 {
            assert_eq!(l.dropped(idx), idx % 8 < 3, "idx {idx}");
        }
    }

    #[test]
    fn lossy_drop_rate_tracks_probability() {
        let l = LinkModel::lossy(99, 0.25);
        let n = 4096u64;
        let drops = (0..n).filter(|&i| l.dropped(i)).count() as f64 / n as f64;
        assert!((drops - 0.25).abs() < 0.05, "empirical drop rate {drops}");
    }
}
