//! Host tensor: the coordinator's unit of data on the request path.
//!
//! Deliberately minimal — dense f32, row-major — because Parallax's
//! contribution is scheduling, not a tensor library.  Conversions to and
//! from `xla::Literal` live in the worker.

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build from shape + data; panics if the element count mismatches.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} != data len {}", data.len());
        Self { shape, data }
    }

    /// All-zeros tensor of the given shape.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    /// Deterministic pseudo-random tensor (xorshift on the seed) —
    /// used for synthetic weights/inputs in examples and benches.
    pub fn randn(shape: Vec<usize>, seed: u64) -> Self {
        let n: usize = shape.iter().product();
        let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1);
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            // xorshift64* then map to ~N(0,1) via sum of uniforms (CLT-ish)
            let mut acc = 0.0f32;
            for _ in 0..4 {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                let u = (s >> 11) as f32 / (1u64 << 53) as f32;
                acc += u;
            }
            data.push((acc - 2.0) * 1.732_050_8);
        }
        Self { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Size in bytes (f32).
    pub fn byte_size(&self) -> usize {
        self.data.len() * 4
    }

    pub fn into_parts(self) -> (Vec<usize>, Vec<f32>) {
        (self.shape, self.data)
    }

    /// Max |a - b| against another tensor; panics on shape mismatch.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_len() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.byte_size(), 24);
    }

    #[test]
    #[should_panic]
    fn new_rejects_bad_len() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn randn_is_deterministic() {
        let a = Tensor::randn(vec![16], 7);
        let b = Tensor::randn(vec![16], 7);
        assert_eq!(a, b);
        let c = Tensor::randn(vec![16], 8);
        assert_ne!(a, c);
    }

    #[test]
    fn randn_roughly_centered() {
        let t = Tensor::randn(vec![4096], 1);
        let mean: f32 = t.data().iter().sum::<f32>() / 4096.0;
        assert!(mean.abs() < 0.2, "mean {mean}");
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Tensor::new(vec![2], vec![1.0, 2.0]);
        let b = Tensor::new(vec![2], vec![1.5, 2.0]);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-6);
    }
}
