//! API-compatible stand-in for the PJRT worker when the crate is built
//! without the `pjrt` feature (the offline default).
//!
//! Construction paths fail with a clear message instead of at link
//! time, so the rest of the stack — simulator, scheduler, governor,
//! serving front-end, host-kernel engine — builds and runs unchanged.
//! `RuntimePool::new` still parses the manifest first, so "artifacts
//! missing" and "backend missing" stay distinguishable errors.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::{Manifest, Tensor};

const NO_BACKEND: &str =
    "PJRT backend not compiled in: uncomment the `xla` dependency in Cargo.toml (needs \
     network access), then build with `--features pjrt`";

/// Handle to a single PJRT worker thread (stub: cannot be spawned).
pub struct PjrtWorker {
    submitted: Arc<AtomicUsize>,
}

/// Cloneable, `Send` client to one worker (stub: every call errors).
#[derive(Clone)]
pub struct WorkerClient {
    submitted: Arc<AtomicUsize>,
}

impl WorkerClient {
    /// Execute `program` with `inputs`; always reports the missing
    /// backend in this build.
    pub fn execute(&self, program: &str, inputs: Vec<Tensor>) -> anyhow::Result<Vec<Tensor>> {
        let _ = (program, inputs);
        self.submitted.fetch_add(1, Ordering::Relaxed);
        anyhow::bail!(NO_BACKEND)
    }
}

impl PjrtWorker {
    /// Spawning always fails in a `pjrt`-less build.
    pub fn spawn(manifest: Manifest) -> anyhow::Result<Self> {
        let _ = manifest;
        anyhow::bail!(NO_BACKEND)
    }

    /// Execute `program` with `inputs` (stub: errors).
    pub fn execute(&self, program: &str, inputs: Vec<Tensor>) -> anyhow::Result<Vec<Tensor>> {
        self.client().execute(program, inputs)
    }

    /// Compile a program ahead of time (stub: errors).
    pub fn warm(&self, program: &str) -> anyhow::Result<()> {
        let _ = program;
        anyhow::bail!(NO_BACKEND)
    }

    /// Requests submitted so far.
    pub fn submitted(&self) -> usize {
        self.submitted.load(Ordering::Relaxed)
    }

    /// A cloneable `Send` client for cross-thread submission.
    pub fn client(&self) -> WorkerClient {
        WorkerClient { submitted: self.submitted.clone() }
    }
}

/// Pool of PJRT workers (stub: construction fails after the manifest
/// parses, mirroring the real pool's error order).
pub struct RuntimePool {
    workers: Vec<PjrtWorker>,
    manifest: Manifest,
}

/// Cheap handle onto one worker slot of the pool.
pub struct WorkerHandle<'a> {
    pub(crate) worker: &'a PjrtWorker,
}

impl RuntimePool {
    /// Spawn `n` workers over the artifacts in `dir` — in this build,
    /// parse the manifest and then report the missing backend.
    pub fn new(dir: impl AsRef<std::path::Path>, n: usize) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let _ = (n, manifest);
        anyhow::bail!(NO_BACKEND)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Pick a worker (stub pools are never constructed, so this is
    /// unreachable in practice).
    pub fn worker(&self) -> WorkerHandle<'_> {
        WorkerHandle { worker: &self.workers[0] }
    }

    /// Cloneable client (see [`RuntimePool::worker`]).
    pub fn client(&self) -> WorkerClient {
        self.workers[0].client()
    }

    /// Execute on the next worker.
    pub fn execute(&self, program: &str, inputs: Vec<Tensor>) -> anyhow::Result<Vec<Tensor>> {
        self.worker().worker.execute(program, inputs)
    }

    /// Pre-compile the given programs across all workers.
    pub fn warm(&self, programs: &[&str]) -> anyhow::Result<()> {
        for w in &self.workers {
            for p in programs {
                w.warm(p)?;
            }
        }
        Ok(())
    }
}

impl WorkerHandle<'_> {
    pub fn execute(&self, program: &str, inputs: Vec<Tensor>) -> anyhow::Result<Vec<Tensor>> {
        self.worker.execute(program, inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawn_reports_missing_backend() {
        let dir = std::env::temp_dir().join("plx_stub_worker_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"[{"name":"m","file":"m.hlo.txt","inputs":[[1]],"outputs":[[1]],"flops":1}]"#,
        )
        .unwrap();
        let err = RuntimePool::new(&dir, 1).unwrap_err().to_string();
        assert!(err.contains("pjrt"), "unexpected error: {err}");
    }

    #[test]
    fn missing_manifest_still_reported_first() {
        let err = RuntimePool::new("/nonexistent/plx_stub", 1).unwrap_err().to_string();
        assert!(!err.contains("pjrt"), "manifest error should win: {err}");
    }
}
