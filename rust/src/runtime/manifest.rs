//! `artifacts/manifest.json` — the contract between `python/compile`
//! (build time) and the Rust engine (run time).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::util::json::Json;

/// Signature of one AOT-lowered branch program.
#[derive(Clone, Debug)]
pub struct ProgramSpec {
    /// Stable program identifier, e.g. `ffn_77x512x2048`.
    pub name: String,
    /// HLO text file name, relative to the artifact dir.
    pub file: String,
    /// Input shapes, in argument order (f32).
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes (programs return tuples).
    pub outputs: Vec<Vec<usize>>,
    /// Analytic FLOP count from the L2 registry — used to cross-check
    /// the L3 FLOP estimator against what is actually executed.
    pub flops: u64,
}

impl ProgramSpec {
    /// Total bytes of all inputs (f32).
    pub fn input_bytes(&self) -> usize {
        self.inputs.iter().map(|s| s.iter().product::<usize>() * 4).sum()
    }

    /// Total bytes of all outputs (f32).
    pub fn output_bytes(&self) -> usize {
        self.outputs.iter().map(|s| s.iter().product::<usize>() * 4).sum()
    }

    fn from_json(j: &Json) -> anyhow::Result<Self> {
        let shapes = |key: &str| -> anyhow::Result<Vec<Vec<usize>>> {
            j.get(key)
                .and_then(Json::as_arr)
                .context("missing shape list")?
                .iter()
                .map(|s| {
                    s.as_arr()
                        .context("shape not an array")?
                        .iter()
                        .map(|d| d.as_usize().context("dim not a number"))
                        .collect()
                })
                .collect()
        };
        Ok(Self {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .context("missing name")?
                .to_string(),
            file: j
                .get("file")
                .and_then(Json::as_str)
                .context("missing file")?
                .to_string(),
            inputs: shapes("inputs")?,
            outputs: shapes("outputs")?,
            flops: j.get("flops").and_then(Json::as_u64).unwrap_or(0),
        })
    }
}

/// Parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    dir: PathBuf,
    programs: HashMap<String, ProgramSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let raw = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`?)"))?;
        let json = Json::parse(&raw).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        let list = json.as_arr().context("manifest must be a JSON array")?;
        let mut programs = HashMap::new();
        for item in list {
            let spec = ProgramSpec::from_json(item)?;
            programs.insert(spec.name.clone(), spec);
        }
        Ok(Self { dir, programs })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn get(&self, name: &str) -> Option<&ProgramSpec> {
        self.programs.get(name)
    }

    pub fn contains(&self, name: &str) -> bool {
        self.programs.contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.programs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.programs.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.programs.keys().map(|s| s.as_str())
    }

    /// Absolute path of a program's HLO file.
    pub fn hlo_path(&self, name: &str) -> Option<PathBuf> {
        self.get(name).map(|p| self.dir.join(&p.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest(dir: &Path) {
        std::fs::write(
            dir.join("manifest.json"),
            r#"[{"name":"m","file":"m.hlo.txt","inputs":[[2,3],[3]],"outputs":[[2,3]],"flops":36}]"#,
        )
        .unwrap();
    }

    #[test]
    fn loads_and_indexes() {
        let dir = std::env::temp_dir().join("plx_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.len(), 1);
        let p = m.get("m").unwrap();
        assert_eq!(p.input_bytes(), (6 + 3) * 4);
        assert_eq!(p.output_bytes(), 24);
        assert!(m.hlo_path("m").unwrap().ends_with("m.hlo.txt"));
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(Manifest::load("/nonexistent/plx").is_err());
    }
}
