//! L3 runtime: load AOT artifacts and execute them via PJRT.
//!
//! The build pipeline (`make artifacts`) lowers every L2 branch program
//! to HLO *text* under `artifacts/` plus a `manifest.json`.  This module
//! is the only place in the crate that touches the `xla` crate:
//!
//! * [`Manifest`] — parsed `manifest.json`, program signatures.
//! * [`PjrtWorker`] — a dedicated OS thread owning a `PjRtClient` (the
//!   crate's client is `Rc`-based and not `Send`, so it can never cross
//!   threads) with a lazily-populated executable cache.  Callers talk to
//!   it through an mpsc request channel and get results on a per-request
//!   reply channel.
//! * [`RuntimePool`] — N workers (N = real parallel lanes for branch
//!   execution) with round-robin dispatch.
//!
//! Python never runs on this path: after `make artifacts` the binary is
//! self-contained.
//!
//! The `xla` crate is only reachable on a networked build, so the PJRT
//! path sits behind the `pjrt` cargo feature.  The default (offline)
//! build substitutes an API-compatible stub whose constructors report
//! the missing backend; integration tests gate themselves on
//! [`artifacts_available`] and skip cleanly.

mod manifest;
mod tensor;
#[cfg(feature = "pjrt")]
mod worker;
#[cfg(not(feature = "pjrt"))]
#[path = "worker_stub.rs"]
mod worker;

pub use manifest::{Manifest, ProgramSpec};
pub use tensor::Tensor;
pub use worker::{PjrtWorker, RuntimePool, WorkerClient, WorkerHandle};

/// Default artifact directory, resolved relative to the crate root so
/// tests and examples work from any CWD.
pub fn default_artifact_dir() -> std::path::PathBuf {
    let mut p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    p.push("artifacts");
    p
}

/// True when AOT artifacts have been built (used to gate integration
/// tests so `cargo test` passes on a tree without `make artifacts`).
pub fn artifacts_available() -> bool {
    default_artifact_dir().join("manifest.json").exists()
}
