//! PJRT worker threads and the runtime pool.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so each
//! worker owns its client on a dedicated OS thread and callers submit
//! [`ExecRequest`]s over an mpsc channel.  Executables are compiled on
//! first use and cached for the lifetime of the worker — compilation is
//! the expensive step (tens of ms), execution is the hot path.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

use super::{Manifest, Tensor};

/// A request to run one AOT program with concrete inputs.
struct ExecRequest {
    program: String,
    inputs: Vec<Tensor>,
    reply: mpsc::Sender<anyhow::Result<Vec<Tensor>>>,
}

enum Msg {
    Exec(ExecRequest),
    /// Compile (warm the cache for) a program without running it.
    Warm(String, mpsc::Sender<anyhow::Result<()>>),
    Shutdown,
}

/// Handle to a single PJRT worker thread.
pub struct PjrtWorker {
    tx: mpsc::Sender<Msg>,
    join: Option<std::thread::JoinHandle<()>>,
    /// Number of in-flight + completed requests (observability).
    submitted: Arc<AtomicUsize>,
}

/// Cloneable, `Send` client to one worker — what engine threads carry
/// into scoped parallel branch execution.
#[derive(Clone)]
pub struct WorkerClient {
    tx: mpsc::Sender<Msg>,
    submitted: Arc<AtomicUsize>,
}

impl WorkerClient {
    /// Execute `program` with `inputs`; blocks until the result arrives.
    pub fn execute(&self, program: &str, inputs: Vec<Tensor>) -> anyhow::Result<Vec<Tensor>> {
        let (reply, rx) = mpsc::channel();
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Msg::Exec(ExecRequest { program: program.to_string(), inputs, reply }))
            .map_err(|_| anyhow::anyhow!("worker channel closed"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("worker dropped reply"))?
    }
}

impl PjrtWorker {
    /// Spawn a worker owning its own `PjRtClient::cpu()`.
    pub fn spawn(manifest: Manifest) -> anyhow::Result<Self> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-worker".into())
            .spawn(move || worker_main(manifest, rx, ready_tx))?;
        // Surface client-creation failures at spawn time.
        ready_rx.recv().map_err(|_| anyhow::anyhow!("worker died during init"))??;
        Ok(Self { tx, join: Some(join), submitted: Arc::new(AtomicUsize::new(0)) })
    }

    /// Execute `program` with `inputs`; blocks until the result is ready.
    pub fn execute(&self, program: &str, inputs: Vec<Tensor>) -> anyhow::Result<Vec<Tensor>> {
        let (reply, rx) = mpsc::channel();
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Msg::Exec(ExecRequest { program: program.to_string(), inputs, reply }))
            .map_err(|_| anyhow::anyhow!("worker channel closed"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("worker dropped reply"))?
    }

    /// Compile a program ahead of time so the first execute is fast.
    pub fn warm(&self, program: &str) -> anyhow::Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Warm(program.to_string(), reply))
            .map_err(|_| anyhow::anyhow!("worker channel closed"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("worker dropped reply"))?
    }

    /// Requests submitted so far.
    pub fn submitted(&self) -> usize {
        self.submitted.load(Ordering::Relaxed)
    }

    /// A cloneable `Send` client for cross-thread submission.
    pub fn client(&self) -> WorkerClient {
        WorkerClient { tx: self.tx.clone(), submitted: self.submitted.clone() }
    }
}

impl Drop for PjrtWorker {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn worker_main(
    manifest: Manifest,
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<anyhow::Result<()>>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow::anyhow!("PjRtClient::cpu failed: {e}")));
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Shutdown => break,
            Msg::Warm(name, reply) => {
                let r = ensure_compiled(&client, &manifest, &mut cache, &name).map(|_| ());
                let _ = reply.send(r);
            }
            Msg::Exec(req) => {
                let result = run_one(&client, &manifest, &mut cache, &req.program, &req.inputs);
                let _ = req.reply.send(result);
            }
        }
    }
}

fn ensure_compiled<'a>(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    cache: &'a mut HashMap<String, xla::PjRtLoadedExecutable>,
    name: &str,
) -> anyhow::Result<&'a xla::PjRtLoadedExecutable> {
    if !cache.contains_key(name) {
        let path = manifest
            .hlo_path(name)
            .ok_or_else(|| anyhow::anyhow!("program {name} not in manifest"))?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))?;
        cache.insert(name.to_string(), exe);
    }
    Ok(cache.get(name).unwrap())
}

fn run_one(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    name: &str,
    inputs: &[Tensor],
) -> anyhow::Result<Vec<Tensor>> {
    let spec = manifest
        .get(name)
        .ok_or_else(|| anyhow::anyhow!("program {name} not in manifest"))?
        .clone();
    if inputs.len() != spec.inputs.len() {
        anyhow::bail!(
            "{name}: expected {} inputs, got {}",
            spec.inputs.len(),
            inputs.len()
        );
    }
    for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
        if t.shape() != s.as_slice() {
            anyhow::bail!("{name}: input {i} shape {:?} != spec {:?}", t.shape(), s);
        }
    }
    let exe = ensure_compiled(client, manifest, cache, name)?;

    let literals: Vec<xla::Literal> = inputs
        .iter()
        .map(|t| {
            let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
            xla::Literal::vec1(t.data())
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("reshape input: {e}"))
        })
        .collect::<anyhow::Result<Vec<_>>>()?;

    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow::anyhow!("execute {name}: {e}"))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?;
    // aot.py lowers with return_tuple=True, so outputs arrive as a tuple.
    let parts = lit
        .to_tuple()
        .map_err(|e| anyhow::anyhow!("untuple result: {e}"))?;
    if parts.len() != spec.outputs.len() {
        anyhow::bail!(
            "{name}: expected {} outputs, got {}",
            spec.outputs.len(),
            parts.len()
        );
    }
    parts
        .into_iter()
        .zip(&spec.outputs)
        .map(|(l, shape)| {
            let v = l
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("read output: {e}"))?;
            Ok(Tensor::new(shape.clone(), v))
        })
        .collect()
}

/// Pool of PJRT workers with round-robin dispatch.
///
/// On a many-core host each worker is a real parallel lane for branch
/// execution; the pool size is the runtime analogue of the paper's
/// "maximum parallel threads" knob (Fig. 3).
pub struct RuntimePool {
    workers: Vec<PjrtWorker>,
    next: AtomicUsize,
    manifest: Manifest,
}

/// Cheap clonable handle onto one worker slot of the pool.
pub struct WorkerHandle<'a> {
    pub(crate) worker: &'a PjrtWorker,
}

impl RuntimePool {
    /// Spawn `n` workers over the artifacts in `dir`.
    pub fn new(dir: impl AsRef<std::path::Path>, n: usize) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let workers = (0..n.max(1))
            .map(|_| PjrtWorker::spawn(manifest.clone()))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Self { workers, next: AtomicUsize::new(0), manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Round-robin pick of a worker.
    pub fn worker(&self) -> WorkerHandle<'_> {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.workers.len();
        WorkerHandle { worker: &self.workers[i] }
    }

    /// Round-robin cloneable client (for engine threads).
    pub fn client(&self) -> WorkerClient {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.workers.len();
        self.workers[i].client()
    }

    /// Execute on the next worker (round-robin).
    pub fn execute(&self, program: &str, inputs: Vec<Tensor>) -> anyhow::Result<Vec<Tensor>> {
        self.worker().worker.execute(program, inputs)
    }

    /// Pre-compile the given programs across all workers.
    pub fn warm(&self, programs: &[&str]) -> anyhow::Result<()> {
        for w in &self.workers {
            for p in programs {
                w.warm(p)?;
            }
        }
        Ok(())
    }
}

impl WorkerHandle<'_> {
    pub fn execute(&self, program: &str, inputs: Vec<Tensor>) -> anyhow::Result<Vec<Tensor>> {
        self.worker.execute(program, inputs)
    }
}
