//! Small self-contained utilities.
//!
//! The build environment is fully offline, so the crates one would
//! normally reach for (serde_json, clap, criterion, rand, proptest) are
//! unavailable.  Each submodule here is a focused, tested replacement
//! for exactly the sliver of functionality Parallax needs.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
