//! Summary statistics over latency/memory/energy samples.

/// Summary of a sample set (times in whatever unit the caller uses).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub std: f64,
}

/// Compute a [`Summary`]; returns None for an empty sample.
pub fn summarize(samples: &[f64]) -> Option<Summary> {
    if samples.is_empty() {
        return None;
    }
    let mut xs: Vec<f64> = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Some(Summary {
        n,
        min: xs[0],
        max: xs[n - 1],
        mean,
        p50: percentile_sorted(&xs, 0.50),
        p95: percentile_sorted(&xs, 0.95),
        p99: percentile_sorted(&xs, 0.99),
        std: var.sqrt(),
    })
}

/// Nearest-rank percentile over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let idx = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len());
    sorted[idx - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_none() {
        assert!(summarize(&[]).is_none());
    }

    #[test]
    fn basic_summary() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_monotone() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&xs, 0.5), 50.0);
        assert_eq!(percentile_sorted(&xs, 0.95), 95.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 100.0);
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
    }
}
