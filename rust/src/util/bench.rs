//! Micro-bench harness (offline replacement for `criterion`).
//!
//! Usage from a `harness = false` bench target:
//!
//! ```ignore
//! let mut b = Bench::new("arena_alloc");
//! b.iter("bump_alloc_1k", || { ... });
//! b.report();
//! ```
//!
//! Each case is warmed up, then timed over enough iterations to exceed
//! a minimum measurement window; mean/p50/min are reported.

use std::time::{Duration, Instant};

use super::stats;

/// One timed case.
#[derive(Debug)]
pub struct Case {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub min_ns: f64,
}

/// A named group of timed cases.
pub struct Bench {
    name: String,
    min_window: Duration,
    samples: usize,
    cases: Vec<Case>,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            min_window: Duration::from_millis(200),
            samples: 20,
            cases: Vec::new(),
        }
    }

    /// Override the measurement window (per sample batch).
    pub fn with_window(mut self, d: Duration) -> Self {
        self.min_window = d;
        self
    }

    /// Time `f`, auto-scaling the iteration count.
    pub fn iter<F: FnMut()>(&mut self, case: &str, mut f: F) {
        // Warm-up + calibration: find iters such that a batch ~ window/samples
        let mut iters = 1u64;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed();
            if dt >= self.min_window / self.samples as u32 || iters > 1 << 28 {
                break;
            }
            iters = (iters * 2).max(
                (iters as f64 * (self.min_window.as_secs_f64() / self.samples as f64)
                    / dt.as_secs_f64().max(1e-9)) as u64,
            );
        }
        let mut per_iter = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            per_iter.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        let s = stats::summarize(&per_iter).unwrap();
        self.cases.push(Case {
            name: case.to_string(),
            iters,
            mean_ns: s.mean,
            p50_ns: s.p50,
            min_ns: s.min,
        });
    }

    /// Record an externally measured value (e.g. one long end-to-end run).
    pub fn record(&mut self, case: &str, value_ns: f64) {
        self.cases.push(Case {
            name: case.to_string(),
            iters: 1,
            mean_ns: value_ns,
            p50_ns: value_ns,
            min_ns: value_ns,
        });
    }

    /// Print a criterion-style report to stdout.  When the `BENCH_JSON`
    /// env var names a file, the group is also appended to it as a JSON
    /// trajectory record (see [`Bench::append_json`]) — the mechanism
    /// behind the committed `BENCH_<n>.json` files that
    /// `tools/check_bench.py` diffs against fresh runs.
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.name);
        for c in &self.cases {
            println!(
                "{:<44} {:>12} /iter (p50 {:>12}, min {:>12})  x{}",
                c.name,
                fmt_ns(c.mean_ns),
                fmt_ns(c.p50_ns),
                fmt_ns(c.min_ns),
                c.iters
            );
        }
        if let Ok(path) = std::env::var("BENCH_JSON") {
            if !path.is_empty() {
                if let Err(e) = self.append_json(&path) {
                    eprintln!("BENCH_JSON: could not write {path}: {e}");
                }
            }
        }
    }

    /// Append this group to a JSON trajectory file: the file holds a
    /// top-level array of `{"group", "cases": [{name, iters, mean_ns,
    /// p50_ns, min_ns}]}` records.  A missing or empty file starts a
    /// new array; a record with the same group name is replaced, so
    /// re-running a bench refreshes its numbers in place.
    pub fn append_json(&self, path: &str) -> std::io::Result<()> {
        use super::json::Json;
        use std::collections::BTreeMap;
        let mut records: Vec<Json> = match std::fs::read_to_string(path) {
            Ok(src) if !src.trim().is_empty() => Json::parse(&src)
                .ok()
                .and_then(|j| j.as_arr().map(<[Json]>::to_vec))
                .unwrap_or_default(),
            _ => Vec::new(),
        };
        records.retain(|r| r.get("group").and_then(Json::as_str) != Some(&self.name));
        let cases: Vec<Json> = self
            .cases
            .iter()
            .map(|c| {
                let mut o = BTreeMap::new();
                o.insert("name".into(), Json::Str(c.name.clone()));
                o.insert("iters".into(), Json::Num(c.iters as f64));
                o.insert("mean_ns".into(), Json::Num(c.mean_ns));
                o.insert("p50_ns".into(), Json::Num(c.p50_ns));
                o.insert("min_ns".into(), Json::Num(c.min_ns));
                Json::Obj(o)
            })
            .collect();
        let mut rec = BTreeMap::new();
        rec.insert("group".into(), Json::Str(self.name.clone()));
        rec.insert("cases".into(), Json::Arr(cases));
        records.push(Json::Obj(rec));
        std::fs::write(path, Json::Arr(records).dump() + "\n")
    }

    pub fn cases(&self) -> &[Case] {
        &self.cases
    }
}

/// Render nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Prevent the optimizer from discarding a value (std black_box wrapper).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_something() {
        let mut b = Bench::new("test").with_window(Duration::from_millis(10));
        let mut acc = 0u64;
        b.iter("add", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(b.cases().len(), 1);
        assert!(b.cases()[0].mean_ns > 0.0);
    }

    #[test]
    fn append_json_replaces_same_group() {
        let path = std::env::temp_dir().join(format!("bench_json_test_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        let mut b = Bench::new("g1");
        b.record("case_a", 1000.0);
        b.append_json(&path).unwrap();
        let mut b2 = Bench::new("g1");
        b2.record("case_a", 2000.0);
        b2.record("case_b", 3000.0);
        b2.append_json(&path).unwrap();
        let j = crate::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 1, "same group replaced, not duplicated");
        let cases = arr[0].get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 2);
        assert_eq!(cases[0].get("mean_ns").unwrap().as_f64().unwrap(), 2000.0);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(12_000_000_000.0).ends_with(" s"));
    }
}
