//! Deterministic PRNG (SplitMix64) — replacement for the `rand` crate.
//!
//! Every stochastic component in Parallax (workload generators, dynamic
//! shape draws, property tests) takes an explicit seed so experiment
//! tables are exactly reproducible.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform u64 in [lo, hi).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi);
        lo + self.next_u64() % (hi - lo)
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal with the given mu/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pick a uniformly random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.range(5, 9);
            assert!((5..9).contains(&x));
        }
    }

    #[test]
    fn normal_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
