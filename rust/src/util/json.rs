//! Minimal JSON parser/serializer — just enough for
//! `artifacts/manifest.json` and the bench trajectory files
//! ([`crate::util::bench`] with `BENCH_JSON` set).
//!
//! Supports objects, arrays, strings (with `\uXXXX` escapes), numbers,
//! booleans and null.  Not performance-critical: it runs once at
//! startup on a <100 KiB manifest, or once per bench report.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize back to a JSON document.  Object keys come out in
    /// `BTreeMap` order, so dump→parse→dump is a fixed point — stable
    /// diffs for committed artifacts like the bench trajectory.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub at: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), at: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.obj(),
            Some(b'[') => self.arr(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.num(),
            _ => Err(self.err("expected value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn num(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn arr(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn obj(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(key, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let j = Json::parse(
            r#"[{"name":"m","file":"m.hlo.txt","inputs":[[2,3],[3]],"outputs":[[2,3]],"flops":36}]"#,
        )
        .unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(), "m");
        assert_eq!(arr[0].get("flops").unwrap().as_u64().unwrap(), 36);
        let ins = arr[0].get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(ins[0].as_arr().unwrap()[1].as_usize().unwrap(), 3);
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn nested_objects() {
        let j = Json::parse(r#"{"a":{"b":[1,2,{"c":"d"}]}}"#).unwrap();
        let inner = j.get("a").unwrap().get("b").unwrap().as_arr().unwrap();
        assert_eq!(inner[2].get("c").unwrap().as_str().unwrap(), "d");
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse(r#""héllo → ok""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo → ok");
    }

    #[test]
    fn dump_roundtrips() {
        let src = r#"{"a":[1,2.5,-3],"b":{"c":"x\"y\n","d":null},"e":true}"#;
        let j = Json::parse(src).unwrap();
        let dumped = j.dump();
        assert_eq!(Json::parse(&dumped).unwrap(), j, "parse(dump(x)) == x");
        assert_eq!(Json::parse(&dumped).unwrap().dump(), dumped, "dump is a fixed point");
    }

    #[test]
    fn dump_integers_without_fraction() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(2.5).dump(), "2.5");
        assert_eq!(Json::Str("a→b".into()).dump(), "\"a→b\"");
    }
}
