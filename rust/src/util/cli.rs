//! Tiny CLI argument helper (offline replacement for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positionals.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.get(key).map(|v| v != "false").unwrap_or(false)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed_styles() {
        let a = parse("eval table3 --device pixel6 --threads=4 --verbose");
        assert_eq!(a.positional, vec!["eval", "table3"]);
        assert_eq!(a.get("device"), Some("pixel6"));
        assert_eq!(a.get_usize("threads", 1), 4);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_usize("threads", 6), 6);
        assert_eq!(a.get_str("device", "pixel6"), "pixel6");
        assert_eq!(a.get_f64("margin", 0.4), 0.4);
    }
}
