//! Mini property-testing harness (offline replacement for `proptest`).
//!
//! A property is a closure taking an [`Rng`]; [`check`] runs it across
//! many seeded cases and reports the failing seed so the case can be
//! replayed deterministically:
//!
//! ```ignore
//! prop::check("arena never aliases live tensors", 256, |rng| {
//!     let lifetimes = gen_lifetimes(rng);
//!     assert_no_alias(&lifetimes);
//! });
//! ```

use super::rng::Rng;

/// Run `cases` seeded instances of `property`.  Panics (with the seed)
/// on the first failure so `PLX_PROP_SEED=<seed>` replays it.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: u64, mut property: F) {
    if let Ok(seed) = std::env::var("PLX_PROP_SEED") {
        let seed: u64 = seed.parse().expect("PLX_PROP_SEED must be a u64");
        let mut rng = Rng::new(seed);
        property(&mut rng);
        return;
    }
    for case in 0..cases {
        let seed = 0xC0FF_EE00 ^ (case.wrapping_mul(0x9E37_79B9));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            property(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} (replay with \
                 PLX_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u64 xor is involutive", 64, |rng| {
            let a = rng.next_u64();
            let b = rng.next_u64();
            assert_eq!(a ^ b ^ b, a);
        });
    }

    #[test]
    #[should_panic(expected = "replay with")]
    fn reports_failing_seed() {
        check("always fails eventually", 8, |rng| {
            assert!(rng.f64() < 0.0, "impossible");
        });
    }
}
