//! Run configuration: a minimal TOML-subset loader + the typed config
//! the CLI and examples consume.
//!
//! Offline environment — no `toml` crate — so we parse the subset we
//! emit: `key = value` lines under `[section]` headers, with string,
//! integer, float and boolean values.  Comments (`#`) and blank lines
//! are ignored.
//!
//! Recognised sections: `[run]` (model/device/mode/protocol),
//! `[scheduler]` (§3.3 knobs) and `[serve]` (dispatcher workers,
//! micro-batch cap, device-wide governor budget).

use std::collections::HashMap;

use crate::device::SocProfile;
use crate::models::ModelKind;
use crate::sched::SchedCfg;
use crate::sim::Mode;

/// Flat `section.key -> value` view of a TOML-subset document.
#[derive(Clone, Debug, Default)]
pub struct RawConfig {
    values: HashMap<String, String>,
}

impl RawConfig {
    pub fn parse(src: &str) -> Result<Self, String> {
        let mut section = String::new();
        let mut values = HashMap::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(format!("line {}: expected key = value", lineno + 1));
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let v = v.trim().trim_matches('"').to_string();
            values.insert(key, v);
        }
        Ok(Self { values })
    }

    pub fn load(path: &std::path::Path) -> Result<Self, String> {
        let src = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Self::parse(&src)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

/// Serving-dispatcher settings (`[serve]` section + `parallax serve`
/// flags): worker pool size, micro-batch cap, and the device-wide
/// memory budget the [`crate::sched::MemoryGovernor`] enforces.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeSettings {
    /// Shared dispatcher worker threads.
    pub workers: usize,
    /// Max requests per model served under one admission.
    pub max_batch: usize,
    /// Device-wide governor budget, MB.
    pub budget_mb: usize,
}

impl Default for ServeSettings {
    fn default() -> Self {
        Self { workers: 4, max_batch: 8, budget_mb: 512 }
    }
}

impl ServeSettings {
    /// Governor budget in bytes.
    pub fn budget_bytes(&self) -> u64 {
        self.budget_mb as u64 * 1_000_000
    }
}

/// Typed run configuration (CLI flags override file values).
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: ModelKind,
    pub device: SocProfile,
    pub mode: Mode,
    pub sched: SchedCfg,
    pub serve: ServeSettings,
    pub runs: usize,
    pub warmup: usize,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            model: ModelKind::ClipText,
            device: SocProfile::pixel6(),
            mode: Mode::CpuOnly,
            sched: SchedCfg::default(),
            serve: ServeSettings::default(),
            runs: 20,
            warmup: 5,
            seed: 42,
        }
    }
}

impl RunConfig {
    /// Merge a raw config file into the defaults.
    pub fn from_raw(raw: &RawConfig) -> Result<Self, String> {
        let mut c = Self::default();
        if let Some(m) = raw.get("run.model") {
            c.model = ModelKind::from_slug(m).ok_or_else(|| format!("unknown model {m}"))?;
        }
        if let Some(d) = raw.get("run.device") {
            c.device = SocProfile::by_name(d).ok_or_else(|| format!("unknown device {d}"))?;
        }
        if let Some(m) = raw.get("run.mode") {
            c.mode = match m {
                "cpu" => Mode::CpuOnly,
                "het" | "heterogeneous" => Mode::Heterogeneous,
                _ => return Err(format!("unknown mode {m}")),
            };
        }
        c.sched.max_threads = raw.get_usize("scheduler.max_threads", c.sched.max_threads);
        c.sched.margin = raw.get_f64("scheduler.margin", c.sched.margin);
        c.serve.workers = raw.get_usize("serve.workers", c.serve.workers);
        c.serve.max_batch = raw.get_usize("serve.max_batch", c.serve.max_batch);
        c.serve.budget_mb = raw.get_usize("serve.budget_mb", c.serve.budget_mb);
        c.runs = raw.get_usize("run.runs", c.runs);
        c.warmup = raw.get_usize("run.warmup", c.warmup);
        c.seed = raw.get_usize("run.seed", c.seed as usize) as u64;
        if !(0.0..1.0).contains(&c.sched.margin) {
            return Err(format!("margin {} out of [0,1)", c.sched.margin));
        }
        if c.serve.workers == 0 || c.serve.max_batch == 0 {
            return Err("serve.workers and serve.max_batch must be >= 1".to_string());
        }
        if c.serve.budget_mb == 0 {
            return Err("serve.budget_mb must be >= 1".to_string());
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# Parallax run config
[run]
model = "whisper-tiny"
device = "redmik50"
mode = "het"
runs = 10

[scheduler]
max_threads = 4
margin = 0.3

[serve]
workers = 3
max_batch = 16
budget_mb = 768
"#;

    #[test]
    fn parses_sample() {
        let raw = RawConfig::parse(SAMPLE).unwrap();
        assert_eq!(raw.get("run.model"), Some("whisper-tiny"));
        assert_eq!(raw.get_usize("scheduler.max_threads", 6), 4);
        let c = RunConfig::from_raw(&raw).unwrap();
        assert_eq!(c.model, ModelKind::WhisperTiny);
        assert_eq!(c.device.name, "redmik50");
        assert_eq!(c.mode, Mode::Heterogeneous);
        assert_eq!(c.sched.max_threads, 4);
        assert!((c.sched.margin - 0.3).abs() < 1e-9);
        assert_eq!(c.serve, ServeSettings { workers: 3, max_batch: 16, budget_mb: 768 });
        assert_eq!(c.serve.budget_bytes(), 768_000_000);
    }

    #[test]
    fn rejects_bad_values() {
        let raw = RawConfig::parse("[run]\nmodel = \"gpt5\"\n").unwrap();
        assert!(RunConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[scheduler]\nmargin = 1.5\n").unwrap();
        assert!(RunConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[serve]\nworkers = 0\n").unwrap();
        assert!(RunConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[serve]\nbudget_mb = 0\n").unwrap();
        assert!(RunConfig::from_raw(&raw).is_err());
        assert!(RawConfig::parse("not a toml line").is_err());
    }

    #[test]
    fn defaults_survive_empty_file() {
        let raw = RawConfig::parse("").unwrap();
        let c = RunConfig::from_raw(&raw).unwrap();
        assert_eq!(c.sched.max_threads, 6);
        assert_eq!(c.runs, 20);
        assert_eq!(c.serve, ServeSettings::default());
    }
}
