//! Global memory governor: a process-wide budget ledger for branch-peak
//! reservations (paper §3.3, lifted from per-model to device-wide).
//!
//! The per-layer scheduler guarantees that *one* model's concurrent
//! branches fit a working budget, but a serving host runs many model
//! pipelines at once — without a shared ledger their individually-safe
//! schedules can add up to the exact memory spike §3.3 is designed to
//! prevent.  The governor closes that gap: every executor (the real
//! engine's waves, the serving dispatcher's admission control, the
//! simulator's budget derivation) leases its peak demand from one
//! process-wide [`MemoryGovernor`] and blocks while the device budget
//! is exhausted.
//!
//! Design points:
//!
//! * **RAII leases** — [`MemoryGovernor::acquire`] returns a [`Lease`]
//!   that returns its bytes on drop and wakes waiters; forgetting to
//!   release is impossible.
//! * **Backpressure, not failure** — when the ledger is full, `acquire`
//!   parks on a condvar until capacity frees up. [`MemoryGovernor::try_acquire`]
//!   is the non-blocking variant for callers with a fallback plan.
//! * **FIFO admission** — blocking acquirers are served strictly in
//!   arrival order, so a large reservation can never be starved by a
//!   stream of smaller ones barging past it.
//! * **Guaranteed progress** — a request larger than the whole budget
//!   can never fit, so it is granted *only* while no memory-holding
//!   lease is live (degraded serial mode, counted in
//!   [`GovernorStats::over_budget_grants`]). This mirrors the §3.3
//!   spill rule: a branch that exceeds the budget on its own still runs,
//!   alone (zero-byte leases may ride along — they hold nothing).
//! * **Zero-cost zero** — zero-byte leases (delegate-only waves hold no
//!   CPU memory) are granted immediately and never wait.
//!
//! # Examples
//!
//! ```
//! use parallax::sched::MemoryGovernor;
//!
//! let gov = MemoryGovernor::new(1_000);
//! let big = gov.acquire(600);
//! // not enough left for another 600-byte reservation...
//! assert!(gov.try_acquire(600).is_none());
//! // ...until the first lease drops.
//! drop(big);
//! assert!(gov.try_acquire(600).is_some());
//! assert_eq!(gov.peak_reserved(), 600);
//! ```

use std::sync::{Condvar, Mutex};

use super::SchedCfg;

#[derive(Clone, Copy, Debug, Default)]
struct Ledger {
    in_use: u64,
    active_leases: usize,
    /// Leases actually holding bytes — zero-byte leases (delegate-only
    /// waves) are excluded so they can never block a degraded-serial
    /// over-budget admission.
    nonzero_leases: usize,
    peak_reserved: u64,
    grants: u64,
    over_budget_grants: u64,
    waits: u64,
    /// FIFO admission tickets: next to hand out / next to serve.
    /// Blocking `acquire`s are admitted strictly in arrival order so a
    /// large reservation can never be starved by a stream of small
    /// ones barging past it.
    next_ticket: u64,
    serving: u64,
}

/// Snapshot of the governor's counters (observability + tests).
#[derive(Clone, Copy, Debug, Default)]
pub struct GovernorStats {
    /// Bytes currently reserved.
    pub in_use: u64,
    /// Leases currently outstanding.
    pub active_leases: usize,
    /// High-water mark of `in_use` over the governor's lifetime.
    pub peak_reserved: u64,
    /// Total leases granted.
    pub grants: u64,
    /// Leases larger than the whole budget, granted in degraded serial
    /// mode while the ledger was idle.
    pub over_budget_grants: u64,
    /// Times an `acquire` had to park and wait for capacity.
    pub waits: u64,
}

/// Process-wide memory budget ledger. See the [module docs](self).
#[derive(Debug)]
pub struct MemoryGovernor {
    budget: u64,
    state: Mutex<Ledger>,
    freed: Condvar,
}

impl MemoryGovernor {
    /// Governor over a fixed device-wide working budget in bytes.
    pub fn new(budget: u64) -> Self {
        Self { budget, state: Mutex::new(Ledger::default()), freed: Condvar::new() }
    }

    /// Governor that admits everything (single-model tools and tests).
    pub fn unlimited() -> Self {
        Self::new(u64::MAX)
    }

    /// Derive the budget from an OS free-memory reading exactly like
    /// the per-model scheduler does: `free × (1 − margin)` (§3.3).
    pub fn from_sched(cfg: &SchedCfg, free_mem: u64) -> Self {
        Self::new(cfg.budget(free_mem))
    }

    /// The configured device-wide budget in bytes.
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Reserve `bytes`, blocking while the ledger cannot admit them.
    ///
    /// Zero-byte reservations are granted immediately. Everything else
    /// queues on a FIFO ticket: waiters are admitted strictly in
    /// arrival order, so a large reservation is never starved by
    /// smaller ones barging past it while it waits. An over-budget
    /// reservation waits (at its turn) for the ledger to go idle and
    /// then runs alone (degraded serial mode).
    pub fn acquire(&self, bytes: u64) -> Lease<'_> {
        let mut st = self.state.lock().unwrap();
        if bytes == 0 {
            Self::grant(&mut st, self.budget, bytes);
            return Lease { gov: self, bytes };
        }
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        // `waits` counts *parked acquires*, not condvar wakeups: one
        // blocked reservation that sleeps through many spurious (or
        // sibling-targeted) notify_all rounds still waited once.
        let mut parked = false;
        loop {
            if st.serving == ticket && Self::fits(&st, self.budget, bytes) {
                st.serving += 1;
                Self::grant(&mut st, self.budget, bytes);
                drop(st);
                // the next ticket holder may already be admissible
                self.freed.notify_all();
                return Lease { gov: self, bytes };
            }
            if !parked {
                st.waits += 1;
                parked = true;
            }
            st = self.freed.wait(st).unwrap();
        }
    }

    /// Non-blocking [`MemoryGovernor::acquire`]: `None` when the
    /// reservation is not immediately admissible.  To preserve the
    /// FIFO no-starvation guarantee, `try_acquire` also refuses (rather
    /// than barging) while blocking acquirers are queued.
    pub fn try_acquire(&self, bytes: u64) -> Option<Lease<'_>> {
        let mut st = self.state.lock().unwrap();
        let no_queue = st.serving == st.next_ticket;
        if bytes == 0 || (no_queue && Self::fits(&st, self.budget, bytes)) {
            Self::grant(&mut st, self.budget, bytes);
            Some(Lease { gov: self, bytes })
        } else {
            None
        }
    }

    fn fits(st: &Ledger, budget: u64, bytes: u64) -> bool {
        // over-budget requests wait only on *memory-holding* leases:
        // zero-byte leases consume nothing, so letting them ride along
        // cannot stack peaks, while counting them could starve the
        // degraded-serial path forever under sustained zero-demand load
        st.in_use.saturating_add(bytes) <= budget
            || (bytes > budget && st.nonzero_leases == 0)
    }

    fn grant(st: &mut Ledger, budget: u64, bytes: u64) {
        st.in_use = st.in_use.saturating_add(bytes);
        st.active_leases += 1;
        if bytes > 0 {
            st.nonzero_leases += 1;
        }
        st.grants += 1;
        if bytes > budget {
            st.over_budget_grants += 1;
        }
        st.peak_reserved = st.peak_reserved.max(st.in_use);
    }

    /// Bytes currently reserved.
    pub fn in_use(&self) -> u64 {
        self.state.lock().unwrap().in_use
    }

    /// High-water mark of reserved bytes.
    pub fn peak_reserved(&self) -> u64 {
        self.state.lock().unwrap().peak_reserved
    }

    /// Counter snapshot.
    pub fn stats(&self) -> GovernorStats {
        let st = self.state.lock().unwrap();
        GovernorStats {
            in_use: st.in_use,
            active_leases: st.active_leases,
            peak_reserved: st.peak_reserved,
            grants: st.grants,
            over_budget_grants: st.over_budget_grants,
            waits: st.waits,
        }
    }
}

/// RAII reservation handle: returns its bytes to the governor and wakes
/// waiters when dropped.
#[derive(Debug)]
pub struct Lease<'g> {
    gov: &'g MemoryGovernor,
    bytes: u64,
}

impl Lease<'_> {
    /// Size of this reservation.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Shrink a live reservation to `bytes`, returning the slack to the
    /// ledger and waking waiters.  Growing is not allowed — a no-op.
    ///
    /// For admission paths that must reserve *before* resolution lands
    /// (e.g. a dispatcher admitting a request at its worst case, then
    /// downsizing once the shapes resolve).  The in-tree §3.4 engine
    /// doesn't need it — barriers resolve before their segment's lease
    /// is sized, so [`MemoryGovernor::acquire`] takes the resolved
    /// figure directly and the slack never leaves the ledger.
    pub fn shrink_to(&mut self, bytes: u64) {
        if bytes >= self.bytes {
            return;
        }
        let mut st = self.gov.state.lock().unwrap();
        st.in_use = st.in_use.saturating_sub(self.bytes - bytes);
        if self.bytes > 0 && bytes == 0 {
            st.nonzero_leases -= 1;
        }
        drop(st);
        self.bytes = bytes;
        self.gov.freed.notify_all();
    }
}

impl Drop for Lease<'_> {
    fn drop(&mut self) {
        let mut st = self.gov.state.lock().unwrap();
        st.in_use = st.in_use.saturating_sub(self.bytes);
        st.active_leases -= 1;
        if self.bytes > 0 {
            st.nonzero_leases -= 1;
        }
        drop(st);
        self.gov.freed.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lease_roundtrip_updates_ledger() {
        let gov = MemoryGovernor::new(100);
        assert_eq!(gov.in_use(), 0);
        {
            let a = gov.acquire(40);
            let b = gov.acquire(60);
            assert_eq!(a.bytes() + b.bytes(), 100);
            assert_eq!(gov.in_use(), 100);
            assert_eq!(gov.stats().active_leases, 2);
        }
        assert_eq!(gov.in_use(), 0);
        assert_eq!(gov.peak_reserved(), 100);
        assert_eq!(gov.stats().grants, 2);
    }

    #[test]
    fn backpressure_blocks_until_release() {
        let gov = Arc::new(MemoryGovernor::new(100));
        let first = gov.acquire(80);
        let g2 = gov.clone();
        let waiter = std::thread::spawn(move || {
            let lease = g2.acquire(50);
            assert_eq!(lease.bytes(), 50);
        });
        // 80 + 50 > 100, so the waiter cannot be admitted before the
        // first lease drops, no matter how the threads interleave.
        // Wait (bounded) until it has actually parked once.
        for _ in 0..2000 {
            if gov.stats().waits >= 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(gov.in_use(), 80);
        drop(first);
        waiter.join().unwrap();
        assert_eq!(gov.in_use(), 0);
        assert!(gov.stats().waits >= 1);
        assert!(gov.peak_reserved() <= 100);
    }

    #[test]
    fn waits_counts_one_per_parked_acquire() {
        // A blocked acquire that rides out many wakeups-without-progress
        // is ONE wait.  Zero-byte lease drops call notify_all, waking
        // the parked waiter each round while 80 + 50 > 100 keeps it
        // inadmissible — the old per-wakeup counting inflated `waits`
        // by the number of rounds.
        let gov = Arc::new(MemoryGovernor::new(100));
        let first = gov.acquire(80);
        let g2 = gov.clone();
        let waiter = std::thread::spawn(move || drop(g2.acquire(50)));
        for _ in 0..2000 {
            if gov.stats().waits >= 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(gov.stats().waits, 1);
        for _ in 0..20 {
            drop(gov.acquire(0)); // drop -> notify_all -> spurious round
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        drop(first);
        waiter.join().unwrap();
        assert_eq!(gov.stats().waits, 1, "wakeup rounds must not inflate waits");
        assert_eq!(gov.in_use(), 0);
    }

    #[test]
    fn fifo_admission_prevents_barging_starvation() {
        let gov = Arc::new(MemoryGovernor::new(100));
        let first = gov.acquire(60);
        let order = Arc::new(Mutex::new(Vec::<&'static str>::new()));

        // a big reservation queues first...
        let (g, o) = (gov.clone(), order.clone());
        let big = std::thread::spawn(move || {
            let lease = g.acquire(90);
            o.lock().unwrap().push("big");
            drop(lease);
        });
        // wait (bounded) until it holds a ticket and has parked
        for _ in 0..2000 {
            if gov.stats().waits >= 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }

        // ...then a small one that *would* fit right now (60+30 ≤ 100)
        // but must not barge past the queued big reservation.
        let (g, o) = (gov.clone(), order.clone());
        let small = std::thread::spawn(move || {
            let lease = g.acquire(30);
            o.lock().unwrap().push("small");
            drop(lease);
        });

        drop(first);
        big.join().unwrap();
        small.join().unwrap();
        // FIFO tickets guarantee service order regardless of timing
        assert_eq!(*order.lock().unwrap(), ["big", "small"]);
        assert_eq!(gov.in_use(), 0);
    }

    #[test]
    fn oversized_request_degrades_to_serial() {
        let gov = MemoryGovernor::new(10);
        let big = gov.acquire(50); // idle ledger: granted, serial mode
        assert_eq!(gov.stats().over_budget_grants, 1);
        assert!(gov.try_acquire(1).is_none(), "ledger is saturated");
        drop(big);
        assert!(gov.try_acquire(1).is_some());
    }

    #[test]
    fn zero_byte_lease_never_waits() {
        let gov = MemoryGovernor::new(10);
        let big = gov.acquire(50);
        let z = gov.try_acquire(0);
        assert!(z.is_some(), "delegate-only waves must not block");
        drop(z);
        drop(big);
    }

    #[test]
    fn zero_byte_leases_cannot_starve_oversize_admission() {
        // sustained zero-demand traffic must not keep an over-budget
        // (degraded serial) reservation waiting for an idle ledger
        let gov = MemoryGovernor::new(10);
        let zero = gov.acquire(0);
        let big = gov.try_acquire(50);
        assert!(big.is_some(), "zero-byte lease blocked degraded-serial admission");
        drop((zero, big));
        assert_eq!(gov.in_use(), 0);
    }

    #[test]
    fn shrink_returns_slack_and_unblocks() {
        let gov = MemoryGovernor::new(100);
        let mut big = gov.acquire(90);
        assert!(gov.try_acquire(40).is_none());
        big.shrink_to(50);
        assert_eq!(gov.in_use(), 50);
        assert_eq!(big.bytes(), 50);
        let small = gov.try_acquire(40).expect("slack returned to the ledger");
        drop((big, small));
        assert_eq!(gov.in_use(), 0, "shrunk lease releases its new size");
        // growing is a no-op
        let mut l = gov.acquire(10);
        l.shrink_to(20);
        assert_eq!(l.bytes(), 10);
        // shrink to zero clears the nonzero count: an over-budget
        // degraded-serial admission becomes possible again
        l.shrink_to(0);
        assert!(gov.try_acquire(500).is_some());
        drop(l);
        assert_eq!(gov.in_use(), 0);
    }

    #[test]
    fn unlimited_admits_everything() {
        let gov = MemoryGovernor::unlimited();
        let a = gov.acquire(u64::MAX / 2);
        let b = gov.acquire(u64::MAX / 2);
        drop((a, b));
        assert_eq!(gov.in_use(), 0);
    }

    #[test]
    fn from_sched_matches_scheduler_budget() {
        let cfg = SchedCfg::default();
        let gov = MemoryGovernor::from_sched(&cfg, 1 << 30);
        assert_eq!(gov.budget(), cfg.budget(1 << 30));
    }
}
