//! Shared per-lane busy-time ledger — the serving tier's cross-model
//! view of the accelerator lanes.
//!
//! The [`MemoryGovernor`](super::MemoryGovernor) answers "how many
//! bytes are in flight"; this ledger answers the placement-side
//! question: "how much modelled lane time have the *other* tenants
//! already claimed?"  It tracks two quantities per lane:
//!
//! * **static load** — the per-request modelled busy seconds each
//!   registered model's [`PlacementPlan`](crate::place::PlacementPlan)
//!   puts on the lane (its
//!   [`lane_busy_s`](crate::place::PlacementPlan::lane_busy_s) sum).
//!   Rebuilt from scratch on every joint re-placement
//!   (`register`/`drop`) and fed back into
//!   [`assign_with_loads`](crate::place::assign_with_loads) so tenants
//!   spread across lanes instead of piling onto the fastest one.
//! * **outstanding work** — modelled service seconds of admitted but
//!   not-yet-completed requests, the figure SLO admission compares a
//!   request's deadline against (`outstanding + service ≤ deadline`).
//!
//! Outstanding time is held internally in integer nanoseconds so that
//! admit/complete pairs cancel *exactly* — a drained server always
//! reads back `0.0`, which the deterministic deadline tests pin.

use std::sync::Mutex;

/// Ledger state: lanes grow on demand (a server does not know its
/// tenants' SoCs until they register).
#[derive(Default)]
struct Ledger {
    /// Per-lane static busy seconds per request, summed over tenants.
    static_s: Vec<f64>,
    /// Per-lane outstanding admitted service, integer nanoseconds.
    outstanding_ns: Vec<u64>,
}

impl Ledger {
    fn ensure(&mut self, lanes: usize) {
        if self.static_s.len() < lanes {
            self.static_s.resize(lanes, 0.0);
        }
        if self.outstanding_ns.len() < lanes {
            self.outstanding_ns.resize(lanes, 0);
        }
    }
}

/// Seconds → integer nanoseconds (saturating; negative and NaN clamp
/// to zero, so a hostile service figure cannot corrupt the ledger).
fn to_ns(s: f64) -> u64 {
    if s.is_nan() {
        return 0;
    }
    (s.max(0.0) * 1e9) as u64
}

/// Shared per-lane busy-time ledger (see module docs).  All methods
/// take `&self`; the server holds it in an `Arc` next to the governor.
#[derive(Default)]
pub struct LaneLedger {
    inner: Mutex<Ledger>,
}

impl LaneLedger {
    /// Ledger sized for `lanes` lanes (it grows on demand anyway).
    pub fn new(lanes: usize) -> Self {
        let led = LaneLedger::default();
        led.inner.lock().unwrap().ensure(lanes);
        led
    }

    /// Number of lanes the ledger has seen so far.
    pub fn num_lanes(&self) -> usize {
        let st = self.inner.lock().unwrap();
        st.static_s.len().max(st.outstanding_ns.len())
    }

    /// Clear the static per-request loads (start of a joint
    /// re-placement pass); outstanding admitted work is untouched.
    pub fn reset_static(&self) {
        self.inner.lock().unwrap().static_s.iter_mut().for_each(|s| *s = 0.0);
    }

    /// Accumulate one tenant's per-lane busy seconds (its placement's
    /// [`lane_busy_s`](crate::place::PlacementPlan::lane_busy_s)).
    pub fn add_static(&self, per_lane_busy_s: &[f64]) {
        let mut st = self.inner.lock().unwrap();
        st.ensure(per_lane_busy_s.len());
        for (slot, add) in st.static_s.iter_mut().zip(per_lane_busy_s) {
            *slot += add;
        }
    }

    /// Snapshot of the accumulated static loads — what the *next*
    /// tenant's `assign_with_loads` call starts from.
    pub fn static_loads(&self) -> Vec<f64> {
        self.inner.lock().unwrap().static_s.clone()
    }

    /// Record an admitted request's modelled service time on a lane.
    pub fn admit(&self, lane: usize, service_s: f64) {
        let mut st = self.inner.lock().unwrap();
        st.ensure(lane + 1);
        st.outstanding_ns[lane] = st.outstanding_ns[lane].saturating_add(to_ns(service_s));
    }

    /// Pop a completed (or abandoned) request's service time.  Pass the
    /// same figure that was admitted; the integer representation makes
    /// the pair cancel exactly.
    pub fn complete(&self, lane: usize, service_s: f64) {
        let mut st = self.inner.lock().unwrap();
        st.ensure(lane + 1);
        st.outstanding_ns[lane] = st.outstanding_ns[lane].saturating_sub(to_ns(service_s));
    }

    /// Outstanding admitted service seconds on a lane — the queueing
    /// estimate SLO admission adds the candidate's own service to.
    pub fn outstanding(&self, lane: usize) -> f64 {
        let st = self.inner.lock().unwrap();
        st.outstanding_ns.get(lane).copied().unwrap_or(0) as f64 / 1e9
    }

    /// Total outstanding service seconds across all lanes.
    pub fn outstanding_total(&self) -> f64 {
        let st = self.inner.lock().unwrap();
        st.outstanding_ns.iter().sum::<u64>() as f64 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_complete_cancels_exactly() {
        let led = LaneLedger::new(2);
        for s in [1.0, 0.25, 0.1, 3.3e-3] {
            led.admit(0, s);
        }
        assert!(led.outstanding(0) > 0.0);
        for s in [1.0, 0.25, 0.1, 3.3e-3] {
            led.complete(0, s);
        }
        assert_eq!(led.outstanding(0), 0.0, "drained ledger must read exactly zero");
        assert_eq!(led.outstanding_total(), 0.0);
    }

    #[test]
    fn static_loads_reset_and_accumulate() {
        let led = LaneLedger::new(0);
        led.add_static(&[0.5, 0.0]);
        led.add_static(&[0.25, 1.0, 2.0]); // grows to 3 lanes
        assert_eq!(led.static_loads(), vec![0.75, 1.0, 2.0]);
        assert_eq!(led.num_lanes(), 3);
        led.reset_static();
        assert_eq!(led.static_loads(), vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn complete_saturates_and_rejects_garbage() {
        let led = LaneLedger::new(1);
        led.complete(0, 5.0); // more than was ever admitted
        assert_eq!(led.outstanding(0), 0.0);
        led.admit(0, f64::NAN);
        led.admit(0, -3.0);
        assert_eq!(led.outstanding(0), 0.0, "NaN/negative service is ignored");
        assert_eq!(led.outstanding(9), 0.0, "unknown lanes read zero");
    }
}
