//! Resource-constrained parallel scheduling (paper §3.3).
//!
//! Per layer, pick the largest subset of branches whose combined
//! estimated peak memory fits the working budget
//! `M_budget = free_mem × (1 − margin)`; run the rest sequentially.
//! Concurrency is additionally capped by `max_threads` (Fig. 3's knob):
//! a layer wider than the cap executes in waves.
//!
//! Budgets come in two flavours:
//!
//! * [`schedule`] takes a raw byte budget — the single-model path.
//! * [`schedule_governed`] plans against a process-wide
//!   [`MemoryGovernor`], the shared ledger that multi-model serving
//!   leases branch-peak reservations from (see [`governor`]).  Both
//!   paths produce identical plans for the same budget, so single- and
//!   multi-model execution share one code path.
//!
//! # Examples
//!
//! ```
//! use parallax::branch::{self, DEFAULT_BETA};
//! use parallax::memory::branch_memories;
//! use parallax::models::micro;
//! use parallax::partition::{partition, CostModel};
//! use parallax::sched::{schedule, SchedCfg};
//!
//! let g = micro::parallel_chains(4, 5);
//! let p = partition(&g, &CostModel::default());
//! let plan = branch::plan(&g, &p, DEFAULT_BETA);
//! let mems = branch_memories(&g, &p, &plan);
//! let scheds = schedule(&plan, &mems, 1 << 30, &SchedCfg::default());
//! // every branch appears exactly once across waves + spill
//! let n: usize = scheds.iter().map(|s| s.all().count()).sum();
//! assert_eq!(n, plan.branches.len());
//! ```

pub mod governor;
pub mod lane_ledger;

pub use governor::{GovernorStats, Lease, MemoryGovernor};
pub use lane_ledger::LaneLedger;

use crate::branch::{Branch, BranchPlan};
use crate::memory::BranchMemory;

/// Scheduler configuration.
#[derive(Clone, Copy, Debug)]
pub struct SchedCfg {
    /// Max concurrently executing CPU branches (paper default 6).
    pub max_threads: usize,
    /// Safety margin over reported free memory (paper: 0.3–0.5).
    pub margin: f64,
}

impl Default for SchedCfg {
    fn default() -> Self {
        Self { max_threads: 6, margin: 0.4 }
    }
}

impl SchedCfg {
    /// Working budget from an OS free-memory reading.
    ///
    /// ```
    /// use parallax::sched::SchedCfg;
    /// let cfg = SchedCfg { max_threads: 6, margin: 0.5 };
    /// assert_eq!(cfg.budget(1000), 500);
    /// ```
    pub fn budget(&self, free_mem: u64) -> u64 {
        (free_mem as f64 * (1.0 - self.margin)) as u64
    }
}

/// Execution plan for one layer: parallel waves followed by the
/// sequential spill (each spilled branch runs alone).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerSchedule {
    /// Groups of branch ids that run concurrently (each group ≤
    /// max_threads wide and within budget).
    pub waves: Vec<Vec<usize>>,
    /// Branches that must run one-at-a-time (memory spill).
    pub sequential: Vec<usize>,
}

impl LayerSchedule {
    /// All branches, in execution order.
    pub fn all(&self) -> impl Iterator<Item = usize> + '_ {
        self.waves
            .iter()
            .flatten()
            .copied()
            .chain(self.sequential.iter().copied())
    }

    /// Max concurrency used.
    pub fn width(&self) -> usize {
        self.waves.iter().map(Vec::len).max().unwrap_or(0).max(
            usize::from(!self.sequential.is_empty()),
        )
    }
}

/// Greedy §3.3 selection for one layer.
///
/// Branches are sorted by ascending M_i so the chosen subset is the
/// *largest possible* count within the budget; the spill runs
/// sequentially.  Chosen branches are then chunked into waves of
/// `max_threads`.  Delegate branches occupy the accelerator, not a CPU
/// thread — they are always scheduled into the first wave.
pub fn schedule_layer(
    _branches: &[Branch],
    mems: &[BranchMemory],
    layer: &[usize],
    budget: u64,
    cfg: &SchedCfg,
    parallel_ok: bool,
) -> LayerSchedule {
    let (delegated, cpu): (Vec<usize>, Vec<usize>) = layer
        .iter()
        .copied()
        .partition(|&b| _branches[b].has_delegate);

    // §3.1 refinement: only the balanced subset is worth fanning out;
    // the rest of the layer runs sequentially either way.
    let subset =
        crate::branch::balanced_parallel_subset(_branches, layer, crate::branch::DEFAULT_BETA);

    if !parallel_ok || subset.len() < 2 {
        // whole layer sequential (plus delegate branches in wave 0 so
        // they still overlap with the first CPU branch).
        let mut waves = Vec::new();
        if !delegated.is_empty() {
            waves.push(delegated);
        }
        return LayerSchedule { waves, sequential: cpu };
    }

    let leftover: Vec<usize> =
        cpu.iter().copied().filter(|b| !subset.contains(b)).collect();

    // ascending M_i -> maximize chosen count
    let mut order = subset;
    order.sort_by_key(|&b| mems[b].total());
    let mut chosen = Vec::new();
    let mut spill = Vec::new();
    let mut used = 0u64;
    for b in order {
        let m = mems[b].total() as u64;
        if used + m <= budget {
            used += m;
            chosen.push(b);
        } else {
            spill.push(b);
        }
    }
    if chosen.len() < 2 {
        // parallelism didn't survive the budget: run everything
        // sequentially (chosen ∪ spill ∪ leftover), delegates overlap.
        let mut seq = chosen;
        seq.extend(spill);
        seq.extend(leftover);
        let mut waves = Vec::new();
        if !delegated.is_empty() {
            waves.push(delegated);
        }
        return LayerSchedule { waves, sequential: seq };
    }

    // chunk into waves of max_threads; delegates join the first wave
    let mut waves: Vec<Vec<usize>> = chosen
        .chunks(cfg.max_threads.max(1))
        .map(|c| c.to_vec())
        .collect();
    if !delegated.is_empty() {
        waves.first_mut().unwrap().extend(delegated);
    }
    let mut sequential = spill;
    sequential.extend(leftover);
    LayerSchedule { waves, sequential }
}

/// Full-model schedule: one [`LayerSchedule`] per layer.
pub fn schedule(
    plan: &BranchPlan,
    mems: &[BranchMemory],
    budget: u64,
    cfg: &SchedCfg,
) -> Vec<LayerSchedule> {
    plan.layers
        .iter()
        .zip(&plan.layer_parallel)
        .map(|(layer, &ok)| {
            schedule_layer(&plan.branches, mems, layer, budget, cfg, ok)
        })
        .collect()
}

/// Full-model schedule against the process-wide memory governor.
///
/// Planning uses the governor's device budget, so a pipeline sharing
/// the device with others never *plans* wider than the global ledger
/// allows; the runtime leases ([`crate::exec::Engine::run_governed`])
/// then enforce the budget across concurrently executing pipelines.
///
/// ```
/// use parallax::branch::{self, DEFAULT_BETA};
/// use parallax::memory::branch_memories;
/// use parallax::models::micro;
/// use parallax::partition::{partition, CostModel};
/// use parallax::sched::{schedule, schedule_governed, MemoryGovernor, SchedCfg};
///
/// let g = micro::parallel_chains(4, 5);
/// let p = partition(&g, &CostModel::default());
/// let plan = branch::plan(&g, &p, DEFAULT_BETA);
/// let mems = branch_memories(&g, &p, &plan);
/// let cfg = SchedCfg::default();
/// let gov = MemoryGovernor::new(1 << 20);
/// assert_eq!(
///     schedule_governed(&plan, &mems, &gov, &cfg),
///     schedule(&plan, &mems, gov.budget(), &cfg),
/// );
/// ```
pub fn schedule_governed(
    plan: &BranchPlan,
    mems: &[BranchMemory],
    gov: &MemoryGovernor,
    cfg: &SchedCfg,
) -> Vec<LayerSchedule> {
    schedule(plan, mems, gov.budget(), cfg)
}

/// Governor demand of one layer under a heterogeneous placement
/// (`crate::place`): the peak CPU-wave branch demand **plus**
/// `inflight_staging` — the host-visible delegate-I/O staging of every
/// lane job in flight while this layer runs (its own dispatches *and*
/// jobs from earlier layers whose outputs have not merged yet; compute
/// the per-layer figure with [`placed_inflight_staging`]).
///
/// Delegated branches hold no host arenas, but their staging buffers
/// stay resident from dispatch until their outputs merge at the first
/// consumer — with cross-layer overlap that can be several layers
/// later, so offloading (on any number of lanes) can never smuggle
/// memory past the §3.3 budget.  A `has_delegate` branch that
/// placement kept on the CPU counts at its full M_i (its arena is real
/// on the host).
/// [`Engine::run_placed`](crate::exec::Engine::run_placed) leases the
/// max of this figure over all layers once per run, held from before
/// the first dispatch until the final drain — so in-flight staging is
/// never resident outside a lease, even in the windows between layers;
/// [`SegmentedEngine::with_placement`](crate::ctrl::SegmentedEngine::with_placement)
/// folds the same in-flight staging term into its per-segment
/// residency demand.
pub fn placed_layer_demand(
    mems: &[BranchMemory],
    placement: &crate::place::PlacementPlan,
    ls: &LayerSchedule,
    inflight_staging: u64,
) -> u64 {
    let mut peak = 0u64;
    for wave in &ls.waves {
        let sum: u64 = wave
            .iter()
            .filter(|&&b| !placement.is_delegated(b))
            .map(|&b| mems[b].total() as u64)
            .sum();
        peak = peak.max(sum);
    }
    for &b in &ls.sequential {
        if !placement.is_delegated(b) {
            peak = peak.max(mems[b].total() as u64);
        }
    }
    inflight_staging + peak
}

/// Per-layer in-flight delegate-I/O staging under cross-layer overlap:
/// a lane job dispatched at layer `i` holds its host-visible staging
/// until its outputs merge at its first consumer's layer (the last
/// layer of `schedules` when no consumer is scheduled — graph outputs
/// merge at the final drain).  `out[i]` is the staging of every job
/// whose dispatch→merge span covers layer `i`; feed it to
/// [`placed_layer_demand`] so multi-lane offload with overlap still
/// can't smuggle memory past the §3.3 budget.  Remote lanes
/// (`crate::device::RemoteLane`) fold in identically: their
/// `staging_bytes` are the link transfer bytes
/// ([`transfer_bytes`](crate::place::transfer_bytes)), staged
/// host-side from uplink dispatch until the downlink merges — so
/// device–edge spills stay inside the governor lease too.
pub fn placed_inflight_staging(
    plan: &BranchPlan,
    placement: &crate::place::PlacementPlan,
    schedules: &[LayerSchedule],
) -> Vec<u64> {
    placed_inflight_staging_from(&plan.branch_succs(), placement, schedules)
}

/// [`placed_inflight_staging`] against a precomputed successor map
/// ([`BranchPlan::branch_succs`]) — the plan is immutable, so hot
/// callers (the engine, which runs once per segment per decode step)
/// compute the successors once and reuse them here.
pub fn placed_inflight_staging_from(
    succs: &[Vec<usize>],
    placement: &crate::place::PlacementPlan,
    schedules: &[LayerSchedule],
) -> Vec<u64> {
    let n = schedules.len();
    let mut out = vec![0u64; n];
    if n == 0 || placement.num_delegated() == 0 {
        return out;
    }
    let mut index_of: std::collections::HashMap<usize, usize> = std::collections::HashMap::new();
    for (i, ls) in schedules.iter().enumerate() {
        for b in ls.all() {
            index_of.insert(b, i);
        }
    }
    for (i, ls) in schedules.iter().enumerate() {
        for d in ls.all().filter(|&b| placement.is_delegated(b)) {
            let merge = succs[d]
                .iter()
                .filter_map(|c| index_of.get(c).copied())
                .min()
                .unwrap_or(n - 1)
                .max(i);
            for slot in out.iter_mut().take(merge + 1).skip(i) {
                *slot += placement.staging_bytes[d];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::branch::{self, DEFAULT_BETA};
    use crate::memory::{branch_memories, BranchMemory};
    use crate::models::micro;
    use crate::partition::{partition, CostModel};

    fn cpu_only(g: &crate::graph::Graph) -> crate::partition::Partition {
        partition(g, &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 })
    }

    #[test]
    fn budget_respected() {
        let g = micro::parallel_chains(6, 5);
        let p = cpu_only(&g);
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let mems = branch_memories(&g, &p, &plan);
        let cfg = SchedCfg::default();
        // budget that fits about half the branches
        let per = mems.iter().map(|m| m.total()).max().unwrap() as u64;
        let budget = per * 3;
        for (li, layer) in plan.layers.iter().enumerate() {
            let ls = schedule_layer(
                &plan.branches, &mems, layer, budget, &cfg, plan.layer_parallel[li],
            );
            for wave in &ls.waves {
                let sum: u64 = wave
                    .iter()
                    .filter(|&&b| !plan.branches[b].has_delegate)
                    .map(|&b| mems[b].total() as u64)
                    .sum();
                assert!(sum <= budget, "wave over budget: {sum} > {budget}");
            }
        }
    }

    #[test]
    fn zero_budget_forces_sequential() {
        let g = micro::parallel_chains(4, 5);
        let p = cpu_only(&g);
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let mems = branch_memories(&g, &p, &plan);
        let cfg = SchedCfg::default();
        let scheds = schedule(&plan, &mems, 0, &cfg);
        for s in &scheds {
            assert!(s.waves.iter().all(|w| w.is_empty()) || s.waves.is_empty());
        }
        // every branch still executes exactly once
        let total: usize = scheds.iter().map(|s| s.all().count()).sum();
        assert_eq!(total, plan.branches.len());
    }

    #[test]
    fn max_threads_caps_wave_width() {
        let g = micro::parallel_chains(8, 5);
        let p = cpu_only(&g);
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let mems = branch_memories(&g, &p, &plan);
        let cfg = SchedCfg { max_threads: 3, margin: 0.4 };
        let scheds = schedule(&plan, &mems, u64::MAX, &cfg);
        for s in &scheds {
            for w in &s.waves {
                assert!(w.len() <= 3);
            }
        }
        // the 8-wide layer splits into ceil(8/3) = 3 waves
        let wide = scheds.iter().find(|s| s.all().count() == 8).unwrap();
        assert_eq!(wide.waves.len(), 3);
    }

    #[test]
    fn all_branches_scheduled_exactly_once() {
        let g = crate::models::ModelKind::ClipText.build();
        let p = partition(&g, &CostModel::default());
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let mems = branch_memories(&g, &p, &plan);
        let scheds = schedule(&plan, &mems, 1 << 30, &SchedCfg::default());
        let mut seen = vec![false; plan.branches.len()];
        for s in &scheds {
            for b in s.all() {
                assert!(!seen[b], "branch {b} scheduled twice");
                seen[b] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn single_chosen_branch_degenerates_to_sequential() {
        // budget fits exactly one branch -> no point "parallelising"
        let g = micro::parallel_chains(4, 5);
        let p = cpu_only(&g);
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let mems = branch_memories(&g, &p, &plan);
        let per = mems.iter().map(BranchMemory::total).max().unwrap() as u64;
        let cfg = SchedCfg::default();
        let li = plan.layers.iter().position(|l| l.len() == 4).unwrap();
        let ls = schedule_layer(
            &plan.branches, &mems, &plan.layers[li], per, &cfg, plan.layer_parallel[li],
        );
        assert!(ls.waves.is_empty());
        assert_eq!(ls.sequential.len(), 4);
    }
}
