//! Graph pass: structural audit of a [`Graph`] without executing it.
//!
//! Proves the properties the interpreting executor
//! (`exec::Engine::eval_host_node`) and the branch planner assume:
//! the DAG is acyclic, every tensor read resolves, each tensor has at
//! most one producer, per-op input arity matches what the kernel will
//! index, no non-`Output` node's results are silently dropped, and
//! every dynamic-class op is a well-formed control barrier (so `ctrl`
//! segmentation can cut at it).

use crate::graph::{Graph, Node, OpClass, OpKind};

use super::{Code, Finding, Pass};

/// Minimum input arity the host kernel for `kind` will index.
///
/// Mirrors `exec::Engine::eval_host_node`: binary kernels read
/// `ins[0..2]`, `LayerNorm`/`Attention` read `ins[0..3]`, everything
/// else reads at most `ins[0]` (and tolerates zero inputs).
fn min_inputs(kind: &OpKind) -> usize {
    match kind {
        OpKind::MatMul
        | OpKind::FullyConnected
        | OpKind::Add
        | OpKind::Sub
        | OpKind::Mul
        | OpKind::Maximum => 2,
        OpKind::LayerNorm | OpKind::Attention { .. } => 3,
        _ => 0,
    }
}

fn node_loc(n: &Node) -> String {
    format!("node {} `{}` ({:?})", n.id.0, n.name, n.kind)
}

/// Run the graph pass. Returns one [`Finding`] per violation; an
/// empty vector means every structural invariant holds.
pub fn check(g: &Graph) -> Vec<Finding> {
    let mut findings = Vec::new();
    let nt = g.tensors().len();

    // Producer table built by scanning node outputs ourselves, so a
    // graph whose cached producer index is stale still gets audited.
    let mut producers: Vec<Vec<u32>> = vec![Vec::new(); nt];
    for n in g.nodes() {
        for t in &n.outputs {
            if (t.0 as usize) < nt {
                producers[t.0 as usize].push(n.id.0);
            }
        }
    }
    for (t, who) in producers.iter().enumerate() {
        if who.len() > 1 {
            findings.push(Finding::error(
                Pass::Graph,
                Code::DuplicateProducer,
                format!("tensor {t}"),
                format!("produced by {} nodes: {who:?}", who.len()),
            ));
        }
    }

    for n in g.nodes() {
        for t in n.inputs.iter().chain(&n.outputs) {
            if t.0 as usize >= nt {
                findings.push(Finding::error(
                    Pass::Graph,
                    Code::DanglingRead,
                    node_loc(n),
                    format!("references tensor {} but the graph has {nt}", t.0),
                ));
            }
        }
        let need = min_inputs(&n.kind);
        if n.inputs.len() < need {
            findings.push(Finding::error(
                Pass::Graph,
                Code::ArityMismatch,
                node_loc(n),
                format!("kernel indexes {} inputs, node has {}", need, n.inputs.len()),
            ));
        }
        if n.outputs.is_empty() && !matches!(n.kind, OpKind::Output) {
            findings.push(Finding::error(
                Pass::Graph,
                Code::ArityMismatch,
                node_loc(n),
                "non-Output node produces no tensors".to_string(),
            ));
        }
        if let OpKind::Split { ways } = n.kind {
            if n.outputs.len() != ways {
                findings.push(Finding::error(
                    Pass::Graph,
                    Code::ArityMismatch,
                    node_loc(n),
                    format!("Split ways={} but {} outputs", ways, n.outputs.len()),
                ));
            }
        }
        if n.kind.class() == OpClass::Dynamic
            && (n.inputs.is_empty() || n.outputs.is_empty())
        {
            findings.push(Finding::error(
                Pass::Graph,
                Code::BarrierMalformed,
                node_loc(n),
                format!(
                    "dynamic-class barrier needs inputs and outputs to resolve \
                     shapes across the cut (has {} in, {} out)",
                    n.inputs.len(),
                    n.outputs.len()
                ),
            ));
        }
    }

    // Kahn's algorithm, replicated rather than calling `topo_order()`,
    // so a cycle names its member nodes instead of just failing.
    let nn = g.nodes().len();
    let mut indeg: Vec<usize> = vec![0; nn];
    for n in g.nodes() {
        indeg[n.id.0 as usize] = g.in_degree(n.id);
    }
    let mut queue: std::collections::VecDeque<_> = g
        .nodes()
        .iter()
        .filter(|n| indeg[n.id.0 as usize] == 0)
        .map(|n| n.id)
        .collect();
    let mut visited = 0usize;
    while let Some(id) = queue.pop_front() {
        visited += 1;
        for s in g.succs(id) {
            let d = &mut indeg[s.0 as usize];
            *d -= 1;
            if *d == 0 {
                queue.push_back(s);
            }
        }
    }
    if visited != nn {
        let stuck: Vec<u32> = g
            .nodes()
            .iter()
            .filter(|n| indeg[n.id.0 as usize] > 0)
            .map(|n| n.id.0)
            .collect();
        findings.push(Finding::error(
            Pass::Graph,
            Code::Cycle,
            format!("nodes {stuck:?}"),
            format!(
                "no topological order: {} of {} nodes are on or behind a cycle",
                stuck.len(),
                nn
            ),
        ));
    }

    // Dead ends are only meaningful once the graph declares sinks:
    // micro test graphs legitimately end on bare compute nodes.
    let has_output = g.nodes().iter().any(|n| matches!(n.kind, OpKind::Output));
    if has_output {
        for n in g.nodes() {
            if matches!(n.kind, OpKind::Output) || n.outputs.is_empty() {
                continue;
            }
            let consumed = n
                .outputs
                .iter()
                .any(|&t| (t.0 as usize) < nt && !g.consumers(t).is_empty());
            if !consumed {
                findings.push(Finding::warning(
                    Pass::Graph,
                    Code::DeadEnd,
                    node_loc(n),
                    "all outputs unconsumed; node is unreachable from any sink"
                        .to_string(),
                ));
            }
        }
    }

    findings
}
