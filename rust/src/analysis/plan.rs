//! Plan pass: verify a [`CapturedPlan`] against [`memory::liveness`]
//! and the §3.3 residency recomputation, without replaying it.
//!
//! A captured plan freezes everything the hot path trusts blindly at
//! replay time: arena offsets, wave lists, per-wave lease demands,
//! and the lane-merge topology. This pass re-derives each from the
//! graph/partition/plan the capture was built from and proves the
//! frozen copy is safe:
//!
//! * **arena aliasing** — recompute each captured branch's internal
//!   lifetimes ([`memory::analyze`]) and prove no two lifetimes that
//!   overlap in time share arena bytes ([`memory::aliasing_pairs`],
//!   Eq. 1's `may_reuse`);
//! * **wave order** — every branch-dependency edge must point forward
//!   in the flattened wave/sequential execution order;
//! * **merge topology** — every delegated branch must appear in the
//!   captured `preds_del` of each of its consumers, so the replay
//!   waits for the lane job to merge at (or before) the consumer's
//!   wave;
//! * **lease domination** — every captured per-wave demand, and the
//!   placed run-wide lease, must dominate the recomputed residency,
//!   so a governed replay can never under-lease.
//!
//! [`CapturedPlan`]: crate::exec::CapturedPlan
//! [`memory::liveness`]: crate::memory::liveness
//! [`memory::analyze`]: crate::memory::analyze
//! [`memory::aliasing_pairs`]: crate::memory::aliasing_pairs

use crate::branch::BranchPlan;
use crate::exec::CapturedPlan;
use crate::graph::Graph;
use crate::memory;
use crate::partition::Partition;
use crate::place::PlacementPlan;
use crate::sched;

use super::{Code, Finding, Pass};

/// Run the plan pass. `placement` must be the placement the replay
/// will run under (the same one the capture was made with); `None`
/// for a classic CPU-pool capture. Segment captures covering a
/// subset of the plan's branches are fine — checks apply to the
/// scheduled subset.
pub fn check(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    cp: &CapturedPlan,
    placement: Option<&PlacementPlan>,
) -> Vec<Finding> {
    let mut findings = Vec::new();
    let nb = plan.branches.len();
    let schedules = cp.schedules();

    if placement.is_some() != cp.is_placed() {
        findings.push(Finding::error(
            Pass::Plan,
            Code::PlanShapeMismatch,
            "CapturedPlan".to_string(),
            format!(
                "captured with_placement={} but replayed with placement={}",
                cp.is_placed(),
                placement.is_some()
            ),
        ));
    }

    // -- branch-id sanity: in range, no duplicates across schedules --
    let mut seen = vec![false; nb];
    let mut ids_ok = true;
    for (li, ls) in schedules.iter().enumerate() {
        for b in ls.all() {
            if b >= nb {
                findings.push(Finding::error(
                    Pass::Plan,
                    Code::PlanShapeMismatch,
                    format!("layer {li}"),
                    format!("schedules branch {b}, plan has {nb}"),
                ));
                ids_ok = false;
            } else if seen[b] {
                findings.push(Finding::error(
                    Pass::Plan,
                    Code::PlanShapeMismatch,
                    format!("layer {li}"),
                    format!("branch {b} scheduled twice"),
                ));
                ids_ok = false;
            } else {
                seen[b] = true;
            }
        }
    }
    if !ids_ok {
        return findings; // positional checks below would be nonsense
    }

    // -- wave order: dependency edges point forward ------------------
    // Flatten the execution order the replay will follow: per layer,
    // each wave is one position (its members run concurrently), then
    // the sequential tail one position each.
    let mut pos = vec![usize::MAX; nb];
    let mut cursor = 0usize;
    for ls in schedules {
        for wave in &ls.waves {
            for &b in wave {
                pos[b] = cursor;
            }
            cursor += 1;
        }
        for &b in &ls.sequential {
            pos[b] = cursor;
            cursor += 1;
        }
    }
    let branch_succs = plan.branch_succs();
    for (a, succs) in branch_succs.iter().enumerate() {
        if pos[a] == usize::MAX {
            continue;
        }
        for &b in succs {
            if pos[b] != usize::MAX && pos[b] <= pos[a] {
                findings.push(Finding::error(
                    Pass::Plan,
                    Code::WaveOrderViolation,
                    format!("branch {a} -> branch {b}"),
                    format!(
                        "consumer at flat position {} does not follow its \
                         producer at {}",
                        pos[b], pos[a]
                    ),
                ));
            }
        }
    }

    // -- arena aliasing: frozen offsets vs recomputed lifetimes ------
    for b in 0..nb {
        if pos[b] == usize::MAX {
            continue;
        }
        let Some(prog) = cp.prog(b) else { continue };
        let nodes = plan.branch_nodes(g, p, b);
        let lts = memory::analyze(g, &nodes);
        let internal: Vec<_> =
            lts.into_iter().filter(|lt| !lt.escapes).collect();
        let arena = prog.arena();
        if arena.offsets.len() != internal.len() {
            findings.push(Finding::error(
                Pass::Plan,
                Code::PlanShapeMismatch,
                format!("branch {b} arena"),
                format!(
                    "{} frozen offsets for {} internal lifetimes",
                    arena.offsets.len(),
                    internal.len()
                ),
            ));
            continue;
        }
        for (i, j) in memory::aliasing_pairs(arena, &internal) {
            findings.push(Finding::error(
                Pass::Plan,
                Code::ArenaOverlap,
                format!("branch {b} arena"),
                format!(
                    "tensors {} (def {}, last use {}, offset {}) and {} \
                     (def {}, last use {}, offset {}) are live together \
                     but share arena bytes",
                    internal[i].tensor.0,
                    internal[i].def_pos,
                    internal[i].last_use,
                    arena.offsets[i],
                    internal[j].tensor.0,
                    internal[j].def_pos,
                    internal[j].last_use,
                    arena.offsets[j],
                ),
            ));
        }
    }

    // -- lease domination: frozen demands vs §3.3 recomputation ------
    // Captured demands are always computed from the engine's
    // max-shape branch memories (even for resolved segment captures),
    // so the recomputation here is exact, not a bound.
    let mems = memory::branch_memories(g, p, plan);
    let on_host = |b: usize| match placement {
        Some(pl) => !pl.is_delegated(b),
        None => !plan.branches[b].has_delegate,
    };
    let demand = |wave: &[usize]| -> u64 {
        wave.iter()
            .filter(|&&b| on_host(b))
            .map(|&b| mems[b].total() as u64)
            .sum()
    };
    if cp.num_layers() != schedules.len() {
        findings.push(Finding::error(
            Pass::Plan,
            Code::PlanShapeMismatch,
            "CapturedPlan.layers".to_string(),
            format!(
                "{} demand layers for {} schedules",
                cp.num_layers(),
                schedules.len()
            ),
        ));
        return findings;
    }
    for (li, ls) in schedules.iter().enumerate() {
        let cl = cp.layer(li);
        if cl.waves.len() != ls.waves.len()
            || cl.sequential.len() != ls.sequential.len()
        {
            findings.push(Finding::error(
                Pass::Plan,
                Code::PlanShapeMismatch,
                format!("layer {li} demands"),
                format!(
                    "{} wave + {} sequential demands for {} waves + {} \
                     sequential branches",
                    cl.waves.len(),
                    cl.sequential.len(),
                    ls.waves.len(),
                    ls.sequential.len()
                ),
            ));
            continue;
        }
        for (wi, (&got, wave)) in cl.waves.iter().zip(&ls.waves).enumerate() {
            let want = demand(wave);
            if got < want {
                findings.push(Finding::error(
                    Pass::Plan,
                    Code::LeaseUnderProvisioned,
                    format!("layer {li} wave {wi}"),
                    format!(
                        "captured lease demand {got} < recomputed residency \
                         {want}; a governed replay would under-lease"
                    ),
                ));
            }
        }
        for (si, (&got, &b)) in
            cl.sequential.iter().zip(&ls.sequential).enumerate()
        {
            let want = demand(&[b]);
            if got < want {
                findings.push(Finding::error(
                    Pass::Plan,
                    Code::LeaseUnderProvisioned,
                    format!("layer {li} sequential {si} (branch {b})"),
                    format!(
                        "captured lease demand {got} < recomputed residency \
                         {want}; a governed replay would under-lease"
                    ),
                ));
            }
        }
    }

    // -- placed topology: merge-by-first-consumer + run-wide lease ---
    let Some(pl) = placement else { return findings };
    let delegated_here =
        schedules.iter().any(|ls| ls.all().any(|b| pl.is_delegated(b)));
    let Some(pp) = cp.placed() else {
        if delegated_here {
            findings.push(Finding::error(
                Pass::Plan,
                Code::PlanShapeMismatch,
                "CapturedPlan.placed".to_string(),
                "schedules delegate branches but the capture froze no lane \
                 topology"
                    .to_string(),
            ));
        }
        return findings;
    };

    let num_lanes = pl
        .delegated()
        .filter_map(|b| pl.lane_of(b))
        .max()
        .map_or(0, |m| m + 1);
    if pp.num_lanes != num_lanes {
        findings.push(Finding::error(
            Pass::Plan,
            Code::PlanShapeMismatch,
            "CapturedPlan.placed.num_lanes".to_string(),
            format!("froze {} lanes, placement needs {num_lanes}", pp.num_lanes),
        ));
        return findings;
    }
    let mut used = vec![false; num_lanes];
    for (b, &scheduled) in seen.iter().enumerate() {
        if scheduled {
            if let Some(l) = pl.lane_of(b) {
                used[l] = true;
            }
        }
    }
    if pp.used != used {
        findings.push(Finding::error(
            Pass::Plan,
            Code::PlanShapeMismatch,
            "CapturedPlan.placed.used".to_string(),
            format!("froze lane-use {:?}, recomputed {used:?}", pp.used),
        ));
    }
    if pp.preds_del.len() != nb {
        findings.push(Finding::error(
            Pass::Plan,
            Code::PlanShapeMismatch,
            "CapturedPlan.placed.preds_del".to_string(),
            format!("{} entries for {nb} branches", pp.preds_del.len()),
        ));
    } else {
        for d in pl.delegated() {
            for &cns in &branch_succs[d] {
                if !pp.preds_del[cns].contains(&d) {
                    findings.push(Finding::error(
                        Pass::Plan,
                        Code::MergeTooLate,
                        format!("lane job {d} -> consumer branch {cns}"),
                        "consumer's frozen merge set omits the lane job; the \
                         replay would read its output before the merge"
                            .to_string(),
                    ));
                }
            }
        }
    }

    let inflight =
        sched::placed_inflight_staging_from(&branch_succs, pl, schedules);
    let want = schedules
        .iter()
        .zip(&inflight)
        .map(|(ls, &infl)| sched::placed_layer_demand(&mems, pl, ls, infl))
        .max()
        .unwrap_or(0);
    if pp.run_demand < want {
        findings.push(Finding::error(
            Pass::Plan,
            Code::LeaseUnderProvisioned,
            "CapturedPlan.placed.run_demand".to_string(),
            format!(
                "frozen run-wide lease {} < recomputed placed residency \
                 {want}; in-flight staging would overrun the governor lease",
                pp.run_demand
            ),
        ));
    }

    findings
}
