//! Static verification of the runtime's core artifacts (no execution).
//!
//! The runtime's correctness story — branch-aware arena reuse (§3.2),
//! governed leases (§3.3), lane placement, and bitwise captured-plan
//! replay (§3.4) — is otherwise enforced only dynamically: a bad
//! artifact is caught only if a test happens to execute that path.
//! This module audits the artifacts themselves, before anything runs:
//!
//! | Pass | Artifact | What it proves |
//! |------|----------|----------------|
//! | [`graph`] | [`Graph`](crate::graph::Graph) | acyclic, no dangling reads, one producer per tensor, op arity, no dead ends, dynamic-op barriers well-formed |
//! | [`placement`] | [`PlacementPlan`](crate::place::PlacementPlan) | `delegate_safe` holds for every delegation, lanes exist and are reachable, remote lanes never take dynamic work, staging bytes match the recomputation |
//! | [`plan`] | [`CapturedPlan`](crate::exec::CapturedPlan) | arena offsets alias only lifetime-disjoint tensors, wave order respects branch dependencies, lane jobs merge by their first consumer, captured lease demands dominate the recomputed §3.3 residency |
//!
//! The fourth (determinism) pass is source-level and lives in
//! `tools/check_determinism.py` plus the feature-gated interleaving
//! tests (`cargo test --features interleave --test interleave`).
//!
//! Every check returns structured [`Finding`]s instead of panicking,
//! so tests can assert the exact finding a seeded-broken artifact
//! produces, and `parallax analyze --all` can sweep every shipped
//! model × device profile. Debug builds also run the plan pass as a
//! pre-replay hook inside [`Engine::run_captured`]
//! (`exec`), turning a corrupted capture into a structured panic
//! instead of silent memory corruption.
//!
//! [`Engine::run_captured`]: crate::exec::Engine::run_captured

pub mod graph;
pub mod placement;
pub mod plan;

use std::fmt;

use crate::branch::{self, DEFAULT_BETA};
use crate::ctrl::ShapeEnv;
use crate::device::SocProfile;
use crate::exec::Engine;
use crate::graph::OpClass;
use crate::models::ModelKind;
use crate::partition::{partition, CostModel};
use crate::place::{self, PlacePolicy};
use crate::sched::SchedCfg;

/// Which analyzer pass produced a finding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pass {
    /// Structural audit of a [`Graph`](crate::graph::Graph).
    Graph,
    /// Legality audit of a [`PlacementPlan`](crate::place::PlacementPlan).
    Placement,
    /// Replay-safety audit of a [`CapturedPlan`](crate::exec::CapturedPlan).
    Plan,
}

impl fmt::Display for Pass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Pass::Graph => "graph",
            Pass::Placement => "placement",
            Pass::Plan => "plan",
        })
    }
}

/// How bad a finding is.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Suspicious but not provably unsafe (e.g. an unreachable node).
    Warning,
    /// Executing the artifact would be incorrect or unsafe.
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        })
    }
}

/// Machine-checkable finding class, so tests can pin the exact
/// finding a seeded-broken artifact must produce.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Code {
    /// The graph has a cycle (Kahn's order excludes ≥1 node).
    Cycle,
    /// A node reads a tensor id outside the graph's tensor table.
    DanglingRead,
    /// Two nodes claim to produce the same tensor.
    DuplicateProducer,
    /// A node's input/output count is wrong for its op kind.
    ArityMismatch,
    /// A non-Output node's outputs are consumed by nobody.
    DeadEnd,
    /// A dynamic-class (control-barrier) op with no inputs or outputs.
    BarrierMalformed,
    /// A delegated branch fails [`place::delegate_safe`] (dynamic op,
    /// dynamic shape, or no delegate region).
    IllegalDelegation,
    /// A delegated branch targets an unreachable lane.
    UnreachableLane,
    /// A delegated branch targets a lane index the SoC doesn't have.
    LaneOutOfBounds,
    /// Recorded staging bytes disagree with the recomputation.
    StagingMismatch,
    /// Two lifetime-overlapping tensors share arena bytes.
    ArenaOverlap,
    /// A branch is scheduled before one of its predecessors.
    WaveOrderViolation,
    /// A lane job's output merges after its first consumer's wave.
    MergeTooLate,
    /// A captured lease demand is below the recomputed residency.
    LeaseUnderProvisioned,
    /// The artifact's vectors don't line up (lengths, duplicate or
    /// out-of-range branch ids, missing per-branch entries).
    PlanShapeMismatch,
}

impl fmt::Display for Code {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Code::Cycle => "cycle",
            Code::DanglingRead => "dangling-read",
            Code::DuplicateProducer => "duplicate-producer",
            Code::ArityMismatch => "arity-mismatch",
            Code::DeadEnd => "dead-end",
            Code::BarrierMalformed => "barrier-malformed",
            Code::IllegalDelegation => "illegal-delegation",
            Code::UnreachableLane => "unreachable-lane",
            Code::LaneOutOfBounds => "lane-out-of-bounds",
            Code::StagingMismatch => "staging-mismatch",
            Code::ArenaOverlap => "arena-overlap",
            Code::WaveOrderViolation => "wave-order-violation",
            Code::MergeTooLate => "merge-too-late",
            Code::LeaseUnderProvisioned => "lease-under-provisioned",
            Code::PlanShapeMismatch => "plan-shape-mismatch",
        })
    }
}

/// One violation found by a static pass.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// The pass that produced this finding.
    pub pass: Pass,
    /// Machine-checkable finding class.
    pub code: Code,
    /// How bad it is.
    pub severity: Severity,
    /// Where: node/tensor/branch/lane/wave context, human-readable.
    pub location: String,
    /// What went wrong, with the numbers that prove it.
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "[{}] {}/{} at {}: {}",
            self.severity, self.pass, self.code, self.location, self.message
        )
    }
}

impl Finding {
    fn error(pass: Pass, code: Code, location: String, message: String) -> Self {
        Finding { pass, code, severity: Severity::Error, location, message }
    }

    fn warning(pass: Pass, code: Code, location: String, message: String) -> Self {
        Finding { pass, code, severity: Severity::Warning, location, message }
    }
}

/// Run every applicable pass over one shipped model on one device
/// profile, building the same artifacts the runtime would: partition
/// with the profile's cost model, branch/layer plan, `Auto` placement,
/// and — for fully static graphs — a placed [`CapturedPlan`]
/// (dynamic graphs replan per segment at runtime, so there is no
/// whole-graph capture to audit).
///
/// [`CapturedPlan`]: crate::exec::CapturedPlan
pub fn analyze_model(kind: ModelKind, soc: &SocProfile) -> Vec<Finding> {
    let g = kind.build();
    let mut findings = graph::check(&g);

    let cm = CostModel::from_profile(soc);
    let p = partition(&g, &cm);
    let plan = branch::plan(&g, &p, DEFAULT_BETA);
    let placed = place::assign(&g, &p, &plan, soc, PlacePolicy::Auto);
    findings.extend(placement::check(&g, &p, &plan, soc, &placed));

    let fully_static =
        g.nodes().iter().all(|n| n.kind.class() != OpClass::Dynamic);
    if fully_static {
        let mems = crate::memory::branch_memories(&g, &p, &plan);
        let cfg = SchedCfg::default();
        let schedules = crate::sched::schedule(&plan, &mems, 1 << 34, &cfg);
        let engine = Engine::new(&g, &p, &plan, None);
        let cp = engine.capture(&schedules, &ShapeEnv::unresolved(), Some(&placed));
        findings.extend(plan::check(&g, &p, &plan, &cp, Some(&placed)));
    }
    findings
}

/// Sweep every shipped model × device profile. Returns one
/// `("model @ device", findings)` row per combination, in a
/// deterministic order.
pub fn analyze_all() -> Vec<(String, Vec<Finding>)> {
    let mut rows = Vec::new();
    for kind in ModelKind::ALL {
        for mk in SocProfile::ALL {
            let soc = mk();
            let label = format!("{} @ {}", kind.slug(), soc.name);
            rows.push((label, analyze_model(kind, &soc)));
        }
    }
    rows
}
