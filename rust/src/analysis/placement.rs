//! Placement pass: prove a [`PlacementPlan`] violates no legality
//! predicate, without running anything.
//!
//! Re-derives the rules `place::assign` is supposed to respect and
//! checks the plan against them from scratch: every delegated branch
//! must satisfy [`delegate_safe`] (static-class ops with static
//! shapes inside a delegate region — which also keeps dynamic work
//! off *remote* lanes, §3.4), its lane must exist and be reachable on
//! this SoC, and its recorded staging bytes must equal the
//! recomputed delegate-I/O figure (staging is folded into layer
//! demand by `sched::placed_layer_demand`, so a wrong figure
//! under-leases the governor).
//!
//! [`delegate_safe`]: crate::place::delegate_safe

use crate::branch::BranchPlan;
use crate::device::SocProfile;
use crate::graph::{Graph, OpClass};
use crate::partition::Partition;
use crate::place::{self, PlacementPlan};

use super::{Code, Finding, Pass};

/// Run the placement pass. Returns one [`Finding`] per violated
/// legality predicate; empty means the plan is safe to execute.
pub fn check(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    soc: &SocProfile,
    pl: &PlacementPlan,
) -> Vec<Finding> {
    let mut findings = Vec::new();
    let nb = plan.branches.len();

    for (name, len) in [
        ("assignment", pl.assignment.len()),
        ("cpu_latency_s", pl.cpu_latency_s.len()),
        ("delegate_latency_s", pl.delegate_latency_s.len()),
        ("staging_bytes", pl.staging_bytes.len()),
    ] {
        if len != nb {
            findings.push(Finding::error(
                Pass::Placement,
                Code::PlanShapeMismatch,
                format!("PlacementPlan.{name}"),
                format!("{len} entries for {nb} branches"),
            ));
        }
    }
    if pl.assignment.len() != nb {
        return findings; // per-branch checks would index out of range
    }

    // Dynamic-class ops are control barriers: the partitioner must
    // leave them on the CPU or `ctrl` can never resolve them.
    for n in g.nodes() {
        if n.kind.class() == OpClass::Dynamic && !p.is_cpu(n.id) {
            findings.push(Finding::error(
                Pass::Placement,
                Code::BarrierMalformed,
                format!("node {} `{}`", n.id.0, n.name),
                "dynamic-class op assigned to a delegate region".to_string(),
            ));
        }
    }

    for b in 0..nb {
        let Some(lane) = pl.lane_of(b) else { continue };
        let loc = format!("branch {b} -> lane {lane}");
        if lane >= soc.lanes.len() {
            findings.push(Finding::error(
                Pass::Placement,
                Code::LaneOutOfBounds,
                loc,
                format!("SoC `{}` has {} lanes", soc.name, soc.lanes.len()),
            ));
            continue;
        }
        let l = &soc.lanes[lane];
        let loc = format!("branch {b} -> lane {lane} `{}`", l.name);
        if !l.reachable {
            findings.push(Finding::error(
                Pass::Placement,
                Code::UnreachableLane,
                loc.clone(),
                "lane exists in the profile but the runtime cannot reach it"
                    .to_string(),
            ));
        }
        if !place::delegate_safe(g, p, plan, b) {
            let kind = if l.remote { "remote lane" } else { "delegate lane" };
            findings.push(Finding::error(
                Pass::Placement,
                Code::IllegalDelegation,
                loc.clone(),
                format!(
                    "branch fails delegate_safe (dynamic op, dynamic shape, \
                     or no delegate region) yet is placed on a {kind}"
                ),
            ));
        }
        let want = place::staging_bytes(g, p, plan, b);
        if pl.staging_bytes[b] != want {
            findings.push(Finding::error(
                Pass::Placement,
                Code::StagingMismatch,
                loc,
                format!(
                    "recorded {} staging bytes, recomputed {want}; layer \
                     demand would mis-lease by the difference",
                    pl.staging_bytes[b]
                ),
            ));
        }
    }

    findings
}
