//! Baseline framework personalities (ORT, ExecuTorch, TFLite) and the
//! top-level per-framework inference pipeline used by the eval harness.
//!
//! Each baseline is a policy triple:
//! * **delegation** — which regions offload in heterogeneous mode
//!   (Table 1's capability matrix),
//! * **execution** — strictly sequential inter-op (branch_parallel =
//!   false) with the framework's intra-op thread pool,
//! * **memory** — global greedy arena (their planners' shared-buffer
//!   strategy).
//!
//! Parallax is the same machinery with its cost-model partitioning,
//! Branch-Layer parallel execution and per-branch arenas.

use std::sync::Arc;

use crate::branch::{self, BranchPlan, DEFAULT_BETA};
use crate::device::SocProfile;
use crate::graph::Graph;
use crate::memory::{branch_memories, BranchMemory};
use crate::models::ModelKind;
use crate::partition::{partition, CostModel, Partition};
use crate::sched::{self, LayerSchedule, MemoryGovernor, SchedCfg};
use crate::sim::{activation_footprint, simulate, FrameworkProfile, Mode, SimResult};
use crate::util::rng::Rng;

/// ONNXRuntime-like: fastest interpreter, partial offload, handles
/// dynamic shapes on CPU, sequential inter-op.
pub fn ort() -> FrameworkProfile {
    FrameworkProfile {
        name: "ORT",
        per_op_dispatch_s: 1.6e-6,
        graph_overhead_s: 0.9e-3,
        sync_overhead_s: 0.0,
        mem_overhead_bytes: 68 << 20,
        branch_parallel: false,
        intra_op_quality: 0.42,
        dyn_realloc_s: 16e-6,
        ctx_switch_s: 4.0e-3,
    }
}

/// ExecuTorch-like: CPU-only (no NNAPI), lean runtime.
pub fn executorch() -> FrameworkProfile {
    FrameworkProfile {
        name: "ExecuTorch",
        per_op_dispatch_s: 2.1e-6,
        graph_overhead_s: 0.7e-3,
        sync_overhead_s: 0.0,
        mem_overhead_bytes: 62 << 20,
        branch_parallel: false,
        intra_op_quality: 0.35,
        dyn_realloc_s: 22e-6,
        ctx_switch_s: 5.0e-3,
    }
}

/// TFLite-like: heavier interpreter, whole-graph CPU revert on dynamic
/// ops, lowest memory (aggressive reuse).
pub fn tflite() -> FrameworkProfile {
    FrameworkProfile {
        name: "TFLite",
        per_op_dispatch_s: 3.0e-6,
        graph_overhead_s: 1.2e-3,
        sync_overhead_s: 0.0,
        mem_overhead_bytes: 58 << 20,
        branch_parallel: false,
        intra_op_quality: 0.30,
        dyn_realloc_s: 30e-6,
        ctx_switch_s: 4.5e-3,
    }
}

/// Parallax: TFLite-integrated runtime + branch parallel execution.
pub fn parallax() -> FrameworkProfile {
    FrameworkProfile {
        name: "Parallax",
        per_op_dispatch_s: 3.0e-6, // built on the TFLite interpreter
        graph_overhead_s: 1.3e-3,  // + partition/branch bookkeeping
        sync_overhead_s: 45e-6,    // wave fork/join
        mem_overhead_bytes: 60 << 20,
        branch_parallel: true,
        intra_op_quality: 0.30,
        dyn_realloc_s: 2e-6, // arena-confined resize (§3.2)
        ctx_switch_s: 0.4e-3, // fine-grained subgraph control
    }
}

/// Framework id for the eval tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Framework {
    Ort,
    ExecuTorch,
    TfLite,
    Parallax,
}

impl Framework {
    pub const ALL: [Framework; 4] =
        [Framework::Ort, Framework::ExecuTorch, Framework::TfLite, Framework::Parallax];

    pub fn profile(&self) -> FrameworkProfile {
        match self {
            Framework::Ort => ort(),
            Framework::ExecuTorch => executorch(),
            Framework::TfLite => tflite(),
            Framework::Parallax => parallax(),
        }
    }
}

/// Why a framework/mode combination is unsupported ("-" in Table 3).
#[derive(Clone, Debug, PartialEq)]
pub enum Unsupported {
    /// No NNAPI/OpenCL path for this framework on this device.
    NoAcceleratorPath,
    /// Framework rejects graphs with dynamic ops in delegate mode.
    DynamicOps,
    /// Operator-set mismatch (e.g. ORT's NNAPI EP rejects NMS graphs).
    OperatorMismatch,
    /// Nothing worth delegating survived partitioning.
    NothingDelegated,
}

/// Build the per-framework partition for a mode, or report "-".
pub fn partition_for(
    fw: Framework,
    g: &Graph,
    soc: &SocProfile,
    mode: Mode,
) -> Result<Partition, Unsupported> {
    let cpu_all = || {
        partition(
            g,
            &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
        )
    };
    if mode == Mode::CpuOnly {
        return Ok(cpu_all());
    }
    let has_dynamic = g
        .nodes()
        .iter()
        .any(|n| g.node_has_dynamic_shape(n.id) || n.kind.is_control_flow());
    // accelerator reachability per framework
    let reachable = match fw {
        Framework::Ort | Framework::ExecuTorch => soc.nnapi,
        // TFLite + Parallax can fall back to the OpenCL path (P30 Pro)
        Framework::TfLite | Framework::Parallax => true,
    };
    if !reachable || fw == Framework::ExecuTorch {
        // ExecuTorch: no NNAPI backend at all (Table 3: every Het = "-")
        return Err(Unsupported::NoAcceleratorPath);
    }
    let p = match fw {
        // ORT: offload every eligible connected region, however small;
        // but its NNAPI EP rejects graphs with NMS outright (Table 3:
        // YOLO ORT Het = "-", "operator-set mismatch").
        Framework::Ort => {
            if g.nodes()
                .iter()
                .any(|n| matches!(n.kind, crate::graph::OpKind::NonMaxSuppression))
            {
                return Err(Unsupported::OperatorMismatch);
            }
            partition(g, &CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX })
        }
        // TFLite: reverts the whole graph to CPU when dynamic ops exist
        Framework::TfLite => {
            if has_dynamic {
                return Err(Unsupported::DynamicOps);
            }
            partition(g, &CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX })
        }
        // Parallax: §3.1 cost-model pruning
        Framework::Parallax => partition(g, &CostModel::default()),
        Framework::ExecuTorch => unreachable!(),
    };
    if p.regions.is_empty() {
        return Err(Unsupported::NothingDelegated);
    }
    Ok(p)
}

/// Everything needed to run repeated inferences of one (framework,
/// model, device, mode) cell.
pub struct Pipeline {
    pub framework: Framework,
    pub profile: FrameworkProfile,
    pub soc: SocProfile,
    pub mode: Mode,
    pub graph: Graph,
    pub partition: Partition,
    pub plan: BranchPlan,
    pub mems: Vec<BranchMemory>,
    pub cfg: SchedCfg,
    pub weight_bytes: u64,
    /// Precomputed fill-independent activation footprint (§Perf).
    pub activation_bytes: u64,
    /// Shared device-wide ledger; when set, per-inference budgets are
    /// capped by the governor so co-resident pipelines plan within one
    /// global envelope.
    pub governor: Option<Arc<MemoryGovernor>>,
}

impl Pipeline {
    /// Build the pipeline, or report why the cell is "-".
    pub fn build(
        fw: Framework,
        model: ModelKind,
        soc: &SocProfile,
        mode: Mode,
        cfg: SchedCfg,
    ) -> Result<Self, Unsupported> {
        let g = model.build();
        let p = partition_for(fw, &g, soc, mode)?;
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let mems = branch_memories(&g, &p, &plan);
        let profile = fw.profile();
        let activation_bytes = activation_footprint(&g, &p, &plan, &profile);
        Ok(Self {
            framework: fw,
            profile,
            soc: soc.clone(),
            mode,
            weight_bytes: model.weight_bytes(),
            graph: g,
            partition: p,
            plan,
            mems,
            cfg,
            activation_bytes,
            governor: None,
        })
    }

    /// Build a pipeline around an arbitrary graph (micro-benchmark and
    /// serving-test workloads) instead of a zoo [`ModelKind`].  The
    /// caller picks the partition cost model; weight bytes are zero
    /// (micro graphs synthesize their weights).  No capability gating:
    /// this is the Parallax-style path for graphs that have no Table 3
    /// cell of their own.
    pub fn from_graph(
        fw: Framework,
        g: Graph,
        cm: &CostModel,
        soc: &SocProfile,
        mode: Mode,
        cfg: SchedCfg,
    ) -> Self {
        let p = partition(&g, cm);
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let mems = branch_memories(&g, &p, &plan);
        let profile = fw.profile();
        let activation_bytes = activation_footprint(&g, &p, &plan, &profile);
        Self {
            framework: fw,
            profile,
            soc: soc.clone(),
            mode,
            weight_bytes: 0,
            graph: g,
            partition: p,
            plan,
            mems,
            cfg,
            activation_bytes,
            governor: None,
        }
    }

    /// Attach a shared device-wide [`MemoryGovernor`] (builder style).
    pub fn with_governor(mut self, governor: Arc<MemoryGovernor>) -> Self {
        self.governor = Some(governor);
        self
    }

    /// Worst-case concurrent §3.3 demand of this pipeline: the max over
    /// layers of the summed CPU branch peaks — what a serving host
    /// should lease from the governor while a request is in flight.
    pub fn peak_branch_demand(&self) -> u64 {
        Self::peak_layer_demand(&self.plan, &self.mems)
    }

    /// The §3.3 layer-peak aggregation over an arbitrary memory table:
    /// max over layers of the summed CPU branch peaks.  Shared by
    /// [`Pipeline::peak_branch_demand`] (worst-case M_i) and the §3.4
    /// serving adapter, which evaluates it with resolved-shape
    /// memories per fill bucket.
    pub fn peak_layer_demand(plan: &BranchPlan, mems: &[BranchMemory]) -> u64 {
        plan.layers
            .iter()
            .map(|layer| {
                layer
                    .iter()
                    .filter(|&&b| !plan.branches[b].has_delegate)
                    .map(|&b| mems[b].total() as u64)
                    .sum::<u64>()
            })
            .max()
            .unwrap_or(0)
    }

    /// [`Pipeline::peak_layer_demand`] under a heterogeneous placement
    /// (`crate::place`): delegated branches contribute their
    /// host-visible delegate-I/O staging instead of a host arena —
    /// held *in flight* from their dispatch layer until their first
    /// consumer's layer, matching the cross-layer overlap the real
    /// engine runs — and `has_delegate` branches the placement kept on
    /// the CPU count at their full M_i.  What a serving host should
    /// lease per in-flight batch when the model was registered with a
    /// placement.
    pub fn peak_placed_demand(&self, placement: &crate::place::PlacementPlan) -> u64 {
        // one pseudo-schedule per layer lets the shared in-flight
        // staging accounting compute the dispatch→merge spans
        let pseudo: Vec<LayerSchedule> = self
            .plan
            .layers
            .iter()
            .map(|l| LayerSchedule { waves: vec![l.clone()], sequential: vec![] })
            .collect();
        let inflight = sched::placed_inflight_staging(&self.plan, placement, &pseudo);
        self.plan
            .layers
            .iter()
            .zip(&inflight)
            .map(|(layer, &staging)| {
                let cpu: u64 = layer
                    .iter()
                    .filter(|&&b| !placement.is_delegated(b))
                    .map(|&b| self.mems[b].total() as u64)
                    .sum();
                staging + cpu
            })
            .max()
            .unwrap_or(0)
    }

    /// Schedule for one inference (queries simulated OS free memory).
    pub fn schedule(&self, rng: &mut Rng) -> Vec<LayerSchedule> {
        if self.profile.branch_parallel {
            let free = self.soc.query_free_memory(rng);
            let mut budget = self.cfg.budget(free);
            if let Some(gov) = &self.governor {
                // one shared envelope: never plan past the device ledger
                budget = budget.min(gov.budget());
            }
            sched::schedule(&self.plan, &self.mems, budget, &self.cfg)
        } else {
            // sequential frameworks: every branch one-at-a-time
            self.plan
                .layers
                .iter()
                .map(|l| LayerSchedule { waves: vec![], sequential: l.clone() })
                .collect()
        }
    }

    /// Run one inference with a dynamic-fill draw.
    pub fn run(&self, rng: &mut Rng, fill: f64) -> SimResult {
        self.run_with_mode(rng, fill, self.mode)
    }

    /// [`Pipeline::run`] under an explicit execution mode, regardless
    /// of how the pipeline was built.  The serving tier uses this for
    /// the degrade path: a deadline-squeezed request on a
    /// heterogeneous-placed model re-runs as `Mode::CpuOnly` without
    /// cloning or re-partitioning the pipeline (same graph, partition,
    /// schedule draw — only the delegate pricing changes).
    pub fn run_with_mode(&self, rng: &mut Rng, fill: f64, mode: Mode) -> SimResult {
        let schedules = self.schedule(rng);
        simulate(
            &self.graph,
            &self.partition,
            &self.plan,
            &schedules,
            &self.mems,
            &self.profile,
            &self.soc,
            &self.cfg,
            mode,
            fill,
            self.weight_bytes,
            self.activation_bytes,
        )
    }

    /// The paper's measurement protocol: 5 warm-ups + `n` timed runs
    /// over random inputs; returns per-run results.  The input-draw
    /// stream is independent of the scheduler's free-memory jitter so
    /// frameworks see identical inputs for a given seed.
    pub fn run_protocol(&self, n: usize, seed: u64) -> Vec<SimResult> {
        let mut fill_rng = Rng::new(seed);
        let mut sched_rng = Rng::new(seed ^ 0x5EED_CAFE);
        (0..n)
            .map(|_| {
                // input-length distribution: text models mostly short
                // inputs, occasionally full-length (Table 3 min/max).
                let fill = 0.15 + 0.85 * fill_rng.f64();
                self.run(&mut sched_rng, fill)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executorch_never_heterogeneous() {
        let soc = SocProfile::pixel6();
        for m in ModelKind::ALL {
            let r = Pipeline::build(
                Framework::ExecuTorch, m, &soc, Mode::Heterogeneous, SchedCfg::default(),
            );
            assert!(r.is_err(), "{}", m.display_name());
        }
    }

    #[test]
    fn tflite_rejects_dynamic_in_het() {
        let soc = SocProfile::pixel6();
        // YOLO has NMS -> dynamic -> "-"
        assert!(matches!(
            Pipeline::build(Framework::TfLite, ModelKind::Yolov8n, &soc, Mode::Heterogeneous, SchedCfg::default()),
            Err(Unsupported::DynamicOps)
        ));
        // SwinV2 is fully static -> supported (Table 3 shows TFLite Het)
        assert!(Pipeline::build(
            Framework::TfLite, ModelKind::Swinv2Tiny, &soc, Mode::Heterogeneous, SchedCfg::default()
        )
        .is_ok());
    }

    #[test]
    fn ort_het_blocked_on_p30() {
        let soc = SocProfile::p30_pro();
        assert!(matches!(
            Pipeline::build(Framework::Ort, ModelKind::ClipText, &soc, Mode::Heterogeneous, SchedCfg::default()),
            Err(Unsupported::NoAcceleratorPath)
        ));
    }

    #[test]
    fn cpu_mode_always_supported() {
        let soc = SocProfile::p30_pro();
        for fw in Framework::ALL {
            for m in ModelKind::ALL {
                assert!(
                    Pipeline::build(fw, m, &soc, Mode::CpuOnly, SchedCfg::default()).is_ok(),
                    "{:?} {}",
                    fw,
                    m.display_name()
                );
            }
        }
    }

    #[test]
    fn parallax_faster_than_tflite_on_whisper_cpu() {
        // the paper's headline CPU-only claim (15-31% on fragmented
        // models); check the *direction* holds in the simulator.
        let soc = SocProfile::pixel6();
        let cfg = SchedCfg::default();
        let plx = Pipeline::build(Framework::Parallax, ModelKind::WhisperTiny, &soc, Mode::CpuOnly, cfg).unwrap();
        let tfl = Pipeline::build(Framework::TfLite, ModelKind::WhisperTiny, &soc, Mode::CpuOnly, cfg).unwrap();
        let rp: Vec<_> = plx.run_protocol(10, 7);
        let rt: Vec<_> = tfl.run_protocol(10, 7);
        let mp = rp.iter().map(|r| r.latency_s).sum::<f64>() / rp.len() as f64;
        let mt = rt.iter().map(|r| r.latency_s).sum::<f64>() / rt.len() as f64;
        assert!(
            mp < mt,
            "Parallax {mp:.4}s should beat TFLite {mt:.4}s on Whisper CPU"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let soc = SocProfile::pixel6();
        let p = Pipeline::build(Framework::Parallax, ModelKind::ClipText, &soc, Mode::CpuOnly, SchedCfg::default()).unwrap();
        let a = p.run_protocol(5, 42);
        let b = p.run_protocol(5, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.latency_s, y.latency_s);
        }
    }
}
