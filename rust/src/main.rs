//! `parallax` — CLI for the Parallax reproduction.
//!
//! ```text
//! parallax run   --model clip-text --device pixel6 --mode cpu [--threads 6]
//! parallax eval  <table3|table4|table5|table6|table7|fig2|fig3|hetero|serving|remote|all>
//! parallax inspect --model whisper-tiny        # graph/branch/layer stats
//! parallax analyze --all                       # static artifact audit
//! parallax serve --requests 64 --concurrency 8 # governed serving demo
//! parallax serve --remote --deadline-ms 5      # + device–edge spill lane
//! parallax smoke                               # PJRT round-trip check
//! ```

use parallax::baselines::{Framework, Pipeline};
use parallax::branch::{self, DEFAULT_BETA};
use parallax::config::{RawConfig, RunConfig};
use parallax::device::SocProfile;
use parallax::models::ModelKind;
use parallax::partition::{partition, CostModel};
use parallax::sim::Mode;
use parallax::util::cli::Args;
use parallax::util::stats::summarize;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "run" => cmd_run(&args),
        "eval" => cmd_eval(&args),
        "inspect" => cmd_inspect(&args),
        "analyze" => cmd_analyze(&args),
        "serve" => cmd_serve(&args),
        "smoke" => cmd_smoke(),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = r#"parallax — runtime parallelization for operator fallbacks (paper repro)

USAGE:
  parallax run     --model <slug> --device <name> [--mode cpu|het]
                   [--threads N] [--margin F] [--runs N] [--framework NAME]
                   [--config file.toml]
  parallax eval    <table3|table4|table5|table6|table7|fig2|fig3|hetero|serving|remote|all>
  parallax inspect --model <slug> [--device <name>]
  parallax analyze [--all | --model <slug> --device <name>]
  parallax serve   [--requests N] [--concurrency N] [--threads N]
                   [--workers N] [--batch N] [--budget-mb N]
                   [--deadline-ms F] [--remote] [--uplink-ms F]
                   [--link-bw-mbps F] [--drop-p F] [--link-seed N]
                   [--config file.toml]
  parallax smoke

models:  yolov8n whisper-tiny swinv2-tiny clip-text distilbert
devices: pixel6 p30pro redmik50
"#;

fn run_config(args: &Args) -> anyhow::Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_raw(
            &RawConfig::load(std::path::Path::new(path)).map_err(anyhow::Error::msg)?,
        )
        .map_err(anyhow::Error::msg)?,
        None => RunConfig::default(),
    };
    if let Some(m) = args.get("model") {
        cfg.model = ModelKind::from_slug(m)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{m}'"))?;
    }
    if let Some(d) = args.get("device") {
        cfg.device =
            SocProfile::by_name(d).ok_or_else(|| anyhow::anyhow!("unknown device '{d}'"))?;
    }
    if let Some(m) = args.get("mode") {
        cfg.mode = match m {
            "cpu" => Mode::CpuOnly,
            "het" => Mode::Heterogeneous,
            _ => anyhow::bail!("mode must be cpu|het"),
        };
    }
    cfg.sched.max_threads = args.get_usize("threads", cfg.sched.max_threads);
    cfg.sched.margin = args.get_f64("margin", cfg.sched.margin);
    cfg.runs = args.get_usize("runs", cfg.runs);
    cfg.seed = args.get_u64("seed", cfg.seed);
    Ok(cfg)
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let cfg = run_config(args)?;
    let fw = match args.get_str("framework", "parallax") {
        "ort" => Framework::Ort,
        "executorch" | "et" => Framework::ExecuTorch,
        "tflite" => Framework::TfLite,
        _ => Framework::Parallax,
    };
    let pipe = match Pipeline::build(fw, cfg.model, &cfg.device, cfg.mode, cfg.sched) {
        Ok(p) => p,
        Err(e) => {
            println!(
                "{:?} on {} in {:?} mode: unsupported ({e:?})",
                fw,
                cfg.device.display_name(),
                cfg.mode
            );
            return Ok(());
        }
    };
    let results = pipe.run_protocol(cfg.runs + cfg.warmup, cfg.seed);
    let timed = &results[cfg.warmup.min(results.len() - 1)..];
    let lats: Vec<f64> = timed.iter().map(|r| r.latency_s * 1e3).collect();
    let s = summarize(&lats).unwrap();
    let peak = timed.iter().map(|r| r.peak_mem_bytes).max().unwrap();
    let energy = timed.iter().map(|r| r.energy_j).sum::<f64>() / timed.len() as f64;
    println!(
        "{:?} | {} | {} | {:?} | threads={}",
        fw,
        cfg.model.display_name(),
        cfg.device.display_name(),
        cfg.mode,
        cfg.sched.max_threads
    );
    println!(
        "latency ms: min {:.1} / mean {:.1} / p95 {:.1} / max {:.1}   \
         peak mem {:.1} MB   energy {:.1} mJ",
        s.min,
        s.mean,
        s.p95,
        s.max,
        peak as f64 / 1e6,
        energy * 1e3
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    let which = args.positional.get(1).map(String::as_str).unwrap_or("all");
    if which == "all" {
        for name in parallax::eval::ALL_EXPERIMENTS {
            println!("{}", parallax::eval::run(name).unwrap());
        }
        return Ok(());
    }
    match parallax::eval::run(which) {
        Some(t) => {
            println!("{t}");
            Ok(())
        }
        None => anyhow::bail!("unknown experiment '{which}' (see --help)"),
    }
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let cfg = run_config(args)?;
    let g = cfg.model.build();
    println!(
        "model: {} ({} nodes, {} edges, {} tensors)",
        cfg.model.display_name(),
        g.num_nodes(),
        g.num_edges(),
        g.tensors().len()
    );
    println!(
        "total FLOPs: {:.2} G",
        parallax::flops::graph_flops(&g) as f64 / 1e9
    );
    for (label, cm) in [
        (
            "pre  (all CPU)",
            CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
        ),
        (
            "post (naive delegation)",
            CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: f64::MAX },
        ),
        ("parallax (cost model)", CostModel::default()),
    ] {
        let p = partition(&g, &cm);
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let (layers, par, maxb) = plan.table7_metrics();
        println!(
            "  {label:<26} nodes={:<5} regions={:<3} branches={:<4} layers={:<4} \
             par-layers={:<3} max-branches={}",
            p.post_node_count(),
            p.regions.len(),
            plan.branches.len(),
            layers,
            par,
            maxb
        );
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> anyhow::Result<()> {
    // Static artifact audit (no execution): graph structure, placement
    // legality, and captured-plan replay safety — see
    // `parallax::analysis` for the pass table.
    let rows = if args.flag("all") || args.get("model").is_none() {
        parallax::analysis::analyze_all()
    } else {
        let cfg = run_config(args)?;
        let label = format!("{} @ {}", cfg.model.slug(), cfg.device.name);
        vec![(label, parallax::analysis::analyze_model(cfg.model, &cfg.device))]
    };
    let mut total = 0usize;
    for (label, findings) in &rows {
        if findings.is_empty() {
            println!("{label:<24} clean");
        } else {
            println!("{label:<24} {} finding(s)", findings.len());
            for f in findings {
                println!("  {f}");
            }
            total += findings.len();
        }
    }
    anyhow::ensure!(
        total == 0,
        "static analysis found {total} violation(s) across {} target(s)",
        rows.len()
    );
    println!("{} target(s) analyzed, zero findings", rows.len());
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    // Simulated-device executors behind the real governed dispatcher
    // (the real-engine serving demo is examples/serve_text_encoders.rs):
    // concurrent CLIP-text + DistilBERT + YOLOv8n traffic admitted
    // against one device-wide memory budget.
    let mut cfg = run_config(args)?;
    let n = args.get_usize("requests", 64);
    let conc = args.get_usize("concurrency", 8);
    cfg.serve.workers = args.get_usize("workers", cfg.serve.workers);
    cfg.serve.max_batch = args.get_usize("batch", cfg.serve.max_batch);
    cfg.serve.budget_mb = args.get_usize("budget-mb", cfg.serve.budget_mb);
    // --remote appends a device–edge spill lane: deadline-tagged
    // requests the local lanes would miss try the edge server (priced
    // on the uplink/bandwidth/server-rate link terms) before degrading
    // to the CPU path — Outcome::Spilled in the tally below
    let soc = if args.flag("remote") {
        let mut rl = parallax::device::RemoteLane::edge_server();
        rl.uplink_latency_s = args.get_f64("uplink-ms", rl.uplink_latency_s * 1e3) / 1e3;
        rl.link_bw = args.get_f64("link-bw-mbps", rl.link_bw / 1e6) * 1e6;
        let link = parallax::device::LinkModel::lossy(
            args.get_u64("link-seed", 2026),
            args.get_f64("drop-p", 0.0),
        );
        // deterministic preview of the seeded fault schedule the
        // engine-level spill path replays (eval remote / tests/remote.rs)
        let window = 256u64;
        let drops = (0..window).filter(|&i| link.dropped(i)).count();
        println!(
            "remote lane: {} (uplink {:.1} ms, link {:.0} MB/s, server {:.0} GFLOP/s \
             sustained) — seeded link drops {}/{} of the next transfers",
            rl.name,
            rl.uplink_latency_s * 1e3,
            rl.link_bw / 1e6,
            rl.server_flops * rl.server_utilization / 1e9,
            drops,
            window,
        );
        SocProfile::pixel6().with_remote(&rl)
    } else {
        SocProfile::pixel6()
    };
    let sched_cfg = cfg.sched;

    let governor = std::sync::Arc::new(parallax::sched::MemoryGovernor::new(
        cfg.serve.budget_bytes(),
    ));
    let mut server = parallax::serve::Server::with_config(
        parallax::serve::ServeCfg { workers: cfg.serve.workers, max_batch: cfg.serve.max_batch },
        governor.clone(),
    );
    let models = [ModelKind::ClipText, ModelKind::DistilBert, ModelKind::Yolov8n];
    for model in models {
        if model == ModelKind::Yolov8n {
            // dynamic NMS tail: lease the per-request resolved demand (§3.4)
            let pipe =
                Pipeline::build(Framework::Parallax, model, &soc, Mode::CpuOnly, sched_cfg)
                    .expect("cpu supported")
                    .with_governor(governor.clone());
            let (demand_fn, exec) = parallax::serve::resolved_pipeline_executor(pipe, 7);
            server.register_with_demand_fn(model.slug(), demand_fn, exec);
            println!(
                "registered {:<12} per-request resolved demand (dynamic NMS tail)",
                model.slug()
            );
        } else {
            // static models: the *server* decides their placement —
            // jointly, over the shared per-lane busy-time ledger, so
            // tenants spread across lanes instead of colliding
            let pipe =
                Pipeline::build(Framework::Parallax, model, &soc, Mode::Heterogeneous, sched_cfg)
                    .or_else(|_| {
                        Pipeline::build(Framework::Parallax, model, &soc, Mode::CpuOnly, sched_cfg)
                    })
                    .expect("cpu supported")
                    .with_governor(governor.clone());
            let placement = server.register_placed(model.slug(), pipe, 7);
            println!(
                "registered {:<12} server-placed: {} delegated branch(es) on {} lane(s), \
                 staging {:.1} KB",
                model.slug(),
                placement.num_delegated(),
                placement.num_lanes_used(),
                placement.total_staging_bytes() as f64 / 1e3
            );
        }
    }
    for (name, p) in server.placements() {
        println!(
            "joint placement {name:<12} lane jobs {:?}",
            p.lane_job_counts(soc.lanes.len())
        );
    }
    let deadline_ms = args.get_f64("deadline-ms", 0.0);
    let deadline_s = if deadline_ms > 0.0 { Some(deadline_ms / 1e3) } else { None };
    let names: Vec<&str> = models.iter().map(|m| m.slug()).collect();
    let report = server.run_load_slo(&names, n, conc, 11, deadline_s)?;
    println!(
        "served {n} requests at concurrency {conc}: {:.1} req/s (wall {:.2}s)",
        report.throughput_rps, report.wall_s
    );
    println!(
        "outcomes: {} admitted / {} spilled / {} degraded-cpu / {} shed / {} dropped",
        report.admitted, report.spilled, report.degraded, report.shed, report.dropped
    );
    for (model, s) in &report.latency {
        println!(
            "  {model:<12} p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms  max {:.2} ms",
            s.p50 * 1e3,
            s.p95 * 1e3,
            s.p99 * 1e3,
            s.max * 1e3
        );
    }
    let stats = governor.stats();
    println!(
        "governor: budget {} MB, peak reserved {:.2} MB, {} grants, \
         {} waits, {} over-budget grants",
        cfg.serve.budget_mb,
        stats.peak_reserved as f64 / 1e6,
        stats.grants,
        stats.waits,
        stats.over_budget_grants
    );
    Ok(())
}

fn cmd_smoke() -> anyhow::Result<()> {
    let dir = parallax::runtime::default_artifact_dir();
    anyhow::ensure!(
        parallax::runtime::artifacts_available(),
        "artifacts missing — run `make artifacts` first"
    );
    let pool = parallax::runtime::RuntimePool::new(&dir, 1)?;
    println!("manifest: {} programs", pool.manifest().len());
    let t = parallax::runtime::Tensor::randn(vec![64, 64], 1);
    let u = parallax::runtime::Tensor::randn(vec![64, 64], 2);
    let out = pool.execute("matmul_64x64x64", vec![t.clone(), u.clone()])?;
    let mut expect = vec![0f32; 64 * 64];
    for i in 0..64 {
        for k in 0..64 {
            let a = t.data()[i * 64 + k];
            for j in 0..64 {
                expect[i * 64 + j] += a * u.data()[k * 64 + j];
            }
        }
    }
    let max_diff = out[0]
        .data()
        .iter()
        .zip(&expect)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("matmul_64x64x64 max |diff| vs host = {max_diff:.2e}");
    anyhow::ensure!(max_diff < 1e-3, "numeric mismatch");
    println!("three-layer pipeline OK");
    Ok(())
}
