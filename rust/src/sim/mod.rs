//! Discrete-event execution simulator.
//!
//! Charges analytic time/energy for a scheduled Branch-Layer plan on a
//! [`SocProfile`] — the substitution for the paper's on-phone
//! measurements (ARCHITECTURE.md §Substitutions).  One simulation = one inference with a
//! concrete dynamic-shape draw; Table 3's min/max come from sweeping
//! the draw across the paper's 30-input protocol.
//!
//! Timing model:
//! * CPU unit in a parallel wave: runs on its own core,
//!   `t = F_eff / (flops_per_core · core_scale)` + per-op dispatch.
//! * CPU unit running alone: intra-op parallelism over the framework's
//!   thread pool (`SocProfile::intra_op_speedup`).
//! * Delegate region: `L + F/R_acc + B/B_bw`, overlapping the first CPU
//!   wave of its layer (§3.1 cost model, Appendix B).
//! * Wave fork/join: `sync_overhead`.
//!
//! Energy: `P_idle·T + P_core·core_seconds + P_acc·acc_busy` (Fig. 2).

use crate::branch::{BranchPlan, Unit};
use crate::device::SocProfile;
use crate::flops;
use crate::graph::{Graph, NodeId, OpKind};
use crate::memory::{self, BranchMemory};
use crate::partition::Partition;
use crate::sched::{LayerSchedule, SchedCfg};

/// Per-framework execution personality (dispatch costs + capabilities).
#[derive(Clone, Copy, Debug)]
pub struct FrameworkProfile {
    pub name: &'static str,
    /// Per-operator dispatch/interpreter overhead, seconds.
    pub per_op_dispatch_s: f64,
    /// One-off per-inference overhead (input staging, graph setup).
    pub graph_overhead_s: f64,
    /// Thread fork/join cost per parallel wave, seconds.
    pub sync_overhead_s: f64,
    /// Framework-resident memory overhead, bytes (runtime structures).
    pub mem_overhead_bytes: u64,
    /// Executes independent branches concurrently (only Parallax).
    pub branch_parallel: bool,
    /// Intra-op thread-pool efficiency multiplier (quality of the
    /// framework's parallel kernels).
    pub intra_op_quality: f64,
    /// Cost per dynamic-shaped op to invalidate + reallocate arena
    /// regions (§3 problem (ii)).  Parallax confines dynamic resizes to
    /// the owning branch's arena and pays almost nothing; global-arena
    /// planners must re-plan and memmove.
    pub dyn_realloc_s: f64,
    /// Host<->accelerator context switch per delegate region invocation
    /// (NNAPI subgraph setup/sync).  The source of the baselines'
    /// "heterogeneous slower than CPU" collapse on fragmented models;
    /// Parallax's fine-grained subgraph control keeps it small.
    pub ctx_switch_s: f64,
}

/// Inference execution mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    CpuOnly,
    Heterogeneous,
}

/// Per-layer profile line (Table 6).
#[derive(Clone, Copy, Debug)]
pub struct LayerProfile {
    pub layer: usize,
    pub latency_s: f64,
    pub branches: usize,
    pub has_delegate: bool,
}

/// One simulated inference.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub latency_s: f64,
    pub peak_mem_bytes: u64,
    pub energy_j: f64,
    pub cpu_core_seconds: f64,
    pub acc_busy_s: f64,
    pub per_layer: Vec<LayerProfile>,
}

/// Single-core share of the SoC memory bandwidth (one streaming core
/// cannot saturate the LPDDR controller).
const BW_SHARE_1CORE: f64 = 0.35;
/// Multi-thread share (an intra-op parallel kernel streams somewhat
/// more, still short of peak).
const BW_SHARE_MULTI: f64 = 0.55;

/// Effective FLOPs of a node for a dynamic-fill draw: dynamic dims are
/// scaled by `fill` (attention-style quadratic ops by `fill²`).
pub fn effective_node_flops(g: &Graph, id: NodeId, fill: f64) -> f64 {
    let base = flops::node_flops(g, id) as f64;
    if !g.node_has_dynamic_shape(id) {
        return base;
    }
    match g.node(id).kind {
        OpKind::Attention { .. } => base * fill * fill,
        _ => base * fill,
    }
}

/// Bytes a node streams (inputs + outputs, worst case × fill).
/// Memory-bound ops (elementwise, softmax, reshuffles) are dominated by
/// this, not FLOPs — the reason they don't profit from intra-op thread
/// pools but *do* overlap across branches.
pub fn effective_node_bytes(g: &Graph, id: NodeId, fill: f64) -> f64 {
    let n = g.node(id);
    let mut total = 0.0;
    for &t in n.inputs.iter().chain(n.outputs.iter()) {
        let info = g.tensor_info(t);
        let b = info.byte_size_max() as f64;
        total += if info.has_dynamic_dim() { b * fill } else { b };
    }
    // pure shape ops (reshape on contiguous buffers) are zero-copy
    if matches!(n.kind, OpKind::Reshape | OpKind::Cast) {
        total *= 0.1;
    }
    total
}

/// Count of dynamic-shaped CPU ops in a unit (each pays the
/// framework's reallocation penalty).
fn unit_dynamic_ops(g: &Graph, p: &Partition, plan: &BranchPlan, u: usize) -> usize {
    match &plan.unit_graph.units[u] {
        Unit::Cpu(id) => usize::from(g.node_has_dynamic_shape(*id)),
        Unit::Region(ri) => p.regions[*ri]
            .iter()
            .filter(|&&id| g.node_has_dynamic_shape(id))
            .count(),
    }
}

/// Effective (FLOPs, streamed bytes) of a unit.
pub fn effective_unit_cost(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    u: usize,
    fill: f64,
) -> (f64, f64) {
    match &plan.unit_graph.units[u] {
        Unit::Cpu(id) => (
            effective_node_flops(g, *id, fill),
            effective_node_bytes(g, *id, fill),
        ),
        Unit::Region(ri) => p.regions[*ri].iter().fold((0.0, 0.0), |(f, b), &id| {
            (
                f + effective_node_flops(g, id, fill),
                b + effective_node_bytes(g, id, fill),
            )
        }),
    }
}

/// Time for one branch inside a parallel wave: pinned to a core group
/// of `threads` cores starting at `core_scale`, with nested intra-op
/// parallelism across the group when the wave is narrower than the
/// thread budget (Parallax's hybrid fan-out).
#[allow(clippy::too_many_arguments)]
fn branch_time_wave(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    fw: &FrameworkProfile,
    soc: &SocProfile,
    b: usize,
    core_scale: f64,
    threads: usize,
    fill: f64,
) -> f64 {
    let rate = soc.cpu_flops_per_core * core_scale;
    let bw = soc.mem_bw
        * if threads > 1 { BW_SHARE_MULTI } else { BW_SHARE_1CORE };
    let mut t = 0.0;
    for &u in &plan.branches[b].units {
        let (f, bytes) = effective_unit_cost(g, p, plan, u, fill);
        let speedup = if threads > 1 {
            let raw = soc.intra_op_speedup(f as u64, threads);
            1.0 + (raw - 1.0) * fw.intra_op_quality
        } else {
            1.0
        };
        t += (f / (rate * speedup)).max(bytes / bw)
            + fw.per_op_dispatch_s * plan.unit_graph.ops[u] as f64
            + fw.dyn_realloc_s * unit_dynamic_ops(g, p, plan, u) as f64;
    }
    t
}

/// Time for one branch run alone with intra-op parallelism.
fn branch_time_intra_op(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    fw: &FrameworkProfile,
    soc: &SocProfile,
    b: usize,
    threads: usize,
    fill: f64,
) -> (f64, f64) {
    // returns (time, core_seconds)
    let bw = soc.mem_bw * BW_SHARE_MULTI;
    let mut t = 0.0;
    let mut cs = 0.0;
    for &u in &plan.branches[b].units {
        let (f, bytes) = effective_unit_cost(g, p, plan, u, fill);
        let raw_speedup = soc.intra_op_speedup(f as u64, threads);
        let speedup = 1.0 + (raw_speedup - 1.0) * fw.intra_op_quality;
        let ut = (f / (soc.cpu_flops_per_core * speedup)).max(bytes / bw)
            + fw.per_op_dispatch_s * plan.unit_graph.ops[u] as f64
            + fw.dyn_realloc_s * unit_dynamic_ops(g, p, plan, u) as f64;
        t += ut;
        cs += ut * speedup.min(threads as f64);
    }
    (t, cs)
}

/// Accelerator time of a delegate branch (§3.1 model): per region,
/// `L + F/R_acc + B/B_bw`.
fn branch_time_delegate(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    fw: &FrameworkProfile,
    soc: &SocProfile,
    b: usize,
    fill: f64,
) -> f64 {
    let mut t = 0.0;
    for &u in &plan.branches[b].units {
        match &plan.unit_graph.units[u] {
            Unit::Region(ri) => {
                let f: f64 = p.regions[*ri]
                    .iter()
                    .map(|&id| effective_node_flops(g, id, fill))
                    .sum();
                let bnd = flops::boundary_bytes(g, &p.regions[*ri]) as f64;
                t += soc.acc_dispatch_s
                    + fw.ctx_switch_s
                    + f / (soc.acc_flops * soc.acc_utilization)
                    + bnd / soc.mem_bw;
            }
            Unit::Cpu(id) => {
                // glue node inside a delegate branch: runs on CPU core 0
                t += effective_node_flops(g, *id, fill) / soc.cpu_flops_per_core;
            }
        }
    }
    t
}

/// Peak §3.3 lease a governed execution of `schedules` holds: the max
/// over parallel waves of the CPU branches' summed M_i (a sequential
/// spill branch holds its own M_i alone; delegate branches occupy the
/// accelerator, not host arenas).
///
/// Table benches use this to report dynamic-model numbers: evaluate it
/// once with the max-shape memories and once with
/// [`crate::ctrl::resolved_branch_memories`] to get the worst-case vs
/// resolved-shape reservation of the same plan (§3.4).
pub fn schedule_peak_demand(
    plan: &BranchPlan,
    schedules: &[LayerSchedule],
    mems: &[BranchMemory],
) -> u64 {
    let mut peak = 0u64;
    for ls in schedules {
        for wave in &ls.waves {
            let sum: u64 = wave
                .iter()
                .filter(|&&b| !plan.branches[b].has_delegate)
                .map(|&b| mems[b].total() as u64)
                .sum();
            peak = peak.max(sum);
        }
        for &b in &ls.sequential {
            if !plan.branches[b].has_delegate {
                peak = peak.max(mems[b].total() as u64);
            }
        }
    }
    peak
}

/// Fill-independent activation footprint for a framework's planner —
/// compute once per pipeline, pass into [`simulate`].
pub fn activation_footprint(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    fw: &FrameworkProfile,
) -> u64 {
    if fw.branch_parallel {
        memory::parallax_footprint(g, p, plan).total() as u64
    } else {
        let (_, greedy) = memory::baseline_footprints(g);
        greedy as u64
    }
}

/// Simulate one inference of a scheduled plan.
#[allow(clippy::too_many_arguments)]
pub fn simulate(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    schedules: &[LayerSchedule],
    mems: &[BranchMemory],
    fw: &FrameworkProfile,
    soc: &SocProfile,
    cfg: &SchedCfg,
    mode: Mode,
    fill: f64,
    weight_bytes: u64,
    activation_bytes: u64,
) -> SimResult {
    let mut total = fw.graph_overhead_s;
    let mut core_seconds = 0.0;
    let mut acc_busy = 0.0;
    let mut per_layer = Vec::with_capacity(schedules.len());

    let hetero = mode == Mode::Heterogeneous;

    for (li, ls) in schedules.iter().enumerate() {
        let mut layer_t = 0.0;
        let mut layer_branches = 0usize;
        let mut layer_has_delegate = false;

        for (wi, wave) in ls.waves.iter().enumerate() {
            if wave.is_empty() {
                continue;
            }
            layer_branches += wave.len();
            // split wave into delegate + cpu lanes
            let mut cpu_times: Vec<f64> = Vec::new();
            let mut delegate_t = 0.0f64;
            // heaviest branches to biggest cores
            let mut cpu_branches: Vec<usize> = wave
                .iter()
                .copied()
                .filter(|&b| !(hetero && plan.branches[b].has_delegate))
                .collect();
            cpu_branches.sort_by(|&a, &b| {
                plan.branches[b].flops.cmp(&plan.branches[a].flops)
            });
            // hybrid fan-out: unused thread budget nests inside branches
            let threads_per_branch = if cpu_branches.is_empty() {
                1
            } else {
                (cfg.max_threads / cpu_branches.len()).max(1)
            };
            for (slot, &b) in cpu_branches.iter().enumerate() {
                let base = slot * threads_per_branch;
                let scale = soc.core_scale[base.min(soc.cpu_cores - 1)];
                let t = branch_time_wave(
                    g, p, plan, fw, soc, b, scale, threads_per_branch, fill,
                );
                cpu_times.push(t);
                core_seconds += t * scale * threads_per_branch as f64 * 0.8;
            }
            for &b in wave {
                if hetero && plan.branches[b].has_delegate {
                    layer_has_delegate = true;
                    let t = branch_time_delegate(g, p, plan, fw, soc, b, fill);
                    delegate_t += t;
                    acc_busy += t;
                }
            }
            let cpu_wave_t = cpu_times.iter().fold(0.0, |a: f64, &b| a.max(b));
            let wave_t = cpu_wave_t.max(delegate_t)
                + if cpu_branches.len() > 1 {
                    fw.sync_overhead_s
                } else {
                    0.0
                };
            let _ = wi;
            layer_t += wave_t;
        }

        for &b in &ls.sequential {
            layer_branches += 1;
            if hetero && plan.branches[b].has_delegate {
                layer_has_delegate = true;
                let t = branch_time_delegate(g, p, plan, fw, soc, b, fill);
                acc_busy += t;
                layer_t += t;
            } else {
                let (t, cs) =
                    branch_time_intra_op(g, p, plan, fw, soc, b, cfg.max_threads, fill);
                layer_t += t;
                core_seconds += cs;
            }
        }

        per_layer.push(LayerProfile {
            layer: li,
            latency_s: layer_t,
            branches: layer_branches,
            has_delegate: layer_has_delegate,
        });
        total += layer_t;
    }

    // memory: weights + activation footprint (precomputed by the
    // caller — it is fill-independent) + runtime overhead
    let peak_mem = weight_bytes + activation_bytes + fw.mem_overhead_bytes;
    let _ = mems;

    let energy = soc.p_idle_w * total
        + soc.p_core_w * core_seconds
        + soc.p_acc_w * acc_busy;

    SimResult {
        latency_s: total,
        peak_mem_bytes: peak_mem,
        energy_j: energy,
        cpu_core_seconds: core_seconds,
        acc_busy_s: acc_busy,
        per_layer,
    }
}

/// Build an [`exec::EnergyModel`](crate::exec::EnergyModel) for the
/// real engine from the *same* per-branch timing terms [`simulate`]
/// charges, so the executor's measured energy ledger and the simulator's
/// closed form (`P_idle·T + P_core·core_seconds + P_acc·acc_busy`)
/// agree term-by-term on static CPU-only runs of the same schedule.
///
/// Each branch appears exactly once across `schedules` (in one wave or
/// one sequential slot), so its span/core contribution is well defined:
/// * wave branch `b` at sorted slot `s`: span = [`branch_time_wave`]
///   under that wave's thread split, core = `t·scale·threads·0.8` —
///   identical to the accumulation inside [`simulate`]'s wave loop;
/// * sequential branch: `(t, core_seconds)` from intra-op timing.
///
/// Terms are derived for the CPU fallback path (the engine charges them
/// only for branches it actually runs on host cores); delegated
/// branches draw lane energy through the engine's per-lane busy ledger
/// instead, priced here via `lane_power_w`.
#[allow(clippy::too_many_arguments)]
pub fn energy_model_for(
    g: &Graph,
    p: &Partition,
    plan: &BranchPlan,
    schedules: &[LayerSchedule],
    fw: &FrameworkProfile,
    soc: &SocProfile,
    cfg: &SchedCfg,
    fill: f64,
) -> crate::exec::EnergyModel {
    let n = plan.branches.len();
    let mut span = vec![0.0; n];
    let mut core = vec![0.0; n];
    for ls in schedules {
        for wave in &ls.waves {
            if wave.is_empty() {
                continue;
            }
            let mut cpu_branches: Vec<usize> = wave.to_vec();
            cpu_branches.sort_by(|&a, &b| {
                plan.branches[b].flops.cmp(&plan.branches[a].flops)
            });
            let threads_per_branch =
                (cfg.max_threads / cpu_branches.len()).max(1);
            for (slot, &b) in cpu_branches.iter().enumerate() {
                let base = slot * threads_per_branch;
                let scale = soc.core_scale[base.min(soc.cpu_cores - 1)];
                let t = branch_time_wave(
                    g, p, plan, fw, soc, b, scale, threads_per_branch, fill,
                );
                span[b] = t;
                core[b] = t * scale * threads_per_branch as f64 * 0.8;
            }
        }
        for &b in &ls.sequential {
            let (t, cs) =
                branch_time_intra_op(g, p, plan, fw, soc, b, cfg.max_threads, fill);
            span[b] = t;
            core[b] = cs;
        }
    }
    crate::exec::EnergyModel {
        p_idle_w: soc.p_idle_w,
        p_core_w: soc.p_core_w,
        lane_power_w: soc.lanes.iter().map(|l| l.power_w).collect(),
        branch_span_s: span,
        branch_core_s: core,
        base_s: fw.graph_overhead_s,
        sync_s: fw.sync_overhead_s,
        idle: Default::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::branch::{self, DEFAULT_BETA};
    use crate::memory::branch_memories;
    use crate::models::micro;
    use crate::partition::{partition, CostModel};
    use crate::sched;

    fn setup(
        g: &Graph,
    ) -> (Partition, BranchPlan, Vec<BranchMemory>, Vec<LayerSchedule>) {
        let p = partition(g, &CostModel::default());
        let plan = branch::plan(g, &p, DEFAULT_BETA);
        let mems = branch_memories(g, &p, &plan);
        let cfg = SchedCfg::default();
        let scheds = sched::schedule(&plan, &mems, 1 << 34, &cfg);
        (p, plan, mems, scheds)
    }

    #[test]
    fn parallel_beats_sequential_on_branchy_graph() {
        let g = micro::parallel_chains(4, 400);
        let (p, plan, mems, scheds) = setup(&g);
        let soc = SocProfile::pixel6();
        let cfg = SchedCfg::default();
        let plx = baselines::parallax();
        let seq_scheds: Vec<LayerSchedule> = scheds
            .iter()
            .map(|s| LayerSchedule {
                waves: vec![],
                sequential: s.all().collect(),
            })
            .collect();
        let act = activation_footprint(&g, &p, &plan, &plx);
        let par = simulate(&g, &p, &plan, &scheds, &mems, &plx, &soc, &cfg, Mode::CpuOnly, 1.0, 0, act);
        let seq = simulate(&g, &p, &plan, &seq_scheds, &mems, &plx, &soc, &cfg, Mode::CpuOnly, 1.0, 0, act);
        assert!(
            par.latency_s < seq.latency_s,
            "parallel {} !< sequential {}",
            par.latency_s,
            seq.latency_s
        );
    }

    #[test]
    fn fill_scales_latency_monotonically() {
        let g = crate::models::ModelKind::ClipText.build();
        let (p, plan, mems, scheds) = setup(&g);
        let soc = SocProfile::pixel6();
        let cfg = SchedCfg::default();
        let plx = baselines::parallax();
        let act = activation_footprint(&g, &p, &plan, &plx);
        let lo = simulate(&g, &p, &plan, &scheds, &mems, &plx, &soc, &cfg, Mode::CpuOnly, 0.2, 0, act);
        let hi = simulate(&g, &p, &plan, &scheds, &mems, &plx, &soc, &cfg, Mode::CpuOnly, 1.0, 0, act);
        assert!(lo.latency_s < hi.latency_s);
    }

    #[test]
    fn energy_positive_and_scales_with_time() {
        let g = micro::parallel_chains(4, 100);
        let (p, plan, mems, scheds) = setup(&g);
        let soc = SocProfile::pixel6();
        let cfg = SchedCfg::default();
        let plx = baselines::parallax();
        let act = activation_footprint(&g, &p, &plan, &plx);
        let r = simulate(&g, &p, &plan, &scheds, &mems, &plx, &soc, &cfg, Mode::CpuOnly, 1.0, 0, act);
        assert!(r.energy_j > 0.0);
        assert!(r.energy_j >= soc.p_idle_w * r.latency_s);
    }

    #[test]
    fn hetero_uses_accelerator_on_delegated_graph() {
        let g = micro::mixed();
        let p = partition(&g, &CostModel { min_ops: 1, min_flops: 0, max_bytes_per_flop: 1e9 });
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let mems = branch_memories(&g, &p, &plan);
        let cfg = SchedCfg::default();
        let scheds = sched::schedule(&plan, &mems, 1 << 34, &cfg);
        let soc = SocProfile::pixel6();
        let plx = baselines::parallax();
        let act = activation_footprint(&g, &p, &plan, &plx);
        let het = simulate(&g, &p, &plan, &scheds, &mems, &plx, &soc, &cfg, Mode::Heterogeneous, 1.0, 0, act);
        let cpu = simulate(&g, &p, &plan, &scheds, &mems, &plx, &soc, &cfg, Mode::CpuOnly, 1.0, 0, act);
        assert!(het.acc_busy_s > 0.0);
        assert_eq!(cpu.acc_busy_s, 0.0);
        // the conv trunk is heavy and static -> accelerator should win
        assert!(het.latency_s < cpu.latency_s);
    }

    #[test]
    fn per_layer_sums_to_total() {
        let g = crate::models::ModelKind::DistilBert.build();
        let (p, plan, mems, scheds) = setup(&g);
        let soc = SocProfile::pixel6();
        let cfg = SchedCfg::default();
        let plx = baselines::parallax();
        let act = activation_footprint(&g, &p, &plan, &plx);
        let r = simulate(&g, &p, &plan, &scheds, &mems, &plx, &soc, &cfg, Mode::CpuOnly, 1.0, 0, act);
        let sum: f64 = r.per_layer.iter().map(|l| l.latency_s).sum();
        assert!((sum + plx.graph_overhead_s - r.latency_s).abs() < 1e-9);
    }

    #[test]
    fn schedule_peak_demand_matches_widest_wave() {
        let g = micro::parallel_chains(4, 5);
        let p = partition(
            &g,
            &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 },
        );
        let plan = branch::plan(&g, &p, DEFAULT_BETA);
        let mems = branch_memories(&g, &p, &plan);
        let cfg = SchedCfg::default();
        let scheds = sched::schedule(&plan, &mems, u64::MAX, &cfg);
        let peak = schedule_peak_demand(&plan, &scheds, &mems);
        assert!(peak > 0);
        // all-sequential never exceeds the widest parallel wave
        let seq: Vec<LayerSchedule> = scheds
            .iter()
            .map(|s| LayerSchedule { waves: vec![], sequential: s.all().collect() })
            .collect();
        assert!(schedule_peak_demand(&plan, &seq, &mems) <= peak);
    }

    #[test]
    fn energy_model_for_matches_simulate_closed_form() {
        let g = micro::parallel_chains(4, 60);
        let (p, plan, mems, scheds) = setup(&g);
        let soc = SocProfile::pixel6();
        let cfg = SchedCfg::default();
        let plx = baselines::parallax();
        let act = activation_footprint(&g, &p, &plan, &plx);
        let r = simulate(
            &g, &p, &plan, &scheds, &mems, &plx, &soc, &cfg, Mode::CpuOnly, 1.0, 0, act,
        );
        let em = energy_model_for(&g, &p, &plan, &scheds, &plx, &soc, &cfg, 1.0);
        // replay the schedule against the per-branch terms: the wave
        // max + sync accumulation must reproduce simulate's totals
        let mut span_total = 0.0;
        for ls in &scheds {
            for wave in &ls.waves {
                if wave.is_empty() {
                    continue;
                }
                let mx = wave
                    .iter()
                    .map(|&b| em.branch_span_s[b])
                    .fold(0.0f64, f64::max);
                span_total += mx + if wave.len() > 1 { em.sync_s } else { 0.0 };
            }
            for &b in &ls.sequential {
                span_total += em.branch_span_s[b];
            }
        }
        let core_total: f64 = em.branch_core_s.iter().sum();
        let t_total = em.base_s + span_total;
        assert!((t_total - r.latency_s).abs() / r.latency_s < 1e-9);
        assert!(
            (core_total - r.cpu_core_seconds).abs()
                <= 1e-9 * r.cpu_core_seconds.max(1e-12)
        );
        let e = em.p_idle_w * t_total + em.p_core_w * core_total;
        assert!((e - r.energy_j).abs() / r.energy_j < 1e-9);
    }

    #[test]
    fn peak_memory_includes_weights() {
        let g = crate::models::ModelKind::ClipText.build();
        let (p, plan, mems, scheds) = setup(&g);
        let soc = SocProfile::pixel6();
        let cfg = SchedCfg::default();
        let plx = baselines::parallax();
        let w = 100_000_000;
        let act = activation_footprint(&g, &p, &plan, &plx);
        let r = simulate(&g, &p, &plan, &scheds, &mems, &plx, &soc, &cfg, Mode::CpuOnly, 1.0, w, act);
        assert!(r.peak_mem_bytes > w);
    }
}
