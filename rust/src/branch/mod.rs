//! Branch-Layer extraction (paper §3.1, Algorithms 1–4).
//!
//! After delegate partitioning the graph intermixes CPU-fallback nodes
//! and indivisible delegate regions.  This module decomposes that mixed
//! view into:
//!
//! 1. **Units** — one per CPU node, one per delegate region
//!    ([`UnitGraph`]).  Control-flow ops are Split-Merge barriers.
//! 2. **Branches** — maximal linear chains of units (Algorithm 1/3):
//!    the schedulable quantum.  Within a branch execution is strictly
//!    sequential; across branches in the same layer it may be parallel.
//! 3. **Layers** — topological waves of branches (Algorithm 2/4):
//!    branches in one layer have no mutual dependencies.
//! 4. **Refinement** — a layer is *parallelizable* only if ≥2 branches
//!    each have N > 2 ops and the heaviest/lightest FLOP ratio is ≤ β
//!    (default 1.5), so thread fan-out never pays more in sync than it
//!    gains in overlap.
//!
//! Everything runs in O(|V|+|E|), matching the paper's claim.

use crate::flops;
use crate::graph::{Graph, NodeId};
use crate::partition::Partition;

/// Node/unit classification (Algorithm 1 line 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeClass {
    Sequential,
    Splitter,
    Merger,
    SplitMerge,
}

/// One schedulable unit: a CPU node or a whole delegate region.
#[derive(Clone, Debug, PartialEq)]
pub enum Unit {
    Cpu(NodeId),
    Region(usize),
}

/// The unit-level view of a partitioned graph.
#[derive(Clone, Debug)]
pub struct UnitGraph {
    pub units: Vec<Unit>,
    pub preds: Vec<Vec<usize>>,
    pub succs: Vec<Vec<usize>>,
    /// FLOPs per unit (region = sum of members).
    pub flops: Vec<u64>,
    /// Fine-grained op count per unit.
    pub ops: Vec<usize>,
    /// Control-flow barrier flag (forced Split-Merge).
    pub barrier: Vec<bool>,
    /// unit index for every graph node.
    pub unit_of_node: Vec<usize>,
}

impl UnitGraph {
    /// Build the unit graph from a partition result.
    pub fn build(g: &Graph, p: &Partition) -> Self {
        let n = g.num_nodes();
        let mut unit_of_node = vec![usize::MAX; n];
        let mut units = Vec::new();
        let mut flops_v = Vec::new();
        let mut ops_v = Vec::new();
        let mut barrier = Vec::new();

        // one unit per delegate region, in region order
        for (ri, region) in p.regions.iter().enumerate() {
            let ui = units.len();
            units.push(Unit::Region(ri));
            flops_v.push(flops::region_flops(g, region));
            ops_v.push(region.len());
            barrier.push(false);
            for &id in region {
                unit_of_node[id.0 as usize] = ui;
            }
        }
        // one unit per CPU node
        for node in g.nodes() {
            if p.is_cpu(node.id) {
                let ui = units.len();
                units.push(Unit::Cpu(node.id));
                flops_v.push(flops::node_flops(g, node.id));
                ops_v.push(1);
                barrier.push(node.kind.is_control_flow());
                unit_of_node[node.id.0 as usize] = ui;
            }
        }

        // unit adjacency (dedup'd)
        let m = units.len();
        let mut preds = vec![Vec::new(); m];
        let mut succs = vec![Vec::new(); m];
        for node in g.nodes() {
            let u = unit_of_node[node.id.0 as usize];
            for s in g.succs(node.id) {
                let v = unit_of_node[s.0 as usize];
                if u != v && !succs[u].contains(&v) {
                    succs[u].push(v);
                    preds[v].push(u);
                }
            }
        }

        Self { units, preds, succs, flops: flops_v, ops: ops_v, barrier, unit_of_node }
    }

    pub fn len(&self) -> usize {
        self.units.len()
    }

    pub fn is_empty(&self) -> bool {
        self.units.is_empty()
    }

    /// Classification per Algorithm 1 (control flow forced Split-Merge).
    pub fn classify(&self, u: usize) -> NodeClass {
        if self.barrier[u] {
            return NodeClass::SplitMerge;
        }
        let din = self.preds[u].len();
        let dout = self.succs[u].len();
        match (din > 1, dout > 1) {
            (false, false) => NodeClass::Sequential,
            (false, true) => NodeClass::Splitter,
            (true, false) => NodeClass::Merger,
            (true, true) => NodeClass::SplitMerge,
        }
    }

    /// Kahn topological order over units.
    pub fn topo(&self) -> Vec<usize> {
        let mut indeg: Vec<usize> = self.preds.iter().map(Vec::len).collect();
        let mut queue: std::collections::VecDeque<usize> =
            (0..self.len()).filter(|&u| indeg[u] == 0).collect();
        let mut order = Vec::with_capacity(self.len());
        while let Some(u) = queue.pop_front() {
            order.push(u);
            for &v in &self.succs[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push_back(v);
                }
            }
        }
        assert_eq!(order.len(), self.len(), "unit graph has a cycle");
        order
    }
}

/// One extracted branch: a maximal linear chain of units.
#[derive(Clone, Debug)]
pub struct Branch {
    pub id: usize,
    pub units: Vec<usize>,
    /// Total FLOPs (workload metric F of §3.1 refinement).
    pub flops: u64,
    /// Fine-grained op count (workload metric N).
    pub ops: usize,
    /// True if the branch contains a delegate region (runs on the
    /// accelerator lane rather than a CPU thread).
    pub has_delegate: bool,
}

/// The full Branch-Layer plan.
#[derive(Clone, Debug)]
pub struct BranchPlan {
    pub unit_graph: UnitGraph,
    pub branches: Vec<Branch>,
    /// branch index of every unit.
    pub branch_of_unit: Vec<usize>,
    /// Layers: topological waves of branch indices (Algorithm 2).
    pub layers: Vec<Vec<usize>>,
    /// Per-layer parallel verdict after refinement.
    pub layer_parallel: Vec<bool>,
}

/// β — max heaviest/lightest FLOP ratio for a balanced layer (§3.1).
pub const DEFAULT_BETA: f64 = 1.5;

/// Minimum ops per branch for parallel execution (§3.1: N > 2).
pub const MIN_BRANCH_OPS: usize = 3;

/// Extract maximal branches (Algorithm 1/3).
///
/// A branch grows from an unvisited head unit and extends while the
/// current unit has exactly one successor, that successor has exactly
/// one predecessor, is unvisited, and neither side is a control-flow
/// barrier.  Every unit lands in exactly one branch.
pub fn extract_branches(ug: &UnitGraph) -> (Vec<Branch>, Vec<usize>) {
    let n = ug.len();
    let mut visited = vec![false; n];
    let mut branches: Vec<Branch> = Vec::new();
    let mut branch_of_unit = vec![usize::MAX; n];

    for u in ug.topo() {
        if visited[u] {
            continue;
        }
        // heads: not Merger/SplitMerge per Algorithm 1, or any leftover
        let mut chain = vec![u];
        visited[u] = true;
        let mut cur = u;
        loop {
            if ug.barrier[cur] || ug.succs[cur].len() != 1 {
                break;
            }
            let next = ug.succs[cur][0];
            if visited[next]
                || ug.preds[next].len() != 1
                || ug.barrier[next]
            {
                break;
            }
            chain.push(next);
            visited[next] = true;
            cur = next;
        }
        let id = branches.len();
        for &m in &chain {
            branch_of_unit[m] = id;
        }
        branches.push(Branch {
            id,
            flops: chain.iter().map(|&m| ug.flops[m]).sum(),
            ops: chain.iter().map(|&m| ug.ops[m]).sum(),
            has_delegate: chain.iter().any(|&m| matches!(ug.units[m], Unit::Region(_))),
            units: chain,
        });
    }
    (branches, branch_of_unit)
}

/// Group branches into topological layers (Algorithm 2/4).
pub fn build_layers(ug: &UnitGraph, branches: &[Branch], branch_of_unit: &[usize]) -> Vec<Vec<usize>> {
    let nb = branches.len();
    // branch dependency in-degrees (dedup'd edges)
    let mut deps: Vec<std::collections::HashSet<usize>> = vec![Default::default(); nb];
    for (u, succs) in ug.succs.iter().enumerate() {
        let bu = branch_of_unit[u];
        for &v in succs {
            let bv = branch_of_unit[v];
            if bu != bv {
                deps[bv].insert(bu);
            }
        }
    }
    let mut indeg: Vec<usize> = deps.iter().map(|d| d.len()).collect();
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); nb];
    for (b, ds) in deps.iter().enumerate() {
        for &d in ds {
            dependents[d].push(b);
        }
    }

    let mut layers = Vec::new();
    let mut queue: Vec<usize> = (0..nb).filter(|&b| indeg[b] == 0).collect();
    let mut placed = 0;
    while !queue.is_empty() {
        let layer = std::mem::take(&mut queue);
        for &b in &layer {
            placed += 1;
            for &d in &dependents[b] {
                indeg[d] -= 1;
                if indeg[d] == 0 {
                    queue.push(d);
                }
            }
        }
        layers.push(layer);
    }
    assert_eq!(placed, nb, "branch dependency graph has a cycle");
    layers
}

/// §3.1 refinement: the *balanced parallel subset* of a layer.
///
/// Qualifying branches (CPU, N > 2) are sorted by descending FLOPs and
/// the maximal prefix with `F_max / F_i ≤ β` is taken — the heaviest
/// balanced group.  Anything outside the subset (tiny glue chains,
/// off-balance stragglers) runs sequentially, so fan-out never pays
/// more in synchronisation than it gains in overlap.  Returns branch
/// ids; parallel execution is worthwhile iff the subset has ≥ 2.
pub fn balanced_parallel_subset(branches: &[Branch], layer: &[usize], beta: f64) -> Vec<usize> {
    let mut q: Vec<usize> = layer
        .iter()
        .copied()
        .filter(|&b| !branches[b].has_delegate && branches[b].ops >= MIN_BRANCH_OPS)
        .collect();
    if q.len() < 2 {
        return Vec::new();
    }
    q.sort_by(|&a, &b| branches[b].flops.cmp(&branches[a].flops));
    let fmax = branches[q[0]].flops.max(1) as f64;
    let take = q
        .iter()
        .take_while(|&&b| fmax / branches[b].flops.max(1) as f64 <= beta)
        .count();
    if take < 2 {
        Vec::new()
    } else {
        q.truncate(take);
        q
    }
}

/// §3.1 refinement verdict for a layer: does a balanced parallel subset
/// of ≥ 2 branches exist?
pub fn layer_is_parallel(branches: &[Branch], layer: &[usize], beta: f64) -> bool {
    !balanced_parallel_subset(branches, layer, beta).is_empty()
}

/// Run the full §3.1 pipeline on a partitioned graph.
pub fn plan(g: &Graph, p: &Partition, beta: f64) -> BranchPlan {
    let ug = UnitGraph::build(g, p);
    let (branches, branch_of_unit) = extract_branches(&ug);
    let layers = build_layers(&ug, &branches, &branch_of_unit);
    let layer_parallel = layers
        .iter()
        .map(|l| layer_is_parallel(&branches, l, beta))
        .collect();
    BranchPlan { unit_graph: ug, branches, branch_of_unit, layers, layer_parallel }
}

impl BranchPlan {
    /// Table 7 metrics: (layers, parallel layers, max branches in a layer).
    pub fn table7_metrics(&self) -> (usize, usize, usize) {
        let layers = self.layers.len();
        let par = self.layer_parallel.iter().filter(|&&p| p).count();
        let maxb = self.layers.iter().map(Vec::len).max().unwrap_or(0);
        (layers, par, maxb)
    }

    /// Branch-level successor sets (dedup'd cross-branch unit edges):
    /// `succs[a]` holds every branch consuming one of `a`'s outputs.
    /// Shared by the cross-layer delegate overlap (first-consumer merge
    /// points) and the in-flight staging accounting
    /// ([`sched::placed_inflight_staging`](crate::sched::placed_inflight_staging)).
    pub fn branch_succs(&self) -> Vec<Vec<usize>> {
        let nb = self.branches.len();
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); nb];
        for (u, us) in self.unit_graph.succs.iter().enumerate() {
            let bu = self.branch_of_unit[u];
            for &v in us {
                let bv = self.branch_of_unit[v];
                if bu != bv && !succs[bu].contains(&bv) {
                    succs[bu].push(bv);
                }
            }
        }
        succs
    }

    /// All graph nodes of a branch, in unit order (regions expanded).
    pub fn branch_nodes(&self, _g: &Graph, p: &Partition, b: usize) -> Vec<NodeId> {
        let mut out = Vec::new();
        for &u in &self.branches[b].units {
            match &self.unit_graph.units[u] {
                Unit::Cpu(id) => out.push(*id),
                Unit::Region(ri) => out.extend(p.regions[*ri].iter().copied()),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::micro;
    use crate::partition::{partition, CostModel};

    fn cpu_only(g: &crate::graph::Graph) -> Partition {
        // cost model that rejects everything -> all CPU
        partition(g, &CostModel { min_ops: usize::MAX, min_flops: u64::MAX, max_bytes_per_flop: 0.0 })
    }

    #[test]
    fn chain_is_one_branch() {
        let g = micro::chain(10);
        let p = cpu_only(&g);
        let plan = plan(&g, &p, DEFAULT_BETA);
        assert_eq!(plan.branches.len(), 1);
        assert_eq!(plan.layers.len(), 1);
        assert!(!plan.layer_parallel[0]); // single branch: not parallel
    }

    #[test]
    fn parallel_chains_form_k_branches_in_one_layer() {
        let g = micro::parallel_chains(4, 5);
        let p = cpu_only(&g);
        let plan = plan(&g, &p, DEFAULT_BETA);
        // split head, 4 chains, merge tail
        let (layers, par, maxb) = plan.table7_metrics();
        assert_eq!(maxb, 4, "{:?}", plan.layers);
        assert!(par >= 1);
        assert!(layers >= 3);
        // the 4 chains are balanced (equal flops) and long enough
        let mid = plan
            .layers
            .iter()
            .position(|l| l.len() == 4)
            .expect("4-wide layer");
        assert!(plan.layer_parallel[mid]);
    }

    #[test]
    fn unbalanced_diamond_fails_beta() {
        // short=3 vs long=12 relus: both N>2, flops ratio 4 > 1.5
        let g = micro::diamond(3, 12);
        let p = cpu_only(&g);
        let plan = plan(&g, &p, DEFAULT_BETA);
        assert!(plan.layer_parallel.iter().all(|&x| !x));
        // but a generous beta accepts it
        let plan2 = plan_beta(&g, &p, 5.0);
        assert!(plan2.layer_parallel.iter().any(|&x| x));
    }

    fn plan_beta(
        g: &crate::graph::Graph,
        p: &Partition,
        beta: f64,
    ) -> BranchPlan {
        plan(g, p, beta)
    }

    #[test]
    fn short_branches_fail_min_ops() {
        // 2-op branches: N = 2 < 3 -> never parallel
        let g = micro::parallel_chains(4, 2);
        let p = cpu_only(&g);
        let plan = plan(&g, &p, DEFAULT_BETA);
        assert!(plan.layer_parallel.iter().all(|&x| !x));
    }

    #[test]
    fn every_unit_in_exactly_one_branch() {
        let g = crate::models::ModelKind::ClipText.build();
        let p = partition(&g, &CostModel::default());
        let plan = plan(&g, &p, DEFAULT_BETA);
        let mut count = vec![0usize; plan.unit_graph.len()];
        for b in &plan.branches {
            for &u in &b.units {
                count[u] += 1;
            }
        }
        assert!(count.iter().all(|&c| c == 1));
        // branch_of_unit consistent
        for b in &plan.branches {
            for &u in &b.units {
                assert_eq!(plan.branch_of_unit[u], b.id);
            }
        }
    }

    #[test]
    fn layers_respect_dependencies() {
        let g = crate::models::ModelKind::DistilBert.build();
        let p = partition(&g, &CostModel::default());
        let plan = plan(&g, &p, DEFAULT_BETA);
        // layer index of each branch
        let mut layer_of = vec![usize::MAX; plan.branches.len()];
        for (li, layer) in plan.layers.iter().enumerate() {
            for &b in layer {
                layer_of[b] = li;
            }
        }
        // for every unit edge across branches, layer must strictly increase
        for (u, succs) in plan.unit_graph.succs.iter().enumerate() {
            for &v in succs {
                let (bu, bv) = (plan.branch_of_unit[u], plan.branch_of_unit[v]);
                if bu != bv {
                    assert!(
                        layer_of[bu] < layer_of[bv],
                        "dependency violated: branch {bu} (layer {}) -> {bv} (layer {})",
                        layer_of[bu],
                        layer_of[bv]
                    );
                }
            }
        }
    }

    #[test]
    fn control_flow_is_singleton_branch() {
        let g = crate::models::ModelKind::WhisperTiny.build();
        let p = partition(&g, &CostModel::default());
        let plan = plan(&g, &p, DEFAULT_BETA);
        for (u, unit) in plan.unit_graph.units.iter().enumerate() {
            if plan.unit_graph.barrier[u] {
                let b = plan.branch_of_unit[u];
                assert_eq!(
                    plan.branches[b].units.len(),
                    1,
                    "barrier unit {unit:?} must be alone in its branch"
                );
            }
        }
    }

    #[test]
    fn qkv_branches_visible_in_clip() {
        // CLIP attention blocks expose >= 3 concurrent branches (q/k/v)
        let g = crate::models::ModelKind::ClipText.build();
        let p = cpu_only(&g);
        let plan = plan(&g, &p, DEFAULT_BETA);
        let (_, _, maxb) = plan.table7_metrics();
        assert!(maxb >= 3, "expected q/k/v parallelism, got max {maxb}");
    }
}
