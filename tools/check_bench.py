#!/usr/bin/env python3
"""Bench-trajectory check: validate and diff recorded BENCH files.

The bench harness (`parallax::util::bench::Bench`) appends one JSON
record per group to the file named by the `BENCH_JSON` env var:

    [{"group": "<name>", "cases": [{"name", "iters", "mean_ns",
      "p50_ns", "min_ns"}, ...]}, ...]

A PR that claims a perf result commits the recorded trajectory as
`BENCH_<n>.json` at the repo root.  This script has two modes:

  validate (1 arg):
      python3 tools/check_bench.py BENCH_6.json
    Checks the file parses and every record/case has the harness schema
    with positive timings.  Exit 1 on malformed records.

  diff (2 args):
      BENCH_JSON=fresh.json cargo bench --bench hotpath
      BENCH_JSON=fresh.json cargo bench --bench serve_throughput
      python3 tools/check_bench.py BENCH_6.json fresh.json
    Compares a fresh run against the committed trajectory on the
    guarded groups (below): a case regresses when its fresh mean is
    more than MARGIN x the committed mean.  The margin is generous —
    bench hosts differ wildly; this guards against order-of-magnitude
    hot-path regressions, not single-digit noise.  Exit 1 on
    regression.

Cases present in only one file are reported but never fail the check
(benches grow over time).  Groups outside GUARDED are informational.
"""

import json
import sys

# Groups whose means are guarded against regression; everything else in
# the trajectory is context.
GUARDED = {
    "coordinator hot paths",
    "captured replay",
    "serve_throughput",
    # multi-tenant shared-ledger vs independent-placement deployments
    # (PR 8): guards the joint-placement serving hot path
    "serve_throughput multi",
    # energy is a deterministic model quantity, not a host timing — the
    # fig2 measured group should reproduce almost exactly across hosts
    "fig2 energy measured",
    # device–edge spill tier vs degraded-CPU fallback under a missed
    # SLO (PR 9): guards the remote-spill serving hot path
    "serve_throughput remote",
}

# A fresh mean above MARGIN x the committed mean fails the check.
MARGIN = 2.0


def load(path):
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, list):
        raise ValueError(f"{path}: top level must be an array of group records")
    table = {}
    for rec in data:
        group = rec.get("group")
        cases = rec.get("cases")
        if not isinstance(group, str) or not isinstance(cases, list):
            raise ValueError(f"{path}: record missing 'group'/'cases': {rec}")
        for c in cases:
            name = c.get("name")
            if not isinstance(name, str):
                raise ValueError(f"{path}: case in '{group}' missing 'name': {c}")
            for k in ("iters", "mean_ns", "p50_ns", "min_ns"):
                v = c.get(k)
                if not isinstance(v, (int, float)) or v <= 0:
                    raise ValueError(
                        f"{path}: case '{group}/{name}' field '{k}' "
                        f"must be a positive number, got {v!r}"
                    )
            table[(group, name)] = c
    return table


def fmt_ns(ns):
    for unit, div in (("s", 1e9), ("ms", 1e6), ("us", 1e3)):
        if ns >= div:
            return f"{ns / div:.2f} {unit}"
    return f"{ns:.1f} ns"


def main(argv):
    if len(argv) not in (2, 3):
        print(__doc__)
        return 2

    try:
        committed = load(argv[1])
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"FAIL: {e}")
        return 1
    groups = sorted({g for g, _ in committed})
    print(f"{argv[1]}: {len(committed)} cases across {len(groups)} groups")
    for g in groups:
        n = sum(1 for gg, _ in committed if gg == g)
        tag = "guarded" if g in GUARDED else "info"
        print(f"  {g:<28} {n:>2} cases  [{tag}]")

    if len(argv) == 2:
        print("OK: trajectory is well-formed")
        return 0

    try:
        fresh = load(argv[2])
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"FAIL: {e}")
        return 1

    regressions = []
    compared = 0
    for key, base in sorted(committed.items()):
        group, name = key
        if group not in GUARDED:
            continue
        cur = fresh.get(key)
        if cur is None:
            print(f"  skip {group}/{name}: not in fresh run")
            continue
        compared += 1
        ratio = cur["mean_ns"] / base["mean_ns"]
        status = "ok"
        if ratio > MARGIN:
            status = "REGRESSION"
            regressions.append((group, name, ratio))
        print(
            f"  {status:<10} {group}/{name}: committed {fmt_ns(base['mean_ns'])}"
            f" -> fresh {fmt_ns(cur['mean_ns'])} ({ratio:.2f}x)"
        )
    for key in sorted(fresh):
        if key not in committed and key[0] in GUARDED:
            print(f"  new  {key[0]}/{key[1]}: {fmt_ns(fresh[key]['mean_ns'])}")

    if regressions:
        print(f"FAIL: {len(regressions)} case(s) regressed beyond {MARGIN}x")
        return 1
    if compared == 0:
        print("WARN: no guarded cases compared (group names changed?)")
    print("OK: no hot-path regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
