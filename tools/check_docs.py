#!/usr/bin/env python3
"""Docs-integrity check: no dangling cross-references.

Verifies, over the whole repo:
  1. relative markdown links `[text](path)` in *.md point at existing
     files (anchors and external URLs are skipped);
  2. `<NAME>.md` mentions in Rust doc comments and *.md prose refer to
     markdown files that exist at the repo root;
  3. `<NAME>.md §Section` references resolve to a real heading of that
     file (substring match against `#`-headings);
  4. every `cargo bench --bench <name>` reproduce command in README.md
     and EXPERIMENTS.md, and every backticked bench target in README's
     paper-table -> bench map, names a `[[bench]]` target that exists
     in Cargo.toml;
  5. every backticked module path in ARCHITECTURE.md's paper-section ->
     module map names a real `rust/src/<module>` (the leading path
     segment must exist as rust/src/<seg>/ or rust/src/<seg>.rs);
  6. every `ExecStats::<field>` mention in EXPERIMENTS.md names a real
     public field of `exec::ExecStats` (rust/src/exec/mod.rs) — the
     §Energy table documents the per-run ledger by field name, so a
     rename there must not silently orphan the docs;
  7. every analyzer pass named in ARCHITECTURE.md's static-analysis
     pass table exists in the tree — `rust/src/analysis/<pass>.rs` for
     the in-process passes, `tools/check_determinism.py` for the
     source-level determinism lint.

Exit code 0 = clean; 1 = dangling references (each printed).
Run from the repo root: `python3 tools/check_docs.py`.
"""

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)#][^)]*)\)")
MD_FILE = re.compile(r"\b([A-Z][A-Z0-9_]*\.md)\b")
MD_SECTION = re.compile(r"\b([A-Z][A-Z0-9_]*\.md)\s+§([A-Za-z0-9_-]+)")
BENCH_CMD = re.compile(r"cargo bench --bench\s+([A-Za-z0-9_-]+)")
BENCH_NAME = re.compile(r'^\s*name\s*=\s*"([^"]+)"\s*$', re.MULTILINE)


def cargo_bench_targets():
    """Names of all [[bench]] targets declared in the root Cargo.toml."""
    path = os.path.join(ROOT, "Cargo.toml")
    targets = set()
    section = None
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            stripped = line.strip()
            if stripped.startswith("[["):
                section = stripped
                continue
            if section == "[[bench]]":
                m = BENCH_NAME.match(line)
                if m:
                    targets.add(m.group(1))
    return targets


def bench_map_rows(readme_text):
    """Backticked target names from the second column of README's
    paper-table -> bench-target map."""
    rows = []
    in_map = False
    for line in readme_text.splitlines():
        if line.startswith("##"):
            in_map = "bench target" in line.lower()
            continue
        if not in_map or not line.startswith("|"):
            continue
        cells = [c.strip() for c in line.split("|")]
        # cells[0] and cells[-1] are the empty outer splits
        if len(cells) >= 3 and cells[2].startswith("`") and cells[2].endswith("`"):
            rows.append(cells[2].strip("`"))
    return rows


MODULE_TOKEN = re.compile(r"`([A-Za-z_][A-Za-z0-9_:]*)`")


def module_map_rows(arch_text):
    """Backticked module tokens from the second column of
    ARCHITECTURE.md's paper-section -> module map."""
    tokens = []
    in_map = False
    for line in arch_text.splitlines():
        if line.startswith("##"):
            in_map = "module map" in line.lower()
            continue
        if not in_map or not line.startswith("|"):
            continue
        cells = [c.strip() for c in line.split("|")]
        # cells[0]/cells[-1] are the empty outer splits; cells[2] is
        # the Module(s) column (skip the header/separator rows)
        if len(cells) < 4 or cells[2] in ("Module(s)", "") or set(cells[2]) <= {"-"}:
            continue
        tokens.extend(MODULE_TOKEN.findall(cells[2]))
    return tokens


EXEC_STATS_REF = re.compile(r"\bExecStats::([a-z_][a-z0-9_]*)\b")
PUB_FIELD = re.compile(r"^\s*pub\s+([a-z_][a-z0-9_]*)\s*:", re.MULTILINE)


def exec_stats_fields():
    """Public field names of `struct ExecStats` in rust/src/exec/mod.rs."""
    path = os.path.join(ROOT, "rust", "src", "exec", "mod.rs")
    if not os.path.exists(path):
        return None
    text = open(path, encoding="utf-8").read()
    m = re.search(r"pub struct ExecStats\s*\{", text)
    if not m:
        return None
    # body runs to the first closing brace at column start after the
    # struct opens (ExecStats is a plain field struct, no nesting)
    body = text[m.end():]
    end = body.find("\n}")
    if end >= 0:
        body = body[:end]
    return set(PUB_FIELD.findall(body))


def check_exec_stats_refs(problems):
    exp = os.path.join(ROOT, "EXPERIMENTS.md")
    if not os.path.exists(exp):
        return
    refs = set(EXEC_STATS_REF.findall(open(exp, encoding="utf-8").read()))
    if not refs:
        return
    fields = exec_stats_fields()
    if fields is None:
        problems.append(
            "EXPERIMENTS.md names ExecStats fields but rust/src/exec/mod.rs "
            "has no parseable `pub struct ExecStats`"
        )
        return
    for field in sorted(refs):
        if field not in fields:
            problems.append(
                f"EXPERIMENTS.md: ExecStats::{field} is not a pub field of "
                f"exec::ExecStats (rust/src/exec/mod.rs)"
            )


def check_module_map(problems):
    arch = os.path.join(ROOT, "ARCHITECTURE.md")
    if not os.path.exists(arch):
        return
    tokens = module_map_rows(open(arch, encoding="utf-8").read())
    if not tokens:
        problems.append(
            "ARCHITECTURE.md: paper-section -> module map has no parseable "
            "module tokens (expected a '## ... module map' table)"
        )
        return
    src = os.path.join(ROOT, "rust", "src")
    for token in tokens:
        seg = token.split("::")[0]
        if not (
            os.path.isdir(os.path.join(src, seg))
            or os.path.exists(os.path.join(src, seg + ".rs"))
        ):
            problems.append(
                f"ARCHITECTURE.md: module-map row names `{token}` but "
                f"rust/src/{seg} does not exist"
            )


def analysis_pass_rows(arch_text):
    """Backticked pass names from the first column of ARCHITECTURE.md's
    static-analysis pass table."""
    passes = []
    in_table = False
    for line in arch_text.splitlines():
        if line.startswith("##"):
            in_table = "static analysis" in line.lower()
            continue
        if not in_table or not line.startswith("|"):
            continue
        cells = [c.strip() for c in line.split("|")]
        if len(cells) >= 3 and cells[1].startswith("`") and cells[1].endswith("`"):
            passes.append(cells[1].strip("`"))
    return passes


def check_analysis_passes(problems):
    arch = os.path.join(ROOT, "ARCHITECTURE.md")
    if not os.path.exists(arch):
        return
    passes = analysis_pass_rows(open(arch, encoding="utf-8").read())
    if not passes:
        problems.append(
            "ARCHITECTURE.md: static-analysis pass table has no parseable "
            "rows (expected a '## Static analysis' table with backticked "
            "pass names in column 1)"
        )
        return
    for name in passes:
        if name == "determinism":
            # Source-level lint lives in tools/, not in the analyzer crate.
            if not os.path.exists(os.path.join(ROOT, "tools", "check_determinism.py")):
                problems.append(
                    "ARCHITECTURE.md: pass `determinism` listed but "
                    "tools/check_determinism.py does not exist"
                )
            continue
        path = os.path.join(ROOT, "rust", "src", "analysis", name + ".rs")
        if not os.path.exists(path):
            problems.append(
                f"ARCHITECTURE.md: pass `{name}` listed but "
                f"rust/src/analysis/{name}.rs does not exist"
            )


def repo_files(exts):
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [
            d for d in dirnames if d not in {".git", "target", "node_modules"}
        ]
        for f in filenames:
            if any(f.endswith(e) for e in exts):
                yield os.path.join(dirpath, f)


def headings(md_path):
    heads = []
    with open(md_path, encoding="utf-8") as fh:
        in_code = False
        for line in fh:
            if line.lstrip().startswith("```"):
                in_code = not in_code
                continue
            if not in_code and line.startswith("#"):
                heads.append(line.lstrip("#").strip())
    return heads


def main():
    problems = []

    # 1. relative markdown links
    for md in repo_files([".md"]):
        text = open(md, encoding="utf-8").read()
        for target in MD_LINK.findall(text):
            target = target.split("#")[0].strip()
            if not target or "://" in target or target.startswith("mailto:"):
                continue
            resolved = os.path.normpath(os.path.join(os.path.dirname(md), target))
            if not os.path.exists(resolved):
                problems.append(
                    f"{os.path.relpath(md, ROOT)}: broken link -> {target}"
                )

    # 2 + 3. <NAME>.md (§Section) mentions in sources and docs
    known_md = {
        os.path.basename(p): p for p in repo_files([".md"])
    }
    for src in list(repo_files([".rs", ".py", ".md", ".toml", ".yml"])):
        rel = os.path.relpath(src, ROOT)
        if rel.startswith("tools" + os.sep):
            continue  # this checker's own docs
        if rel == "ISSUE.md":
            continue  # transient work order; cites paper sections, not repo headings
        text = open(src, encoding="utf-8", errors="replace").read()
        for name in set(MD_FILE.findall(text)):
            if name not in known_md:
                problems.append(f"{rel}: references missing file {name}")
        for name, section in set(MD_SECTION.findall(text)):
            if name not in known_md:
                continue  # already reported above
            heads = headings(known_md[name])
            if not any(section.lower() in h.lower() for h in heads):
                problems.append(
                    f"{rel}: {name} §{section} has no matching heading"
                )

    # 4. bench reproduce commands + README bench-map rows must name
    #    real Cargo.toml [[bench]] targets
    targets = cargo_bench_targets()
    for name in ("README.md", "EXPERIMENTS.md"):
        path = os.path.join(ROOT, name)
        if not os.path.exists(path):
            continue
        text = open(path, encoding="utf-8").read()
        for target in set(BENCH_CMD.findall(text)):
            if target not in targets:
                problems.append(
                    f"{name}: `cargo bench --bench {target}` names no "
                    f"Cargo.toml [[bench]] target"
                )
    readme = os.path.join(ROOT, "README.md")
    if os.path.exists(readme):
        rows = bench_map_rows(open(readme, encoding="utf-8").read())
        if not rows:
            problems.append(
                "README.md: paper-table -> bench map has no parseable rows "
                "(expected a '## ... bench target ...' table)"
            )
        for target in rows:
            if target not in targets:
                problems.append(
                    f"README.md: bench-map row `{target}` names no "
                    f"Cargo.toml [[bench]] target"
                )

    # 5. ARCHITECTURE.md module-map rows must name real rust/src modules
    check_module_map(problems)

    # 6. EXPERIMENTS.md ExecStats field mentions must exist in the struct
    check_exec_stats_refs(problems)

    # 7. ARCHITECTURE.md static-analysis passes must exist in the tree
    check_analysis_passes(problems)

    if problems:
        print("docs-integrity check FAILED:")
        for p in sorted(problems):
            print(f"  {p}")
        return 1
    print("docs-integrity check OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
