#!/usr/bin/env python3
"""Docs-integrity check: no dangling cross-references.

Verifies, over the whole repo:
  1. relative markdown links `[text](path)` in *.md point at existing
     files (anchors and external URLs are skipped);
  2. `<NAME>.md` mentions in Rust doc comments and *.md prose refer to
     markdown files that exist at the repo root;
  3. `<NAME>.md §Section` references resolve to a real heading of that
     file (substring match against `#`-headings).

Exit code 0 = clean; 1 = dangling references (each printed).
Run from the repo root: `python3 tools/check_docs.py`.
"""

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)#][^)]*)\)")
MD_FILE = re.compile(r"\b([A-Z][A-Z0-9_]*\.md)\b")
MD_SECTION = re.compile(r"\b([A-Z][A-Z0-9_]*\.md)\s+§([A-Za-z0-9_-]+)")


def repo_files(exts):
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [
            d for d in dirnames if d not in {".git", "target", "node_modules"}
        ]
        for f in filenames:
            if any(f.endswith(e) for e in exts):
                yield os.path.join(dirpath, f)


def headings(md_path):
    heads = []
    with open(md_path, encoding="utf-8") as fh:
        in_code = False
        for line in fh:
            if line.lstrip().startswith("```"):
                in_code = not in_code
                continue
            if not in_code and line.startswith("#"):
                heads.append(line.lstrip("#").strip())
    return heads


def main():
    problems = []

    # 1. relative markdown links
    for md in repo_files([".md"]):
        text = open(md, encoding="utf-8").read()
        for target in MD_LINK.findall(text):
            target = target.split("#")[0].strip()
            if not target or "://" in target or target.startswith("mailto:"):
                continue
            resolved = os.path.normpath(os.path.join(os.path.dirname(md), target))
            if not os.path.exists(resolved):
                problems.append(
                    f"{os.path.relpath(md, ROOT)}: broken link -> {target}"
                )

    # 2 + 3. <NAME>.md (§Section) mentions in sources and docs
    known_md = {
        os.path.basename(p): p for p in repo_files([".md"])
    }
    for src in list(repo_files([".rs", ".py", ".md", ".toml", ".yml"])):
        rel = os.path.relpath(src, ROOT)
        if rel.startswith("tools" + os.sep):
            continue  # this checker's own docs
        text = open(src, encoding="utf-8", errors="replace").read()
        for name in set(MD_FILE.findall(text)):
            if name not in known_md:
                problems.append(f"{rel}: references missing file {name}")
        for name, section in set(MD_SECTION.findall(text)):
            if name not in known_md:
                continue  # already reported above
            heads = headings(known_md[name])
            if not any(section.lower() in h.lower() for h in heads):
                problems.append(
                    f"{rel}: {name} §{section} has no matching heading"
                )

    if problems:
        print("docs-integrity check FAILED:")
        for p in sorted(problems):
            print(f"  {p}")
        return 1
    print("docs-integrity check OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
