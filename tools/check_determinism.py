#!/usr/bin/env python3
"""Determinism lint: source patterns that make runs non-reproducible.

Parallax's experiment tables are pinned byte-for-byte (EXPERIMENTS.md),
which only holds if the runtime never consults ambient entropy.  This
lint walks rust/src/ and flags the three ways that guarantee has been
lost in practice:

  R1  unseeded RNG — `thread_rng`, `from_entropy`, `rand::random`, or
      `RandomState::new` anywhere in rust/src.  Every stochastic
      component must take an explicit seed (util::rng::Rng).
  R2  wall-clock reads in deterministic layers — `Instant::now()` /
      `SystemTime::now()` inside exec/, sched/, memory/, ctrl/ or
      place/, except the timing-harness idiom `let <var> = Instant::now()`
      (binding a start time to measure *real* latency is the point of a
      benchmark; branching on it inside planning code is not).
  R3  keyed-map iteration feeding float accumulation — iterating a
      `HashMap`/`HashSet`-typed local (`.values()`/`.iter()`/`.keys()`)
      in the same statement as a float fold (`sum`, `+=`, `fold`).
      HashMap iteration order is randomized per process, and float
      addition is not associative, so such a fold differs run to run.
      Sorting within the statement (`.sorted`, `sort_by`, BTreeMap)
      exempts the line.

A line ending with `// det-ok: <reason>` is exempt from all rules —
the reason is mandatory and reviewed like a `#[allow]`.

Exit code 0 = clean; 1 = findings (each printed as
`<file>:<line>: R<n> <message>`).

Run from the repo root: `python3 tools/check_determinism.py`.
Self-check the lint itself: `python3 tools/check_determinism.py --self-test`.
"""

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "rust", "src")

# Layers that must stay wall-clock free (R2).  The eval/ and serving
# harnesses intentionally measure real time; the planning and replay
# layers must not.
CLOCK_FREE_DIRS = ("exec", "sched", "memory", "ctrl", "place")

PRAGMA = re.compile(r"//\s*det-ok:\s*\S")

R1_UNSEEDED = re.compile(r"\b(thread_rng|from_entropy|rand::random|RandomState::new)\b")
R2_CLOCK = re.compile(r"\b(Instant::now|SystemTime::now)\s*\(")
R2_BINDING = re.compile(r"\blet\s+\w+\s*=\s*(std::time::)?Instant::now\s*\(\s*\)\s*;")
R3_MAP_DECL = re.compile(r"\b(?:let|let\s+mut)\s+(\w+)\s*:\s*Hash(?:Map|Set)\b"
                         r"|\b(?:let|let\s+mut)\s+(\w+)\s*=\s*Hash(?:Map|Set)\s*::")
R3_FLOAT_FOLD = re.compile(r"(\.sum::<f(32|64)>|\bfold\s*\(|\+=)")
R3_SORTED = re.compile(r"(sort|BTreeMap|BTreeSet)")


def lint_lines(relpath, lines):
    """Findings for one file, as (line_no, rule, message) tuples."""
    findings = []
    parts = relpath.replace("\\", "/").split("/")
    clock_free = any(p in CLOCK_FREE_DIRS for p in parts)
    map_vars = set()
    for i, line in enumerate(lines, start=1):
        if PRAGMA.search(line):
            continue
        code = line.split("//")[0]

        m = R1_UNSEEDED.search(code)
        if m:
            findings.append((i, "R1", f"unseeded RNG `{m.group(1)}` — take an "
                             "explicit seed (util::rng::Rng)"))

        if clock_free:
            m = R2_CLOCK.search(code)
            if m and not R2_BINDING.search(code):
                findings.append((i, "R2", f"wall-clock `{m.group(1)}()` in a "
                                 "deterministic layer — thread a modelled "
                                 "time or a start-instant binding instead"))

        m = R3_MAP_DECL.search(code)
        if m:
            map_vars.add(m.group(1) or m.group(2))
        for var in map_vars:
            if re.search(rf"\b{re.escape(var)}\s*\.\s*(values|iter|keys)\s*\(", code):
                if R3_FLOAT_FOLD.search(code) and not R3_SORTED.search(code):
                    findings.append((i, "R3", f"HashMap `{var}` iterated into a "
                                     "float fold — order is per-process random; "
                                     "sort first or use a BTreeMap"))
    return findings


def lint_tree():
    findings = []
    for dirpath, _dirnames, filenames in os.walk(SRC):
        for name in sorted(filenames):
            if not name.endswith(".rs"):
                continue
            path = os.path.join(dirpath, name)
            rel = os.path.relpath(path, ROOT)
            with open(path, encoding="utf-8") as fh:
                lines = fh.read().splitlines()
            for line_no, rule, msg in lint_lines(rel, lines):
                findings.append(f"{rel}:{line_no}: {rule} {msg}")
    return findings


# --- self-test -------------------------------------------------------------

BAD_SNIPPETS = [
    # (fake path, source, expected rule)
    ("rust/src/eval/bad.rs", "let mut rng = rand::thread_rng();", "R1"),
    ("rust/src/util/bad.rs", "let h = RandomState::new();", "R1"),
    ("rust/src/sched/bad.rs", "if Instant::now() > deadline { park(); }", "R2"),
    ("rust/src/exec/bad.rs", "stats.push(SystemTime::now());", "R2"),
    ("rust/src/memory/bad.rs",
     "let m = HashMap::new();\nlet total: f64 = m.values().sum::<f64>();", "R3"),
    ("rust/src/place/bad.rs",
     "let mut m: HashMap<u32, f64> = Default::default();\n"
     "for v in m.values() { acc += v; }", "R3"),
]

OK_SNIPPETS = [
    # Patterns the lint must NOT flag.
    ("rust/src/exec/ok.rs", "let start = Instant::now();"),          # harness idiom
    ("rust/src/eval/ok.rs", "let t = Instant::now();"),              # non-clock-free dir
    ("rust/src/memory/ok.rs",
     "let m = HashMap::new();\n"
     "let mut v: Vec<f64> = m.values().copied().collect(); v.sort_by(f64::total_cmp);"),
    ("rust/src/sched/ok.rs",
     "let x = thread_rng(); // det-ok: quoted in a doc example, never run"),
]


def self_test():
    failures = []
    for path, src, rule in BAD_SNIPPETS:
        got = lint_lines(path, src.splitlines())
        if not any(r == rule for _, r, _ in got):
            failures.append(f"self-test: expected {rule} in {path!r}, got {got}")
    for path, src in OK_SNIPPETS:
        got = lint_lines(path, src.splitlines())
        if got:
            failures.append(f"self-test: expected clean for {path!r}, got {got}")
    if failures:
        print("\n".join(failures))
        return 1
    print(f"self-test ok: {len(BAD_SNIPPETS)} bad snippets flagged, "
          f"{len(OK_SNIPPETS)} good snippets clean")
    return 0


def main():
    if "--self-test" in sys.argv[1:]:
        return self_test()
    findings = lint_tree()
    if findings:
        print("\n".join(findings))
        print(f"\n{len(findings)} determinism finding(s)")
        return 1
    print("determinism lint clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
